//! Fine-tuning scenario (the Table-3 workload at laptop scale): compare
//! every BP-optimization method on the synthetic vision task, including
//! HOT+LoRA, and print a Table-3-shaped summary.
//!
//! Run: `cargo run --release --example finetune_vision -- [--steps 60]`

use anyhow::Result;
use hot::backend::Executor;
use hot::config::RunConfig;
use hot::coordinator::{LoraTrainer, Trainer};
use hot::util::args::Args;
use hot::util::timer::Table;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let steps = args.usize_or("steps", 60);
    let rt = hot::backend::by_name(&args.str_or("backend", "auto"),
                                   &args.str_or("artifacts", "artifacts"))?;
    println!("backend: {}", rt.name());

    let mut table = Table::new(&["method", "final loss", "eval acc",
                                 "steps/s"]);

    for variant in ["fp", "lora", "luq", "lbp", "hot", "hot+lora"] {
        let mut cfg = RunConfig::default();
        cfg.preset = "small".into();
        cfg.steps = steps;
        cfg.lr = 1e-3;
        cfg.warmup_steps = steps / 10 + 1;
        cfg.eval_every = 0;
        let (loss, acc, sps) = match variant {
            "lora" | "hot+lora" => {
                let key = if variant == "lora" { "lora_fp_small" }
                          else { "lora_hotfrozen_small" };
                let mut tr = LoraTrainer::new(rt.clone(), cfg, key)?;
                for _ in 0..steps {
                    tr.step_once()?;
                }
                (tr.metrics.smoothed_loss(8).unwrap(),
                 tr.metrics.records.last().unwrap().acc,
                 tr.metrics.throughput_steps_per_s())
            }
            v => {
                cfg.variant = v.into();
                cfg.calib_batches = if v == "hot" { 2 } else { 0 };
                let mut tr = Trainer::new(rt.clone(), cfg)?;
                tr.calibrate()?;
                for _ in 0..steps {
                    tr.step_once(hot::coordinator::Mode::Fused)?;
                }
                let (_, ea) = tr.eval(4)?;
                (tr.metrics.smoothed_loss(8).unwrap(), ea,
                 tr.metrics.throughput_steps_per_s())
            }
        };
        table.row(&[variant.into(), format!("{loss:.4}"),
                    format!("{acc:.4}"), format!("{sps:.2}")]);
    }
    table.print(&format!(
        "fine-tuning comparison, {steps} steps (Table 3 at synthetic scale)"));
    Ok(())
}
