//! LQS calibration walkthrough (paper §5.2.2, Fig 6/9).
//!
//! Runs the calibration artifact over clean data and over data with an
//! injected token outlier, prints the per-layer MSE statistics, the
//! outlier rankings, and the resulting per-token/per-tensor selection.
//!
//! Run: `cargo run --release --example lqs_calibration`

use std::sync::Arc;

use anyhow::Result;
use hot::backend::Executor;
use hot::config::RunConfig;
use hot::coordinator::lqs::CalibReport;
use hot::coordinator::Trainer;
use hot::data::VisionDataset;
use hot::util::timer::Table;

fn calib_with(rt: &Arc<dyn Executor>, tr: &Trainer, ds: &VisionDataset,
              outlier: Option<(usize, f32)>) -> Result<CalibReport> {
    let batch = tr.batch_size();
    let mut per_batch = Vec::new();
    for b in 0..2u64 {
        let (x, y) = match outlier {
            None => ds.batch(2, b, batch),
            Some((tok, gain)) => ds.batch_with_outlier(2, b, batch, tok, gain),
        };
        per_batch.push(rt.calib_step(&format!("calib_{}", tr.cfg.preset),
                                     &tr.weights, &x, &y)?);
    }
    CalibReport::from_batches(&tr.preset.qlinears, &per_batch, 0.5)
}

fn main() -> Result<()> {
    let rt = hot::backend::by_name("auto", "artifacts")?;
    println!("backend: {}", rt.name());
    let mut cfg = RunConfig::default();
    cfg.preset = "small".into();
    let tr = Trainer::new(rt.clone(), cfg)?;
    let model = &tr.preset.model;
    let ds = VisionDataset::new(model.seq, model.in_dim, model.n_classes, 7);

    let clean = calib_with(&rt, &tr, &ds, None)?;
    let spiky = calib_with(&rt, &tr, &ds, Some((5, 40.0)))?;

    let mut t = Table::new(&["layer", "outlier(clean)", "outlier(spiky)",
                             "mse_tensor", "mse_token", "LQS choice"]);
    for (lc, ls) in clean.layers.iter().zip(&spiky.layers) {
        let per_token = {
            let rel = (ls.mse_tensor - ls.mse_token)
                / ls.mse_tensor.max(1e-12);
            rel >= 0.5
        };
        t.row(&[
            lc.name.clone(),
            format!("{:.2}", lc.outlier_ratio),
            format!("{:.2}", ls.outlier_ratio),
            format!("{:.2e}", ls.mse_tensor),
            format!("{:.2e}", ls.mse_token),
            if per_token { "per-token".into() } else { "per-tensor".into() },
        ]);
    }
    t.print("LQS calibration: clean vs token-outlier data (Fig 6/9)");

    println!("\nper-token layers, clean data : {}", clean.n_per_token());
    println!("per-token layers, spiky data : {}", spiky.n_per_token());
    println!("top-3 outlier layers (spiky):");
    for (name, ratio) in spiky.outlier_ranking().into_iter().take(3) {
        println!("  {name}: {ratio:.2}");
    }
    Ok(())
}
