//! Memory planner: given a zoo architecture and a device budget, find the
//! largest feasible batch per method and print the Fig-1 style sweep —
//! the practical "can I train this on my 24 GB card?" tool the paper's
//! intro motivates.
//!
//! Run: `cargo run --release --example memory_planner -- \
//!        [--model vit_b] [--budget-gb 24]`

use anyhow::{bail, Result};
use hot::costmodel::{breakdown, max_feasible_batch, zoo, MemMethod};
use hot::util::args::Args;
use hot::util::timer::Table;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let model = args.str_or("model", "vit_b");
    let budget = args.f64_or("budget-gb", 24.0);
    let spec = match model.as_str() {
        "vit_b" => zoo::vit_b(),
        "vit_s" => zoo::vit_s(),
        "resnet50" => zoo::resnet50(),
        "resnet18" => zoo::resnet18(),
        "efficientformer_l7" => zoo::efficientformer_l7(),
        "efficientformer_l1" => zoo::efficientformer_l1(),
        m => bail!("unknown model {m}"),
    };
    let methods: [(&str, MemMethod); 5] = [
        ("FP", MemMethod::Fp32),
        ("LBP-WHT/LUQ", MemMethod::FpActivations),
        ("LoRA", MemMethod::Lora { r_lora: 8 }),
        ("HOT", MemMethod::Hot { rank: 8, abc: true }),
        ("HOT+LoRA", MemMethod::HotLora { rank: 8, r_lora: 8 }),
    ];
    let batches = [32, 64, 128, 256, 512, 1024, 2048];

    let mut t = Table::new(&["method", "b=64", "b=256", "b=1024",
                             "max batch @ budget"]);
    for (name, m) in methods {
        let gb = |b: usize| format!("{:.1}", breakdown(&spec, b, m).gb());
        let max = max_feasible_batch(&spec, &batches, m, budget)
            .map(|b| b.to_string())
            .unwrap_or_else(|| "none".into());
        t.row(&[name.into(), gb(64), gb(256), gb(1024), max]);
    }
    t.print(&format!("{} memory (GB) vs batch — budget {budget} GB (Fig 1)",
                     spec.name));

    println!("\nparams: {:.1}M, backward MACs/sample: {:.2}G",
             spec.params() as f64 / 1e6,
             2.0 * spec.total_macs() as f64 / 1e9);
    Ok(())
}
