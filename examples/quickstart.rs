//! Quickstart: the whole three-layer stack in one page.
//!
//! 1. picks an execution backend (native CPU by default; PJRT artifacts
//!    when built with `--features pjrt` and `make artifacts` has run)
//! 2. runs the HQ kernel demo (Pallas-lowered HLO on PJRT; the bit-level
//!    mirror on the native backend)
//! 3. fine-tunes the `small` ViT for a handful of steps with HOT
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;
use hot::backend::Executor;
use hot::config::RunConfig;
use hot::coordinator::{Mode, Trainer};
use hot::runtime::Value;
use hot::util::prng::Pcg32;

fn main() -> Result<()> {
    // --- 1. backend -------------------------------------------------------
    let rt = hot::backend::by_name("auto", "artifacts")?;
    println!("{}", rt.describe());

    // --- 2. the HQ kernel demo --------------------------------------------
    // On PJRT this is pl.pallas_call(...) lowered into HLO; natively it's
    // the same math host-side: g_x = dequant(Q4(HT(g_y)) @ Q4(HT(w))).
    let mut rng = Pcg32::seeded(0);
    let gy: Vec<f32> = (0..64 * 64).map(|_| rng.normal()).collect();
    let w: Vec<f32> = (0..64 * 48).map(|_| rng.normal()).collect();
    let out = rt.execute_raw(
        "kernel_hq_demo",
        &[
            Value::F32 { shape: vec![64, 64], data: gy },
            Value::F32 { shape: vec![64, 48], data: w },
        ],
    )?;
    println!("HQ kernel: g_x shape {:?}, g_x[0..4] = {:?}",
             out[0].shape(), &out[0].as_f32()?[..4]);

    // --- 3. a short HOT fine-tune -----------------------------------------
    let mut cfg = RunConfig::default();
    cfg.preset = "small".into();
    cfg.variant = "hot".into();
    cfg.steps = 12;
    cfg.calib_batches = 1;
    cfg.warmup_steps = 2;
    let mut tr = Trainer::new(rt, cfg)?;
    tr.calibrate()?; // LQS: pick per-token vs per-tensor per layer
    for _ in 0..12 {
        tr.step_once(Mode::Fused)?;
    }
    println!("loss curve: {}", tr.metrics.curve_string(3));
    let (el, ea) = tr.eval(4)?;
    println!("eval after 12 steps: loss {el:.4} acc {ea:.3}");
    Ok(())
}
