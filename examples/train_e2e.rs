//! End-to-end driver (DESIGN.md §End-to-end validation).
//!
//! Trains the ViT-style transformer on the synthetic vision corpus for a
//! few hundred steps, HOT vs FP side by side, and reports:
//!   * both loss curves (logged for EXPERIMENTS.md)
//!   * final eval accuracy for both
//!   * ABC context-buffer stats from a split-mode segment (the rust-held
//!     compressed CTX of the paper's Fig 5)
//!   * throughput
//!
//! Run: `cargo run --release --example train_e2e -- [--steps 200]
//!       [--preset small] [--variant hot] [--csv out.csv]`

use std::sync::Arc;

use anyhow::Result;
use hot::backend::Executor;
use hot::config::RunConfig;
use hot::coordinator::{Mode, Trainer};
use hot::util::args::Args;

fn run(rt: Arc<dyn Executor>, preset: &str, variant: &str, steps: usize,
       seed: u64) -> Result<Trainer> {
    let mut cfg = RunConfig::default();
    cfg.preset = preset.into();
    cfg.variant = variant.into();
    cfg.steps = steps;
    cfg.seed = seed;
    cfg.lr = 1e-3;
    cfg.warmup_steps = steps / 20 + 1;
    cfg.calib_batches = if variant == "hot" { 2 } else { 0 };
    cfg.eval_every = (steps / 4).max(1);
    let mut tr = Trainer::new(rt, cfg)?;
    tr.train()?;
    Ok(tr)
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1)); // skip `--example x`
    let steps = args.usize_or("steps", 200);
    let preset = args.str_or("preset", "small");
    let seed = args.u64_or("seed", 0);
    let rt = hot::backend::by_name(&args.str_or("backend", "auto"),
                                   &args.str_or("artifacts", "artifacts"))?;
    println!("backend: {}", rt.name());

    println!("== end-to-end: {preset} for {steps} steps, HOT vs FP ==");
    let hot_tr = run(rt.clone(), &preset, "hot", steps, seed)?;
    let fp_tr = run(rt.clone(), &preset, "fp", steps, seed)?;

    println!("\nHOT loss curve: {}", hot_tr.metrics.curve_string(steps / 10 + 1));
    println!("FP  loss curve: {}", fp_tr.metrics.curve_string(steps / 10 + 1));
    let (hl, ha) = (hot_tr.metrics.evals.last().unwrap().1,
                    hot_tr.metrics.evals.last().unwrap().2);
    let (fl, fa) = (fp_tr.metrics.evals.last().unwrap().1,
                    fp_tr.metrics.evals.last().unwrap().2);
    println!("\nfinal eval  HOT: loss {hl:.4} acc {ha:.4}");
    println!("final eval  FP : loss {fl:.4} acc {fa:.4}");
    println!("acc gap (FP - HOT): {:+.4}  (paper: <1% on fine-tuning)",
             fa - ha);
    println!("throughput  HOT: {:.2} steps/s, FP: {:.2} steps/s",
             hot_tr.metrics.throughput_steps_per_s(),
             fp_tr.metrics.throughput_steps_per_s());

    // --- split-mode segment: rust-owned ABC buffers ------------------------
    let mut cfg = RunConfig::default();
    cfg.preset = preset.clone();
    cfg.variant = "hot".into();
    cfg.steps = 8;
    cfg.calib_batches = 0;
    let mut sp = Trainer::new(rt.clone(), cfg)?;
    for _ in 0..8 {
        sp.step_once(Mode::Split)?;
    }
    let st = sp.state.ctx.stats();
    println!("\nABC ctx (split mode, 8 steps): peak {} KiB, \
              fp32-equivalent {} KiB, compression {:.2}x",
             st.peak_bytes / 1024, st.fp32_equiv_bytes / 1024 / 8,
             sp.state.ctx.compression_ratio());

    if let Some(csv) = args.get("csv") {
        hot_tr.metrics.save_csv(csv)?;
        println!("HOT metrics -> {csv}");
    }
    Ok(())
}
