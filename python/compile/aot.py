"""AOT artifact emitter: lower L2 graphs to HLO *text* + manifest.json.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` 0.1.6 crate links) rejects; the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Usage (from python/):  python -m compile.aot --suite default --out ../artifacts

Every artifact's calling convention (flat input/output lists with names,
shapes, dtypes) is recorded in manifest.json; initial parameters are
dumped as raw little-endian f32 blobs so the rust coordinator starts from
the exact same state pytest verified.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import lora as LR
from compile import model as M
from compile import train as T
from compile.config import BackwardConfig, ModelConfig, OptimizerConfig, PRESETS


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: the default printer elides non-scalar constants as "{...}",
    # which xla_extension 0.5.1's text parser silently materializes as
    # ZEROS — every Hadamard matrix in the graphs would vanish. Print
    # large constants in full, and strip source metadata (the old parser
    # rejects the newer `source_end_line` attribute).
    po = xc._xla.HloPrintOptions()
    po.print_large_constants = True
    po.print_metadata = False
    text = comp.as_hlo_module().to_string(po)
    assert "{...}" not in text, "constant elision leaked into HLO text"
    return text


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def anchor(first_out, args):
    """Tie a zero-valued function of EVERY input into ``first_out``.

    jax.jit silently drops unused arguments at trace time (e.g. the FP
    variant never reads lqs_mask), which would desynchronize the HLO
    parameter list from the manifest calling convention. Entry parameters
    are never removed once they exist in the module, so a 0-weighted sum
    is enough to pin them."""
    z = jnp.float32(0.0)
    for a in args:
        s = jnp.sum(a)
        z = z + 0.0 * s.astype(jnp.float32)
    return first_out + z


def _sd(name, s):
    return {"name": name, "shape": [int(d) for d in s.shape],
            "dtype": str(s.dtype)}


class Emitter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest = {"presets": {}, "artifacts": {}}
        os.makedirs(out_dir, exist_ok=True)

    def add_preset(self, name: str, cfg: ModelConfig, seed: int = 0):
        params = M.init_params(cfg, seed=seed)
        names = M.param_names(cfg)
        blob = b"".join(np.asarray(params[k], np.float32).tobytes()
                        for k in names)
        path = f"params_init_{name}.bin"
        with open(os.path.join(self.out_dir, path), "wb") as f:
            f.write(blob)
        self.manifest["presets"][name] = {
            "model": {
                "arch": cfg.arch, "d_model": cfg.d_model, "depth": cfg.depth,
                "heads": cfg.heads, "seq": cfg.seq, "in_dim": cfg.in_dim,
                "n_classes": cfg.n_classes, "mlp_ratio": cfg.mlp_ratio,
            },
            "params": [{"name": k, "shape": [int(d) for d in params[k].shape],
                        "dtype": "float32"} for k in names],
            "qlinears": M.qlinear_names(cfg),
            "init_blob": path,
            "init_seed": seed,
        }
        return params, names

    def emit(self, key: str, fn, in_specs, in_names, out_names,
             meta: dict):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        path = f"{key}.hlo.txt"
        with open(os.path.join(self.out_dir, path), "w") as f:
            f.write(text)
        entry = dict(meta)
        entry["file"] = path
        entry["inputs"] = [_sd(n, s) for n, s in zip(in_names, in_specs)]
        out_shapes = jax.eval_shape(fn, *in_specs)
        flat, _ = jax.tree_util.tree_flatten(out_shapes)
        assert len(flat) == len(out_names), (key, len(flat), len(out_names))
        entry["outputs"] = [_sd(n, s) for n, s in zip(out_names, flat)]
        self.manifest["artifacts"][key] = entry
        print(f"  {key}: {len(text) / 1e6:.2f} MB HLO in "
              f"{time.time() - t0:.1f}s")

    def finish(self):
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        print(f"wrote manifest with {len(self.manifest['artifacts'])} artifacts")


# ---------------------------------------------------------------------------
# Flat-arg wrappers (HLO parameters are positional; dicts flatten in
# model.param_names order)
# ---------------------------------------------------------------------------


def _x_spec(cfg: ModelConfig, batch: int):
    if cfg.arch == "lm":
        return spec((batch, cfg.seq), jnp.int32)
    return spec((batch, cfg.seq, cfg.in_dim))


def _y_spec(cfg: ModelConfig, batch: int):
    if cfg.arch == "lm":
        return spec((batch, cfg.seq), jnp.int32)
    return spec((batch,), jnp.int32)


def build_train_step(cfg, bcfg, ocfg, batch):
    names = M.param_names(cfg)
    params0 = M.init_params(cfg)
    p_specs = [spec(params0[k].shape) for k in names]
    np_ = len(names)
    step_fn = T.make_train_step(cfg, bcfg, ocfg)

    def flat(*args):
        p = dict(zip(names, args[:np_]))
        m = dict(zip(names, args[np_:2 * np_]))
        v = dict(zip(names, args[2 * np_:3 * np_]))
        step, lr, mask, x, y = args[3 * np_:]
        new_p, new_m, new_v, loss, acc = step_fn(p, m, v, step, lr, mask, x, y)
        return (*[new_p[k] for k in names], *[new_m[k] for k in names],
                *[new_v[k] for k in names], anchor(loss, args), acc)

    in_specs = (p_specs + p_specs + p_specs
                + [spec(()), spec(()), spec((cfg.n_qlinears(),)),
                   _x_spec(cfg, batch), _y_spec(cfg, batch)])
    in_names = ([f"param.{k}" for k in names] + [f"m.{k}" for k in names]
                + [f"v.{k}" for k in names]
                + ["step", "lr", "lqs_mask", "x", "y"])
    out_names = ([f"param.{k}" for k in names] + [f"m.{k}" for k in names]
                 + [f"v.{k}" for k in names] + ["loss", "acc"])
    return flat, in_specs, in_names, out_names


def build_eval_step(cfg, batch):
    names = M.param_names(cfg)
    params0 = M.init_params(cfg)
    p_specs = [spec(params0[k].shape) for k in names]
    ev = T.make_eval_step(cfg, BackwardConfig(variant="fp"))

    def flat(*args):
        p = dict(zip(names, args[:len(names)]))
        x, y = args[len(names):]
        loss, acc = ev(p, x, y)
        return (anchor(loss, args), acc)

    in_specs = p_specs + [_x_spec(cfg, batch), _y_spec(cfg, batch)]
    in_names = [f"param.{k}" for k in names] + ["x", "y"]
    return flat, in_specs, in_names, ["loss", "acc"]


def build_grad_step(cfg, bcfg, batch):
    names = M.param_names(cfg)
    params0 = M.init_params(cfg)
    p_specs = [spec(params0[k].shape) for k in names]
    gf = T.make_grad_step(cfg, bcfg)

    def flat(*args):
        p = dict(zip(names, args[:len(names)]))
        mask, x, y = args[len(names):]
        grads, loss, acc = gf(p, mask, x, y)
        return (*[grads[k] for k in names], anchor(loss, args), acc)

    in_specs = p_specs + [spec((cfg.n_qlinears(),)),
                          _x_spec(cfg, batch), _y_spec(cfg, batch)]
    in_names = [f"param.{k}" for k in names] + ["lqs_mask", "x", "y"]
    out_names = [f"grad.{k}" for k in names] + ["loss", "acc"]
    return flat, in_specs, in_names, out_names


def build_opt_step(cfg, ocfg):
    names = M.param_names(cfg)
    params0 = M.init_params(cfg)
    p_specs = [spec(params0[k].shape) for k in names]
    np_ = len(names)
    of = T.make_opt_step(cfg, ocfg)

    def flat(*args):
        p = dict(zip(names, args[:np_]))
        g = dict(zip(names, args[np_:2 * np_]))
        m = dict(zip(names, args[2 * np_:3 * np_]))
        v = dict(zip(names, args[3 * np_:4 * np_]))
        step, lr = args[4 * np_:]
        new_p, new_m, new_v = of(p, g, m, v, step, lr)
        first = anchor(new_p[names[0]], args)
        rest = [new_p[k] for k in names[1:]]
        return (first, *rest, *[new_m[k] for k in names],
                *[new_v[k] for k in names])

    in_specs = p_specs * 4 + [spec(()), spec(())]
    in_names = ([f"param.{k}" for k in names] + [f"grad.{k}" for k in names]
                + [f"m.{k}" for k in names] + [f"v.{k}" for k in names]
                + ["step", "lr"])
    out_names = ([f"param.{k}" for k in names] + [f"m.{k}" for k in names]
                 + [f"v.{k}" for k in names])
    return flat, in_specs, in_names, out_names


def build_split_steps(cfg, bcfg, batch):
    names = M.param_names(cfg)
    params0 = M.init_params(cfg)
    p_specs = [spec(params0[k].shape) for k in names]
    fwd, bwd, schema = T.make_split_steps(cfg, bcfg, batch)

    ctx_names, ctx_meta = [], []
    idx = 0
    for kind, name, keys, has_flag in schema:
        for k, shp, dt in keys:
            ctx_names.append(f"ctx.{name}.{k}")
            ctx_meta.append({"module": name, "kind": kind, "key": k,
                             "shape": [int(d) for d in shp], "dtype": dt,
                             "index": idx})
            idx += 1

    def fwd_flat(*args):
        p = dict(zip(names, args[:len(names)]))
        mask, x, y = args[len(names):]
        loss, acc, *flat = fwd(p, mask, x, y)
        return (anchor(loss, args), acc, *flat)

    fwd_specs = p_specs + [spec((cfg.n_qlinears(),)),
                           _x_spec(cfg, batch), _y_spec(cfg, batch)]
    fwd_in = [f"param.{k}" for k in names] + ["lqs_mask", "x", "y"]
    fwd_out = ["loss", "acc"] + ctx_names

    ctx_specs = [spec(m["shape"], jnp.dtype(m["dtype"])) for m in ctx_meta]

    def bwd_flat(*args):
        p = dict(zip(names, args[:len(names)]))
        rest = args[len(names):]
        mask, x = rest[0], rest[1]
        ctx = rest[2:]
        g0, *gs = bwd(p, mask, x, *ctx)
        return (anchor(g0, args), *gs)

    bwd_specs = p_specs + [spec((cfg.n_qlinears(),)), _x_spec(cfg, batch)] \
        + ctx_specs
    bwd_in = [f"param.{k}" for k in names] + ["lqs_mask", "x"] + ctx_names
    bwd_out = [f"grad.{k}" for k in names]
    return ((fwd_flat, fwd_specs, fwd_in, fwd_out),
            (bwd_flat, bwd_specs, bwd_in, bwd_out), ctx_meta)


def build_calib_step(cfg, bcfg, batch):
    names = M.param_names(cfg)
    params0 = M.init_params(cfg)
    p_specs = [spec(params0[k].shape) for k in names]
    cf = T.make_calib_step(cfg, bcfg)

    def flat(*args):
        p = dict(zip(names, args[:len(names)]))
        x, y = args[len(names):]
        o0, *rest = cf(p, x, y)
        return (anchor(o0, args), *rest)

    in_specs = p_specs + [_x_spec(cfg, batch), _y_spec(cfg, batch)]
    in_names = [f"param.{k}" for k in names] + ["x", "y"]
    out_names = ["mse_tensor", "mse_token", "outlier", "gx_err_hq",
                 "gx_err_hla", "gw_err_hq", "gw_err_hla"]
    return flat, in_specs, in_names, out_names


def build_lora_step(cfg, bcfg, ocfg, batch, hot_frozen, hot_decomposed,
                    r_lora=8):
    names = M.param_names(cfg)
    params0 = M.init_params(cfg)
    p_specs = [spec(params0[k].shape) for k in names]
    t_names = sorted(list(LR.lora_names(cfg, r_lora))
                     + ["embed.w", "embed.b", "head.w", "head.b"])
    t_shapes = dict(LR.lora_param_specs(cfg, r_lora))
    for k in ("embed.w", "embed.b", "head.w", "head.b"):
        t_shapes[k] = tuple(params0[k].shape)
    t_specs = [spec(t_shapes[k]) for k in t_names]
    nt = len(t_names)
    step_fn = LR.make_lora_train_step(cfg, bcfg, ocfg, r_lora=r_lora,
                                      hot_frozen=hot_frozen,
                                      hot_decomposed=hot_decomposed)

    def flat(*args):
        base = dict(zip(names, args[:len(names)]))
        off = len(names)
        t = dict(zip(t_names, args[off:off + nt]))
        m = dict(zip(t_names, args[off + nt:off + 2 * nt]))
        v = dict(zip(t_names, args[off + 2 * nt:off + 3 * nt]))
        step, lr, mask, x, y = args[off + 3 * nt:]
        new_t, new_m, new_v, loss, acc = step_fn(base, t, m, v, step, lr,
                                                 mask, x, y)
        return (*[new_t[k] for k in t_names], *[new_m[k] for k in t_names],
                *[new_v[k] for k in t_names], anchor(loss, args), acc)

    in_specs = (p_specs + t_specs * 3
                + [spec(()), spec(()), spec((cfg.n_qlinears(),)),
                   _x_spec(cfg, batch), _y_spec(cfg, batch)])
    in_names = ([f"param.{k}" for k in names]
                + [f"t.{k}" for k in t_names] + [f"m.{k}" for k in t_names]
                + [f"v.{k}" for k in t_names]
                + ["step", "lr", "lqs_mask", "x", "y"])
    out_names = ([f"t.{k}" for k in t_names] + [f"m.{k}" for k in t_names]
                 + [f"v.{k}" for k in t_names] + ["loss", "acc"])
    meta_t = [{"name": k, "shape": [int(d) for d in t_shapes[k]],
               "dtype": "float32"} for k in t_names]
    return flat, in_specs, in_names, out_names, meta_t


def build_kernel_demo(kind: str, l=64, o=64, i=48, rank=8):
    """Pallas-kernel-bearing artifacts: prove L1 lowers into HLO the rust
    runtime can execute (interpret=True -> plain HLO ops)."""
    if kind == "hq":
        from compile.kernels import hq_matmul

        def fn(gy, w):
            return (hq_matmul.hq_matmul(gy, w, bits=4),)

        in_specs = [spec((l, o)), spec((o, i))]
        return fn, in_specs, ["gy", "w"], ["gx"]
    if kind == "hla":
        from compile.kernels import hla_matmul

        def fn(gy, x):
            return (hla_matmul.hla_matmul(gy, x, rank=rank, bits=8),)

        in_specs = [spec((l, o)), spec((l, i))]
        return fn, in_specs, ["gy", "x"], ["gw"]
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Suites
# ---------------------------------------------------------------------------


def emit_training_family(em: Emitter, preset: str, batch: int,
                         variants, ocfg, include_infra: bool):
    cfg = PRESETS[preset]
    em.add_preset(preset, cfg)
    for variant in variants:
        bcfg = BackwardConfig(variant=variant)
        fn, ins, inn, outn = build_train_step(cfg, bcfg, ocfg, batch)
        em.emit(f"train_{variant}_{preset}", fn, ins, inn, outn,
                {"kind": "train_step", "preset": preset, "variant": variant,
                 "batch": batch, "rank": bcfg.rank})
    if include_infra:
        fn, ins, inn, outn = build_eval_step(cfg, batch)
        em.emit(f"eval_{preset}", fn, ins, inn, outn,
                {"kind": "eval_step", "preset": preset, "batch": batch})
        bcfg = BackwardConfig(variant="hot")
        fn, ins, inn, outn = build_grad_step(cfg, bcfg, batch)
        em.emit(f"grad_hot_{preset}", fn, ins, inn, outn,
                {"kind": "grad_step", "preset": preset, "variant": "hot",
                 "batch": batch})
        fn, ins, inn, outn = build_opt_step(cfg, ocfg)
        em.emit(f"opt_{preset}", fn, ins, inn, outn,
                {"kind": "opt_step", "preset": preset})
        fn, ins, inn, outn = build_calib_step(cfg, BackwardConfig(variant="hot"),
                                              batch)
        em.emit(f"calib_{preset}", fn, ins, inn, outn,
                {"kind": "calib_step", "preset": preset, "batch": batch})
        for variant in ("hot", "fp"):
            bcfg = BackwardConfig(variant=variant)
            (fwd, bwd, ctx_meta) = build_split_steps(cfg, bcfg, batch)
            em.emit(f"fwd_{variant}_{preset}", *fwd,
                    {"kind": "fwd_step", "preset": preset, "variant": variant,
                     "batch": batch, "ctx": ctx_meta})
            em.emit(f"bwd_{variant}_{preset}", *bwd,
                    {"kind": "bwd_step", "preset": preset, "variant": variant,
                     "batch": batch})


def emit_default(em: Emitter, batch: int):
    ocfg = OptimizerConfig()
    emit_training_family(em, "small", batch,
                         ["fp", "hot", "lbp", "luq", "int4"], ocfg,
                         include_infra=True)
    # Pallas-kernel demos (L1 inside rust-executable HLO)
    for kind in ("hq", "hla"):
        fn, ins, inn, outn = build_kernel_demo(kind)
        em.emit(f"kernel_{kind}_demo", fn, ins, inn, outn,
                {"kind": "kernel_demo", "demo": kind})
    # LoRA (vision): fp-LoRA and the paper's winning HOT-on-frozen recipe
    cfg = PRESETS["small"]
    for tag, hf, hdec, variant in (
            ("lora_fp", False, False, "fp"),
            ("lora_hotfrozen", True, False, "hot")):
        fn, ins, inn, outn, meta_t = build_lora_step(
            cfg, BackwardConfig(variant=variant), ocfg, batch, hf, hdec)
        em.emit(f"{tag}_small", fn, ins, inn, outn,
                {"kind": "lora_step", "preset": "small", "variant": variant,
                 "hot_frozen": hf, "hot_decomposed": hdec, "batch": batch,
                 "trainable": meta_t})


def emit_full(em: Emitter, batch: int):
    ocfg = OptimizerConfig()
    emit_default(em, batch)
    # Table 2 path-sensitivity family at tiny scale
    emit_training_family(em, "tiny", batch,
                         ["gx_hq4", "gx_q4", "gx_ext_hla", "gx_int_hla",
                          "gw_hq4", "gw_hla", "gw_hot", "fp", "hot", "lbp",
                          "luq", "int4"],
                         ocfg, include_infra=True)
    # Table 8 rank sweep (hot with r != 8)
    cfg = PRESETS["tiny"]
    for r in (1, 2, 4, 16):
        bcfg = BackwardConfig(variant="hot", rank=r)
        fn, ins, inn, outn = build_train_step(cfg, bcfg, ocfg, batch)
        em.emit(f"train_hot_r{r}_tiny", fn, ins, inn, outn,
                {"kind": "train_step", "preset": "tiny", "variant": "hot",
                 "batch": batch, "rank": r})
    # Table 9 remaining LoRA combos
    cfg_s = PRESETS["small"]
    for tag, hf, hdec in (("lora_hotdec", False, True),
                          ("lora_hotboth", True, True)):
        fn, ins, inn, outn, meta_t = build_lora_step(
            cfg_s, BackwardConfig(variant="hot"), ocfg, batch, hf, hdec)
        em.emit(f"{tag}_small", fn, ins, inn, outn,
                {"kind": "lora_step", "preset": "small", "variant": "hot",
                 "hot_frozen": hf, "hot_decomposed": hdec, "batch": batch,
                 "trainable": meta_t})
    # LM family (Table 4 analog)
    emit_training_family(em, "lm_tiny", batch,
                         ["fp", "hot", "lbp", "luq"], ocfg,
                         include_infra=False)
    # MLP family (CNN stand-in for Tables 3/10)
    emit_training_family(em, "mlp_small", batch,
                         ["fp", "hot", "lbp", "luq", "int4"], ocfg,
                         include_infra=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", choices=("default", "full"), default="default")
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--batch", type=int, default=32)
    args = ap.parse_args()
    em = Emitter(args.out)
    em.manifest["suite"] = args.suite
    em.manifest["batch"] = args.batch
    t0 = time.time()
    if args.suite == "default":
        emit_default(em, args.batch)
    else:
        emit_full(em, args.batch)
    em.finish()
    print(f"total {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
