"""Model / backward-path configuration shared by L2 graphs, aot.py and tests.

The ``variant`` string selects the backward implementation for every
quantized linear (qlinear) in the model — this is the axis all the paper's
comparisons move along:

  fp          exact FP32 backprop (paper's "FP" column)
  hot         HOT: g_x = HT+INT4 pseudo-stochastic quant (HQ),
              g_w = internal-HLA(rank) + INT8, LQS mask selects per-token
              vs per-tensor scales per layer, ABC compresses the stored x
  lbp         LBP-WHT [46]: g_x = external HLA on L, g_w = internal HLA,
              FP arithmetic (no quantization)
  luq         LUQ [7]: logarithmic FP4 stochastic quant of g_y on both
              paths, INT4 min-max quant of w / x operands
  int4        plain INT4 min-max quant on both paths (no HT) — the
              "INT4" column of Table 10
  --- single-path ablations (Table 2) ---
  gx_hq4      g_x = HT+INT4, g_w exact
  gx_q4       g_x = INT4 without HT, g_w exact
  gx_ext_hla  g_x = external HLA, g_w exact
  gx_int_hla  g_x = internal HLA (rank over O), g_w exact
  gw_hq4      g_w = HT+INT4 quant, g_x exact
  gw_hla      g_w = internal HLA only (no quant), g_x exact
  gw_hot      g_w = HLA+INT8 (HOT's g_w), g_x exact
"""

from __future__ import annotations

import dataclasses
from typing import Optional

VARIANTS = (
    "fp", "hot", "lbp", "luq", "int4",
    "gx_hq4", "gx_q4", "gx_ext_hla", "gx_int_hla",
    "gw_hq4", "gw_hla", "gw_hot",
)


@dataclasses.dataclass(frozen=True)
class BackwardConfig:
    """How gradients are computed for every qlinear layer."""

    variant: str = "hot"
    rank: int = 8            # HLA low-pass rank r out of `block`
    block: int = 16          # Hadamard tile (paper: order-4 block-diag, n=16)
    gx_bits: int = 4         # HQ precision on the activation-gradient path
    gw_bits: int = 8         # quant precision on the weight-gradient path
    criterion: str = "sequency"  # low-pass selection: sequency | lp_l1
    abc: bool = True         # compress x at forward time (ABC) vs at bwd
    use_pallas: bool = False  # route qlinear bwd through the L1 Pallas kernels

    def __post_init__(self):
        if self.variant not in VARIANTS:
            raise ValueError(f"unknown variant {self.variant!r}")
        if not 1 <= self.rank <= self.block:
            raise ValueError(f"rank {self.rank} outside [1, {self.block}]")

    def tag(self) -> str:
        """Artifact-name suffix (stable across runs)."""
        parts = [self.variant]
        if self.variant in ("hot", "lbp", "gw_hot", "gw_hla",
                            "gx_ext_hla", "gx_int_hla") and self.rank != 8:
            parts.append(f"r{self.rank}")
        if not self.abc and self.variant == "hot":
            parts.append("noabc")
        if self.use_pallas:
            parts.append("pallas")
        return "_".join(parts)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A ViT-style transformer encoder (the paper's main testbed family).

    arch:
      vit  — patch-embed -> encoder blocks -> mean-pool -> classifier
      lm   — token-embed -> causal encoder blocks -> per-position LM head
      mlp  — patch-embed -> (fc1, gelu, fc2) blocks -> pool -> classifier
             (conv-as-matmul stand-in for the CNN families in the paper)
    """

    arch: str = "vit"
    d_model: int = 64
    depth: int = 2
    heads: int = 2
    seq: int = 32            # L; must be a multiple of block (16)
    in_dim: int = 48         # patch feature dim (vision) / vocab (lm)
    n_classes: int = 10
    mlp_ratio: int = 4

    def __post_init__(self):
        if self.arch not in ("vit", "lm", "mlp"):
            raise ValueError(f"unknown arch {self.arch!r}")
        if self.seq % 16:
            raise ValueError("seq must be a multiple of 16 (Hadamard tiles)")
        if self.d_model % 16:
            raise ValueError("d_model must be a multiple of 16")
        if self.d_model % self.heads:
            raise ValueError("d_model must divide evenly into heads")

    @property
    def d_mlp(self) -> int:
        return self.d_model * self.mlp_ratio

    def n_qlinears(self) -> int:
        """Number of quantized linears == length of the LQS mask.

        vit/lm blocks carry (qkv, proj, fc1, fc2); mlp blocks (fc1, fc2);
        plus embed and head."""
        per_block = 4 if self.arch in ("vit", "lm") else 2
        return 2 + per_block * self.depth


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 1e-3          # base LR; the per-step LR is a graph input
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01


# ---------------------------------------------------------------------------
# Presets
# ---------------------------------------------------------------------------

PRESETS = {
    # unit-test scale: fast to lower, fast to run under pytest
    "tiny": ModelConfig(arch="vit", d_model=32, depth=2, heads=2, seq=16,
                        in_dim=16, n_classes=4, mlp_ratio=2),
    # default artifact scale: what `make artifacts` ships and the rust
    # examples/benches consume (~0.45M params)
    "small": ModelConfig(arch="vit", d_model=96, depth=4, heads=4, seq=32,
                         in_dim=48, n_classes=16, mlp_ratio=4),
    # e2e driver --large scale (~7M params)
    "base": ModelConfig(arch="vit", d_model=256, depth=8, heads=8, seq=64,
                        in_dim=96, n_classes=32, mlp_ratio=4),
    "lm_tiny": ModelConfig(arch="lm", d_model=64, depth=2, heads=2, seq=32,
                           in_dim=128, n_classes=128, mlp_ratio=2),
    "lm_small": ModelConfig(arch="lm", d_model=128, depth=4, heads=4, seq=64,
                            in_dim=256, n_classes=256, mlp_ratio=4),
    "mlp_small": ModelConfig(arch="mlp", d_model=96, depth=4, heads=1, seq=32,
                             in_dim=48, n_classes=16, mlp_ratio=4),
}
