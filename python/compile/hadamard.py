"""Walsh-Hadamard utilities shared by the L1 kernels and the L2 graphs.

The paper (HOT, §3.1) uses the *block-diagonal* Hadamard transform of
order n=16 ("order-4 2D HT" in Xi et al. [43]'s terminology): a dimension
of size D (multiple of 16) is split into D/16 independent tiles and each
tile is multiplied by the normalized 16x16 Walsh-Hadamard matrix. All of
HOT's machinery — HQ on the g_x path, HLA on the g_w path, ABC's
forward-time activation compression — is built from this one primitive.

Everything here is pure numpy/jnp and used at trace time; the Pallas
kernels in kernels/ re-express the same math with explicit tiling.
"""

from __future__ import annotations

import functools

import numpy as np
import jax.numpy as jnp

# Default Hadamard block size used throughout the paper (n = 16).
BLOCK = 16


@functools.lru_cache(maxsize=None)
def hadamard_matrix(n: int, normalized: bool = True) -> np.ndarray:
    """Sylvester (natural-order) Walsh-Hadamard matrix of size n (power of 2).

    When ``normalized``, rows are scaled by 1/sqrt(n) so H @ H.T == I.
    """
    if n & (n - 1) or n <= 0:
        raise ValueError(f"Hadamard order must be a power of two, got {n}")
    h = np.array([[1.0]], dtype=np.float64)
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    if normalized:
        h = h / np.sqrt(n)
    return h.astype(np.float32)


@functools.lru_cache(maxsize=None)
def sequency_order(n: int) -> tuple:
    """Permutation mapping sequency rank -> natural (Sylvester) row index.

    Sequency of a Walsh basis vector = number of sign changes; low sequency
    == low "frequency". ``sequency_order(n)[k]`` is the natural-order row
    holding the k-th lowest-frequency basis vector. Computed by direct
    sign-change counting (robust, n is tiny).
    """
    h = hadamard_matrix(n, normalized=False)
    changes = (np.diff(np.sign(h), axis=1) != 0).sum(axis=1)
    # stable argsort so ties (there are none for true Walsh rows) keep order
    return tuple(int(i) for i in np.argsort(changes, kind="stable"))


@functools.lru_cache(maxsize=None)
def lp_l1_order_2d(bh: int, bw: int) -> tuple:
    """LP_L1 low-pass ordering for a 2D (bh x bw) Hadamard basis.

    LBP-WHT's LP_L1 criterion ranks the 2D basis kron(v_row, v_col) by the
    L1 norm of its (vertical, horizontal) sequency pair, so low-pass
    vectors that are smooth in *both* image directions come first. Returns
    a permutation of range(bh*bw) into natural-order flat indices.
    """
    sv = {nat: seq for seq, nat in enumerate(sequency_order(bh))}
    sh = {nat: seq for seq, nat in enumerate(sequency_order(bw))}
    flat = []
    for r in range(bh):
        for c in range(bw):
            flat.append((sv[r] + sh[c], sv[r], sh[c], r * bw + c))
    flat.sort()
    return tuple(f[-1] for f in flat)


def lowpass_indices(rank: int, block: int = BLOCK, criterion: str = "sequency") -> tuple:
    """Natural-order indices of the ``rank`` lowest-frequency components.

    criterion:
      * "sequency" — 1D sequency order (used for transformer L dims).
      * "lp_l1"    — LBP-WHT's 2D LP_L1 order over a 4x4 spatial tile
                     (used when L = H*W image patches; block must be 16).
    """
    if not 1 <= rank <= block:
        raise ValueError(f"rank must be in [1, {block}], got {rank}")
    if criterion == "sequency":
        order = sequency_order(block)
    elif criterion == "lp_l1":
        side = int(np.sqrt(block))
        if side * side != block:
            raise ValueError("lp_l1 needs a square block")
        order = lp_l1_order_2d(side, side)
    else:
        raise ValueError(f"unknown criterion {criterion!r}")
    return tuple(order[:rank])


@functools.lru_cache(maxsize=None)
def reduced_hadamard(rank: int, block: int = BLOCK, criterion: str = "sequency") -> np.ndarray:
    """The (rank x block) reduced matrix H-hat of HOT Eq. (5)/(6):
    the ``rank`` lowest-frequency rows of the normalized Walsh matrix."""
    h = hadamard_matrix(block)
    sel = np.asarray(lowpass_indices(rank, block, criterion), dtype=np.int64)
    return h[sel, :]


# ---------------------------------------------------------------------------
# jnp transforms (trace-time building blocks for the L2 graphs and ref.py)
# ---------------------------------------------------------------------------


def block_ht(x: jnp.ndarray, axis: int = -1, block: int = BLOCK) -> jnp.ndarray:
    """Block-diagonal Hadamard transform along ``axis``.

    Splits the axis into tiles of ``block`` and multiplies each by H. The
    transform is orthonormal: block_ht(block_ht(x)) == x (H is symmetric
    for Sylvester order after normalization... H @ H == I since H == H.T).
    """
    x = jnp.moveaxis(x, axis, -1)
    d = x.shape[-1]
    if d % block:
        raise ValueError(f"axis size {d} not a multiple of block {block}")
    h = jnp.asarray(hadamard_matrix(block))
    y = x.reshape(*x.shape[:-1], d // block, block) @ h.T
    y = y.reshape(*x.shape)
    return jnp.moveaxis(y, -1, axis)


def block_hla(
    x: jnp.ndarray,
    rank: int,
    axis: int = -1,
    block: int = BLOCK,
    criterion: str = "sequency",
) -> jnp.ndarray:
    """Hadamard low-rank projection: HT along ``axis`` then keep the ``rank``
    lowest-frequency components of every tile. Output axis size D*rank/block.

    This is HOT's internal-HLA operand compression (Eq. 5): the returned
    tensor is (H-hat @ x) laid out tile-major.
    """
    x = jnp.moveaxis(x, axis, -1)
    d = x.shape[-1]
    if d % block:
        raise ValueError(f"axis size {d} not a multiple of block {block}")
    hh = jnp.asarray(reduced_hadamard(rank, block, criterion))
    y = x.reshape(*x.shape[:-1], d // block, block) @ hh.T
    y = y.reshape(*x.shape[:-1], (d // block) * rank)
    return jnp.moveaxis(y, -1, axis)


def block_hla_expand(
    x: jnp.ndarray,
    rank: int,
    axis: int = -1,
    block: int = BLOCK,
    criterion: str = "sequency",
) -> jnp.ndarray:
    """Adjoint of block_hla: H-hat.T @ x, expanding D*rank/block back to D.

    Used by *external* HLA (Eq. 6), where the approximated product is
    H-hat.T @ (H-hat @ P) @ S — compress, multiply, then expand."""
    x = jnp.moveaxis(x, axis, -1)
    d = x.shape[-1]
    if d % rank:
        raise ValueError(f"axis size {d} not a multiple of rank {rank}")
    hh = jnp.asarray(reduced_hadamard(rank, block, criterion))
    y = x.reshape(*x.shape[:-1], d // rank, rank) @ hh
    y = y.reshape(*x.shape[:-1], (d // rank) * block)
    return jnp.moveaxis(y, -1, axis)
