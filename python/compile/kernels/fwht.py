"""Pallas kernel: block-diagonal (Fast) Walsh-Hadamard transform.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's CUDA
FWHT stages butterflies through GPU shared memory. On TPU the natural
formulation of an order-16 block transform is a dense (.., 16) x (16, 16)
matmul on the MXU — the 16x16 Walsh matrix lives in VMEM once and every
(rows_tile, 16) operand tile streams through the systolic array. We ship
both formulations:

  * ``block_fwht``      — MXU form: reshape to (bm, D/16, 16) @ H16.
  * ``block_fwht_bfly`` — butterfly form: log2(16)=4 stages of add/sub on
    strided halves (the VPU-friendly variant; exercises the same
    schedule the CUDA kernel used, adapted to lane-parallel vectors).

Both run under ``interpret=True`` (CPU has no Mosaic backend); they lower
to identical HLO-visible semantics and are verified against
``hadamard.block_ht`` / each other in pytest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile import hadamard as hd

# Target row-tile: sized so a (TILE_ROWS, 1024) f32 operand + result fit
# comfortably in ~16 MB VMEM with double buffering (2 * 2 * 512KB).
TILE_ROWS = 128


def _row_tiles(n_rows: int) -> int:
    return min(TILE_ROWS, n_rows)


def _fwht_mxu_kernel(x_ref, h_ref, o_ref, *, block: int):
    """One row-tile: (bm, D) -> (bm, D/block, block) @ H^T -> (bm, D)."""
    x = x_ref[...]
    bm, d = x.shape
    h = h_ref[...]
    y = x.reshape(bm, d // block, block) @ h.T
    o_ref[...] = y.reshape(bm, d)


def block_fwht(x: jnp.ndarray, block: int = hd.BLOCK) -> jnp.ndarray:
    """Block-diag HT along the last axis of a 2-D array (MXU formulation)."""
    m, d = x.shape
    if d % block:
        raise ValueError(f"last dim {d} not a multiple of {block}")
    bm = _row_tiles(m)
    if m % bm:
        raise ValueError(f"rows {m} not a multiple of tile {bm}")
    h = jnp.asarray(hd.hadamard_matrix(block))
    return pl.pallas_call(
        functools.partial(_fwht_mxu_kernel, block=block),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((block, block), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), h)


def _fwht_bfly_kernel(x_ref, o_ref, *, block: int):
    """Butterfly formulation: stages of add/sub over the tile axis.

    The (bm, D) tile is viewed as (bm, D/block, block); each stage s
    pairs lanes differing in bit 2^s. All adds/subs are lane-parallel
    (VPU), no MXU involvement, matching FWHT's O(n log n) op count."""
    x = x_ref[...]
    bm, d = x.shape
    v = x.reshape(bm, d // block, block)
    size = 1
    while size < block:
        v = v.reshape(bm, d // block, block // (2 * size), 2, size)
        a = v[:, :, :, 0, :]
        b = v[:, :, :, 1, :]
        v = jnp.stack([a + b, a - b], axis=3)
        size *= 2
    v = v.reshape(bm, d // block, block) * (1.0 / jnp.sqrt(float(block)))
    o_ref[...] = v.reshape(bm, d)


def block_fwht_bfly(x: jnp.ndarray, block: int = hd.BLOCK) -> jnp.ndarray:
    """Butterfly (true-FWHT) variant of :func:`block_fwht`.

    Note: stage ordering produces the same *set* of Walsh coefficients in
    Sylvester (natural) order, identical to the matmul form.
    """
    m, d = x.shape
    if d % block:
        raise ValueError(f"last dim {d} not a multiple of {block}")
    bm = _row_tiles(m)
    if m % bm:
        raise ValueError(f"rows {m} not a multiple of tile {bm}")
    return pl.pallas_call(
        functools.partial(_fwht_bfly_kernel, block=block),
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32))


def _fwht_amax_kernel(x_ref, h_ref, o_ref, amax_ref, *, block: int):
    """Fused HT + per-tile abs-max (first half of the HQ pipeline).

    Emitting the running amax alongside the transform saves one full
    memory pass: the quantizer's min-max scale needs max|HT(x)| and
    computing it in the epilogue of the transform kernel keeps the
    transformed tile in VMEM."""
    x = x_ref[...]
    bm, d = x.shape
    y = (x.reshape(bm, d // block, block) @ h_ref[...].T).reshape(bm, d)
    o_ref[...] = y
    amax_ref[0] = jnp.max(jnp.abs(y))


def block_fwht_amax(x: jnp.ndarray, block: int = hd.BLOCK):
    """Returns (HT(x), amax) where amax = max|HT(x)| (scalar f32)."""
    m, d = x.shape
    bm = _row_tiles(m)
    if d % block or m % bm:
        raise ValueError(f"bad shape {(m, d)} for block {block}, tile {bm}")
    h = jnp.asarray(hd.hadamard_matrix(block))
    y, part = pl.pallas_call(
        functools.partial(_fwht_amax_kernel, block=block),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((block, block), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, d), jnp.float32),
            jax.ShapeDtypeStruct((m // bm,), jnp.float32),
        ],
        interpret=True,
    )(x.astype(jnp.float32), h)
    return y, jnp.max(part)
