"""Pallas kernels: HLA compression + fused INT8 GEMM — the g_w path (§5.2).

Two kernels:

  ``hla_project_amax``  internal-HLA operand compression along the L dim:
      view (L, D) as (L/16, 16, D), contract the 16-axis with the reduced
      Walsh matrix H-hat (rank r rows, LP-ordered), emit the (L*r/16, D)
      compressed tensor + fused abs-max. This is also ABC's forward-time
      compression kernel — the same op runs right after the forward
      matmul and its int8 output is what gets *stored* for backward.

  ``hla_gemm``          pseudo-stochastic INT8 quant of both compressed
      operands + integer GEMM contracting the compressed-L dim (int32
      accumulate) + FP32 dequant. ``per_token=True`` switches the g_y
      operand to row-wise scales (LQS per-token mode); those scales sit
      on the contracted dim, so that branch dequantizes g_y rows first —
      matching the semantics the paper needs while the per-tensor branch
      stays a pure INT8 MXU GEMM.

TPU mapping: H-hat is an (r, 16) constant in VMEM; the projection is an
MXU matmul over the tile axis. Compressed operands are r/16 the size of
the originals, so GEMM tiles shrink accordingly (the source of HLA's
speedup before quantization even starts).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile import hadamard as hd
from compile.kernels import ref

TILE_COLS = 256
TILE_M = 128
TILE_N = 128


def _pick_tile(dim: int, target: int) -> int:
    t = min(target, dim)
    while dim % t:
        t -= 1
    return t


def _hla_project_kernel(x_ref, hh_ref, o_ref, amax_ref, *, block: int, rank: int):
    """(L, bc) column tile -> (L*r/block, bc) compressed + tile abs-max."""
    x = x_ref[...]
    l, bc = x.shape
    hh = hh_ref[...]  # (rank, block)
    xt = x.reshape(l // block, block, bc)
    # contract the 16-axis with H-hat: (L/b, r, bc)
    y = jax.lax.dot_general(
        hh, xt, (((1,), (1,)), ((), ()))
    )  # -> (rank, L/b, bc) with batch on neither: dims (r, L/b, bc)? see below
    # dot_general(hh (r,b), xt (L/b, b, bc)) contracting b: result (r, L/b, bc)
    y = jnp.swapaxes(y, 0, 1).reshape(l // block * rank, bc)
    o_ref[...] = y
    amax_ref[0] = jnp.max(jnp.abs(y))


def hla_project_amax(x: jnp.ndarray, rank: int, block: int = hd.BLOCK,
                     criterion: str = "sequency"):
    """Compress (L, D) along L to (L*rank/block, D); returns (y, amax)."""
    l, d = x.shape
    if l % block:
        raise ValueError(f"L={l} not a multiple of block {block}")
    bc = _pick_tile(d, TILE_COLS)
    hh = jnp.asarray(hd.reduced_hadamard(rank, block, criterion))
    lc = l // block * rank
    y, part = pl.pallas_call(
        functools.partial(_hla_project_kernel, block=block, rank=rank),
        grid=(d // bc,),
        in_specs=[
            pl.BlockSpec((l, bc), lambda j: (0, j)),
            pl.BlockSpec((rank, block), lambda j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((lc, bc), lambda j: (0, j)),
            pl.BlockSpec((1,), lambda j: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((lc, d), jnp.float32),
            jax.ShapeDtypeStruct((d // bc,), jnp.float32),
        ],
        interpret=True,
    )(x.astype(jnp.float32), hh)
    return y, jnp.max(part)


def _hla_gemm_kernel(g_ref, x_ref, sg_ref, sx_ref, o_ref, *,
                     bits: int, per_token: bool):
    """Contract compressed-L: (Lc, bo)ᵀ x (Lc, bi) -> (bo, bi)."""
    qmax = ref.QMAX[bits]
    g = g_ref[...]
    x = x_ref[...]
    sx = sx_ref[0, 0]

    def q(t, s):
        v = t / s
        u_bits = jax.lax.bitcast_convert_type(t, jnp.uint32)
        u = (u_bits & jnp.uint32(0x7FF)).astype(jnp.float32) / 2048.0
        f = jnp.floor(v)
        r = f + (v - f > u).astype(jnp.float32)
        return jnp.clip(r, -qmax, qmax)

    qx = q(x, sx).astype(jnp.int8)
    if per_token:
        sg = sg_ref[...]  # (Lc, 1) row scales on the contracted dim
        g_deq = q(g, sg) * sg
        acc = jax.lax.dot_general(
            g_deq, qx.astype(jnp.float32), (((0,), (0,)), ((), ()))
        )
        o_ref[...] = acc * sx
    else:
        sg = sg_ref[0, 0]
        qg = q(g, sg).astype(jnp.int8)
        acc = jax.lax.dot_general(
            qg, qx, (((0,), (0,)), ((), ())), preferred_element_type=jnp.int32
        )
        o_ref[...] = acc.astype(jnp.float32) * (sg * sx)


def hla_gemm(gc: jnp.ndarray, xc: jnp.ndarray, s_g: jnp.ndarray,
             s_x: jnp.ndarray, bits: int = 8,
             per_token: bool = False) -> jnp.ndarray:
    """Quant + integer GEMM + dequant over compressed operands.

    gc: (Lc, O), xc: (Lc, I), output g_w: (O, I)."""
    lc, o = gc.shape
    lc2, i = xc.shape
    assert lc == lc2, (gc.shape, xc.shape)
    bo = _pick_tile(o, TILE_M)
    bi = _pick_tile(i, TILE_N)
    if per_token:
        sg = s_g.reshape(lc, 1).astype(jnp.float32)
        sg_spec = pl.BlockSpec((lc, 1), lambda i2, j: (0, 0))
    else:
        sg = jnp.asarray(s_g, jnp.float32).reshape(1, 1)
        sg_spec = pl.BlockSpec((1, 1), lambda i2, j: (0, 0))
    return pl.pallas_call(
        functools.partial(_hla_gemm_kernel, bits=bits, per_token=per_token),
        grid=(o // bo, i // bi),
        in_specs=[
            pl.BlockSpec((lc, bo), lambda i2, j: (0, i2)),
            pl.BlockSpec((lc, bi), lambda i2, j: (0, j)),
            sg_spec,
            pl.BlockSpec((1, 1), lambda i2, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bo, bi), lambda i2, j: (i2, j)),
        out_shape=jax.ShapeDtypeStruct((o, i), jnp.float32),
        interpret=True,
    )(gc.astype(jnp.float32), xc.astype(jnp.float32), sg,
      jnp.asarray(s_x, jnp.float32).reshape(1, 1))


def hla_matmul(gy: jnp.ndarray, x: jnp.ndarray, rank: int, bits: int = 8,
               block: int = hd.BLOCK, per_token: bool = False,
               criterion: str = "sequency") -> jnp.ndarray:
    """Full g_w path: internal HLA(L) on both operands -> INT8 quant ->
    integer GEMM -> dequant. gy: (L, O), x: (L, I) -> g_w: (O, I).

    Matches :func:`compile.kernels.ref.hla_matmul_ref` exactly."""
    qmax = ref.QMAX[bits]
    gc, amax_g = hla_project_amax(gy, rank, block, criterion)
    xc, amax_x = hla_project_amax(x, rank, block, criterion)
    s_x = jnp.maximum(amax_x, 1e-8) / qmax
    if per_token:
        s_g = jnp.maximum(jnp.max(jnp.abs(gc), axis=1, keepdims=True), 1e-8) / qmax
    else:
        s_g = jnp.maximum(amax_g, 1e-8) / qmax
    return hla_gemm(gc, xc, s_g, s_x, bits=bits, per_token=per_token)
