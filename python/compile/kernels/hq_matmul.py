"""Pallas kernel: fused HQ matmul — the g_x backward path (HOT §5.1).

    g_x = dequant( Q4(g_y · Hᵀ) ·int· Q4(H · w) )

Pipeline (mirrors the paper's CUDA module split, adapted to TPU):

  phase 1  ``fwht.block_fwht_amax``   HT along the contracted O dim with a
           fused abs-max epilogue (scale source for min-max quant).
  phase 2  ``hq_gemm``                fused pseudo-stochastic INT4 quant of
           both tiles + integer GEMM (int8 container, int32 accumulate ==
           the INT4 tensor-core path) + FP32 dequant epilogue.

TPU mapping: the quantized operands are MXU-native int8; accumulation in
int32 matches the MXU integer pipeline; the dequant epilogue is one
scalar multiply on the (bm, bn) output tile while it is still in VMEM.
Grid is (M/bm, N/bn) with the full K dim resident per tile — for HOT's
layer shapes (K = O ≤ 4608) a (128, K) int8 tile is ≤ 0.6 MB of VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile import hadamard as hd
from compile.kernels import fwht, ref

TILE_M = 128
TILE_N = 128


def _pick_tile(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` not exceeding ``target`` (keeps the grid
    exact for the small test shapes; production shapes hit ``target``)."""
    t = min(target, dim)
    while dim % t:
        t -= 1
    return t


def _hq_gemm_kernel(a_ref, b_ref, sa_ref, sb_ref, o_ref, *, bits: int):
    """Quantize (bm,K) x (K,bn) tiles pseudo-stochastically and contract.

    Integer math throughout: products of values in [-7,7] accumulated in
    int32 — bit-identical to an INT4 tensor-core GEMM with int32 accum."""
    qmax = ref.QMAX[bits]
    sa = sa_ref[0, 0]
    sb = sb_ref[0, 0]

    def q(x, s):
        v = x / s
        u_bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
        u = (u_bits & jnp.uint32(0x7FF)).astype(jnp.float32) / 2048.0
        f = jnp.floor(v)
        r = f + (v - f > u).astype(jnp.float32)
        return jnp.clip(r, -qmax, qmax).astype(jnp.int8)

    qa = q(a_ref[...], sa)
    qb = q(b_ref[...], sb)
    acc = jax.lax.dot_general(
        qa, qb, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    o_ref[...] = acc.astype(jnp.float32) * (sa * sb)


def hq_gemm(a: jnp.ndarray, b: jnp.ndarray, s_a: jnp.ndarray,
            s_b: jnp.ndarray, bits: int = 4) -> jnp.ndarray:
    """Fused quant + integer GEMM + dequant: (M,K) x (K,N) -> (M,N) f32."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm = _pick_tile(m, TILE_M)
    bn = _pick_tile(n, TILE_N)
    return pl.pallas_call(
        functools.partial(_hq_gemm_kernel, bits=bits),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a.astype(jnp.float32), b.astype(jnp.float32),
      jnp.asarray(s_a, jnp.float32).reshape(1, 1),
      jnp.asarray(s_b, jnp.float32).reshape(1, 1))


def hq_matmul(gy: jnp.ndarray, w: jnp.ndarray, bits: int = 4,
              block: int = hd.BLOCK) -> jnp.ndarray:
    """Full g_x path: HT(O) -> INT4 pseudo-stochastic quant -> integer GEMM
    -> FP32 dequant. gy: (L, O), w: (O, I) -> g_x: (L, I).

    Must match :func:`compile.kernels.ref.hq_matmul_ref` exactly (same
    rounding decisions: both quantize the same HT output bits)."""
    qmax = ref.QMAX[bits]
    gy_t, amax_g = fwht.block_fwht_amax(gy, block=block)
    # w's contracted dim (O) is axis 0: transform its transpose. On TPU the
    # production kernel uses a column-major BlockSpec instead of an explicit
    # transpose; numerics are identical.
    wt_t, amax_w = fwht.block_fwht_amax(w.T, block=block)
    s_g = jnp.maximum(amax_g, 1e-8) / qmax
    s_w = jnp.maximum(amax_w, 1e-8) / qmax
    return hq_gemm(gy_t, wt_t.T, s_g, s_w, bits=bits)
