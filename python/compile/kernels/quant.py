"""Pallas kernel: pseudo-stochastic min-max quantizer (HOT §5.1).

The paper replaces true stochastic rounding with a *pseudo*-stochastic
variant (NITI [39]): the lower 11 bits of the FP32 input are reinterpreted
as the uniform sample that decides round-up vs round-down. This keeps the
estimator unbiased in practice while making the op a pure elementwise
function of its input — no RNG state, no extra memory traffic, trivially
fusable into a transform epilogue or a GEMM prologue.

Kernels here quantize given a precomputed scale (scales come from the
fused amax epilogues in fwht.py / hla_matmul.py, mirroring the paper's
two-phase CUDA pipeline). Per-tensor and per-token (row-wise) scales are
both supported; INT4 values are carried in an int8 container in [-7, 7]
(see also ``pack_int4``/``unpack_int4`` for the 2-nibbles-per-byte storage
format used by the rust ABC buffer manager).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels import ref

TILE_ROWS = 128


def _quant_kernel(x_ref, s_ref, o_ref, *, bits: int, per_token: bool):
    x = x_ref[...]
    s = s_ref[...]
    scale = s if per_token else s[0, 0]
    qmax = ref.QMAX[bits]
    v = x / scale
    u_bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    u = (u_bits & jnp.uint32(0x7FF)).astype(jnp.float32) / 2048.0
    f = jnp.floor(v)
    q = f + (v - f > u).astype(jnp.float32)
    o_ref[...] = jnp.clip(q, -qmax, qmax).astype(jnp.int8)


def quantize_ps(x: jnp.ndarray, scale: jnp.ndarray, bits: int,
                per_token: bool = False) -> jnp.ndarray:
    """Pseudo-stochastic quantize (L, D) f32 -> int8 grid values.

    scale: scalar () or (1,1) for per-tensor; (L, 1) for per-token."""
    m, d = x.shape
    bm = min(TILE_ROWS, m)
    if m % bm:
        raise ValueError(f"rows {m} not a multiple of tile {bm}")
    if per_token:
        s = scale.reshape(m, 1).astype(jnp.float32)
        s_spec = pl.BlockSpec((bm, 1), lambda i: (i, 0))
    else:
        s = jnp.asarray(scale, jnp.float32).reshape(1, 1)
        s_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_quant_kernel, bits=bits, per_token=per_token),
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0)), s_spec],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.int8),
        interpret=True,
    )(x.astype(jnp.float32), s)


def _dequant_kernel(q_ref, s_ref, o_ref, *, per_token: bool):
    q = q_ref[...]
    s = s_ref[...]
    scale = s if per_token else s[0, 0]
    o_ref[...] = q.astype(jnp.float32) * scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray,
               per_token: bool = False) -> jnp.ndarray:
    """int8 grid values * scale -> f32 (the CUBLAS-FP32 stage in Fig 8)."""
    m, d = q.shape
    bm = min(TILE_ROWS, m)
    if m % bm:
        raise ValueError(f"rows {m} not a multiple of tile {bm}")
    if per_token:
        s = scale.reshape(m, 1).astype(jnp.float32)
        s_spec = pl.BlockSpec((bm, 1), lambda i: (i, 0))
    else:
        s = jnp.asarray(scale, jnp.float32).reshape(1, 1)
        s_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_dequant_kernel, per_token=per_token),
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0)), s_spec],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=True,
    )(q, s)


# ---------------------------------------------------------------------------
# INT4 nibble packing (storage format; PyTorch has no int4 dtype and
# neither does HLO — the paper packs two INT4 values per INT8 byte).
# ---------------------------------------------------------------------------


def pack_int4(q: jnp.ndarray) -> jnp.ndarray:
    """(..., 2k) int8 values in [-8,7] -> (..., k) int8 with two nibbles.

    Low nibble = even index, high nibble = odd index (two's complement)."""
    if q.shape[-1] % 2:
        raise ValueError("last dim must be even to pack nibbles")
    lo = q[..., 0::2].astype(jnp.int32) & 0xF
    hi = q[..., 1::2].astype(jnp.int32) & 0xF
    return ((hi << 4) | lo).astype(jnp.uint8).view(jnp.int8)


def unpack_int4(p: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`pack_int4` (sign-extends each nibble)."""
    b = p.view(jnp.uint8).astype(jnp.int32)
    lo = b & 0xF
    hi = (b >> 4) & 0xF
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*p.shape[:-1], p.shape[-1] * 2).astype(jnp.int8)
