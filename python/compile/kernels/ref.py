"""Pure-jnp oracles for every L1 Pallas kernel.

These are the correctness contracts: pytest (and hypothesis sweeps) assert
that each Pallas kernel reproduces the corresponding function here, and
the rust `quant`/`hadamard` modules mirror the same bit-level semantics so
host-side buffer handling agrees with what the HLO graphs produce.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile import hadamard as hd

# Integer ranges. INT4 is carried in an int8 container clamped to [-7, 7]
# (the paper packs two INT4 nibbles per INT8 for storage; value range is
# symmetric so the dequant scale has no zero-point).
QMAX = {4: 7, 8: 127}


# ---------------------------------------------------------------------------
# Pseudo-stochastic quantization (NITI-style, HOT §5.1)
# ---------------------------------------------------------------------------


def pseudo_random_unit(x: jnp.ndarray) -> jnp.ndarray:
    """The paper's zero-cost randomness: the lower 11 mantissa bits of the
    FP32 input reinterpreted as a uniform sample in [0, 1)."""
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    return (bits & jnp.uint32(0x7FF)).astype(jnp.float32) / 2048.0


def ps_round(v: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Stochastic rounding of v given uniform sample u in [0,1):
    round up iff frac(v) > u. Unbiased: E[ps_round(v)] == v for uniform u."""
    f = jnp.floor(v)
    return f + (v - f > u).astype(v.dtype)


def minmax_scale(x: jnp.ndarray, bits: int, axis=None) -> jnp.ndarray:
    """Min-max symmetric scale: max|x| over ``axis`` mapped to qmax.
    axis=None -> per-tensor scalar; axis=1 on (L, D) -> per-token (row)."""
    qmax = QMAX[bits]
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    return jnp.maximum(amax, 1e-8) / qmax


def quantize_ps(x: jnp.ndarray, scale: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pseudo-stochastic quantize to a signed integer grid (int8 container).

    The random source is derived from the *input* float's low mantissa
    bits, so the op is deterministic and fuses into a single elementwise
    pass (no RNG state, no extra memory traffic) — exactly the property
    the paper's CUDA kernel exploits."""
    qmax = QMAX[bits]
    v = x / scale
    q = ps_round(v, pseudo_random_unit(x))
    return jnp.clip(q, -qmax, qmax).astype(jnp.int8)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def fake_quant_ps(x: jnp.ndarray, bits: int, axis=None) -> jnp.ndarray:
    """quantize -> dequantize in one go (the L2 graphs' form)."""
    s = minmax_scale(x, bits, axis)
    return dequantize(quantize_ps(x, s, bits), s)


# ---------------------------------------------------------------------------
# LUQ baseline quantizer (Chmiel et al. [7]): logarithmic (power-of-two)
# stochastic quantization with stochastic underflow pruning.
# ---------------------------------------------------------------------------


def quantize_luq(x: jnp.ndarray, bits: int = 4) -> jnp.ndarray:
    """Fake-quant LUQ: values snap stochastically to signed powers of two.

    With b bits: 1 sign bit, the rest select one of 2^(b-1)-1 exponent
    levels below max|x| (plus zero). Underflow (|x| < smallest level) is
    pruned stochastically to keep the estimate unbiased."""
    levels = 2 ** (bits - 1) - 1
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-20)
    e_hi = jnp.floor(jnp.log2(amax))
    e_lo = e_hi - (levels - 1)
    mag = jnp.abs(x)
    sgn = jnp.sign(x)
    # log-domain stochastic rounding between adjacent powers of two
    e = jnp.clip(jnp.log2(jnp.maximum(mag, 2.0 ** (e_lo - 40))), e_lo, e_hi)
    ef = jnp.floor(e)
    pl, ph = 2.0**ef, 2.0 ** (ef + 1.0)
    ph = jnp.minimum(ph, 2.0**e_hi)
    p_up = jnp.where(ph > pl, (mag - pl) / jnp.maximum(ph - pl, 1e-20), 0.0)
    u = pseudo_random_unit(x)
    snapped = jnp.where(u < p_up, ph, pl)
    # stochastic underflow: keep w.p. mag/2^e_lo at value 2^e_lo, else 0
    under = mag < 2.0**e_lo
    keep = u < mag / 2.0**e_lo
    out = jnp.where(under, jnp.where(keep, 2.0**e_lo, 0.0), snapped)
    return sgn * jnp.where(mag == 0.0, 0.0, out)


# ---------------------------------------------------------------------------
# g_x path oracle: HQ matmul (HT along contraction dim + INT4, HOT §5.1)
# ---------------------------------------------------------------------------


def hq_matmul_ref(gy: jnp.ndarray, w: jnp.ndarray, bits: int = 4,
                  block: int = hd.BLOCK) -> jnp.ndarray:
    """g_x = Q(g_y Hᵀ) · Q(H w) with pseudo-stochastic INT quant.

    gy: (L, O), w: (O, I)  ->  (L, I). The HT is applied along the shared
    O dimension so orthogonality cancels: exact in the absence of
    quantization. Integer GEMM accumulates in int32; the returned value is
    the dequantized FP32 product."""
    gy_t = hd.block_ht(gy, axis=1, block=block)
    w_t = hd.block_ht(w, axis=0, block=block)
    s_g = minmax_scale(gy_t, bits)
    s_w = minmax_scale(w_t, bits)
    q_g = quantize_ps(gy_t, s_g, bits)
    q_w = quantize_ps(w_t, s_w, bits)
    acc = jax.lax.dot_general(
        q_g, q_w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    return acc.astype(jnp.float32) * (s_g * s_w)


# ---------------------------------------------------------------------------
# g_w path oracle: HLA matmul (internal HLA along L + INT8, HOT §5.2)
# ---------------------------------------------------------------------------


def hla_compress_ref(x: jnp.ndarray, rank: int, bits: int = 8,
                     block: int = hd.BLOCK, criterion: str = "sequency"):
    """ABC's forward-time compression: HLA along axis 0 (the L dim) then
    INT8 quantize. Returns (q:int8 (L*rank/block, D), scale: scalar).
    This pair is exactly what crosses the fwd->bwd boundary (the rust
    coordinator stores it)."""
    xc = hd.block_hla(x, rank, axis=0, block=block, criterion=criterion)
    s = minmax_scale(xc, bits)
    return quantize_ps(xc, s, bits), s


def hla_matmul_ref(gy: jnp.ndarray, x: jnp.ndarray, rank: int,
                   bits: int = 8, block: int = hd.BLOCK,
                   per_token: bool = False,
                   criterion: str = "sequency") -> jnp.ndarray:
    """g_w = (H-hat g_y)ᵀ · (H-hat x), both INT8-quantized.

    gy: (L, O), x: (L, I) -> (O, I). ``per_token`` selects row-wise scales
    for the compressed g_y (LQS per-token mode); row scales live on the
    *contracted* dim so that branch dequantizes before the GEMM — the
    per-tensor branch stays a pure INT8 GEMM."""
    gc = hd.block_hla(gy, rank, axis=0, block=block, criterion=criterion)
    xq, s_x = hla_compress_ref(x, rank, bits, block, criterion)
    if per_token:
        s_g = minmax_scale(gc, bits, axis=1)  # (Lc, 1)
        g_deq = dequantize(quantize_ps(gc, s_g, bits), s_g)
        acc = jax.lax.dot_general(
            g_deq, xq.astype(jnp.float32), (((0,), (0,)), ((), ()))
        )
        return acc * s_x
    s_g = minmax_scale(gc, bits)
    q_g = quantize_ps(gc, s_g, bits)
    acc = jax.lax.dot_general(
        q_g, xq, (((0,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    return acc.astype(jnp.float32) * (s_g * s_x)


# ---------------------------------------------------------------------------
# LBP-WHT baseline oracles (Yang et al. [46])
# ---------------------------------------------------------------------------


def lbp_gx_ref(gy: jnp.ndarray, w: jnp.ndarray, rank: int,
               block: int = hd.BLOCK) -> jnp.ndarray:
    """LBP-WHT's g_x: *external* HLA on the L dim of g_y —
    g_x ≈ H-hatᵀ (H-hat g_y) w. FP arithmetic (their kernels are FP16)."""
    gc = hd.block_hla(gy, rank, axis=0, block=block)
    out = gc @ w
    return hd.block_hla_expand(out, rank, axis=0, block=block)


def lbp_gw_ref(gy: jnp.ndarray, x: jnp.ndarray, rank: int,
               block: int = hd.BLOCK) -> jnp.ndarray:
    """LBP-WHT's g_w: internal HLA along L (same as HOT) but FP, no quant."""
    gc = hd.block_hla(gy, rank, axis=0, block=block)
    xc = hd.block_hla(x, rank, axis=0, block=block)
    return gc.T @ xc
