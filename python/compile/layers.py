"""L2 layer primitives with *explicit* forward/backward.

HOT's contribution is what happens between the forward and backward pass
of every linear layer: which tensors are saved (ABC), in what format
(HLA+INT8), and how each gradient GEMM is approximated (HQ vs HLA). To
make that first-class — and to let the rust coordinator own the saved
buffers (the red "CTX" in the paper's Fig 5) — backprop here is written
*manually*: every primitive is a (forward -> ctx, backward(ctx, g) ->
grads) pair instead of relying on jax autodiff. pytest verifies the fp
variant against ``jax.grad`` to machine precision.

All qlinears operate on flattened (N = B*L, D) operands. Because L is a
multiple of the Hadamard tile (16), flattening never mixes samples within
a tile, so per-sample HLA along L equals block-HLA along N.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from compile import hadamard as hd
from compile.config import BackwardConfig
from compile.kernels import ref

Ctx = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# qlinear: y = x @ w.T + b — the paper's object of study
# ---------------------------------------------------------------------------


def qlinear_fwd(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                cfg: BackwardConfig) -> Tuple[jnp.ndarray, Ctx]:
    """Forward (always exact FP32) + build the saved ctx for backward.

    x: (N, I), w: (O, I), b: (O,) -> y: (N, O).

    What goes into ctx is *the* memory story of the paper:
      - fp / lbp / luq / int4 / all gx_* ablations: raw x (these methods
        keep FP activations, Fig 2);
      - hot & gw_hot with ABC: HLA+INT8-compressed x + one scale — 1/8 of
        the bytes (Fig 5's CTX);
      - hot with abc=False (Table 7's first row): raw x is kept and the
        same compression runs at backward time (numerically identical,
        memory savings forfeited).
    """
    y = x @ w.T + b
    # Layers whose N (flattened L) dim doesn't tile into Hadamard blocks —
    # e.g. the pooled classifier head when B % 16 != 0 — keep raw FP
    # residuals and exact backward, matching the paper's practice of
    # leaving the final head un-optimized.
    needs_compressed = (cfg.variant in ("hot", "gw_hot") and cfg.abc
                        and x.shape[0] % cfg.block == 0)
    if needs_compressed:
        xq, sx = ref.hla_compress_ref(x, cfg.rank, cfg.gw_bits, cfg.block,
                                      cfg.criterion)
        ctx = {"xq": xq, "sx": sx}
    else:
        ctx = {"x": x}
    return y, ctx


def _gx_exact(gy, w):
    return gy @ w


def _gw_exact(gy, x):
    return gy.T @ x


def _gx_hq(gy, w, cfg, bits):
    """HQ: HT along the contracted O dim + pseudo-stochastic INT quant."""
    if cfg.use_pallas:
        from compile.kernels import hq_matmul
        return hq_matmul.hq_matmul(gy, w, bits=bits, block=cfg.block)
    return ref.hq_matmul_ref(gy, w, bits=bits, block=cfg.block)


def _gx_q4_noht(gy, w, cfg):
    """Plain INT4 on g_x (Table 2's '4-bit Q' row): no HT protection."""
    s_g = ref.minmax_scale(gy, cfg.gx_bits)
    s_w = ref.minmax_scale(w, cfg.gx_bits)
    q_g = ref.quantize_ps(gy, s_g, cfg.gx_bits)
    q_w = ref.quantize_ps(w, s_w, cfg.gx_bits)
    acc = jax.lax.dot_general(q_g, q_w, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * (s_g * s_w)


def _gx_ext_hla(gy, w, cfg):
    """External HLA on L (LBP-WHT's g_x): compress rows, GEMM, expand."""
    return ref.lbp_gx_ref(gy, w, cfg.rank, cfg.block)


def _gx_int_hla(gy, w, cfg):
    """Internal HLA over the contracted O dim (Table 2's worst row)."""
    gc = hd.block_hla(gy, cfg.rank, axis=1, block=cfg.block)
    wc = hd.block_hla(w, cfg.rank, axis=0, block=cfg.block)
    return gc @ wc


def _gw_hot(gy, ctx, cfg, pt_flag):
    """HOT's g_w: internal HLA along L + INT8, LQS-selected scale scheme.

    ``pt_flag`` is a traced f32 scalar in {0,1}: 1 -> per-token scales for
    the compressed g_y, 0 -> per-tensor. Carrying it as data (rather than
    a static) lets one HLO artifact serve any LQS selection — the rust
    calibration controller just feeds a different mask."""
    gc = hd.block_hla(gy, cfg.rank, axis=0, block=cfg.block, criterion=cfg.criterion)
    if "xq" in ctx:
        xq, sx = ctx["xq"], ctx["sx"]
    else:
        xq, sx = ref.hla_compress_ref(ctx["x"], cfg.rank, cfg.gw_bits,
                                      cfg.block, cfg.criterion)
    bits = cfg.gw_bits
    # per-tensor branch (pure INT8 GEMM)
    s_t = ref.minmax_scale(gc, bits)
    q_t = ref.quantize_ps(gc, s_t, bits)
    out_t = jax.lax.dot_general(q_t, xq, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.int32
                                ).astype(jnp.float32) * (s_t * sx)
    # per-token branch (row scales on the contracted dim -> dequant first)
    s_k = ref.minmax_scale(gc, bits, axis=1)
    g_deq = ref.dequantize(ref.quantize_ps(gc, s_k, bits), s_k)
    out_k = jax.lax.dot_general(g_deq, xq.astype(jnp.float32),
                                (((0,), (0,)), ((), ()))) * sx
    return pt_flag * out_k + (1.0 - pt_flag) * out_t


def _gw_hla_only(gy, ctx, cfg):
    """Internal HLA, FP arithmetic (LBP-WHT's g_w / Table 2 row 3)."""
    x = ctx["x"]
    gc = hd.block_hla(gy, cfg.rank, axis=0, block=cfg.block)
    xc = hd.block_hla(x, cfg.rank, axis=0, block=cfg.block)
    return gc.T @ xc


def _gw_hq4(gy, ctx, cfg):
    """HT+INT4 on g_w (Table 2 row 2 — the configuration that *fails*)."""
    x = ctx["x"]
    gy_t = hd.block_ht(gy, axis=0, block=cfg.block)
    x_t = hd.block_ht(x, axis=0, block=cfg.block)
    s_g = ref.minmax_scale(gy_t, 4)
    s_x = ref.minmax_scale(x_t, 4)
    q_g = ref.quantize_ps(gy_t, s_g, 4)
    q_x = ref.quantize_ps(x_t, s_x, 4)
    acc = jax.lax.dot_general(q_g, q_x, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * (s_g * s_x)


def _luq_pair(gy, other, bits_other=4):
    """LUQ: log-quantize g_y (FP4-style), min-max INT4 the other operand."""
    g_q = ref.quantize_luq(gy, 4)
    s_o = ref.minmax_scale(other, bits_other)
    o_q = ref.dequantize(ref.quantize_ps(other, s_o, bits_other), s_o)
    return g_q, o_q


def qlinear_bwd(gy: jnp.ndarray, w: jnp.ndarray, ctx: Ctx,
                cfg: BackwardConfig, pt_flag: jnp.ndarray
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Backward for y = x w.T + b: returns (g_x, g_w, g_b).

    gy: (N, O). Every variant keeps g_b exact (a column sum — the paper
    never quantizes bias gradients)."""
    v = cfg.variant
    g_b = jnp.sum(gy, axis=0)
    n, o = gy.shape
    # static shape gates: the HQ path transforms the contracted O dim, the
    # HLA/L paths tile the flattened N dim. Layers that don't tile (the
    # classifier head, odd patch dims) silently fall back to exact BP.
    can_o = o % cfg.block == 0
    can_n = n % cfg.block == 0

    # --- g_x (needs w) ------------------------------------------------
    if v in ("hot", "gx_hq4") and not can_o:
        g_x = _gx_exact(gy, w)
    elif v in ("lbp", "gx_ext_hla", "gx_int_hla") and not (can_n if v != "gx_int_hla" else can_o):
        g_x = _gx_exact(gy, w)
    elif v in ("hot", "gx_hq4"):
        g_x = _gx_hq(gy, w, cfg, cfg.gx_bits)
    elif v == "gx_q4":
        g_x = _gx_q4_noht(gy, w, cfg)
    elif v in ("lbp", "gx_ext_hla"):
        g_x = _gx_ext_hla(gy, w, cfg)
    elif v == "gx_int_hla":
        g_x = _gx_int_hla(gy, w, cfg)
    elif v == "luq":
        g_q, w_q = _luq_pair(gy, w)
        g_x = g_q @ w_q
    elif v == "int4":
        g_x = _gx_q4_noht(gy, w, cfg)
    else:  # fp, gw_*
        g_x = _gx_exact(gy, w)

    # --- g_w (needs saved x / compressed x) ----------------------------
    if v in ("hot", "gw_hot", "lbp", "gw_hla", "gw_hq4") and not can_n:
        g_w = _gw_exact(gy, ctx["x"])
    elif v in ("hot", "gw_hot"):
        g_w = _gw_hot(gy, ctx, cfg, pt_flag)
    elif v in ("lbp", "gw_hla"):
        g_w = _gw_hla_only(gy, ctx, cfg)
    elif v == "gw_hq4":
        g_w = _gw_hq4(gy, ctx, cfg)
    elif v == "luq":
        g_q, x_q = _luq_pair(gy, ctx["x"])
        g_w = g_q.T @ x_q
    elif v == "int4":
        x = ctx["x"]
        s_g = ref.minmax_scale(gy, 4)
        s_x = ref.minmax_scale(x, 4)
        q_g = ref.quantize_ps(gy, s_g, 4)
        q_x = ref.quantize_ps(x, s_x, 4)
        g_w = jax.lax.dot_general(q_g, q_x, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.int32
                                  ).astype(jnp.float32) * (s_g * s_x)
    else:  # fp, gx_*
        g_w = _gw_exact(gy, ctx["x"])

    # g_w is (O, I): gy.T @ x with gy (N,O), x (N,I) — matches w's layout.
    return g_x, g_w, g_b


# ---------------------------------------------------------------------------
# LayerNorm (FP; HOT leaves normalization layers untouched)
# ---------------------------------------------------------------------------


def layernorm_fwd(x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray,
                  eps: float = 1e-5) -> Tuple[jnp.ndarray, Ctx]:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    xc = x - mu
    var = jnp.mean(xc * xc, axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + eps)
    xhat = xc * rstd
    return xhat * gamma + beta, {"xhat": xhat, "rstd": rstd}


def layernorm_bwd(gy: jnp.ndarray, gamma: jnp.ndarray, ctx: Ctx
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    xhat, rstd = ctx["xhat"], ctx["rstd"]
    d = xhat.shape[-1]
    g_gamma = jnp.sum(gy * xhat, axis=tuple(range(gy.ndim - 1)))
    g_beta = jnp.sum(gy, axis=tuple(range(gy.ndim - 1)))
    gh = gy * gamma
    g_x = (gh - jnp.mean(gh, axis=-1, keepdims=True)
           - xhat * jnp.mean(gh * xhat, axis=-1, keepdims=True)) * rstd
    _ = d
    return g_x, g_gamma, g_beta


# ---------------------------------------------------------------------------
# GELU (tanh approximation, as in ViT/timm)
# ---------------------------------------------------------------------------

_K0 = 0.7978845608028654  # sqrt(2/pi)
_K1 = 0.044715


def gelu_fwd(x: jnp.ndarray) -> Tuple[jnp.ndarray, Ctx]:
    t = jnp.tanh(_K0 * (x + _K1 * x ** 3))
    return 0.5 * x * (1.0 + t), {"x": x, "t": t}


def gelu_bwd(gy: jnp.ndarray, ctx: Ctx) -> jnp.ndarray:
    x, t = ctx["x"], ctx["t"]
    dt = (1.0 - t * t) * _K0 * (1.0 + 3.0 * _K1 * x * x)
    return gy * (0.5 * (1.0 + t) + 0.5 * x * dt)


# ---------------------------------------------------------------------------
# Multi-head self-attention core (FP; the qkv/proj linears around it are
# qlinears and carry HOT's machinery — the score/context matmuls stay FP,
# matching the paper which only rewires nn.Linear/conv backward)
# ---------------------------------------------------------------------------


def attention_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  heads: int, causal: bool) -> Tuple[jnp.ndarray, Ctx]:
    """q, k, v: (B, L, D) -> out (B, L, D)."""
    b, l, d = q.shape
    dh = d // heads

    def split(t):
        return t.reshape(b, l, heads, dh).transpose(0, 2, 1, 3)

    qh, kh, vh = split(q), split(k), split(v)
    scores = (qh @ kh.transpose(0, 1, 3, 2)) / jnp.sqrt(float(dh))
    if causal:
        mask = jnp.tril(jnp.ones((l, l), jnp.float32))
        scores = jnp.where(mask[None, None] > 0, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = (p @ vh).transpose(0, 2, 1, 3).reshape(b, l, d)
    return out, {"qh": qh, "kh": kh, "vh": vh, "p": p}


def attention_bwd(gy: jnp.ndarray, ctx: Ctx, heads: int
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    qh, kh, vh, p = ctx["qh"], ctx["kh"], ctx["vh"], ctx["p"]
    b, h, l, dh = qh.shape
    d = h * dh
    go = gy.reshape(b, l, h, dh).transpose(0, 2, 1, 3)
    g_v = p.transpose(0, 1, 3, 2) @ go
    g_p = go @ vh.transpose(0, 1, 3, 2)
    # softmax backward: g_s = p * (g_p - sum(g_p * p))
    g_s = p * (g_p - jnp.sum(g_p * p, axis=-1, keepdims=True))
    g_s = g_s / jnp.sqrt(float(dh))
    g_q = g_s @ kh
    g_k = g_s.transpose(0, 1, 3, 2) @ qh

    def merge(t):
        return t.transpose(0, 2, 1, 3).reshape(b, l, d)

    return merge(g_q), merge(g_k), merge(g_v)


# ---------------------------------------------------------------------------
# Softmax cross-entropy (mean over all label positions)
# ---------------------------------------------------------------------------


def softmax_xent_fwd(logits: jnp.ndarray, labels: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, Ctx]:
    """logits (N, C), labels (N,) int32 -> (loss, acc, ctx)."""
    lse = jax.nn.logsumexp(logits, axis=-1, keepdims=True)
    logp = logits - lse
    n, c = logits.shape
    onehot = jax.nn.one_hot(labels, c, dtype=jnp.float32)
    loss = -jnp.mean(jnp.sum(onehot * logp, axis=-1))
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
    _ = n
    return loss, acc, {"p": jnp.exp(logp), "onehot": onehot}


def softmax_xent_bwd(ctx: Ctx) -> jnp.ndarray:
    """d loss / d logits (for unit upstream gradient)."""
    return (ctx["p"] - ctx["onehot"]) / float(ctx["p"].shape[0])
