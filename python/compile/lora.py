"""HOT + LoRA joint optimization (paper §5.3, Tables 3/4/9).

LoRA freezes the base weight w and learns a low-rank update B·A (rank
``r_lora``). HOT composes with it per the paper's ablation (Table 9):

  * frozen path:    w never updates, so **g_w is skipped entirely**; only
    g_x flows through w. ``hot_frozen=True`` computes that g_x with HOT's
    HQ-INT4 (the winning configuration).
  * decomposed path: A/B gradients. ``hot_decomposed=True`` applies
    HLA+INT8 to them (the configuration the paper shows *fails* —
    57.96% vs 92.51%); default is exact BP, the paper's recommendation.

Only LoRA-adapted qlinears differ from model.py; everything else
(layernorm, attention core, gelu, loss) is reused. Adapted layers:
qkv, proj, fc1, fc2 (vit/lm blocks). embed/head stay trainable in full
(standard practice for small heads).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np
import jax.numpy as jnp

from compile import layers as L
from compile import model as M
from compile.config import BackwardConfig, ModelConfig, OptimizerConfig
from compile.train import adamw_update

Params = Dict[str, jnp.ndarray]

LORA_TARGETS = ("attn.wqkv", "attn.wo", "fc1.w", "fc2.w")


def lora_param_specs(cfg: ModelConfig, r_lora: int) -> List[Tuple[str, tuple]]:
    """(name, shape) for every LoRA tensor, sorted by name."""
    base = M.init_params(cfg, seed=0)
    specs = []
    for k, v in base.items():
        if any(k.endswith(t) for t in LORA_TARGETS):
            o, i = v.shape
            specs.append((k + ".lora_a", (r_lora, i)))
            specs.append((k + ".lora_b", (o, r_lora)))
    return sorted(specs)


def init_lora(cfg: ModelConfig, r_lora: int = 8, seed: int = 1) -> Params:
    """A ~ N(0, 1/r); B = 0 (standard LoRA init: adapter starts as a no-op)."""
    rng = np.random.default_rng(seed)
    out: Params = {}
    for name, shape in lora_param_specs(cfg, r_lora):
        if name.endswith(".lora_a"):
            out[name] = jnp.asarray(rng.normal(0, 1.0 / shape[0], shape),
                                    jnp.float32)
        else:
            out[name] = jnp.zeros(shape, jnp.float32)
    return out


def lora_names(cfg: ModelConfig, r_lora: int = 8) -> List[str]:
    return [n for n, _ in lora_param_specs(cfg, r_lora)]


# ---------------------------------------------------------------------------
# LoRA-adapted qlinear
# ---------------------------------------------------------------------------


def qlinear_lora_fwd(x, w, b, a_mat, b_mat, scale: float,
                     bcfg: BackwardConfig, hot_decomposed: bool):
    """y = x wᵀ + scale · (x Aᵀ) Bᵀ + b.

    ctx keeps u = x Aᵀ (tiny: N×r) and x — compressed iff the decomposed
    path runs under HOT (otherwise FP, per the paper's winning recipe).
    The frozen path never needs x at all (g_w skipped)."""
    u = x @ a_mat.T
    y = x @ w.T + scale * (u @ b_mat.T) + b
    from compile.kernels import ref
    if hot_decomposed and x.shape[0] % bcfg.block == 0:
        xq, sx = ref.hla_compress_ref(x, bcfg.rank, bcfg.gw_bits, bcfg.block,
                                      bcfg.criterion)
        ctx = {"u": u, "xq": xq, "sx": sx}
    else:
        ctx = {"u": u, "x": x}
    return y, ctx


def qlinear_lora_bwd(gy, w, a_mat, b_mat, scale: float, ctx,
                     bcfg: BackwardConfig, hot_frozen: bool,
                     hot_decomposed: bool, pt_flag):
    """Returns (g_x, g_a, g_b_mat, g_bias). No g_w — w is frozen."""
    from compile import hadamard as hd
    from compile.kernels import ref
    from compile.layers import _gx_hq

    n, o = gy.shape
    g_bias = jnp.sum(gy, axis=0)
    # frozen-path g_x
    if hot_frozen and o % bcfg.block == 0:
        g_x = _gx_hq(gy, w, bcfg, bcfg.gx_bits)
    else:
        g_x = gy @ w
    # decomposed-path gradients
    u = ctx["u"]
    g_u = scale * (gy @ b_mat)  # (N, r)
    if hot_decomposed and "xq" in ctx:
        # HLA+INT8 on the decomposed g_w-like products (Table 9 ablation)
        gc_u = hd.block_hla(g_u, bcfg.rank, axis=0, block=bcfg.block)
        s_gu = ref.minmax_scale(gc_u, bcfg.gw_bits)
        q_gu = ref.quantize_ps(gc_u, s_gu, bcfg.gw_bits)
        import jax
        g_a = jax.lax.dot_general(
            q_gu, ctx["xq"], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.int32
        ).astype(jnp.float32) * (s_gu * ctx["sx"])
        gc_y = hd.block_hla(gy, bcfg.rank, axis=0, block=bcfg.block)
        uc = hd.block_hla(u, bcfg.rank, axis=0, block=bcfg.block)
        s_gy = ref.minmax_scale(gc_y, bcfg.gw_bits)
        s_u = ref.minmax_scale(uc, bcfg.gw_bits)
        g_bm = scale * (ref.dequantize(ref.quantize_ps(gc_y, s_gy, bcfg.gw_bits), s_gy).T
                        @ ref.dequantize(ref.quantize_ps(uc, s_u, bcfg.gw_bits), s_u))
    else:
        x = ctx["x"]
        g_a = g_u.T @ x                      # (r, I)
        g_bm = scale * (gy.T @ u)            # (O, r)
    g_x = g_x + g_u @ a_mat
    _ = pt_flag
    return g_x, g_a, g_bm, g_bias


# ---------------------------------------------------------------------------
# Full LoRA model forward/backward (reuses model.py non-linear pieces)
# ---------------------------------------------------------------------------


def forward_lora(params: Params, lparams: Params, x, labels,
                 cfg: ModelConfig, bcfg: BackwardConfig, scale: float,
                 hot_decomposed: bool, lqs_mask):
    b, l, d = x.shape[0], cfg.seq, cfg.d_model
    xf = M._embed_input(params, x, cfg)
    ctxs: list = []
    qi = 0

    def ql_plain(name, t2d, w, bias):
        nonlocal qi
        y, ctx = L.qlinear_fwd(t2d, w, bias, bcfg)
        ctxs.append(("ql", name, ctx, lqs_mask[qi]))
        qi += 1
        return y

    def ql_lora(wname, bname, t2d):
        nonlocal qi
        y, ctx = qlinear_lora_fwd(t2d, params[wname], params[bname],
                                  lparams[wname + ".lora_a"],
                                  lparams[wname + ".lora_b"],
                                  scale, bcfg, hot_decomposed)
        ctxs.append(("qlora", wname, ctx, lqs_mask[qi]))
        qi += 1
        return y

    h = ql_plain("embed", xf.reshape(b * l, -1), params["embed.w"],
                 params["embed.b"])
    h = h.reshape(b, l, d) + params["pos"][None]
    for i in range(cfg.depth):
        pre = f"blk{i}."
        hn, c1 = L.layernorm_fwd(h, params[pre + "ln1.g"], params[pre + "ln1.b"])
        ctxs.append(("ln", pre + "ln1", c1, None))
        qkv = ql_lora(pre + "attn.wqkv", pre + "attn.bqkv",
                      hn.reshape(b * l, d)).reshape(b, l, 3 * d)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        att, ca = L.attention_fwd(q, k, v, cfg.heads, causal=(cfg.arch == "lm"))
        ctxs.append(("attn", pre + "attn", ca, None))
        proj = ql_lora(pre + "attn.wo", pre + "attn.bo",
                       att.reshape(b * l, d))
        h = h + proj.reshape(b, l, d)
        hn, c2 = L.layernorm_fwd(h, params[pre + "ln2.g"], params[pre + "ln2.b"])
        ctxs.append(("ln", pre + "ln2", c2, None))
        f1 = ql_lora(pre + "fc1.w", pre + "fc1.b", hn.reshape(b * l, d))
        g1, cg = L.gelu_fwd(f1)
        ctxs.append(("gelu", pre + "gelu", cg, None))
        f2 = ql_lora(pre + "fc2.w", pre + "fc2.b", g1)
        h = h + f2.reshape(b, l, d)
    hn, cf = L.layernorm_fwd(h, params["lnf.g"], params["lnf.b"])
    ctxs.append(("ln", "lnf", cf, None))
    pooled = jnp.mean(hn, axis=1)
    logits = ql_plain("head", pooled, params["head.w"], params["head.b"])
    loss, acc, cce = L.softmax_xent_fwd(logits, labels)
    ctxs.append(("ce", "loss", cce, None))
    return loss, acc, ctxs


def backward_lora(params: Params, lparams: Params, x, cfg: ModelConfig,
                  bcfg: BackwardConfig, scale: float, hot_frozen: bool,
                  hot_decomposed: bool, ctxs: list) -> Params:
    """Gradients for LoRA params + embed/head (the trainable set)."""
    b, l, d = x.shape[0], cfg.seq, cfg.d_model
    grads: Params = {}
    it = list(ctxs)[::-1]
    pos = 0

    def take(kind):
        nonlocal pos
        k, name, ctx, flag = it[pos]
        assert k == kind, (k, kind, name)
        pos += 1
        return name, ctx, flag

    _, cce, _ = take("ce")
    g_logits = L.softmax_xent_bwd(cce)
    name, ch, fh = take("ql")
    g_pooled, grads["head.w"], grads["head.b"] = L.qlinear_bwd(
        g_logits, params["head.w"], ch, bcfg, fh)
    _, cf, _ = take("ln")
    g_hn = jnp.broadcast_to(g_pooled[:, None, :] / float(l), (b, l, d))
    g_h, _, _ = L.layernorm_bwd(g_hn, params["lnf.g"], cf)

    def lora_bwd(gy, wname, ctx, flag):
        g_x, g_a, g_bm, g_bias = qlinear_lora_bwd(
            gy, params[wname], lparams[wname + ".lora_a"],
            lparams[wname + ".lora_b"], scale, ctx, bcfg,
            hot_frozen, hot_decomposed, flag)
        grads[wname + ".lora_a"] = g_a
        grads[wname + ".lora_b"] = g_bm
        _ = g_bias  # biases frozen alongside w
        return g_x

    for i in reversed(range(cfg.depth)):
        pre = f"blk{i}."
        name, cfc2, ff2 = take("qlora")
        g_f2in = lora_bwd(g_h.reshape(b * l, d), pre + "fc2.w", cfc2, ff2)
        _, cg, _ = take("gelu")
        g_f1 = L.gelu_bwd(g_f2in, cg)
        name, cfc1, ff1 = take("qlora")
        g_hn2 = lora_bwd(g_f1, pre + "fc1.w", cfc1, ff1)
        _, c2, _ = take("ln")
        g_res, _, _ = L.layernorm_bwd(g_hn2.reshape(b, l, d),
                                      params[pre + "ln2.g"], c2)
        g_h = g_h + g_res
        name, cproj, fp_ = take("qlora")
        g_att = lora_bwd(g_h.reshape(b * l, d), pre + "attn.wo", cproj, fp_)
        _, ca, _ = take("attn")
        g_q, g_k, g_v = L.attention_bwd(g_att.reshape(b, l, d), ca, cfg.heads)
        g_qkv = jnp.concatenate([g_q, g_k, g_v], axis=-1)
        name, cqkv, fq = take("qlora")
        g_hn1 = lora_bwd(g_qkv.reshape(b * l, 3 * d), pre + "attn.wqkv",
                         cqkv, fq)
        _, c1, _ = take("ln")
        g_res, _, _ = L.layernorm_bwd(g_hn1.reshape(b, l, d),
                                      params[pre + "ln1.g"], c1)
        g_h = g_h + g_res

    _, cemb, fe = take("ql")
    _, grads["embed.w"], grads["embed.b"] = L.qlinear_bwd(
        g_h.reshape(b * l, d), params["embed.w"], cemb, bcfg, fe)
    assert pos == len(it)
    return grads


def make_lora_train_step(cfg: ModelConfig, bcfg: BackwardConfig,
                         ocfg: OptimizerConfig, r_lora: int = 8,
                         scale: float = 2.0, hot_frozen: bool = True,
                         hot_decomposed: bool = False):
    """f(base_params, trainable, m, v, step, lr, lqs_mask, x, y) ->
    (new_trainable, new_m, new_v, loss, acc).

    ``trainable`` = LoRA tensors + embed/head (+biases), flattened in
    sorted-name order by aot.py."""
    _ = r_lora

    def split(trainable):
        lp = {k: v for k, v in trainable.items() if ".lora_" in k}
        extra = {k: v for k, v in trainable.items() if ".lora_" not in k}
        return lp, extra

    def step_fn(base, trainable, m, v, step, lr, lqs_mask, x, y):
        lp, extra = split(trainable)
        merged = dict(base)
        merged.update(extra)  # embed/head live updates
        loss, acc, ctxs = forward_lora(merged, lp, x, y, cfg, bcfg, scale,
                                       hot_decomposed, lqs_mask)
        grads = backward_lora(merged, lp, x, cfg, bcfg, scale,
                              hot_frozen, hot_decomposed, ctxs)
        new_t, new_m, new_v = adamw_update(trainable, grads, m, v, step,
                                           lr, ocfg)
        return new_t, new_m, new_v, loss, acc

    return step_fn
