"""L2 model: ViT-style transformer / MLP with explicit manual backprop.

``forward`` returns (logits, ctx-list); ``backward`` consumes the ctx-list
and produces the full gradient pytree. The fp variant is verified against
``jax.grad`` in pytest. The ctx-list is the paper's Fig-5 "CTX": in the
split fwd/bwd artifacts its qlinear entries (int8 + scale under HOT's
ABC) literally cross the HLO boundary into the rust coordinator's buffer
manager.

Parameter pytree layout (dict; flattened in sorted-key order by aot.py):

  embed.w (D, P)  embed.b (D,)  pos (L, D)
  blk{i}.ln1.g/.b          blk{i}.attn.wqkv (3D, D) / .bqkv
  blk{i}.attn.wo (D, D) / .bo
  blk{i}.ln2.g/.b          blk{i}.fc1.w (M, D)/.b   blk{i}.fc2.w (D, M)/.b
  lnf.g/.b      head.w (C, D)   head.b (C,)

qlinear order for the LQS mask: embed, then per block [qkv, proj, fc1,
fc2] (vit/lm) or [fc1, fc2] (mlp), then head — matching
``ModelConfig.n_qlinears``.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from compile import layers as L
from compile.config import BackwardConfig, ModelConfig

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    """truncated-normal-ish init (numpy: artifacts must be reproducible
    without jax RNG-version drift; rust re-reads these exact bytes)."""
    rng = np.random.default_rng(seed)

    def dense(o, i, scale=None):
        s = scale if scale is not None else (2.0 / (o + i)) ** 0.5
        return jnp.asarray(rng.normal(0.0, s, size=(o, i)), jnp.float32)

    def zeros(*shape):
        return jnp.zeros(shape, jnp.float32)

    def ones(*shape):
        return jnp.ones(shape, jnp.float32)

    d, m, l = cfg.d_model, cfg.d_mlp, cfg.seq
    p: Params = {
        "embed.w": dense(d, cfg.in_dim),
        "embed.b": zeros(d),
        "pos": jnp.asarray(rng.normal(0, 0.02, size=(l, d)), jnp.float32),
        "lnf.g": ones(d), "lnf.b": zeros(d),
        "head.w": dense(cfg.n_classes, d), "head.b": zeros(cfg.n_classes),
    }
    for i in range(cfg.depth):
        pre = f"blk{i}."
        p[pre + "ln2.g"] = ones(d)
        p[pre + "ln2.b"] = zeros(d)
        p[pre + "fc1.w"] = dense(m, d)
        p[pre + "fc1.b"] = zeros(m)
        p[pre + "fc2.w"] = dense(d, m)
        p[pre + "fc2.b"] = zeros(d)
        if cfg.arch in ("vit", "lm"):
            p[pre + "ln1.g"] = ones(d)
            p[pre + "ln1.b"] = zeros(d)
            p[pre + "attn.wqkv"] = dense(3 * d, d)
            p[pre + "attn.bqkv"] = zeros(3 * d)
            p[pre + "attn.wo"] = dense(d, d)
            p[pre + "attn.bo"] = zeros(d)
    return p


def param_names(cfg: ModelConfig) -> List[str]:
    return sorted(init_params(cfg, seed=0).keys())


def qlinear_names(cfg: ModelConfig) -> List[str]:
    """LQS-mask ordering of the quantized linears."""
    names = ["embed"]
    for i in range(cfg.depth):
        if cfg.arch in ("vit", "lm"):
            names += [f"blk{i}.qkv", f"blk{i}.proj"]
        names += [f"blk{i}.fc1", f"blk{i}.fc2"]
    names.append("head")
    return names


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _embed_input(params: Params, x, cfg: ModelConfig):
    """vision/mlp: x (B, L, P) patch features; lm: x (B, L) int32 tokens
    are one-hot embedded through the same qlinear (keeps every trainable
    matmul on the HOT path)."""
    if cfg.arch == "lm":
        x = jax.nn.one_hot(x, cfg.in_dim, dtype=jnp.float32)
    return x


def forward(params: Params, x, labels, cfg: ModelConfig,
            bcfg: BackwardConfig, lqs_mask: jnp.ndarray
            ) -> Tuple[jnp.ndarray, jnp.ndarray, list]:
    """Returns (loss, acc, ctxs). ctxs[k] aligns with the backward walk."""
    b = x.shape[0]
    l, d = cfg.seq, cfg.d_model
    xf = _embed_input(params, x, cfg)
    ctxs: list = []
    qi = 0  # qlinear index into lqs_mask

    def ql(name, t2d, w, bias):
        nonlocal qi
        y, ctx = L.qlinear_fwd(t2d, w, bias, bcfg)
        ctxs.append(("ql", name, ctx, lqs_mask[qi]))
        qi += 1
        return y

    h = ql("embed", xf.reshape(b * l, -1), params["embed.w"], params["embed.b"])
    h = h.reshape(b, l, d) + params["pos"][None]

    for i in range(cfg.depth):
        pre = f"blk{i}."
        if cfg.arch in ("vit", "lm"):
            hn, ctx_ln1 = L.layernorm_fwd(h, params[pre + "ln1.g"],
                                          params[pre + "ln1.b"])
            ctxs.append(("ln", pre + "ln1", ctx_ln1, None))
            qkv = ql(pre + "qkv", hn.reshape(b * l, d),
                     params[pre + "attn.wqkv"], params[pre + "attn.bqkv"])
            qkv = qkv.reshape(b, l, 3 * d)
            q, k, v = jnp.split(qkv, 3, axis=-1)
            att, ctx_att = L.attention_fwd(q, k, v, cfg.heads,
                                           causal=(cfg.arch == "lm"))
            ctxs.append(("attn", pre + "attn", ctx_att, None))
            proj = ql(pre + "proj", att.reshape(b * l, d),
                      params[pre + "attn.wo"], params[pre + "attn.bo"])
            h = h + proj.reshape(b, l, d)

        hn, ctx_ln2 = L.layernorm_fwd(h, params[pre + "ln2.g"],
                                      params[pre + "ln2.b"])
        ctxs.append(("ln", pre + "ln2", ctx_ln2, None))
        f1 = ql(pre + "fc1", hn.reshape(b * l, d),
                params[pre + "fc1.w"], params[pre + "fc1.b"])
        g1, ctx_gelu = L.gelu_fwd(f1)
        ctxs.append(("gelu", pre + "gelu", ctx_gelu, None))
        f2 = ql(pre + "fc2", g1, params[pre + "fc2.w"], params[pre + "fc2.b"])
        h = h + f2.reshape(b, l, d)

    hn, ctx_lnf = L.layernorm_fwd(h, params["lnf.g"], params["lnf.b"])
    ctxs.append(("ln", "lnf", ctx_lnf, None))

    if cfg.arch == "lm":
        logits = ql("head", hn.reshape(b * l, d),
                    params["head.w"], params["head.b"])
        loss, acc, ctx_ce = L.softmax_xent_fwd(logits, labels.reshape(b * l))
    else:
        pooled = jnp.mean(hn, axis=1)  # (B, D)
        logits = ql("head", pooled, params["head.w"], params["head.b"])
        loss, acc, ctx_ce = L.softmax_xent_fwd(logits, labels)
    ctxs.append(("ce", "loss", ctx_ce, None))
    return loss, acc, ctxs


# ---------------------------------------------------------------------------
# Backward (walks ctxs in reverse; mirrors forward exactly)
# ---------------------------------------------------------------------------


def backward(params: Params, x, cfg: ModelConfig, bcfg: BackwardConfig,
             ctxs: list, diag_sink: list = None) -> Params:
    """Full-model manual backprop. Returns grads keyed like params.

    ``diag_sink``: optional list; when given, every qlinear appends
    (qlinear_name, g_y, ctx, weight_name) in *reverse* model order — the
    raw material for the LQS calibration step and the Fig-4/Fig-6
    diagnostics. (The extra retention is why calibration runs on a small
    set before training, exactly as in the paper §5.2.2.)"""
    b = (x.shape[0])
    l, d = cfg.seq, cfg.d_model
    grads: Params = {}
    it = list(ctxs)[::-1]
    pos = 0

    def take(kind):
        nonlocal pos
        k, name, ctx, flag = it[pos]
        assert k == kind, (k, kind, name)
        pos += 1
        return name, ctx, flag

    # --- loss & head ----------------------------------------------------
    _, ctx_ce, _ = take("ce")
    g_logits = L.softmax_xent_bwd(ctx_ce)

    def ql_bwd(gy, wname, bname, ctx, flag):
        if diag_sink is not None:
            diag_sink.append((wname, gy, ctx, flag))
        g_x, g_w, g_b = L.qlinear_bwd(gy, params[wname], ctx, bcfg, flag)
        grads[wname] = g_w
        grads[bname] = g_b
        return g_x

    name, ctx_head, flag_head = take("ql")
    g_pooled_or_seq = ql_bwd(g_logits, "head.w", "head.b", ctx_head, flag_head)

    _, ctx_lnf, _ = take("ln")
    if cfg.arch == "lm":
        g_hn = g_pooled_or_seq.reshape(b, l, d)
    else:
        g_hn = jnp.broadcast_to(g_pooled_or_seq[:, None, :] / float(l),
                                (b, l, d))
    g_h, grads["lnf.g"], grads["lnf.b"] = L.layernorm_bwd(g_hn, params["lnf.g"],
                                                          ctx_lnf)

    # --- blocks in reverse ----------------------------------------------
    for i in reversed(range(cfg.depth)):
        pre = f"blk{i}."
        # MLP sub-block
        _, ctx_fc2, flag_fc2 = take("ql")
        g_f2in = ql_bwd(g_h.reshape(b * l, d), pre + "fc2.w", pre + "fc2.b",
                        ctx_fc2, flag_fc2)
        _, ctx_gelu, _ = take("gelu")
        g_f1 = L.gelu_bwd(g_f2in, ctx_gelu)
        _, ctx_fc1, flag_fc1 = take("ql")
        g_hn2 = ql_bwd(g_f1, pre + "fc1.w", pre + "fc1.b", ctx_fc1, flag_fc1)
        _, ctx_ln2, _ = take("ln")
        g_res, grads[pre + "ln2.g"], grads[pre + "ln2.b"] = L.layernorm_bwd(
            g_hn2.reshape(b, l, d), params[pre + "ln2.g"], ctx_ln2)
        g_h = g_h + g_res

        if cfg.arch in ("vit", "lm"):
            _, ctx_proj, flag_proj = take("ql")
            g_att = ql_bwd(g_h.reshape(b * l, d), pre + "attn.wo",
                           pre + "attn.bo", ctx_proj, flag_proj)
            _, ctx_att, _ = take("attn")
            g_q, g_k, g_v = L.attention_bwd(g_att.reshape(b, l, d), ctx_att,
                                            cfg.heads)
            g_qkv = jnp.concatenate([g_q, g_k, g_v], axis=-1)
            _, ctx_qkv, flag_qkv = take("ql")
            g_hn1 = ql_bwd(g_qkv.reshape(b * l, 3 * d), pre + "attn.wqkv",
                           pre + "attn.bqkv", ctx_qkv, flag_qkv)
            _, ctx_ln1, _ = take("ln")
            g_res, grads[pre + "ln1.g"], grads[pre + "ln1.b"] = L.layernorm_bwd(
                g_hn1.reshape(b, l, d), params[pre + "ln1.g"], ctx_ln1)
            g_h = g_h + g_res

    # --- embed ------------------------------------------------------------
    grads["pos"] = jnp.sum(g_h, axis=0)
    _, ctx_embed, flag_embed = take("ql")
    ql_bwd(g_h.reshape(b * l, d), "embed.w", "embed.b", ctx_embed, flag_embed)
    assert pos == len(it), (pos, len(it))
    return grads


# ---------------------------------------------------------------------------
# Convenience wrappers
# ---------------------------------------------------------------------------


def loss_and_grads(params: Params, x, labels, cfg: ModelConfig,
                   bcfg: BackwardConfig, lqs_mask: jnp.ndarray):
    loss, acc, ctxs = forward(params, x, labels, cfg, bcfg, lqs_mask)
    grads = backward(params, x, cfg, bcfg, ctxs)
    return loss, acc, grads


def loss_fp_autodiff(params: Params, x, labels, cfg: ModelConfig):
    """Reference loss via the same forward, for jax.grad cross-checks."""
    mask = jnp.zeros((cfg.n_qlinears(),), jnp.float32)
    loss, _, _ = forward(params, x, labels, cfg,
                         BackwardConfig(variant="fp"), mask)
    return loss
