"""L2 training graphs: fused train step, split fwd/bwd, eval, calibration.

Every function here is lowered to an HLO-text artifact by aot.py and then
driven from rust. Calling conventions are flat lists of arrays (pytrees
flattened in ``model.param_names`` order) — the manifest records the
ordering so the rust side never guesses.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from compile import hadamard as hd
from compile import model as M
from compile.config import BackwardConfig, ModelConfig, OptimizerConfig
from compile.kernels import ref

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# AdamW (decoupled weight decay; the paper's fine-tuning optimizer)
# ---------------------------------------------------------------------------


def adamw_update(params: Params, grads: Params, m: Params, v: Params,
                 step: jnp.ndarray, lr: jnp.ndarray, ocfg: OptimizerConfig
                 ) -> Tuple[Params, Params, Params]:
    """One AdamW step. ``step`` is the 1-based step counter (f32 scalar),
    ``lr`` the scheduled learning rate (rust owns the schedule)."""
    b1, b2, eps, wd = ocfg.beta1, ocfg.beta2, ocfg.eps, ocfg.weight_decay
    bc1 = 1.0 - b1 ** step
    bc2 = 1.0 - b2 ** step
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        nm = b1 * m[k] + (1.0 - b1) * g
        nv = b2 * v[k] + (1.0 - b2) * (g * g)
        upd = (nm / bc1) / (jnp.sqrt(nv / bc2) + eps)
        # no weight decay on norms/biases/pos (standard practice)
        decay = 0.0 if (k.endswith(".b") or k.endswith(".g")
                        or k == "pos") else wd
        new_p[k] = params[k] - lr * (upd + decay * params[k])
        new_m[k] = nm
        new_v[k] = nv
    return new_p, new_m, new_v


def sgd_update(params: Params, grads: Params, m: Params, lr: jnp.ndarray,
               momentum: float = 0.9, wd: float = 5e-4
               ) -> Tuple[Params, Params]:
    """SGD+momentum (the paper's pre-training optimizer for CNNs)."""
    new_p, new_m = {}, {}
    for k in params:
        decay = 0.0 if (k.endswith(".b") or k.endswith(".g")
                        or k == "pos") else wd
        g = grads[k] + decay * params[k]
        nm = momentum * m[k] + g
        new_p[k] = params[k] - lr * nm
        new_m[k] = nm
    return new_p, new_m


# ---------------------------------------------------------------------------
# Fused step (fwd + bwd + optimizer in one HLO module)
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, bcfg: BackwardConfig,
                    ocfg: OptimizerConfig):
    """Returns f(params, m, v, step, lr, lqs_mask, x, y) ->
    (new_params, new_m, new_v, loss, acc)."""

    def train_step(params, m, v, step, lr, lqs_mask, x, y):
        loss, acc, ctxs = M.forward(params, x, y, cfg, bcfg, lqs_mask)
        grads = M.backward(params, x, cfg, bcfg, ctxs)
        new_p, new_m, new_v = adamw_update(params, grads, m, v, step, lr, ocfg)
        return new_p, new_m, new_v, loss, acc

    return train_step


def make_eval_step(cfg: ModelConfig, bcfg: BackwardConfig):
    def eval_step(params, x, y):
        mask = jnp.zeros((cfg.n_qlinears(),), jnp.float32)
        loss, acc, _ = M.forward(params, x, y, cfg,
                                 BackwardConfig(variant="fp"), mask)
        return loss, acc

    _ = bcfg
    return eval_step


def make_grad_step(cfg: ModelConfig, bcfg: BackwardConfig):
    """Gradients only (no optimizer) — used for microbatch accumulation:
    the rust coordinator sums these across microbatches then calls the
    separate opt_step artifact once."""

    def grad_step(params, lqs_mask, x, y):
        loss, acc, ctxs = M.forward(params, x, y, cfg, bcfg, lqs_mask)
        grads = M.backward(params, x, cfg, bcfg, ctxs)
        return grads, loss, acc

    return grad_step


def make_opt_step(cfg: ModelConfig, ocfg: OptimizerConfig):
    def opt_step(params, grads, m, v, step, lr):
        return adamw_update(params, grads, m, v, step, lr, ocfg)

    _ = cfg
    return opt_step


# ---------------------------------------------------------------------------
# Split fwd / bwd (the ABC story: compressed ctx crosses the HLO boundary
# and lives in the rust coordinator's buffer manager between the calls)
# ---------------------------------------------------------------------------


def ctx_to_flat(ctxs: list) -> Tuple[List[jnp.ndarray], list]:
    """Flatten the ctx list to arrays + a static schema.

    Schema entries: (kind, name, [(key, shape, dtype), ...], has_flag)."""
    flat, schema = [], []
    for kind, name, ctx, flag in ctxs:
        keys = sorted(ctx.keys())
        schema.append((kind, name,
                       [(k, tuple(ctx[k].shape), str(ctx[k].dtype))
                        for k in keys],
                       flag is not None))
        for k in keys:
            flat.append(ctx[k])
    return flat, schema


def flat_to_ctx(flat: List[jnp.ndarray], schema: list,
                lqs_mask: jnp.ndarray) -> list:
    ctxs, i, qi = [], 0, 0
    for kind, name, keys, has_flag in schema:
        ctx = {}
        for k, _, _ in keys:
            ctx[k] = flat[i]
            i += 1
        flag = None
        if has_flag:
            flag = lqs_mask[qi]
            qi += 1
        ctxs.append((kind, name, ctx, flag))
    assert i == len(flat)
    return ctxs


def make_split_steps(cfg: ModelConfig, bcfg: BackwardConfig,
                     batch: int, seq_or_none=None):
    """Build (fwd_fn, bwd_fn, ctx_schema).

    fwd: (params, lqs_mask, x, y) -> (loss, acc, *ctx_flat)
    bwd: (params, lqs_mask, x, *ctx_flat) -> (grads..., in param order)

    The schema is produced by tracing fwd once with abstract values, so
    aot.py can describe every ctx tensor (shape/dtype — int8 ctx entries
    are HOT's compressed activations) in the manifest."""
    import numpy as np

    params = M.init_params(cfg, seed=0)
    if cfg.arch == "lm":
        x_spec = jnp.zeros((batch, cfg.seq), jnp.int32)
    else:
        x_spec = jnp.zeros((batch, cfg.seq, cfg.in_dim), jnp.float32)
    y_spec = (jnp.zeros((batch, cfg.seq), jnp.int32) if cfg.arch == "lm"
              else jnp.zeros((batch,), jnp.int32))
    mask = jnp.zeros((cfg.n_qlinears(),), jnp.float32)
    # trace once abstractly to learn the ctx schema (names/kinds are
    # static python, so they leave eval_shape via a side channel)
    schema_box = []

    def _probe(p, xx, yy):
        _, _, ctxs = M.forward(p, xx, yy, cfg, bcfg, mask)
        flat, schema = ctx_to_flat(ctxs)
        schema_box.append(schema)
        return tuple(flat)

    jax.eval_shape(_probe, params, x_spec, y_spec)
    schema = schema_box[0]
    _ = np

    def fwd(params, lqs_mask, x, y):
        loss, acc, ctxs = M.forward(params, x, y, cfg, bcfg, lqs_mask)
        flat, _ = ctx_to_flat(ctxs)
        return (loss, acc, *flat)

    def bwd(params, lqs_mask, x, *ctx_flat):
        ctxs = flat_to_ctx(list(ctx_flat), schema, lqs_mask)
        grads = M.backward(params, x, cfg, bcfg, ctxs)
        return tuple(grads[k] for k in M.param_names(cfg))

    return fwd, bwd, schema


# ---------------------------------------------------------------------------
# LQS calibration step (paper §5.2.2) + Fig-4 / Fig-6 diagnostics
# ---------------------------------------------------------------------------


def make_calib_step(cfg: ModelConfig, bcfg: BackwardConfig):
    """f(params, x, y) -> per-qlinear diagnostic vectors (model order):

      mse_tensor   MSE(FP gc, per-tensor-INT8 gc)   } LQS inputs
      mse_token    MSE(FP gc, per-token-INT8 gc)    } (gc = HLA(g_y))
      outlier      max-token |g_y| / mean-token |g_y|     (Fig 6/9)
      gx_err_hq    rel-MSE of HT+INT4 g_x vs exact        (Fig 4 top)
      gx_err_hla   rel-MSE of external-HLA g_x vs exact   (Fig 4 top)
      gw_err_hq    rel-MSE of HT+INT4 g_w vs exact        (Fig 4 bottom)
      gw_err_hla   rel-MSE of HLA-r g_w vs exact          (Fig 4 bottom)

    Runs FP backward (calibration happens before training, paper: "a
    small calibration set prior to training")."""
    fp = BackwardConfig(variant="fp")
    nq = cfg.n_qlinears()

    def calib_step(params, x, y):
        mask = jnp.zeros((nq,), jnp.float32)
        _, _, ctxs = M.forward(params, x, y, cfg, fp, mask)
        sink: list = []
        M.backward(params, x, cfg, fp, ctxs, diag_sink=sink)
        sink = sink[::-1]  # model order
        outs = {k: [] for k in ("mse_tensor", "mse_token", "outlier",
                                "gx_err_hq", "gx_err_hla",
                                "gw_err_hq", "gw_err_hla")}
        for wname, gy, ctx, _ in sink:
            xx = ctx["x"]
            w = params[wname]
            n, o = gy.shape
            exact_gx = gy @ w
            exact_gw = gy.T @ xx
            gx_norm = jnp.mean(exact_gx * exact_gx) + 1e-12
            gw_norm = jnp.mean(exact_gw * exact_gw) + 1e-12
            if n % bcfg.block == 0:
                gc = hd.block_hla(gy, bcfg.rank, axis=0, block=bcfg.block)
                e_t = gc - ref.fake_quant_ps(gc, bcfg.gw_bits)
                e_k = gc - ref.dequantize(
                    ref.quantize_ps(gc, ref.minmax_scale(gc, bcfg.gw_bits, axis=1),
                                    bcfg.gw_bits),
                    ref.minmax_scale(gc, bcfg.gw_bits, axis=1))
                outs["mse_tensor"].append(jnp.mean(e_t * e_t))
                outs["mse_token"].append(jnp.mean(e_k * e_k))
                ghla = ref.lbp_gw_ref(gy, xx, bcfg.rank, bcfg.block)
                outs["gw_err_hla"].append(
                    jnp.mean((ghla - exact_gw) ** 2) / gw_norm)
                gx_hla = ref.lbp_gx_ref(gy, w, bcfg.rank, bcfg.block)
                outs["gx_err_hla"].append(
                    jnp.mean((gx_hla - exact_gx) ** 2) / gx_norm)
                gy_t = hd.block_ht(gy, axis=0, block=bcfg.block)
                x_t = hd.block_ht(xx, axis=0, block=bcfg.block)
                gw_hq = (ref.fake_quant_ps(gy_t, 4).T @ ref.fake_quant_ps(x_t, 4))
                outs["gw_err_hq"].append(
                    jnp.mean((gw_hq - exact_gw) ** 2) / gw_norm)
            else:
                for k in ("mse_tensor", "mse_token", "gw_err_hla",
                          "gx_err_hla", "gw_err_hq"):
                    outs[k].append(jnp.float32(0.0))
            if o % bcfg.block == 0:
                gx_hq = ref.hq_matmul_ref(gy, w, bcfg.gx_bits, bcfg.block)
                outs["gx_err_hq"].append(
                    jnp.mean((gx_hq - exact_gx) ** 2) / gx_norm)
            else:
                outs["gx_err_hq"].append(jnp.float32(0.0))
            row_amax = jnp.max(jnp.abs(gy), axis=1)
            outs["outlier"].append(jnp.max(row_amax)
                                   / (jnp.mean(row_amax) + 1e-12))
        return tuple(jnp.stack(outs[k]) for k in
                     ("mse_tensor", "mse_token", "outlier", "gx_err_hq",
                      "gx_err_hla", "gw_err_hq", "gw_err_hla"))

    return calib_step


def lqs_select(mse_tensor, mse_token, threshold: float = 0.5):
    """The paper's rule: per-token iff the error difference is >= 50%.

    Returns the {0,1} mask in qlinear (model) order."""
    rel = (mse_tensor - mse_token) / jnp.maximum(mse_tensor, 1e-12)
    return (rel >= threshold).astype(jnp.float32)
