"""aot.py: manifest correctness, calling-convention stability, HLO text
hygiene (the constant-elision regression is guarded here)."""

import json
import os

import jax
import pytest

from compile import aot
from compile import model as M
from compile.config import BackwardConfig, OptimizerConfig, PRESETS


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    em = aot.Emitter(out)
    cfg = PRESETS["tiny"]
    em.add_preset("tiny", cfg)
    ocfg = OptimizerConfig()
    fn, ins, inn, outn = aot.build_train_step(
        cfg, BackwardConfig(variant="fp"), ocfg, batch=16)
    em.emit("train_fp_tiny", fn, ins, inn, outn,
            {"kind": "train_step", "preset": "tiny", "variant": "fp",
             "batch": 16})
    (fwd, bwd, ctx_meta) = aot.build_split_steps(
        cfg, BackwardConfig(variant="hot"), batch=16)
    em.emit("fwd_hot_tiny", *fwd,
            {"kind": "fwd_step", "preset": "tiny", "variant": "hot",
             "batch": 16, "ctx": ctx_meta})
    em.finish()
    with open(os.path.join(out, "manifest.json")) as f:
        return out, json.load(f)


class TestHloHygiene:
    def test_no_elided_constants(self, emitted):
        out, manifest = emitted
        for key, meta in manifest["artifacts"].items():
            text = open(os.path.join(out, meta["file"])).read()
            assert "{...}" not in text, f"{key} has elided constants"

    def test_no_new_metadata_attrs(self, emitted):
        out, manifest = emitted
        for key, meta in manifest["artifacts"].items():
            text = open(os.path.join(out, meta["file"])).read()
            assert "source_end_line" not in text, key

    def test_entry_exists(self, emitted):
        out, manifest = emitted
        for meta in manifest["artifacts"].values():
            text = open(os.path.join(out, meta["file"])).read()
            assert "ENTRY" in text


class TestCallingConvention:
    def test_param_count_stable(self, emitted):
        _, manifest = emitted
        cfg = PRESETS["tiny"]
        names = M.param_names(cfg)
        meta = manifest["artifacts"]["train_fp_tiny"]
        # 3*P state + step + lr + mask + x + y
        assert len(meta["inputs"]) == 3 * len(names) + 5
        assert len(meta["outputs"]) == 3 * len(names) + 2

    def test_unused_args_pinned(self, emitted):
        """The fp variant never reads lqs_mask; anchor() must keep it in
        the HLO parameter list (the jit-drops-args regression)."""
        out, manifest = emitted
        meta = manifest["artifacts"]["train_fp_tiny"]
        text = open(os.path.join(out, meta["file"])).read()
        entry = text[text.index("ENTRY"):]
        n_params = entry.count(" parameter(")
        assert n_params == len(meta["inputs"]), \
            f"HLO has {n_params} params, manifest {len(meta['inputs'])}"

    def test_fwd_ctx_schema_matches_outputs(self, emitted):
        _, manifest = emitted
        meta = manifest["artifacts"]["fwd_hot_tiny"]
        assert len(meta["outputs"]) == 2 + len(meta["ctx"])
        # hot+abc ctx must include int8 compressed activations
        dts = {c["dtype"] for c in meta["ctx"]}
        assert "int8" in dts

    def test_init_blob_size(self, emitted):
        out, manifest = emitted
        preset = manifest["presets"]["tiny"]
        want = sum(
            4 * int(jax.numpy.prod(jax.numpy.asarray(p["shape"])))
            for p in preset["params"])
        got = os.path.getsize(os.path.join(out, preset["init_blob"]))
        assert got == want


class TestAnchor:
    def test_anchor_preserves_value(self):
        import jax.numpy as jnp
        args = (jnp.ones((3, 3)), jnp.asarray([1, 2], jnp.int32))
        out = aot.anchor(jnp.float32(2.5), args)
        assert float(out) == 2.5
