"""Pallas FWHT kernels vs the pure-jnp oracle."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import hadamard as hd
from compile.kernels import fwht


def _rand(m, d, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=(m, d)) * 3,
                       jnp.float32)


class TestMxuForm:
    def test_matches_ref_small(self):
        x = _rand(8, 32)
        got = fwht.block_fwht(x)
        want = hd.block_ht(x, axis=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_multi_tile_rows(self):
        # 256 rows => two grid steps at TILE_ROWS=128
        x = _rand(256, 16, seed=1)
        got = fwht.block_fwht(x)
        want = hd.block_ht(x, axis=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    @settings(deadline=None, max_examples=10)
    @given(m=st.sampled_from([1, 2, 4, 8]), tiles=st.integers(1, 4),
           seed=st.integers(0, 100))
    def test_hypothesis_shapes(self, m, tiles, seed):
        x = _rand(m, 16 * tiles, seed)
        got = fwht.block_fwht(x)
        want = hd.block_ht(x, axis=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


class TestButterflyForm:
    def test_matches_mxu_form(self):
        x = _rand(4, 64, seed=2)
        a = fwht.block_fwht(x)
        b = fwht.block_fwht_bfly(x)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)

    def test_involution(self):
        x = _rand(4, 32, seed=3)
        y = fwht.block_fwht_bfly(fwht.block_fwht_bfly(x))
        np.testing.assert_allclose(np.asarray(y), np.asarray(x),
                                   rtol=1e-5, atol=1e-5)


class TestFusedAmax:
    def test_amax_correct(self):
        x = _rand(8, 48, seed=4)
        y, amax = fwht.block_fwht_amax(x)
        want = hd.block_ht(x, axis=1)
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(float(amax),
                                   float(jnp.max(jnp.abs(want))), rtol=1e-5)

    def test_amax_multi_tile(self):
        x = _rand(256, 16, seed=5)
        _, amax = fwht.block_fwht_amax(x)
        want = hd.block_ht(x, axis=1)
        np.testing.assert_allclose(float(amax),
                                   float(jnp.max(jnp.abs(want))), rtol=1e-5)
