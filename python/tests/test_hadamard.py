"""Unit tests for the trace-time Walsh-Hadamard utilities."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import hadamard as hd


class TestHadamardMatrix:
    def test_orthonormal(self):
        for n in (2, 4, 16, 64):
            h = hd.hadamard_matrix(n)
            np.testing.assert_allclose(h @ h.T, np.eye(n), atol=1e-6)

    def test_symmetric_sylvester(self):
        h = hd.hadamard_matrix(16)
        np.testing.assert_allclose(h, h.T)

    def test_entries_pm_one(self):
        h = hd.hadamard_matrix(16, normalized=False)
        assert set(np.unique(h)) == {-1.0, 1.0}

    def test_bad_order_raises(self):
        with pytest.raises(ValueError):
            hd.hadamard_matrix(12)


class TestOrders:
    def test_sequency_is_permutation(self):
        for n in (4, 16, 32):
            assert sorted(hd.sequency_order(n)) == list(range(n))

    def test_sequency_monotone_sign_changes(self):
        h = hd.hadamard_matrix(16, normalized=False)
        order = hd.sequency_order(16)
        changes = [
            int((np.diff(np.sign(h[i])) != 0).sum()) for i in order
        ]
        assert changes == sorted(changes)
        assert changes[0] == 0  # DC first

    def test_lp_l1_is_permutation(self):
        assert sorted(hd.lp_l1_order_2d(4, 4)) == list(range(16))

    def test_lp_l1_dc_first(self):
        # the (0,0)-sequency basis is the all-ones (DC) vector = natural row 0
        assert hd.lp_l1_order_2d(4, 4)[0] == 0

    def test_lowpass_indices_prefix(self):
        full = hd.lowpass_indices(16)
        for r in (1, 2, 4, 8):
            assert hd.lowpass_indices(r) == full[:r]

    def test_lowpass_bad_rank(self):
        with pytest.raises(ValueError):
            hd.lowpass_indices(0)
        with pytest.raises(ValueError):
            hd.lowpass_indices(17)


class TestBlockHT:
    def test_involution(self):
        # normalized Sylvester H is symmetric => H @ H == I
        x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 64)), jnp.float32)
        y = hd.block_ht(hd.block_ht(x, axis=1), axis=1)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-5)

    def test_orthogonality_cancels_in_product(self):
        # (P Hᵀ)(H S) == P S — the identity HQ relies on (Eq. 3)
        rng = np.random.default_rng(1)
        p = jnp.asarray(rng.normal(size=(8, 32)), jnp.float32)
        s = jnp.asarray(rng.normal(size=(32, 8)), jnp.float32)
        pt = hd.block_ht(p, axis=1)
        st_ = hd.block_ht(s, axis=0)
        np.testing.assert_allclose(np.asarray(pt @ st_), np.asarray(p @ s),
                                   rtol=1e-4, atol=1e-4)

    def test_axis0(self):
        x = jnp.asarray(np.random.default_rng(2).normal(size=(32, 5)), jnp.float32)
        y = hd.block_ht(x, axis=0)
        y2 = hd.block_ht(x.T, axis=1).T
        np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=1e-5)

    def test_energy_preserved(self):
        x = jnp.asarray(np.random.default_rng(3).normal(size=(16, 48)), jnp.float32)
        y = hd.block_ht(x, axis=1)
        np.testing.assert_allclose(float(jnp.sum(x * x)), float(jnp.sum(y * y)),
                                   rtol=1e-5)

    def test_bad_dim(self):
        with pytest.raises(ValueError):
            hd.block_ht(jnp.zeros((4, 10)), axis=1)


class TestHLA:
    def test_full_rank_is_ht(self):
        x = jnp.asarray(np.random.default_rng(4).normal(size=(8, 32)), jnp.float32)
        y = hd.block_hla(x, rank=16, axis=1)
        # rank 16 keeps everything, permuted into sequency order per tile
        z = hd.block_ht(x, axis=1)
        assert y.shape == z.shape
        np.testing.assert_allclose(np.sort(np.asarray(y)), np.sort(np.asarray(z)),
                                   atol=1e-5)

    def test_shapes(self):
        x = jnp.zeros((64, 24))
        for r in (1, 2, 4, 8):
            assert hd.block_hla(x, r, axis=0).shape == (64 // 16 * r, 24)

    def test_projection_idempotent(self):
        # expand(compress(x)) is an orthogonal projection: applying
        # compress again is lossless
        x = jnp.asarray(np.random.default_rng(5).normal(size=(32, 8)), jnp.float32)
        c = hd.block_hla(x, 8, axis=0)
        e = hd.block_hla_expand(c, 8, axis=0)
        c2 = hd.block_hla(e, 8, axis=0)
        np.testing.assert_allclose(np.asarray(c), np.asarray(c2), atol=1e-5)

    def test_dc_component_preserved(self):
        # constant-along-L signals are pure DC: HLA with any rank is exact
        x = jnp.ones((32, 6), jnp.float32) * 3.0
        e = hd.block_hla_expand(hd.block_hla(x, 1, axis=0), 1, axis=0)
        np.testing.assert_allclose(np.asarray(e), np.asarray(x), atol=1e-5)

    @settings(deadline=None, max_examples=20)
    @given(r=st.sampled_from([1, 2, 4, 8, 16]),
           tiles=st.integers(1, 4), d=st.integers(1, 9))
    def test_error_decreases_with_rank_dc_heavy(self, r, tiles, d):
        # smooth (low-frequency) signals reconstruct with small error
        l = 16 * tiles
        t = np.linspace(0, 1, l)[:, None]
        x = jnp.asarray(np.cos(np.pi * t) @ np.ones((1, d)), jnp.float32)
        e = hd.block_hla_expand(hd.block_hla(x, r, axis=0), r, axis=0)
        err = float(jnp.mean((e - x) ** 2))
        full = hd.block_hla_expand(hd.block_hla(x, 16, axis=0), 16, axis=0)
        err_full = float(jnp.mean((full - x) ** 2))
        assert err_full <= err + 1e-6

    def test_reduced_hadamard_rows_orthonormal(self):
        hh = hd.reduced_hadamard(8)
        np.testing.assert_allclose(hh @ hh.T, np.eye(8), atol=1e-6)

    def test_lp_l1_criterion_variant(self):
        hh = hd.reduced_hadamard(8, criterion="lp_l1")
        assert hh.shape == (8, 16)
        np.testing.assert_allclose(hh @ hh.T, np.eye(8), atol=1e-6)
