"""Fused HLA (g_w) kernel vs oracle + ABC compression + LQS semantics."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import hadamard as hd
from compile.kernels import hla_matmul, ref


def _rand(shape, seed=0, scale=1.0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape) * scale,
                       jnp.float32)


def _rel_err(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-12)


class TestProjectKernel:
    def test_matches_block_hla(self):
        x = _rand((64, 32), 0)
        got, amax = hla_matmul.hla_project_amax(x, rank=8)
        want = hd.block_hla(x, 8, axis=0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(float(amax),
                                   float(jnp.max(jnp.abs(want))), rtol=1e-5)

    @settings(deadline=None, max_examples=10)
    @given(tiles=st.integers(1, 4), d=st.sampled_from([8, 16, 96]),
           r=st.sampled_from([1, 2, 4, 8, 16]), seed=st.integers(0, 30))
    def test_hypothesis(self, tiles, d, r, seed):
        x = _rand((16 * tiles, d), seed)
        got, _ = hla_matmul.hla_project_amax(x, rank=r)
        want = hd.block_hla(x, r, axis=0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_lp_l1_criterion(self):
        x = _rand((32, 16), 1)
        got, _ = hla_matmul.hla_project_amax(x, rank=4, criterion="lp_l1")
        want = hd.block_hla(x, 4, axis=0, criterion="lp_l1")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


class TestFusedGw:
    def test_matches_ref_per_tensor(self):
        gy = _rand((64, 32), 2)
        x = _rand((64, 16), 3)
        got = hla_matmul.hla_matmul(gy, x, rank=8)
        want = ref.hla_matmul_ref(gy, x, rank=8)
        assert _rel_err(got, want) < 8e-3

    def test_matches_ref_per_token(self):
        gy = _rand((64, 32), 4)
        x = _rand((64, 16), 5)
        got = hla_matmul.hla_matmul(gy, x, rank=8, per_token=True)
        want = ref.hla_matmul_ref(gy, x, rank=8, per_token=True)
        assert _rel_err(got, want) < 8e-3

    @settings(deadline=None, max_examples=8)
    @given(tiles=st.integers(1, 3), o=st.sampled_from([16, 32]),
           i=st.sampled_from([16, 48]), r=st.sampled_from([2, 4, 8]),
           seed=st.integers(0, 30))
    def test_hypothesis(self, tiles, o, i, r, seed):
        gy = _rand((16 * tiles, o), seed)
        x = _rand((16 * tiles, i), seed + 1)
        got = hla_matmul.hla_matmul(gy, x, rank=r)
        want = ref.hla_matmul_ref(gy, x, rank=r)
        assert _rel_err(got, want) < 2e-2


class TestABCCompression:
    def test_compressed_sizes(self):
        """ABC's memory claim: r=8/16 HLA halves L; INT8 quarters bytes —
        the stored buffer is 1/8 the FP32 original (paper: 'up to 12.5%')."""
        x = _rand((128, 64), 6)
        q, s = ref.hla_compress_ref(x, rank=8)
        assert q.shape == (64, 64) and q.dtype == jnp.int8
        orig_bytes = 128 * 64 * 4
        comp_bytes = 64 * 64 * 1 + 4
        # 12.5% + the 4-byte scale (paper: "up to 12.5%")
        assert comp_bytes / orig_bytes <= 0.126

    def test_compress_then_gw_consistent(self):
        """Splitting compression (fwd-time, ABC) from the GEMM (bwd-time)
        gives the same g_w as the fused op — the invariant that lets the
        rust coordinator hold the compressed buffer across the boundary."""
        gy = _rand((64, 32), 7)
        x = _rand((64, 16), 8)
        xq, s_x = ref.hla_compress_ref(x, rank=8)
        gc = hd.block_hla(gy, 8, axis=0)
        s_g = ref.minmax_scale(gc, 8)
        q_g = ref.quantize_ps(gc, s_g, 8)
        manual = (np.asarray(q_g).astype(np.int32).T
                  @ np.asarray(xq).astype(np.int32)).astype(np.float32) \
            * float(s_g) * float(s_x)
        fused = np.asarray(ref.hla_matmul_ref(gy, x, rank=8))
        np.testing.assert_allclose(manual, fused, rtol=1e-5, atol=1e-5)


class TestApproximationQuality:
    def test_hla_on_gw_beats_quant_on_gw(self):
        """§4.3: the L-averaged g_w path tolerates HLA but is hurt by
        aggressive (4-bit) quantization — reproduce the ordering with
        smooth-gradient synthetic data."""
        rng = np.random.default_rng(9)
        l, o, i = 128, 32, 32
        t = np.linspace(0, 1, l)[:, None]
        smooth = np.cos(np.pi * t)
        gy = jnp.asarray((smooth @ rng.normal(size=(1, o))
                          + 0.05 * rng.normal(size=(l, o))), jnp.float32)
        x = jnp.asarray((smooth @ rng.normal(size=(1, i))
                         + 0.05 * rng.normal(size=(l, i))), jnp.float32)
        exact = np.asarray(gy.T @ x)

        via_hla = np.asarray(ref.hla_matmul_ref(gy, x, rank=8))
        # HT + INT4 on the same path (what Table 2 shows fails)
        gy_t = hd.block_ht(gy, axis=0)
        x_t = hd.block_ht(x, axis=0)
        via_q4 = np.asarray(ref.fake_quant_ps(gy_t, 4).T @ ref.fake_quant_ps(x_t, 4))

        assert _rel_err(via_hla, exact) < _rel_err(via_q4, exact)

    def test_rank_monotonicity(self):
        """Table 8's trend: g_w error shrinks as rank grows."""
        rng = np.random.default_rng(10)
        l = 64
        t = np.linspace(0, 1, l)[:, None]
        gy = jnp.asarray(np.cos(np.pi * t) @ rng.normal(size=(1, 32))
                         + 0.1 * rng.normal(size=(l, 32)), jnp.float32)
        x = jnp.asarray(np.cos(2 * np.pi * t) @ rng.normal(size=(1, 32))
                        + 0.1 * rng.normal(size=(l, 32)), jnp.float32)
        exact = np.asarray(gy.T @ x)
        errs = [
            _rel_err(ref.lbp_gw_ref(gy, x, rank=r), exact)
            for r in (1, 4, 16)
        ]
        assert errs[2] <= errs[1] <= errs[0] + 1e-6


class TestLbpBaseline:
    def test_lbp_gx_shape_and_fullrank_exact(self):
        gy = _rand((32, 16), 11)
        w = _rand((16, 8), 12)
        out = ref.lbp_gx_ref(gy, w, rank=16)
        assert out.shape == (32, 8)
        np.testing.assert_allclose(np.asarray(out), np.asarray(gy @ w),
                                   rtol=1e-4, atol=1e-4)

    def test_lbp_gw_fullrank_exact(self):
        gy = _rand((32, 16), 13)
        x = _rand((32, 8), 14)
        out = ref.lbp_gw_ref(gy, x, rank=16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(gy.T @ x),
                                   rtol=1e-4, atol=1e-4)
