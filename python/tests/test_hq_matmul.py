"""Fused HQ (g_x) kernel vs oracle + approximation-quality properties."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import hq_matmul, ref


def _rand(shape, seed=0, scale=1.0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape) * scale,
                       jnp.float32)


def _rel_err(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-12)


class TestKernelVsRef:
    def test_matches_ref(self):
        gy = _rand((32, 32), 0)
        w = _rand((32, 16), 1)
        got = hq_matmul.hq_matmul(gy, w)
        want = ref.hq_matmul_ref(gy, w)
        # identical HT bits -> identical rounding -> near-identical output
        assert _rel_err(got, want) < 8e-3

    def test_int8_variant(self):
        gy = _rand((16, 32), 2)
        w = _rand((32, 32), 3)
        got = hq_matmul.hq_matmul(gy, w, bits=8)
        want = ref.hq_matmul_ref(gy, w, bits=8)
        assert _rel_err(got, want) < 8e-3

    @settings(deadline=None, max_examples=10)
    @given(l=st.sampled_from([4, 16, 64]), o=st.sampled_from([16, 32, 48]),
           i=st.sampled_from([8, 16, 32]), seed=st.integers(0, 50))
    def test_hypothesis_shapes(self, l, o, i, seed):
        gy = _rand((l, o), seed)
        w = _rand((o, i), seed + 1)
        got = hq_matmul.hq_matmul(gy, w)
        want = ref.hq_matmul_ref(gy, w)
        assert _rel_err(got, want) < 2e-2

    def test_multi_tile_grid(self):
        gy = _rand((256, 32), 4)
        w = _rand((32, 256), 5)
        got = hq_matmul.hq_matmul(gy, w)
        want = ref.hq_matmul_ref(gy, w)
        assert _rel_err(got, want) < 8e-3


class TestIntegerEquivalence:
    def test_int_gemm_equals_fake_quant(self):
        """quant->intGEMM->dequant == quant->dequant->fpGEMM (exactness
        contract of DESIGN.md §3)."""
        from compile import hadamard as hd
        gy = _rand((16, 32), 6)
        w = _rand((32, 16), 7)
        gy_t = hd.block_ht(gy, axis=1)
        w_t = hd.block_ht(w, axis=0)
        s_g = ref.minmax_scale(gy_t, 4)
        s_w = ref.minmax_scale(w_t, 4)
        q_g = ref.quantize_ps(gy_t, s_g, 4)
        q_w = ref.quantize_ps(w_t, s_w, 4)
        int_path = np.asarray(ref.hq_matmul_ref(gy, w))
        fp_path = np.asarray(
            (ref.dequantize(q_g, s_g) @ ref.dequantize(q_w, s_w)))
        np.testing.assert_allclose(int_path, fp_path, rtol=1e-5, atol=1e-5)


class TestApproximationQuality:
    def test_ht_reduces_quant_error_on_outliers(self):
        """The paper's core claim for HQ (§4.2): HT spreads outliers, so
        HT+INT4 beats plain INT4 on outlier-heavy gradients."""
        rng = np.random.default_rng(8)
        gy = rng.normal(size=(64, 64)).astype(np.float32)
        gy[5, :] *= 50.0  # token outlier, as in Fig 6
        w = rng.normal(size=(64, 64)).astype(np.float32)
        gyj, wj = jnp.asarray(gy), jnp.asarray(w)
        exact = np.asarray(gyj @ wj)

        hq = np.asarray(ref.hq_matmul_ref(gyj, wj, bits=4))
        # plain INT4: no HT
        q_g = ref.fake_quant_ps(gyj, 4)
        q_w = ref.fake_quant_ps(wj, 4)
        plain = np.asarray(q_g @ q_w)

        assert _rel_err(hq, exact) < _rel_err(plain, exact)

    def test_hq_int8_close_to_exact(self):
        gy = _rand((64, 64), 9)
        w = _rand((64, 64), 10)
        exact = np.asarray(gy @ w)
        hq = np.asarray(ref.hq_matmul_ref(gy, w, bits=8))
        assert _rel_err(hq, exact) < 0.02
