"""HOT + LoRA joint optimization (Table 9 semantics)."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import lora as LR
from compile import model as M
from compile.config import BackwardConfig, OptimizerConfig, PRESETS

TINY = PRESETS["tiny"]
OPT = OptimizerConfig(lr=3e-3)


def _batch(cfg, b=16, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, cfg.seq, cfg.in_dim)), jnp.float32)
    y = jnp.asarray(rng.integers(0, cfg.n_classes, size=(b,)), jnp.int32)
    return x, y


def _trainable(cfg, r=4, seed=1):
    base = M.init_params(cfg, seed=seed)
    t = dict(LR.init_lora(cfg, r_lora=r, seed=seed))
    for k in ("embed.w", "embed.b", "head.w", "head.b"):
        t[k] = base[k]
    return base, t


class TestLoraStructure:
    def test_param_specs(self):
        specs = LR.lora_param_specs(TINY, r_lora=4)
        # 4 targets per block * 2 tensors * depth
        assert len(specs) == 2 * 4 * TINY.depth
        for name, shape in specs:
            assert name.endswith(".lora_a") or name.endswith(".lora_b")
            assert 4 in shape

    def test_b_init_zero_makes_noop(self):
        """B=0 -> adapter output is zero -> LoRA fwd == base fwd."""
        cfg = TINY
        base, t = _trainable(cfg)
        lp = {k: v for k, v in t.items() if ".lora_" in k}
        x, y = _batch(cfg)
        mask = jnp.zeros((cfg.n_qlinears(),), jnp.float32)
        bcfg = BackwardConfig(variant="fp")
        loss_l, acc_l, _ = LR.forward_lora(base, lp, x, y, cfg, bcfg, 2.0,
                                           False, mask)
        loss_b, acc_b, _ = M.forward(base, x, y, cfg, bcfg, mask)
        np.testing.assert_allclose(float(loss_l), float(loss_b), rtol=1e-5)


class TestLoraBackward:
    def test_fp_lora_grads_match_autodiff(self):
        cfg = TINY
        base, t = _trainable(cfg, seed=2)
        lp = {k: v for k, v in t.items() if ".lora_" in k}
        # make B nonzero so gradients flow everywhere
        lp = {k: (v + 0.1 if k.endswith(".lora_b") else v)
              for k, v in lp.items()}
        x, y = _batch(cfg, seed=2)
        mask = jnp.zeros((cfg.n_qlinears(),), jnp.float32)
        bcfg = BackwardConfig(variant="fp")

        def loss_fn(lp_):
            loss, _, _ = LR.forward_lora(base, lp_, x, y, cfg, bcfg, 2.0,
                                         False, mask)
            return loss

        auto = jax.grad(loss_fn)(lp)
        _, _, ctxs = LR.forward_lora(base, lp, x, y, cfg, bcfg, 2.0, False,
                                     mask)
        manual = LR.backward_lora(base, lp, x, cfg, bcfg, 2.0, False, False,
                                  ctxs)
        for k in auto:
            np.testing.assert_allclose(np.asarray(manual[k]),
                                       np.asarray(auto[k]),
                                       rtol=2e-3, atol=2e-5, err_msg=k)

    def test_hot_frozen_changes_gx_not_lora_grads_structure(self):
        cfg = TINY
        base, t = _trainable(cfg, seed=3)
        lp = {k: (v + 0.1 if k.endswith(".lora_b") else v)
              for k, v in t.items() if ".lora_" in k}
        x, y = _batch(cfg, seed=3)
        mask = jnp.zeros((cfg.n_qlinears(),), jnp.float32)
        bcfg = BackwardConfig(variant="fp")
        _, _, ctxs = LR.forward_lora(base, lp, x, y, cfg, bcfg, 2.0, False,
                                     mask)
        g_exact = LR.backward_lora(base, lp, x, cfg, bcfg, 2.0, False, False,
                                   ctxs)
        g_hot = LR.backward_lora(base, lp, x, cfg, bcfg, 2.0, True, False,
                                 ctxs)
        assert set(g_exact) == set(g_hot)
        # gradients differ (quantized g_x perturbs upstream) but correlate
        va = np.concatenate([np.asarray(g_exact[k]).ravel()
                             for k in sorted(g_exact)])
        vb = np.concatenate([np.asarray(g_hot[k]).ravel()
                             for k in sorted(g_hot)])
        cos = va @ vb / (np.linalg.norm(va) * np.linalg.norm(vb) + 1e-12)
        assert 0.7 < cos < 1.0 + 1e-9


class TestLoraTraining:
    def _run(self, hot_frozen, hot_decomposed, steps=20, seed=4):
        cfg = TINY
        base, t = _trainable(cfg, seed=seed)
        m = {k: jnp.zeros_like(v) for k, v in t.items()}
        v = {k: jnp.zeros_like(vv) for k, vv in t.items()}
        bcfg = BackwardConfig(variant="hot")
        step_fn = jax.jit(LR.make_lora_train_step(
            cfg, bcfg, OPT, hot_frozen=hot_frozen,
            hot_decomposed=hot_decomposed))
        rng = np.random.default_rng(seed)
        centers = rng.normal(0, 1.5, size=(cfg.n_classes, cfg.seq, cfg.in_dim))
        mask = jnp.zeros((cfg.n_qlinears(),), jnp.float32)
        losses = []
        for i in range(steps):
            yb = rng.integers(0, cfg.n_classes, size=(16,))
            xb = centers[yb] + rng.normal(0, 0.5, size=(16, cfg.seq, cfg.in_dim))
            t, m, v, loss, acc = step_fn(
                base, t, m, v, jnp.float32(i + 1), jnp.float32(OPT.lr),
                mask, jnp.asarray(xb, jnp.float32), jnp.asarray(yb, jnp.int32))
            losses.append(float(loss))
        return losses

    def test_hot_on_frozen_converges(self):
        losses = self._run(hot_frozen=True, hot_decomposed=False)
        assert losses[-1] < losses[0]
        assert all(np.isfinite(l) for l in losses)

    def test_all_table9_configs_finite(self):
        for hf in (False, True):
            for hdec in (False, True):
                losses = self._run(hf, hdec, steps=6, seed=5)
                assert all(np.isfinite(l) for l in losses), (hf, hdec)
