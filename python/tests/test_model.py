"""Manual backprop vs jax.grad (fp), variant behaviours, shape contracts."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.config import BackwardConfig, ModelConfig, PRESETS


TINY = PRESETS["tiny"]
FP = BackwardConfig(variant="fp")


def _batch(cfg: ModelConfig, b=16, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.arch == "lm":
        x = jnp.asarray(rng.integers(0, cfg.in_dim, size=(b, cfg.seq)), jnp.int32)
        y = jnp.asarray(rng.integers(0, cfg.n_classes, size=(b, cfg.seq)), jnp.int32)
    else:
        x = jnp.asarray(rng.normal(size=(b, cfg.seq, cfg.in_dim)), jnp.float32)
        y = jnp.asarray(rng.integers(0, cfg.n_classes, size=(b,)), jnp.int32)
    return x, y


def _mask(cfg):
    return jnp.zeros((cfg.n_qlinears(),), jnp.float32)


class TestManualBackpropExact:
    @pytest.mark.parametrize("preset", ["tiny", "lm_tiny", "mlp_small"])
    def test_fp_grads_match_autodiff(self, preset):
        cfg = PRESETS[preset]
        params = M.init_params(cfg, seed=3)
        x, y = _batch(cfg, b=4 if preset != "tiny" else 16, seed=1)
        loss, acc, grads = M.loss_and_grads(params, x, y, cfg, FP, _mask(cfg))
        auto = jax.grad(M.loss_fp_autodiff)(params, x, y, cfg)
        assert set(grads) == set(auto)
        for k in grads:
            np.testing.assert_allclose(np.asarray(grads[k]),
                                       np.asarray(auto[k]),
                                       rtol=2e-3, atol=2e-5,
                                       err_msg=k)

    def test_loss_matches_forward_only(self):
        params = M.init_params(TINY)
        x, y = _batch(TINY)
        loss1, _, _ = M.forward(params, x, y, TINY, FP, _mask(TINY))
        loss2 = M.loss_fp_autodiff(params, x, y, TINY)
        np.testing.assert_allclose(float(loss1), float(loss2), rtol=1e-6)


class TestVariants:
    @pytest.mark.parametrize("variant", [
        "hot", "lbp", "luq", "int4", "gx_hq4", "gx_q4", "gx_ext_hla",
        "gx_int_hla", "gw_hq4", "gw_hla", "gw_hot"])
    def test_variant_produces_finite_grads(self, variant):
        cfg = TINY
        bcfg = BackwardConfig(variant=variant)
        params = M.init_params(cfg, seed=4)
        x, y = _batch(cfg, seed=2)
        loss, acc, grads = M.loss_and_grads(params, x, y, cfg, bcfg, _mask(cfg))
        assert np.isfinite(float(loss))
        for k, g in grads.items():
            assert np.isfinite(np.asarray(g)).all(), k
            assert g.shape == params[k].shape, k

    def test_hot_grads_approximate_fp(self):
        cfg = TINY
        params = M.init_params(cfg, seed=5)
        x, y = _batch(cfg, seed=3)
        _, _, g_fp = M.loss_and_grads(params, x, y, cfg, FP, _mask(cfg))
        _, _, g_hot = M.loss_and_grads(
            params, x, y, cfg, BackwardConfig(variant="hot"), _mask(cfg))
        # cosine similarity of the full gradient vector should be high
        va = np.concatenate([np.asarray(g_fp[k]).ravel() for k in sorted(g_fp)])
        vb = np.concatenate([np.asarray(g_hot[k]).ravel() for k in sorted(g_hot)])
        cos = va @ vb / (np.linalg.norm(va) * np.linalg.norm(vb) + 1e-12)
        # INT4 at d_model=32 is the worst case for HQ (few Hadamard tiles
        # to mix outliers into); production dims sit much higher.
        assert cos > 0.85

    def test_gx_int_hla_worse_than_hot(self):
        """Table 2's headline: internal HLA on g_x is catastrophic
        compared to HQ on g_x — check gradient fidelity ordering."""
        cfg = TINY
        params = M.init_params(cfg, seed=6)
        x, y = _batch(cfg, seed=4)
        _, _, g_fp = M.loss_and_grads(params, x, y, cfg, FP, _mask(cfg))

        def grad_err(variant):
            _, _, g = M.loss_and_grads(
                params, x, y, cfg, BackwardConfig(variant=variant), _mask(cfg))
            num = den = 0.0
            for k in g_fp:
                num += float(jnp.sum((g[k] - g_fp[k]) ** 2))
                den += float(jnp.sum(g_fp[k] ** 2))
            return num / den

        assert grad_err("gx_int_hla") > grad_err("gx_hq4")

    def test_lqs_mask_changes_gw_only(self):
        cfg = TINY
        params = M.init_params(cfg, seed=7)
        x, y = _batch(cfg, seed=5)
        bcfg = BackwardConfig(variant="hot")
        ones = jnp.ones((cfg.n_qlinears(),), jnp.float32)
        _, _, g0 = M.loss_and_grads(params, x, y, cfg, bcfg, _mask(cfg))
        _, _, g1 = M.loss_and_grads(params, x, y, cfg, bcfg, ones)
        # per-token vs per-tensor alters weight grads...
        diff = sum(float(jnp.sum((g0[k] - g1[k]) ** 2))
                   for k in g0 if k.endswith(".w") or "wqkv" in k)
        assert diff > 0
        # ...but never biases (always exact)
        for k in g0:
            if k.endswith(".b") and k != "embed.b":
                np.testing.assert_allclose(np.asarray(g0[k]), np.asarray(g1[k]),
                                           rtol=1e-6, atol=1e-7)


class TestAbc:
    def test_abc_residuals_are_int8(self):
        cfg = TINY
        params = M.init_params(cfg)
        x, y = _batch(cfg)
        bcfg = BackwardConfig(variant="hot", abc=True)
        _, _, ctxs = M.forward(params, x, y, cfg, bcfg, _mask(cfg))
        ql_ctxs = [c for kind, _, c, _ in ctxs if kind == "ql"]
        compressed = [c for c in ql_ctxs if "xq" in c]
        # every tile-compatible qlinear stores int8 + scale, nothing else
        assert len(compressed) >= cfg.n_qlinears() - 2
        for c in compressed:
            assert c["xq"].dtype == jnp.int8
            assert set(c) == {"xq", "sx"}

    def test_abc_on_off_same_grads(self):
        """ABC changes *where* compression happens, never the math."""
        cfg = TINY
        params = M.init_params(cfg, seed=8)
        x, y = _batch(cfg, seed=6)
        m = _mask(cfg)
        _, _, g_on = M.loss_and_grads(
            params, x, y, cfg, BackwardConfig(variant="hot", abc=True), m)
        _, _, g_off = M.loss_and_grads(
            params, x, y, cfg, BackwardConfig(variant="hot", abc=False), m)
        for k in g_on:
            np.testing.assert_allclose(np.asarray(g_on[k]),
                                       np.asarray(g_off[k]),
                                       rtol=1e-5, atol=1e-6, err_msg=k)


class TestShapes:
    def test_qlinear_names_count(self):
        for preset, cfg in PRESETS.items():
            assert len(M.qlinear_names(cfg)) == cfg.n_qlinears(), preset

    def test_param_names_stable(self):
        names = M.param_names(TINY)
        assert names == sorted(names)
        assert "embed.w" in names and "head.w" in names
