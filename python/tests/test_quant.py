"""Pseudo-stochastic quantizer: Pallas kernel vs oracle + statistical props."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import quant, ref


def _rand(m, d, seed=0, scale=3.0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=(m, d)) * scale,
                       jnp.float32)


class TestKernelVsRef:
    def test_per_tensor_bit_exact(self):
        x = _rand(8, 32)
        for bits in (4, 8):
            s = ref.minmax_scale(x, bits)
            got = quant.quantize_ps(x, s, bits)
            want = ref.quantize_ps(x, s, bits)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_per_token_bit_exact(self):
        x = _rand(16, 32, seed=1)
        s = ref.minmax_scale(x, 8, axis=1)
        got = quant.quantize_ps(x, s, 8, per_token=True)
        want = ref.quantize_ps(x, s, 8)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @settings(deadline=None, max_examples=15)
    @given(m=st.sampled_from([1, 4, 128, 256]), d=st.integers(1, 33),
           bits=st.sampled_from([4, 8]), seed=st.integers(0, 50))
    def test_hypothesis_sweep(self, m, d, bits, seed):
        x = _rand(m, d, seed)
        s = ref.minmax_scale(x, bits)
        got = quant.quantize_ps(x, s, bits)
        want = ref.quantize_ps(x, s, bits)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_dequant_kernel(self):
        x = _rand(8, 16, seed=2)
        s = ref.minmax_scale(x, 8)
        q = quant.quantize_ps(x, s, 8)
        got = quant.dequantize(q, s)
        want = ref.dequantize(q, s)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


class TestQuantizerProperties:
    def test_range_respected(self):
        for bits, qmax in ((4, 7), (8, 127)):
            x = _rand(32, 32, seed=3, scale=100.0)
            s = ref.minmax_scale(x, bits)
            q = np.asarray(ref.quantize_ps(x, s, bits))
            assert q.max() <= qmax and q.min() >= -qmax

    def test_roundtrip_error_bounded(self):
        # |dequant(quant(x)) - x| <= scale (one rounding step)
        x = _rand(64, 64, seed=4)
        for bits in (4, 8):
            s = float(ref.minmax_scale(x, bits))
            y = np.asarray(ref.fake_quant_ps(x, bits))
            assert np.max(np.abs(y - np.asarray(x))) <= s * (1 + 1e-5)

    def test_nearly_unbiased(self):
        # mean of quant error over many samples ~ 0 (stochastic rounding)
        x = _rand(512, 512, seed=5)
        y = np.asarray(ref.fake_quant_ps(x, 4))
        err = y - np.asarray(x)
        s = float(ref.minmax_scale(x, 4))
        assert abs(err.mean()) < 0.02 * s

    def test_deterministic(self):
        x = _rand(16, 16, seed=6)
        s = ref.minmax_scale(x, 4)
        a = np.asarray(ref.quantize_ps(x, s, 4))
        b = np.asarray(ref.quantize_ps(x, s, 4))
        np.testing.assert_array_equal(a, b)

    def test_exact_grid_points_fixed(self):
        # values already on the grid never move
        s = jnp.float32(0.5)
        x = jnp.arange(-7, 8, dtype=jnp.float32) * 0.5
        q = np.asarray(ref.quantize_ps(x.reshape(1, -1), s, 4))
        np.testing.assert_array_equal(q[0], np.arange(-7, 8))

    def test_per_token_scales_isolate_rows(self):
        # one huge row must not destroy small rows' resolution (LQS case a)
        x = np.ones((4, 16), np.float32) * 0.01
        x[0] *= 1000
        xj = jnp.asarray(x)
        per_tensor = np.asarray(ref.fake_quant_ps(xj, 8))
        s_tok = ref.minmax_scale(xj, 8, axis=1)
        per_token = np.asarray(ref.dequantize(ref.quantize_ps(xj, s_tok, 8), s_tok))
        err_tensor = np.abs(per_tensor[1:] - x[1:]).mean()
        err_token = np.abs(per_token[1:] - x[1:]).mean()
        assert err_token < err_tensor


class TestInt4Packing:
    def test_roundtrip(self):
        rng = np.random.default_rng(7)
        q = jnp.asarray(rng.integers(-8, 8, size=(6, 32)), jnp.int8)
        p = quant.pack_int4(q)
        assert p.shape == (6, 16)
        np.testing.assert_array_equal(np.asarray(quant.unpack_int4(p)),
                                      np.asarray(q))

    @settings(deadline=None, max_examples=20)
    @given(m=st.integers(1, 8), k=st.integers(1, 16), seed=st.integers(0, 99))
    def test_roundtrip_hypothesis(self, m, k, seed):
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.integers(-8, 8, size=(m, 2 * k)), jnp.int8)
        np.testing.assert_array_equal(
            np.asarray(quant.unpack_int4(quant.pack_int4(q))), np.asarray(q))


class TestLuq:
    def test_values_are_powers_of_two(self):
        x = _rand(32, 32, seed=8)
        y = np.asarray(ref.quantize_luq(x, 4))
        nz = np.abs(y[y != 0])
        exps = np.log2(nz)
        np.testing.assert_allclose(exps, np.round(exps), atol=1e-5)

    def test_level_count(self):
        x = _rand(64, 64, seed=9)
        y = np.asarray(ref.quantize_luq(x, 4))
        levels = np.unique(np.abs(y[y != 0]))
        assert len(levels) <= 2 ** 3  # 7 exponents + underflow level
    def test_sign_preserved(self):
        x = _rand(32, 32, seed=10)
        y = np.asarray(ref.quantize_luq(x, 4))
        xn = np.asarray(x)
        mask = y != 0
        assert (np.sign(y[mask]) == np.sign(xn[mask])).all()

    def test_roughly_unbiased(self):
        x = jnp.abs(_rand(512, 512, seed=11)) + 0.1
        y = np.asarray(ref.quantize_luq(x, 4))
        rel = (y.mean() - float(jnp.mean(x))) / float(jnp.mean(x))
        assert abs(rel) < 0.1
