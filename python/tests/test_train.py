"""Train/eval/calib/split steps: convergence smoke + consistency checks."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import train as T
from compile.config import BackwardConfig, ModelConfig, OptimizerConfig, PRESETS

TINY = PRESETS["tiny"]
OPT = OptimizerConfig(lr=3e-3)


def _dataset(cfg: ModelConfig, n=128, seed=0):
    """Linearly-separable gaussian clusters — any sane trainer should fit."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, 1.5, size=(cfg.n_classes, cfg.seq, cfg.in_dim))
    y = rng.integers(0, cfg.n_classes, size=(n,))
    x = centers[y] + rng.normal(0, 0.5, size=(n, cfg.seq, cfg.in_dim))
    return (jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.int32))


def _states(cfg, seed=0):
    p = M.init_params(cfg, seed)
    z = {k: jnp.zeros_like(v) for k, v in p.items()}
    return p, dict(z), {k: jnp.zeros_like(v) for k, v in p.items()}


def _run(cfg, bcfg, steps=30, batch=16, seed=0):
    params, m, v = _states(cfg, seed)
    x_all, y_all = _dataset(cfg, n=batch * 4, seed=seed)
    step_fn = jax.jit(T.make_train_step(cfg, bcfg, OPT))
    mask = jnp.zeros((cfg.n_qlinears(),), jnp.float32)
    losses = []
    for i in range(steps):
        s = (i % 4) * batch
        xb, yb = x_all[s:s + batch], y_all[s:s + batch]
        params, m, v, loss, acc = step_fn(params, m, v,
                                          jnp.float32(i + 1),
                                          jnp.float32(OPT.lr), mask, xb, yb)
        losses.append(float(loss))
    return losses


class TestTrainStep:
    def test_fp_converges(self):
        losses = _run(TINY, BackwardConfig(variant="fp"))
        assert losses[-1] < losses[0] * 0.7

    def test_hot_converges(self):
        losses = _run(TINY, BackwardConfig(variant="hot"))
        assert losses[-1] < losses[0] * 0.8

    def test_hot_tracks_fp(self):
        l_fp = _run(TINY, BackwardConfig(variant="fp"), steps=25, seed=1)
        l_hot = _run(TINY, BackwardConfig(variant="hot"), steps=25, seed=1)
        # HOT's final loss within a modest factor of FP's (paper: <1% acc gap)
        assert l_hot[-1] < l_fp[-1] * 2.0 + 0.5

    def test_all_losses_finite(self):
        for variant in ("lbp", "luq", "int4"):
            losses = _run(TINY, BackwardConfig(variant=variant), steps=8)
            assert all(np.isfinite(l) for l in losses), variant


class TestOptimizers:
    def test_adamw_decays_weights_not_biases(self):
        cfg = TINY
        p, m, v = _states(cfg)
        g = {k: jnp.zeros_like(x) for k, x in p.items()}
        ocfg = OptimizerConfig(lr=0.1, weight_decay=0.5)
        np_, _, _ = T.adamw_update(p, g, m, v, jnp.float32(1), jnp.float32(0.1),
                                   ocfg)
        # zero grads: only decay moves weights
        assert float(jnp.sum((np_["embed.w"] - p["embed.w"]) ** 2)) > 0
        np.testing.assert_array_equal(np.asarray(np_["embed.b"]),
                                      np.asarray(p["embed.b"]))

    def test_sgd_momentum_accumulates(self):
        cfg = TINY
        p, m, _ = _states(cfg)
        g = {k: jnp.ones_like(x) for k, x in p.items()}
        p1, m1 = T.sgd_update(p, g, m, jnp.float32(0.1), momentum=0.9, wd=0.0)
        p2, m2 = T.sgd_update(p1, g, m1, jnp.float32(0.1), momentum=0.9, wd=0.0)
        d1 = float(jnp.mean(jnp.abs(p1["embed.w"] - p["embed.w"])))
        d2 = float(jnp.mean(jnp.abs(p2["embed.w"] - p1["embed.w"])))
        assert d2 > d1  # momentum grows the step


class TestSplitSteps:
    def _split_vs_fused(self, variant):
        cfg = TINY
        bcfg = BackwardConfig(variant=variant)
        batch = 16
        fwd, bwd, _ = T.make_split_steps(cfg, bcfg, batch)
        params = M.init_params(cfg, seed=2)
        x_all, y_all = _dataset(cfg, n=batch, seed=2)
        mask = jnp.zeros((cfg.n_qlinears(),), jnp.float32)

        out = jax.jit(fwd)(params, mask, x_all, y_all)
        loss, _, ctx_flat = out[0], out[1], out[2:]
        grads_split = jax.jit(bwd)(params, mask, x_all, *ctx_flat)

        g_fn = jax.jit(T.make_grad_step(cfg, bcfg))
        grads_fused, loss2, _ = g_fn(params, mask, x_all, y_all)
        np.testing.assert_allclose(float(loss), float(loss2), rtol=1e-6)
        return {n: g for n, g in zip(M.param_names(cfg), grads_split)}, \
            grads_fused

    def test_split_equals_fused_gradients_fp(self):
        """Identical math in one or two HLO modules -> identical grads."""
        split, fused = self._split_vs_fused("fp")
        for name, g in split.items():
            np.testing.assert_allclose(np.asarray(g), np.asarray(fused[name]),
                                       rtol=1e-5, atol=1e-6, err_msg=name)

    def test_split_matches_fused_gradients_hot(self):
        """The pseudo-stochastic quantizer keys its rounding off input
        mantissa bits, so two separately compiled programs (whose float
        reassociation differs at the ULP level) may flip a handful of
        INT4 decisions. Require strong statistical agreement rather than
        bit equality."""
        split, fused = self._split_vs_fused("hot")
        va = np.concatenate([np.asarray(split[k]).ravel() for k in sorted(split)])
        vb = np.concatenate([np.asarray(fused[k]).ravel() for k in sorted(fused)])
        cos = va @ vb / (np.linalg.norm(va) * np.linalg.norm(vb) + 1e-12)
        assert cos > 0.99

    def test_schema_lists_int8_ctx(self):
        cfg = TINY
        _, _, schema = T.make_split_steps(
            cfg, BackwardConfig(variant="hot", abc=True), batch=16)
        int8 = [keys for kind, _, keys, _ in schema if kind == "ql"
                for k, s, d in keys if d == "int8"]
        assert int8, "ABC must expose int8 compressed residuals"


class TestCalibration:
    def test_calib_outputs_shapes(self):
        cfg = TINY
        bcfg = BackwardConfig(variant="hot")
        calib = jax.jit(T.make_calib_step(cfg, bcfg))
        params = M.init_params(cfg, seed=3)
        x, y = _dataset(cfg, n=16, seed=3)
        outs = calib(params, x, y)
        assert len(outs) == 7
        for o in outs:
            assert o.shape == (cfg.n_qlinears(),)
            assert np.isfinite(np.asarray(o)).all()

    def test_lqs_rule(self):
        mt = jnp.asarray([1.0, 1.0, 1.0])
        mk = jnp.asarray([0.2, 0.6, 0.51])
        mask = np.asarray(T.lqs_select(mt, mk))
        # diff >= 50% -> per-token (1)
        np.testing.assert_array_equal(mask, [1.0, 0.0, 0.0])

    def test_outlier_detection(self):
        """Inject a token outlier into the data and verify the calib stats
        see a larger outlier ratio vs clean data in at least one layer."""
        cfg = TINY
        bcfg = BackwardConfig(variant="hot")
        calib = jax.jit(T.make_calib_step(cfg, bcfg))
        params = M.init_params(cfg, seed=4)
        x, y = _dataset(cfg, n=16, seed=4)
        x_out = x.at[:, 3, :].mul(40.0)
        clean = calib(params, x, y)[2]
        spiky = calib(params, x_out, y)[2]
        assert float(jnp.max(spiky)) > float(jnp.max(clean))
