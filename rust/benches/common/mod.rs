//! Shared helpers for the training-based benches.

// each bench binary includes this file and uses a different subset
#![allow(dead_code)]

use std::sync::Arc;

use hot::backend::Executor;
use hot::config::RunConfig;
use hot::coordinator::{Mode, Trainer};

pub const DIR: &str = "artifacts";

/// Bench length: HOT_BENCH_STEPS env var overrides (quality results
/// sharpen with more steps; default keeps `cargo bench` under control).
pub fn steps(default: usize) -> usize {
    std::env::var("HOT_BENCH_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Shared bench entry point: logging + obs env knobs (`HOT_LOG`,
/// `HOT_TRACE`) and the `HOT_THREADS` kernel-pool budget. Every bench
/// binary calls this (directly or via `executor_or_exit`) before any
/// timing, so env-knob handling cannot drift per binary.
pub fn init() {
    hot::util::log::init_from_env();
    hot::obs::init_from_env();
    if let Some(t) = std::env::var("HOT_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
    {
        hot::kernels::set_num_threads(t);
    }
}

/// Backend for the benches: PJRT over real artifacts when compiled in
/// and available, the native CPU backend otherwise — so the bench
/// trajectories populate on any machine. `HOT_THREADS` pins the kernel
/// pool budget (benches have no CLI, so the knob rides an env var).
pub fn executor_or_exit() -> Arc<dyn Executor> {
    init();
    match hot::backend::by_name("auto", DIR) {
        Ok(rt) => {
            hot::info!("bench backend: {}", rt.name());
            rt
        }
        Err(e) => {
            hot::warn_!("no usable backend: {e}");
            std::process::exit(0);
        }
    }
}

pub struct TrainOutcome {
    pub final_loss: f32,
    pub eval_loss: f32,
    pub eval_acc: f32,
    pub steps_per_s: f64,
    pub diverged: bool,
}

/// Train `variant` on `preset` for `n` steps and evaluate. Divergence
/// (NaN/inf loss) is reported, mirroring the paper's "NaN" table cells.
pub fn train_variant(rt: Arc<dyn Executor>, preset: &str, variant: &str,
                     n: usize, seed: u64, lr: f64) -> TrainOutcome {
    train_variant_noise(rt, preset, variant, n, seed, lr, 0.5)
}

pub fn train_variant_noise(rt: Arc<dyn Executor>, preset: &str, variant: &str,
                           n: usize, seed: u64, lr: f64, noise: f64)
                           -> TrainOutcome {
    let mut cfg = RunConfig::default();
    cfg.data_noise = noise;
    cfg.preset = preset.into();
    cfg.variant = variant.into();
    cfg.steps = n;
    cfg.seed = seed;
    cfg.lr = lr;
    cfg.warmup_steps = n / 10 + 1;
    cfg.eval_every = 0;
    cfg.calib_batches = if variant == "hot" { 1 } else { 0 };
    let mut tr = Trainer::new(rt.clone(), cfg).expect("trainer");
    tr.calibrate().expect("calibrate");
    run_and_eval(rt, preset, tr, n)
}

/// Like `train_variant` but executes an explicit train-step key
/// (rank-sweep variants such as `train_hot_r4_tiny`).
pub fn train_variant_with_key(rt: Arc<dyn Executor>, preset: &str, key: &str,
                              n: usize, seed: u64, lr: f64) -> TrainOutcome {
    train_variant_with_key_noise(rt, preset, key, n, seed, lr, 0.5)
}

pub fn train_variant_with_key_noise(rt: Arc<dyn Executor>, preset: &str,
                                    key: &str, n: usize, seed: u64, lr: f64,
                                    noise: f64) -> TrainOutcome {
    let mut cfg = RunConfig::default();
    cfg.data_noise = noise;
    cfg.preset = preset.into();
    cfg.variant = "hot".into();
    cfg.steps = n;
    cfg.seed = seed;
    cfg.lr = lr;
    cfg.warmup_steps = n / 10 + 1;
    cfg.eval_every = 0;
    cfg.calib_batches = 0;
    let mut tr = Trainer::new(rt.clone(), cfg).expect("trainer");
    tr.key_override = Some(key.to_string());
    run_and_eval(rt, preset, tr, n)
}

fn run_and_eval(rt: Arc<dyn Executor>, preset: &str, mut tr: Trainer,
                n: usize) -> TrainOutcome {
    let mut diverged = false;
    for _ in 0..n {
        match tr.step_once(Mode::Fused) {
            Ok((loss, _)) if loss.is_finite() => {}
            _ => {
                diverged = true;
                break;
            }
        }
    }
    let has_eval = rt.supports(&format!("eval_{preset}"));
    let (el, ea) = if diverged || !has_eval {
        (f32::NAN, f32::NAN)
    } else {
        tr.eval(4).unwrap_or((f32::NAN, f32::NAN))
    };
    TrainOutcome {
        final_loss: tr.metrics.smoothed_loss(8).unwrap_or(f32::NAN),
        eval_loss: el,
        eval_acc: ea,
        steps_per_s: tr.metrics.throughput_steps_per_s(),
        diverged,
    }
}

pub fn fmt_acc(o: &TrainOutcome) -> String {
    if o.diverged {
        "NaN".into()
    } else if o.eval_acc.is_nan() {
        format!("loss {:.3}", o.final_loss)
    } else {
        format!("{:.3}", o.eval_acc)
    }
}
