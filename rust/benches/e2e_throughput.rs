//! End-to-end coordinator throughput (ours; no direct paper analog —
//! this is the L3 perf gate for EXPERIMENTS.md §Perf).
//!
//! Measures steady-state step time for fused / split / accum modes and
//! breaks out the coordinator's host-side overhead vs XLA execute time.

#[path = "common/mod.rs"]
mod common;

use std::time::Instant;

use hot::config::RunConfig;
use hot::coordinator::{Mode, Trainer};
use hot::util::timer::Table;

fn bench_mode(rt: std::sync::Arc<hot::runtime::Runtime>, preset: &str,
              mode: Mode, steps: usize) -> (f64, f64) {
    let mut cfg = RunConfig::default();
    cfg.preset = preset.into();
    cfg.variant = "hot".into();
    cfg.steps = steps;
    cfg.calib_batches = 0;
    let mut tr = Trainer::new(rt, cfg).expect("trainer");
    tr.step_once(mode).expect("warmup/compile");
    let t0 = Instant::now();
    for _ in 1..steps {
        tr.step_once(mode).expect("step");
    }
    let total = t0.elapsed().as_secs_f64() / (steps - 1) as f64;
    // data-generation-only overhead estimate
    let t1 = Instant::now();
    for i in 0..20 {
        std::hint::black_box(tr.data.batch(0, i, tr.batch_size()));
    }
    let data_s = t1.elapsed().as_secs_f64() / 20.0;
    (total, data_s)
}

fn main() {
    let rt = common::runtime_or_exit();
    let steps = common::steps(12).max(4);
    let mut t = Table::new(&["preset", "mode", "step time", "data-gen share"]);
    for preset in ["tiny", "small"] {
        for (name, mode) in [("fused", Mode::Fused), ("split", Mode::Split)] {
            if mode == Mode::Split
                && !rt.manifest.artifacts
                    .contains_key(&format!("fwd_hot_{preset}"))
            {
                continue;
            }
            let (step_s, data_s) = bench_mode(rt.clone(), preset, mode, steps);
            t.row(&[preset.into(), name.into(),
                    format!("{:.1} ms", step_s * 1e3),
                    format!("{:.1}%", 100.0 * data_s / step_s)]);
        }
    }
    t.print("end-to-end coordinator throughput (HOT variant)");
    println!("note: XLA-CPU execute dominates; coordinator overhead = \
              data-gen + literal marshalling (see EXPERIMENTS.md §Perf)");
}
