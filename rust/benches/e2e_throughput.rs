//! End-to-end coordinator throughput (ours; no direct paper analog —
//! this is the L3 perf gate for EXPERIMENTS.md §Perf).
//!
//! Measures steady-state step time for fused / split / accum modes on
//! the active backend (native by default — no artifacts needed), breaks
//! out the data-generation share, and emits a machine-readable
//! `BENCH_e2e.json` so the bench trajectory populates run over run.

#[path = "common/mod.rs"]
mod common;

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use hot::backend::Executor;
use hot::config::RunConfig;
use hot::coordinator::{Mode, Trainer};
use hot::util::json::Json;
use hot::util::timer::Table;

struct ModeResult {
    preset: String,
    mode: &'static str,
    threads: usize,
    simd: bool,
    step_s: f64,
    data_s: f64,
    /// mean FLOPs/step from the kernels' own obs counters (not a model)
    flops_per_step: f64,
    /// mean bytes through the quantization epilogues per step
    bytes_q_per_step: f64,
}

struct ModeTimings {
    step_s: f64,
    data_s: f64,
    flops_per_step: f64,
    bytes_q_per_step: f64,
}

fn bench_mode(rt: Arc<dyn Executor>, preset: &str, mode: Mode,
              steps: usize) -> ModeTimings {
    let mut cfg = RunConfig::default();
    cfg.preset = preset.into();
    cfg.variant = "hot".into();
    cfg.steps = steps;
    cfg.batch = 16;
    cfg.calib_batches = 0;
    if mode == Mode::Accum {
        cfg.accum = 2; // measure real accumulation, not a degenerate loop
    }
    let mut tr = Trainer::new(rt, cfg).expect("trainer");
    // tracing stays on for the whole run: the per-step StepRecord then
    // carries the counter deltas the rows below consume, and its cost
    // is bounded <1% by the obs_trace overhead test
    hot::obs::set_trace_enabled(true);
    tr.step_once(mode).expect("warmup/compile");
    let t0 = Instant::now();
    for _ in 1..steps {
        tr.step_once(mode).expect("step");
    }
    let total = t0.elapsed().as_secs_f64() / (steps - 1) as f64;
    hot::obs::set_trace_enabled(false);
    // steady-state counter means, warmup record excluded
    let tail = &tr.metrics.records[1..];
    let flops_per_step = tail.iter().map(|r| r.prof_flops as f64)
        .sum::<f64>() / tail.len() as f64;
    let bytes_q_per_step = tail.iter().map(|r| r.prof_bytes_quant as f64)
        .sum::<f64>() / tail.len() as f64;
    // data-generation-only overhead estimate
    let t1 = Instant::now();
    for i in 0..20 {
        std::hint::black_box(tr.data.batch(0, i, tr.batch_size()));
    }
    let data_s = t1.elapsed().as_secs_f64() / 20.0;
    ModeTimings { step_s: total, data_s, flops_per_step, bytes_q_per_step }
}

fn main() {
    let rt = common::executor_or_exit();
    let steps = common::steps(12).max(4);
    let max_threads = hot::kernels::num_threads();
    // (threads, simd) cells: the kernel pool and SIMD tier only drive
    // the native backend; sweeping them under PJRT would record
    // duplicate rows as fake scaling signal. The (1, scalar) cell is
    // the baseline the SIMD-tier step-time delta is read against.
    let simd_avail =
        hot::kernels::active_tier() != hot::kernels::Tier::Scalar;
    let mut cells = vec![(1usize, true)];
    if rt.name() == "native" {
        if simd_avail {
            cells.push((1, false));
        }
        if max_threads > 1 {
            cells.push((max_threads, true));
        }
    }
    let mut results: Vec<ModeResult> = Vec::new();
    let mut t = Table::new(&["preset", "mode", "threads", "simd",
                             "step time", "steps/s", "GFLOP/s",
                             "data-gen share"]);
    for preset in ["tiny", "small", "base"] {
        for (name, mode) in [("fused", Mode::Fused), ("split", Mode::Split),
                             ("accum", Mode::Accum)] {
            // base is heavy: fused only, so the bench stays bounded
            if preset == "base" && mode != Mode::Fused {
                continue;
            }
            let needed = match mode {
                Mode::Fused => format!("train_hot_{preset}"),
                Mode::Split => format!("fwd_hot_{preset}"),
                Mode::Accum => format!("grad_hot_{preset}"),
            };
            if !rt.supports(&needed) {
                continue;
            }
            // base steps are ~100x tiny steps; fewer samples keep the
            // bench bounded without losing the steady-state signal
            let steps = if preset == "base" { steps.min(4) } else { steps };
            for &(threads, simd) in &cells {
                hot::kernels::set_num_threads(threads);
                hot::kernels::set_simd_enabled(simd);
                // record what actually ran, not what was requested: on
                // scalar-only hardware (or under PJRT, which bypasses
                // the kernel pool entirely) the row must not claim a
                // SIMD tier it never had
                let effective =
                    simd && simd_avail && rt.name() == "native";
                let tm = bench_mode(rt.clone(), preset, mode, steps);
                t.row(&[preset.into(), name.into(), threads.to_string(),
                        if effective { "on" } else { "off" }.into(),
                        format!("{:.1} ms", tm.step_s * 1e3),
                        format!("{:.2}", 1.0 / tm.step_s),
                        format!("{:.2}",
                                tm.flops_per_step / tm.step_s / 1e9),
                        format!("{:.1}%", 100.0 * tm.data_s / tm.step_s)]);
                results.push(ModeResult {
                    preset: preset.into(), mode: name, threads,
                    simd: effective, step_s: tm.step_s, data_s: tm.data_s,
                    flops_per_step: tm.flops_per_step,
                    bytes_q_per_step: tm.bytes_q_per_step,
                });
            }
        }
    }
    hot::kernels::set_num_threads(0);
    hot::kernels::set_simd_enabled(true);
    t.print(&format!("end-to-end throughput (HOT variant, {} backend)",
                     rt.name()));

    // machine-readable trajectory point
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("e2e_throughput".into()));
    root.insert("backend".to_string(), Json::Str(rt.name().into()));
    root.insert("tier".to_string(),
                Json::Str(hot::kernels::active_tier().name().into()));
    // distinguishes real runs of this binary from modeled artifacts a
    // toolchain-less container may have committed
    root.insert("provenance".to_string(), Json::Str("measured".into()));
    root.insert("steps".to_string(), Json::Num(steps as f64));
    let rows: Vec<Json> = results
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("preset".to_string(), Json::Str(r.preset.clone()));
            m.insert("mode".to_string(), Json::Str(r.mode.into()));
            m.insert("threads".to_string(), Json::Num(r.threads as f64));
            m.insert("simd".to_string(), Json::Bool(r.simd));
            m.insert("step_ms".to_string(), Json::Num(r.step_s * 1e3));
            m.insert("steps_per_sec".to_string(), Json::Num(1.0 / r.step_s));
            m.insert("datagen_share".to_string(),
                     Json::Num(r.data_s / r.step_s));
            m.insert("flops_per_step".to_string(),
                     Json::Num(r.flops_per_step));
            m.insert("bytes_quantized_per_step".to_string(),
                     Json::Num(r.bytes_q_per_step));
            m.insert("gflops".to_string(),
                     Json::Num(r.flops_per_step / r.step_s / 1e9));
            Json::Obj(m)
        })
        .collect();
    root.insert("results".to_string(), Json::Arr(rows));
    let path = "BENCH_e2e.json";
    match std::fs::write(path, Json::Obj(root).to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => hot::warn_!("could not write {path}: {e}"),
    }
}
