//! End-to-end coordinator throughput — thin shim over the shared
//! harness.
//!
//! `cargo bench --bench e2e_throughput` runs exactly the e2e suite of
//! `hot bench` (`hot::bench::suites::run_e2e`): per-step sampling
//! through the cell runner (no hand-rolled `Instant` loops here),
//! robust stats, obs-counter work totals, schema-v2 `BENCH_e2e.json`.
//! `HOT_BENCH_STEPS` doubles as the smoke switch (tiny preset only)
//! and, when numeric, the per-cell step count.

#[path = "common/mod.rs"]
mod common;

fn main() {
    let rt = common::executor_or_exit();
    let smoke = std::env::var("HOT_BENCH_STEPS").is_ok();
    let steps = common::steps(12);
    let path = "BENCH_e2e.json";
    match hot::bench::suites::run_e2e(rt, smoke, steps) {
        Ok(report) => match report.save(path) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => hot::warn_!("could not write {path}: {e}"),
        },
        Err(e) => hot::warn_!("e2e suite failed: {e}"),
    }
}
