//! Fig 1 — training memory vs batch size for ViT-B on a 24 GB device.
//! Paper: FP (and LBP/LUQ) OOM at batch 256; HOT trains up to 1024.

#[path = "common/mod.rs"]
mod common;

use hot::costmodel::{breakdown, max_feasible_batch, zoo, MemMethod};
use hot::util::timer::Table;

fn main() {
    common::init();
    let spec = zoo::vit_b();
    let batches = [64, 128, 256, 512, 1024];
    let methods: [(&str, MemMethod); 4] = [
        ("FP", MemMethod::Fp32),
        ("LBP-WHT", MemMethod::FpActivations),
        ("LUQ", MemMethod::FpActivations),
        ("HOT", MemMethod::Hot { rank: 8, abc: true }),
    ];
    let mut t = Table::new(&["method", "b=64", "b=128", "b=256", "b=512",
                             "b=1024", "max batch @24GB"]);
    for (name, m) in methods {
        let mut row = vec![name.to_string()];
        for b in batches {
            let gb = breakdown(&spec, b, m).gb();
            row.push(if gb <= 24.0 {
                format!("{gb:.1}")
            } else {
                format!("{gb:.1} (OOM)")
            });
        }
        row.push(
            max_feasible_batch(&spec, &batches, m, 24.0)
                .map(|b| b.to_string())
                .unwrap_or_else(|| "none".into()),
        );
        t.row(&row);
    }
    t.print("Fig 1 — ViT-B training memory (GB) vs batch, 24 GB budget");

    let fp = max_feasible_batch(&spec, &batches, MemMethod::Fp32, 24.0);
    let hot = max_feasible_batch(&spec, &batches,
                                 MemMethod::Hot { rank: 8, abc: true }, 24.0);
    println!("\npaper claim:  FP fails at 256, HOT trains at 1024");
    println!("measured   :  FP max {:?}, HOT max {:?}", fp, hot);
    assert!(fp.unwrap_or(0) < 256 && hot == Some(1024),
            "Fig-1 shape must hold");
    println!("SHAPE HOLDS");
}
