//! Fig 2 — component-wise memory breakdown, ViT-B @ batch 256.
//! Paper: intermediate activations dominate; HOT collapses that bar.

#[path = "common/mod.rs"]
mod common;

use hot::costmodel::{breakdown, zoo, MemMethod};
use hot::util::timer::Table;

fn main() {
    common::init();
    let spec = zoo::vit_b();
    let batch = 256;
    let mut t = Table::new(&["method", "weights", "optimizer", "grads",
                             "activations", "eager extras", "total GB"]);
    let gb = |x: u64| format!("{:.2}", x as f64 / (1u64 << 30) as f64);
    let methods: [(&str, MemMethod); 5] = [
        ("FP", MemMethod::Fp32),
        ("LBP-WHT/LUQ", MemMethod::FpActivations),
        ("LoRA", MemMethod::Lora { r_lora: 8 }),
        ("HOT", MemMethod::Hot { rank: 8, abc: true }),
        ("HOT+LoRA", MemMethod::HotLora { rank: 8, r_lora: 8 }),
    ];
    for (name, m) in methods {
        let b = breakdown(&spec, batch, m);
        t.row(&[name.into(), gb(b.weights), gb(b.optimizer), gb(b.gradients),
                gb(b.activations), gb(b.attention),
                format!("{:.2}", b.gb())]);
    }
    t.print("Fig 2 — ViT-B @ 256 component breakdown (GB)");

    let fp = breakdown(&spec, batch, MemMethod::Fp32);
    let hotl = breakdown(&spec, batch, MemMethod::Hot { rank: 8, abc: true });
    let act_ratio = hotl.activations as f64 / fp.activations as f64;
    println!("\nactivation compression: {:.3} (paper/theory: 0.125 = 1/8)",
             act_ratio);
    println!("total reduction: {:.0}% (paper: up to 75% on ViT)",
             100.0 * (1.0 - hotl.total() as f64 / fp.total() as f64));
    assert!((act_ratio - 0.125).abs() < 0.01);
    println!("SHAPE HOLDS");
}
