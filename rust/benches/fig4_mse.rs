//! Fig 4 — layer-wise gradient-approximation error for HT+INT4 vs HLA on
//! both backward paths, measured through the calibration artifact on the
//! real model.
//!
//! Paper: g_w errors are higher under HT+INT4 than HLA (quantization
//! hurts the weight path); g_x errors accumulate with depth under HLA.

#[path = "common/mod.rs"]
mod common;

use hot::config::RunConfig;
use hot::coordinator::Trainer;
use hot::util::timer::Table;

fn main() {
    let rt = common::executor_or_exit();
    let mut cfg = RunConfig::default();
    cfg.preset = "small".into();
    cfg.calib_batches = 2;
    let mut tr = Trainer::new(rt, cfg).expect("trainer");
    let rep = tr.calibrate().expect("calib").expect("calib artifact");

    let mut t = Table::new(&["layer", "gx HT+INT4", "gx HLA", "gw HT+INT4",
                             "gw HLA"]);
    for l in &rep.layers {
        t.row(&[l.name.clone(), format!("{:.3e}", l.gx_err_hq),
                format!("{:.3e}", l.gx_err_hla),
                format!("{:.3e}", l.gw_err_hq),
                format!("{:.3e}", l.gw_err_hla)]);
    }
    t.print("Fig 4 — per-layer relative gradient MSE (ViT small)");

    // shape: on the g_w path, HT+INT4 errs more than HLA on most layers
    let active: Vec<_> = rep.layers.iter()
        .filter(|l| l.gw_err_hq > 0.0 && l.gw_err_hla > 0.0).collect();
    let gw_worse = active.iter().filter(|l| l.gw_err_hq > l.gw_err_hla)
        .count();
    println!("\ng_w: HT+INT4 worse than HLA on {gw_worse}/{} layers \
              (paper: all)", active.len());
    assert!(gw_worse * 2 > active.len(),
            "quantization must hurt the g_w path more than HLA");

    // accumulated-error claim: HLA-on-g_x error grows toward the input
    // (errors compound as the gradient flows backward through more
    // HLA-approximated layers). The calib diagnostic is per-layer/one-
    // shot, so report the depth profile rather than asserting it.
    println!("gx HLA depth profile (embed..head): {:?}",
             rep.layers.iter().map(|l| (l.gx_err_hla * 1e3).round() / 1e3)
                 .collect::<Vec<_>>());
    println!("SHAPE HOLDS (g_w ordering)");
}
