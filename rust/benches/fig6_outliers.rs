//! Fig 6 / Fig 9 — token-level outlier structure of the output gradient
//! g_y, per layer, and its interaction with per-token vs per-tensor
//! quantization.
//!
//! Paper: attention-proj / fc2 layers show consistent token outliers
//! (case a: per-token wins); fc1 layers don't (case b: per-tensor is
//! fine). We reproduce the *mechanism*: injecting a token outlier into
//! the input raises per-layer outlier ratios and flips LQS decisions.

#[path = "common/mod.rs"]
mod common;

use hot::backend::Executor;
use hot::config::RunConfig;
use hot::coordinator::lqs::CalibReport;
use hot::coordinator::Trainer;
use hot::data::VisionDataset;
use hot::util::timer::Table;

fn calib(rt: &std::sync::Arc<dyn Executor>, tr: &Trainer,
         ds: &VisionDataset, outlier: Option<(usize, f32)>) -> CalibReport {
    let batch = tr.batch_size();
    let mut per_batch = Vec::new();
    for b in 0..2u64 {
        let (x, y) = match outlier {
            None => ds.batch(2, b, batch),
            Some((tok, gain)) => ds.batch_with_outlier(2, b, batch, tok, gain),
        };
        let outs = rt.calib_step(&format!("calib_{}", tr.cfg.preset),
                                 &tr.weights, &x, &y)
            .expect("calib");
        per_batch.push(outs);
    }
    CalibReport::from_batches(&tr.preset.qlinears, &per_batch, 0.5).unwrap()
}

fn main() {
    let rt = common::executor_or_exit();
    let mut cfg = RunConfig::default();
    cfg.preset = "small".into();
    let tr = Trainer::new(rt.clone(), cfg).expect("trainer");
    let m = &tr.preset.model;
    let ds = VisionDataset::new(m.seq, m.in_dim, m.n_classes, 3);

    let clean = calib(&rt, &tr, &ds, None);
    let spiky = calib(&rt, &tr, &ds, Some((7, 50.0)));

    let mut t = Table::new(&["layer", "outlier ratio (clean)",
                             "outlier ratio (token-spike)", "LQS clean",
                             "LQS spike"]);
    let (mc, ms) = (clean.lqs_mask(), spiky.lqs_mask());
    for (i, (lc, ls)) in clean.layers.iter().zip(&spiky.layers).enumerate() {
        let lab = |v: f32| if v > 0.5 { "token" } else { "tensor" };
        t.row(&[lc.name.clone(), format!("{:.2}", lc.outlier_ratio),
                format!("{:.2}", ls.outlier_ratio),
                lab(mc[i]).into(), lab(ms[i]).into()]);
    }
    t.print("Fig 6/9 — g_y token-outlier structure per layer");

    let mean_clean: f64 = clean.layers.iter().map(|l| l.outlier_ratio)
        .sum::<f64>() / clean.layers.len() as f64;
    let mean_spiky: f64 = spiky.layers.iter().map(|l| l.outlier_ratio)
        .sum::<f64>() / spiky.layers.len() as f64;
    println!("\nmean outlier ratio: clean {mean_clean:.2} -> spiky \
              {mean_spiky:.2}");
    assert!(mean_spiky > mean_clean,
            "token spikes must surface in g_y outlier stats");
    println!("per-token layers: clean {} -> spiky {}", clean.n_per_token(),
             spiky.n_per_token());
    println!("SHAPE HOLDS (outliers detected; LQS reacts)");
}
