//! Fig 7 — estimated memory (batch 256) + backward compute (Gbops) for
//! ResNet-50, ViT-B, EfficientFormer-L7 under each method.
//! Paper: HOT cuts memory up to 86% (ResNet-50) / 75% (ViT) and compute
//! ~64-65% vs FP, beating LBP-WHT and LUQ on compute.

#[path = "common/mod.rs"]
mod common;

use hot::costmodel::{breakdown, model_bops, zoo, MemMethod, Method};
use hot::util::timer::Table;

fn main() {
    common::init();
    let specs = [zoo::resnet50(), zoo::vit_b(), zoo::efficientformer_l7()];
    let mem_methods: [(&str, MemMethod); 3] = [
        ("FP", MemMethod::Fp32),
        ("LBP/LUQ", MemMethod::FpActivations),
        ("HOT", MemMethod::Hot { rank: 8, abc: true }),
    ];
    let bops_methods: [(&str, Method); 4] = [
        ("FP", Method::Fp32),
        ("LBP-WHT", Method::LbpWht { rank: 8 }),
        ("LUQ", Method::Luq),
        ("HOT", Method::Hot { rank: 8 }),
    ];

    let mut tm = Table::new(&["model", "FP GB", "LBP/LUQ GB", "HOT GB",
                              "reduction"]);
    for spec in &specs {
        let f = breakdown(spec, 256, mem_methods[0].1).gb();
        let l = breakdown(spec, 256, mem_methods[1].1).gb();
        let h = breakdown(spec, 256, mem_methods[2].1).gb();
        tm.row(&[spec.name.clone(), format!("{f:.1}"), format!("{l:.1}"),
                 format!("{h:.1}"), format!("{:.0}%", 100.0 * (1.0 - h / f))]);
    }
    tm.print("Fig 7 (top) — memory @ batch 256");

    let mut tb = Table::new(&["model", "FP Gbops", "LBP Gbops", "LUQ Gbops",
                              "HOT Gbops", "HOT vs FP"]);
    for spec in &specs {
        let v: Vec<f64> = bops_methods
            .iter()
            .map(|(_, m)| model_bops(&spec.layers, *m) as f64 / 1e9)
            .collect();
        tb.row(&[spec.name.clone(), format!("{:.0}", v[0]),
                 format!("{:.0}", v[1]), format!("{:.0}", v[2]),
                 format!("{:.0}", v[3]),
                 format!("-{:.0}%", 100.0 * (1.0 - v[3] / v[0]))]);
    }
    tb.print("Fig 7 (bottom) — backward bit-operations per sample");

    // shape assertions: HOT < LUQ-ish band, HOT < FP by >= 55% everywhere
    for spec in &specs {
        let f = model_bops(&spec.layers, Method::Fp32) as f64;
        let h = model_bops(&spec.layers, Method::Hot { rank: 8 }) as f64;
        assert!(h / f < 0.45, "{}: HOT bops ratio {}", spec.name, h / f);
        let fm = breakdown(spec, 256, MemMethod::Fp32).total() as f64;
        let hm = breakdown(spec, 256, MemMethod::Hot { rank: 8, abc: true })
            .total() as f64;
        assert!(hm / fm < 0.45, "{}: HOT mem ratio {}", spec.name, hm / fm);
    }
    println!("\nSHAPE HOLDS (HOT ≥55% cheaper than FP on both axes)");
}
