//! Fig 8 — per-module kernel latency breakdown (HT / HLA / quant /
//! integer GEMM / dequant) for the representative layers, FP32 vs
//! LBP-WHT vs HOT. Paper: integer GEMM collapses the GEMM bar (182μs ->
//! 25μs on ViT-B qkv); HT+HLA overhead ~16% of FP.

#[path = "common/mod.rs"]
mod common;

use hot::costmodel::zoo::Layer;
use hot::costmodel::Method;
use hot::latsim::{pipeline, total_us, RTX_3090};
use hot::util::timer::Table;

fn main() {
    common::init();
    let layers = [
        ("ResNet-50", Layer::new("layer4.conv2", 49, 512, 4608)),
        ("ViT-B", Layer::new("qkv", 197, 2304, 768)),
        ("EfficientFormer-L7", Layer::new("stages.1.fc1", 784, 768, 192)),
    ];
    let g = RTX_3090;
    for (model, l) in &layers {
        let mut t = Table::new(&["method", "module", "us"]);
        for m in [Method::Fp32, Method::LbpWht { rank: 8 },
                  Method::Hot { rank: 8 }] {
            for k in pipeline(&g, l, m) {
                t.row(&[m.label(), k.name.clone(), format!("{:.1}", k.us)]);
            }
            t.row(&[m.label(), "TOTAL".into(),
                    format!("{:.1}", total_us(&g, l, m))]);
        }
        t.print(&format!("Fig 8 — {model} {} ({},{},{})", l.name, l.l, l.o,
                         l.i));
    }

    // shape assertions on the ViT-B flagship layer
    let qkv = &layers[1].1;
    let hot_parts = pipeline(&g, qkv, Method::Hot { rank: 8 });
    let gemm: f64 = hot_parts.iter().filter(|k| k.name.contains("gemm"))
        .map(|k| k.us).sum();
    let fp_gemm: f64 = pipeline(&g, qkv, Method::Fp32).iter()
        .map(|k| k.us).sum();
    println!("\ninteger GEMM {gemm:.0}us vs FP GEMM {fp_gemm:.0}us \
              (paper: 25 vs 182)");
    assert!(gemm < fp_gemm / 3.0, "int GEMM must collapse the GEMM bar");
    let transforms: f64 = hot_parts.iter()
        .filter(|k| k.name == "ht" || k.name == "hla")
        .map(|k| k.us).sum();
    let ovh = transforms / fp_gemm;
    println!("HT+HLA overhead vs FP: {:.0}% (paper: ~16%)", 100.0 * ovh);
    assert!(ovh < 0.4, "transform overhead out of band: {ovh}");
    println!("SHAPE HOLDS");
}
