//! GEMM kernel throughput — thin shim over the shared harness.
//!
//! `cargo bench --bench kernel_gemm` runs exactly the kernels suite of
//! `hot bench` (`hot::bench::suites::run_kernels`): same cells, same
//! warmup/MAD methodology, same schema-v2 `BENCH_kernels.json`. All
//! methodology lives in `rust/src/bench/`; this file only selects the
//! smoke tier and writes the report. `HOT_BENCH_STEPS` (any value)
//! keeps its historical meaning as the CI smoke switch.

#[path = "common/mod.rs"]
mod common;

fn main() {
    common::init();
    let smoke = std::env::var("HOT_BENCH_STEPS").is_ok();
    let report = hot::bench::suites::run_kernels(smoke);
    let path = "BENCH_kernels.json";
    match report.save(path) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => hot::warn_!("could not write {path}: {e}"),
    }
}
