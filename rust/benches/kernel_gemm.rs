//! GEMM kernel throughput: naive oracle vs blocked vs blocked+threaded,
//! f32 and i8, across thread budgets — the perf gate for the
//! `rust/src/kernels/` subsystem (ours; no direct paper analog, but it
//! is the compute story behind the paper's Table 6 speedups).
//!
//! Emits `BENCH_kernels.json` with GFLOP/s (f32) / GOP/s (i8) per
//! (size, impl, threads) so the bench trajectory tracks kernel perf
//! run over run. `HOT_BENCH_STEPS` is unused here; sizing is fixed so
//! points stay comparable.

use std::collections::BTreeMap;
use std::time::Duration;

use hot::kernels::{self, reference};
use hot::util::json::Json;
use hot::util::prng::Pcg32;
use hot::util::timer::{bench, Table};

struct Point {
    kind: &'static str,
    size: usize,
    imp: &'static str,
    threads: usize,
    gflops: f64,
}

fn gflops(size: usize, secs: f64) -> f64 {
    2.0 * (size * size * size) as f64 / secs / 1e9
}

fn bench_size(size: usize, budget_ms: u64, points: &mut Vec<Point>) {
    let mut rng = Pcg32::seeded(size as u64);
    let a: Vec<f32> = (0..size * size).map(|_| rng.normal()).collect();
    let b: Vec<f32> = (0..size * size).map(|_| rng.normal()).collect();
    let qa: Vec<i8> =
        (0..size * size).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
    let qb: Vec<i8> =
        (0..size * size).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
    let budget = Duration::from_millis(budget_ms);

    // naive oracles (single-threaded by construction)
    let st = bench(1, budget, 64, || {
        std::hint::black_box(reference::matmul(&a, &b, size, size, size));
    });
    points.push(Point { kind: "f32", size, imp: "naive", threads: 1,
                        gflops: gflops(size, st.median_s) });
    let st = bench(1, budget, 64, || {
        std::hint::black_box(reference::matmul_i8_nn(&qa, &qb, size, size,
                                                     size));
    });
    points.push(Point { kind: "i8", size, imp: "naive", threads: 1,
                        gflops: gflops(size, st.median_s) });

    // blocked kernels at 1 / 2 / 4 threads
    for threads in [1usize, 2, 4] {
        kernels::set_num_threads(threads);
        let imp = if threads == 1 { "blocked" } else { "blocked+threaded" };
        let st = bench(1, budget, 64, || {
            std::hint::black_box(kernels::gemm_f32_nn(&a, &b, size, size,
                                                      size));
        });
        points.push(Point { kind: "f32", size, imp, threads,
                            gflops: gflops(size, st.median_s) });
        let st = bench(1, budget, 64, || {
            std::hint::black_box(kernels::gemm_i8_nn(&qa, &qb, size, size,
                                                     size));
        });
        points.push(Point { kind: "i8", size, imp, threads,
                            gflops: gflops(size, st.median_s) });
    }
    kernels::set_num_threads(0);
}

fn main() {
    let mut points: Vec<Point> = Vec::new();
    for (size, budget_ms) in [(64usize, 150u64), (128, 250), (256, 600)] {
        bench_size(size, budget_ms, &mut points);
    }

    let mut t = Table::new(&["kind", "size", "impl", "threads", "GFLOP/s",
                             "vs naive"]);
    for p in &points {
        let naive = points
            .iter()
            .find(|q| q.kind == p.kind && q.size == p.size && q.imp == "naive")
            .map(|q| q.gflops)
            .unwrap_or(f64::NAN);
        t.row(&[p.kind.into(), format!("{0}x{0}x{0}", p.size), p.imp.into(),
                p.threads.to_string(), format!("{:.2}", p.gflops),
                format!("{:.2}x", p.gflops / naive)]);
    }
    t.print("GEMM kernels: naive vs blocked vs blocked+threaded");

    let rows: Vec<Json> = points
        .iter()
        .map(|p| {
            let mut m = BTreeMap::new();
            m.insert("kind".to_string(), Json::Str(p.kind.into()));
            m.insert("n".to_string(), Json::Num(p.size as f64));
            m.insert("k".to_string(), Json::Num(p.size as f64));
            m.insert("m".to_string(), Json::Num(p.size as f64));
            m.insert("impl".to_string(), Json::Str(p.imp.into()));
            m.insert("threads".to_string(), Json::Num(p.threads as f64));
            m.insert("gflops".to_string(), Json::Num(p.gflops));
            Json::Obj(m)
        })
        .collect();
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("kernel_gemm".into()));
    root.insert("results".to_string(), Json::Arr(rows));
    let path = "BENCH_kernels.json";
    match std::fs::write(path, Json::Obj(root).to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
