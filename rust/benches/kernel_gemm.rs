//! GEMM kernel throughput: naive oracle vs the scalar tier vs the SIMD
//! tier (AVX2/NEON), f32 and i8, across thread budgets — the perf gate
//! for the `rust/src/kernels/` subsystem (ours; no direct paper analog,
//! but it is the compute story behind the paper's Table 6 speedups).
//!
//! Emits `BENCH_kernels.json` with GFLOP/s (f32) / GOP/s (i8) per
//! (size, impl, threads) plus a `deltas` block recording the
//! scalar-vs-SIMD speedup per (kind, size) at one thread — the number
//! the SIMD-tier acceptance gate reads (>= 2x for f32 at 512^3 on any
//! AVX2/NEON machine). `HOT_BENCH_STEPS` (any value) switches to the
//! CI smoke sizing: small shapes, short budgets, same schema.
//!
//! FLOP counts come from the obs counters the kernels themselves bump
//! (one instrumented run per cell with tracing enabled, tracing off for
//! the timed loop) rather than a hand-computed 2n^3 — so shortcut paths
//! (one-hot gathers, zero-skipping) are billed for the work they do.

use std::collections::BTreeMap;
use std::time::Duration;

use hot::kernels::{self, reference, Tier};
use hot::util::json::Json;
use hot::util::prng::Pcg32;
use hot::util::timer::{bench, Table};

struct Point {
    kind: &'static str,
    size: usize,
    imp: &'static str,
    threads: usize,
    gflops: f64,
}

/// FLOPs one invocation of `f` performs, read off the kernels' own obs
/// counters (tracing is flipped on only for this single untimed run).
fn counted_flops<F: FnMut()>(mut f: F) -> u64 {
    hot::obs::set_trace_enabled(true);
    let before = hot::obs::flops_total();
    f();
    let fl = hot::obs::flops_total() - before;
    hot::obs::set_trace_enabled(false);
    fl
}

fn gflops(flops: u64, secs: f64) -> f64 {
    flops as f64 / secs / 1e9
}

fn bench_size(size: usize, budget_ms: u64, simd_avail: bool,
              points: &mut Vec<Point>) {
    let mut rng = Pcg32::seeded(size as u64);
    let a: Vec<f32> = (0..size * size).map(|_| rng.normal()).collect();
    let b: Vec<f32> = (0..size * size).map(|_| rng.normal()).collect();
    let qa: Vec<i8> =
        (0..size * size).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
    let qb: Vec<i8> =
        (0..size * size).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
    let budget = Duration::from_millis(budget_ms);

    // naive oracles (single-threaded by construction); skipped at large
    // sizes where a naive iteration alone would blow the budget
    if size <= 256 {
        let fl = counted_flops(|| {
            std::hint::black_box(reference::matmul(&a, &b, size, size, size));
        });
        let st = bench(1, budget, 64, || {
            std::hint::black_box(reference::matmul(&a, &b, size, size, size));
        });
        points.push(Point { kind: "f32", size, imp: "naive", threads: 1,
                            gflops: gflops(fl, st.median_s) });
        let fl = counted_flops(|| {
            std::hint::black_box(reference::matmul_i8_nn(&qa, &qb, size, size,
                                                         size));
        });
        let st = bench(1, budget, 64, || {
            std::hint::black_box(reference::matmul_i8_nn(&qa, &qb, size, size,
                                                         size));
        });
        points.push(Point { kind: "i8", size, imp: "naive", threads: 1,
                            gflops: gflops(fl, st.median_s) });
    }

    // blocked kernels: scalar tier vs SIMD tier at 1 / 2 / 4 threads
    for (imp, simd) in [("scalar", false), ("simd", true)] {
        if simd && !simd_avail {
            continue;
        }
        kernels::set_simd_enabled(simd);
        for threads in [1usize, 2, 4] {
            kernels::set_num_threads(threads);
            let fl = counted_flops(|| {
                std::hint::black_box(kernels::gemm_f32_nn(&a, &b, size, size,
                                                          size));
            });
            let st = bench(1, budget, 64, || {
                std::hint::black_box(kernels::gemm_f32_nn(&a, &b, size, size,
                                                          size));
            });
            points.push(Point { kind: "f32", size, imp, threads,
                                gflops: gflops(fl, st.median_s) });
            let fl = counted_flops(|| {
                std::hint::black_box(kernels::gemm_i8_nn(&qa, &qb, size, size,
                                                         size));
            });
            let st = bench(1, budget, 64, || {
                std::hint::black_box(kernels::gemm_i8_nn(&qa, &qb, size, size,
                                                         size));
            });
            points.push(Point { kind: "i8", size, imp, threads,
                                gflops: gflops(fl, st.median_s) });
        }
    }
    kernels::set_simd_enabled(true);
    kernels::set_num_threads(0);
}

fn main() {
    let tier = hot::kernels::active_tier();
    let simd_avail = tier != Tier::Scalar;
    // CI smoke mode: the memory-bench smoke convention (HOT_BENCH_STEPS
    // set) trims sizes/budgets so the step stays fast while still
    // exercising every (impl, threads) cell and the JSON contract
    let smoke = std::env::var("HOT_BENCH_STEPS").is_ok();
    let sizes: &[(usize, u64)] = if smoke {
        &[(64, 40), (128, 80)]
    } else {
        &[(64, 150), (128, 250), (256, 600), (512, 1500)]
    };
    let mut points: Vec<Point> = Vec::new();
    for &(size, budget_ms) in sizes {
        bench_size(size, budget_ms, simd_avail, &mut points);
    }

    let find = |kind: &str, size: usize, imp: &str, threads: usize| {
        points
            .iter()
            .find(|q| q.kind == kind && q.size == size && q.imp == imp
                  && q.threads == threads)
            .map(|q| q.gflops)
    };
    let mut t = Table::new(&["kind", "size", "impl", "threads", "GFLOP/s",
                             "vs scalar@1t"]);
    for p in &points {
        let base = find(p.kind, p.size, "scalar", 1).unwrap_or(f64::NAN);
        t.row(&[p.kind.into(), format!("{0}x{0}x{0}", p.size), p.imp.into(),
                p.threads.to_string(), format!("{:.2}", p.gflops),
                format!("{:.2}x", p.gflops / base)]);
    }
    t.print(&format!("GEMM kernels: naive vs scalar vs simd (tier: {})",
                     tier.name()));

    let rows: Vec<Json> = points
        .iter()
        .map(|p| {
            let mut m = BTreeMap::new();
            m.insert("kind".to_string(), Json::Str(p.kind.into()));
            m.insert("n".to_string(), Json::Num(p.size as f64));
            m.insert("k".to_string(), Json::Num(p.size as f64));
            m.insert("m".to_string(), Json::Num(p.size as f64));
            m.insert("impl".to_string(), Json::Str(p.imp.into()));
            m.insert("threads".to_string(), Json::Num(p.threads as f64));
            m.insert("gflops".to_string(), Json::Num(p.gflops));
            Json::Obj(m)
        })
        .collect();
    // scalar-vs-SIMD deltas at 1 thread: the acceptance-gate numbers
    let mut deltas: Vec<Json> = Vec::new();
    if simd_avail {
        for &(size, _) in sizes {
            for kind in ["f32", "i8"] {
                let (Some(s), Some(v)) = (find(kind, size, "scalar", 1),
                                          find(kind, size, "simd", 1))
                else {
                    continue;
                };
                let mut m = BTreeMap::new();
                m.insert("kind".to_string(), Json::Str(kind.into()));
                m.insert("size".to_string(), Json::Num(size as f64));
                m.insert("scalar_gflops".to_string(), Json::Num(s));
                m.insert("simd_gflops".to_string(), Json::Num(v));
                m.insert("speedup".to_string(), Json::Num(v / s));
                deltas.push(Json::Obj(m));
            }
        }
    }
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("kernel_gemm".into()));
    root.insert("tier".to_string(), Json::Str(tier.name().into()));
    // distinguishes real runs of this binary from the C-mirror /
    // modeled artifacts a toolchain-less container may have committed
    root.insert("provenance".to_string(), Json::Str("measured".into()));
    root.insert("results".to_string(), Json::Arr(rows));
    root.insert("deltas".to_string(), Json::Arr(deltas));
    let path = "BENCH_kernels.json";
    match std::fs::write(path, Json::Obj(root).to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => hot::warn_!("could not write {path}: {e}"),
    }
}
