//! ABC memory bench — the measured counterpart of the paper's Fig 2 /
//! Table 7 activation-memory story, at ctx granularity.
//!
//! Trains FP32 vs HOT (no ABC) vs HOT+ABC (INT8) vs HOT+ABC (INT4
//! nibbles) in SPLIT mode, where every saved-for-backward tensor
//! crosses the backend boundary into the byte-accounted `CtxStore`, and
//! records live/peak ctx bytes + the metadata-derived compression
//! ratio. Each measured peak is cross-checked against the analytic
//! `costmodel::native_ctx_bytes` prediction (tolerance 15%; the model
//! mirrors the ctx schema, so the two should agree exactly). Emits
//! `BENCH_memory.json` and self-validates:
//!
//!   * HOT+ABC peak ctx < 0.5x FP32 on the `base` preset (CI smoke gate)
//!   * best HOT+ABC peak <= 0.35x FP32 on an LM preset (paper's "up to
//!     75%" activation claim, exceeded at ctx granularity because the
//!     custom backward also packs the attention/GELU/CE residuals)
//!   * split-mode loss decreases with packed ctx enabled (when the
//!     step budget is large enough to read a trend)

#[path = "common/mod.rs"]
mod common;

use std::collections::BTreeMap;
use std::sync::Arc;

use hot::backend::native::layers::BackwardCfg;
use hot::backend::native::presets;
use hot::backend::Executor;
use hot::config::RunConfig;
use hot::coordinator::{Mode, Trainer};
use hot::costmodel::native_ctx_bytes;
use hot::util::json::Json;
use hot::util::timer::Table;

struct Row {
    preset: String,
    method: &'static str,
    variant: &'static str,
    peak_bytes: u64,
    predicted_bytes: u64,
    compression: f64,
    first_loss: f32,
    last_loss: f32,
}

fn bench_one(rt: Arc<dyn Executor>, preset: &str, variant: &str,
             batch: usize, steps: usize) -> (u64, f64, f32, f32) {
    let mut cfg = RunConfig::default();
    cfg.preset = preset.into();
    cfg.variant = variant.into();
    cfg.steps = steps;
    cfg.batch = batch;
    cfg.calib_batches = 0;
    cfg.eval_every = 0;
    cfg.warmup_steps = steps / 4 + 1;
    let mut tr = Trainer::new(rt, cfg).expect("trainer");
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for s in 0..steps {
        let (loss, _) = tr.step_once(Mode::Split).expect("split step");
        if s == 0 {
            first = loss;
        }
        last = loss;
    }
    assert_eq!(tr.state.ctx.stats().live_bytes, 0,
               "ctx leak after training");
    (tr.state.ctx.stats().peak_bytes, tr.state.ctx.compression_ratio(),
     first, last)
}

fn main() {
    let rt = common::executor_or_exit();
    if rt.name() != "native" {
        // ctx byte accounting is native-exact; PJRT artifacts pin their
        // own ctx schema, so the prediction cross-check would not apply
        hot::warn_!("memory bench targets the native backend; got {}",
                    rt.name());
        return;
    }
    let steps = common::steps(6).max(2);
    let methods: [(&'static str, &'static str); 4] = [
        ("fp32", "fp"),
        ("hot_noabc", "hot_noabc"),
        ("hot_abc_int8", "hot"),
        ("hot_abc_int4", "hot_abc4"),
    ];
    let preset_list: [(&str, usize); 3] = [("tiny", 16), ("lm_tiny", 8),
                                           ("base", 4)];
    let mut rows: Vec<Row> = Vec::new();
    let mut t = Table::new(&["preset", "method", "peak ctx B", "vs fp32",
                             "model B", "compression", "loss first->last"]);
    for (preset, batch) in preset_list {
        let mut fp_peak = 0u64;
        for (method, variant) in methods {
            let (peak, compression, first, last) =
                bench_one(rt.clone(), preset, variant, batch, steps);
            let shape = presets::shape_of(preset).expect("preset shape");
            let cfg = BackwardCfg::parse(variant).expect("variant");
            let predicted = native_ctx_bytes(&shape, &cfg, batch);
            let rel = (peak as f64 - predicted as f64).abs()
                / predicted as f64;
            assert!(rel <= 0.15,
                    "{preset}/{method}: measured peak {peak} vs cost-model \
                     {predicted} ({:.1}% off — schema drift?)", rel * 100.0);
            if method == "fp32" {
                fp_peak = peak;
            }
            let frac = peak as f64 / fp_peak as f64;
            t.row(&[preset.into(), method.into(), peak.to_string(),
                    format!("{frac:.3}x"), predicted.to_string(),
                    format!("{compression:.2}x"),
                    format!("{first:.3} -> {last:.3}")]);
            rows.push(Row { preset: preset.into(), method, variant,
                            peak_bytes: peak, predicted_bytes: predicted,
                            compression, first_loss: first,
                            last_loss: last });
        }
    }
    t.print(&format!("split-mode ctx memory, {} steps per cell (native \
                      backend)", steps));

    let peak_of = |preset: &str, method: &str| -> u64 {
        rows.iter()
            .find(|r| r.preset == preset && r.method == method)
            .map(|r| r.peak_bytes)
            .expect("row present")
    };
    // CI smoke gate: ABC must at least halve the base-preset ctx
    let (base_fp, base_abc) = (peak_of("base", "fp32"),
                               peak_of("base", "hot_abc_int8"));
    assert!((base_abc as f64) < 0.5 * base_fp as f64,
            "HOT+ABC peak ctx must be < 0.5x FP32 on base: {base_abc} vs \
             {base_fp}");
    // paper claim, exceeded: <= 0.35x FP32 on an LM preset
    let lm_fp = peak_of("lm_tiny", "fp32");
    let lm_best = peak_of("lm_tiny", "hot_abc_int8")
        .min(peak_of("lm_tiny", "hot_abc_int4"));
    assert!(lm_best as f64 <= 0.35 * lm_fp as f64,
            "HOT+ABC must reach <= 0.35x FP32 ctx on lm_tiny: {lm_best} vs \
             {lm_fp}");
    // no-ABC HOT stores eager-style FP ctx — the savings must come from
    // the packed schema, not from the variant label
    assert_eq!(peak_of("lm_tiny", "hot_noabc"), lm_fp,
               "hot_noabc must store the same eager ctx as fp32");
    // with enough steps the packed-ctx runs must actually learn
    if steps >= 6 {
        for r in rows.iter().filter(|r| r.method.starts_with("hot_abc")) {
            assert!(r.last_loss < r.first_loss,
                    "{}/{}: loss {} -> {} did not decrease with packed ctx",
                    r.preset, r.method, r.first_loss, r.last_loss);
        }
    }

    // schema-v2 provenance envelope (same keys as the harness suites;
    // the result rows stay byte-centric — there is no timing block to
    // compare, so `hot bench --check` does not gate this file)
    let smoke = std::env::var("HOT_BENCH_STEPS").is_ok();
    let host = hot::bench::roofline::host(smoke);
    let mut hostj = BTreeMap::new();
    hostj.insert("fingerprint".to_string(), Json::Str(host.fingerprint));
    hostj.insert("threads_avail".to_string(),
                 Json::Num(host.threads_avail as f64));
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("memory".into()));
    root.insert("schema_version".to_string(),
                Json::Num(hot::bench::SCHEMA_VERSION as f64));
    root.insert("provenance".to_string(),
                Json::Str(hot::bench::PROVENANCE_MEASURED.into()));
    root.insert("provenance_detail".to_string(),
                Json::Str("ctx byte accounting from a real split-mode \
                           training run on the native backend".into()));
    root.insert("git_sha".to_string(),
                Json::Str(hot::bench::record::git_sha()));
    root.insert("host".to_string(), Json::Obj(hostj));
    root.insert("tier".to_string(),
                Json::Str(hot::kernels::active_tier().name().into()));
    root.insert("smoke".to_string(), Json::Bool(smoke));
    root.insert("backend".to_string(), Json::Str(rt.name().into()));
    root.insert("steps".to_string(), Json::Num(steps as f64));
    let jrows: Vec<Json> = rows
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("preset".to_string(), Json::Str(r.preset.clone()));
            m.insert("method".to_string(), Json::Str(r.method.into()));
            m.insert("variant".to_string(), Json::Str(r.variant.into()));
            m.insert("peak_ctx_bytes".to_string(),
                     Json::Num(r.peak_bytes as f64));
            m.insert("costmodel_bytes".to_string(),
                     Json::Num(r.predicted_bytes as f64));
            m.insert("compression_ratio".to_string(),
                     Json::Num(r.compression));
            m.insert("first_loss".to_string(), Json::Num(r.first_loss as f64));
            m.insert("last_loss".to_string(), Json::Num(r.last_loss as f64));
            Json::Obj(m)
        })
        .collect();
    root.insert("results".to_string(), Json::Arr(jrows));
    let path = "BENCH_memory.json";
    std::fs::write(path, Json::Obj(root).to_string()).expect("write json");
    // self-validate: the file must parse back and keep every row
    let text = std::fs::read_to_string(path).expect("read back");
    let parsed = Json::parse(&text).expect("BENCH_memory.json must parse");
    let n = parsed.get("results").and_then(Json::as_arr).map(|a| a.len());
    assert_eq!(n, Some(rows.len()), "json row count");
    println!("wrote {path} ({} rows)", rows.len());
}
