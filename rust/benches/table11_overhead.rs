//! Table 11 / Appendix D — the added FLOPs of HOT's transform + quant +
//! dequant pipeline vs vanilla BP, per layer.
//! Paper example: 'stages.3.fc2' (49, 448, 1792) — vanilla 137.3 MFlops,
//! HOT overhead ~11.5 MFlops (<10%); overhead negligible when
//! log n << dims.

#[path = "common/mod.rs"]
mod common;

use hot::costmodel::zoo::{table6_layers, Layer};
use hot::costmodel::{overhead_flops, total_flops, Method};
use hot::util::timer::Table;

fn main() {
    common::init();
    let mut t = Table::new(&["layer", "(L,O,I)", "vanilla MF", "HOT ovh MF",
                             "ovh %", "HOT total MF"]);
    let mut rows: Vec<(String, Layer)> = table6_layers();
    rows.push(("EfficientFormer-L1".into(),
               Layer::new("stages.3.fc2", 49, 448, 1792)));
    for (_, l) in &rows {
        let van = total_flops(l, Method::Fp32) as f64 / 1e6;
        let ovh = overhead_flops(l, Method::Hot { rank: 8 }) as f64 / 1e6;
        let tot = total_flops(l, Method::Hot { rank: 8 }) as f64 / 1e6;
        t.row(&[l.name.clone(), format!("({},{},{})", l.l, l.o, l.i),
                format!("{van:.1}"), format!("{ovh:.1}"),
                format!("{:.1}%", 100.0 * ovh / van), format!("{tot:.1}")]);
    }
    t.print("Table 11 — HOT per-layer FLOP overhead (MFlops)");

    // Appendix D's example layer: overhead in the paper's band
    let fc2 = Layer::new("stages.3.fc2", 49, 448, 1792);
    let van = total_flops(&fc2, Method::Fp32) as f64 / 1e6;
    let ovh = overhead_flops(&fc2, Method::Hot { rank: 8 }) as f64 / 1e6;
    println!("\nAppendix-D layer: vanilla {van:.1} MF (paper 137.3), \
              overhead {ovh:.1} MF (paper ~11.5)");
    assert!(ovh / van < 0.15, "overhead must be 'negligible': {}", ovh / van);

    // overhead fraction shrinks as dims grow (log n fixed)
    let small = Layer::new("s", 64, 64, 64);
    let big = Layer::new("b", 1024, 1024, 1024);
    let f_small = overhead_flops(&small, Method::Hot { rank: 8 }) as f64
        / total_flops(&small, Method::Fp32) as f64;
    let f_big = overhead_flops(&big, Method::Hot { rank: 8 }) as f64
        / total_flops(&big, Method::Fp32) as f64;
    assert!(f_big < f_small);
    println!("overhead fraction: {:.1}% (64³) -> {:.2}% (1024³)",
             100.0 * f_small, 100.0 * f_big);
    println!("SHAPE HOLDS");
}
