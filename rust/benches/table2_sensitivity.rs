//! Table 2 — optimization-sensitivity analysis: which technique may be
//! applied to which backward path. Pre-trains the tiny model from
//! scratch per configuration.
//!
//! Paper's ordering (ResNet50/CIFAR100):
//!   g_x: HT+4bit (76.16) ≈ FP >> 4bit-noHT (73.4) > ext-HLA (72.01)
//!        >> int-HLA (51.10 — catastrophic)
//!   g_w: int-HLA (76.29) ≈ FP >> HT+4bit (72.43)

#[path = "common/mod.rs"]
mod common;

use hot::util::timer::Table;

const NOISE: f64 = 6.0; // hard-mode task (FP ~0.75 at tiny scale)

fn main() {
    let rt = common::executor_or_exit();
    let n = common::steps(120);
    let rows: &[(&str, &str, &str, f64)] = &[
        // (variant, gx label, gw label, paper acc)
        ("fp", "FP", "FP", 76.46),
        ("gw_hq4", "FP", "HT + 4-bit Q", 72.43),
        ("gw_hla", "FP", "Internal-HLA", 76.29),
        ("gw_hot", "FP", "HLA + INT8 (HOT)", -1.0),
        ("gx_q4", "4-bit Q", "FP", 73.40),
        ("gx_hq4", "HT + 4-bit Q", "FP", 76.16),
        ("gx_ext_hla", "External-HLA", "FP", 72.01),
        ("gx_int_hla", "Internal-HLA", "FP", 51.10),
    ];
    let mut t = Table::new(&["g_x path", "g_w path", "acc (ours)",
                             "acc (paper)"]);
    let mut accs = std::collections::BTreeMap::new();
    for (variant, gx, gw, paper) in rows {
        let o = common::train_variant_noise(rt.clone(), "tiny", variant, n, 1,
                                            3e-3, NOISE);
        accs.insert(variant.to_string(), o.eval_acc);
        t.row(&[gx.to_string(), gw.to_string(), common::fmt_acc(&o),
                if *paper < 0.0 { "-".into() } else { format!("{paper:.2}") }]);
    }
    t.print(&format!("Table 2 — path sensitivity (tiny pretrain, {n} steps)"));

    let a = |k: &str| accs[k];
    println!("\ng_x: HQ4 {:.3} vs int-HLA {:.3} (paper: 76.16 vs 51.10)",
             a("gx_hq4"), a("gx_int_hla"));
    println!("g_w: HLA {:.3} vs HQ4 {:.3} (paper: 76.29 vs 72.43)",
             a("gw_hla"), a("gw_hq4"));
    // Stability: every path config must train without NaN at this scale.
    for (k, v) in &accs {
        assert!(v.is_finite(), "{k} diverged");
    }
    // Scale caveat (recorded in EXPERIMENTS.md): at laptop scale the
    // transformer's residual stream masks per-path gradient corruption,
    // so end-task accuracy compresses across configs. The paper's
    // catastrophic orderings ARE reproduced at the gradient level:
    //   python/tests/test_model.py::test_gx_int_hla_worse_than_hot
    //   python/tests/test_hla_matmul.py::test_hla_on_gw_beats_quant_on_gw
    // both assert the Table-2 mechanism on real model gradients.
    println!("\nall configs stable; mechanism-level ordering verified in \
              pytest (see bench source)");
    println!("SHAPE HOLDS (stability + gradient-level ordering)");
}
