//! Table 6 — per-layer backward latency (μs) on the simulated RTX 3090.
//! Paper: HOT 1.6-3.3x vs FP per layer, ~2.6x avg on ViT-B, beating
//! LBP-WHT throughout.

#[path = "common/mod.rs"]
mod common;

use hot::costmodel::zoo::{table6_layers, vit_b, Layer};
use hot::costmodel::Method;
use hot::latsim::{avg_speedup, total_us, RTX_3090};
use hot::util::timer::Table;

fn main() {
    common::init();
    // the paper's measured values for reference columns
    let paper: &[(&str, f64, f64, f64)] = &[
        ("layer1.conv1", 115.0, 106.0, 62.0),
        ("layer1.conv2", 134.0, 117.0, 59.0),
        ("layer2.conv1", 117.0, 99.0, 67.0),
        ("layer2.conv2", 124.0, 81.0, 60.0),
        ("layer3.conv2", 114.0, 85.0, 64.0),
        ("layer4.conv2", 137.0, 102.0, 72.0),
        ("qkv", 182.0, 110.0, 70.0),
        ("proj", 122.0, 108.0, 71.0),
        ("fc1", 226.0, 120.0, 73.0),
        ("fc2", 233.0, 112.0, 72.0),
        ("stages.0.fc1", 125.0, 123.0, 63.0),
        ("stages.1.fc1", 129.0, 108.0, 68.0),
        ("stages.2.fc1", 126.0, 102.0, 66.0),
        ("stages.3.qkv", 128.0, 105.0, 62.0),
        ("stages.3.proj", 111.0, 105.0, 69.0),
        ("stages.3.fc1", 146.0, 110.0, 66.0),
    ];

    let g = RTX_3090;
    let mut t = Table::new(&["layer", "(L,O,I)", "FP sim/paper", "LBP sim/paper",
                             "HOT sim/paper", "speedup sim/paper"]);
    for ((model, l), (pname, pfp, plbp, phot)) in
        table6_layers().iter().zip(paper)
    {
        assert_eq!(&l.name, pname);
        let fp = total_us(&g, l, Method::Fp32);
        let lbp = total_us(&g, l, Method::LbpWht { rank: 8 });
        let hotl = total_us(&g, l, Method::Hot { rank: 8 });
        t.row(&[format!("{model}/{}", l.name),
                format!("({},{},{})", l.l, l.o, l.i),
                format!("{fp:.0}/{pfp:.0}"),
                format!("{lbp:.0}/{plbp:.0}"),
                format!("{hotl:.0}/{phot:.0}"),
                format!("{:.1}x/{:.1}x", fp / hotl, pfp / phot)]);
        assert!(hotl < fp, "{}: HOT must beat FP", l.name);
    }
    t.print("Table 6 — simulated vs paper backward latency (μs)");

    let vit_layers: Vec<Layer> =
        vit_b().layers.into_iter().filter(|l| l.l > 1).collect();
    let s = avg_speedup(&g, &vit_layers, Method::Hot { rank: 8 });
    println!("\nViT-B average HOT speedup: {s:.2}x (paper: 2.6x)");
    assert!(s > 1.8 && s < 3.6, "avg speedup out of band: {s}");
    println!("SHAPE HOLDS (HOT wins every layer; avg in band)");
}
