//! Table 7 — incremental ablation of HOT's components: base HOT, +ABC,
//! +ABC+LQS. Memory from the cost model (as in the paper: "Memory
//! represents theoretical calculations"), acceleration from the latency
//! simulator, accuracy from real (tiny-scale) training.
//!
//! Paper: ABC cuts memory 17.48 -> 3.8 GB at equal accuracy; LQS lifts
//! acceleration 2.3x -> 2.6x at -0.2% accuracy.

#[path = "common/mod.rs"]
mod common;

use hot::config::RunConfig;
use hot::coordinator::{Mode, Trainer};
use hot::costmodel::zoo::{vit_b, Layer};
use hot::costmodel::{breakdown, MemMethod, Method};
use hot::latsim::{avg_speedup, RTX_3090};
use hot::util::timer::Table;

fn train_acc(rt: std::sync::Arc<dyn hot::backend::Executor>, lqs: bool,
             n: usize) -> f32 {
    let mut cfg = RunConfig::default();
    cfg.preset = "tiny".into();
    cfg.variant = "hot".into();
    cfg.steps = n;
    cfg.lr = 3e-3;
    cfg.warmup_steps = n / 10 + 1;
    cfg.calib_batches = if lqs { 2 } else { 0 };
    cfg.eval_every = 0;
    let mut tr = Trainer::new(rt, cfg).expect("trainer");
    tr.calibrate().expect("calib");
    for _ in 0..n {
        tr.step_once(Mode::Fused).expect("step");
    }
    tr.eval(4).expect("eval").1
}

fn main() {
    let rt = common::executor_or_exit();
    let n = common::steps(100);
    let spec = vit_b();
    let vit_layers: Vec<Layer> =
        spec.layers.iter().filter(|l| l.l > 1).cloned().collect();

    // memory: ViT-B @ batch 128 as in the appendix experiment
    let gb = |m: MemMethod| breakdown(&spec, 128, m).gb();
    let mem_noabc = gb(MemMethod::Hot { rank: 8, abc: false });
    let mem_abc = gb(MemMethod::Hot { rank: 8, abc: true });

    // acceleration: LQS's gain in the paper comes from keeping cheap
    // per-tensor scales where tolerable; model it as the per-tensor
    // pipeline vs a conservatively all-per-token pipeline (per-token
    // dequant of the contracted dim costs an extra FP pass on g_y)
    let acc_base = avg_speedup(&RTX_3090, &vit_layers, Method::Hot { rank: 8 });
    let acc_lqs = acc_base; // per-tensor wherever possible == base pipeline
    let acc_all_token = {
        // surcharge: per-token g_w path runs its GEMM in FP16 instead of
        // INT8 (scales on the contracted dim cannot factor out)
        let mut s = 0.0;
        for l in &vit_layers {
            let fp = hot::latsim::total_us(&RTX_3090, l, Method::Fp32);
            let hot_us = hot::latsim::total_us(&RTX_3090, l,
                                               Method::Hot { rank: 8 });
            let lbp_gw = hot::latsim::total_us(&RTX_3090, l,
                                               Method::LbpWht { rank: 8 });
            // per-token penalty ~ the fp16-gw cost difference
            s += fp / (hot_us + 0.25 * lbp_gw);
        }
        s / vit_layers.len() as f64
    };

    let acc_no_lqs = train_acc(rt.clone(), false, n);
    let acc_with_lqs = train_acc(rt, true, n);

    let mut t = Table::new(&["config", "memory GB (ViT-B b128)",
                             "accel (sim)", "accuracy (tiny)"]);
    t.row(&["HOT (no ABC, all per-token)".into(), format!("{mem_noabc:.2}"),
            format!("{acc_all_token:.1}x"), format!("{acc_no_lqs:.3}")]);
    t.row(&["HOT + ABC".into(), format!("{mem_abc:.2}"),
            format!("{acc_all_token:.1}x"), format!("{acc_no_lqs:.3}")]);
    t.row(&["HOT + ABC + LQS".into(), format!("{mem_abc:.2}"),
            format!("{acc_lqs:.1}x"), format!("{acc_with_lqs:.3}")]);
    t.print(&format!("Table 7 — incremental ablation ({n} steps)"));

    println!("\npaper: 17.48 -> 3.8 GB (-79%), 2.3x -> 2.6x, 93.2 -> 92.99");
    println!("ours : {:.2} -> {:.2} GB (-{:.0}%), {:.1}x -> {:.1}x, \
              {:.3} -> {:.3}",
             mem_noabc, mem_abc, 100.0 * (1.0 - mem_abc / mem_noabc),
             acc_all_token, acc_lqs, acc_no_lqs, acc_with_lqs);
    assert!(mem_abc < mem_noabc * 0.35, "ABC must cut memory ~4x+");
    assert!(acc_lqs > acc_all_token, "LQS must improve acceleration");
    assert!((acc_with_lqs - acc_no_lqs).abs() < 0.15,
            "LQS must not change accuracy materially");
    println!("SHAPE HOLDS");
}
