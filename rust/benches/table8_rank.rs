//! Table 8 — HLA low-pass rank ablation (r ∈ {16, 8, 4, 2, 1}).
//! Paper (EfficientFormer-L1 / CIFAR100 pretrain): accuracy plateaus at
//! r=8 (76.25 vs 76.35 full-rank) and collapses below r=4; backward
//! compute shrinks with r.

#[path = "common/mod.rs"]
mod common;

use hot::backend::Executor;
use hot::costmodel::zoo::efficientformer_l1;
use hot::costmodel::{model_bops, Method};
use hot::util::timer::Table;

fn main() {
    let rt = common::executor_or_exit();
    let n = common::steps(120);
    let spec = efficientformer_l1();
    let paper: &[(usize, f64, f64)] = &[
        (16, 1647.48, 76.35),
        (8, 1383.54, 76.25),
        (4, 1251.56, 73.09),
        (2, 1185.58, 68.46),
        (1, 1152.59, 47.28),
    ];
    let mut t = Table::new(&["r", "Gbops (ours)", "Gbops (paper)",
                             "acc (ours)", "acc (paper)"]);
    let mut accs = Vec::new();
    for (r, p_cost, p_acc) in paper {
        let key = if *r == 8 { "train_hot_tiny".to_string() }
                  else { format!("train_hot_r{r}_tiny") };
        assert!(rt.supports(&key), "missing {key}");
        let variant_steps = common::train_variant_with_key_noise(
            rt.clone(), "tiny", &key, n, 5, 3e-3, 6.0);
        let bops = model_bops(&spec.layers, Method::Hot { rank: *r }) as f64
            / 1e9;
        accs.push((*r, variant_steps.eval_acc));
        t.row(&[r.to_string(), format!("{bops:.0}"), format!("{p_cost:.0}"),
                common::fmt_acc(&variant_steps), format!("{p_acc:.2}")]);
    }
    t.print(&format!("Table 8 — HLA rank ablation (tiny pretrain, {n} steps)"));

    // shape: cost strictly monotone in r; all ranks train stably.
    let cost = |r: usize| model_bops(&spec.layers, Method::Hot { rank: r });
    assert!(cost(1) < cost(4) && cost(4) < cost(8) && cost(8) < cost(16));
    let acc8 = accs.iter().find(|(r, _)| *r == 8).unwrap().1;
    let acc1 = accs.iter().find(|(r, _)| *r == 1).unwrap().1;
    println!("\nacc r=8 {acc8:.3} vs r=1 {acc1:.3} (paper: 76.25 vs 47.28)");
    for (r, a) in &accs {
        assert!(a.is_finite(), "r={r} diverged");
    }
    assert!(acc8 + 0.05 >= acc1,
            "higher rank must never lose materially to rank 1");
    // Scale caveat (EXPERIMENTS.md): the paper's rank-1 accuracy collapse
    // needs 200-epoch CIFAR100 training; at laptop scale the residual
    // stream compresses end-task differences. The rank-error monotonicity
    // that drives it is asserted on real tensors in
    // python/tests/test_hla_matmul.py::test_rank_monotonicity and
    // rust hadamard::tests::prop_hla_error_monotone_in_rank.
    println!("SHAPE HOLDS (cost monotone; stability; error-monotonicity \
              in unit tests)");
}
