//! Table 9 — where may HOT be applied inside a LoRA fine-tune?
//! Configurations: HOT on {frozen, decomposed} weight paths.
//!
//! Paper: HOT-on-frozen-only wins (92.51 vs 92.61 exact LoRA); applying
//! HOT to the decomposed (adapter) path collapses accuracy (57.96 /
//! 58.68).

#[path = "common/mod.rs"]
mod common;

use hot::config::RunConfig;
use hot::coordinator::LoraTrainer;
use hot::util::timer::Table;

fn run(rt: std::sync::Arc<dyn hot::backend::Executor>, key: &str, n: usize)
       -> (f32, bool) {
    let mut cfg = RunConfig::default();
    cfg.preset = "small".into();
    cfg.steps = n;
    cfg.lr = 2e-3;
    cfg.warmup_steps = n / 10 + 1;
    let mut tr = LoraTrainer::new(rt, cfg, key).expect("lora trainer");
    let mut diverged = false;
    for _ in 0..n {
        match tr.step_once() {
            Ok((l, _)) if l.is_finite() => {}
            _ => {
                diverged = true;
                break;
            }
        }
    }
    (tr.metrics.smoothed_loss(8).unwrap_or(f32::NAN), diverged)
}

fn main() {
    let rt = common::executor_or_exit();
    let n = common::steps(80);
    let rows: &[(&str, &str, &str, f64)] = &[
        ("lora_fp_small", "x", "x", 92.61),
        ("lora_hotdec_small", "x", "v", 57.96),
        ("lora_hotfrozen_small", "v", "x", 92.51),
        ("lora_hotboth_small", "v", "v", 58.68),
    ];
    let mut t = Table::new(&["HOT on frozen", "HOT on decomposed",
                             "final loss (ours)", "acc (paper)"]);
    let mut losses = std::collections::BTreeMap::new();
    for (key, hf, hdec, paper) in rows {
        let (loss, diverged) = run(rt.clone(), key, n);
        losses.insert(key.to_string(), loss);
        t.row(&[hf.to_string(), hdec.to_string(),
                if diverged { "NaN".into() } else { format!("{loss:.4}") },
                format!("{paper:.2}")]);
    }
    t.print(&format!("Table 9 — HOT x LoRA weight-type ablation ({n} steps)"));

    let frozen = losses["lora_hotfrozen_small"];
    let dec = losses["lora_hotdec_small"];
    let fp = losses["lora_fp_small"];
    println!("\nfrozen-only {frozen:.4} vs decomposed {dec:.4} vs exact \
              {fp:.4}");
    for (k, l) in &losses {
        assert!(l.is_finite(), "{k} diverged");
    }
    assert!(frozen < fp * 1.5 + 0.3,
            "HOT on frozen must stay near exact LoRA");
    // Scale caveat (EXPERIMENTS.md): the paper's decomposed-path collapse
    // (92.51 -> 57.96) emerges over 50-epoch CIFAR100 fine-tunes; at this
    // scale all configs fit the task and differences sit in the 3rd
    // decimal. The mechanism — quantized adapter gradients corrupt the
    // A/B update direction — is exercised (hot_decomposed runs the
    // HLA+INT8 adapter path) and its gradients verified in
    // python/tests/test_lora.py.
    println!("SHAPE HOLDS (stability; frozen-only ~= exact LoRA)");
}
