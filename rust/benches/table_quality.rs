//! Tables 3, 4, 5, 10 — training-quality comparison across methods,
//! architectures and task families (vision ViT, MLP/CNN stand-in, causal
//! LM), at synthetic laptop scale.
//!
//! Paper shape: HOT tracks FP within ~1%, beats LBP-WHT almost
//! everywhere, and never NaNs; LUQ/plain-INT4 degrade or fail on the
//! harder settings.

#[path = "common/mod.rs"]
mod common;

use hot::backend::Executor;
use hot::util::timer::Table;

fn main() {
    let rt = common::executor_or_exit();
    let n = common::steps(100);
    let variants = ["fp", "hot", "lbp", "luq", "int4"];

    // (table analog, preset, lr, has int4 artifacts)
    let families: &[(&str, &str, f64, bool)] = &[
        ("Table 3/5/10 — ViT (vision)", "small", 1e-3, true),
        ("Table 3/10 — MLP (conv stand-in)", "mlp_small", 1e-3, true),
        ("Table 4 — causal LM", "lm_tiny", 3e-3, false),
    ];

    let mut summary = Vec::new();
    for (title, preset, lr, with_int4) in families {
        let mut t = Table::new(&["method", "final train loss", "eval acc",
                                 "steps/s"]);
        let mut fp_loss = f32::NAN;
        let mut hot_loss = f32::NAN;
        let mut lbp_loss = f32::NAN;
        for v in variants {
            if v == "int4" && !with_int4 {
                continue;
            }
            let key = format!("train_{v}_{preset}");
            if !rt.supports(&key) {
                continue;
            }
            let o = common::train_variant(rt.clone(), preset, v, n, 3, *lr);
            match v {
                "fp" => fp_loss = o.final_loss,
                "hot" => hot_loss = o.final_loss,
                "lbp" => lbp_loss = o.final_loss,
                _ => {}
            }
            t.row(&[v.to_string(), format!("{:.4}", o.final_loss),
                    common::fmt_acc(&o), format!("{:.2}", o.steps_per_s)]);
        }
        t.print(&format!("{title} ({n} steps)"));
        summary.push((title.to_string(), fp_loss, hot_loss, lbp_loss));
    }

    println!();
    for (title, fp, hotl, lbp) in &summary {
        println!("{title}: FP {fp:.3}  HOT {hotl:.3}  LBP {lbp:.3}");
        assert!(hotl.is_finite(), "HOT must never NaN (paper: only HOT \
                 is stable everywhere)");
        // HOT within a modest band of FP; not catastrophically worse
        assert!(*hotl < fp * 1.6 + 0.35,
                "{title}: HOT {hotl} too far from FP {fp}");
    }
    println!("SHAPE HOLDS (HOT stable + near-FP on all families)");
}
