//! Execution backends — the `Executor` trait abstracts "run a
//! train/fwd/bwd/opt step" so the coordinator, benches and tests are
//! agnostic to *where* the math happens:
//!
//!   * `NativeBackend` (default): pure-rust forward/backward/optimizer
//!     built on `tensor`/`hadamard`/`quant` — self-contained, no
//!     artifacts, no PJRT. This is what `cargo test` exercises.
//!   * `runtime::Runtime` (behind the non-default `pjrt` feature): the
//!     original AOT-artifact path — HLO text compiled once through the
//!     PJRT CPU client, executed many times.
//!
//! Both speak the same "artifact key" naming scheme
//! (`train_{variant}_{preset}`, `fwd_…`, `bwd_…`, `grad_…`,
//! `opt_{preset}`, `eval_{preset}`, `infer_{preset}`, `calib_{preset}`,
//! `lora_{tag}_{preset}`, `kernel_*_demo`) so run configs, benches and
//! checkpoints are portable across backends. See DESIGN.md §Backends for
//! the execution matrix.

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod state;

use std::sync::Arc;

use anyhow::{bail, Result};

pub use native::NativeBackend;
pub use state::{AdapterSet, ParamId, TrainState, WeightStore};

use crate::runtime::manifest::{CtxSpec, Preset, TensorSpec};
use crate::runtime::value::Value;

/// Output of a split-mode forward: metrics + the saved-for-backward ctx
/// tensors (HOT+ABC entries arrive HLA+INT8 compressed) and their specs
/// for the `CtxStore`'s byte accounting.
#[derive(Debug)]
pub struct ForwardOut {
    pub loss: f32,
    pub acc: f32,
    pub ctx: Vec<Value>,
    pub ctx_specs: Vec<CtxSpec>,
}

/// Output of a gradient-only step (accumulation mode).
#[derive(Debug)]
pub struct GradOut {
    pub grads: Vec<Value>,
    pub loss: f32,
    pub acc: f32,
}

/// Static description of a LoRA fine-tuning step's trainable set.
#[derive(Debug, Clone)]
pub struct LoraMeta {
    pub preset: String,
    pub trainable: Vec<TensorSpec>,
    pub batch: Option<usize>,
}

/// One execution backend. Model state arrives typed: frozen base
/// weights as a `&WeightStore` (mutable only for the opt-applying
/// steps), training-only state (AdamW moments, ctx store) as a
/// `&mut TrainState`, per-tenant LoRA overlays as an `&mut AdapterSet`.
/// Remaining tensor traffic (batches, ctx, grads) uses `Value`;
/// parameter order is always the preset's manifest order (sorted
/// names).
///
/// Deliberately NOT `Send`/`Sync`: real PJRT clients hold `Rc`
/// internals, so executors are single-threaded by contract (the
/// coordinator never shares one across threads).
pub trait Executor {
    /// Short backend id: "native" or "pjrt".
    fn name(&self) -> &'static str;

    /// Human-readable summary for `hot info`.
    fn describe(&self) -> String;

    fn preset_names(&self) -> Vec<String>;

    fn preset(&self, name: &str) -> Result<Preset>;

    /// Initial parameter values for a preset (deterministic per backend).
    fn init_params(&self, preset: &str) -> Result<Vec<Value>>;

    /// Initial parameters moved into an owned `WeightStore` (no extra
    /// copy beyond the one into the `Arc` slabs).
    fn init_store(&self, preset: &str) -> Result<WeightStore> {
        let p = self.preset(preset)?;
        WeightStore::from_values(p.params, self.init_params(preset)?)
    }

    /// Batch size used when nothing pins it.
    fn default_batch(&self) -> usize;

    /// Whether this backend can run `key`.
    fn supports(&self, key: &str) -> bool;

    /// Batch size pinned by a compiled artifact (PJRT graphs are
    /// shape-static). `None` means the caller picks (native backend).
    fn key_batch(&self, key: &str) -> Option<usize>;

    /// Fused step: forward + backward + AdamW in one call. Weights and
    /// moments update in place; returns (loss, acc).
    #[allow(clippy::too_many_arguments)]
    fn train_step(&self, key: &str, weights: &mut WeightStore,
                  state: &mut TrainState, step: f32, lr: f32,
                  lqs_mask: &[f32], x: &Value, y: &Value)
                  -> Result<(f32, f32)>;

    /// Split-mode forward: emits the saved ctx instead of applying it.
    fn forward_step(&self, key: &str, weights: &WeightStore,
                    lqs_mask: &[f32], x: &Value, y: &Value)
                    -> Result<ForwardOut>;

    /// Split-mode backward: consumes the ctx, returns grads (param order).
    fn backward_step(&self, key: &str, weights: &WeightStore,
                     lqs_mask: &[f32], x: &Value, ctx: Vec<Value>)
                     -> Result<Vec<Value>>;

    /// Gradient-only step for microbatch accumulation.
    fn grad_step(&self, key: &str, weights: &WeightStore, lqs_mask: &[f32],
                 x: &Value, y: &Value) -> Result<GradOut>;

    /// AdamW over the store's slabs + the state's moments, in place.
    fn opt_step(&self, key: &str, weights: &mut WeightStore,
                grads: &[Value], state: &mut TrainState, step: f32,
                lr: f32) -> Result<()>;

    /// FP forward over an eval batch: (loss, acc). Routes through the
    /// inference walk — no backward ctx is built or quantized.
    fn eval_step(&self, key: &str, weights: &WeightStore, x: &Value,
                 y: &Value) -> Result<(f32, f32)>;

    /// Inference-only forward: batched logits from frozen weights, no
    /// `TrainState`, no ctx writes, no quant-for-backward epilogues.
    /// Key grammar: `infer_{preset}`. Backends without an inference
    /// path keep the default and report unsupported.
    fn infer(&self, key: &str, weights: &WeightStore, x: &Value)
             -> Result<Value> {
        let _ = (weights, x);
        bail!("backend {:?} has no inference path for {key:?}", self.name())
    }

    /// Degraded inference for serving under sustained overload: the
    /// same walk as [`infer`] but with the GEMM weights through the
    /// INT8 kernel tiers (per-tensor min-max scales, quantized once
    /// per frozen store and cached by the backend). Logits are
    /// approximate but deterministic — the rung of the serve
    /// degradation ladder between full precision and load shedding.
    ///
    /// [`infer`]: Executor::infer
    fn infer_degraded(&self, key: &str, weights: &WeightStore, x: &Value)
                      -> Result<Value> {
        let _ = (weights, x);
        bail!("backend {:?} has no degraded inference path for {key:?}",
              self.name())
    }

    /// LQS calibration: the 7 per-qlinear diagnostic vectors (model
    /// order) — mse_tensor, mse_token, outlier, gx_err_hq, gx_err_hla,
    /// gw_err_hq, gw_err_hla.
    fn calib_step(&self, key: &str, weights: &WeightStore, x: &Value,
                  y: &Value) -> Result<Vec<Vec<f32>>>;

    /// Trainable-set description for a LoRA step key.
    fn lora_meta(&self, key: &str) -> Result<LoraMeta>;

    /// LoRA fused step: the adapter overlay and moments update in
    /// place, the shared base stays frozen; returns (loss, acc).
    #[allow(clippy::too_many_arguments)]
    fn lora_step(&self, key: &str, adapters: &mut AdapterSet,
                 state: &mut TrainState, step: f32, lr: f32,
                 lqs_mask: &[f32], x: &Value, y: &Value)
                 -> Result<(f32, f32)>;

    /// Raw execution for kernel demos / debug tooling. PJRT runs any
    /// artifact; native mirrors the `kernel_*_demo` entries.
    fn execute_raw(&self, key: &str, args: &[Value]) -> Result<Vec<Value>>;
}

// ---------------------------------------------------------------------------
// Key grammar shared by both backends
// ---------------------------------------------------------------------------

/// Step-key kinds; `tag` carries the backward-variant string where the
/// kind has one (e.g. "hot", "hot_r4", "fp", "hotfrozen").
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StepKey {
    Train { tag: String, preset: String },
    Fwd { tag: String, preset: String },
    Bwd { tag: String, preset: String },
    Grad { tag: String, preset: String },
    Opt { preset: String },
    Eval { preset: String },
    Infer { preset: String },
    Calib { preset: String },
    Lora { tag: String, preset: String },
    Kernel { name: String },
}

impl StepKey {
    /// Parse a key against a list of known preset names (presets may
    /// contain underscores — match the longest suffix).
    pub fn parse(key: &str, presets: &[String]) -> Result<StepKey> {
        if let Some(name) = key.strip_prefix("kernel_") {
            return Ok(StepKey::Kernel { name: name.to_string() });
        }
        let (kind, rest) = match key.split_once('_') {
            Some(p) => p,
            None => bail!("unparseable step key {key:?}"),
        };
        let find_preset = |rest: &str| -> Option<(String, String)> {
            // longest preset suffix wins ("lm_tiny" over "tiny")
            let mut best: Option<&String> = None;
            for p in presets {
                let matches = rest == p.as_str()
                    || rest.ends_with(&format!("_{p}"));
                if matches && best.map(|b| p.len() > b.len()).unwrap_or(true) {
                    best = Some(p);
                }
            }
            best.map(|p| {
                let tag = if rest.len() == p.len() {
                    String::new()
                } else {
                    rest[..rest.len() - p.len() - 1].to_string()
                };
                (tag, p.clone())
            })
        };
        let parsed = find_preset(rest);
        let (tag, preset) = match parsed {
            Some(tp) => tp,
            None => bail!("step key {key:?} names no known preset \
                           (have: {presets:?})"),
        };
        Ok(match kind {
            "train" => StepKey::Train { tag, preset },
            "fwd" => StepKey::Fwd { tag, preset },
            "bwd" => StepKey::Bwd { tag, preset },
            "grad" => StepKey::Grad { tag, preset },
            "opt" => StepKey::Opt { preset },
            "eval" => StepKey::Eval { preset },
            "infer" => StepKey::Infer { preset },
            "calib" => StepKey::Calib { preset },
            "lora" => StepKey::Lora { tag, preset },
            other => bail!("unknown step kind {other:?} in key {key:?}"),
        })
    }
}

// ---------------------------------------------------------------------------
// Factory
// ---------------------------------------------------------------------------

/// `by_name` with an explicit kernel thread budget (0 = one per core).
/// The budget is process-wide: it configures the `kernels` pool every
/// native GEMM/FWHT routes through, so it applies to whichever backend
/// comes back (PJRT manages its own intra-op threads).
pub fn by_name_threaded(backend: &str, artifacts: &str, threads: usize)
                        -> Result<Arc<dyn Executor>> {
    crate::kernels::set_num_threads(threads);
    by_name(backend, artifacts)
}

/// Construct a backend by name: "native", "pjrt", or "auto" (pjrt when
/// compiled in *and* the artifact dir exists; native otherwise).
pub fn by_name(backend: &str, artifacts: &str) -> Result<Arc<dyn Executor>> {
    match backend {
        "native" => Ok(Arc::new(NativeBackend::new())),
        "pjrt" => {
            #[cfg(feature = "pjrt")]
            {
                Ok(Arc::new(crate::runtime::Runtime::new(artifacts)?))
            }
            #[cfg(not(feature = "pjrt"))]
            {
                let _ = artifacts;
                bail!("this binary was built without the `pjrt` feature; \
                       rebuild with `--features pjrt` or use --backend native")
            }
        }
        "auto" => {
            #[cfg(feature = "pjrt")]
            {
                if crate::runtime::manifest::artifacts_available(artifacts) {
                    // a failing PJRT bring-up (e.g. the offline xla stub)
                    // must not take down auto mode — native always works
                    match crate::runtime::Runtime::new(artifacts) {
                        Ok(rt) => return Ok(Arc::new(rt)),
                        Err(e) => crate::warn_!(
                            "auto backend: PJRT unavailable ({e}); \
                             falling back to native"),
                    }
                }
            }
            let _ = artifacts;
            Ok(Arc::new(NativeBackend::new()))
        }
        other => bail!("unknown backend {other:?} (native|pjrt|auto)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn presets() -> Vec<String> {
        ["tiny", "small", "lm_tiny", "mlp_small"]
            .iter()
            .map(|s| s.to_string())
            .collect()
    }

    #[test]
    fn parses_train_keys() {
        let k = StepKey::parse("train_hot_tiny", &presets()).unwrap();
        assert_eq!(k, StepKey::Train { tag: "hot".into(), preset: "tiny".into() });
        let k = StepKey::parse("train_hot_r4_tiny", &presets()).unwrap();
        assert_eq!(k, StepKey::Train { tag: "hot_r4".into(), preset: "tiny".into() });
    }

    #[test]
    fn longest_preset_suffix_wins() {
        let k = StepKey::parse("train_hot_lm_tiny", &presets()).unwrap();
        assert_eq!(k, StepKey::Train { tag: "hot".into(), preset: "lm_tiny".into() });
        let k = StepKey::parse("grad_gx_int_hla_mlp_small", &presets()).unwrap();
        assert_eq!(k, StepKey::Grad { tag: "gx_int_hla".into(),
                                      preset: "mlp_small".into() });
    }

    #[test]
    fn tagless_kinds() {
        assert_eq!(StepKey::parse("opt_tiny", &presets()).unwrap(),
                   StepKey::Opt { preset: "tiny".into() });
        assert_eq!(StepKey::parse("eval_lm_tiny", &presets()).unwrap(),
                   StepKey::Eval { preset: "lm_tiny".into() });
        assert_eq!(StepKey::parse("calib_small", &presets()).unwrap(),
                   StepKey::Calib { preset: "small".into() });
        assert_eq!(StepKey::parse("infer_lm_tiny", &presets()).unwrap(),
                   StepKey::Infer { preset: "lm_tiny".into() });
        assert!(StepKey::parse("infer_nopreset", &presets()).is_err());
    }

    #[test]
    fn lora_and_kernel_keys() {
        assert_eq!(StepKey::parse("lora_hotfrozen_small", &presets()).unwrap(),
                   StepKey::Lora { tag: "hotfrozen".into(), preset: "small".into() });
        assert_eq!(StepKey::parse("kernel_hq_demo", &presets()).unwrap(),
                   StepKey::Kernel { name: "hq_demo".into() });
    }

    #[test]
    fn rejects_unknown() {
        assert!(StepKey::parse("train_hot_nopreset", &presets()).is_err());
        assert!(StepKey::parse("bogus", &presets()).is_err());
        assert!(StepKey::parse("frob_hot_tiny", &presets()).is_err());
    }

    #[test]
    fn factory_native_always_works() {
        let b = by_name("native", "artifacts").unwrap();
        assert_eq!(b.name(), "native");
        assert!(by_name("frobnicate", "artifacts").is_err());
    }
}
