//! Native layer primitives with explicit forward/backward — the rust
//! port of python/compile/layers.py (+ the kernels/ref.py oracles),
//! built on the `hadamard`/`quant` mirrors so both backends share one
//! set of bit-level quantizer semantics.
//!
//! All qlinears operate on flattened (N = B*L, D) row-major slices.
//! Forward is always exact FP32; the `variant` selects how each gradient
//! GEMM is approximated (HQ on the input-gradient path, HLA+INT8 on the
//! weight-gradient path for HOT) and what the saved ctx looks like
//! (HLA+INT8-compressed activations under ABC).

use anyhow::{bail, Result};

use crate::hadamard::lowpass::Criterion;
use crate::hadamard::{block_hla_axis0, block_hla_expand_axis0, BLOCK};
use crate::kernels::{fwht_quant_cols, fwht_quant_rows, gemm_f32_nn,
                     gemm_f32_nt, gemm_f32_tn, gemm_i8_nn_deq,
                     gemm_i8_tn_deq, quant_pack_rows, transpose};
use crate::quant;
use crate::quant::AbcAct;

// ---------------------------------------------------------------------------
// Backward configuration (config.py BackwardConfig)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    Fp,
    Hot,
    Lbp,
    Luq,
    Int4,
    GxHq4,
    GxQ4,
    GxExtHla,
    GxIntHla,
    GwHq4,
    GwHla,
    GwHot,
}

impl Variant {
    /// Base-variant names, longest first so prefix matching is unambiguous
    /// ("gx_int_hla" before "gx_hq4" before implicit separators).
    const NAMES: [(&'static str, Variant); 12] = [
        ("gx_ext_hla", Variant::GxExtHla),
        ("gx_int_hla", Variant::GxIntHla),
        ("gx_hq4", Variant::GxHq4),
        ("gw_hq4", Variant::GwHq4),
        ("gw_hla", Variant::GwHla),
        ("gw_hot", Variant::GwHot),
        ("gx_q4", Variant::GxQ4),
        ("int4", Variant::Int4),
        ("hot", Variant::Hot),
        ("lbp", Variant::Lbp),
        ("luq", Variant::Luq),
        ("fp", Variant::Fp),
    ];
}

#[derive(Debug, Clone, Copy)]
pub struct BackwardCfg {
    pub variant: Variant,
    pub rank: usize,
    pub gx_bits: u8,
    pub gw_bits: u8,
    pub abc: bool,
    /// Storage width of the packed ABC qlinear payload (8 = one byte
    /// per code, 4 = two nibbles per byte). Independent of `gw_bits`,
    /// which quantizes the gradient operand of the g_w GEMM.
    pub abc_bits: u8,
    pub criterion: Criterion,
}

impl Default for BackwardCfg {
    fn default() -> Self {
        BackwardCfg { variant: Variant::Hot, rank: 8, gx_bits: 4, gw_bits: 8,
                      abc: true, abc_bits: 8, criterion: Criterion::Sequency }
    }
}

impl BackwardCfg {
    /// Parse a variant tag like "hot", "hot_r4", "hot_noabc", "gx_int_hla"
    /// (the artifact-key grammar of BackwardConfig.tag()).
    pub fn parse(tag: &str) -> Result<BackwardCfg> {
        let mut best: Option<(&str, Variant)> = None;
        for (name, v) in Variant::NAMES {
            let ok = tag == name || tag.starts_with(&format!("{name}_"));
            if ok && best.map(|(b, _)| name.len() > b.len()).unwrap_or(true) {
                best = Some((name, v));
            }
        }
        let (name, variant) = match best {
            Some(b) => b,
            None => bail!("unknown backward variant tag {tag:?}"),
        };
        let mut cfg = BackwardCfg { variant, ..BackwardCfg::default() };
        if tag.len() > name.len() {
            for part in tag[name.len() + 1..].split('_') {
                if part == "noabc" {
                    cfg.abc = false;
                } else if part == "abc4" {
                    cfg.abc_bits = 4;
                } else if part == "abc8" {
                    cfg.abc_bits = 8;
                } else if part == "pallas" {
                    // pallas-vs-ref kernel routing is an artifact-side
                    // distinction; semantics are identical host-side
                } else if let Some(r) = part.strip_prefix('r') {
                    let r: usize = r.parse()
                        .map_err(|_| anyhow::anyhow!("bad rank in {tag:?}"))?;
                    if !(1..=BLOCK).contains(&r) {
                        bail!("rank {r} outside [1, {BLOCK}] in tag {tag:?}");
                    }
                    cfg.rank = r;
                } else {
                    bail!("unknown variant suffix {part:?} in tag {tag:?}");
                }
            }
        }
        Ok(cfg)
    }

    /// Whether ABC compresses a qlinear's saved activations at this row
    /// count. THE single source of truth for the split-mode wire format:
    /// `qlinear_fwd` (what the forward saves) and `model::ctx_layout`
    /// (what the backward expects) both key off it.
    pub fn compresses(&self, rows: usize) -> bool {
        matches!(self.variant, Variant::Hot | Variant::GwHot)
            && self.abc
            && rows % BLOCK == 0
    }

    /// Whether this variant's custom backward owns the ctx schema and
    /// packs the non-qlinear saved buffers (LN x-hat, attention
    /// internals, GELU input, CE probabilities) into the per-row INT8
    /// storage format, recomputing what it can (GELU's tanh, the CE
    /// one-hot). FP/LBP/LUQ model the paper's eager-mode baselines and
    /// keep every residual raw-FP32 (the asymmetry `costmodel::memory`'s
    /// `eager_extra_bytes` charges).
    pub fn packs_ctx(&self) -> bool {
        matches!(self.variant, Variant::Hot | Variant::GwHot) && self.abc
    }
}

// ---------------------------------------------------------------------------
// Kernel oracles (kernels/ref.py) — GEMMs route through the blocked,
// multi-threaded `crate::kernels` subsystem; the old naive loop nests
// survive only as `kernels::reference` oracles.
// ---------------------------------------------------------------------------

/// HQ matmul: g_x = Q(g_y Hᵀ) · Q(H w) — HT along the contracted O dim,
/// pseudo-stochastic INT quant, int32 accumulation (ref.hq_matmul_ref).
/// FWHT and the min-max scan run as one fused pass per operand, and the
/// dequant scale rides the GEMM's output write. (INT4 values travel in
/// an i8 container here; `kernels::gemm_i4_nn_deq` serves operands that
/// arrive already nibble-packed, e.g. the ABC wire format — packing a
/// freshly quantized tensor just to unpack it would cost an extra pass.)
pub fn hq_matmul(gy: &[f32], n: usize, o: usize, w: &[f32], i: usize,
                 bits: u8) -> Vec<f32> {
    let (q_g, s_g) = fwht_quant_rows(gy, n, o, bits);
    let (q_w, s_w) = fwht_quant_cols(w, o, i, bits);
    gemm_i8_nn_deq(&q_g, &q_w, n, o, i, s_g * s_w)
}

/// ABC's forward-time compression: HLA along N, then the fused per-row
/// quantize → pack epilogue (ref.hla_compress_ref, storage-side). The
/// result is the packed ctx payload itself — (n/16*rank, cols) INT
/// codes two-nibbles-per-byte at 4 bits, one scale per compressed row.
pub fn hla_compress(x: &[f32], n: usize, cols: usize, rank: usize, bits: u8,
                    criterion: Criterion) -> AbcAct {
    let xc = block_hla_axis0(x, n, cols, rank, criterion);
    let nc = n / BLOCK * rank;
    let (data, scales) = quant_pack_rows(&xc, nc, cols, bits);
    let xa = AbcAct { rows: nc, cols, bits, data, scales };
    if crate::obs::enabled() {
        // per-layer quantizer telemetry, attributed to the module name
        // the model walk last set: amax of the compressed activations,
        // saturation incidence against each row's min-max scale, and
        // the dequant round-trip error — the raw signal the LQS report
        // ranks layers by. Runs only under the trace gate (one extra
        // pass over xc), so the untraced hot path is untouched.
        let qmax = quant::qmax(bits) as f32;
        let mut amax = 0.0f32;
        let mut clipped = 0u64;
        for (r, row) in xc.chunks_exact(cols).enumerate() {
            let lim = qmax * xa.scale(r);
            for &v in row {
                let a = v.abs();
                amax = amax.max(a);
                if a >= lim {
                    clipped += 1;
                }
            }
        }
        let err: f64 = xc
            .iter()
            .zip(&xa.dequantize())
            .map(|(&a, &b)| (a - b).abs() as f64)
            .sum();
        crate::obs::record_quant(amax, clipped, err, xc.len() as u64);
    }
    xa
}

/// HOT's g_w = (H-hat g_y)ᵀ · (H-hat x) with the saved x arriving in
/// packed ABC form (ref.hla_matmul_ref). `per_token` selects row scales
/// on the compressed g_y; either way the combined (g row scale · x row
/// scale) dequant folds into the g operand — row scales live on the
/// contracted dim, so they cannot ride a single output scale — and one
/// FP TN GEMM finishes the job.
#[allow(clippy::too_many_arguments)]
pub fn hla_matmul(gy: &[f32], n: usize, o: usize, xa: &AbcAct, rank: usize,
                  bits: u8, per_token: bool, criterion: Criterion)
                  -> Vec<f32> {
    let gc = block_hla_axis0(gy, n, o, rank, criterion);
    let nc = n / BLOCK * rank;
    debug_assert_eq!(xa.rows, nc);
    let i = xa.cols;
    let s_t = if per_token { 0.0 } else { quant::minmax_scale(&gc, bits) };
    let s_k = if per_token {
        quant::minmax_scale_rows(&gc, nc, o, bits)
    } else {
        Vec::new()
    };
    let mut g_deq = vec![0.0f32; nc * o];
    for r in 0..nc {
        let s_g = if per_token { s_k[r] } else { s_t };
        let s = s_g * xa.scale(r);
        for c in 0..o {
            let q = quant::quantize_ps_one(gc[r * o + c], s_g, bits);
            g_deq[r * o + c] = q as f32 * s;
        }
    }
    let xf = xa.unpack_f32();
    gemm_f32_tn(&g_deq, &xf, nc, o, i)
}

/// LBP-WHT's g_x: external HLA on N — H-hatᵀ(H-hat g_y)w (ref.lbp_gx_ref).
pub fn lbp_gx(gy: &[f32], n: usize, o: usize, w: &[f32], i: usize,
              rank: usize) -> Vec<f32> {
    let gc = block_hla_axis0(gy, n, o, rank, Criterion::Sequency);
    let nc = n / BLOCK * rank;
    let out = gemm_f32_nn(&gc, w, nc, o, i);
    block_hla_expand_axis0(&out, nc, i, rank, Criterion::Sequency)
}

/// LBP-WHT's g_w: internal HLA along N, FP arithmetic (ref.lbp_gw_ref).
pub fn lbp_gw(gy: &[f32], n: usize, o: usize, x: &[f32], i: usize,
              rank: usize) -> Vec<f32> {
    let gc = block_hla_axis0(gy, n, o, rank, Criterion::Sequency);
    let xc = block_hla_axis0(x, n, i, rank, Criterion::Sequency);
    let nc = n / BLOCK * rank;
    gemm_f32_tn(&gc, &xc, nc, o, i)
}

/// Fake-quant (quantize -> dequantize) with a per-tensor min-max scale.
pub fn fake_quant(x: &[f32], bits: u8) -> Vec<f32> {
    let s = quant::minmax_scale(x, bits);
    x.iter()
        .map(|&v| quant::quantize_ps_one(v, s, bits) as f32 * s)
        .collect()
}

// ---------------------------------------------------------------------------
// qlinear: y = x @ w.T + b — the paper's object of study
// ---------------------------------------------------------------------------

/// Saved-for-backward state of one qlinear (the paper's CTX entry).
#[derive(Debug, Clone)]
pub struct QlCtx {
    /// raw FP activations (kept by fp/lbp/luq/int4/ablation variants and
    /// by HOT when ABC is off or the layer doesn't tile)
    pub x: Option<Vec<f32>>,
    /// HLA + per-row INT quantized activations in packed storage form
    /// (HOT with ABC)
    pub xq: Option<AbcAct>,
    pub n: usize,
    pub i: usize,
}

/// y = x w.T + b (exact FP32). Public because the inference-only walk
/// (`model::fwd_infer`, the LoRA merged-forward) computes the same
/// activations without building any saved-for-backward ctx — HOT's
/// forward is always exact, so inference and training forwards share
/// this single GEMM + bias epilogue.
pub fn qlinear_y(x: &[f32], n: usize, i: usize, w: &[f32], o: usize,
                 bias: &[f32]) -> Vec<f32> {
    let mut y = gemm_f32_nt(x, w, n, i, o);
    for r in 0..n {
        let row = &mut y[r * o..(r + 1) * o];
        for (v, b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
    y
}

/// y = x w.T + b through the INT8 kernel tier — the serving
/// degradation ladder's reduced-precision forward (DESIGN.md
/// §Serving). `wq_t` is the weight pre-quantized *and* pre-transposed
/// to (i, o): serving weights are frozen, so the quantize+transpose is
/// paid once per store (`model::QuantParams`) while the activation is
/// quantized per-tensor on the fly, exactly the gx_q4_noht recipe
/// below but with the weight half hoisted out of the hot path. Output
/// is approximate (per-tensor min-max scales) and deterministic — the
/// pseudo-stochastic rounding is input-keyed, so a degraded request
/// replayed against the same weights reproduces bit-identically.
pub fn qlinear_y_i8(x: &[f32], n: usize, i: usize, wq_t: &[i8],
                    w_scale: f32, o: usize, bias: &[f32]) -> Vec<f32> {
    let s_x = quant::minmax_scale(x, 8);
    let xq = quant::quantize_ps(x, s_x, 8);
    let mut y = gemm_i8_nn_deq(&xq, wq_t, n, i, o, s_x * w_scale);
    for r in 0..n {
        let row = &mut y[r * o..(r + 1) * o];
        for (v, b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
    y
}

/// Shared forward core: the compress-or-keep ctx decision lives in ONE
/// place; `Cow` carries whether the caller handed over ownership (the
/// uncompressed ctx then keeps the buffer without copying) or only a
/// borrow (only that path pays a `to_vec`). The compressing path never
/// materializes an owned copy either way.
fn qlinear_fwd_cow(x: std::borrow::Cow<'_, [f32]>, n: usize, i: usize,
                   w: &[f32], o: usize, bias: &[f32], cfg: &BackwardCfg)
                   -> (Vec<f32>, QlCtx) {
    let y = qlinear_y(&x, n, i, w, o, bias);
    let ctx = if cfg.compresses(n) {
        let xa = hla_compress(&x, n, i, cfg.rank, cfg.abc_bits,
                              cfg.criterion);
        QlCtx { x: None, xq: Some(xa), n, i }
    } else {
        QlCtx { x: Some(x.into_owned()), xq: None, n, i }
    };
    (y, ctx)
}

/// Forward (always exact FP32) + build the saved ctx. Takes `x` by
/// value: every forward-walk caller hands over an activation it no
/// longer needs, so the uncompressed ctx keeps the buffer itself
/// instead of copying it (the old hot-path `to_vec`), and the ABC path
/// compresses from the moved buffer and drops it.
pub fn qlinear_fwd(x: Vec<f32>, n: usize, i: usize, w: &[f32], o: usize,
                   bias: &[f32], cfg: &BackwardCfg) -> (Vec<f32>, QlCtx) {
    qlinear_fwd_cow(std::borrow::Cow::Owned(x), n, i, w, o, bias, cfg)
}

/// `qlinear_fwd` for callers that only hold a borrow (the LoRA walk's
/// `Value` inputs): the compressing path never materializes an owned
/// copy, and only the uncompressed ctx pays the `to_vec`.
pub fn qlinear_fwd_borrowed(x: &[f32], n: usize, i: usize, w: &[f32],
                            o: usize, bias: &[f32], cfg: &BackwardCfg)
                            -> (Vec<f32>, QlCtx) {
    qlinear_fwd_cow(std::borrow::Cow::Borrowed(x), n, i, w, o, bias, cfg)
}

fn gx_q4_noht(gy: &[f32], n: usize, o: usize, w: &[f32], i: usize,
              bits: u8) -> Vec<f32> {
    let s_g = quant::minmax_scale(gy, bits);
    let s_w = quant::minmax_scale(w, bits);
    let q_g = quant::quantize_ps(gy, s_g, bits);
    let q_w = quant::quantize_ps(w, s_w, bits);
    gemm_i8_nn_deq(&q_g, &q_w, n, o, i, s_g * s_w)
}

fn gx_int_hla(gy: &[f32], n: usize, o: usize, w: &[f32], i: usize,
              rank: usize) -> Vec<f32> {
    // internal HLA over the contracted O dim (Table 2's worst row)
    let gy_t = transpose(gy, n, o); // (o, n)
    let gct = block_hla_axis0(&gy_t, o, n, rank, Criterion::Sequency);
    let oc = o / BLOCK * rank;
    let gc = transpose(&gct, oc, n); // (n, oc)
    let wc = block_hla_axis0(w, o, i, rank, Criterion::Sequency); // (oc, i)
    gemm_f32_nn(&gc, &wc, n, oc, i)
}

fn gw_hot(gy: &[f32], n: usize, o: usize, ctx: &QlCtx, cfg: &BackwardCfg,
          pt_flag: f32) -> Vec<f32> {
    let owned;
    let xa: &AbcAct = match &ctx.xq {
        Some(a) => a,
        None => {
            let x = ctx.x.as_ref().expect("qlinear ctx holds x or xq");
            owned = hla_compress(x, n, ctx.i, cfg.rank, cfg.abc_bits,
                                 cfg.criterion);
            &owned
        }
    };
    hla_matmul(gy, n, o, xa, cfg.rank, cfg.gw_bits, pt_flag > 0.5,
               cfg.criterion)
}

fn gw_hq4(gy: &[f32], n: usize, o: usize, x: &[f32], i: usize) -> Vec<f32> {
    let (q_g, s_g) = fwht_quant_cols(gy, n, o, 4);
    let (q_x, s_x) = fwht_quant_cols(x, n, i, 4);
    gemm_i8_tn_deq(&q_g, &q_x, n, o, i, s_g * s_x)
}

fn luq_pair(gy: &[f32], other: &[f32], bits_other: u8) -> (Vec<f32>, Vec<f32>) {
    let g_q = quant::quantize_luq(gy, 4);
    let s_o = quant::minmax_scale(other, bits_other);
    let o_q: Vec<f32> = other
        .iter()
        .map(|&v| quant::quantize_ps_one(v, s_o, bits_other) as f32 * s_o)
        .collect();
    (g_q, o_q)
}

/// Backward for y = x w.T + b: (g_x, g_w, g_b). g_b is always exact (the
/// paper never quantizes bias gradients). `need_gx = false` skips the
/// input-gradient GEMM (the first layer's g_x is never consumed).
#[allow(clippy::too_many_arguments)]
pub fn qlinear_bwd(gy: &[f32], n: usize, o: usize, w: &[f32], i: usize,
                   ctx: &QlCtx, cfg: &BackwardCfg, pt_flag: f32,
                   need_gx: bool) -> (Option<Vec<f32>>, Vec<f32>, Vec<f32>) {
    use Variant::*;
    debug_assert_eq!(gy.len(), n * o);
    debug_assert_eq!(w.len(), o * i);

    let mut g_b = vec![0.0f32; o];
    for r in 0..n {
        for (c, gb) in g_b.iter_mut().enumerate() {
            *gb += gy[r * o + c];
        }
    }

    let can_o = o % BLOCK == 0;
    let can_n = n % BLOCK == 0;
    let v = cfg.variant;

    // --- g_x (needs w) --------------------------------------------------
    let g_x = if !need_gx {
        None
    } else {
        Some(match v {
            Hot | GxHq4 if !can_o => gemm_f32_nn(gy, w, n, o, i),
            Lbp | GxExtHla if !can_n => gemm_f32_nn(gy, w, n, o, i),
            GxIntHla if !can_o => gemm_f32_nn(gy, w, n, o, i),
            Hot | GxHq4 => hq_matmul(gy, n, o, w, i, cfg.gx_bits),
            GxQ4 => gx_q4_noht(gy, n, o, w, i, cfg.gx_bits),
            Lbp | GxExtHla => lbp_gx(gy, n, o, w, i, cfg.rank),
            GxIntHla => gx_int_hla(gy, n, o, w, i, cfg.rank),
            Luq => {
                let (g_q, w_q) = luq_pair(gy, w, 4);
                gemm_f32_nn(&g_q, &w_q, n, o, i)
            }
            Int4 => gx_q4_noht(gy, n, o, w, i, 4),
            Fp | GwHq4 | GwHla | GwHot => gemm_f32_nn(gy, w, n, o, i),
        })
    };

    // --- g_w (needs saved x / compressed x) -------------------------------
    fn raw_of(ctx: &QlCtx) -> &[f32] {
        ctx.x.as_deref().expect("variant requires raw ctx activations")
    }
    let g_w = match v {
        Hot | GwHot | Lbp | GwHla | GwHq4 if !can_n => {
            gemm_f32_tn(gy, raw_of(ctx), n, o, i)
        }
        Hot | GwHot => gw_hot(gy, n, o, ctx, cfg, pt_flag),
        Lbp | GwHla => lbp_gw(gy, n, o, raw_of(ctx), i, cfg.rank),
        GwHq4 => gw_hq4(gy, n, o, raw_of(ctx), i),
        Luq => {
            let (g_q, x_q) = luq_pair(gy, raw_of(ctx), 4);
            gemm_f32_tn(&g_q, &x_q, n, o, i)
        }
        Int4 => {
            let x = raw_of(ctx);
            let s_g = quant::minmax_scale(gy, 4);
            let s_x = quant::minmax_scale(x, 4);
            let q_g = quant::quantize_ps(gy, s_g, 4);
            let q_x = quant::quantize_ps(x, s_x, 4);
            gemm_i8_tn_deq(&q_g, &q_x, n, o, i, s_g * s_x)
        }
        Fp | GxHq4 | GxQ4 | GxExtHla | GxIntHla => {
            gemm_f32_tn(gy, raw_of(ctx), n, o, i)
        }
    };
    (g_x, g_w, g_b)
}

// ---------------------------------------------------------------------------
// LayerNorm (FP; HOT leaves normalization untouched)
// ---------------------------------------------------------------------------

pub struct LnCtx {
    pub xhat: Vec<f32>, // (rows, d)
    pub rstd: Vec<f32>, // (rows,)
}

pub fn layernorm_fwd(x: &[f32], rows: usize, d: usize, gamma: &[f32],
                     beta: &[f32]) -> (Vec<f32>, LnCtx) {
    let eps = 1e-5f32;
    let mut y = vec![0.0f32; rows * d];
    let mut xhat = vec![0.0f32; rows * d];
    let mut rstd = vec![0.0f32; rows];
    for r in 0..rows {
        let row = &x[r * d..(r + 1) * d];
        let mu: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>()
            / d as f32;
        let rs = 1.0 / (var + eps).sqrt();
        rstd[r] = rs;
        for c in 0..d {
            let xh = (row[c] - mu) * rs;
            xhat[r * d + c] = xh;
            y[r * d + c] = xh * gamma[c] + beta[c];
        }
    }
    (y, LnCtx { xhat, rstd })
}

/// Returns (g_x, g_gamma, g_beta).
pub fn layernorm_bwd(gy: &[f32], rows: usize, d: usize, gamma: &[f32],
                     ctx: &LnCtx) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut g_gamma = vec![0.0f32; d];
    let mut g_beta = vec![0.0f32; d];
    let mut g_x = vec![0.0f32; rows * d];
    for r in 0..rows {
        let gr = &gy[r * d..(r + 1) * d];
        let xh = &ctx.xhat[r * d..(r + 1) * d];
        let mut mean_gh = 0.0f32;
        let mut mean_ghx = 0.0f32;
        for c in 0..d {
            let gh = gr[c] * gamma[c];
            g_gamma[c] += gr[c] * xh[c];
            g_beta[c] += gr[c];
            mean_gh += gh;
            mean_ghx += gh * xh[c];
        }
        mean_gh /= d as f32;
        mean_ghx /= d as f32;
        let rs = ctx.rstd[r];
        for c in 0..d {
            let gh = gr[c] * gamma[c];
            g_x[r * d + c] = (gh - mean_gh - xh[c] * mean_ghx) * rs;
        }
    }
    (g_x, g_gamma, g_beta)
}

// ---------------------------------------------------------------------------
// GELU (tanh approximation, as in ViT/timm)
// ---------------------------------------------------------------------------

const K0: f32 = 0.797_884_56; // sqrt(2/pi)
const K1: f32 = 0.044_715;

pub struct GeluCtx {
    pub x: Vec<f32>,
    pub t: Vec<f32>,
}

/// The tanh factor of the GELU. Pure function of x, so the packed ctx
/// schema drops `t` from storage and rebuilds it here before the
/// backward — bit-identical to the forward's value for the same x.
pub fn gelu_t(x: &[f32]) -> Vec<f32> {
    x.iter().map(|&v| (K0 * (v + K1 * v * v * v)).tanh()).collect()
}

/// Takes `x` by value — the ctx owns the moved pre-activation buffer
/// instead of copying it (the old hot-path `to_vec`).
pub fn gelu_fwd(x: Vec<f32>) -> (Vec<f32>, GeluCtx) {
    let t = gelu_t(&x);
    let y: Vec<f32> = x.iter().zip(&t).map(|(&v, &tt)| 0.5 * v * (1.0 + tt))
        .collect();
    (y, GeluCtx { x, t })
}

pub fn gelu_bwd(gy: &[f32], ctx: &GeluCtx) -> Vec<f32> {
    gy.iter()
        .zip(ctx.x.iter().zip(&ctx.t))
        .map(|(&g, (&x, &t))| {
            let dt = (1.0 - t * t) * K0 * (1.0 + 3.0 * K1 * x * x);
            g * (0.5 * (1.0 + t) + 0.5 * x * dt)
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Multi-head self-attention core (FP; the qkv/proj qlinears around it
// carry HOT's machinery, matching the paper)
// ---------------------------------------------------------------------------

pub struct AttnCtx {
    pub qh: Vec<f32>, // (b, h, l, dh)
    pub kh: Vec<f32>,
    pub vh: Vec<f32>,
    pub p: Vec<f32>, // (b, h, l, l)
}

/// q, k, v are (b, l, d) flattened; returns out (b, l, d) + ctx.
#[allow(clippy::too_many_arguments)]
pub fn attention_fwd(q: &[f32], k: &[f32], v: &[f32], b: usize, l: usize,
                     d: usize, heads: usize, causal: bool)
                     -> (Vec<f32>, AttnCtx) {
    let dh = d / heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let split = |t: &[f32]| -> Vec<f32> {
        // (b, l, h*dh) -> (b, h, l, dh)
        let mut out = vec![0.0f32; b * heads * l * dh];
        for bi in 0..b {
            for ti in 0..l {
                for h in 0..heads {
                    for c in 0..dh {
                        out[((bi * heads + h) * l + ti) * dh + c] =
                            t[(bi * l + ti) * d + h * dh + c];
                    }
                }
            }
        }
        out
    };
    let qh = split(q);
    let kh = split(k);
    let vh = split(v);
    let bh = b * heads;
    let mut p = vec![0.0f32; bh * l * l];
    for g in 0..bh {
        for t in 0..l {
            let qrow = &qh[(g * l + t) * dh..(g * l + t + 1) * dh];
            let prow = &mut p[(g * l + t) * l..(g * l + t + 1) * l];
            for (s, pv) in prow.iter_mut().enumerate() {
                if causal && s > t {
                    *pv = f32::NEG_INFINITY;
                    continue;
                }
                let krow = &kh[(g * l + s) * dh..(g * l + s + 1) * dh];
                let mut acc = 0.0f32;
                for (a, bb) in qrow.iter().zip(krow) {
                    acc += a * bb;
                }
                *pv = acc * scale;
            }
            // stable softmax over s
            let mx = prow.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0f32;
            for pv in prow.iter_mut() {
                *pv = (*pv - mx).exp();
                z += *pv;
            }
            for pv in prow.iter_mut() {
                *pv /= z;
            }
        }
    }
    let mut out = vec![0.0f32; b * l * d];
    for bi in 0..b {
        for h in 0..heads {
            let g = bi * heads + h;
            for t in 0..l {
                let prow = &p[(g * l + t) * l..(g * l + t + 1) * l];
                let dst = &mut out[(bi * l + t) * d + h * dh
                                   ..(bi * l + t) * d + (h + 1) * dh];
                for (s, &pv) in prow.iter().enumerate() {
                    if pv == 0.0 {
                        continue;
                    }
                    let vrow = &vh[(g * l + s) * dh..(g * l + s + 1) * dh];
                    for (dv, &vv) in dst.iter_mut().zip(vrow) {
                        *dv += pv * vv;
                    }
                }
            }
        }
    }
    (out, AttnCtx { qh, kh, vh, p })
}

/// gy (b, l, d) -> (g_q, g_k, g_v) each (b, l, d).
pub fn attention_bwd(gy: &[f32], ctx: &AttnCtx, b: usize, l: usize, d: usize,
                     heads: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let dh = d / heads;
    let scale = 1.0 / (dh as f32).sqrt();
    let bh = b * heads;
    // go: (b, h, l, dh) view of gy
    let mut go = vec![0.0f32; bh * l * dh];
    for bi in 0..b {
        for t in 0..l {
            for h in 0..heads {
                for c in 0..dh {
                    go[((bi * heads + h) * l + t) * dh + c] =
                        gy[(bi * l + t) * d + h * dh + c];
                }
            }
        }
    }
    let mut g_vh = vec![0.0f32; bh * l * dh];
    let mut g_qh = vec![0.0f32; bh * l * dh];
    let mut g_kh = vec![0.0f32; bh * l * dh];
    let mut g_s_row = vec![0.0f32; l];
    for g in 0..bh {
        for t in 0..l {
            let prow = &ctx.p[(g * l + t) * l..(g * l + t + 1) * l];
            let grow = &go[(g * l + t) * dh..(g * l + t + 1) * dh];
            // g_v += pᵀ go ; g_p = go vhᵀ
            let mut dot = 0.0f32; // sum_s g_p[s] * p[s]
            for s in 0..l {
                let vrow = &ctx.vh[(g * l + s) * dh..(g * l + s + 1) * dh];
                let mut gp = 0.0f32;
                for (a, bb) in grow.iter().zip(vrow) {
                    gp += a * bb;
                }
                g_s_row[s] = gp;
                dot += gp * prow[s];
            }
            for s in 0..l {
                let pv = prow[s];
                let gs = pv * (g_s_row[s] - dot) * scale;
                if pv != 0.0 {
                    let gv = &mut g_vh[(g * l + s) * dh..(g * l + s + 1) * dh];
                    for (dv, &gg) in gv.iter_mut().zip(grow) {
                        *dv += pv * gg;
                    }
                }
                if gs != 0.0 {
                    let krow = &ctx.kh[(g * l + s) * dh..(g * l + s + 1) * dh];
                    let qrow = &ctx.qh[(g * l + t) * dh..(g * l + t + 1) * dh];
                    let gq = &mut g_qh[(g * l + t) * dh..(g * l + t + 1) * dh];
                    for (dv, &kk) in gq.iter_mut().zip(krow) {
                        *dv += gs * kk;
                    }
                    let gk = &mut g_kh[(g * l + s) * dh..(g * l + s + 1) * dh];
                    for (dv, &qq) in gk.iter_mut().zip(qrow) {
                        *dv += gs * qq;
                    }
                }
            }
        }
    }
    let merge = |t: &[f32]| -> Vec<f32> {
        let mut out = vec![0.0f32; b * l * d];
        for bi in 0..b {
            for ti in 0..l {
                for h in 0..heads {
                    for c in 0..dh {
                        out[(bi * l + ti) * d + h * dh + c] =
                            t[((bi * heads + h) * l + ti) * dh + c];
                    }
                }
            }
        }
        out
    };
    (merge(&g_qh), merge(&g_kh), merge(&g_vh))
}

// ---------------------------------------------------------------------------
// Softmax cross-entropy (mean over all label positions)
// ---------------------------------------------------------------------------

pub struct CeCtx {
    pub p: Vec<f32>,      // (n, c) softmax probabilities
    pub onehot: Vec<f32>, // (n, c)
}

/// logits (n, c), labels (n,) -> (loss, acc, ctx).
pub fn softmax_xent_fwd(logits: &[f32], n: usize, c: usize, labels: &[i32])
                        -> (f32, f32, CeCtx) {
    debug_assert_eq!(labels.len(), n);
    let mut p = vec![0.0f32; n * c];
    let mut onehot = vec![0.0f32; n * c];
    let mut loss = 0.0f64;
    let mut correct = 0usize;
    for r in 0..n {
        let row = &logits[r * c..(r + 1) * c];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for &v in row {
            z += (v - mx).exp();
        }
        let lse = mx + z.ln();
        let lab = labels[r] as usize;
        debug_assert!(lab < c);
        onehot[r * c + lab] = 1.0;
        loss -= (row[lab] - lse) as f64;
        let mut argmax = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > row[argmax] {
                argmax = j;
            }
            p[r * c + j] = (v - lse).exp();
        }
        if argmax == lab {
            correct += 1;
        }
    }
    ((loss / n as f64) as f32, correct as f32 / n as f32, CeCtx { p, onehot })
}

/// d loss / d logits for unit upstream gradient.
pub fn softmax_xent_bwd(ctx: &CeCtx, n: usize) -> Vec<f32> {
    ctx.p
        .iter()
        .zip(&ctx.onehot)
        .map(|(&p, &o)| (p - o) / n as f32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use crate::util::proptest::rel_err;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Pcg32::seeded(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    #[test]
    fn variant_tag_parsing() {
        let c = BackwardCfg::parse("hot").unwrap();
        assert_eq!(c.variant, Variant::Hot);
        assert_eq!(c.rank, 8);
        assert!(c.abc);
        let c = BackwardCfg::parse("hot_r4").unwrap();
        assert_eq!(c.rank, 4);
        let c = BackwardCfg::parse("hot_noabc").unwrap();
        assert!(!c.abc);
        assert!(!c.packs_ctx(), "noabc keeps the eager ctx schema");
        let c = BackwardCfg::parse("hot_abc4").unwrap();
        assert_eq!(c.abc_bits, 4);
        assert!(c.packs_ctx());
        assert_eq!(BackwardCfg::parse("hot_abc4_r4").unwrap().rank, 4);
        assert!(!BackwardCfg::parse("fp").unwrap().packs_ctx());
        let c = BackwardCfg::parse("gx_int_hla").unwrap();
        assert_eq!(c.variant, Variant::GxIntHla);
        assert_eq!(BackwardCfg::parse("fp").unwrap().variant, Variant::Fp);
        assert!(BackwardCfg::parse("warp").is_err());
        assert!(BackwardCfg::parse("hot_r99").is_err());
    }

    #[test]
    fn matmul_identities() {
        let a = randv(6 * 4, 1);
        let b = randv(4 * 5, 2);
        let ab = gemm_f32_nn(&a, &b, 6, 4, 5);
        // x @ w.T with w = b.T equals a @ b
        let bt = transpose(&b, 4, 5); // (5, 4)
        let ab2 = gemm_f32_nt(&a, &bt, 6, 4, 5);
        assert!(rel_err(&ab, &ab2) < 1e-5);
        // (a.T).T @ b == a @ b
        let at = transpose(&a, 6, 4); // (4, 6)
        let ab3 = gemm_f32_tn(&at, &b, 4, 6, 5);
        assert!(rel_err(&ab, &ab3) < 1e-5);
    }

    #[test]
    fn int_gemm_matches_float() {
        use crate::kernels::{gemm_i8_nn, gemm_i8_tn};
        let mut r = Pcg32::seeded(3);
        let a: Vec<i8> = (0..8 * 6).map(|_| (r.below(15) as i8) - 7).collect();
        let b: Vec<i8> = (0..6 * 5).map(|_| (r.below(15) as i8) - 7).collect();
        let af: Vec<f32> = a.iter().map(|&v| v as f32).collect();
        let bf: Vec<f32> = b.iter().map(|&v| v as f32).collect();
        let got: Vec<f32> = gemm_i8_nn(&a, &b, 8, 6, 5)
            .iter().map(|&v| v as f32).collect();
        assert!(rel_err(&got, &gemm_f32_nn(&af, &bf, 8, 6, 5)) < 1e-6);
        let at: Vec<i8> = {
            let mut out = vec![0i8; 6 * 8];
            for r0 in 0..8 {
                for c0 in 0..6 {
                    out[c0 * 8 + r0] = a[r0 * 6 + c0];
                }
            }
            out
        };
        let got2: Vec<f32> = gemm_i8_tn(&at, &b, 6, 8, 5)
            .iter().map(|&v| v as f32).collect();
        assert!(rel_err(&got2, &gemm_f32_nn(&af, &bf, 8, 6, 5)) < 1e-6);
    }

    #[test]
    fn hq_matmul_tracks_exact_at_8_bits() {
        // HT on the contracted dim cancels exactly; at 8 bits only the
        // quantization noise remains
        let gy = randv(32 * 32, 4);
        let w = randv(32 * 16, 5);
        let got = hq_matmul(&gy, 32, 32, &w, 16, 8);
        let want = gemm_f32_nn(&gy, &w, 32, 32, 16);
        assert!(rel_err(&got, &want) < 0.05, "{}", rel_err(&got, &want));
    }

    #[test]
    fn int4_nibble_gemm_could_serve_the_hq_path_bit_exactly() {
        // the packed-operand kernel must agree bit-for-bit with the
        // production hq route on real HQ operands, so a future caller
        // whose g_y already lives in the ABC nibble wire format can
        // switch kernels without a numerics change
        use crate::kernels::gemm_i4_nn_deq;
        let gy = randv(32 * 32, 40);
        let w = randv(32 * 16, 41);
        let want = hq_matmul(&gy, 32, 32, &w, 16, 4);
        let (q_g, s_g) = fwht_quant_rows(&gy, 32, 32, 4);
        let (q_w, s_w) = fwht_quant_cols(&w, 32, 16, 4);
        let got = gemm_i4_nn_deq(&quant::pack_int4(&q_g), &q_w, 32, 32, 16,
                                 s_g * s_w);
        assert_eq!(got, want);
    }

    #[test]
    fn fp_qlinear_bwd_is_exact() {
        let cfg = BackwardCfg { variant: Variant::Fp, ..Default::default() };
        let (n, i, o) = (5, 4, 3);
        let x = randv(n * i, 6);
        let w = randv(o * i, 7);
        let bias = vec![0.1f32; o];
        let (y, ctx) = qlinear_fwd(x.clone(), n, i, &w, o, &bias, &cfg);
        // y[r][c] = sum_k x[r][k] w[c][k] + b[c]
        let mut want_y = gemm_f32_nt(&x, &w, n, i, o);
        for r in 0..n {
            for c in 0..o {
                want_y[r * o + c] += bias[c];
            }
        }
        assert!(rel_err(&y, &want_y) < 1e-6);
        let gy = randv(n * o, 8);
        let (gx, gw, gb) = qlinear_bwd(&gy, n, o, &w, i, &ctx, &cfg, 0.0, true);
        assert!(rel_err(gx.as_ref().unwrap(),
                        &gemm_f32_nn(&gy, &w, n, o, i)) < 1e-6);
        assert!(rel_err(&gw, &gemm_f32_tn(&gy, &x, n, o, i)) < 1e-6);
        let want_gb: Vec<f32> = (0..o)
            .map(|c| (0..n).map(|r| gy[r * o + c]).sum())
            .collect();
        assert!(rel_err(&gb, &want_gb) < 1e-6);
    }

    #[test]
    fn hot_ctx_is_compressed_and_usable() {
        let cfg = BackwardCfg::default(); // hot, abc
        let (n, i, o) = (32, 16, 16);
        let x = randv(n * i, 9);
        let w = randv(o * i, 10);
        let bias = vec![0.0f32; o];
        let (_, ctx) = qlinear_fwd(x.clone(), n, i, &w, o, &bias, &cfg);
        assert!(ctx.x.is_none());
        let xa = ctx.xq.as_ref().unwrap();
        let nc = n / BLOCK * cfg.rank;
        assert_eq!((xa.rows, xa.cols), (nc, i));
        assert_eq!(xa.data.len(), nc * i, "INT8 payload: one byte per code");
        assert_eq!(xa.scales.len(), nc, "per-row scales");
        let gy = randv(n * o, 11);
        let (gx, gw, _) = qlinear_bwd(&gy, n, o, &w, i, &ctx, &cfg, 0.0, true);
        // approximations stay in the exact gradients' ballpark
        let exact_gx = gemm_f32_nn(&gy, &w, n, o, i);
        let exact_gw = gemm_f32_tn(&gy, &x, n, o, i);
        assert!(rel_err(gx.as_ref().unwrap(), &exact_gx) < 1.0);
        assert!(rel_err(&gw, &exact_gw) < 1.0);
        // per-token flag flips the g_w computation but not its scale
        let (_, gw_pt, _) = qlinear_bwd(&gy, n, o, &w, i, &ctx, &cfg, 1.0, true);
        assert!(gw_pt.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn abc4_ctx_packs_nibbles_and_still_trains_the_gw_path() {
        let cfg = BackwardCfg { abc_bits: 4, ..Default::default() };
        let (n, i, o) = (32, 16, 16);
        let x = randv(n * i, 90);
        let w = randv(o * i, 91);
        let bias = vec![0.0f32; o];
        let (_, ctx) = qlinear_fwd(x.clone(), n, i, &w, o, &bias, &cfg);
        let xa = ctx.xq.as_ref().unwrap();
        let nc = n / BLOCK * cfg.rank;
        assert_eq!(xa.bits, 4);
        assert_eq!(xa.data.len(), (nc * i).div_ceil(2),
                   "INT4 payload packs two codes per byte");
        assert!(xa.unpack().iter().all(|&q| (-7..=7).contains(&q)));
        let gy = randv(n * o, 92);
        let (_, gw, _) = qlinear_bwd(&gy, n, o, &w, i, &ctx, &cfg, 0.0, true);
        let exact = gemm_f32_tn(&gy, &x, n, o, i);
        assert!(rel_err(&gw, &exact) < 1.0, "{}", rel_err(&gw, &exact));
    }

    #[test]
    fn non_tiling_layers_fall_back_to_exact() {
        let cfg = BackwardCfg::default();
        let (n, i, o) = (5, 4, 3); // nothing tiles into 16
        let x = randv(n * i, 12);
        let w = randv(o * i, 13);
        let bias = vec![0.0f32; o];
        let (_, ctx) = qlinear_fwd(x.clone(), n, i, &w, o, &bias, &cfg);
        assert!(ctx.x.is_some(), "non-tiling layer keeps raw FP residuals");
        let gy = randv(n * o, 14);
        let (gx, gw, _) = qlinear_bwd(&gy, n, o, &w, i, &ctx, &cfg, 0.0, true);
        assert!(rel_err(gx.as_ref().unwrap(),
                        &gemm_f32_nn(&gy, &w, n, o, i)) < 1e-6);
        assert!(rel_err(&gw, &gemm_f32_tn(&gy, &x, n, o, i)) < 1e-6);
    }

    #[test]
    fn all_variants_produce_finite_grads() {
        let (n, i, o) = (32, 16, 16);
        let x = randv(n * i, 15);
        let w = randv(o * i, 16);
        let gy = randv(n * o, 17);
        let bias = vec![0.0f32; o];
        for tag in ["fp", "hot", "lbp", "luq", "int4", "gx_hq4", "gx_q4",
                    "gx_ext_hla", "gx_int_hla", "gw_hq4", "gw_hla", "gw_hot"] {
            let cfg = BackwardCfg::parse(tag).unwrap();
            let (_, ctx) = qlinear_fwd(x.clone(), n, i, &w, o, &bias, &cfg);
            let (gx, gw, gb) =
                qlinear_bwd(&gy, n, o, &w, i, &ctx, &cfg, 0.0, true);
            assert!(gx.unwrap().iter().all(|v| v.is_finite()), "{tag} gx");
            assert!(gw.iter().all(|v| v.is_finite()), "{tag} gw");
            assert!(gb.iter().all(|v| v.is_finite()), "{tag} gb");
        }
    }

    #[test]
    fn layernorm_grad_invariants() {
        let (rows, d) = (6, 8);
        let x = randv(rows * d, 18);
        let gamma = randv(d, 19);
        let beta = vec![0.0f32; d];
        let (y, ctx) = layernorm_fwd(&x, rows, d, &gamma, &beta);
        assert_eq!(y.len(), rows * d);
        let gy = randv(rows * d, 20);
        let (gx, ggamma, gbeta) = layernorm_bwd(&gy, rows, d, &gamma, &ctx);
        // per-row: sum of g_x is 0 and g_x ⟂ xhat (exact LN identities)
        for r in 0..rows {
            let row = &gx[r * d..(r + 1) * d];
            let s: f32 = row.iter().sum();
            assert!(s.abs() < 1e-3, "row {r} sum {s}");
            let dot: f32 = row.iter().zip(&ctx.xhat[r * d..(r + 1) * d])
                .map(|(a, b)| a * b).sum();
            assert!(dot.abs() < 1e-2, "row {r} dot {dot}");
        }
        let want_gbeta: Vec<f32> = (0..d)
            .map(|c| (0..rows).map(|r| gy[r * d + c]).sum())
            .collect();
        assert!(rel_err(&gbeta, &want_gbeta) < 1e-5);
        assert!(ggamma.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn gelu_matches_finite_difference() {
        let xs = [-2.0f32, -0.5, 0.0, 0.3, 1.7];
        let (_, ctx) = gelu_fwd(xs.to_vec());
        let g = gelu_bwd(&vec![1.0; xs.len()], &ctx);
        for (j, &x) in xs.iter().enumerate() {
            let eps = 1e-3f32;
            let f = |v: f32| {
                let t = (K0 * (v + K1 * v * v * v)).tanh();
                0.5 * v * (1.0 + t)
            };
            let fd = (f(x + eps) - f(x - eps)) / (2.0 * eps);
            assert!((g[j] - fd).abs() < 1e-2, "x={x}: {} vs {fd}", g[j]);
        }
    }

    #[test]
    fn attention_shapes_and_causality() {
        let (b, l, d, heads) = (2, 4, 8, 2);
        let q = randv(b * l * d, 21);
        let k = randv(b * l * d, 22);
        let v = randv(b * l * d, 23);
        let (out, ctx) = attention_fwd(&q, &k, &v, b, l, d, heads, true);
        assert_eq!(out.len(), b * l * d);
        // causal: p[t][s] == 0 for s > t
        for g in 0..b * heads {
            for t in 0..l {
                for s in t + 1..l {
                    assert_eq!(ctx.p[(g * l + t) * l + s], 0.0);
                }
            }
        }
        // softmax rows sum to 1
        for row in ctx.p.chunks(l) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        let gy = randv(b * l * d, 24);
        let (gq, gk, gv) = attention_bwd(&gy, &ctx, b, l, d, heads);
        assert!(gq.iter().chain(&gk).chain(&gv).all(|v| v.is_finite()));
    }

    #[test]
    fn attention_grad_directional_check() {
        // d/deps loss(q + eps*dq, ...) == <g_q, dq> for loss = <out, r>
        let (b, l, d, heads) = (1, 4, 8, 2);
        let q = randv(b * l * d, 25);
        let k = randv(b * l * d, 26);
        let v = randv(b * l * d, 27);
        let r = randv(b * l * d, 28);
        let dq = randv(b * l * d, 29);
        let loss = |qv: &[f32]| -> f32 {
            let (out, _) = attention_fwd(qv, &k, &v, b, l, d, heads, false);
            out.iter().zip(&r).map(|(a, bb)| a * bb).sum()
        };
        let (_, ctx) = attention_fwd(&q, &k, &v, b, l, d, heads, false);
        let (gq, _, _) = attention_bwd(&r, &ctx, b, l, d, heads);
        let analytic: f32 = gq.iter().zip(&dq).map(|(a, bb)| a * bb).sum();
        let eps = 1e-3f32;
        let qp: Vec<f32> = q.iter().zip(&dq).map(|(a, bb)| a + eps * bb).collect();
        let qm: Vec<f32> = q.iter().zip(&dq).map(|(a, bb)| a - eps * bb).collect();
        let fd = (loss(&qp) - loss(&qm)) / (2.0 * eps);
        assert!((analytic - fd).abs() < 0.05 * fd.abs().max(1.0),
                "{analytic} vs {fd}");
    }

    #[test]
    fn xent_known_values() {
        // two rows, 2 classes, logits strongly favouring the label
        let logits = vec![5.0, -5.0, -5.0, 5.0];
        let labels = vec![0, 1];
        let (loss, acc, ctx) = softmax_xent_fwd(&logits, 2, 2, &labels);
        assert!(loss < 0.01, "{loss}");
        assert_eq!(acc, 1.0);
        let g = softmax_xent_bwd(&ctx, 2);
        // gradient sums to zero per row
        assert!((g[0] + g[1]).abs() < 1e-6);
        assert!((g[2] + g[3]).abs() < 1e-6);
        // wrong labels: high loss, zero acc
        let (loss2, acc2, _) = softmax_xent_fwd(&logits, 2, 2, &[1, 0]);
        assert!(loss2 > 5.0);
        assert_eq!(acc2, 0.0);
    }

    #[test]
    fn lbp_paths_reconstruct_smooth_signals() {
        // low-frequency gy along N: external HLA g_x should track exact
        let (n, o, i) = (32, 16, 16);
        let mut gy = vec![0.0f32; n * o];
        for r in 0..n {
            let t = (r as f32 / n as f32 * std::f32::consts::PI).cos();
            for c in 0..o {
                gy[r * o + c] = t * (0.2 + c as f32 / o as f32);
            }
        }
        let w = randv(o * i, 30);
        let got = lbp_gx(&gy, n, o, &w, i, 8);
        let want = gemm_f32_nn(&gy, &w, n, o, i);
        assert!(rel_err(&got, &want) < 0.25, "{}", rel_err(&got, &want));
    }
}
