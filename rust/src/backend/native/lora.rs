//! Native HOT + LoRA joint optimization — the rust port of
//! python/compile/lora.py (paper §5.3, Tables 3/4/9).
//!
//! LoRA freezes the base weight w and learns a low-rank update B·A. HOT
//! composes with it per the paper's ablation:
//!   * frozen path: g_w is skipped entirely; `hot_frozen` computes the
//!     remaining g_x through w with HQ-INT4 (the winning configuration);
//!   * decomposed path: `hot_decomposed` applies HLA+INT8 to the A/B
//!     gradients (the configuration the paper shows fails).
//!
//! Adapted layers: qkv, proj, fc1, fc2. embed/head stay trainable in
//! full. Fused-step only (LoRA fine-tuning never runs split/accum).

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Context, Result};

use crate::backend::native::layers::{self, BackwardCfg, Variant};
use crate::backend::native::model::Params;
use crate::backend::native::presets::{self, ModelShape};
use crate::hadamard::{block_hla_axis0, BLOCK};
use crate::kernels::{gemm_f32_nn, gemm_f32_nt, gemm_f32_tn};
use crate::quant::AbcAct;
use crate::runtime::manifest::{DType, TensorSpec};
use crate::runtime::value::Value;

pub const LORA_TARGETS: [&str; 4] = ["attn.wqkv", "attn.wo", "fc1.w", "fc2.w"];
pub const DEFAULT_R_LORA: usize = 8;
pub const LORA_SCALE: f32 = 2.0;

/// How HOT composes with the LoRA paths (parsed from the key tag).
#[derive(Debug, Clone, Copy)]
pub struct LoraCfg {
    pub bcfg: BackwardCfg,
    pub hot_frozen: bool,
    pub hot_decomposed: bool,
    pub r_lora: usize,
}

impl LoraCfg {
    pub fn parse(tag: &str) -> Result<LoraCfg> {
        let (frozen, dec, variant) = match tag {
            "fp" => (false, false, Variant::Fp),
            "hotfrozen" => (true, false, Variant::Hot),
            "hotdec" => (false, true, Variant::Hot),
            "hotboth" => (true, true, Variant::Hot),
            other => bail!("unknown lora tag {other:?} \
                            (fp|hotfrozen|hotdec|hotboth)"),
        };
        Ok(LoraCfg {
            bcfg: BackwardCfg { variant, ..BackwardCfg::default() },
            hot_frozen: frozen,
            hot_decomposed: dec,
            r_lora: DEFAULT_R_LORA,
        })
    }
}

fn is_target(name: &str) -> bool {
    LORA_TARGETS.iter().any(|t| name.ends_with(t))
}

/// The trainable set: LoRA tensors + embed/head (+biases), sorted by name
/// (lora.py lora_param_specs + make_lora_train_step's "extra" set).
pub fn trainable_specs(shape: &ModelShape, r_lora: usize) -> Vec<TensorSpec> {
    let mut specs: Vec<TensorSpec> = Vec::new();
    for base in presets::param_specs(shape) {
        if is_target(&base.name) {
            let (o, i) = (base.shape[0], base.shape[1]);
            specs.push(TensorSpec { name: format!("{}.lora_a", base.name),
                                    shape: vec![r_lora, i],
                                    dtype: DType::F32 });
            specs.push(TensorSpec { name: format!("{}.lora_b", base.name),
                                    shape: vec![o, r_lora],
                                    dtype: DType::F32 });
        } else if matches!(base.name.as_str(),
                           "embed.w" | "embed.b" | "head.w" | "head.b") {
            specs.push(base);
        }
    }
    specs.sort_by(|a, b| a.name.cmp(&b.name));
    specs
}

// ---------------------------------------------------------------------------
// LoRA-adapted qlinear
// ---------------------------------------------------------------------------

struct LoraQlCtx {
    u: Vec<f32>, // x @ Aᵀ, (n, r)
    x: Option<Vec<f32>>,
    xq: Option<AbcAct>,
    n: usize,
    i: usize,
}

/// y = x wᵀ + scale · (x Aᵀ) Bᵀ + b.
#[allow(clippy::too_many_arguments)]
fn qlinear_lora_fwd(x: &[f32], n: usize, i: usize, w: &[f32], o: usize,
                    bias: &[f32], a: &[f32], bm: &[f32], cfg: &LoraCfg)
                    -> (Vec<f32>, LoraQlCtx) {
    let r = cfg.r_lora;
    let u = gemm_f32_nt(x, a, n, i, r);
    let mut y = gemm_f32_nt(x, w, n, i, o);
    let ub = gemm_f32_nt(&u, bm, n, r, o);
    for row in 0..n {
        for c in 0..o {
            y[row * o + c] += LORA_SCALE * ub[row * o + c] + bias[c];
        }
    }
    let ctx = if cfg.hot_decomposed && n % BLOCK == 0 {
        let xa = layers::hla_compress(x, n, i, cfg.bcfg.rank,
                                      cfg.bcfg.abc_bits, cfg.bcfg.criterion);
        LoraQlCtx { u, x: None, xq: Some(xa), n, i }
    } else {
        LoraQlCtx { u, x: Some(x.to_vec()), xq: None, n, i }
    };
    (y, ctx)
}

/// Returns (g_x, g_a, g_bm). No g_w — w is frozen (biases too).
#[allow(clippy::too_many_arguments)]
fn qlinear_lora_bwd(gy: &[f32], n: usize, o: usize, w: &[f32], i: usize,
                    a: &[f32], bm: &[f32], ctx: &LoraQlCtx, cfg: &LoraCfg)
                    -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let r = cfg.r_lora;
    // frozen-path g_x
    let mut g_x = if cfg.hot_frozen && o % BLOCK == 0 {
        layers::hq_matmul(gy, n, o, w, i, cfg.bcfg.gx_bits)
    } else {
        gemm_f32_nn(gy, w, n, o, i)
    };
    // decomposed-path gradients
    let mut g_u = gemm_f32_nn(gy, bm, n, o, r); // gy (n,o) @ bm (o,r)
    for v in g_u.iter_mut() {
        *v *= LORA_SCALE;
    }
    let (g_a, g_bm) = if let Some(xa) = &ctx.xq {
        // HLA + packed INT8 on the decomposed products (Table 9
        // ablation) — same g_w shape as the full HOT path, so the
        // shared kernel applies (it folds the per-row x scales into the
        // dequantized g_u operand ahead of an FP TN GEMM).
        let bits = cfg.bcfg.gw_bits;
        let rank = cfg.bcfg.rank;
        let nc = n / BLOCK * rank;
        let g_a = layers::hla_matmul(&g_u, n, r, xa, rank, bits, false,
                                     cfg.bcfg.criterion);
        let gc_y = block_hla_axis0(gy, n, o, rank, cfg.bcfg.criterion);
        let uc = block_hla_axis0(&ctx.u, n, r, rank, cfg.bcfg.criterion);
        let mut g_bm = gemm_f32_tn(&layers::fake_quant(&gc_y, bits),
                                   &layers::fake_quant(&uc, bits), nc, o, r);
        for v in g_bm.iter_mut() {
            *v *= LORA_SCALE;
        }
        (g_a, g_bm)
    } else {
        let x = ctx.x.as_deref().expect("lora ctx holds x or xq");
        let g_a = gemm_f32_tn(&g_u, x, n, r, i);
        let mut g_bm = gemm_f32_tn(gy, &ctx.u, n, o, r);
        for v in g_bm.iter_mut() {
            *v *= LORA_SCALE;
        }
        (g_a, g_bm)
    };
    // g_x += g_u @ A
    let ga_path = gemm_f32_nn(&g_u, a, n, r, i);
    for (gv, av) in g_x.iter_mut().zip(&ga_path) {
        *gv += av;
    }
    (g_x, g_a, g_bm)
}

// ---------------------------------------------------------------------------
// Full LoRA model (reuses the non-linear pieces from layers.rs)
// ---------------------------------------------------------------------------

enum Saved {
    Ql { module: String, ctx: layers::QlCtx, flag: f32 },
    QLora { wname: String, ctx: LoraQlCtx },
    Ln(layers::LnCtx),
    Gelu(layers::GeluCtx),
    Attn(layers::AttnCtx),
    Ce(layers::CeCtx),
}

pub struct LoraStepOut {
    pub loss: f32,
    pub acc: f32,
    /// Pre-softmax head outputs (b, classes) — kept so the merged-
    /// adapter inference walk can be pinned bit-identical to training.
    pub logits: Vec<f32>,
    pub grads: BTreeMap<String, Vec<f32>>,
}

/// One fused LoRA forward+backward. `merged` maps base params with the
/// trainable embed/head overrides applied; `lora` maps the adapter
/// tensors. Returns grads keyed by trainable names only.
pub fn lora_loss_and_grads(shape: &ModelShape, cfg: &LoraCfg,
                           merged: &Params, lora: &Params, lqs_mask: &[f32],
                           x: &Value, y: &Value) -> Result<LoraStepOut> {
    ensure!(shape.arch == "vit", "LoRA fine-tuning targets the vit presets");
    let (d, l, m) = (shape.d_model, shape.seq, shape.d_mlp());
    let dims = x.shape();
    ensure!(dims.len() == 3 && dims[1] == l && dims[2] == shape.in_dim,
            "input must be (b, {l}, {}), got {dims:?}", shape.in_dim);
    let b = dims[0];
    let n = b * l;
    let labels = match y {
        Value::I32 { data, .. } => data.clone(),
        _ => bail!("labels must be i32"),
    };
    ensure!(labels.len() == b, "labels must be (b,)");
    let mut saved: Vec<Saved> = Vec::new();
    let mut qi = 0usize;
    let bcfg = cfg.bcfg;

    // --- forward ------------------------------------------------------------
    let sp_fwd = crate::obs::span(crate::obs::Span::Forward);
    crate::obs::set_layer("embed");
    let (mut h, ql) = layers::qlinear_fwd_borrowed(x.as_f32()?, n,
                                                   shape.in_dim,
                                                   merged.f("embed.w")?, d,
                                                   merged.f("embed.b")?,
                                                   &bcfg);
    saved.push(Saved::Ql { module: "embed".into(), ctx: ql,
                           flag: lqs_mask.first().copied().unwrap_or(0.0) });
    qi += 1;
    let pos = merged.f("pos")?;
    for row in 0..n {
        let t = row % l;
        for j in 0..d {
            h[row * d + j] += pos[t * d + j];
        }
    }

    for blk in 0..shape.depth {
        let pre = format!("blk{blk}.");
        let mut lora_fwd = |saved: &mut Vec<Saved>, qi: &mut usize,
                            inp: &[f32], rows: usize, in_dim: usize,
                            wname: String, bname: String, o: usize|
                            -> Result<Vec<f32>> {
            let a = lora.f(&format!("{wname}.lora_a"))?;
            let bm = lora.f(&format!("{wname}.lora_b"))?;
            crate::obs::set_layer(&wname);
            let (y, ctx) = qlinear_lora_fwd(inp, rows, in_dim,
                                            merged.f(&wname)?, o,
                                            merged.f(&bname)?, a, bm, cfg);
            saved.push(Saved::QLora { wname, ctx });
            *qi += 1;
            Ok(y)
        };
        let (hn, ln) = layers::layernorm_fwd(&h, n, d,
                                             merged.f(&format!("{pre}ln1.g"))?,
                                             merged.f(&format!("{pre}ln1.b"))?);
        saved.push(Saved::Ln(ln));
        let qkv = lora_fwd(&mut saved, &mut qi, &hn, n, d,
                           format!("{pre}attn.wqkv"),
                           format!("{pre}attn.bqkv"), 3 * d)?;
        let mut q = vec![0.0f32; n * d];
        let mut k = vec![0.0f32; n * d];
        let mut v = vec![0.0f32; n * d];
        for row in 0..n {
            for j in 0..d {
                q[row * d + j] = qkv[row * 3 * d + j];
                k[row * d + j] = qkv[row * 3 * d + d + j];
                v[row * d + j] = qkv[row * 3 * d + 2 * d + j];
            }
        }
        let (att, actx) = layers::attention_fwd(&q, &k, &v, b, l, d,
                                                shape.heads, false);
        saved.push(Saved::Attn(actx));
        let proj = lora_fwd(&mut saved, &mut qi, &att, n, d,
                            format!("{pre}attn.wo"),
                            format!("{pre}attn.bo"), d)?;
        for (hv, pv) in h.iter_mut().zip(&proj) {
            *hv += pv;
        }
        let (hn, ln) = layers::layernorm_fwd(&h, n, d,
                                             merged.f(&format!("{pre}ln2.g"))?,
                                             merged.f(&format!("{pre}ln2.b"))?);
        saved.push(Saved::Ln(ln));
        let f1 = lora_fwd(&mut saved, &mut qi, &hn, n, d,
                          format!("{pre}fc1.w"), format!("{pre}fc1.b"), m)?;
        let (g1, gc) = layers::gelu_fwd(f1);
        saved.push(Saved::Gelu(gc));
        let f2 = lora_fwd(&mut saved, &mut qi, &g1, n, m,
                          format!("{pre}fc2.w"), format!("{pre}fc2.b"), d)?;
        for (hv, fv) in h.iter_mut().zip(&f2) {
            *hv += fv;
        }
    }

    let (hn, lnf) = layers::layernorm_fwd(&h, n, d, merged.f("lnf.g")?,
                                          merged.f("lnf.b")?);
    saved.push(Saved::Ln(lnf));
    let mut pooled = vec![0.0f32; b * d];
    for bi in 0..b {
        for t in 0..l {
            for j in 0..d {
                pooled[bi * d + j] += hn[(bi * l + t) * d + j] / l as f32;
            }
        }
    }
    let c = shape.n_classes;
    crate::obs::set_layer("head");
    let (logits, hctx) = layers::qlinear_fwd(pooled, b, d,
                                             merged.f("head.w")?, c,
                                             merged.f("head.b")?, &bcfg);
    saved.push(Saved::Ql { module: "head".into(), ctx: hctx,
                           flag: lqs_mask.get(qi).copied().unwrap_or(0.0) });
    let (loss, acc, ce) = layers::softmax_xent_fwd(&logits, b, c, &labels);
    saved.push(Saved::Ce(ce));
    drop(sp_fwd);

    // --- backward -------------------------------------------------------------
    let _sp_bwd = crate::obs::span(crate::obs::Span::Backward);
    let mut grads: BTreeMap<String, Vec<f32>> = BTreeMap::new();
    let mut it = saved.into_iter().rev();
    let mut take = move || it.next().context("lora ctx walk underflow");

    let ce = match take()? {
        Saved::Ce(c) => c,
        _ => bail!("lora walk: expected ce"),
    };
    let g_logits = layers::softmax_xent_bwd(&ce, b);
    let (head_ctx, head_flag) = match take()? {
        Saved::Ql { ctx, flag, .. } => (ctx, flag),
        _ => bail!("lora walk: expected head ql"),
    };
    let (g_pooled, g_hw, g_hb) = layers::qlinear_bwd(
        &g_logits, b, c, merged.f("head.w")?, d, &head_ctx, &bcfg,
        head_flag, true);
    grads.insert("head.w".into(), g_hw);
    grads.insert("head.b".into(), g_hb);
    let g_pooled = g_pooled.expect("head g_x");

    let lnf = match take()? {
        Saved::Ln(ln) => ln,
        _ => bail!("lora walk: expected lnf"),
    };
    let mut g_hn = vec![0.0f32; n * d];
    for bi in 0..b {
        for t in 0..l {
            for j in 0..d {
                g_hn[(bi * l + t) * d + j] = g_pooled[bi * d + j] / l as f32;
            }
        }
    }
    let (mut g_h, _, _) = layers::layernorm_bwd(&g_hn, n, d,
                                                merged.f("lnf.g")?, &lnf);

    for blk in (0..shape.depth).rev() {
        let pre = format!("blk{blk}.");
        let mut lora_bwd = |take: &mut dyn FnMut() -> Result<Saved>,
                            gy: &[f32], rows: usize, o: usize|
                            -> Result<Vec<f32>> {
            let (wname, ctx) = match take()? {
                Saved::QLora { wname, ctx } => (wname, ctx),
                _ => bail!("lora walk: expected qlora"),
            };
            let wv = merged.t(&wname)?;
            let i = wv.shape[1];
            ensure!(ctx.n == rows && ctx.i == i, "{wname}: ctx dims drifted");
            let a = lora.f(&format!("{wname}.lora_a"))?;
            let bm = lora.f(&format!("{wname}.lora_b"))?;
            crate::obs::set_layer(&wname);
            let (g_x, g_a, g_bm) = qlinear_lora_bwd(gy, rows, o,
                                                    wv.data, i, a, bm,
                                                    &ctx, cfg);
            grads.insert(format!("{wname}.lora_a"), g_a);
            grads.insert(format!("{wname}.lora_b"), g_bm);
            Ok(g_x)
        };
        let g_f2in = lora_bwd(&mut take, &g_h, n, d)?;
        let gelu = match take()? {
            Saved::Gelu(g) => g,
            _ => bail!("lora walk: expected gelu"),
        };
        let g_f1 = layers::gelu_bwd(&g_f2in, &gelu);
        let g_hn2 = lora_bwd(&mut take, &g_f1, n, m)?;
        let ln2 = match take()? {
            Saved::Ln(ln) => ln,
            _ => bail!("lora walk: expected ln2"),
        };
        let (g_res, _, _) = layers::layernorm_bwd(
            &g_hn2, n, d, merged.f(&format!("{pre}ln2.g"))?, &ln2);
        for (hv, rv) in g_h.iter_mut().zip(&g_res) {
            *hv += rv;
        }
        let g_att = lora_bwd(&mut take, &g_h, n, d)?;
        let actx = match take()? {
            Saved::Attn(a) => a,
            _ => bail!("lora walk: expected attn"),
        };
        let (g_q, g_k, g_v) = layers::attention_bwd(&g_att, &actx, b, l, d,
                                                    shape.heads);
        let mut g_qkv = vec![0.0f32; n * 3 * d];
        for row in 0..n {
            for j in 0..d {
                g_qkv[row * 3 * d + j] = g_q[row * d + j];
                g_qkv[row * 3 * d + d + j] = g_k[row * d + j];
                g_qkv[row * 3 * d + 2 * d + j] = g_v[row * d + j];
            }
        }
        let g_hn1 = lora_bwd(&mut take, &g_qkv, n, 3 * d)?;
        let ln1 = match take()? {
            Saved::Ln(ln) => ln,
            _ => bail!("lora walk: expected ln1"),
        };
        let (g_res, _, _) = layers::layernorm_bwd(
            &g_hn1, n, d, merged.f(&format!("{pre}ln1.g"))?, &ln1);
        for (hv, rv) in g_h.iter_mut().zip(&g_res) {
            *hv += rv;
        }
    }

    let (embed_ctx, embed_flag) = match take()? {
        Saved::Ql { ctx, flag, .. } => (ctx, flag),
        _ => bail!("lora walk: expected embed ql"),
    };
    let (_, g_ew, g_eb) = layers::qlinear_bwd(
        &g_h, n, d, merged.f("embed.w")?, shape.in_dim, &embed_ctx, &bcfg,
        embed_flag, false);
    grads.insert("embed.w".into(), g_ew);
    grads.insert("embed.b".into(), g_eb);

    Ok(LoraStepOut { loss, acc, logits, grads })
}

// ---------------------------------------------------------------------------
// Inference-only LoRA forward (no saved state)
// ---------------------------------------------------------------------------

/// y = x wᵀ + scale · (x Aᵀ) Bᵀ + b — the adapted qlinear with no ctx.
/// Same GEMMs in the same order as `qlinear_lora_fwd`, minus the
/// compress-or-keep epilogue.
#[allow(clippy::too_many_arguments)]
fn qlinear_lora_y(x: &[f32], n: usize, i: usize, w: &[f32], o: usize,
                  bias: &[f32], a: &[f32], bm: &[f32], r: usize)
                  -> Vec<f32> {
    let u = gemm_f32_nt(x, a, n, i, r);
    let mut y = gemm_f32_nt(x, w, n, i, o);
    let ub = gemm_f32_nt(&u, bm, n, r, o);
    for row in 0..n {
        for c in 0..o {
            y[row * o + c] += LORA_SCALE * ub[row * o + c] + bias[c];
        }
    }
    y
}

/// Merged-adapter inference walk: batched logits (b, classes) from the
/// frozen base + one tenant's adapters, with zero saved-for-backward
/// state. Bit-identical to `lora_loss_and_grads`'s logits (the forward
/// is exact FP for every LoRA tag; pinned by the parity test below).
pub fn lora_infer_logits(shape: &ModelShape, cfg: &LoraCfg, merged: &Params,
                         lora: &Params, x: &Value) -> Result<Vec<f32>> {
    ensure!(shape.arch == "vit", "LoRA fine-tuning targets the vit presets");
    let (d, l, m) = (shape.d_model, shape.seq, shape.d_mlp());
    let dims = x.shape();
    ensure!(dims.len() == 3 && dims[1] == l && dims[2] == shape.in_dim,
            "input must be (b, {l}, {}), got {dims:?}", shape.in_dim);
    let b = dims[0];
    let n = b * l;
    let r = cfg.r_lora;

    let mut h = layers::qlinear_y(x.as_f32()?, n, shape.in_dim,
                                  merged.f("embed.w")?, d,
                                  merged.f("embed.b")?);
    let pos = merged.f("pos")?;
    for row in 0..n {
        let t = row % l;
        for j in 0..d {
            h[row * d + j] += pos[t * d + j];
        }
    }

    for blk in 0..shape.depth {
        let pre = format!("blk{blk}.");
        let lora_y = |inp: &[f32], rows: usize, in_dim: usize,
                      wname: String, bname: String, o: usize|
                      -> Result<Vec<f32>> {
            let a = lora.f(&format!("{wname}.lora_a"))?;
            let bm = lora.f(&format!("{wname}.lora_b"))?;
            Ok(qlinear_lora_y(inp, rows, in_dim, merged.f(&wname)?, o,
                              merged.f(&bname)?, a, bm, r))
        };
        let (hn, _) = layers::layernorm_fwd(&h, n, d,
                                            merged.f(&format!("{pre}ln1.g"))?,
                                            merged.f(&format!("{pre}ln1.b"))?);
        let qkv = lora_y(&hn, n, d, format!("{pre}attn.wqkv"),
                         format!("{pre}attn.bqkv"), 3 * d)?;
        let mut q = vec![0.0f32; n * d];
        let mut k = vec![0.0f32; n * d];
        let mut v = vec![0.0f32; n * d];
        for row in 0..n {
            for j in 0..d {
                q[row * d + j] = qkv[row * 3 * d + j];
                k[row * d + j] = qkv[row * 3 * d + d + j];
                v[row * d + j] = qkv[row * 3 * d + 2 * d + j];
            }
        }
        let (att, _) = layers::attention_fwd(&q, &k, &v, b, l, d,
                                             shape.heads, false);
        let proj = lora_y(&att, n, d, format!("{pre}attn.wo"),
                          format!("{pre}attn.bo"), d)?;
        for (hv, pv) in h.iter_mut().zip(&proj) {
            *hv += pv;
        }
        let (hn, _) = layers::layernorm_fwd(&h, n, d,
                                            merged.f(&format!("{pre}ln2.g"))?,
                                            merged.f(&format!("{pre}ln2.b"))?);
        let f1 = lora_y(&hn, n, d, format!("{pre}fc1.w"),
                        format!("{pre}fc1.b"), m)?;
        let (g1, _) = layers::gelu_fwd(f1);
        let f2 = lora_y(&g1, n, m, format!("{pre}fc2.w"),
                        format!("{pre}fc2.b"), d)?;
        for (hv, fv) in h.iter_mut().zip(&f2) {
            *hv += fv;
        }
    }

    let (hn, _) = layers::layernorm_fwd(&h, n, d, merged.f("lnf.g")?,
                                        merged.f("lnf.b")?);
    let mut pooled = vec![0.0f32; b * d];
    for bi in 0..b {
        for t in 0..l {
            for j in 0..d {
                pooled[bi * d + j] += hn[(bi * l + t) * d + j] / l as f32;
            }
        }
    }
    Ok(layers::qlinear_y(&pooled, b, d, merged.f("head.w")?,
                         shape.n_classes, merged.f("head.b")?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    fn tiny_shape() -> ModelShape {
        ModelShape { arch: "vit", d_model: 16, depth: 1, heads: 2, seq: 16,
                     in_dim: 8, n_classes: 3, mlp_ratio: 2 }
    }

    fn lora_init(shape: &ModelShape, cfg: &LoraCfg, base: &[Value],
                 base_specs: &[TensorSpec], seed: u64) -> Vec<Value> {
        let mut rng = Pcg32::seeded(seed);
        trainable_specs(shape, cfg.r_lora)
            .iter()
            .map(|s| {
                if s.name.ends_with(".lora_a") {
                    let mut data = vec![0.0f32; s.numel()];
                    rng.fill_normal(&mut data, 0.0, 1.0 / s.shape[0] as f32);
                    Value::F32 { shape: s.shape.clone(), data }
                } else if s.name.ends_with(".lora_b") {
                    Value::F32 { shape: s.shape.clone(),
                                 data: vec![0.0; s.numel()] }
                } else {
                    let idx = base_specs
                        .iter()
                        .position(|b| b.name == s.name)
                        .unwrap();
                    base[idx].clone()
                }
            })
            .collect()
    }

    #[test]
    fn trainable_specs_shape() {
        let shape = tiny_shape();
        let specs = trainable_specs(&shape, 8);
        // 4 targets x 2 tensors per block + embed.w/b + head.w/b
        assert_eq!(specs.len(), 8 * shape.depth + 4);
        for w in specs.windows(2) {
            assert!(w[0].name < w[1].name);
        }
        let a = specs.iter().find(|s| s.name == "blk0.fc1.w.lora_a").unwrap();
        assert_eq!(a.shape, vec![8, shape.d_model]);
        let b = specs.iter().find(|s| s.name == "blk0.fc1.w.lora_b").unwrap();
        assert_eq!(b.shape, vec![shape.d_mlp(), 8]);
    }

    #[test]
    fn lora_tags_parse() {
        assert!(LoraCfg::parse("fp").unwrap().bcfg.variant == Variant::Fp);
        let c = LoraCfg::parse("hotfrozen").unwrap();
        assert!(c.hot_frozen && !c.hot_decomposed);
        let c = LoraCfg::parse("hotdec").unwrap();
        assert!(!c.hot_frozen && c.hot_decomposed);
        let c = LoraCfg::parse("hotboth").unwrap();
        assert!(c.hot_frozen && c.hot_decomposed);
        assert!(LoraCfg::parse("nope").is_err());
    }

    #[test]
    fn zero_b_makes_adapter_a_noop_and_grads_flow() {
        let shape = tiny_shape();
        let base_specs = presets::param_specs(&shape);
        let base = presets::init_values(&shape, 1);
        for tag in ["fp", "hotfrozen", "hotdec", "hotboth"] {
            let cfg = LoraCfg::parse(tag).unwrap();
            let trainable = lora_init(&shape, &cfg, &base, &base_specs, 2);
            let tspecs = trainable_specs(&shape, cfg.r_lora);
            let merged_vals: Vec<Value> = base.clone();
            let merged = Params::new(&base_specs, &merged_vals).unwrap();
            let lora_specs: Vec<TensorSpec> = tspecs
                .iter()
                .filter(|s| s.name.contains(".lora_"))
                .cloned()
                .collect();
            let lora_vals: Vec<Value> = tspecs
                .iter()
                .zip(&trainable)
                .filter(|(s, _)| s.name.contains(".lora_"))
                .map(|(_, v)| v.clone())
                .collect();
            let lora = Params::new(&lora_specs, &lora_vals).unwrap();
            let mut rng = Pcg32::seeded(3);
            let n = 4 * shape.seq * shape.in_dim;
            let x = Value::F32 {
                shape: vec![4, shape.seq, shape.in_dim],
                data: (0..n).map(|_| rng.normal()).collect(),
            };
            let y = Value::I32 {
                shape: vec![4],
                data: (0..4).map(|_| rng.below(3) as i32).collect(),
            };
            let mask = vec![0.0f32; shape.n_qlinears()];
            let out = lora_loss_and_grads(&shape, &cfg, &merged, &lora,
                                          &mask, &x, &y).unwrap();
            assert!(out.loss.is_finite(), "{tag}");
            // every trainable gets a grad; lora_a grads are zero when B=0
            // (g_a = scale·(gy B)ᵀ x and B starts at 0), lora_b's are not
            for s in &tspecs {
                let g = out.grads.get(&s.name)
                    .unwrap_or_else(|| panic!("{tag}: no grad {}", s.name));
                assert_eq!(g.len(), s.numel(), "{tag} {}", s.name);
                assert!(g.iter().all(|v| v.is_finite()), "{tag} {}", s.name);
            }
            let gb: f32 = out.grads["blk0.fc1.w.lora_b"]
                .iter()
                .map(|v| v.abs())
                .sum();
            assert!(gb > 0.0, "{tag}: lora_b grad must be nonzero");
        }
    }

    #[test]
    fn infer_logits_bit_identical_to_training_forward() {
        // The merged-adapter inference walk is the fused LoRA forward
        // minus the ctx writes — same GEMMs in the same order, so same
        // bits. Nonzero A *and* B so the adapters actually steer the
        // logits; all four tags cover LoRA x HOT on/off. One GEMM tier
        // per comparison: hold the kernels gate.
        let _gate = crate::kernels::pool::test_serial();
        let shape = tiny_shape();
        let base_specs = presets::param_specs(&shape);
        let base = presets::init_values(&shape, 4);
        for tag in ["fp", "hotfrozen", "hotdec", "hotboth"] {
            let cfg = LoraCfg::parse(tag).unwrap();
            let tspecs = trainable_specs(&shape, cfg.r_lora);
            let mut rng = Pcg32::seeded(5);
            let trainable: Vec<Value> = tspecs
                .iter()
                .map(|s| {
                    if s.name.contains(".lora_") {
                        let mut data = vec![0.0f32; s.numel()];
                        rng.fill_normal(&mut data, 0.0, 0.1);
                        Value::F32 { shape: s.shape.clone(), data }
                    } else {
                        let idx = base_specs
                            .iter()
                            .position(|b| b.name == s.name)
                            .unwrap();
                        base[idx].clone()
                    }
                })
                .collect();
            // merged/lora views exactly as the executor builds them
            let mut merged = Params::new(&base_specs, &base).unwrap();
            let mut lora = Params::from_pairs(std::iter::empty()).unwrap();
            for (s, v) in tspecs.iter().zip(&trainable) {
                if s.name.contains(".lora_") {
                    lora.insert(s.name.as_str(), v).unwrap();
                } else {
                    merged.insert(s.name.as_str(), v).unwrap();
                }
            }
            let mut drng = Pcg32::seeded(6);
            let n = 3 * shape.seq * shape.in_dim;
            let x = Value::F32 {
                shape: vec![3, shape.seq, shape.in_dim],
                data: (0..n).map(|_| drng.normal()).collect(),
            };
            let y = Value::I32 {
                shape: vec![3],
                data: (0..3).map(|_| drng.below(3) as i32).collect(),
            };
            let mask = vec![0.0f32; shape.n_qlinears()];
            let out = lora_loss_and_grads(&shape, &cfg, &merged, &lora,
                                          &mask, &x, &y).unwrap();
            let il = lora_infer_logits(&shape, &cfg, &merged, &lora, &x)
                .unwrap();
            assert_eq!(out.logits.len(), il.len(), "{tag}");
            for (i, (a, b)) in out.logits.iter().zip(&il).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(),
                           "{tag} logit[{i}]: {a} vs {b}");
            }
        }
    }
}
