//! `NativeBackend` — the pure-rust `Executor`: runs the full HOT
//! training loop (fused / split / accum, eval, LQS calibration, LoRA)
//! with zero external dependencies. Where the PJRT backend executes AOT
//! artifacts, this one executes the same math through the host-side
//! mirrors (`hadamard`, `quant`) plus the model/optimizer ports in this
//! module — the decomposition HOT's backward makes possible is exactly
//! what makes a from-scratch CPU backend tractable.

pub mod layers;
pub mod lora;
pub mod model;
pub mod optim;
pub mod presets;

use anyhow::{bail, ensure, Context, Result};

use crate::backend::{AdapterSet, Executor, ForwardOut, GradOut, LoraMeta,
                     StepKey, TrainState, WeightStore};
use crate::backend::native::layers::BackwardCfg;
use crate::backend::native::model::Params;
use crate::backend::native::presets::ModelShape;
use crate::runtime::manifest::Preset;
use crate::runtime::value::Value;

/// Seed for the deterministic initial parameters (the native analog of
/// the artifact init blobs, which aot.py generates with a fixed seed).
const INIT_SEED: u64 = 0;

struct Entry {
    name: String,
    shape: ModelShape,
    preset: Preset,
}

/// How many (store, preset) INT8 weight snapshots `infer_degraded`
/// keeps before evicting the oldest — bounds memory under tenant churn.
const QCACHE_CAP: usize = 64;

pub struct NativeBackend {
    entries: Vec<Entry>,
    /// INT8 weight snapshots for degraded serving, keyed by (first-slab
    /// data pointer, preset): a frozen store is quantized once, then
    /// every degraded request against it (including `share()`d views,
    /// which alias the same slabs) reuses the snapshot. `RefCell`
    /// because `Executor` methods take `&self`; the backend is
    /// deliberately not `Sync` — each serve worker owns its own.
    qcache: std::cell::RefCell<Vec<(usize, String,
                                    std::rc::Rc<model::QuantParams>)>>,
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl NativeBackend {
    /// `new` plus an explicit kernel thread budget (0 = one per core).
    /// The budget lands in the process-wide `kernels` pool that every
    /// GEMM/FWHT this backend executes routes through.
    pub fn with_threads(threads: usize) -> NativeBackend {
        crate::kernels::set_num_threads(threads);
        Self::new()
    }

    /// `new` plus an explicit SIMD toggle: `false` pins every kernel to
    /// the portable scalar tier, `true` restores the auto-detected
    /// AVX2/NEON tier (`kernels::dispatch`). The `HOT_SIMD=0`
    /// environment override wins over this knob. Like the thread
    /// budget, the setting is process-wide.
    pub fn with_simd(enabled: bool) -> NativeBackend {
        crate::kernels::set_simd_enabled(enabled);
        Self::new()
    }

    /// `new` plus the hot-path tracing gate (`obs`): `true` arms span
    /// recording and counters, `false` returns every probe to its
    /// single relaxed-atomic-load fast path. `HOT_TRACE=1` in the
    /// environment (applied by `obs::init_from_env`) is equivalent.
    /// Process-wide, like the thread/SIMD knobs. Tracing never changes
    /// numerics: recording is read-only on the data path.
    pub fn with_trace(enabled: bool) -> NativeBackend {
        crate::obs::set_trace_enabled(enabled);
        Self::new()
    }

    pub fn new() -> NativeBackend {
        let entries = presets::builtin_presets()
            .into_iter()
            .map(|(name, shape)| Entry {
                name: name.to_string(),
                preset: presets::to_preset(name, &shape),
                shape,
            })
            .collect();
        NativeBackend { entries, qcache: std::cell::RefCell::new(Vec::new()) }
    }

    /// The cached INT8 snapshot for (store, preset), built on first
    /// use. Slab identity (the first slab's data pointer) is the cache
    /// key: stores are immutable while shared, and serve tenants hold
    /// `share()`d views of one base, so they all hit one entry.
    fn quantized(&self, preset: &str, store: &WeightStore)
                 -> std::rc::Rc<model::QuantParams> {
        let key = store
            .iter()
            .next()
            .map(|(_, d)| d.as_ptr() as usize)
            .unwrap_or(0);
        let mut cache = self.qcache.borrow_mut();
        if let Some((_, _, qp)) =
            cache.iter().find(|(k, p, _)| *k == key && p == preset)
        {
            return qp.clone();
        }
        let qp = std::rc::Rc::new(model::QuantParams::from_store(store));
        if cache.len() >= QCACHE_CAP {
            cache.remove(0);
        }
        cache.push((key, preset.to_string(), qp.clone()));
        qp
    }

    fn entry(&self, name: &str) -> Result<&Entry> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .with_context(|| format!("unknown native preset {name:?}"))
    }

    fn parse(&self, key: &str) -> Result<StepKey> {
        StepKey::parse(key, &self.preset_names())
    }

    /// (entry, bcfg) for a tagged step key.
    fn step_ctx(&self, tag: &str, preset: &str) -> Result<(&Entry, BackwardCfg)> {
        Ok((self.entry(preset)?, BackwardCfg::parse(tag)?))
    }

    fn run_forward_backward(&self, tag: &str, preset: &str,
                            weights: &WeightStore, lqs_mask: &[f32],
                            x: &Value, y: &Value)
                            -> Result<(f32, f32, Vec<Value>)> {
        let (e, bcfg) = self.step_ctx(tag, preset)?;
        let p = Params::from_store(weights);
        let fwd = {
            let _sp = crate::obs::span(crate::obs::Span::Forward);
            model::forward(&e.shape, &bcfg, &p, lqs_mask, x, y)?
        };
        let grads = {
            let _sp = crate::obs::span(crate::obs::Span::Backward);
            model::backward(&e.shape, &bcfg, &p, lqs_mask, &fwd.ctxs, None)?
        };
        Ok((fwd.loss, fwd.acc,
            model::grads_to_values(&e.preset.params, grads)?))
    }

    /// In-place AdamW over the store's slabs and the state's moments —
    /// the native steady-state optimizer path. No slab is cloned; the
    /// call fails if any slab is currently shared (frozen) or the grad/
    /// moment arity disagrees with the preset.
    fn apply_adamw(&self, preset: &str, weights: &mut WeightStore,
                   grads: &[Value], state: &mut TrainState, step: f32,
                   lr: f32) -> Result<()> {
        let _sp = crate::obs::span(crate::obs::Span::OptStep);
        let specs = &self.entry(preset)?.preset.params;
        ensure!(weights.len() == specs.len() && grads.len() == specs.len()
                && state.m.len() == specs.len()
                && state.v.len() == specs.len(),
                "adamw arity mismatch: {} specs vs {}/{}/{}/{}", specs.len(),
                weights.len(), grads.len(), state.m.len(), state.v.len());
        for (i, spec) in specs.iter().enumerate() {
            let id = weights
                .id(&spec.name)
                .with_context(|| format!("store has no slab for {}",
                                         spec.name))?;
            optim::adamw_inplace(&spec.name, weights.slab_mut(id)?,
                                 grads[i].as_f32()?,
                                 state.m[i].as_f32_mut()?,
                                 state.v[i].as_f32_mut()?, step, lr)?;
        }
        Ok(())
    }
}

impl Executor for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn describe(&self) -> String {
        let names: Vec<&str> =
            self.entries.iter().map(|e| e.name.as_str()).collect();
        format!("native CPU backend — presets {names:?}; variants fp/hot/\
                 lbp/luq/int4 + single-path ablations; modes fused/split/\
                 accum, eval, calib, lora (no artifacts needed)")
    }

    fn preset_names(&self) -> Vec<String> {
        self.entries.iter().map(|e| e.name.clone()).collect()
    }

    fn preset(&self, name: &str) -> Result<Preset> {
        Ok(self.entry(name)?.preset.clone())
    }

    fn init_params(&self, preset: &str) -> Result<Vec<Value>> {
        Ok(presets::init_values(&self.entry(preset)?.shape, INIT_SEED))
    }

    fn default_batch(&self) -> usize {
        32
    }

    fn supports(&self, key: &str) -> bool {
        match self.parse(key) {
            Err(_) => false,
            Ok(StepKey::Train { tag, .. })
            | Ok(StepKey::Fwd { tag, .. })
            | Ok(StepKey::Bwd { tag, .. })
            | Ok(StepKey::Grad { tag, .. }) => BackwardCfg::parse(&tag).is_ok(),
            Ok(StepKey::Opt { .. }) | Ok(StepKey::Eval { .. })
            | Ok(StepKey::Infer { .. }) | Ok(StepKey::Calib { .. }) => true,
            Ok(StepKey::Lora { tag, preset }) => {
                lora::LoraCfg::parse(&tag).is_ok()
                    && self.entry(&preset)
                        .map(|e| e.shape.arch == "vit")
                        .unwrap_or(false)
            }
            Ok(StepKey::Kernel { name }) => {
                matches!(name.as_str(), "hq_demo" | "hla_demo")
            }
        }
    }

    fn key_batch(&self, _key: &str) -> Option<usize> {
        None // nothing is shape-static natively; the run config decides
    }

    fn train_step(&self, key: &str, weights: &mut WeightStore,
                  state: &mut TrainState, step: f32, lr: f32,
                  lqs_mask: &[f32], x: &Value, y: &Value)
                  -> Result<(f32, f32)> {
        let (tag, preset) = match self.parse(key)? {
            StepKey::Train { tag, preset } => (tag, preset),
            other => bail!("{key:?} is not a train step ({other:?})"),
        };
        let (loss, acc, grads) = self.run_forward_backward(
            &tag, &preset, weights, lqs_mask, x, y)?;
        self.apply_adamw(&preset, weights, &grads, state, step, lr)?;
        Ok((loss, acc))
    }

    fn forward_step(&self, key: &str, weights: &WeightStore,
                    lqs_mask: &[f32], x: &Value, y: &Value)
                    -> Result<ForwardOut> {
        let (tag, preset) = match self.parse(key)? {
            StepKey::Fwd { tag, preset } => (tag, preset),
            other => bail!("{key:?} is not a fwd step ({other:?})"),
        };
        let (e, bcfg) = self.step_ctx(&tag, &preset)?;
        let p = Params::from_store(weights);
        let fwd = {
            let _sp = crate::obs::span(crate::obs::Span::Forward);
            model::forward(&e.shape, &bcfg, &p, lqs_mask, x, y)?
        };
        let (ctx, ctx_specs) = model::flatten_ctx(fwd.ctxs);
        Ok(ForwardOut { loss: fwd.loss, acc: fwd.acc, ctx, ctx_specs })
    }

    fn backward_step(&self, key: &str, weights: &WeightStore,
                     lqs_mask: &[f32], x: &Value, ctx: Vec<Value>)
                     -> Result<Vec<Value>> {
        let (tag, preset) = match self.parse(key)? {
            StepKey::Bwd { tag, preset } => (tag, preset),
            other => bail!("{key:?} is not a bwd step ({other:?})"),
        };
        let (e, bcfg) = self.step_ctx(&tag, &preset)?;
        let p = Params::from_store(weights);
        ensure!(!x.shape().is_empty(), "model input must be batched");
        let b = x.shape()[0];
        let ctxs = model::parse_ctx(&e.shape, &bcfg, b, ctx)?;
        let grads = {
            let _sp = crate::obs::span(crate::obs::Span::Backward);
            model::backward(&e.shape, &bcfg, &p, lqs_mask, &ctxs, None)?
        };
        model::grads_to_values(&e.preset.params, grads)
    }

    fn grad_step(&self, key: &str, weights: &WeightStore, lqs_mask: &[f32],
                 x: &Value, y: &Value) -> Result<GradOut> {
        let (tag, preset) = match self.parse(key)? {
            StepKey::Grad { tag, preset } => (tag, preset),
            other => bail!("{key:?} is not a grad step ({other:?})"),
        };
        let (loss, acc, grads) = self.run_forward_backward(
            &tag, &preset, weights, lqs_mask, x, y)?;
        Ok(GradOut { grads, loss, acc })
    }

    fn opt_step(&self, key: &str, weights: &mut WeightStore,
                grads: &[Value], state: &mut TrainState, step: f32,
                lr: f32) -> Result<()> {
        let preset = match self.parse(key)? {
            StepKey::Opt { preset } => preset,
            other => bail!("{key:?} is not an opt step ({other:?})"),
        };
        self.apply_adamw(&preset, weights, grads, state, step, lr)
    }

    fn eval_step(&self, key: &str, weights: &WeightStore, x: &Value,
                 y: &Value) -> Result<(f32, f32)> {
        let preset = match self.parse(key)? {
            StepKey::Eval { preset } => preset,
            other => bail!("{key:?} is not an eval step ({other:?})"),
        };
        let e = self.entry(&preset)?;
        // ctx-free inference walk: held-out passes build no backward
        // state and run no quantize-for-backward epilogues
        let p = Params::from_store(weights);
        model::eval_infer(&e.shape, &p, x, y)
    }

    fn infer(&self, key: &str, weights: &WeightStore, x: &Value)
             -> Result<Value> {
        let preset = match self.parse(key)? {
            StepKey::Infer { preset } => preset,
            other => bail!("{key:?} is not an infer step ({other:?})"),
        };
        let e = self.entry(&preset)?;
        let p = Params::from_store(weights);
        model::fwd_infer(&e.shape, &p, x)
    }

    fn infer_degraded(&self, key: &str, weights: &WeightStore, x: &Value)
                      -> Result<Value> {
        let preset = match self.parse(key)? {
            StepKey::Infer { preset } => preset,
            other => bail!("{key:?} is not an infer step ({other:?})"),
        };
        let e = self.entry(&preset)?;
        let qp = self.quantized(&preset, weights);
        let p = Params::from_store(weights);
        model::fwd_infer_i8(&e.shape, &p, &qp, x)
    }

    fn calib_step(&self, key: &str, weights: &WeightStore, x: &Value,
                  y: &Value) -> Result<Vec<Vec<f32>>> {
        let preset = match self.parse(key)? {
            StepKey::Calib { preset } => preset,
            other => bail!("{key:?} is not a calib step ({other:?})"),
        };
        let e = self.entry(&preset)?;
        let p = Params::from_store(weights);
        model::calibrate(&e.shape, &p, x, y)
    }

    fn lora_meta(&self, key: &str) -> Result<LoraMeta> {
        let (tag, preset) = match self.parse(key)? {
            StepKey::Lora { tag, preset } => (tag, preset),
            other => bail!("{key:?} is not a lora step ({other:?})"),
        };
        let cfg = lora::LoraCfg::parse(&tag)?;
        let e = self.entry(&preset)?;
        ensure!(e.shape.arch == "vit", "LoRA targets the vit presets");
        Ok(LoraMeta {
            preset: preset.clone(),
            trainable: lora::trainable_specs(&e.shape, cfg.r_lora),
            batch: None,
        })
    }

    fn lora_step(&self, key: &str, adapters: &mut AdapterSet,
                 state: &mut TrainState, step: f32, lr: f32,
                 lqs_mask: &[f32], x: &Value, y: &Value)
                 -> Result<(f32, f32)> {
        let (tag, preset) = match self.parse(key)? {
            StepKey::Lora { tag, preset } => (tag, preset),
            other => bail!("{key:?} is not a lora step ({other:?})"),
        };
        let cfg = lora::LoraCfg::parse(&tag)?;
        let e = self.entry(&preset)?;
        let tspecs = lora::trainable_specs(&e.shape, cfg.r_lora);
        ensure!(adapters.trainable().len() == tspecs.len(),
                "{} trainable tensors given, lora step wants {}",
                adapters.trainable().len(), tspecs.len());
        ensure!(state.m.len() == tspecs.len()
                && state.v.len() == tspecs.len(),
                "lora moment arity mismatch");
        let (loss, acc, grads) = {
            // merged view: frozen base slabs + live embed/head overrides
            // — the base store is never copied, only borrowed
            ensure!(adapters.base().len() == e.preset.params.len(),
                    "base param arity mismatch");
            let mut merged = Params::from_store(adapters.base());
            let mut lp = Params::from_pairs(std::iter::empty())?;
            for (s, val) in tspecs.iter().zip(adapters.trainable()) {
                ensure!(val.shape() == s.shape.as_slice(),
                        "trainable {}: shape {:?} != spec {:?}", s.name,
                        val.shape(), s.shape);
                if s.name.contains(".lora_") {
                    lp.insert(s.name.as_str(), val)?;
                } else {
                    merged.insert(s.name.as_str(), val)?; // override wins
                }
            }
            let out = lora::lora_loss_and_grads(&e.shape, &cfg, &merged,
                                                &lp, lqs_mask, x, y)?;
            let grads = model::grads_to_values(&tspecs, out.grads)?;
            (out.loss, out.acc, grads)
        };
        // in-place AdamW over the tenant's overlay; the shared base
        // stays untouched (and stays frozen if other sessions hold it)
        let _sp = crate::obs::span(crate::obs::Span::OptStep);
        for (i, spec) in tspecs.iter().enumerate() {
            optim::adamw_inplace(&spec.name,
                                 adapters.trainable_mut()[i].as_f32_mut()?,
                                 grads[i].as_f32()?,
                                 state.m[i].as_f32_mut()?,
                                 state.v[i].as_f32_mut()?, step, lr)?;
        }
        Ok((loss, acc))
    }

    fn execute_raw(&self, key: &str, args: &[Value]) -> Result<Vec<Value>> {
        let name = match self.parse(key)? {
            StepKey::Kernel { name } => name,
            other => bail!("execute_raw on the native backend only runs \
                            kernel demos, not {other:?}"),
        };
        ensure!(args.len() == 2, "kernel {key}: {} args given, want 2",
                args.len());
        let a = args[0].as_f32()?;
        let b = args[1].as_f32()?;
        let (ash, bsh) = (args[0].shape(), args[1].shape());
        ensure!(ash.len() == 2 && bsh.len() == 2,
                "kernel {key}: operands must be 2-D, got {ash:?}/{bsh:?}");
        match name.as_str() {
            "hq_demo" => {
                // gy (n, o) x w (o, i) -> g_x (n, i), HT+INT4 on the
                // contracted dim (mirrors the Pallas hq kernel demo)
                let (n, o) = (ash[0], ash[1]);
                ensure!(bsh[0] == o, "hq demo: gy cols {o} != w rows {}",
                        bsh[0]);
                ensure!(o % 16 == 0, "hq demo: contracted dim must tile \
                                      into 16, got {o}");
                let i = bsh[1];
                let out = layers::hq_matmul(a, n, o, b, i, 4);
                Ok(vec![Value::F32 { shape: vec![n, i], data: out }])
            }
            "hla_demo" => {
                // gy (n, o) x x (n, i) -> g_w (o, i), HLA+INT8 along N
                let (n, o) = (ash[0], ash[1]);
                ensure!(bsh[0] == n, "hla demo: gy rows {n} != x rows {}",
                        bsh[0]);
                ensure!(n % 16 == 0, "hla demo: N must tile into 16, got {n}");
                let i = bsh[1];
                let cfg = BackwardCfg::default();
                let xa = layers::hla_compress(b, n, i, cfg.rank,
                                              cfg.abc_bits, cfg.criterion);
                let out = layers::hla_matmul(a, n, o, &xa, cfg.rank,
                                             cfg.gw_bits, false,
                                             cfg.criterion);
                Ok(vec![Value::F32 { shape: vec![o, i], data: out }])
            }
            other => bail!("unknown kernel demo {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::VisionDataset;
    use crate::util::prng::Pcg32;

    fn backend() -> NativeBackend {
        NativeBackend::new()
    }

    #[test]
    fn supports_the_artifact_key_families() {
        let b = backend();
        for key in ["train_hot_tiny", "train_fp_small", "train_hot_r4_tiny",
                    "train_hot_lm_tiny", "fwd_hot_tiny", "bwd_hot_tiny",
                    "grad_hot_tiny", "opt_tiny", "eval_lm_tiny", "calib_small",
                    "infer_tiny", "infer_lm_tiny",
                    "lora_hotfrozen_small", "lora_fp_small", "kernel_hq_demo",
                    "kernel_hla_demo", "train_gx_int_hla_tiny",
                    "train_hot_mlp_small"] {
            assert!(b.supports(key), "{key}");
        }
        for key in ["train_warp_tiny", "train_hot_nopreset", "kernel_nope",
                    "infer_nopreset", "lora_hotfrozen_lm_tiny"] {
            assert!(!b.supports(key), "{key}");
        }
        assert_eq!(b.key_batch("train_hot_tiny"), None);
    }

    #[test]
    fn init_matches_preset_specs() {
        let b = backend();
        for name in b.preset_names() {
            let p = b.preset(&name).unwrap();
            let init = b.init_params(&name).unwrap();
            assert_eq!(init.len(), p.params.len(), "{name}");
            for (v, s) in init.iter().zip(&p.params) {
                assert_eq!(v.shape(), s.shape.as_slice(), "{name} {}", s.name);
            }
        }
    }

    #[test]
    fn kernel_demos_execute_and_validate() {
        let b = backend();
        let mut rng = Pcg32::seeded(1);
        let gy = Value::F32 { shape: vec![32, 32],
                              data: (0..32 * 32).map(|_| rng.normal())
                                  .collect() };
        let w = Value::F32 { shape: vec![32, 16],
                             data: (0..32 * 16).map(|_| rng.normal())
                                 .collect() };
        let out = b.execute_raw("kernel_hq_demo", &[gy.clone(), w.clone()])
            .unwrap();
        assert_eq!(out[0].shape(), &[32, 16]);
        let out = b.execute_raw("kernel_hla_demo", &[gy.clone(), w.clone()])
            .unwrap();
        assert_eq!(out[0].shape(), &[32, 16]);
        assert!(b.execute_raw("kernel_hq_demo", &[]).is_err());
        assert!(b.execute_raw("no_such_artifact", &[]).is_err());
        let tiny = Value::F32 { shape: vec![2, 2], data: vec![0.0; 4] };
        assert!(b.execute_raw("kernel_hq_demo",
                              &[tiny.clone(), tiny.clone()]).is_err());
    }

    #[test]
    fn fused_steps_descend_on_tiny_vision() {
        let b = backend();
        let preset = b.preset("tiny").unwrap();
        let ds = VisionDataset::new(preset.model.seq, preset.model.in_dim,
                                    preset.model.n_classes, 0);
        let mut weights = b.init_store("tiny").unwrap();
        let mut state = TrainState::new(&preset.params, 0);
        let mask = vec![0.0f32; preset.qlinears.len()];
        let mut losses = Vec::new();
        for step in 0..12 {
            let (x, y) = ds.batch(0, step as u64, 8);
            let (loss, _) = b.train_step("train_hot_tiny", &mut weights,
                                         &mut state, step as f32 + 1.0,
                                         5e-3, &mask, &x, &y).unwrap();
            losses.push(loss);
        }
        assert!(losses.iter().all(|l| l.is_finite()), "{losses:?}");
        let tail: f32 = losses[9..].iter().sum::<f32>() / 3.0;
        assert!(tail < losses[0], "loss did not decrease: {losses:?}");
    }

    #[test]
    fn grad_plus_opt_equals_train_step() {
        let b = backend();
        let preset = b.preset("tiny").unwrap();
        let ds = VisionDataset::new(preset.model.seq, preset.model.in_dim,
                                    preset.model.n_classes, 1);
        let mut w1 = b.init_store("tiny").unwrap();
        let mut s1 = TrainState::new(&preset.params, 0);
        let mut w2 = b.init_store("tiny").unwrap();
        let mut s2 = TrainState::new(&preset.params, 0);
        let mask = vec![0.0f32; preset.qlinears.len()];
        let (x, y) = ds.batch(0, 0, 8);
        // fp is deterministic and ctx-identical across paths
        let (floss, _) = b.train_step("train_fp_tiny", &mut w1, &mut s1,
                                      1.0, 1e-3, &mask, &x, &y).unwrap();
        let g = b.grad_step("grad_fp_tiny", &w2, &mask, &x, &y).unwrap();
        b.opt_step("opt_tiny", &mut w2, &g.grads, &mut s2, 1.0, 1e-3)
            .unwrap();
        assert!((floss - g.loss).abs() < 1e-6);
        for ((s, a), (_, bb)) in w1.iter().zip(w2.iter()) {
            for (x0, x1) in a.iter().zip(bb) {
                assert!((x0 - x1).abs() < 1e-6, "{}", s.name);
            }
        }
    }

    #[test]
    fn infer_serves_from_shared_frozen_store() {
        let b = backend();
        let preset = b.preset("tiny").unwrap();
        let ds = VisionDataset::new(preset.model.seq, preset.model.in_dim,
                                    preset.model.n_classes, 2);
        let weights = b.init_store("tiny").unwrap();
        // a serving handle: frozen, pointer-shared, still inferable
        let serving = weights.share();
        let (x, _) = ds.batch(1, 0, 4);
        let logits = b.infer("infer_tiny", &serving, &x).unwrap();
        assert_eq!(logits.shape(), &[4, preset.model.n_classes]);
        assert!(logits.as_f32().unwrap().iter().all(|v| v.is_finite()));
        assert!(b.infer("train_hot_tiny", &serving, &x).is_err());
    }

    #[test]
    fn degraded_infer_is_finite_deterministic_and_tracks_f32() {
        use crate::data::LmDataset;
        let b = backend();
        let preset = b.preset("lm_tiny").unwrap();
        let ds = LmDataset::new(preset.model.seq, preset.model.in_dim, 3);
        let weights = b.init_store("lm_tiny").unwrap();
        let (x, _) = ds.batch(1, 0, 4);
        let exact = b.infer("infer_lm_tiny", &weights, &x).unwrap();
        let deg = b.infer_degraded("infer_lm_tiny", &weights, &x).unwrap();
        assert_eq!(deg.shape(), exact.shape());
        let (ef, df) = (exact.as_f32().unwrap(), deg.as_f32().unwrap());
        assert!(df.iter().all(|v| v.is_finite()));
        // approximate, but the INT8 tier must stay in the same ballpark
        let (mut num, mut den) = (0.0f64, 0.0f64);
        for (a, d) in ef.iter().zip(df) {
            num += ((a - d) as f64).powi(2);
            den += (*a as f64).powi(2);
        }
        assert!(num / den.max(1e-12) < 0.25,
                "int8 rel err {}", num / den.max(1e-12));
        // deterministic: replays bit-identically, including through a
        // share()d view (which must hit the same cached snapshot)
        let again = b.infer_degraded("infer_lm_tiny", &weights, &x).unwrap();
        assert_eq!(again.as_f32().unwrap(), df);
        let shared = weights.share();
        let via_share =
            b.infer_degraded("infer_lm_tiny", &shared, &x).unwrap();
        assert_eq!(via_share.as_f32().unwrap(), df);
        assert_eq!(b.qcache.borrow().len(), 1, "share() views share one \
                                                snapshot");
        assert!(b.infer_degraded("train_hot_tiny", &weights, &x).is_err());
    }
}
