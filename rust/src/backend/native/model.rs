//! Native full-model forward/backward — the rust port of
//! python/compile/model.py's explicit manual backprop.
//!
//! `forward` returns (loss, acc, ctx-list); `backward` consumes the
//! ctx-list in reverse and produces the full gradient set. The ctx-list
//! is the paper's Fig-5 "CTX": in split mode its entries literally cross
//! the backend boundary as `Value`s and live in the coordinator's
//! `CtxStore` between the calls. Under HOT's ABC the entries arrive in
//! the packed storage format (qlinear x: HLA + per-row INT8/INT4
//! nibble codes; LN/attention/GELU/CE residuals: per-row INT8), with
//! GELU's tanh and the CE one-hot recomputed in the backward instead of
//! stored — see DESIGN.md §Memory for the schema table.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Context, Result};

use crate::backend::native::layers::{self, AttnCtx, BackwardCfg, CeCtx,
                                     GeluCtx, LnCtx, QlCtx, Variant};
use crate::backend::native::presets::ModelShape;
use crate::hadamard::{block_hla_axis0, fwht, BLOCK};
use crate::kernels;
use crate::quant;
use crate::runtime::manifest::{CtxSpec, TensorSpec};
use crate::runtime::value::Value;

// ---------------------------------------------------------------------------
// Parameter view (sorted-spec order -> by-name access)
// ---------------------------------------------------------------------------

/// One parameter as the model walk sees it: a shape plus a borrowed f32
/// slice. Deliberately storage-agnostic — the borrow can come from a
/// `Value::F32`, a `WeightStore` slab, or any other f32 buffer, which is
/// what lets one forward/backward serve both the training path (Values)
/// and the Arc-shared serving path (slabs) without copies.
#[derive(Clone, Copy)]
pub struct PTensor<'a> {
    pub shape: &'a [usize],
    pub data: &'a [f32],
}

pub struct Params<'a> {
    by_name: BTreeMap<&'a str, PTensor<'a>>,
}

impl<'a> Params<'a> {
    pub fn new(specs: &'a [TensorSpec], values: &'a [Value]) -> Result<Params<'a>> {
        ensure!(specs.len() == values.len(),
                "{} params given, preset wants {}", values.len(), specs.len());
        let mut p = Params { by_name: BTreeMap::new() };
        for (s, v) in specs.iter().zip(values) {
            ensure!(v.shape() == s.shape.as_slice(),
                    "param {}: shape {:?} != spec {:?}", s.name, v.shape(),
                    s.shape);
            p.insert(s.name.as_str(), v)?;
        }
        Ok(p)
    }

    /// Borrow every slab of a `WeightStore` — the zero-copy serving
    /// path. The store stays frozen; the view is read-only by type.
    pub fn from_store(store: &'a crate::backend::state::WeightStore)
                      -> Params<'a> {
        let mut by_name = BTreeMap::new();
        for (s, d) in store.iter() {
            by_name.insert(s.name.as_str(),
                           PTensor { shape: &s.shape, data: d });
        }
        Params { by_name }
    }

    /// Insert or override one entry — how the LoRA step overlays
    /// trainable embed/head tensors on the frozen base view.
    pub fn insert(&mut self, name: &'a str, v: &'a Value) -> Result<()> {
        self.by_name
            .insert(name, PTensor { shape: v.shape(), data: v.as_f32()? });
        Ok(())
    }

    /// Build a view from explicit (name, value) pairs — later pairs win.
    pub fn from_pairs<I>(pairs: I) -> Result<Params<'a>>
    where
        I: IntoIterator<Item = (&'a str, &'a Value)>,
    {
        let mut p = Params { by_name: BTreeMap::new() };
        for (name, v) in pairs {
            p.insert(name, v)?;
        }
        Ok(p)
    }

    pub fn t(&self, name: &str) -> Result<PTensor<'a>> {
        self.by_name
            .get(name)
            .copied()
            .with_context(|| format!("no parameter {name:?}"))
    }

    pub fn f(&self, name: &str) -> Result<&'a [f32]> {
        Ok(self.t(name)?.data)
    }
}

// ---------------------------------------------------------------------------
// Ctx entries (one per saved-for-backward primitive, forward order)
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub struct CtxEntry {
    pub kind: &'static str, // "ql" | "ln" | "gelu" | "attn" | "ce"
    pub module: String,
    /// HLA rank of a rank-compressed "xq" payload (0 = none); stamped
    /// onto the flattened `CtxSpec`s so the `CtxStore` can account the
    /// true FP32-equivalent footprint from metadata.
    pub rank: usize,
    /// (key, tensor) pairs, sorted by key — the flattening contract.
    pub items: Vec<(&'static str, Value)>,
}

impl CtxEntry {
    fn item(&self, key: &str) -> Result<&Value> {
        self.items
            .iter()
            .find(|(k, _)| *k == key)
            .map(|(_, v)| v)
            .with_context(|| format!("ctx {}:{} has no item {key:?}",
                                     self.kind, self.module))
    }

    fn has(&self, key: &str) -> bool {
        self.items.iter().any(|(k, _)| *k == key)
    }
}

fn f32_value(shape: Vec<usize>, data: Vec<f32>) -> Value {
    Value::F32 { shape, data }
}

/// Storage width of the packed non-qlinear ctx buffers (LN x-hat,
/// attention internals, GELU input, CE probs). Fixed at INT8 — these
/// feed gradient paths directly and per-row INT8 keeps them within a
/// fraction of a percent of raw; `abc_bits` narrows only the
/// HLA-compressed qlinear payloads.
const CTX_PACK_BITS: u8 = 8;

/// Raw f32 or the per-row packed storage form, per the variant's ctx
/// schema (`BackwardCfg::packs_ctx`).
fn ctx_value(shape: Vec<usize>, data: Vec<f32>, pack: bool) -> Value {
    if pack {
        Value::quantize_rows(shape, &data, CTX_PACK_BITS)
    } else {
        Value::F32 { shape, data }
    }
}

fn entry_ql(module: String, ctx: QlCtx) -> CtxEntry {
    match (ctx.x, ctx.xq) {
        (None, Some(xa)) => {
            let rank = xa.rows * BLOCK / ctx.n;
            CtxEntry {
                kind: "ql",
                module,
                rank,
                items: vec![("xq", Value::QuantF32 {
                    shape: vec![xa.rows, xa.cols],
                    bits: xa.bits,
                    data: xa.data,
                    scales: xa.scales,
                })],
            }
        }
        (Some(x), _) => CtxEntry {
            kind: "ql",
            module,
            rank: 0,
            items: vec![("x", f32_value(vec![ctx.n, ctx.i], x))],
        },
        (None, None) => unreachable!("qlinear ctx holds x or xq"),
    }
}

fn entry_ln(module: String, ctx: LnCtx, rows: usize, d: usize, pack: bool)
            -> CtxEntry {
    CtxEntry {
        kind: "ln",
        module,
        rank: 0,
        items: vec![
            ("rstd", f32_value(vec![rows], ctx.rstd)),
            ("xhat", ctx_value(vec![rows, d], ctx.xhat, pack)),
        ],
    }
}

fn entry_gelu(module: String, ctx: GeluCtx, n: usize, m: usize, pack: bool)
              -> CtxEntry {
    // packed schema: t is a pure function of x — recomputed in the
    // backward instead of stored
    let items = if pack {
        vec![("x", ctx_value(vec![n, m], ctx.x, true))]
    } else {
        vec![
            ("t", f32_value(vec![n, m], ctx.t)),
            ("x", f32_value(vec![n, m], ctx.x)),
        ]
    };
    CtxEntry { kind: "gelu", module, rank: 0, items }
}

fn entry_attn(module: String, ctx: AttnCtx, b: usize, h: usize, l: usize,
              dh: usize, pack: bool) -> CtxEntry {
    CtxEntry {
        kind: "attn",
        module,
        rank: 0,
        items: vec![
            ("kh", ctx_value(vec![b, h, l, dh], ctx.kh, pack)),
            ("p", ctx_value(vec![b, h, l, l], ctx.p, pack)),
            ("qh", ctx_value(vec![b, h, l, dh], ctx.qh, pack)),
            ("vh", ctx_value(vec![b, h, l, dh], ctx.vh, pack)),
        ],
    }
}

fn entry_ce(module: String, ctx: CeCtx, labels: &[i32], n: usize, c: usize,
            pack: bool) -> CtxEntry {
    // packed schema: the one-hot is n·c·4 bytes standing for n labels —
    // store the labels and rebuild it in the backward
    let items = if pack {
        vec![
            ("labels", Value::I32 { shape: vec![n], data: labels.to_vec() }),
            ("p", ctx_value(vec![n, c], ctx.p, true)),
        ]
    } else {
        vec![
            ("onehot", f32_value(vec![n, c], ctx.onehot)),
            ("p", f32_value(vec![n, c], ctx.p)),
        ]
    };
    CtxEntry { kind: "ce", module, rank: 0, items }
}

// --- parsing back (split-mode backward) -------------------------------------

fn ql_ctx_of(e: &CtxEntry, rank: usize) -> Result<QlCtx> {
    if e.has("xq") {
        let xqv = e.item("xq")?;
        let shape = xqv.shape();
        ensure!(shape.len() == 2, "xq must be 2-D");
        let (nc, i) = (shape[0], shape[1]);
        ensure!(nc % rank == 0, "xq rows {nc} don't tile into rank {rank}");
        let xa = match xqv {
            Value::QuantF32 { bits, data, scales, .. } => crate::quant::AbcAct {
                rows: nc, cols: i, bits: *bits, data: data.clone(),
                scales: scales.clone(),
            },
            v => bail!("xq must be the packed QuantF32 wire format, got {:?}",
                       v.dtype()),
        };
        Ok(QlCtx { x: None, xq: Some(xa), n: nc / rank * BLOCK, i })
    } else {
        let xv = e.item("x")?;
        let shape = xv.shape();
        ensure!(shape.len() == 2, "ctx x must be 2-D");
        Ok(QlCtx { x: Some(xv.as_f32()?.to_vec()), xq: None,
                   n: shape[0], i: shape[1] })
    }
}

fn ln_ctx_of(e: &CtxEntry) -> Result<LnCtx> {
    Ok(LnCtx {
        xhat: e.item("xhat")?.to_f32()?,
        rstd: e.item("rstd")?.as_f32()?.to_vec(),
    })
}

fn gelu_ctx_of(e: &CtxEntry) -> Result<GeluCtx> {
    let x = e.item("x")?.to_f32()?;
    let t = if e.has("t") {
        e.item("t")?.to_f32()?
    } else {
        layers::gelu_t(&x) // packed schema: t recomputed, not stored
    };
    Ok(GeluCtx { x, t })
}

fn attn_ctx_of(e: &CtxEntry) -> Result<AttnCtx> {
    Ok(AttnCtx {
        qh: e.item("qh")?.to_f32()?,
        kh: e.item("kh")?.to_f32()?,
        vh: e.item("vh")?.to_f32()?,
        p: e.item("p")?.to_f32()?,
    })
}

fn ce_ctx_of(e: &CtxEntry) -> Result<(CeCtx, usize, usize)> {
    let pv = e.item("p")?;
    let shape = pv.shape().to_vec();
    ensure!(shape.len() == 2, "ce ctx p must be 2-D");
    let (n, c) = (shape[0], shape[1]);
    let onehot = if e.has("onehot") {
        e.item("onehot")?.as_f32()?.to_vec()
    } else {
        // packed schema stores the labels; rebuild the one-hot
        let labels = e.item("labels")?.as_i32()?;
        ensure!(labels.len() == n, "ce labels length {} != {n}", labels.len());
        let mut oh = vec![0.0f32; n * c];
        for (r, &lab) in labels.iter().enumerate() {
            ensure!((0..c as i32).contains(&lab), "label {lab} outside {c}");
            oh[r * c + lab as usize] = 1.0;
        }
        oh
    };
    Ok((CeCtx { p: pv.to_f32()?, onehot }, n, c))
}

/// Flatten ctx entries into Values + manifest-style specs (the split-mode
/// boundary format the `CtxStore` accounts for).
pub fn flatten_ctx(ctxs: Vec<CtxEntry>) -> (Vec<Value>, Vec<CtxSpec>) {
    let mut values = Vec::new();
    let mut specs = Vec::new();
    for e in ctxs {
        for (key, v) in e.items {
            specs.push(CtxSpec {
                module: e.module.clone(),
                kind: e.kind.to_string(),
                key: key.to_string(),
                shape: v.shape().to_vec(),
                dtype: v.dtype(),
                index: values.len(),
                rank: if key == "xq" { e.rank } else { 0 },
            });
            values.push(v);
        }
    }
    (values, specs)
}

/// The static ctx schema for (shape, cfg, batch): (kind, module, keys).
/// Both split-mode endpoints derive it independently, so nothing about
/// entry boundaries needs to cross the wire.
pub fn ctx_layout(shape: &ModelShape, cfg: &BackwardCfg, b: usize)
                  -> Vec<(&'static str, String, Vec<&'static str>)> {
    let n = b * shape.seq;
    let packed = cfg.packs_ctx();
    let ql_keys = |rows: usize| -> Vec<&'static str> {
        if cfg.compresses(rows) {
            vec!["xq"]
        } else {
            vec!["x"]
        }
    };
    let gelu_keys: Vec<&'static str> =
        if packed { vec!["x"] } else { vec!["t", "x"] };
    let ce_keys: Vec<&'static str> =
        if packed { vec!["labels", "p"] } else { vec!["onehot", "p"] };
    let mut out = Vec::new();
    out.push(("ql", "embed".to_string(), ql_keys(n)));
    for i in 0..shape.depth {
        let pre = format!("blk{i}.");
        if shape.has_attention() {
            out.push(("ln", format!("{pre}ln1"), vec!["rstd", "xhat"]));
            out.push(("ql", format!("{pre}qkv"), ql_keys(n)));
            out.push(("attn", format!("{pre}attn"),
                      vec!["kh", "p", "qh", "vh"]));
            out.push(("ql", format!("{pre}proj"), ql_keys(n)));
        }
        out.push(("ln", format!("{pre}ln2"), vec!["rstd", "xhat"]));
        out.push(("ql", format!("{pre}fc1"), ql_keys(n)));
        out.push(("gelu", format!("{pre}gelu"), gelu_keys.clone()));
        out.push(("ql", format!("{pre}fc2"), ql_keys(n)));
    }
    out.push(("ln", "lnf".to_string(), vec!["rstd", "xhat"]));
    let head_rows = if shape.arch == "lm" { n } else { b };
    out.push(("ql", "head".to_string(), ql_keys(head_rows)));
    out.push(("ce", "loss".to_string(), ce_keys));
    out
}

/// Rebuild ctx entries from the flat Value list (split-mode backward).
pub fn parse_ctx(shape: &ModelShape, cfg: &BackwardCfg, b: usize,
                 flat: Vec<Value>) -> Result<Vec<CtxEntry>> {
    let layout = ctx_layout(shape, cfg, b);
    let want: usize = layout.iter().map(|(_, _, keys)| keys.len()).sum();
    ensure!(flat.len() == want,
            "{} ctx values given, schema wants {want}", flat.len());
    let mut it = flat.into_iter();
    let mut out = Vec::with_capacity(layout.len());
    for (kind, module, keys) in layout {
        let rank = if keys.contains(&"xq") { cfg.rank } else { 0 };
        let items: Vec<(&'static str, Value)> = keys
            .into_iter()
            .map(|k| (k, it.next().expect("length checked above")))
            .collect();
        out.push(CtxEntry { kind, module, rank, items });
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Forward
// ---------------------------------------------------------------------------

pub struct FwdOut {
    pub loss: f32,
    pub acc: f32,
    /// Pre-softmax head outputs, ((b*seq, c) lm / (b, c) otherwise) —
    /// kept so the fwd_infer parity tests can pin bit-identity against
    /// the training walk.
    pub logits: Vec<f32>,
    pub ctxs: Vec<CtxEntry>,
}

/// Decode the model input into flattened (B*L, in_dim) features and
/// return (features, batch). LM token ids are one-hot embedded so every
/// trainable matmul stays on the HOT path (model.py `_embed_input`).
fn embed_input(shape: &ModelShape, x: &Value) -> Result<(Vec<f32>, usize)> {
    let (l, i) = (shape.seq, shape.in_dim);
    if shape.arch == "lm" {
        let dims = x.shape();
        ensure!(dims.len() == 2 && dims[1] == l,
                "lm input must be (b, {l}) tokens, got {dims:?}");
        let b = dims[0];
        let toks = match x {
            Value::I32 { data, .. } => data,
            _ => bail!("lm input must be i32 tokens"),
        };
        let mut xf = vec![0.0f32; b * l * i];
        for (r, &t) in toks.iter().enumerate() {
            ensure!((0..i as i32).contains(&t), "token {t} outside vocab {i}");
            xf[r * i + t as usize] = 1.0;
        }
        Ok((xf, b))
    } else {
        let dims = x.shape();
        ensure!(dims.len() == 3 && dims[1] == l && dims[2] == i,
                "input must be (b, {l}, {i}), got {dims:?}");
        Ok((x.as_f32()?.to_vec(), dims[0]))
    }
}

fn labels_of(shape: &ModelShape, y: &Value, b: usize) -> Result<Vec<i32>> {
    let data = match y {
        Value::I32 { data, .. } => data,
        _ => bail!("labels must be i32"),
    };
    if shape.arch == "lm" {
        ensure!(y.shape() == [b, shape.seq].as_slice(),
                "lm labels must be (b, seq)");
    } else {
        ensure!(y.shape() == [b].as_slice(), "labels must be (b,)");
    }
    Ok(data.clone())
}

pub fn forward(shape: &ModelShape, cfg: &BackwardCfg, p: &Params,
               lqs_mask: &[f32], x: &Value, y: &Value) -> Result<FwdOut> {
    ensure!(lqs_mask.len() == shape.n_qlinears(),
            "lqs mask length {} != {}", lqs_mask.len(), shape.n_qlinears());
    let (d, l, m) = (shape.d_model, shape.seq, shape.d_mlp());
    let (xf, b) = embed_input(shape, x)?;
    let labels = labels_of(shape, y, b)?;
    let n = b * l;
    let packed = cfg.packs_ctx();
    let mut ctxs: Vec<CtxEntry> = Vec::new();

    // embed + positional encoding
    crate::obs::set_layer("embed");
    let (mut h, ql) = layers::qlinear_fwd(xf, n, shape.in_dim,
                                          p.f("embed.w")?, d,
                                          p.f("embed.b")?, cfg);
    ctxs.push(entry_ql("embed".into(), ql));
    let pos = p.f("pos")?;
    for r in 0..n {
        let t = r % l;
        let row = &mut h[r * d..(r + 1) * d];
        for (v, pv) in row.iter_mut().zip(&pos[t * d..(t + 1) * d]) {
            *v += pv;
        }
    }

    for blk in 0..shape.depth {
        let pre = format!("blk{blk}.");
        if shape.has_attention() {
            let (hn, ln) = layers::layernorm_fwd(
                &h, n, d, p.f(&format!("{pre}ln1.g"))?,
                p.f(&format!("{pre}ln1.b"))?);
            ctxs.push(entry_ln(format!("{pre}ln1"), ln, n, d, packed));
            if crate::obs::enabled() {
                crate::obs::set_layer(&format!("{pre}qkv"));
            }
            let (qkv, ql) = layers::qlinear_fwd(
                hn, n, d, p.f(&format!("{pre}attn.wqkv"))?, 3 * d,
                p.f(&format!("{pre}attn.bqkv"))?, cfg);
            ctxs.push(entry_ql(format!("{pre}qkv"), ql));
            let mut q = vec![0.0f32; n * d];
            let mut k = vec![0.0f32; n * d];
            let mut v = vec![0.0f32; n * d];
            for r in 0..n {
                for j in 0..d {
                    q[r * d + j] = qkv[r * 3 * d + j];
                    k[r * d + j] = qkv[r * 3 * d + d + j];
                    v[r * d + j] = qkv[r * 3 * d + 2 * d + j];
                }
            }
            let (att, actx) = layers::attention_fwd(
                &q, &k, &v, b, l, d, shape.heads, shape.arch == "lm");
            ctxs.push(entry_attn(format!("{pre}attn"), actx, b, shape.heads,
                                 l, d / shape.heads, packed));
            if crate::obs::enabled() {
                crate::obs::set_layer(&format!("{pre}proj"));
            }
            let (proj, ql) = layers::qlinear_fwd(
                att, n, d, p.f(&format!("{pre}attn.wo"))?, d,
                p.f(&format!("{pre}attn.bo"))?, cfg);
            ctxs.push(entry_ql(format!("{pre}proj"), ql));
            for (hv, pv) in h.iter_mut().zip(&proj) {
                *hv += pv;
            }
        }
        let (hn, ln) = layers::layernorm_fwd(
            &h, n, d, p.f(&format!("{pre}ln2.g"))?,
            p.f(&format!("{pre}ln2.b"))?);
        ctxs.push(entry_ln(format!("{pre}ln2"), ln, n, d, packed));
        if crate::obs::enabled() {
            crate::obs::set_layer(&format!("{pre}fc1"));
        }
        let (f1, ql) = layers::qlinear_fwd(
            hn, n, d, p.f(&format!("{pre}fc1.w"))?, m,
            p.f(&format!("{pre}fc1.b"))?, cfg);
        ctxs.push(entry_ql(format!("{pre}fc1"), ql));
        let (g1, gc) = layers::gelu_fwd(f1);
        ctxs.push(entry_gelu(format!("{pre}gelu"), gc, n, m, packed));
        if crate::obs::enabled() {
            crate::obs::set_layer(&format!("{pre}fc2"));
        }
        let (f2, ql) = layers::qlinear_fwd(
            g1, n, m, p.f(&format!("{pre}fc2.w"))?, d,
            p.f(&format!("{pre}fc2.b"))?, cfg);
        ctxs.push(entry_ql(format!("{pre}fc2"), ql));
        for (hv, fv) in h.iter_mut().zip(&f2) {
            *hv += fv;
        }
    }

    let (hn, ln) = layers::layernorm_fwd(&h, n, d, p.f("lnf.g")?,
                                         p.f("lnf.b")?);
    ctxs.push(entry_ln("lnf".into(), ln, n, d, packed));

    let c = shape.n_classes;
    crate::obs::set_layer("head");
    let (loss, acc, ce, logits) = if shape.arch == "lm" {
        let (logits, ql) = layers::qlinear_fwd(hn, n, d, p.f("head.w")?, c,
                                               p.f("head.b")?, cfg);
        ctxs.push(entry_ql("head".into(), ql));
        let (loss, acc, ce) = layers::softmax_xent_fwd(&logits, n, c, &labels);
        (loss, acc, ce, logits)
    } else {
        let mut pooled = vec![0.0f32; b * d];
        for bi in 0..b {
            for t in 0..l {
                let row = &hn[(bi * l + t) * d..(bi * l + t + 1) * d];
                let dst = &mut pooled[bi * d..(bi + 1) * d];
                for (pv, hv) in dst.iter_mut().zip(row) {
                    *pv += hv / l as f32;
                }
            }
        }
        let (logits, ql) = layers::qlinear_fwd(pooled, b, d, p.f("head.w")?,
                                               c, p.f("head.b")?, cfg);
        ctxs.push(entry_ql("head".into(), ql));
        let (loss, acc, ce) = layers::softmax_xent_fwd(&logits, b, c, &labels);
        (loss, acc, ce, logits)
    };
    ctxs.push(entry_ce("loss".into(), ce, &labels,
                       if shape.arch == "lm" { n } else { b }, c, packed));
    Ok(FwdOut { loss, acc, logits, ctxs })
}

// ---------------------------------------------------------------------------
// Inference-only forward (no saved-for-backward state)
// ---------------------------------------------------------------------------

/// The forward walk with every ctx push and quantize-for-backward
/// epilogue removed. HOT's forward is always exact FP32, so this is the
/// *same* arithmetic as `forward` — same GEMM calls in the same order —
/// and the logits are bit-identical to the training walk's for every
/// variant (pinned by the parity property test below). What changes is
/// what it *doesn't* do: no `hla_compress`, no `quantize_rows`, no ctx
/// materialization, so obs quant counters stay flat and a serving
/// session needs nothing but a frozen `WeightStore` view.
///
/// Returns (logits, b) with logits ((b*seq, c) lm / (b, c) otherwise).
fn infer_logits(shape: &ModelShape, p: &Params, x: &Value)
                -> Result<(Vec<f32>, usize)> {
    let (d, l, m) = (shape.d_model, shape.seq, shape.d_mlp());
    let (xf, b) = embed_input(shape, x)?;
    let n = b * l;

    // embed + positional encoding
    let mut h = layers::qlinear_y(&xf, n, shape.in_dim, p.f("embed.w")?, d,
                                  p.f("embed.b")?);
    let pos = p.f("pos")?;
    for r in 0..n {
        let t = r % l;
        let row = &mut h[r * d..(r + 1) * d];
        for (v, pv) in row.iter_mut().zip(&pos[t * d..(t + 1) * d]) {
            *v += pv;
        }
    }

    for blk in 0..shape.depth {
        let pre = format!("blk{blk}.");
        if shape.has_attention() {
            let (hn, _) = layers::layernorm_fwd(
                &h, n, d, p.f(&format!("{pre}ln1.g"))?,
                p.f(&format!("{pre}ln1.b"))?);
            let qkv = layers::qlinear_y(
                &hn, n, d, p.f(&format!("{pre}attn.wqkv"))?, 3 * d,
                p.f(&format!("{pre}attn.bqkv"))?);
            let mut q = vec![0.0f32; n * d];
            let mut k = vec![0.0f32; n * d];
            let mut v = vec![0.0f32; n * d];
            for r in 0..n {
                for j in 0..d {
                    q[r * d + j] = qkv[r * 3 * d + j];
                    k[r * d + j] = qkv[r * 3 * d + d + j];
                    v[r * d + j] = qkv[r * 3 * d + 2 * d + j];
                }
            }
            let (att, _) = layers::attention_fwd(
                &q, &k, &v, b, l, d, shape.heads, shape.arch == "lm");
            let proj = layers::qlinear_y(
                &att, n, d, p.f(&format!("{pre}attn.wo"))?, d,
                p.f(&format!("{pre}attn.bo"))?);
            for (hv, pv) in h.iter_mut().zip(&proj) {
                *hv += pv;
            }
        }
        let (hn, _) = layers::layernorm_fwd(
            &h, n, d, p.f(&format!("{pre}ln2.g"))?,
            p.f(&format!("{pre}ln2.b"))?);
        let f1 = layers::qlinear_y(&hn, n, d, p.f(&format!("{pre}fc1.w"))?,
                                   m, p.f(&format!("{pre}fc1.b"))?);
        let (g1, _) = layers::gelu_fwd(f1);
        let f2 = layers::qlinear_y(&g1, n, m, p.f(&format!("{pre}fc2.w"))?,
                                   d, p.f(&format!("{pre}fc2.b"))?);
        for (hv, fv) in h.iter_mut().zip(&f2) {
            *hv += fv;
        }
    }

    let (hn, _) = layers::layernorm_fwd(&h, n, d, p.f("lnf.g")?,
                                        p.f("lnf.b")?);
    let c = shape.n_classes;
    let logits = if shape.arch == "lm" {
        layers::qlinear_y(&hn, n, d, p.f("head.w")?, c, p.f("head.b")?)
    } else {
        let mut pooled = vec![0.0f32; b * d];
        for bi in 0..b {
            for t in 0..l {
                let row = &hn[(bi * l + t) * d..(bi * l + t + 1) * d];
                let dst = &mut pooled[bi * d..(bi + 1) * d];
                for (pv, hv) in dst.iter_mut().zip(row) {
                    *pv += hv / l as f32;
                }
            }
        }
        layers::qlinear_y(&pooled, b, d, p.f("head.w")?, c, p.f("head.b")?)
    };
    Ok((logits, b))
}

/// Inference-only forward: batched logits from a frozen parameter view,
/// zero saved-for-backward state. Output shape (b, seq, classes) for lm,
/// (b, classes) otherwise.
pub fn fwd_infer(shape: &ModelShape, p: &Params, x: &Value) -> Result<Value> {
    let (logits, b) = infer_logits(shape, p, x)?;
    let out_shape = if shape.arch == "lm" {
        vec![b, shape.seq, shape.n_classes]
    } else {
        vec![b, shape.n_classes]
    };
    Ok(Value::F32 { shape: out_shape, data: logits })
}

/// Eval through the inference walk: (loss, acc) with no ctx writes and
/// no quantization — what `eval_step` routes through so held-out passes
/// stop paying (and stop *recording*) the quantize-for-backward tax.
pub fn eval_infer(shape: &ModelShape, p: &Params, x: &Value, y: &Value)
                  -> Result<(f32, f32)> {
    let (logits, b) = infer_logits(shape, p, x)?;
    let labels = labels_of(shape, y, b)?;
    let rows = if shape.arch == "lm" { b * shape.seq } else { b };
    let (loss, acc, _) =
        layers::softmax_xent_fwd(&logits, rows, shape.n_classes, &labels);
    Ok((loss, acc))
}

// ---------------------------------------------------------------------------
// Degraded inference: INT8 weights through the int GEMM tiers
// ---------------------------------------------------------------------------

/// Per-tensor INT8 snapshot of a store's GEMM weights, pre-transposed
/// to (i, o) so the int kernels run NN against row-major activations
/// (there is no i8 NT kernel). Built once when serving degrades under
/// sustained overload: the weights are frozen, so the quantize +
/// transpose cost amortizes over every degraded request, and the
/// backend caches the snapshot per (store, preset). Biases, LayerNorm
/// parameters and the positional table stay exact f32 — they are
/// vector adds, not GEMMs, and carry none of the FLOP cost.
pub struct QuantParams {
    /// name -> ((i, o)-layout INT8 codes, per-tensor min-max scale)
    w: BTreeMap<String, (Vec<i8>, f32)>,
}

impl QuantParams {
    /// The tensors that feed `qlinear_y` in the inference walk.
    fn is_gemm_weight(name: &str) -> bool {
        name.ends_with(".w") || name.ends_with(".wqkv")
            || name.ends_with(".wo")
    }

    /// Quantize + transpose every GEMM weight of `store` (per-tensor
    /// min-max 8-bit, the same scales `gx_q4_noht` uses on the
    /// backward's int path).
    pub fn from_store(store: &crate::backend::state::WeightStore)
                      -> QuantParams {
        let mut w = BTreeMap::new();
        for (spec, data) in store.iter() {
            if !Self::is_gemm_weight(&spec.name) || spec.shape.len() != 2 {
                continue;
            }
            let (o, i) = (spec.shape[0], spec.shape[1]);
            let s = quant::minmax_scale(data, 8);
            let q = quant::quantize_ps(data, s, 8);
            let mut qt = vec![0i8; i * o];
            for r in 0..o {
                for c in 0..i {
                    qt[c * o + r] = q[r * i + c];
                }
            }
            w.insert(spec.name.clone(), (qt, s));
        }
        QuantParams { w }
    }

    fn get(&self, name: &str) -> Result<(&[i8], f32)> {
        self.w
            .get(name)
            .map(|(q, s)| (q.as_slice(), *s))
            .with_context(|| format!("no quantized weight {name:?}"))
    }
}

/// `infer_logits` with every `qlinear_y` routed through the INT8
/// kernel tier (`layers::qlinear_y_i8`). Same walk, same non-GEMM ops
/// in f32 — only the GEMMs trade precision for the int tier's
/// throughput. Logits are approximate but deterministic.
fn infer_logits_i8(shape: &ModelShape, p: &Params, qp: &QuantParams,
                   x: &Value) -> Result<(Vec<f32>, usize)> {
    let (d, l, m) = (shape.d_model, shape.seq, shape.d_mlp());
    let (xf, b) = embed_input(shape, x)?;
    let n = b * l;
    let qy = |x: &[f32], n: usize, i: usize, name: &str, o: usize,
              bias: &[f32]| -> Result<Vec<f32>> {
        let (wq, s) = qp.get(name)?;
        Ok(layers::qlinear_y_i8(x, n, i, wq, s, o, bias))
    };

    let mut h = qy(&xf, n, shape.in_dim, "embed.w", d, p.f("embed.b")?)?;
    let pos = p.f("pos")?;
    for r in 0..n {
        let t = r % l;
        let row = &mut h[r * d..(r + 1) * d];
        for (v, pv) in row.iter_mut().zip(&pos[t * d..(t + 1) * d]) {
            *v += pv;
        }
    }

    for blk in 0..shape.depth {
        let pre = format!("blk{blk}.");
        if shape.has_attention() {
            let (hn, _) = layers::layernorm_fwd(
                &h, n, d, p.f(&format!("{pre}ln1.g"))?,
                p.f(&format!("{pre}ln1.b"))?);
            let qkv = qy(&hn, n, d, &format!("{pre}attn.wqkv"), 3 * d,
                         p.f(&format!("{pre}attn.bqkv"))?)?;
            let mut q = vec![0.0f32; n * d];
            let mut k = vec![0.0f32; n * d];
            let mut v = vec![0.0f32; n * d];
            for r in 0..n {
                for j in 0..d {
                    q[r * d + j] = qkv[r * 3 * d + j];
                    k[r * d + j] = qkv[r * 3 * d + d + j];
                    v[r * d + j] = qkv[r * 3 * d + 2 * d + j];
                }
            }
            let (att, _) = layers::attention_fwd(
                &q, &k, &v, b, l, d, shape.heads, shape.arch == "lm");
            let proj = qy(&att, n, d, &format!("{pre}attn.wo"), d,
                          p.f(&format!("{pre}attn.bo"))?)?;
            for (hv, pv) in h.iter_mut().zip(&proj) {
                *hv += pv;
            }
        }
        let (hn, _) = layers::layernorm_fwd(
            &h, n, d, p.f(&format!("{pre}ln2.g"))?,
            p.f(&format!("{pre}ln2.b"))?);
        let f1 = qy(&hn, n, d, &format!("{pre}fc1.w"), m,
                    p.f(&format!("{pre}fc1.b"))?)?;
        let (g1, _) = layers::gelu_fwd(f1);
        let f2 = qy(&g1, n, m, &format!("{pre}fc2.w"), d,
                    p.f(&format!("{pre}fc2.b"))?)?;
        for (hv, fv) in h.iter_mut().zip(&f2) {
            *hv += fv;
        }
    }

    let (hn, _) = layers::layernorm_fwd(&h, n, d, p.f("lnf.g")?,
                                        p.f("lnf.b")?);
    let c = shape.n_classes;
    let logits = if shape.arch == "lm" {
        qy(&hn, n, d, "head.w", c, p.f("head.b")?)?
    } else {
        let mut pooled = vec![0.0f32; b * d];
        for bi in 0..b {
            for t in 0..l {
                let row = &hn[(bi * l + t) * d..(bi * l + t + 1) * d];
                let dst = &mut pooled[bi * d..(bi + 1) * d];
                for (pv, hv) in dst.iter_mut().zip(row) {
                    *pv += hv / l as f32;
                }
            }
        }
        qy(&pooled, b, d, "head.w", c, p.f("head.b")?)?
    };
    Ok((logits, b))
}

/// Degraded inference-only forward: same contract as [`fwd_infer`] but
/// the GEMMs run INT8 — the middle rung of the serving degradation
/// ladder between full-precision service and load shedding.
pub fn fwd_infer_i8(shape: &ModelShape, p: &Params, qp: &QuantParams,
                    x: &Value) -> Result<Value> {
    let (logits, b) = infer_logits_i8(shape, p, qp, x)?;
    let out_shape = if shape.arch == "lm" {
        vec![b, shape.seq, shape.n_classes]
    } else {
        vec![b, shape.n_classes]
    };
    Ok(Value::F32 { shape: out_shape, data: logits })
}

// ---------------------------------------------------------------------------
// Backward (walks ctxs in reverse; mirrors forward exactly)
// ---------------------------------------------------------------------------

/// Raw material for the LQS calibration diagnostics: one entry per
/// qlinear in *reverse* model order (model.py's `diag_sink`).
pub struct QlDiag {
    pub wname: String,
    pub gy: Vec<f32>,
    pub n: usize,
    pub o: usize,
    pub x: Vec<f32>,
    pub i: usize,
}

struct Walker<'a> {
    ctxs: &'a [CtxEntry],
    flags: Vec<f32>,
    pos: usize,
}

impl<'a> Walker<'a> {
    fn new(ctxs: &'a [CtxEntry], lqs_mask: &[f32]) -> Walker<'a> {
        let mut flags = vec![0.0f32; ctxs.len()];
        let mut qi = 0usize;
        for (idx, e) in ctxs.iter().enumerate() {
            if e.kind == "ql" {
                flags[idx] = lqs_mask.get(qi).copied().unwrap_or(0.0);
                qi += 1;
            }
        }
        Walker { ctxs, flags, pos: ctxs.len() }
    }

    fn take(&mut self, kind: &str) -> Result<(&'a CtxEntry, f32)> {
        ensure!(self.pos > 0, "ctx walk underflow (wanted {kind})");
        self.pos -= 1;
        let e = &self.ctxs[self.pos];
        ensure!(e.kind == kind, "ctx walk: expected {kind}, got {} ({})",
                e.kind, e.module);
        Ok((e, self.flags[self.pos]))
    }
}

#[allow(clippy::too_many_arguments)]
fn ql_backward(gy: &[f32], n: usize, o: usize, p: &Params, wname: &str,
               bname: &str, entry: &CtxEntry, cfg: &BackwardCfg, flag: f32,
               need_gx: bool, grads: &mut BTreeMap<String, Vec<f32>>,
               diag: &mut Option<&mut Vec<QlDiag>>)
               -> Result<Option<Vec<f32>>> {
    let wv = p.t(wname)?;
    ensure!(wv.shape.len() == 2 && wv.shape[0] == o,
            "{wname}: shape {:?} incompatible with gy cols {o}", wv.shape);
    let i = wv.shape[1];
    let ctx = ql_ctx_of(entry, cfg.rank)?;
    ensure!(ctx.n == n && ctx.i == i,
            "{wname}: ctx dims ({}, {}) != ({n}, {i})", ctx.n, ctx.i);
    if let Some(sink) = diag.as_deref_mut() {
        let x = ctx.x.clone().with_context(
            || format!("{wname}: calibration needs raw FP ctx"))?;
        sink.push(QlDiag { wname: wname.to_string(), gy: gy.to_vec(), n, o,
                           x, i });
    }
    // attribute quantizer telemetry (the hla_compress epilogues the
    // backward may run on gy) to the same module name the forward used
    crate::obs::set_layer(&entry.module);
    let (gx, gw, gb) =
        layers::qlinear_bwd(gy, n, o, wv.data, i, &ctx, cfg, flag,
                            need_gx);
    grads.insert(wname.to_string(), gw);
    grads.insert(bname.to_string(), gb);
    Ok(gx)
}

/// Full-model manual backprop; returns grads keyed like params.
pub fn backward(shape: &ModelShape, cfg: &BackwardCfg, p: &Params,
                lqs_mask: &[f32], ctxs: &[CtxEntry],
                mut diag: Option<&mut Vec<QlDiag>>)
                -> Result<BTreeMap<String, Vec<f32>>> {
    let (d, l) = (shape.d_model, shape.seq);
    let mut grads: BTreeMap<String, Vec<f32>> = BTreeMap::new();
    let mut w = Walker::new(ctxs, lqs_mask);

    // --- loss & head ------------------------------------------------------
    let (ce_entry, _) = w.take("ce")?;
    let (ce, ce_rows, c) = ce_ctx_of(ce_entry)?;
    let g_logits = layers::softmax_xent_bwd(&ce, ce_rows);

    let (head_entry, head_flag) = w.take("ql")?;
    let g_pooled_or_seq = ql_backward(&g_logits, ce_rows, c, p, "head.w",
                                      "head.b", head_entry, cfg, head_flag,
                                      true, &mut grads, &mut diag)?
        .expect("head g_x requested");

    let b = if shape.arch == "lm" { ce_rows / l } else { ce_rows };
    let n = b * l;

    let (lnf_entry, _) = w.take("ln")?;
    let g_hn: Vec<f32> = if shape.arch == "lm" {
        g_pooled_or_seq
    } else {
        let mut out = vec![0.0f32; n * d];
        for bi in 0..b {
            for t in 0..l {
                let src = &g_pooled_or_seq[bi * d..(bi + 1) * d];
                let dst = &mut out[(bi * l + t) * d..(bi * l + t + 1) * d];
                for (o_, s) in dst.iter_mut().zip(src) {
                    *o_ = s / l as f32;
                }
            }
        }
        out
    };
    let lnf = ln_ctx_of(lnf_entry)?;
    let (mut g_h, gg, gb) = layers::layernorm_bwd(&g_hn, n, d, p.f("lnf.g")?,
                                                  &lnf);
    grads.insert("lnf.g".into(), gg);
    grads.insert("lnf.b".into(), gb);

    // --- blocks in reverse --------------------------------------------------
    for blk in (0..shape.depth).rev() {
        let pre = format!("blk{blk}.");
        let m = shape.d_mlp();
        // MLP sub-block
        let (fc2_entry, f2_flag) = w.take("ql")?;
        let g_f2in = ql_backward(&g_h, n, d, p, &format!("{pre}fc2.w"),
                                 &format!("{pre}fc2.b"), fc2_entry, cfg,
                                 f2_flag, true, &mut grads, &mut diag)?
            .expect("fc2 g_x");
        let (gelu_entry, _) = w.take("gelu")?;
        let g_f1 = layers::gelu_bwd(&g_f2in, &gelu_ctx_of(gelu_entry)?);
        let (fc1_entry, f1_flag) = w.take("ql")?;
        let g_hn2 = ql_backward(&g_f1, n, m, p, &format!("{pre}fc1.w"),
                                &format!("{pre}fc1.b"), fc1_entry, cfg,
                                f1_flag, true, &mut grads, &mut diag)?
            .expect("fc1 g_x");
        let (ln2_entry, _) = w.take("ln")?;
        let (g_res, gg, gb) = layers::layernorm_bwd(
            &g_hn2, n, d, p.f(&format!("{pre}ln2.g"))?,
            &ln_ctx_of(ln2_entry)?);
        grads.insert(format!("{pre}ln2.g"), gg);
        grads.insert(format!("{pre}ln2.b"), gb);
        for (hv, rv) in g_h.iter_mut().zip(&g_res) {
            *hv += rv;
        }

        if shape.has_attention() {
            let (proj_entry, pr_flag) = w.take("ql")?;
            let g_att = ql_backward(&g_h, n, d, p, &format!("{pre}attn.wo"),
                                    &format!("{pre}attn.bo"), proj_entry, cfg,
                                    pr_flag, true, &mut grads, &mut diag)?
                .expect("proj g_x");
            let (attn_entry, _) = w.take("attn")?;
            let actx = attn_ctx_of(attn_entry)?;
            let (g_q, g_k, g_v) = layers::attention_bwd(&g_att, &actx, b, l,
                                                        d, shape.heads);
            let mut g_qkv = vec![0.0f32; n * 3 * d];
            for r in 0..n {
                for j in 0..d {
                    g_qkv[r * 3 * d + j] = g_q[r * d + j];
                    g_qkv[r * 3 * d + d + j] = g_k[r * d + j];
                    g_qkv[r * 3 * d + 2 * d + j] = g_v[r * d + j];
                }
            }
            let (qkv_entry, qk_flag) = w.take("ql")?;
            let g_hn1 = ql_backward(&g_qkv, n, 3 * d, p,
                                    &format!("{pre}attn.wqkv"),
                                    &format!("{pre}attn.bqkv"), qkv_entry,
                                    cfg, qk_flag, true, &mut grads,
                                    &mut diag)?
                .expect("qkv g_x");
            let (ln1_entry, _) = w.take("ln")?;
            let (g_res, gg, gb) = layers::layernorm_bwd(
                &g_hn1, n, d, p.f(&format!("{pre}ln1.g"))?,
                &ln_ctx_of(ln1_entry)?);
            grads.insert(format!("{pre}ln1.g"), gg);
            grads.insert(format!("{pre}ln1.b"), gb);
            for (hv, rv) in g_h.iter_mut().zip(&g_res) {
                *hv += rv;
            }
        }
    }

    // --- positional encoding + embed ----------------------------------------
    let mut g_pos = vec![0.0f32; l * d];
    for r in 0..n {
        let t = r % l;
        let src = &g_h[r * d..(r + 1) * d];
        let dst = &mut g_pos[t * d..(t + 1) * d];
        for (o_, s) in dst.iter_mut().zip(src) {
            *o_ += s;
        }
    }
    grads.insert("pos".into(), g_pos);
    let (embed_entry, e_flag) = w.take("ql")?;
    ql_backward(&g_h, n, d, p, "embed.w", "embed.b", embed_entry, cfg,
                e_flag, false, &mut grads, &mut diag)?;
    ensure!(w.pos == 0, "{} unconsumed ctx entries", w.pos);
    Ok(grads)
}

/// Grads map -> Values in spec order.
pub fn grads_to_values(specs: &[TensorSpec],
                       mut grads: BTreeMap<String, Vec<f32>>)
                       -> Result<Vec<Value>> {
    let mut out = Vec::with_capacity(specs.len());
    for s in specs {
        let g = grads
            .remove(&s.name)
            .with_context(|| format!("backward produced no grad for {}",
                                     s.name))?;
        ensure!(g.len() == s.numel(), "grad {}: {} values, spec wants {}",
                s.name, g.len(), s.numel());
        out.push(Value::F32 { shape: s.shape.clone(), data: g });
    }
    ensure!(grads.is_empty(), "backward produced extra grads: {:?}",
            grads.keys().collect::<Vec<_>>());
    Ok(out)
}

// ---------------------------------------------------------------------------
// LQS calibration diagnostics (train.py make_calib_step)
// ---------------------------------------------------------------------------

fn mean_sq(xs: &[f32]) -> f64 {
    xs.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
        / xs.len().max(1) as f64
}

fn mean_sq_diff(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y) as f64) * ((x - y) as f64))
        .sum::<f64>()
        / a.len().max(1) as f64
}

/// The 7 per-qlinear diagnostic vectors in model order: mse_tensor,
/// mse_token, outlier, gx_err_hq, gx_err_hla, gw_err_hq, gw_err_hla.
pub fn calibrate(shape: &ModelShape, p: &Params, x: &Value, y: &Value)
                 -> Result<Vec<Vec<f32>>> {
    let fp = BackwardCfg { variant: Variant::Fp, ..Default::default() };
    let hot = BackwardCfg::default();
    let mask = vec![0.0f32; shape.n_qlinears()];
    let fwd = forward(shape, &fp, p, &mask, x, y)?;
    let mut sink: Vec<QlDiag> = Vec::new();
    backward(shape, &fp, p, &mask, &fwd.ctxs, Some(&mut sink))?;
    sink.reverse(); // reverse walk order -> model order

    let nq = shape.n_qlinears();
    ensure!(sink.len() == nq, "calib captured {} qlinears, want {nq}",
            sink.len());
    let mut outs = vec![vec![0.0f32; nq]; 7];
    for (q, dg) in sink.iter().enumerate() {
        let (n, o, i) = (dg.n, dg.o, dg.i);
        let wv = p.f(&dg.wname)?;
        let exact_gx = kernels::gemm_f32_nn(&dg.gy, wv, n, o, i);
        let exact_gw = kernels::gemm_f32_tn(&dg.gy, &dg.x, n, o, i);
        let gx_norm = mean_sq(&exact_gx) + 1e-12;
        let gw_norm = mean_sq(&exact_gw) + 1e-12;
        if n % BLOCK == 0 {
            let gc = block_hla_axis0(&dg.gy, n, o, hot.rank,
                                     hot.criterion);
            let nc = n / BLOCK * hot.rank;
            let fq_t = layers::fake_quant(&gc, hot.gw_bits);
            outs[0][q] = mean_sq_diff(&gc, &fq_t) as f32;
            let s_k = quant::minmax_scale_rows(&gc, nc, o, hot.gw_bits);
            let mut fq_k = vec![0.0f32; nc * o];
            for r in 0..nc {
                for cix in 0..o {
                    let qv = quant::quantize_ps_one(gc[r * o + cix], s_k[r],
                                                    hot.gw_bits);
                    fq_k[r * o + cix] = qv as f32 * s_k[r];
                }
            }
            outs[1][q] = mean_sq_diff(&gc, &fq_k) as f32;
            let ghla = layers::lbp_gw(&dg.gy, n, o, &dg.x, i, hot.rank);
            outs[6][q] = (mean_sq_diff(&ghla, &exact_gw) / gw_norm) as f32;
            let gx_hla = layers::lbp_gx(&dg.gy, n, o, wv, i, hot.rank);
            outs[4][q] = (mean_sq_diff(&gx_hla, &exact_gx) / gx_norm) as f32;
            let mut gy_t = dg.gy.clone();
            fwht::block_fwht_cols(&mut gy_t, n, o);
            let mut x_t = dg.x.clone();
            fwht::block_fwht_cols(&mut x_t, n, i);
            let gw_hq = kernels::gemm_f32_tn(&layers::fake_quant(&gy_t, 4),
                                             &layers::fake_quant(&x_t, 4), n,
                                             o, i);
            outs[5][q] = (mean_sq_diff(&gw_hq, &exact_gw) / gw_norm) as f32;
        }
        if o % BLOCK == 0 {
            let gx_hq = layers::hq_matmul(&dg.gy, n, o, wv, i, hot.gx_bits);
            outs[3][q] = (mean_sq_diff(&gx_hq, &exact_gx) / gx_norm) as f32;
        }
        // token-outlier structure of g_y (Fig 6/9)
        let mut mx = 0.0f64;
        let mut mean = 0.0f64;
        for r in 0..n {
            let amax = dg.gy[r * o..(r + 1) * o]
                .iter()
                .fold(0.0f32, |a, v| a.max(v.abs())) as f64;
            mx = mx.max(amax);
            mean += amax / n as f64;
        }
        outs[2][q] = (mx / (mean + 1e-12)) as f32;
    }
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::presets;
    use crate::util::prng::Pcg32;

    fn test_shape() -> ModelShape {
        ModelShape { arch: "vit", d_model: 16, depth: 1, heads: 2, seq: 16,
                     in_dim: 8, n_classes: 3, mlp_ratio: 2 }
    }

    fn batch(shape: &ModelShape, b: usize, seed: u64) -> (Value, Value) {
        let mut rng = Pcg32::seeded(seed);
        if shape.arch == "lm" {
            let n = b * shape.seq;
            let x: Vec<i32> = (0..n)
                .map(|_| rng.below(shape.in_dim as u32) as i32)
                .collect();
            let y: Vec<i32> = (0..n)
                .map(|_| rng.below(shape.n_classes as u32) as i32)
                .collect();
            (Value::I32 { shape: vec![b, shape.seq], data: x },
             Value::I32 { shape: vec![b, shape.seq], data: y })
        } else {
            let n = b * shape.seq * shape.in_dim;
            let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let y: Vec<i32> = (0..b)
                .map(|_| rng.below(shape.n_classes as u32) as i32)
                .collect();
            (Value::F32 { shape: vec![b, shape.seq, shape.in_dim], data: x },
             Value::I32 { shape: vec![b], data: y })
        }
    }

    fn fp_cfg() -> BackwardCfg {
        BackwardCfg { variant: Variant::Fp, ..Default::default() }
    }

    #[test]
    fn forward_runs_and_ctx_matches_layout() {
        let shape = test_shape();
        let specs = presets::param_specs(&shape);
        let values = presets::init_values(&shape, 1);
        let p = Params::new(&specs, &values).unwrap();
        let mask = vec![0.0; shape.n_qlinears()];
        let (x, y) = batch(&shape, 4, 2);
        for cfg in [fp_cfg(), BackwardCfg::default()] {
            let out = forward(&shape, &cfg, &p, &mask, &x, &y).unwrap();
            assert!(out.loss.is_finite() && out.loss > 0.0);
            assert!((0.0..=1.0).contains(&out.acc));
            let layout = ctx_layout(&shape, &cfg, 4);
            assert_eq!(out.ctxs.len(), layout.len());
            for (e, (kind, module, keys)) in out.ctxs.iter().zip(&layout) {
                assert_eq!(e.kind, *kind, "{module}");
                assert_eq!(&e.module, module);
                let got: Vec<&str> = e.items.iter().map(|(k, _)| *k).collect();
                assert_eq!(&got, keys, "{module}");
            }
        }
    }

    #[test]
    fn fp_backward_matches_directional_derivative() {
        let shape = test_shape();
        let specs = presets::param_specs(&shape);
        let values = presets::init_values(&shape, 3);
        let mask = vec![0.0; shape.n_qlinears()];
        let (x, y) = batch(&shape, 4, 4);
        let cfg = fp_cfg();

        let loss_of = |vals: &[Value]| -> f32 {
            let p = Params::new(&specs, vals).unwrap();
            forward(&shape, &cfg, &p, &mask, &x, &y).unwrap().loss
        };

        let p = Params::new(&specs, &values).unwrap();
        let fwd = forward(&shape, &cfg, &p, &mask, &x, &y).unwrap();
        let grads = backward(&shape, &cfg, &p, &mask, &fwd.ctxs, None).unwrap();

        // random unit direction over the full parameter set
        let mut rng = Pcg32::seeded(5);
        let dirs: Vec<Vec<f32>> = specs
            .iter()
            .map(|s| (0..s.numel()).map(|_| rng.normal()).collect())
            .collect();
        let norm: f32 = dirs
            .iter()
            .flat_map(|d| d.iter())
            .map(|v| v * v)
            .sum::<f32>()
            .sqrt();

        let mut analytic = 0.0f32;
        for (s, dir) in specs.iter().zip(&dirs) {
            let g = &grads[&s.name];
            for (gv, dv) in g.iter().zip(dir) {
                analytic += gv * dv / norm;
            }
        }

        let eps = 2e-3f32;
        let shift = |sign: f32| -> Vec<Value> {
            values
                .iter()
                .zip(&dirs)
                .map(|(v, dir)| {
                    let data = v
                        .as_f32()
                        .unwrap()
                        .iter()
                        .zip(dir)
                        .map(|(a, d)| a + sign * eps * d / norm)
                        .collect();
                    Value::F32 { shape: v.shape().to_vec(), data }
                })
                .collect()
        };
        let fd = (loss_of(&shift(1.0)) - loss_of(&shift(-1.0))) / (2.0 * eps);
        assert!((analytic - fd).abs() < 5e-3 + 0.05 * fd.abs(),
                "directional derivative mismatch: analytic {analytic} vs \
                 finite-diff {fd}");
    }

    #[test]
    fn split_roundtrip_matches_direct_backward() {
        // two forwards must agree closely; hold the kernels gate so a
        // concurrent set_simd_enabled toggle (the SIMD tier tests)
        // cannot flip the f32 GEMM tier between them
        let _gate = crate::kernels::pool::test_serial();
        let shape = test_shape();
        let specs = presets::param_specs(&shape);
        let values = presets::init_values(&shape, 6);
        let p = Params::new(&specs, &values).unwrap();
        let mask = vec![0.0; shape.n_qlinears()];
        let (x, y) = batch(&shape, 4, 7);
        let cfg = BackwardCfg::default(); // hot + abc

        let fwd = forward(&shape, &cfg, &p, &mask, &x, &y).unwrap();
        let direct = backward(&shape, &cfg, &p, &mask, &fwd.ctxs, None).unwrap();

        let fwd2 = forward(&shape, &cfg, &p, &mask, &x, &y).unwrap();
        let (flat, specs_ctx) = flatten_ctx(fwd2.ctxs);
        assert!(!flat.is_empty());
        assert_eq!(flat.len(), specs_ctx.len());
        // HOT+ABC: at least one int8 compressed entry crosses the boundary
        assert!(specs_ctx.iter().any(|s| s.key == "xq"));
        let parsed = parse_ctx(&shape, &cfg, 4, flat).unwrap();
        let roundtrip = backward(&shape, &cfg, &p, &mask, &parsed, None).unwrap();
        for (name, g) in &direct {
            let r = &roundtrip[name];
            for (a, b) in g.iter().zip(r) {
                assert!((a - b).abs() < 1e-6, "{name}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn prop_packed_store_roundtrip_grads_bit_identical() {
        // fwd -> packed ctx -> CtxStore put/take -> parse -> bwd must
        // match the in-memory backward bit for bit: the wire format
        // (nibble packing included) is storage-side only. Sweeps
        // odd/prime dims, ranks {4, 8, 16} and both payload widths.
        // Bit-identity across two forwards requires one GEMM tier for
        // the whole test: hold the kernels gate against concurrent
        // set_simd_enabled togglers.
        let _gate = crate::kernels::pool::test_serial();
        crate::util::proptest::check("packed ctx store roundtrip", 8, |case| {
            use crate::coordinator::ctx::CtxStore;
            let rank = [4usize, 8, 16][case.usize_in(0, 2)];
            let abc_bits = if case.rng.uniform() < 0.5 { 4u8 } else { 8 };
            let arch = ["vit", "mlp", "lm"][case.usize_in(0, 2)];
            let in_dim = if arch == "lm" {
                [13usize, 16, 17][case.usize_in(0, 2)]
            } else {
                [7usize, 11, 16][case.usize_in(0, 2)]
            };
            let b = [1usize, 3, 5][case.usize_in(0, 2)];
            let shape = ModelShape { arch, d_model: 16, depth: 1, heads: 2,
                                     seq: 16, in_dim, n_classes: 3,
                                     mlp_ratio: 2 };
            let cfg = BackwardCfg { rank, abc_bits, ..BackwardCfg::default() };
            let specs = presets::param_specs(&shape);
            let values = presets::init_values(&shape, 1 + rank as u64);
            let p = Params::new(&specs, &values).map_err(|e| e.to_string())?;
            let mask = vec![0.0f32; shape.n_qlinears()];
            let (x, y) = batch(&shape, b, 40 + b as u64);
            // the quantizer is pseudo-stochastic (keyed off input bits),
            // so two forwards on identical inputs emit identical ctx
            let fwd = forward(&shape, &cfg, &p, &mask, &x, &y)
                .map_err(|e| e.to_string())?;
            let direct = backward(&shape, &cfg, &p, &mask, &fwd.ctxs, None)
                .map_err(|e| e.to_string())?;
            let fwd2 = forward(&shape, &cfg, &p, &mask, &x, &y)
                .map_err(|e| e.to_string())?;
            let (flat, specs_ctx) = flatten_ctx(fwd2.ctxs);
            let mut store = CtxStore::new(0);
            store.put(0, flat, &specs_ctx).map_err(|e| e.to_string())?;
            let vals = store.take(0).map_err(|e| e.to_string())?;
            if store.stats().live_bytes != 0 {
                return Err("store leaked live bytes".into());
            }
            let parsed = parse_ctx(&shape, &cfg, b, vals)
                .map_err(|e| e.to_string())?;
            let rt = backward(&shape, &cfg, &p, &mask, &parsed, None)
                .map_err(|e| e.to_string())?;
            for (name, g) in &direct {
                let r = &rt[name];
                for (i, (a, bb)) in g.iter().zip(r).enumerate() {
                    if a.to_bits() != bb.to_bits() {
                        return Err(format!(
                            "{arch} r{rank} b{b} abc{abc_bits} {name}[{i}]: \
                             {a} != {bb}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_infer_logits_bit_identical_to_training_forward() {
        // `fwd_infer` is the training forward minus the ctx writes —
        // same GEMMs in the same order, so the logits must match bit
        // for bit. The forward is variant-independent (HOT only touches
        // gradients and storage), so the sweep covers every backward
        // family, odd/prime dims, all three archs and both SIMD tiers.
        // Bit-identity needs one GEMM tier per case: hold the kernels
        // gate against concurrent set_simd_enabled togglers.
        let _gate = crate::kernels::pool::test_serial();
        let prev = crate::kernels::simd_enabled();
        crate::util::proptest::check("infer/train forward parity", 12,
                                     |case| {
            let tag = ["fp", "lbp", "luq", "hot", "hot_noabc", "hot_abc4"]
                [case.usize_in(0, 5)];
            let cfg = BackwardCfg::parse(tag).map_err(|e| e.to_string())?;
            let arch = ["vit", "mlp", "lm"][case.usize_in(0, 2)];
            let in_dim = if arch == "lm" {
                [13usize, 16, 17][case.usize_in(0, 2)]
            } else {
                [7usize, 11, 16][case.usize_in(0, 2)]
            };
            let b = [1usize, 3, 5][case.usize_in(0, 2)];
            crate::kernels::set_simd_enabled(case.rng.uniform() < 0.5);
            let simd = crate::kernels::simd_enabled();
            let shape = ModelShape { arch, d_model: 16, depth: 1, heads: 2,
                                     seq: 16, in_dim, n_classes: 3,
                                     mlp_ratio: 2 };
            let specs = presets::param_specs(&shape);
            let values = presets::init_values(&shape, 21 + b as u64);
            let p = Params::new(&specs, &values).map_err(|e| e.to_string())?;
            let mask = vec![0.0f32; shape.n_qlinears()];
            let (x, y) = batch(&shape, b, 50 + b as u64);
            let fwd = forward(&shape, &cfg, &p, &mask, &x, &y)
                .map_err(|e| e.to_string())?;
            let iv = fwd_infer(&shape, &p, &x).map_err(|e| e.to_string())?;
            let want: Vec<usize> = if arch == "lm" {
                vec![b, shape.seq, shape.n_classes]
            } else {
                vec![b, shape.n_classes]
            };
            if iv.shape() != want.as_slice() {
                return Err(format!("infer shape {:?}, want {want:?}",
                                   iv.shape()));
            }
            let il = iv.as_f32().map_err(|e| e.to_string())?;
            if il.len() != fwd.logits.len() {
                return Err(format!("logit count {} != {}", il.len(),
                                   fwd.logits.len()));
            }
            for (i, (a, bb)) in fwd.logits.iter().zip(il).enumerate() {
                if a.to_bits() != bb.to_bits() {
                    return Err(format!(
                        "{arch} {tag} b{b} simd={simd} logit[{i}]: \
                         {a} != {bb}"));
                }
            }
            // eval through the infer walk reproduces the training loss
            let (el, ea) = eval_infer(&shape, &p, &x, &y)
                .map_err(|e| e.to_string())?;
            if el.to_bits() != fwd.loss.to_bits()
                || ea.to_bits() != fwd.acc.to_bits() {
                return Err(format!(
                    "{arch} {tag}: eval_infer ({el}, {ea}) != \
                     fwd ({}, {})", fwd.loss, fwd.acc));
            }
            Ok(())
        });
        crate::kernels::set_simd_enabled(prev);
    }

    #[test]
    fn store_view_forward_matches_value_view() {
        // Params::from_store borrows WeightStore slabs; the walk must
        // see the exact same bytes as through a Vec<Value> view.
        let _gate = crate::kernels::pool::test_serial();
        let shape = test_shape();
        let specs = presets::param_specs(&shape);
        let values = presets::init_values(&shape, 12);
        let ws = crate::backend::state::WeightStore::from_values(
            specs.clone(), values.clone()).unwrap();
        let mask = vec![0.0; shape.n_qlinears()];
        let (x, y) = batch(&shape, 3, 13);
        let cfg = BackwardCfg::default();
        let pv = Params::new(&specs, &values).unwrap();
        let ps = Params::from_store(&ws);
        let a = forward(&shape, &cfg, &pv, &mask, &x, &y).unwrap();
        let b = forward(&shape, &cfg, &ps, &mask, &x, &y).unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        for (u, v) in a.logits.iter().zip(&b.logits) {
            assert_eq!(u.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn grads_cover_every_param() {
        for arch in ["vit", "lm", "mlp"] {
            let shape = ModelShape { arch, d_model: 16, depth: 1, heads: 2,
                                     seq: 16, in_dim: 8, n_classes: 3,
                                     mlp_ratio: 2 };
            let specs = presets::param_specs(&shape);
            let values = presets::init_values(&shape, 8);
            let p = Params::new(&specs, &values).unwrap();
            let mask = vec![0.0; shape.n_qlinears()];
            let (x, y) = batch(&shape, 2, 9);
            let cfg = BackwardCfg::default();
            let fwd = forward(&shape, &cfg, &p, &mask, &x, &y).unwrap();
            let grads = backward(&shape, &cfg, &p, &mask, &fwd.ctxs, None)
                .unwrap();
            let gv = grads_to_values(&specs, grads).unwrap();
            assert_eq!(gv.len(), specs.len(), "{arch}");
            for (g, s) in gv.iter().zip(&specs) {
                assert_eq!(g.shape(), s.shape.as_slice(), "{arch} {}", s.name);
                assert!(g.as_f32().unwrap().iter().all(|v| v.is_finite()),
                        "{arch} {}", s.name);
            }
        }
    }

    #[test]
    fn calibration_vectors_are_sane() {
        let shape = test_shape();
        let specs = presets::param_specs(&shape);
        let values = presets::init_values(&shape, 10);
        let p = Params::new(&specs, &values).unwrap();
        let (x, y) = batch(&shape, 4, 11);
        let outs = calibrate(&shape, &p, &x, &y).unwrap();
        assert_eq!(outs.len(), 7);
        let nq = shape.n_qlinears();
        for v in &outs {
            assert_eq!(v.len(), nq);
            assert!(v.iter().all(|x| x.is_finite()));
        }
        // outlier ratio (max/mean of row maxima) is >= 1 by construction
        assert!(outs[2].iter().all(|&r| r >= 1.0 - 1e-5));
    }
}
