//! AdamW with decoupled weight decay — the native mirror of
//! train.py::adamw_update (the paper's fine-tuning optimizer). `step` is
//! the 1-based counter; `lr` the scheduled rate (the coordinator owns the
//! schedule, exactly as with the AOT artifacts).

use anyhow::{ensure, Result};

use crate::runtime::manifest::TensorSpec;
use crate::runtime::value::Value;

pub const BETA1: f32 = 0.9;
pub const BETA2: f32 = 0.999;
pub const EPS: f32 = 1e-8;
pub const WEIGHT_DECAY: f32 = 0.01;

/// No weight decay on norms/biases/pos (standard practice; train.py).
fn decay_of(name: &str) -> f32 {
    if name.ends_with(".b") || name.ends_with(".g") || name == "pos" {
        0.0
    } else {
        WEIGHT_DECAY
    }
}

/// One AdamW update over a single tensor, in place — THE definition of
/// the math. `weights.slab_mut` / `TrainState` moments route through
/// here in steady state (no reallocation, no slab clones); the
/// value-returning `adamw` below wraps it for callers that want fresh
/// buffers.
pub fn adamw_inplace(name: &str, p: &mut [f32], g: &[f32], m: &mut [f32],
                     v: &mut [f32], step: f32, lr: f32) -> Result<()> {
    ensure!(g.len() == p.len() && m.len() == p.len() && v.len() == p.len(),
            "{name}: adamw tensor lens {}/{}/{}/{} disagree", p.len(),
            g.len(), m.len(), v.len());
    let bc1 = 1.0 - BETA1.powf(step);
    let bc2 = 1.0 - BETA2.powf(step);
    let decay = decay_of(name);
    for j in 0..p.len() {
        let nm = BETA1 * m[j] + (1.0 - BETA1) * g[j];
        let nv = BETA2 * v[j] + (1.0 - BETA2) * g[j] * g[j];
        let upd = (nm / bc1) / ((nv / bc2).sqrt() + EPS);
        p[j] -= lr * (upd + decay * p[j]);
        m[j] = nm;
        v[j] = nv;
    }
    Ok(())
}

/// One AdamW step over a flat state; returns (params, m, v). Clones the
/// inputs and defers to `adamw_inplace` — the boundary-path flavor
/// (PJRT write-backs, tests); the trainer's native loop uses the
/// in-place form directly.
pub fn adamw(specs: &[TensorSpec], params: &[Value], grads: &[Value],
             m: &[Value], v: &[Value], step: f32, lr: f32)
             -> Result<(Vec<Value>, Vec<Value>, Vec<Value>)> {
    let _sp = crate::obs::span(crate::obs::Span::OptStep);
    ensure!(params.len() == specs.len() && grads.len() == specs.len()
            && m.len() == specs.len() && v.len() == specs.len(),
            "adamw arity mismatch: {} specs vs {}/{}/{}/{}", specs.len(),
            params.len(), grads.len(), m.len(), v.len());
    let mut new_p = Vec::with_capacity(specs.len());
    let mut new_m = Vec::with_capacity(specs.len());
    let mut new_v = Vec::with_capacity(specs.len());
    for (i, spec) in specs.iter().enumerate() {
        let mut pd = params[i].as_f32()?.to_vec();
        let g = grads[i].as_f32()?;
        let mut md = m[i].as_f32()?.to_vec();
        let mut vd = v[i].as_f32()?.to_vec();
        adamw_inplace(&spec.name, &mut pd, g, &mut md, &mut vd, step, lr)?;
        new_p.push(Value::F32 { shape: spec.shape.clone(), data: pd });
        new_m.push(Value::F32 { shape: spec.shape.clone(), data: md });
        new_v.push(Value::F32 { shape: spec.shape.clone(), data: vd });
    }
    Ok((new_p, new_m, new_v))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::DType;

    fn spec(name: &str, n: usize) -> TensorSpec {
        TensorSpec { name: name.into(), shape: vec![n], dtype: DType::F32 }
    }

    fn val(data: Vec<f32>) -> Value {
        Value::F32 { shape: vec![data.len()], data }
    }

    #[test]
    fn descends_against_gradient() {
        let specs = vec![spec("w.w", 2)];
        let params = vec![val(vec![1.0, -1.0])];
        let grads = vec![val(vec![1.0, -1.0])];
        let zeros = vec![val(vec![0.0, 0.0])];
        let (p, m, v) = adamw(&specs, &params, &grads, &zeros, &zeros,
                              1.0, 0.1).unwrap();
        let pd = p[0].as_f32().unwrap();
        assert!(pd[0] < 1.0, "positive grad must decrease param");
        assert!(pd[1] > -1.0, "negative grad must increase param");
        assert!(m[0].as_f32().unwrap()[0] > 0.0);
        assert!(v[0].as_f32().unwrap()[0] > 0.0);
    }

    #[test]
    fn decay_skips_biases_gains_pos() {
        assert_eq!(decay_of("blk0.fc1.w"), WEIGHT_DECAY);
        assert_eq!(decay_of("head.w"), WEIGHT_DECAY);
        assert_eq!(decay_of("blk0.attn.wqkv.lora_b"), WEIGHT_DECAY);
        assert_eq!(decay_of("embed.b"), 0.0);
        assert_eq!(decay_of("lnf.g"), 0.0);
        assert_eq!(decay_of("pos"), 0.0);
    }

    #[test]
    fn zero_grad_with_decay_shrinks_weights() {
        let specs = vec![spec("w.w", 1)];
        let params = vec![val(vec![2.0])];
        let grads = vec![val(vec![0.0])];
        let zeros = vec![val(vec![0.0])];
        let (p, _, _) = adamw(&specs, &params, &grads, &zeros, &zeros,
                              1.0, 0.1).unwrap();
        let got = p[0].as_f32().unwrap()[0];
        assert!(got < 2.0 && got > 1.9, "{got}");
    }

    #[test]
    fn bias_correction_uses_step() {
        // with m=v=0 and the same grad, step 1 and step 100 give the same
        // update direction; just verify both are finite and nonzero
        let specs = vec![spec("a.w", 1)];
        let params = vec![val(vec![0.0])];
        let grads = vec![val(vec![0.5])];
        let zeros = vec![val(vec![0.0])];
        for step in [1.0f32, 100.0] {
            let (p, _, _) = adamw(&specs, &params, &grads, &zeros, &zeros,
                                  step, 0.01).unwrap();
            let got = p[0].as_f32().unwrap()[0];
            assert!(got < 0.0 && got.is_finite(), "step {step}: {got}");
        }
    }

    #[test]
    fn arity_checked() {
        let specs = vec![spec("a.w", 1), spec("b.w", 1)];
        let one = vec![val(vec![0.0])];
        assert!(adamw(&specs, &one, &one, &one, &one, 1.0, 0.1).is_err());
    }
}
