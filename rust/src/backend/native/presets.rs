//! Native model presets — the rust mirror of python/compile/config.py's
//! `PRESETS` plus the parameter-pytree layout of model.py. Parameter
//! vectors everywhere in the repo are flattened in sorted-name order (the
//! same convention aot.py bakes into the artifact manifest), so the two
//! backends interoperate on checkpoints and run configs.

use anyhow::{bail, Result};

use crate::runtime::manifest::{DType, ModelMeta, Preset, TensorSpec};
use crate::runtime::value::Value;
use crate::util::prng::Pcg32;

/// Architecture + dimensions of one preset (config.py `ModelConfig`).
#[derive(Debug, Clone)]
pub struct ModelShape {
    pub arch: &'static str, // "vit" | "lm" | "mlp"
    pub d_model: usize,
    pub depth: usize,
    pub heads: usize,
    pub seq: usize,
    pub in_dim: usize,
    pub n_classes: usize,
    pub mlp_ratio: usize,
}

impl ModelShape {
    pub fn d_mlp(&self) -> usize {
        self.d_model * self.mlp_ratio
    }

    pub fn has_attention(&self) -> bool {
        matches!(self.arch, "vit" | "lm")
    }

    pub fn n_qlinears(&self) -> usize {
        let per_block = if self.has_attention() { 4 } else { 2 };
        2 + per_block * self.depth
    }
}

/// The preset table (config.py PRESETS, verbatim dimensions).
pub fn builtin_presets() -> Vec<(&'static str, ModelShape)> {
    vec![
        ("tiny", ModelShape { arch: "vit", d_model: 32, depth: 2, heads: 2,
                              seq: 16, in_dim: 16, n_classes: 4, mlp_ratio: 2 }),
        ("small", ModelShape { arch: "vit", d_model: 96, depth: 4, heads: 4,
                               seq: 32, in_dim: 48, n_classes: 16, mlp_ratio: 4 }),
        ("base", ModelShape { arch: "vit", d_model: 256, depth: 8, heads: 8,
                              seq: 64, in_dim: 96, n_classes: 32, mlp_ratio: 4 }),
        ("lm_tiny", ModelShape { arch: "lm", d_model: 64, depth: 2, heads: 2,
                                 seq: 32, in_dim: 128, n_classes: 128,
                                 mlp_ratio: 2 }),
        ("lm_small", ModelShape { arch: "lm", d_model: 128, depth: 4, heads: 4,
                                  seq: 64, in_dim: 256, n_classes: 256,
                                  mlp_ratio: 4 }),
        ("mlp_small", ModelShape { arch: "mlp", d_model: 96, depth: 4, heads: 1,
                                   seq: 32, in_dim: 48, n_classes: 16,
                                   mlp_ratio: 4 }),
    ]
}

/// (name, shape) pairs of every parameter, *unsorted* (model.py layout).
fn raw_param_shapes(s: &ModelShape) -> Vec<(String, Vec<usize>)> {
    let (d, m, l) = (s.d_model, s.d_mlp(), s.seq);
    let mut p: Vec<(String, Vec<usize>)> = vec![
        ("embed.w".into(), vec![d, s.in_dim]),
        ("embed.b".into(), vec![d]),
        ("pos".into(), vec![l, d]),
        ("lnf.g".into(), vec![d]),
        ("lnf.b".into(), vec![d]),
        ("head.w".into(), vec![s.n_classes, d]),
        ("head.b".into(), vec![s.n_classes]),
    ];
    for i in 0..s.depth {
        let pre = format!("blk{i}.");
        p.push((format!("{pre}ln2.g"), vec![d]));
        p.push((format!("{pre}ln2.b"), vec![d]));
        p.push((format!("{pre}fc1.w"), vec![m, d]));
        p.push((format!("{pre}fc1.b"), vec![m]));
        p.push((format!("{pre}fc2.w"), vec![d, m]));
        p.push((format!("{pre}fc2.b"), vec![d]));
        if s.has_attention() {
            p.push((format!("{pre}ln1.g"), vec![d]));
            p.push((format!("{pre}ln1.b"), vec![d]));
            p.push((format!("{pre}attn.wqkv"), vec![3 * d, d]));
            p.push((format!("{pre}attn.bqkv"), vec![3 * d]));
            p.push((format!("{pre}attn.wo"), vec![d, d]));
            p.push((format!("{pre}attn.bo"), vec![d]));
        }
    }
    p
}

/// Parameter specs in manifest (sorted-name) order.
pub fn param_specs(s: &ModelShape) -> Vec<TensorSpec> {
    let mut shapes = raw_param_shapes(s);
    shapes.sort_by(|a, b| a.0.cmp(&b.0));
    shapes
        .into_iter()
        .map(|(name, shape)| TensorSpec { name, shape, dtype: DType::F32 })
        .collect()
}

/// LQS-mask ordering of the quantized linears (model.py qlinear_names).
pub fn qlinear_names(s: &ModelShape) -> Vec<String> {
    let mut names = vec!["embed".to_string()];
    for i in 0..s.depth {
        if s.has_attention() {
            names.push(format!("blk{i}.qkv"));
            names.push(format!("blk{i}.proj"));
        }
        names.push(format!("blk{i}.fc1"));
        names.push(format!("blk{i}.fc2"));
    }
    names.push("head".to_string());
    names
}

/// Manifest-compatible `Preset` view of a native preset.
pub fn to_preset(name: &str, s: &ModelShape) -> Preset {
    Preset {
        name: name.to_string(),
        model: ModelMeta {
            arch: s.arch.to_string(),
            d_model: s.d_model,
            depth: s.depth,
            heads: s.heads,
            seq: s.seq,
            in_dim: s.in_dim,
            n_classes: s.n_classes,
        },
        params: param_specs(s),
        qlinears: qlinear_names(s),
        // native presets need no on-disk init blob; init_values() below
        // generates the deterministic seed state instead
        init_blob: String::new(),
    }
}

/// Deterministic initial parameters (sorted-spec order). Dense weights
/// get Glorot-style N(0, sqrt(2/(o+i))), `pos` N(0, 0.02), norm gains 1,
/// everything else 0 — the same scheme as model.py init_params (exact
/// bytes differ across backends; only the distribution matters).
pub fn init_values(s: &ModelShape, seed: u64) -> Vec<Value> {
    let mut rng = Pcg32::new(seed, 0x1417);
    param_specs(s)
        .iter()
        .map(|spec| {
            let n = spec.numel();
            let mut data = vec![0.0f32; n];
            let name = spec.name.as_str();
            if name == "pos" {
                rng.fill_normal(&mut data, 0.0, 0.02);
            } else if name.ends_with(".g") {
                data.iter_mut().for_each(|v| *v = 1.0);
            } else if spec.shape.len() == 2 {
                let (o, i) = (spec.shape[0], spec.shape[1]);
                let std = (2.0 / (o + i) as f32).sqrt();
                rng.fill_normal(&mut data, 0.0, std);
            }
            // 1-D non-gain tensors (biases) stay zero
            Value::F32 { shape: spec.shape.clone(), data }
        })
        .collect()
}

/// Fetch a builtin shape by preset name.
pub fn shape_of(name: &str) -> Result<ModelShape> {
    for (n, s) in builtin_presets() {
        if n == name {
            return Ok(s);
        }
    }
    bail!("unknown native preset {name:?} (have: {:?})",
          builtin_presets().iter().map(|(n, _)| *n).collect::<Vec<_>>())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_sorted_and_complete() {
        let s = shape_of("tiny").unwrap();
        let specs = param_specs(&s);
        for w in specs.windows(2) {
            assert!(w[0].name < w[1].name, "{} !< {}", w[0].name, w[1].name);
        }
        // vit: 7 global + 12 per block
        assert_eq!(specs.len(), 7 + 12 * s.depth);
        let total: usize = specs.iter().map(TensorSpec::numel).sum();
        assert!(total > 0);
    }

    #[test]
    fn mlp_has_no_attention_params() {
        let s = shape_of("mlp_small").unwrap();
        let specs = param_specs(&s);
        assert!(specs.iter().all(|p| !p.name.contains("attn")));
        assert_eq!(specs.len(), 7 + 6 * s.depth);
        assert_eq!(s.n_qlinears(), 2 + 2 * s.depth);
    }

    #[test]
    fn qlinear_count_matches_shape() {
        for (name, s) in builtin_presets() {
            assert_eq!(qlinear_names(&s).len(), s.n_qlinears(), "{name}");
        }
    }

    #[test]
    fn init_deterministic_and_scaled() {
        let s = shape_of("tiny").unwrap();
        let a = init_values(&s, 0);
        let b = init_values(&s, 0);
        let specs = param_specs(&s);
        for (i, spec) in specs.iter().enumerate() {
            assert_eq!(a[i].as_f32().unwrap(), b[i].as_f32().unwrap(),
                       "{}", spec.name);
            let data = a[i].as_f32().unwrap();
            if spec.name.ends_with(".g") {
                assert!(data.iter().all(|&v| v == 1.0));
            } else if spec.name.ends_with(".b") {
                assert!(data.iter().all(|&v| v == 0.0));
            } else if spec.shape.len() == 2 {
                let amax = data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                assert!(amax > 0.0 && amax < 2.0, "{}: {amax}", spec.name);
            }
        }
    }

    #[test]
    fn preset_view_matches() {
        let s = shape_of("lm_tiny").unwrap();
        let p = to_preset("lm_tiny", &s);
        assert_eq!(p.model.arch, "lm");
        assert_eq!(p.params.len(), param_specs(&s).len());
        assert_eq!(p.qlinears.len(), s.n_qlinears());
        assert!(shape_of("nope").is_err());
    }
}
