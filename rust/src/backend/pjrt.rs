//! `Executor` implementation for the PJRT artifact `Runtime` (behind the
//! `pjrt` feature). The artifact calling conventions — flat argument
//! lists in manifest order, outputs popped from the tail — live here, so
//! the coordinator speaks only the semantic trait.
//!
//! The typed state (`WeightStore` / `TrainState` / `AdapterSet`) crosses
//! this boundary as `Value` lists: device execution copies host buffers
//! into literals anyway, so `WeightStore::to_values` at entry and
//! `replace_from_values` on the way back are the natural conversion
//! points. (The zero-copy slab path is a native-backend property.)

use anyhow::{bail, Context, Result};

use crate::backend::{AdapterSet, Executor, ForwardOut, GradOut, LoraMeta,
                     TrainState, WeightStore};
use crate::runtime::value::Value;
use crate::runtime::{Preset, Runtime};

fn mask_value(lqs_mask: &[f32]) -> Value {
    Value::F32 { shape: vec![lqs_mask.len()], data: lqs_mask.to_vec() }
}

/// Pop `[params..., m..., v..., loss, acc]`-shaped outputs.
#[allow(clippy::type_complexity)]
fn pop_step_out(mut outs: Vec<Value>, np: usize, key: &str)
                -> Result<(Vec<Value>, Vec<Value>, Vec<Value>, f32, f32)> {
    let acc = outs.pop().context("acc")?.scalar()?;
    let loss = outs.pop().context("loss")?.scalar()?;
    if outs.len() != 3 * np {
        bail!("{key}: {} state tensors returned, want {}", outs.len(), 3 * np);
    }
    let v = outs.split_off(2 * np);
    let m = outs.split_off(np);
    Ok((outs, m, v, loss, acc))
}

impl Executor for Runtime {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn describe(&self) -> String {
        format!("PJRT artifact backend — suite {:?}, {} artifacts, {} presets",
                self.manifest.suite, self.manifest.artifacts.len(),
                self.manifest.presets.len())
    }

    fn preset_names(&self) -> Vec<String> {
        self.manifest.presets.keys().cloned().collect()
    }

    fn preset(&self, name: &str) -> Result<Preset> {
        Ok(self.manifest.preset(name)?.clone())
    }

    fn init_params(&self, preset: &str) -> Result<Vec<Value>> {
        let p = self.manifest.preset(preset)?;
        let init = self.manifest.load_init(preset)?;
        Ok(p.params
            .iter()
            .zip(init)
            .map(|(spec, data)| Value::F32 { shape: spec.shape.clone(), data })
            .collect())
    }

    fn default_batch(&self) -> usize {
        self.manifest.batch
    }

    fn supports(&self, key: &str) -> bool {
        self.manifest.artifacts.contains_key(key)
    }

    fn key_batch(&self, key: &str) -> Option<usize> {
        // PJRT graphs are shape-static: an artifact's batch always wins,
        // falling back to the suite-wide lowering batch — even when the
        // key isn't lowered (eval/calibrate against a partial suite must
        // still size batches for the shape-static artifacts they do hit).
        Some(self.manifest
            .artifacts
            .get(key)
            .and_then(|a| a.batch)
            .unwrap_or(self.manifest.batch))
    }

    fn train_step(&self, key: &str, weights: &mut WeightStore,
                  state: &mut TrainState, step: f32, lr: f32,
                  lqs_mask: &[f32], x: &Value, y: &Value)
                  -> Result<(f32, f32)> {
        let params = weights.to_values();
        let step_v = Value::scalar_f32(step);
        let lr_v = Value::scalar_f32(lr);
        let mask_v = mask_value(lqs_mask);
        let mut args: Vec<&Value> =
            params.iter().chain(&state.m).chain(&state.v).collect();
        args.push(&step_v);
        args.push(&lr_v);
        args.push(&mask_v);
        args.push(x);
        args.push(y);
        let (p, m, v, loss, acc) =
            pop_step_out(self.execute_refs(key, &args)?, params.len(), key)?;
        weights.replace_from_values(p)?;
        state.m = m;
        state.v = v;
        Ok((loss, acc))
    }

    fn forward_step(&self, key: &str, weights: &WeightStore,
                    lqs_mask: &[f32], x: &Value, y: &Value)
                    -> Result<ForwardOut> {
        let meta = self.manifest.artifact(key)?.clone();
        let params = weights.to_values();
        let mask_v = mask_value(lqs_mask);
        let mut args: Vec<&Value> = params.iter().collect();
        args.push(&mask_v);
        args.push(x);
        args.push(y);
        let mut outs = self.execute_refs(key, &args)?;
        let ctx = outs.split_off(2);
        let acc = outs.pop().context("acc")?.scalar()?;
        let loss = outs.pop().context("loss")?.scalar()?;
        // artifact manifests carry the HLA rank per artifact, not per ctx
        // entry — propagate it onto the compressed payloads so the
        // CtxStore's FP32-equivalent accounting stays metadata-exact
        let mut ctx_specs = meta.ctx;
        if let Some(rank) = meta.rank {
            for s in ctx_specs.iter_mut() {
                if s.key == "xq" && s.rank == 0 {
                    s.rank = rank;
                }
            }
        }
        Ok(ForwardOut { loss, acc, ctx, ctx_specs })
    }

    fn backward_step(&self, key: &str, weights: &WeightStore,
                     lqs_mask: &[f32], x: &Value, ctx: Vec<Value>)
                     -> Result<Vec<Value>> {
        let params = weights.to_values();
        let mask_v = mask_value(lqs_mask);
        let mut args: Vec<&Value> = params.iter().collect();
        args.push(&mask_v);
        args.push(x);
        args.extend(ctx.iter());
        self.execute_refs(key, &args)
    }

    fn grad_step(&self, key: &str, weights: &WeightStore, lqs_mask: &[f32],
                 x: &Value, y: &Value) -> Result<GradOut> {
        let params = weights.to_values();
        let mask_v = mask_value(lqs_mask);
        let mut args: Vec<&Value> = params.iter().collect();
        args.push(&mask_v);
        args.push(x);
        args.push(y);
        let mut outs = self.execute_refs(key, &args)?;
        let acc = outs.pop().context("acc")?.scalar()?;
        let loss = outs.pop().context("loss")?.scalar()?;
        if outs.len() != params.len() {
            bail!("{key}: grad arity {} != {}", outs.len(), params.len());
        }
        Ok(GradOut { grads: outs, loss, acc })
    }

    fn opt_step(&self, key: &str, weights: &mut WeightStore,
                grads: &[Value], state: &mut TrainState, step: f32,
                lr: f32) -> Result<()> {
        let params = weights.to_values();
        let np = params.len();
        let step_v = Value::scalar_f32(step);
        let lr_v = Value::scalar_f32(lr);
        let mut args: Vec<&Value> = params
            .iter()
            .chain(grads)
            .chain(&state.m)
            .chain(&state.v)
            .collect();
        args.push(&step_v);
        args.push(&lr_v);
        let mut outs = self.execute_refs(key, &args)?;
        if outs.len() != 3 * np {
            bail!("{key}: opt arity {} != {}", outs.len(), 3 * np);
        }
        let v = outs.split_off(2 * np);
        let m = outs.split_off(np);
        weights.replace_from_values(outs)?;
        state.m = m;
        state.v = v;
        Ok(())
    }

    fn eval_step(&self, key: &str, weights: &WeightStore, x: &Value,
                 y: &Value) -> Result<(f32, f32)> {
        let params = weights.to_values();
        let mut args: Vec<&Value> = params.iter().collect();
        args.push(x);
        args.push(y);
        let outs = self.execute_refs(key, &args)?;
        Ok((outs[0].scalar()?, outs[1].scalar()?))
    }

    // infer: default (unsupported) — no inference-only artifacts are
    // lowered; PJRT serving would execute eval graphs instead.

    fn calib_step(&self, key: &str, weights: &WeightStore, x: &Value,
                  y: &Value) -> Result<Vec<Vec<f32>>> {
        let params = weights.to_values();
        let mut args: Vec<&Value> = params.iter().collect();
        args.push(x);
        args.push(y);
        let outs = self.execute_refs(key, &args)?;
        outs.iter()
            .map(|v| v.as_f32().map(|s| s.to_vec()))
            .collect()
    }

    fn lora_meta(&self, key: &str) -> Result<LoraMeta> {
        let meta = self.manifest.artifact(key)?;
        Ok(LoraMeta {
            preset: meta.preset.clone().context("lora artifact preset")?,
            trainable: meta.trainable.clone(),
            batch: Some(meta.batch.unwrap_or(self.manifest.batch)),
        })
    }

    fn lora_step(&self, key: &str, adapters: &mut AdapterSet,
                 state: &mut TrainState, step: f32, lr: f32,
                 lqs_mask: &[f32], x: &Value, y: &Value)
                 -> Result<(f32, f32)> {
        let base = adapters.base().to_values();
        let step_v = Value::scalar_f32(step);
        let lr_v = Value::scalar_f32(lr);
        let mask_v = mask_value(lqs_mask);
        let nt = adapters.trainable().len();
        let mut args: Vec<&Value> = base
            .iter()
            .chain(adapters.trainable())
            .chain(&state.m)
            .chain(&state.v)
            .collect();
        args.push(&step_v);
        args.push(&lr_v);
        args.push(&mask_v);
        args.push(x);
        args.push(y);
        let (t, m, v, loss, acc) =
            pop_step_out(self.execute_refs(key, &args)?, nt, key)?;
        for (slot, nv) in adapters.trainable_mut().iter_mut().zip(t) {
            *slot = nv;
        }
        state.m = m;
        state.v = v;
        Ok((loss, acc))
    }

    fn execute_raw(&self, key: &str, args: &[Value]) -> Result<Vec<Value>> {
        self.execute(key, args)
    }
}
