//! Model-state ownership, decoupled from execution (ISSUE 8).
//!
//! Three types split what the backends used to tangle into one
//! `Vec<Value>` soup:
//!
//!   * [`WeightStore`] — the frozen base weights, one `Arc<[f32]>` slab
//!     per parameter in sorted-spec order. Cheap to [`share`] across
//!     sessions/tenants (slab refcount bumps, no copies); mutable only
//!     while *unshared* (`Arc::get_mut`), which is exactly the training
//!     loop's situation — the single `Trainer`-owned store updates in
//!     place, and the moment a checkpoint or a serving session shares
//!     it, the slabs freeze.
//!   * [`AdapterSet`] — one tenant's trainable overlay (LoRA A/B pairs
//!     plus full-rank embed/head overrides) referencing a shared base.
//!     Two `AdapterSet`s over one base hold pointer-identical base
//!     slabs (pinned by `Arc::ptr_eq` in tests).
//!   * [`TrainState`] — everything training needs *besides* weights:
//!     AdamW moments and the ABC ctx store. Inference needs none of it,
//!     so "training = WeightStore + TrainState, inference = WeightStore
//!     alone" falls out of the types.
//!
//! [`share`]: WeightStore::share

use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use crate::coordinator::ctx::CtxStore;
use crate::runtime::manifest::TensorSpec;
use crate::runtime::value::Value;

/// Typed index into a `WeightStore`'s sorted-spec registry. Stable for
/// the lifetime of the store (and of every store `share`d from it).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct ParamId(pub usize);

/// Frozen base weights behind `Arc<[f32]>` slabs, keyed by a typed
/// `ParamId` registry in sorted-spec order (the repo-wide parameter
/// flattening convention).
#[derive(Debug, Clone)]
pub struct WeightStore {
    specs: Arc<Vec<TensorSpec>>,
    slabs: Vec<Arc<[f32]>>,
}

impl WeightStore {
    /// Move a flat value vector (sorted-spec order) into slabs. The
    /// `Vec<f32>` buffers are consumed, not cloned — this is the one
    /// construction-time copy into the `Arc` allocations; steady state
    /// never copies a slab again.
    pub fn from_values(specs: Vec<TensorSpec>, values: Vec<Value>)
                       -> Result<WeightStore> {
        ensure!(specs.len() == values.len(),
                "weight store arity: {} specs vs {} values", specs.len(),
                values.len());
        let mut slabs = Vec::with_capacity(specs.len());
        for (spec, v) in specs.iter().zip(values) {
            v.check_spec(spec)?;
            let (_, data) = v.into_f32()?;
            slabs.push(Arc::<[f32]>::from(data));
        }
        let store = WeightStore { specs: Arc::new(specs), slabs };
        crate::obs::count(crate::obs::Counter::WeightBytesShared,
                          store.total_bytes() as u64);
        Ok(store)
    }

    /// Build slabs straight from a raw little-endian f32 blob in
    /// sorted-spec order (the checkpoint wire format) — one decode pass,
    /// no intermediate `Vec<Value>` layer.
    pub fn from_le_bytes(specs: Vec<TensorSpec>, bytes: &[u8])
                         -> Result<WeightStore> {
        let want: usize = specs.iter().map(|s| s.numel() * 4).sum();
        ensure!(bytes.len() == want,
                "weight blob is {} bytes, specs want {want}", bytes.len());
        let mut slabs = Vec::with_capacity(specs.len());
        let mut off = 0usize;
        for s in &specs {
            let n = s.numel();
            let mut data = vec![0.0f32; n];
            for (i, x) in data.iter_mut().enumerate() {
                let b = &bytes[off + 4 * i..off + 4 * i + 4];
                *x = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
            }
            off += 4 * n;
            slabs.push(Arc::<[f32]>::from(data));
        }
        let store = WeightStore { specs: Arc::new(specs), slabs };
        crate::obs::count(crate::obs::Counter::WeightBytesShared,
                          store.total_bytes() as u64);
        Ok(store)
    }

    /// A second handle onto the same frozen slabs: refcount bumps only,
    /// no weight bytes move. After this, neither handle can mutate in
    /// place until the other is dropped ("frozen once shared").
    pub fn share(&self) -> WeightStore {
        WeightStore { specs: self.specs.clone(),
                      slabs: self.slabs.clone() }
    }

    pub fn len(&self) -> usize {
        self.slabs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slabs.is_empty()
    }

    pub fn specs(&self) -> &[TensorSpec] {
        &self.specs
    }

    /// Registry lookup (specs are sorted by name, so this is a binary
    /// search).
    pub fn id(&self, name: &str) -> Option<ParamId> {
        self.specs
            .binary_search_by(|s| s.name.as_str().cmp(name))
            .ok()
            .map(ParamId)
    }

    pub fn spec(&self, id: ParamId) -> &TensorSpec {
        &self.specs[id.0]
    }

    /// Borrow one slab's data by id.
    pub fn slab(&self, id: ParamId) -> &[f32] {
        &self.slabs[id.0]
    }

    /// The raw `Arc` handle — what `Arc::ptr_eq` sharing assertions and
    /// zero-copy session handoffs read.
    pub fn slab_arc(&self, id: ParamId) -> &Arc<[f32]> {
        &self.slabs[id.0]
    }

    /// Borrow a parameter's data by name.
    pub fn f(&self, name: &str) -> Result<&[f32]> {
        match self.id(name) {
            Some(id) => Ok(self.slab(id)),
            None => bail!("weight store has no param {name:?}"),
        }
    }

    /// `(spec, data)` walk in sorted-spec order.
    pub fn iter(&self) -> impl Iterator<Item = (&TensorSpec, &[f32])> {
        self.specs.iter().zip(self.slabs.iter().map(|s| &**s))
    }

    /// In-place mutation — only possible while this store is the sole
    /// owner of the slab (training-loop steady state). Errors once the
    /// slab has been shared: shared weights are frozen by construction,
    /// which is what keeps serving sessions immutable under a training
    /// loop's feet.
    pub fn slab_mut(&mut self, id: ParamId) -> Result<&mut [f32]> {
        let name = &self.specs[id.0].name;
        match Arc::get_mut(&mut self.slabs[id.0]) {
            Some(s) => Ok(s),
            None => bail!("param {name:?} is frozen (slab is shared); \
                           in-place updates need sole ownership"),
        }
    }

    /// Stored weight bytes (f32 slabs only — specs carry no payload).
    pub fn total_bytes(&self) -> usize {
        self.slabs.iter().map(|s| s.len() * 4).sum()
    }

    /// Materialize `Vec<Value>`s — a boundary conversion for backends
    /// that must copy host buffers anyway (PJRT device literals). Never
    /// on the native steady-state path.
    pub fn to_values(&self) -> Vec<Value> {
        self.iter()
            .map(|(s, d)| Value::F32 { shape: s.shape.clone(),
                                       data: d.to_vec() })
            .collect()
    }

    /// Name of the first parameter containing a non-finite value, if
    /// any — the resilience sentinel's weight guard.
    pub fn first_non_finite(&self) -> Option<&str> {
        self.iter()
            .find(|(_, d)| d.iter().any(|x| !x.is_finite()))
            .map(|(s, _)| s.name.as_str())
    }

    /// Overwrite every slab from a returned value vector (the PJRT
    /// boundary's write-back after a device-side optimizer step).
    pub fn replace_from_values(&mut self, values: Vec<Value>) -> Result<()> {
        ensure!(values.len() == self.slabs.len(),
                "replace arity: {} values vs {} slabs", values.len(),
                self.slabs.len());
        for (i, v) in values.into_iter().enumerate() {
            v.check_spec(&self.specs[i])?;
            let (_, data) = v.into_f32()?;
            self.slabs[i] = Arc::from(data);
        }
        Ok(())
    }
}

/// One tenant's trainable overlay over a shared frozen base: LoRA A/B
/// pairs plus the full-rank tensors the fine-tune recipe keeps
/// trainable (embed/head). Holds its own `WeightStore` handle, so the
/// base outlives any trainer/session shuffling.
#[derive(Debug)]
pub struct AdapterSet {
    base: WeightStore,
    specs: Vec<TensorSpec>,
    trainable: Vec<Value>,
}

impl AdapterSet {
    /// `base.share()` + the tenant's trainable tensors (sorted-spec
    /// order, one value per spec).
    pub fn new(base: &WeightStore, specs: Vec<TensorSpec>,
               trainable: Vec<Value>) -> Result<AdapterSet> {
        ensure!(specs.len() == trainable.len(),
                "adapter arity: {} specs vs {} values", specs.len(),
                trainable.len());
        for (s, v) in specs.iter().zip(&trainable) {
            v.check_spec(s)?;
        }
        let set = AdapterSet { base: base.share(), specs, trainable };
        crate::obs::count(crate::obs::Counter::AdapterBytes,
                          set.adapter_bytes() as u64);
        Ok(set)
    }

    pub fn base(&self) -> &WeightStore {
        &self.base
    }

    pub fn specs(&self) -> &[TensorSpec] {
        &self.specs
    }

    pub fn trainable(&self) -> &[Value] {
        &self.trainable
    }

    pub fn trainable_mut(&mut self) -> &mut [Value] {
        &mut self.trainable
    }

    /// Per-tenant bytes: the trainable overlay only — the shared base
    /// is charged once to `WeightBytesShared`, not per adapter.
    pub fn adapter_bytes(&self) -> usize {
        self.trainable.iter().map(Value::bytes).sum()
    }
}

/// Mutable training-only state: AdamW moments (sorted-spec order,
/// matching the weights they track) and the ABC ctx store. A `Trainer`
/// owns exactly one; inference paths never see it.
#[derive(Debug)]
pub struct TrainState {
    pub m: Vec<Value>,
    pub v: Vec<Value>,
    pub ctx: CtxStore,
}

impl TrainState {
    /// Zeroed moments for `specs` + a ctx store with `mem_budget` bytes
    /// (0 = unlimited).
    pub fn new(specs: &[TensorSpec], mem_budget: u64) -> TrainState {
        let zeros: Vec<Value> =
            specs.iter().map(Value::zeros_like_spec).collect();
        TrainState { m: zeros.clone(), v: zeros,
                     ctx: CtxStore::new(mem_budget) }
    }

    /// Label of the first AdamW moment containing a non-finite value,
    /// if any (`specs` names the tensors, in the moments' sorted-spec
    /// order). A NaN gradient poisons `m` on the very step it appears,
    /// so this is the sentinel's earliest divergence detector.
    pub fn first_non_finite(&self, specs: &[TensorSpec]) -> Option<String> {
        for (label, moments) in [("adamw m", &self.m), ("adamw v", &self.v)] {
            for (i, mv) in moments.iter().enumerate() {
                let Ok(d) = mv.as_f32() else { continue };
                if d.iter().any(|x| !x.is_finite()) {
                    let name = specs.get(i).map(|s| s.name.as_str())
                        .unwrap_or("?");
                    return Some(format!("{name} ({label})"));
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::DType;

    fn specs() -> Vec<TensorSpec> {
        vec![
            TensorSpec { name: "a.w".into(), shape: vec![2, 2],
                         dtype: DType::F32 },
            TensorSpec { name: "b.w".into(), shape: vec![3],
                         dtype: DType::F32 },
        ]
    }

    fn values() -> Vec<Value> {
        vec![
            Value::F32 { shape: vec![2, 2], data: vec![1.0, 2.0, 3.0, 4.0] },
            Value::F32 { shape: vec![3], data: vec![5.0, 6.0, 7.0] },
        ]
    }

    #[test]
    fn registry_and_accessors() {
        let ws = WeightStore::from_values(specs(), values()).unwrap();
        assert_eq!(ws.len(), 2);
        assert_eq!(ws.total_bytes(), (4 + 3) * 4);
        let id = ws.id("b.w").unwrap();
        assert_eq!(ws.spec(id).name, "b.w");
        assert_eq!(ws.slab(id), &[5.0, 6.0, 7.0]);
        assert_eq!(ws.f("a.w").unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        assert!(ws.id("nope").is_none());
        assert!(ws.f("nope").is_err());
        let names: Vec<&str> =
            ws.iter().map(|(s, _)| s.name.as_str()).collect();
        assert_eq!(names, vec!["a.w", "b.w"]);
    }

    #[test]
    fn arity_and_spec_mismatches_rejected() {
        assert!(WeightStore::from_values(specs(), values()[..1].to_vec())
            .is_err());
        let mut bad = values();
        bad[1] = Value::F32 { shape: vec![4], data: vec![0.0; 4] };
        assert!(WeightStore::from_values(specs(), bad).is_err());
    }

    #[test]
    fn sharing_is_by_pointer_and_freezes_slabs() {
        let mut ws = WeightStore::from_values(specs(), values()).unwrap();
        let id = ws.id("a.w").unwrap();
        // sole owner: in-place mutation works
        ws.slab_mut(id).unwrap()[0] = 9.0;
        assert_eq!(ws.slab(id)[0], 9.0);
        // share: pointer-identical slabs, both handles frozen
        let other = ws.share();
        assert!(Arc::ptr_eq(ws.slab_arc(id), other.slab_arc(id)));
        assert!(ws.slab_mut(id).is_err(), "shared slab must freeze");
        drop(other);
        // sole owner again: thaws
        ws.slab_mut(id).unwrap()[0] = 11.0;
        assert_eq!(ws.slab(id)[0], 11.0);
    }

    #[test]
    fn le_bytes_roundtrip() {
        let ws = WeightStore::from_values(specs(), values()).unwrap();
        let mut blob = Vec::new();
        for (_, d) in ws.iter() {
            for x in d {
                blob.extend_from_slice(&x.to_le_bytes());
            }
        }
        let back = WeightStore::from_le_bytes(specs(), &blob).unwrap();
        for ((_, a), (_, b)) in ws.iter().zip(back.iter()) {
            assert_eq!(a, b);
        }
        assert!(WeightStore::from_le_bytes(specs(), &blob[..4]).is_err());
    }

    #[test]
    fn two_adapter_sets_share_one_base() {
        let ws = WeightStore::from_values(specs(), values()).unwrap();
        let aspecs = vec![TensorSpec { name: "a.w.lora_a".into(),
                                       shape: vec![2, 2],
                                       dtype: DType::F32 }];
        let mk = || -> Vec<Value> {
            vec![Value::F32 { shape: vec![2, 2], data: vec![0.0; 4] }]
        };
        let t0 = AdapterSet::new(&ws, aspecs.clone(), mk()).unwrap();
        let t1 = AdapterSet::new(&ws, aspecs, mk()).unwrap();
        // the acceptance assertion: per-tenant sets, one frozen base
        for id in 0..ws.len() {
            assert!(Arc::ptr_eq(t0.base().slab_arc(ParamId(id)),
                                t1.base().slab_arc(ParamId(id))));
        }
        assert_eq!(t0.adapter_bytes(), 16);
        // adapters are independent per tenant
        assert_eq!(t0.trainable().len(), 1);
    }

    #[test]
    fn train_state_moments_match_specs() {
        let st = TrainState::new(&specs(), 0);
        assert_eq!(st.m.len(), 2);
        assert_eq!(st.v[1].numel(), 3);
        assert_eq!(st.ctx.stats().live_bytes, 0);
    }

    #[test]
    fn non_finite_scans_name_the_tensor() {
        let mut ws = WeightStore::from_values(specs(), values()).unwrap();
        assert_eq!(ws.first_non_finite(), None);
        let id = ws.id("b.w").unwrap();
        ws.slab_mut(id).unwrap()[2] = f32::INFINITY;
        assert_eq!(ws.first_non_finite(), Some("b.w"));

        let mut st = TrainState::new(&specs(), 0);
        assert_eq!(st.first_non_finite(&specs()), None);
        st.v[0].as_f32_mut().unwrap()[3] = f32::NAN;
        assert_eq!(st.first_non_finite(&specs()),
                   Some("a.w (adamw v)".to_string()));
    }
}
