//! Baseline comparison: diff a fresh `BenchReport` against a committed
//! one, with per-cell noise-aware tolerances.
//!
//! The tolerance for each cell is derived from the **baseline's own
//! dispersion** — a cell whose baseline MAD is 1% of its median gets a
//! tight gate, a noisy cell gets a loose one — never a single global
//! percentage. A floor keeps quantization of very quiet cells from
//! producing zero-width gates (which would flag every rerun).
//!
//! Perf gating is fingerprint-gated: when the two reports come from
//! different machines (`host.fingerprint` mismatch — the usual case
//! for a CI runner checking a baseline measured elsewhere), timing
//! comparisons are rendered for information but never fail the check;
//! only schema/provenance structure is enforced. On a fingerprint
//! match the full dispersion-derived gates apply and
//! `CompareOutcome::failed()` drives the nonzero exit of
//! `hot bench --check`.

use std::collections::BTreeMap;

use crate::bench::record::BenchReport;
use crate::bench::stats::Robust;

/// Minimum allowed slowdown before a cell can ever be called a
/// regression: quiet cells (MAD ≈ 0) still tolerate scheduler-level
/// run-to-run drift.
pub const TOL_FLOOR: f64 = 0.10;

/// How many baseline relative MADs of slowdown to allow.
pub const TOL_MAD_K: f64 = 4.0;

/// Per-cell allowed relative slowdown, from the baseline's own
/// dispersion: `max(K × MAD/median, (p90−p10)/median, floor)`.
pub fn tolerance(base: &Robust) -> f64 {
    if base.median_s <= 0.0 {
        return TOL_FLOOR;
    }
    let rel_mad = base.mad_s / base.median_s;
    let rel_spread =
        ((base.p90_s - base.p10_s) / base.median_s).max(0.0);
    (TOL_MAD_K * rel_mad).max(rel_spread).max(TOL_FLOOR)
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    /// within tolerance
    Ok,
    /// fresh median slower than baseline × (1 + tol)
    Regression,
    /// fresh median faster than baseline × (1 − tol) — informational
    Improvement,
    /// cell present only in the fresh run (new coverage)
    New,
    /// cell present only in the baseline (e.g. a smoke run covering a
    /// subset) — informational, never a failure
    Missing,
}

impl Status {
    pub fn name(self) -> &'static str {
        match self {
            Status::Ok => "ok",
            Status::Regression => "REGRESSION",
            Status::Improvement => "improvement",
            Status::New => "new",
            Status::Missing => "missing",
        }
    }
}

/// One cell's diff row.
#[derive(Debug, Clone)]
pub struct CellDiff {
    pub id: String,
    /// 0.0 for `New` rows
    pub base_median_s: f64,
    /// 0.0 for `Missing` rows
    pub fresh_median_s: f64,
    /// fresh / base (1.0 for New/Missing rows)
    pub ratio: f64,
    pub tol: f64,
    pub status: Status,
}

/// The full comparison result; render with `render_terminal` /
/// `render_markdown`, gate CI on `failed()`.
#[derive(Debug, Clone)]
pub struct CompareOutcome {
    pub base_fingerprint: String,
    pub fresh_fingerprint: String,
    pub fingerprint_match: bool,
    /// set when the reports are not structurally comparable (schema
    /// version or suite mismatch) — always a failure
    pub schema_mismatch: Option<String>,
    pub diffs: Vec<CellDiff>,
}

impl CompareOutcome {
    pub fn regressions(&self) -> Vec<&CellDiff> {
        self.diffs
            .iter()
            .filter(|d| d.status == Status::Regression)
            .collect()
    }

    /// Whether `hot bench --check` should exit nonzero: structural
    /// mismatch always fails; timing regressions fail only when the
    /// fingerprints match (same machine, numbers comparable).
    pub fn failed(&self) -> bool {
        self.schema_mismatch.is_some()
            || (self.fingerprint_match && !self.regressions().is_empty())
    }

    fn rows(&self) -> Vec<[String; 6]> {
        self.diffs
            .iter()
            .map(|d| {
                let ms = |s: f64| {
                    if s > 0.0 {
                        format!("{:.3}ms", s * 1e3)
                    } else {
                        "-".to_string()
                    }
                };
                let delta = match d.status {
                    Status::New | Status::Missing => "-".to_string(),
                    _ => format!("{:+.1}%", (d.ratio - 1.0) * 100.0),
                };
                [
                    d.id.clone(),
                    ms(d.base_median_s),
                    ms(d.fresh_median_s),
                    delta,
                    format!("±{:.0}%", d.tol * 100.0),
                    d.status.name().to_string(),
                ]
            })
            .collect()
    }

    fn gate_note(&self) -> String {
        if self.fingerprint_match {
            format!("fingerprints match ({}) — perf gates active",
                    self.base_fingerprint)
        } else {
            format!(
                "fingerprint mismatch (baseline {}, fresh {}) — \
                 structural check only, timing shown for information",
                self.base_fingerprint, self.fresh_fingerprint
            )
        }
    }

    /// Plain-text report for terminal output.
    pub fn render_terminal(&self) -> String {
        let headers =
            ["cell", "baseline", "fresh", "delta", "tol", "status"];
        let rows = self.rows();
        let mut w: Vec<usize> =
            headers.iter().map(|h| h.len()).collect();
        for r in &rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let fmt = |cells: &[String]| -> String {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<width$}  ", c, width = w[i]));
            }
            s.trim_end().to_string()
        };
        let mut out = String::new();
        out.push_str(&format!("{}\n", self.gate_note()));
        if let Some(m) = &self.schema_mismatch {
            out.push_str(&format!("SCHEMA MISMATCH: {m}\n"));
        }
        out.push_str(&fmt(
            &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
        ));
        out.push('\n');
        for r in &rows {
            out.push_str(&fmt(r));
            out.push('\n');
        }
        let reg = self.regressions().len();
        out.push_str(&format!(
            "{} cells, {} regression{}{}\n",
            self.diffs.len(),
            reg,
            if reg == 1 { "" } else { "s" },
            if reg > 0 && !self.fingerprint_match {
                " (not gated: fingerprint mismatch)"
            } else {
                ""
            },
        ));
        out
    }

    /// GitHub-flavored markdown report (the CI artifact).
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str("# Bench comparison\n\n");
        out.push_str(&format!("{}\n\n", self.gate_note()));
        if let Some(m) = &self.schema_mismatch {
            out.push_str(&format!("**SCHEMA MISMATCH:** {m}\n\n"));
        }
        out.push_str(
            "| cell | baseline | fresh | delta | tol | status |\n",
        );
        out.push_str("|---|---|---|---|---|---|\n");
        for r in self.rows() {
            out.push_str(&format!(
                "| {} | {} | {} | {} | {} | {} |\n",
                r[0], r[1], r[2], r[3], r[4], r[5]
            ));
        }
        let reg = self.regressions().len();
        out.push_str(&format!(
            "\n**{}** cells, **{}** regressions, check {}.\n",
            self.diffs.len(),
            reg,
            if self.failed() { "**FAILED**" } else { "passed" },
        ));
        out
    }
}

/// Diff `fresh` against `base`, cell-by-cell on `BenchRecord::id`.
pub fn compare(base: &BenchReport, fresh: &BenchReport) -> CompareOutcome {
    let schema_mismatch = if base.schema_version != fresh.schema_version {
        Some(format!(
            "schema_version {} (baseline) vs {} (fresh)",
            base.schema_version, fresh.schema_version
        ))
    } else if base.bench != fresh.bench {
        Some(format!("suite '{}' (baseline) vs '{}' (fresh)",
                     base.bench, fresh.bench))
    } else {
        None
    };
    let base_cells: BTreeMap<&str, &Robust> = base
        .results
        .iter()
        .map(|r| (r.id.as_str(), &r.timing))
        .collect();
    let fresh_cells: BTreeMap<&str, &Robust> = fresh
        .results
        .iter()
        .map(|r| (r.id.as_str(), &r.timing))
        .collect();
    let mut diffs = Vec::new();
    for (id, bt) in &base_cells {
        match fresh_cells.get(id) {
            Some(ft) => {
                let tol = tolerance(bt);
                let ratio = if bt.median_s > 0.0 {
                    ft.median_s / bt.median_s
                } else {
                    1.0
                };
                let status = if ratio > 1.0 + tol {
                    Status::Regression
                } else if ratio < 1.0 - tol {
                    Status::Improvement
                } else {
                    Status::Ok
                };
                diffs.push(CellDiff {
                    id: id.to_string(),
                    base_median_s: bt.median_s,
                    fresh_median_s: ft.median_s,
                    ratio,
                    tol,
                    status,
                });
            }
            None => diffs.push(CellDiff {
                id: id.to_string(),
                base_median_s: bt.median_s,
                fresh_median_s: 0.0,
                ratio: 1.0,
                tol: tolerance(bt),
                status: Status::Missing,
            }),
        }
    }
    for (id, ft) in &fresh_cells {
        if !base_cells.contains_key(id) {
            diffs.push(CellDiff {
                id: id.to_string(),
                base_median_s: 0.0,
                fresh_median_s: ft.median_s,
                ratio: 1.0,
                tol: TOL_FLOOR,
                status: Status::New,
            });
        }
    }
    CompareOutcome {
        base_fingerprint: base.host.fingerprint.clone(),
        fresh_fingerprint: fresh.host.fingerprint.clone(),
        fingerprint_match: base.host.fingerprint == fresh.host.fingerprint
            && base.host.fingerprint != "unknown",
        schema_mismatch,
        diffs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::record::{BenchRecord, BenchReport, HostInfo,
                               SCHEMA_VERSION};
    use crate::util::prng::Pcg32;
    use std::collections::BTreeMap;

    fn report_with(cells: &[(&str, f64, f64)], fp: &str) -> BenchReport {
        // (id, median_s, mad_s)
        let results = cells
            .iter()
            .map(|(id, med, mad)| BenchRecord {
                id: id.to_string(),
                params: BTreeMap::new(),
                timing: Robust {
                    iters: 10,
                    rejected: 0,
                    median_s: *med,
                    mean_s: *med,
                    min_s: *med * 0.98,
                    p10_s: *med * 0.99,
                    p90_s: *med * 1.02,
                    mad_s: *mad,
                },
                flops: 1000,
                bytes_moved: 100,
                gflops: 1.0,
                roofline: None,
            })
            .collect();
        BenchReport {
            bench: "kernels".to_string(),
            schema_version: SCHEMA_VERSION,
            provenance: "measured".to_string(),
            provenance_detail: "fixture".to_string(),
            git_sha: "abc1234".to_string(),
            host: HostInfo {
                fingerprint: fp.to_string(),
                freq_ghz: Some(2.1),
                mem_bw_gbps: Some(10.0),
                threads_avail: 1,
            },
            tier: "avx2".to_string(),
            smoke: false,
            results,
            extra: BTreeMap::new(),
        }
    }

    const FP: &str = "x86_64/avx2+fma/1c@2.10GHz";

    #[test]
    fn tolerance_scales_with_baseline_dispersion() {
        let quiet = Robust {
            iters: 10, rejected: 0, median_s: 1e-3, mean_s: 1e-3,
            min_s: 1e-3, p10_s: 1e-3, p90_s: 1e-3, mad_s: 0.0,
        };
        let noisy = Robust { mad_s: 1e-4, ..quiet.clone() };
        assert_eq!(tolerance(&quiet), TOL_FLOOR,
                   "quiet cell sits at the floor");
        assert!(tolerance(&noisy) > tolerance(&quiet),
                "noisy baseline earns a wider gate");
        assert!((tolerance(&noisy) - 0.4).abs() < 1e-12,
                "4 x (1e-4/1e-3)");
    }

    #[test]
    fn synthetic_2x_slowdown_is_flagged_as_regression() {
        // the acceptance scenario: same machine, one cell twice as slow
        let base = report_with(
            &[("f32/256/simd/1t", 1.0e-3, 1.0e-5),
              ("i8/256/simd/1t", 0.5e-3, 1.0e-5)],
            FP,
        );
        let mut fresh = base.clone();
        fresh.results[0].timing.median_s *= 2.0;
        let out = compare(&base, &fresh);
        assert!(out.fingerprint_match);
        assert_eq!(out.regressions().len(), 1);
        assert_eq!(out.regressions()[0].id, "f32/256/simd/1t");
        assert!(out.failed(), "2x slowdown must fail the check");
        assert!(out.render_terminal().contains("REGRESSION"));
        assert!(out.render_markdown().contains("**FAILED**"));
    }

    #[test]
    fn fingerprint_mismatch_disables_perf_gating() {
        // same 2x slowdown, but the fresh run is on another machine:
        // shown, not gated — a CI runner cannot gate numbers measured
        // on the maintainer's box
        let base = report_with(&[("f32/256/simd/1t", 1.0e-3, 1e-5)], FP);
        let mut fresh = base.clone();
        fresh.results[0].timing.median_s *= 2.0;
        fresh.host.fingerprint = "x86_64/avx2+fma/8c@3.50GHz".to_string();
        let out = compare(&base, &fresh);
        assert!(!out.fingerprint_match);
        assert_eq!(out.regressions().len(), 1, "still rendered");
        assert!(!out.failed(), "but never a CI failure");
        assert!(out.render_terminal().contains("fingerprint mismatch"));
    }

    #[test]
    fn schema_mismatch_always_fails() {
        let base = report_with(&[("a", 1e-3, 0.0)], FP);
        let mut fresh = base.clone();
        fresh.schema_version = SCHEMA_VERSION + 1;
        fresh.host.fingerprint = "some/other/machine".to_string();
        let out = compare(&base, &fresh);
        assert!(out.schema_mismatch.is_some());
        assert!(out.failed(),
                "schema break fails even across machines");
    }

    #[test]
    fn subset_and_superset_cells_are_informational() {
        let base =
            report_with(&[("a", 1e-3, 0.0), ("b", 2e-3, 0.0)], FP);
        let fresh =
            report_with(&[("a", 1e-3, 0.0), ("c", 3e-3, 0.0)], FP);
        let out = compare(&base, &fresh);
        let by_id = |id: &str| {
            out.diffs.iter().find(|d| d.id == id).unwrap().status
        };
        assert_eq!(by_id("a"), Status::Ok);
        assert_eq!(by_id("b"), Status::Missing);
        assert_eq!(by_id("c"), Status::New);
        assert!(!out.failed(),
                "coverage drift is informational, not a regression");
    }

    #[test]
    fn prop_identical_runs_are_never_flagged() {
        // property: for any report, compare(r, r) has no regressions
        // and does not fail — the gate must be self-consistent under
        // zero change no matter how noisy the baseline was
        let mut rng = Pcg32::seeded(0xBE7C);
        for round in 0..200 {
            let ncells = 1 + rng.below(8) as usize;
            let cells: Vec<(String, f64, f64)> = (0..ncells)
                .map(|i| {
                    let med =
                        1e-6 * (1.0 + rng.below(1_000_000) as f64);
                    // MAD anywhere from zero to wildly noisy (half the
                    // median)
                    let mad =
                        med * (rng.below(1000) as f64 / 2000.0);
                    (format!("cell/{i}"), med, mad)
                })
                .collect();
            let borrowed: Vec<(&str, f64, f64)> = cells
                .iter()
                .map(|(id, m, d)| (id.as_str(), *m, *d))
                .collect();
            let r = report_with(&borrowed, FP);
            let out = compare(&r, &r);
            assert!(out.regressions().is_empty(),
                    "round {round}: identical runs flagged");
            assert!(!out.failed(), "round {round}: identical runs fail");
            assert!(out.diffs.iter().all(|d| d.status == Status::Ok),
                    "round {round}: identical cells must all be ok");
        }
    }
}
