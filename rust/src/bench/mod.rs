//! The shared benchmark harness (the "perf observatory").
//!
//! Before this module, every bench binary hand-rolled its own timing
//! loop, stats, and JSON — methodology drifted per file and the
//! committed `BENCH_*.json` carried no provenance a CI job could
//! check. The harness owns all of it once:
//!
//!   * [`stats`] — warmup detection, fixed-count or time-budgeted
//!     sampling, median/p10/p90 + MAD outlier rejection, min-of-k;
//!     all timing `Instant`-based;
//!   * [`runner`] — the per-cell protocol: obs counters drained (and
//!     asserted drained-to-zero) at cell start, one instrumented run
//!     for counter-derived FLOPs/bytes, clean timed sampling after;
//!   * [`record`] — the versioned BenchRecord schema (v2): provenance
//!     envelope with git SHA, `CpuCaps` fingerprint, SIMD tier, plus
//!     per-cell dispersion stats and a roofline block;
//!   * [`roofline`] — analytic peak FLOP/s from the CPU probe
//!     (frequency × width × FMA), measured stream-copy bandwidth
//!     ceiling, compute-bound/memory-bound attribution per cell;
//!   * [`compare`] — baseline diffing with per-cell tolerances derived
//!     from the baseline's own dispersion (never a global %),
//!     fingerprint-gated so cross-machine comparisons inform but
//!     never fail, terminal + markdown rendering;
//!   * [`suites`] — the kernel and e2e cell sets, shared by
//!     `hot bench` and the `cargo bench` shim binaries.
//!
//! CI runs `hot bench --smoke --check .` and fails on regression
//! against the committed baselines (when fingerprints match) or on
//! schema/provenance drift (always).

pub mod compare;
pub mod record;
pub mod roofline;
pub mod runner;
pub mod stats;
pub mod suites;

pub use compare::{compare, CompareOutcome};
pub use record::{BenchRecord, BenchReport, PROVENANCE_MEASURED,
                 SCHEMA_VERSION};
pub use runner::{run_cell, Measured};
pub use stats::{robust, sample, Policy, Robust};
