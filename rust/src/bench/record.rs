//! The versioned bench-record schema (v2) and its JSON round-trip.
//!
//! Every committed `BENCH_*.json` is a serialized `BenchReport`:
//! a provenance envelope (schema version, provenance string, git SHA,
//! `CpuCaps` fingerprint, thread/SIMD tier) around a list of
//! `BenchRecord` cells. Each cell carries the robust timing block from
//! `bench::stats`, the obs-counter-derived FLOP and byte totals, and a
//! roofline attribution block. `bench::compare` consumes two of these;
//! CI asserts the envelope fields on the committed files.
//!
//! Parsing is lenient the same way `obs::chrome::parse_trace` is:
//! unknown top-level keys are preserved in `extra` (round-tripped, not
//! dropped), unknown per-record keys are ignored, and only the fields
//! compare actually needs are required.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::bench::stats::Robust;
use crate::util::json::Json;

/// Bump when the envelope or cell layout changes shape. v1 was the
/// ad-hoc per-binary format; v2 adds the provenance envelope, the
/// dispersion timing block, and the roofline block.
pub const SCHEMA_VERSION: u64 = 2;

/// The provenance string CI requires on committed BENCH files: numbers
/// that came out of a timed run of real code on a named host, never
/// modeled or copied from the paper.
pub const PROVENANCE_MEASURED: &str = "measured";

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn int(v: u64) -> Json {
    Json::Num(v as f64)
}

fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

/// Roofline attribution for one cell: achieved throughput against the
/// machine's estimated compute and bandwidth ceilings, and which
/// ceiling the cell is actually pinned to.
#[derive(Debug, Clone, PartialEq)]
pub struct Roofline {
    /// estimated peak for this cell's tier/elem/threads (GFLOP/s)
    pub peak_gflops: Option<f64>,
    /// achieved / peak compute
    pub frac_peak: Option<f64>,
    /// bytes_moved / time (GB/s)
    pub achieved_gbps: Option<f64>,
    /// measured stream-copy ceiling (GB/s)
    pub peak_gbps: Option<f64>,
    /// achieved / peak bandwidth
    pub frac_bw: Option<f64>,
    /// flops / bytes_moved — compared against the machine ridge point
    pub intensity_flops_per_byte: Option<f64>,
    /// "compute-bound" | "memory-bound" | "unknown"
    pub bound: String,
}

impl Roofline {
    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        let mut put = |k: &str, v: Option<f64>| {
            if let Some(v) = v {
                m.insert(k.to_string(), num(v));
            }
        };
        put("peak_gflops", self.peak_gflops);
        put("frac_peak", self.frac_peak);
        put("achieved_gbps", self.achieved_gbps);
        put("peak_gbps", self.peak_gbps);
        put("frac_bw", self.frac_bw);
        put("intensity_flops_per_byte", self.intensity_flops_per_byte);
        m.insert("bound".to_string(), s(&self.bound));
        Json::Obj(m)
    }

    fn from_json(j: &Json) -> Roofline {
        let f = |k: &str| j.get(k).and_then(|v| v.as_f64());
        Roofline {
            peak_gflops: f("peak_gflops"),
            frac_peak: f("frac_peak"),
            achieved_gbps: f("achieved_gbps"),
            peak_gbps: f("peak_gbps"),
            frac_bw: f("frac_bw"),
            intensity_flops_per_byte: f("intensity_flops_per_byte"),
            bound: j
                .get("bound")
                .and_then(|v| v.as_str())
                .unwrap_or("unknown")
                .to_string(),
        }
    }
}

/// One bench cell: identity, parameters, robust timing, counter-derived
/// work totals, and roofline attribution.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchRecord {
    /// stable compare key, e.g. `"f32/512/simd/1t"` — baselines and
    /// fresh runs are matched cell-by-cell on this
    pub id: String,
    /// free-form cell parameters (kind, n, threads, preset, mode, ...)
    pub params: BTreeMap<String, Json>,
    pub timing: Robust,
    /// obs-counter FLOPs per iteration (0 when the cell does no GEMM)
    pub flops: u64,
    /// obs-counter bytes per iteration: packed-panel traffic plus
    /// quantize/pack transfer — the roofline bandwidth numerator
    pub bytes_moved: u64,
    /// flops / median_s, in GFLOP/s (0 when flops is 0)
    pub gflops: f64,
    pub roofline: Option<Roofline>,
}

fn timing_json(t: &Robust) -> Json {
    let mut m = BTreeMap::new();
    m.insert("iters".to_string(), int(t.iters as u64));
    m.insert("rejected".to_string(), int(t.rejected as u64));
    m.insert("median_s".to_string(), num(t.median_s));
    m.insert("mean_s".to_string(), num(t.mean_s));
    m.insert("min_s".to_string(), num(t.min_s));
    m.insert("p10_s".to_string(), num(t.p10_s));
    m.insert("p90_s".to_string(), num(t.p90_s));
    m.insert("mad_s".to_string(), num(t.mad_s));
    Json::Obj(m)
}

fn timing_from_json(j: &Json) -> Result<Robust> {
    let f = |k: &str| {
        j.get(k)
            .and_then(|v| v.as_f64())
            .with_context(|| format!("timing block missing '{k}'"))
    };
    Ok(Robust {
        iters: j.get("iters").and_then(|v| v.as_usize()).unwrap_or(1),
        rejected: j.get("rejected").and_then(|v| v.as_usize()).unwrap_or(0),
        median_s: f("median_s")?,
        mean_s: f("mean_s").unwrap_or(f("median_s")?),
        min_s: f("min_s").unwrap_or(f("median_s")?),
        p10_s: f("p10_s").unwrap_or(f("median_s")?),
        p90_s: f("p90_s").unwrap_or(f("median_s")?),
        mad_s: f("mad_s").unwrap_or(0.0),
    })
}

impl BenchRecord {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("id".to_string(), s(&self.id));
        m.insert("params".to_string(), Json::Obj(self.params.clone()));
        m.insert("timing".to_string(), timing_json(&self.timing));
        m.insert("flops".to_string(), int(self.flops));
        m.insert("bytes_moved".to_string(), int(self.bytes_moved));
        m.insert("gflops".to_string(), num(self.gflops));
        if let Some(r) = &self.roofline {
            m.insert("roofline".to_string(), r.to_json());
        }
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<BenchRecord> {
        let id = j
            .get("id")
            .and_then(|v| v.as_str())
            .context("record missing 'id'")?
            .to_string();
        let timing = timing_from_json(
            j.get("timing").with_context(|| {
                format!("record '{id}' missing 'timing' block")
            })?,
        )?;
        Ok(BenchRecord {
            id,
            params: j
                .get("params")
                .and_then(|v| v.as_obj())
                .cloned()
                .unwrap_or_default(),
            timing,
            flops: j.get("flops").and_then(|v| v.as_i64()).unwrap_or(0)
                as u64,
            bytes_moved: j
                .get("bytes_moved")
                .and_then(|v| v.as_i64())
                .unwrap_or(0) as u64,
            gflops: j.get("gflops").and_then(|v| v.as_f64()).unwrap_or(0.0),
            roofline: j.get("roofline").map(Roofline::from_json),
        })
    }
}

/// The machine identity block of a report: used by `bench::compare` to
/// decide whether perf gating is meaningful (numbers from two different
/// machines never gate each other).
#[derive(Debug, Clone, PartialEq)]
pub struct HostInfo {
    /// `CpuCaps::fingerprint()` — "x86_64/avx2+fma/1c@2.10GHz"
    pub fingerprint: String,
    pub freq_ghz: Option<f64>,
    /// measured stream-copy bandwidth ceiling
    pub mem_bw_gbps: Option<f64>,
    pub threads_avail: usize,
}

impl HostInfo {
    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("fingerprint".to_string(), s(&self.fingerprint));
        if let Some(f) = self.freq_ghz {
            m.insert("freq_ghz".to_string(), num(f));
        }
        if let Some(b) = self.mem_bw_gbps {
            m.insert("mem_bw_gbps".to_string(), num(b));
        }
        m.insert("threads_avail".to_string(),
                 int(self.threads_avail as u64));
        Json::Obj(m)
    }

    fn from_json(j: &Json) -> HostInfo {
        HostInfo {
            fingerprint: j
                .get("fingerprint")
                .and_then(|v| v.as_str())
                .unwrap_or("unknown")
                .to_string(),
            freq_ghz: j.get("freq_ghz").and_then(|v| v.as_f64()),
            mem_bw_gbps: j.get("mem_bw_gbps").and_then(|v| v.as_f64()),
            threads_avail: j
                .get("threads_avail")
                .and_then(|v| v.as_usize())
                .unwrap_or(1),
        }
    }
}

/// A full bench report: the provenance envelope plus the result cells.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchReport {
    /// suite name: "kernels" | "e2e" | "memory"
    pub bench: String,
    pub schema_version: u64,
    /// "measured" for anything this harness produced
    pub provenance: String,
    /// how the numbers were obtained, honestly (host, method, caveats)
    pub provenance_detail: String,
    /// short commit SHA at measurement time, "+dirty" when the tree
    /// had uncommitted changes, "unknown" outside a git checkout
    pub git_sha: String,
    pub host: HostInfo,
    /// active SIMD tier name at measurement time
    pub tier: String,
    /// true when produced under `--smoke` (reduced sizes/iterations)
    pub smoke: bool,
    pub results: Vec<BenchRecord>,
    /// unrecognized top-level keys (e.g. suite-specific `deltas`),
    /// preserved verbatim across a load/save round-trip
    pub extra: BTreeMap<String, Json>,
}

/// Envelope keys owned by the schema; everything else round-trips
/// through `extra`.
const ENVELOPE_KEYS: &[&str] = &[
    "bench", "schema_version", "provenance", "provenance_detail",
    "git_sha", "host", "tier", "smoke", "results",
];

impl BenchReport {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("bench".to_string(), s(&self.bench));
        m.insert("schema_version".to_string(), int(self.schema_version));
        m.insert("provenance".to_string(), s(&self.provenance));
        m.insert("provenance_detail".to_string(),
                 s(&self.provenance_detail));
        m.insert("git_sha".to_string(), s(&self.git_sha));
        m.insert("host".to_string(), self.host.to_json());
        m.insert("tier".to_string(), s(&self.tier));
        m.insert("smoke".to_string(), Json::Bool(self.smoke));
        m.insert(
            "results".to_string(),
            Json::Arr(self.results.iter().map(|r| r.to_json()).collect()),
        );
        for (k, v) in &self.extra {
            m.insert(k.clone(), v.clone());
        }
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<BenchReport> {
        let results = j
            .get("results")
            .and_then(|v| v.as_arr())
            .context("report missing 'results' array")?
            .iter()
            .map(BenchRecord::from_json)
            .collect::<Result<Vec<_>>>()?;
        let gs = |k: &str, default: &str| {
            j.get(k).and_then(|v| v.as_str()).unwrap_or(default).to_string()
        };
        let extra = j
            .as_obj()
            .context("report is not an object")?
            .iter()
            .filter(|(k, _)| !ENVELOPE_KEYS.contains(&k.as_str()))
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect();
        Ok(BenchReport {
            bench: gs("bench", "unknown"),
            schema_version: j
                .get("schema_version")
                .and_then(|v| v.as_i64())
                .context("report missing 'schema_version'")?
                as u64,
            provenance: gs("provenance", ""),
            provenance_detail: gs("provenance_detail", ""),
            git_sha: gs("git_sha", "unknown"),
            host: j
                .get("host")
                .map(HostInfo::from_json)
                .unwrap_or(HostInfo {
                    fingerprint: "unknown".to_string(),
                    freq_ghz: None,
                    mem_bw_gbps: None,
                    threads_avail: 1,
                }),
            tier: gs("tier", "unknown"),
            smoke: j.get("smoke").and_then(|v| v.as_bool()).unwrap_or(false),
            results,
            extra,
        })
    }

    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing bench report to {path}"))
    }

    pub fn load(path: &str) -> Result<BenchReport> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading bench report {path}"))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?;
        Self::from_json(&j)
            .with_context(|| format!("decoding bench report {path}"))
    }
}

/// Short git SHA of HEAD with a `+dirty` suffix when the working tree
/// has uncommitted changes; "unknown" when git is unavailable (e.g. a
/// source tarball). Spawning git twice per report is fine — this runs
/// once per bench invocation, not per cell.
pub fn git_sha() -> String {
    let run = |args: &[&str]| {
        std::process::Command::new("git")
            .args(args)
            .output()
            .ok()
            .filter(|o| o.status.success())
            .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
    };
    match run(&["rev-parse", "--short", "HEAD"]) {
        Some(sha) if !sha.is_empty() => {
            let dirty = run(&["status", "--porcelain"])
                .map(|s| !s.is_empty())
                .unwrap_or(false);
            if dirty { format!("{sha}+dirty") } else { sha }
        }
        _ => "unknown".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_report() -> BenchReport {
        let timing = Robust {
            iters: 12,
            rejected: 1,
            median_s: 2.5e-3,
            mean_s: 2.6e-3,
            min_s: 2.4e-3,
            p10_s: 2.45e-3,
            p90_s: 2.8e-3,
            mad_s: 5.0e-5,
        };
        let mut params = BTreeMap::new();
        params.insert("n".to_string(), Json::Num(256.0));
        params.insert("kind".to_string(), Json::Str("f32".to_string()));
        let rec = BenchRecord {
            id: "f32/256/simd/1t".to_string(),
            params,
            timing,
            flops: 33_554_432,
            bytes_moved: 1_048_576,
            gflops: 13.4,
            roofline: Some(Roofline {
                peak_gflops: Some(67.2),
                frac_peak: Some(0.2),
                achieved_gbps: Some(0.42),
                peak_gbps: Some(12.0),
                frac_bw: Some(0.035),
                intensity_flops_per_byte: Some(32.0),
                bound: "compute-bound".to_string(),
            }),
        };
        let mut extra = BTreeMap::new();
        extra.insert("deltas".to_string(),
                     Json::Arr(vec![Json::Num(1.5)]));
        BenchReport {
            bench: "kernels".to_string(),
            schema_version: SCHEMA_VERSION,
            provenance: PROVENANCE_MEASURED.to_string(),
            provenance_detail: "test fixture".to_string(),
            git_sha: "abc1234".to_string(),
            host: HostInfo {
                fingerprint: "x86_64/avx2+fma/1c@2.10GHz".to_string(),
                freq_ghz: Some(2.1),
                mem_bw_gbps: Some(12.0),
                threads_avail: 1,
            },
            tier: "avx2".to_string(),
            smoke: false,
            results: vec![rec],
            extra,
        }
    }

    #[test]
    fn report_roundtrips_through_json() {
        let r = sample_report();
        let j = r.to_json();
        let text = j.to_string();
        let back =
            BenchReport::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, r, "serialize -> parse -> decode is lossless");
        // the unknown-key channel survives too
        assert!(back.extra.contains_key("deltas"));
    }

    #[test]
    fn parser_is_lenient_about_optional_fields() {
        // a minimal v2 document: only schema_version, results, and the
        // per-record id/timing.median_s are truly required
        let j = Json::parse(
            r#"{"schema_version":2,
                "results":[{"id":"x","timing":{"median_s":0.001}}],
                "from_the_future":{"anything":true}}"#,
        )
        .unwrap();
        let r = BenchReport::from_json(&j).unwrap();
        assert_eq!(r.results.len(), 1);
        assert_eq!(r.results[0].timing.median_s, 0.001);
        assert_eq!(r.results[0].timing.p90_s, 0.001,
                   "percentiles default to the median");
        assert_eq!(r.host.fingerprint, "unknown");
        assert!(r.extra.contains_key("from_the_future"),
                "foreign keys preserved, not dropped");
    }

    #[test]
    fn parser_rejects_structurally_broken_documents() {
        let no_results = Json::parse(r#"{"schema_version":2}"#).unwrap();
        assert!(BenchReport::from_json(&no_results).is_err());
        let no_version =
            Json::parse(r#"{"results":[]}"#).unwrap();
        assert!(BenchReport::from_json(&no_version).is_err());
        let bad_record = Json::parse(
            r#"{"schema_version":2,"results":[{"timing":{}}]}"#,
        )
        .unwrap();
        assert!(BenchReport::from_json(&bad_record).is_err(),
                "a record without an id cannot be compared");
    }

    #[test]
    fn git_sha_is_well_formed() {
        let sha = git_sha();
        assert!(!sha.is_empty());
        // either "unknown" or a hex-ish short sha, optionally +dirty
        if sha != "unknown" {
            let base = sha.strip_suffix("+dirty").unwrap_or(&sha);
            assert!(base.len() >= 6,
                    "short sha should be at least 6 chars: {sha}");
            assert!(base.chars().all(|c| c.is_ascii_hexdigit()),
                    "sha should be hex: {sha}");
        }
    }
}
