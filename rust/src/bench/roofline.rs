//! Roofline attribution: where each bench cell sits against the
//! machine's compute and bandwidth ceilings.
//!
//! The compute ceiling is the classic analytic peak from the `CpuCaps`
//! probe — frequency × SIMD width × FMA ports per tier/element
//! (`kernels::peak_gflops`). The bandwidth ceiling is *measured*: a
//! stream-copy probe over a cache-busting buffer, best-of-k, because a
//! modeled DRAM number would be fiction on shared/virtualized hosts.
//! `HOT_MEM_GBPS` overrides the probe (CI containers with throttled or
//! noisy memory can pin a known value).
//!
//! Attribution per cell: arithmetic intensity (FLOPs / bytes moved,
//! both from drained obs counters) against the machine ridge point
//! (peak FLOP/s ÷ peak bytes/s) decides `compute-bound` vs
//! `memory-bound`; missing inputs degrade the verdict to `unknown`
//! rather than inventing a ceiling.

use std::sync::OnceLock;
use std::time::Instant;

use crate::bench::record::{HostInfo, Roofline};
use crate::kernels::{self, Elem, Tier};

/// Stream-probe working-set size. Far beyond any L2/L3 slice this repo
/// runs on, so the copy streams from memory rather than cache.
const PROBE_BYTES: usize = 32 << 20;
const PROBE_BYTES_SMOKE: usize = 8 << 20;
const PROBE_PASSES: usize = 5;

/// Measured stream-copy bandwidth ceiling in GB/s (read + write
/// counted), memoized for the process. `HOT_MEM_GBPS` overrides.
/// Returns `None` only if the override is malformed-and-zero — the
/// probe itself always produces a number.
pub fn mem_bw_gbps(smoke: bool) -> Option<f64> {
    static BW: OnceLock<Option<f64>> = OnceLock::new();
    *BW.get_or_init(|| {
        if let Some(b) = std::env::var("HOT_MEM_GBPS")
            .ok()
            .and_then(|v| v.parse::<f64>().ok())
        {
            return if b > 0.0 { Some(b) } else { None };
        }
        let bytes =
            if smoke { PROBE_BYTES_SMOKE } else { PROBE_BYTES };
        let words = bytes / 8;
        let src = vec![0x55AA_55AA_55AA_55AAu64; words];
        let mut dst = vec![0u64; words];
        dst.copy_from_slice(&src); // warm: faults + first-touch pages
        let mut best = f64::INFINITY;
        for _ in 0..PROBE_PASSES {
            let t0 = Instant::now();
            dst.copy_from_slice(&src);
            std::hint::black_box(&mut dst);
            best = best.min(t0.elapsed().as_secs_f64());
        }
        // a pass reads `bytes` and writes `bytes`
        Some(2.0 * bytes as f64 / best / 1e9)
    })
}

/// Machine identity + ceilings for the report envelope.
pub fn host(smoke: bool) -> HostInfo {
    HostInfo {
        fingerprint: kernels::caps().fingerprint(),
        freq_ghz: kernels::cpu_freq_ghz(),
        mem_bw_gbps: mem_bw_gbps(smoke),
        threads_avail: std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
    }
}

/// Build the roofline block for one cell from its measured work totals
/// and timing. `flops`/`bytes` are per-iteration obs-counter totals;
/// `median_s` the robust per-iteration time. Cells with no counted
/// work (flops == 0) get bandwidth attribution only; cells with no
/// byte traffic get compute attribution only.
pub fn attribute(
    flops: u64,
    bytes: u64,
    median_s: f64,
    tier: Tier,
    elem: Elem,
    threads: usize,
    peak_gbps: Option<f64>,
) -> Roofline {
    let peak_gflops = kernels::peak_gflops(tier, elem, threads);
    let achieved_gflops = if median_s > 0.0 && flops > 0 {
        Some(flops as f64 / median_s / 1e9)
    } else {
        None
    };
    let achieved_gbps = if median_s > 0.0 && bytes > 0 {
        Some(bytes as f64 / median_s / 1e9)
    } else {
        None
    };
    let frac_peak = match (achieved_gflops, peak_gflops) {
        (Some(a), Some(p)) if p > 0.0 => Some(a / p),
        _ => None,
    };
    let frac_bw = match (achieved_gbps, peak_gbps) {
        (Some(a), Some(p)) if p > 0.0 => Some(a / p),
        _ => None,
    };
    let intensity = if bytes > 0 && flops > 0 {
        Some(flops as f64 / bytes as f64)
    } else {
        None
    };
    // the ridge point: below it a kernel cannot reach peak compute no
    // matter how good its inner loop is — the verdict is structural,
    // from work totals and machine ceilings, not from achieved time
    let bound = match (intensity, peak_gflops, peak_gbps) {
        (Some(i), Some(pf), Some(pb)) if pb > 0.0 => {
            if i < pf / pb {
                "memory-bound"
            } else {
                "compute-bound"
            }
        }
        _ => "unknown",
    }
    .to_string();
    Roofline {
        peak_gflops,
        frac_peak,
        achieved_gbps,
        peak_gbps,
        frac_bw,
        intensity_flops_per_byte: intensity,
        bound,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_reports_a_plausible_bandwidth() {
        // smoke-size probe: anything from a throttled container to a
        // desktop should land between 0.1 and 1000 GB/s
        let bw = mem_bw_gbps(true);
        if let Some(bw) = bw {
            assert!(bw > 0.1 && bw < 1000.0, "implausible: {bw} GB/s");
        }
        // memoized: a second call agrees exactly
        assert_eq!(bw, mem_bw_gbps(true));
    }

    #[test]
    fn host_block_is_populated() {
        let h = host(true);
        assert!(h.fingerprint.starts_with(std::env::consts::ARCH));
        assert!(h.threads_avail >= 1);
    }

    #[test]
    fn attribution_verdicts_follow_the_ridge() {
        // synthetic machine-independent check: pin the ceilings via a
        // known bandwidth and exercise both sides of the ridge
        let pb = Some(10.0); // GB/s
        // high intensity: 1 GFLOP over 1 KB -> compute-bound whenever
        // the compute peak is known
        let hi = attribute(1_000_000_000, 1_024, 0.5, Tier::Scalar,
                           Elem::F32, 1, pb);
        // low intensity: 1 KFLOP over 1 GB -> memory-bound
        let lo = attribute(1_024, 1_000_000_000, 0.5, Tier::Scalar,
                           Elem::F32, 1, pb);
        if kernels::cpu_freq_ghz().is_some() {
            assert_eq!(hi.bound, "compute-bound");
            assert_eq!(lo.bound, "memory-bound");
            assert!(hi.frac_peak.unwrap() > 0.0);
        } else {
            assert_eq!(hi.bound, "unknown");
        }
        assert!(lo.achieved_gbps.unwrap() > 0.0);
        assert_eq!(lo.peak_gbps, pb);
    }

    #[test]
    fn missing_inputs_degrade_to_unknown() {
        let r = attribute(0, 0, 0.001, Tier::Scalar, Elem::F32, 1, None);
        assert_eq!(r.bound, "unknown");
        assert_eq!(r.frac_peak, None);
        assert_eq!(r.achieved_gbps, None);
        assert_eq!(r.intensity_flops_per_byte, None);
    }
}
