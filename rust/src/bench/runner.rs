//! The per-cell measurement protocol: obs-counter hygiene, one
//! instrumented run for work totals, then clean timed sampling.
//!
//! Cell protocol (the order matters and is pinned by tests):
//!
//!   1. **flush** — `obs::drain_counters()` discards whatever warmup,
//!      setup, or the previous cell charged to the process-wide meters;
//!   2. **zero check** — a second drain must read zero on every work
//!      counter (FLOPs, bytes). A nonzero reading means something is
//!      still running between cells and every number after it would be
//!      cross-charged — that is a harness bug, so the runner panics
//!      rather than emitting a poisoned record. Pinned as a regression
//!      test in `rust/tests/obs_trace.rs`;
//!   3. **counted run** — tracing force-enabled for exactly one
//!      iteration; the drained deltas are the cell's per-iteration
//!      FLOPs and bytes moved (kernel-reported, not formula-derived);
//!   4. **timed runs** — tracing forced *off* so the sampled series
//!      measures the kernel, not the meters; `stats::sample` +
//!      `stats::robust` produce the timing block;
//!   5. **restore** — the pre-cell tracing state comes back and the
//!      meters are left drained for the next cell.

use crate::bench::stats::{self, Policy, Robust};
use crate::obs::{self, Counter, N_COUNTERS};

/// Sum of the per-tier FLOP counters in a drained counter block.
pub fn flops_of(c: &[u64; N_COUNTERS]) -> u64 {
    c[Counter::FlopsScalar as usize]
        + c[Counter::FlopsAvx2 as usize]
        + c[Counter::FlopsNeon as usize]
}

/// Sum of the byte-traffic counters in a drained counter block: GEMM
/// packed-panel traffic plus quantize/pack output — the roofline
/// bandwidth numerator.
pub fn bytes_of(c: &[u64; N_COUNTERS]) -> u64 {
    c[Counter::BytesQuantized as usize]
        + c[Counter::BytesPacked as usize]
        + c[Counter::BytesPanels as usize]
}

/// Everything `run_cell` measured for one cell.
#[derive(Debug, Clone)]
pub struct Measured {
    pub timing: Robust,
    /// raw kept+rejected sample series (seconds), for callers that
    /// derive extra figures (e.g. steps/s)
    pub samples: Vec<f64>,
    /// per-iteration FLOPs from the instrumented run
    pub flops: u64,
    /// per-iteration bytes moved from the instrumented run
    pub bytes_moved: u64,
    /// the full drained counter block of the instrumented run
    pub counters: [u64; N_COUNTERS],
}

impl Measured {
    /// flops / median, in GFLOP/s (0 when the cell did no counted work)
    pub fn gflops(&self) -> f64 {
        if self.flops > 0 && self.timing.median_s > 0.0 {
            self.flops as f64 / self.timing.median_s / 1e9
        } else {
            0.0
        }
    }
}

/// Run one bench cell under the drain-to-zero protocol. See the
/// module docs for the five steps. `f` is one iteration of the cell's
/// workload.
pub fn run_cell<F: FnMut()>(policy: &Policy, mut f: F) -> Measured {
    // 1. flush anything charged since the last drain
    obs::drain_counters();
    // 2. the meter must now read zero — anything else means work is
    //    leaking across cell boundaries
    let z = obs::drain_counters();
    assert!(
        flops_of(&z) == 0 && bytes_of(&z) == 0,
        "obs work counters not drained to zero at cell start \
         (flops={}, bytes={}): work is leaking across bench cells",
        flops_of(&z),
        bytes_of(&z),
    );
    // 3. one instrumented iteration for the work totals
    let was_on = obs::enabled();
    obs::set_trace_enabled(true);
    f();
    let counters = obs::drain_counters();
    // 4. timed sampling with the meters off
    obs::set_trace_enabled(false);
    let samples = stats::sample(policy, &mut f);
    let timing = stats::robust(&samples);
    // 5. restore and leave the meters drained
    obs::set_trace_enabled(was_on);
    obs::drain_counters();
    Measured {
        timing,
        samples,
        flops: flops_of(&counters),
        bytes_moved: bytes_of(&counters),
        counters,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels;

    #[test]
    fn cell_protocol_counts_work_and_restores_state() {
        let _gate = crate::kernels::pool::test_serial();
        let was_on = obs::enabled();
        let n = 16;
        let a = vec![1.0f32; n * n];
        let b = vec![0.5f32; n * n];
        let m = run_cell(&Policy::fixed(3), || {
            std::hint::black_box(kernels::gemm_f32_nn(&a, &b, n, n, n));
        });
        assert_eq!(obs::enabled(), was_on, "tracing state restored");
        assert_eq!(m.timing.iters + m.timing.rejected, 3);
        // `>=`: the counted run briefly enables the process-global
        // tracing gate, and lib tests run concurrently — a neighboring
        // GEMM test may add its own flops to the window. The cell's own
        // work is the guaranteed floor.
        assert!(m.flops >= 2 * (n * n * n) as u64,
                "counter-derived FLOPs for one iteration: {}", m.flops);
        assert!(m.bytes_moved > 0, "panel traffic counted");
        assert!(m.gflops() > 0.0);
    }

    #[test]
    fn zero_work_cell_keeps_the_meters_clean() {
        let _gate = crate::kernels::pool::test_serial();
        let m = run_cell(&Policy::fixed(2), || {
            std::hint::black_box((0..64).sum::<u64>());
        });
        // no concurrent test can charge this window unless tracing is
        // enabled, and only run_cell enables it under the gate
        if !obs::enabled() {
            assert_eq!(m.flops, 0);
            assert_eq!(m.gflops(), 0.0);
        }
    }
}
