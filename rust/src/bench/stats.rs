//! Sampling methodology and robust statistics for the bench harness.
//!
//! Every bench cell in the repo runs through `sample` + `robust`, so
//! methodology is owned here once instead of hand-rolled per binary:
//!
//!   * **warmup detection** — unmeasured iterations run until the
//!     per-iteration time stops improving markedly (caches hot, arenas
//!     grown, plan cache populated), bounded by `max_warmup`;
//!   * **sampling** — timed iterations until a wall budget elapses or
//!     an iteration cap is hit, never fewer than `min_iters` samples
//!     (`Policy::fixed` pins an exact count instead — the
//!     deterministic-length mode the e2e suite uses for step series);
//!   * **robust reporting** — median / mean / min / p10 / p90 plus the
//!     median absolute deviation, with MAD-based outlier rejection
//!     (a timer interrupt or scheduler preemption must not move the
//!     cell's number; a genuinely bimodal distribution must not be
//!     trimmed to one mode).
//!
//! All timing is `Instant`-based (monotonic); wall-clock never enters.

use std::time::{Duration, Instant};

/// Reject a sample when its deviation from the median exceeds
/// `MAD_K × MAD`. 5 normalized MADs ≈ 7.4σ for Gaussian noise — far
/// past jitter, but inside a 100× scheduler spike.
const MAD_K: f64 = 5.0;

/// When MAD is (near) zero — a constant-looking series — fall back to
/// rejecting only samples more than this fraction away from the
/// median, so a lone spike over an otherwise constant series is still
/// dropped while a truly constant series loses nothing.
const REL_FLOOR: f64 = 0.25;

/// Robust summary of one cell's timing samples (seconds). This struct
/// is the `timing` block of a v2 `BenchRecord`.
#[derive(Debug, Clone, PartialEq)]
pub struct Robust {
    /// samples kept after outlier rejection
    pub iters: usize,
    /// samples dropped by the MAD rule
    pub rejected: usize,
    pub median_s: f64,
    pub mean_s: f64,
    /// min-of-k: the least-noise estimate of the cell's true cost
    pub min_s: f64,
    pub p10_s: f64,
    pub p90_s: f64,
    /// median absolute deviation of the kept samples — the dispersion
    /// the baseline-compare tolerance is derived from
    pub mad_s: f64,
}

/// Median of a sorted slice (upper median for even lengths, matching
/// the repo's historical `samples[n / 2]` convention).
fn median_sorted(sorted: &[f64]) -> f64 {
    sorted[sorted.len() / 2]
}

/// Median absolute deviation around `center`.
fn mad_about(samples: &[f64], center: f64) -> f64 {
    let mut dev: Vec<f64> = samples.iter().map(|&x| (x - center).abs())
        .collect();
    dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
    median_sorted(&dev)
}

/// Robust statistics with MAD outlier rejection. Panics on an empty
/// input — a cell that produced no samples is a harness bug, not a
/// statistics question.
pub fn robust(samples: &[f64]) -> Robust {
    assert!(!samples.is_empty(), "robust() needs at least one sample");
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = median_sorted(&sorted);
    let mad = mad_about(&sorted, med);
    // threshold: MAD-scaled when the series has real dispersion, a
    // relative floor when it is (near-)constant — see module docs
    let thresh = (MAD_K * mad).max(REL_FLOOR * med.abs());
    let kept: Vec<f64> = if thresh > 0.0 {
        sorted.iter().copied().filter(|&x| (x - med).abs() <= thresh)
            .collect()
    } else {
        sorted.clone()
    };
    let rejected = sorted.len() - kept.len();
    // the median always survives its own threshold, so kept is
    // non-empty whenever sorted is
    let n = kept.len();
    Robust {
        iters: n,
        rejected,
        median_s: median_sorted(&kept),
        mean_s: kept.iter().sum::<f64>() / n as f64,
        min_s: kept[0],
        p10_s: kept[n / 10],
        p90_s: kept[(n * 9 / 10).min(n - 1)],
        mad_s: mad_about(&kept, median_sorted(&kept)),
    }
}

/// How a cell is sampled. Construct through `timed` or `fixed`.
#[derive(Debug, Clone)]
pub struct Policy {
    /// wall budget for the timed loop (ignored by `fixed`)
    pub budget: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
    /// warmup iteration cap (0 = no warmup at all)
    pub max_warmup: usize,
}

impl Policy {
    /// Time-budgeted sampling: at least `min_iters` samples, then keep
    /// sampling until `budget_ms` elapses, hard-capped at `max_iters`.
    pub fn timed(budget_ms: u64, max_iters: usize) -> Policy {
        Policy {
            budget: Duration::from_millis(budget_ms),
            min_iters: 5.min(max_iters.max(1)),
            max_iters: max_iters.max(1),
            max_warmup: 8,
        }
    }

    /// Fixed-iteration-count sampling: exactly `iters` timed samples
    /// (plus warmup). Deterministic work per cell — the CI smoke mode.
    pub fn fixed(iters: usize) -> Policy {
        Policy {
            budget: Duration::ZERO,
            min_iters: iters.max(1),
            max_iters: iters.max(1),
            max_warmup: 2,
        }
    }
}

/// Run the warmup phase: unmeasured iterations until the time stops
/// improving by >10% over the best seen, bounded by `max_warmup`.
/// Returns how many warmup iterations ran.
fn warm<F: FnMut()>(max_warmup: usize, f: &mut F) -> usize {
    let mut best = f64::INFINITY;
    for w in 0..max_warmup {
        let t0 = Instant::now();
        f();
        let t = t0.elapsed().as_secs_f64();
        if t >= best * 0.9 {
            return w + 1; // stabilized: no marked improvement left
        }
        best = best.min(t);
    }
    max_warmup
}

/// Sample `f` under `policy`: warmup detection, then the timed loop.
/// Returns the raw per-iteration seconds (feed to `robust`).
pub fn sample<F: FnMut()>(policy: &Policy, mut f: F) -> Vec<f64> {
    warm(policy.max_warmup, &mut f);
    let mut samples = Vec::with_capacity(policy.min_iters);
    let loop_start = Instant::now();
    while samples.len() < policy.max_iters
        && (samples.len() < policy.min_iters
            || loop_start.elapsed() < policy.budget)
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_series_is_reported_verbatim() {
        let s = vec![2.0e-3; 17];
        let r = robust(&s);
        assert_eq!(r.iters, 17);
        assert_eq!(r.rejected, 0, "a constant series loses nothing");
        assert_eq!(r.median_s, 2.0e-3);
        assert_eq!(r.mean_s, 2.0e-3);
        assert_eq!(r.min_s, 2.0e-3);
        assert_eq!(r.p10_s, 2.0e-3);
        assert_eq!(r.p90_s, 2.0e-3);
        assert_eq!(r.mad_s, 0.0);
    }

    #[test]
    fn bimodal_series_keeps_both_modes() {
        // ten fast samples, ten slow: the rejection rule must not trim
        // the series down to one mode (that would hide real bimodality
        // — e.g. a cell alternating between two code paths)
        let mut s = vec![1.0e-3; 10];
        s.extend(vec![2.0e-3; 10]);
        let r = robust(&s);
        assert_eq!(r.rejected, 0, "bimodal modes are data, not outliers");
        assert_eq!(r.iters, 20);
        assert_eq!(r.min_s, 1.0e-3);
        // upper median of the sorted 20-sample series: the slow mode
        assert_eq!(r.median_s, 2.0e-3);
        assert!((r.mean_s - 1.5e-3).abs() < 1e-12);
        assert_eq!(r.mad_s, 1.0e-3, "bimodal dispersion must be visible");
    }

    #[test]
    fn single_spike_is_rejected() {
        // nineteen identical samples and one 100x scheduler spike: the
        // spike is dropped (counted), the median untouched
        let mut s = vec![1.0e-3; 19];
        s.push(100.0e-3);
        let r = robust(&s);
        assert_eq!(r.rejected, 1, "the spike must be rejected");
        assert_eq!(r.iters, 19);
        assert_eq!(r.median_s, 1.0e-3);
        assert_eq!(r.mean_s, 1.0e-3, "mean no longer polluted");
        assert_eq!(r.p90_s, 1.0e-3);
    }

    #[test]
    fn spike_survives_when_dispersion_is_real() {
        // the same 3x sample is NOT an outlier when the series is
        // genuinely noisy at that scale
        let s = vec![1.0, 2.0, 3.0, 1.5, 2.5, 0.5, 2.0, 1.0, 3.0, 2.2];
        let r = robust(&s);
        assert_eq!(r.rejected, 0, "wide series: everything within MADs");
    }

    #[test]
    fn percentiles_are_ordered() {
        let s: Vec<f64> = (1..=100).map(|i| i as f64 * 1e-4).collect();
        let r = robust(&s);
        assert!(r.min_s <= r.p10_s);
        assert!(r.p10_s <= r.median_s);
        assert!(r.median_s <= r.p90_s);
        assert!(r.mad_s > 0.0);
    }

    #[test]
    fn fixed_policy_pins_the_sample_count() {
        let mut n = 0u32;
        let s = sample(&Policy::fixed(7), || {
            n += 1;
            std::hint::black_box((0..50).sum::<u64>());
        });
        assert_eq!(s.len(), 7);
        // warmup ran too (up to the cap), so total calls exceed samples
        assert!(n >= 8 && n <= 7 + 2, "warmup {} outside cap", n - 7);
    }

    #[test]
    fn timed_policy_respects_min_and_cap() {
        let s = sample(&Policy::timed(5, 10_000), || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(s.len() >= 5, "min_iters floor");
        assert!(s.len() <= 10_000, "max_iters cap");
        assert!(s.iter().all(|&t| t >= 0.0));
    }

    #[test]
    #[should_panic]
    fn empty_input_is_a_harness_bug() {
        robust(&[]);
    }
}
