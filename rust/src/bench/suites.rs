//! The bench suites themselves: which cells each suite runs and how
//! their records are assembled. Shared by the `hot bench` subcommand
//! and the `cargo bench` shim binaries (`benches/kernel_gemm.rs`,
//! `benches/e2e_throughput.rs`), so a committed `BENCH_*.json` is
//! harness-produced no matter which entry point wrote it.
//!
//! Each suite returns a schema-v2 `BenchReport`; callers decide where
//! to write it and whether to diff it against a baseline
//! (`bench::compare`). Suites print their traditional terminal tables
//! as they go — the human-readable view the bench binaries always had.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::Result;

use crate::backend::Executor;
use crate::bench::record::{BenchRecord, BenchReport, git_sha,
                           PROVENANCE_MEASURED, SCHEMA_VERSION};
use crate::bench::stats::{self, Policy};
use crate::bench::{roofline, runner};
use crate::config::RunConfig;
use crate::coordinator::{Mode, Trainer};
use crate::kernels::{self, reference, Elem, Tier};
use crate::util::json::Json;
use crate::util::prng::Pcg32;
use crate::util::timer::Table;

/// Assemble the v2 provenance envelope around a suite's results.
fn envelope(bench: &str, smoke: bool, detail: &str,
            results: Vec<BenchRecord>,
            extra: BTreeMap<String, Json>) -> BenchReport {
    BenchReport {
        bench: bench.to_string(),
        schema_version: SCHEMA_VERSION,
        provenance: PROVENANCE_MEASURED.to_string(),
        provenance_detail: detail.to_string(),
        git_sha: git_sha(),
        host: roofline::host(smoke),
        tier: kernels::active_tier().name().to_string(),
        smoke,
        results,
        extra,
    }
}

/// Run one kernel cell through the runner and attribute it.
fn kernel_cell<F: FnMut()>(id: String, kind: &str, size: usize,
                           imp: &str, threads: usize, tier: Tier,
                           elem: Elem, policy: &Policy,
                           peak_bw: Option<f64>, f: F) -> BenchRecord {
    let m = runner::run_cell(policy, f);
    let roof = roofline::attribute(m.flops, m.bytes_moved,
                                   m.timing.median_s, tier, elem,
                                   threads, peak_bw);
    let mut params = BTreeMap::new();
    params.insert("kind".to_string(), Json::Str(kind.to_string()));
    params.insert("n".to_string(), Json::Num(size as f64));
    params.insert("k".to_string(), Json::Num(size as f64));
    params.insert("m".to_string(), Json::Num(size as f64));
    params.insert("impl".to_string(), Json::Str(imp.to_string()));
    params.insert("threads".to_string(), Json::Num(threads as f64));
    let gflops = m.gflops();
    BenchRecord {
        id,
        params,
        timing: m.timing,
        flops: m.flops,
        bytes_moved: m.bytes_moved,
        gflops,
        roofline: Some(roof),
    }
}

/// GEMM kernel throughput: naive oracle vs the scalar tier vs the SIMD
/// tier, f32 and i8, across thread budgets. The successor of the old
/// standalone `kernel_gemm` bench; cell ids are
/// `{kind}/{size}/{impl}/{threads}t`.
pub fn run_kernels(smoke: bool) -> BenchReport {
    let tier = kernels::active_tier();
    let simd_avail = tier != Tier::Scalar;
    let peak_bw = roofline::mem_bw_gbps(smoke);
    let sizes: &[(usize, u64)] = if smoke {
        &[(64, 40), (128, 80)]
    } else {
        &[(64, 150), (128, 250), (256, 600), (512, 1500)]
    };
    let mut results: Vec<BenchRecord> = Vec::new();
    for &(size, budget_ms) in sizes {
        let mut rng = Pcg32::seeded(size as u64);
        let a: Vec<f32> =
            (0..size * size).map(|_| rng.normal()).collect();
        let b: Vec<f32> =
            (0..size * size).map(|_| rng.normal()).collect();
        let qa: Vec<i8> = (0..size * size)
            .map(|_| (rng.below(255) as i32 - 127) as i8)
            .collect();
        let qb: Vec<i8> = (0..size * size)
            .map(|_| (rng.below(255) as i32 - 127) as i8)
            .collect();
        let policy = Policy::timed(budget_ms, 64);

        // naive oracles (single-threaded by construction); skipped at
        // large sizes where one naive iteration alone blows the budget
        if size <= 256 {
            results.push(kernel_cell(
                format!("f32/{size}/naive/1t"), "f32", size, "naive", 1,
                Tier::Scalar, Elem::F32, &policy, peak_bw, || {
                    std::hint::black_box(reference::matmul(
                        &a, &b, size, size, size));
                }));
            results.push(kernel_cell(
                format!("i8/{size}/naive/1t"), "i8", size, "naive", 1,
                Tier::Scalar, Elem::I8, &policy, peak_bw, || {
                    std::hint::black_box(reference::matmul_i8_nn(
                        &qa, &qb, size, size, size));
                }));
        }

        // blocked kernels: scalar tier vs SIMD tier at 1 / 2 / 4
        // threads
        for (imp, simd) in [("scalar", false), ("simd", true)] {
            if simd && !simd_avail {
                continue;
            }
            kernels::set_simd_enabled(simd);
            let cell_tier = if simd { tier } else { Tier::Scalar };
            for threads in [1usize, 2, 4] {
                kernels::set_num_threads(threads);
                results.push(kernel_cell(
                    format!("f32/{size}/{imp}/{threads}t"), "f32", size,
                    imp, threads, cell_tier, Elem::F32, &policy,
                    peak_bw, || {
                        std::hint::black_box(kernels::gemm_f32_nn(
                            &a, &b, size, size, size));
                    }));
                results.push(kernel_cell(
                    format!("i8/{size}/{imp}/{threads}t"), "i8", size,
                    imp, threads, cell_tier, Elem::I8, &policy,
                    peak_bw, || {
                        std::hint::black_box(kernels::gemm_i8_nn(
                            &qa, &qb, size, size, size));
                    }));
            }
        }
        kernels::set_simd_enabled(true);
        kernels::set_num_threads(0);
    }

    let find = |kind: &str, size: usize, imp: &str, threads: usize| {
        let id = format!("{kind}/{size}/{imp}/{threads}t");
        results.iter().find(|r| r.id == id).map(|r| r.gflops)
    };
    let mut t = Table::new(&["cell", "GFLOP/s", "median", "mad",
                             "vs scalar@1t", "roofline"]);
    for r in &results {
        let kind =
            r.params.get("kind").and_then(|v| v.as_str()).unwrap_or("?");
        let size = r.params.get("n").and_then(|v| v.as_usize())
            .unwrap_or(0);
        let base = find(kind, size, "scalar", 1).unwrap_or(f64::NAN);
        let roof = r.roofline.as_ref().map(|x| {
            match x.frac_peak {
                Some(fp) => format!("{} {:.0}%", x.bound, fp * 100.0),
                None => x.bound.clone(),
            }
        }).unwrap_or_default();
        t.row(&[r.id.clone(), format!("{:.2}", r.gflops),
                format!("{:.3}ms", r.timing.median_s * 1e3),
                format!("{:.1}us", r.timing.mad_s * 1e6),
                format!("{:.2}x", r.gflops / base), roof]);
    }
    t.print(&format!("GEMM kernels: naive vs scalar vs simd (tier: {})",
                     tier.name()));

    // scalar-vs-SIMD deltas at 1 thread: the acceptance-gate numbers
    let mut deltas: Vec<Json> = Vec::new();
    if simd_avail {
        for &(size, _) in sizes {
            for kind in ["f32", "i8"] {
                let (Some(s), Some(v)) = (find(kind, size, "scalar", 1),
                                          find(kind, size, "simd", 1))
                else {
                    continue;
                };
                let mut m = BTreeMap::new();
                m.insert("kind".to_string(),
                         Json::Str(kind.to_string()));
                m.insert("size".to_string(), Json::Num(size as f64));
                m.insert("scalar_gflops".to_string(), Json::Num(s));
                m.insert("simd_gflops".to_string(), Json::Num(v));
                m.insert("speedup".to_string(), Json::Num(v / s));
                deltas.push(Json::Obj(m));
            }
        }
    }
    let mut extra = BTreeMap::new();
    extra.insert("deltas".to_string(), Json::Arr(deltas));
    envelope(
        "kernels", smoke,
        "in-process timed run via the rust/src/bench harness: \
         warmup-detected sampling, MAD outlier rejection; FLOPs and \
         bytes from the kernels' own obs counters (one instrumented \
         run per cell), bandwidth ceiling from a stream-copy probe",
        results, extra)
}

/// End-to-end coordinator throughput: steady-state step time for
/// fused / split / accum across presets and (threads, simd) cells.
/// The per-step times ARE the samples — no hand-rolled wall loop; the
/// successor of the old standalone `e2e_throughput` bench. Cell ids
/// are `{preset}/{mode}/{threads}t/{simd|scalar}`.
pub fn run_e2e(rt: Arc<dyn Executor>, smoke: bool,
               steps: usize) -> Result<BenchReport> {
    let steps = steps.max(4);
    let presets: &[&str] =
        if smoke { &["tiny"] } else { &["tiny", "small", "base"] };
    let max_threads = kernels::num_threads();
    // (threads, simd) cells: the kernel pool and SIMD tier only drive
    // the native backend; sweeping them under PJRT would record
    // duplicate rows as fake scaling signal. The (1, scalar) cell is
    // the baseline the SIMD-tier step-time delta is read against.
    let simd_avail = kernels::active_tier() != Tier::Scalar;
    let mut cells = vec![(1usize, true)];
    if rt.name() == "native" {
        if simd_avail {
            cells.push((1, false));
        }
        if max_threads > 1 {
            cells.push((max_threads, true));
        }
    }
    let peak_bw = roofline::mem_bw_gbps(smoke);
    let mut results: Vec<BenchRecord> = Vec::new();
    let mut t = Table::new(&["cell", "step time", "mad", "steps/s",
                             "GFLOP/s", "data-gen share", "roofline"]);
    for preset in presets {
        for (mode_name, mode) in [("fused", Mode::Fused),
                                  ("split", Mode::Split),
                                  ("accum", Mode::Accum)] {
            // base is heavy: fused only, so the bench stays bounded
            if *preset == "base" && mode != Mode::Fused {
                continue;
            }
            let needed = match mode {
                Mode::Fused => format!("train_hot_{preset}"),
                Mode::Split => format!("fwd_hot_{preset}"),
                Mode::Accum => format!("grad_hot_{preset}"),
            };
            if !rt.supports(&needed) {
                continue;
            }
            // base steps are ~100x tiny steps; fewer samples keep the
            // bench bounded without losing the steady-state signal
            let steps_here =
                if *preset == "base" { steps.min(4) } else { steps };
            for &(threads, simd) in &cells {
                kernels::set_num_threads(threads);
                kernels::set_simd_enabled(simd);
                // record what actually ran, not what was requested: on
                // scalar-only hardware (or under PJRT, which bypasses
                // the kernel pool entirely) the row must not claim a
                // SIMD tier it never had
                let effective =
                    simd && simd_avail && rt.name() == "native";
                let mut cfg = RunConfig::default();
                cfg.preset = preset.to_string();
                cfg.variant = "hot".into();
                cfg.steps = steps_here;
                cfg.batch = 16;
                cfg.calib_batches = 0;
                if mode == Mode::Accum {
                    // measure real accumulation, not a degenerate loop
                    cfg.accum = 2;
                }
                let mut tr = Trainer::new(rt.clone(), cfg)?;
                // the runner's warmup phase absorbs the first
                // (compile/alloc-heavy) steps; each timed iteration is
                // one training step, so the step series feeds the
                // robust stats directly
                let m = runner::run_cell(
                    &Policy::fixed(steps_here.saturating_sub(1).max(3)),
                    || {
                        tr.step_once(mode).expect("step");
                    });
                // data-generation-only share, sampled the same way
                let bsz = tr.batch_size();
                let mut i = 0usize;
                let data = stats::robust(&stats::sample(
                    &Policy::fixed(20), || {
                        std::hint::black_box(tr.data.batch(0, i, bsz));
                        i += 1;
                    }));
                let step_s = m.timing.median_s;
                let datagen_share =
                    if step_s > 0.0 { data.median_s / step_s } else { 0.0 };
                let tier_here = kernels::active_tier();
                let roof = roofline::attribute(
                    m.flops, m.bytes_moved, step_s, tier_here,
                    Elem::F32, threads, peak_bw);
                let id = format!(
                    "{preset}/{mode_name}/{threads}t/{}",
                    if effective { "simd" } else { "scalar" });
                let mut params = BTreeMap::new();
                params.insert("preset".to_string(),
                              Json::Str(preset.to_string()));
                params.insert("mode".to_string(),
                              Json::Str(mode_name.to_string()));
                params.insert("threads".to_string(),
                              Json::Num(threads as f64));
                params.insert("simd".to_string(), Json::Bool(effective));
                params.insert("step_ms".to_string(),
                              Json::Num(step_s * 1e3));
                params.insert("steps_per_sec".to_string(),
                              Json::Num(if step_s > 0.0 {
                                  1.0 / step_s
                              } else {
                                  0.0
                              }));
                params.insert("datagen_share".to_string(),
                              Json::Num(datagen_share));
                t.row(&[id.clone(),
                        format!("{:.1} ms", step_s * 1e3),
                        format!("{:.2} ms", m.timing.mad_s * 1e3),
                        format!("{:.2}", 1.0 / step_s.max(1e-12)),
                        format!("{:.2}", m.gflops()),
                        format!("{:.1}%", 100.0 * datagen_share),
                        roof.bound.clone()]);
                let gflops = m.gflops();
                results.push(BenchRecord {
                    id,
                    params,
                    timing: m.timing,
                    flops: m.flops,
                    bytes_moved: m.bytes_moved,
                    gflops,
                    roofline: Some(roof),
                });
            }
        }
    }
    kernels::set_num_threads(0);
    kernels::set_simd_enabled(true);
    t.print(&format!("end-to-end throughput (HOT variant, {} backend)",
                     rt.name()));
    let mut extra = BTreeMap::new();
    extra.insert("backend".to_string(),
                 Json::Str(rt.name().to_string()));
    extra.insert("steps".to_string(), Json::Num(steps as f64));
    Ok(envelope(
        "e2e", smoke,
        "in-process timed run via the rust/src/bench harness: each \
         sample is one real training step (warmup steps absorbed by \
         the runner), FLOPs and bytes from obs counters over an \
         instrumented step, bandwidth ceiling from a stream-copy probe",
        results, extra))
}

/// Serving latency/throughput: p50/p99 and req/s through the real
/// multi-tenant `Server` across batch caps × tenant counts × serve
/// fault plans (`BENCH_serve.json`). This suite does NOT go through
/// `runner::run_cell` — its drain-to-zero obs-counter protocol assumes
/// a single thread charging counters, and serve workers charge them
/// concurrently — so each cell collects raw per-request latencies and
/// feeds them to the robust stats directly.
pub fn run_serve(smoke: bool) -> Result<BenchReport> {
    use std::time::{Duration, Instant};

    use crate::backend::NativeBackend;
    use crate::data::LmDataset;
    use crate::resilience::fault;
    use crate::serve::{Registry, ServeCfg, Server};

    let preset = "lm_tiny";
    let backend = NativeBackend::new();
    let p = backend.preset(preset)?;
    let base = backend.init_store(preset)?;
    let ds = LmDataset::new(p.model.seq, p.model.in_dim, 13);
    let n_requests = if smoke { 48 } else { 240 };
    let faults: &[(&str, Option<&str>)] = &[
        ("none", None),
        ("slow", Some("slow-request:5")),
        ("panic", Some("panic-in-batch:3")),
    ];
    let mut results: Vec<BenchRecord> = Vec::new();
    let mut t = Table::new(&["cell", "p50", "p99", "req/s", "ok", "shed",
                             "expired", "panics"]);
    for &max_batch in &[1usize, 8] {
        for &tenants in &[2usize, 8] {
            for &(fname, fplan) in faults {
                fault::disarm();
                if let Some(plan) = fplan {
                    fault::arm(fault::parse(plan)?);
                }
                let reg = Registry::new(base.share(), preset);
                for ti in 0..tenants {
                    reg.register(&format!("tenant-{ti}"))?;
                }
                let srv = Server::start(reg, ServeCfg {
                    preset: preset.into(),
                    max_queue: 512,
                    deadline: Duration::from_secs(30),
                    max_batch,
                    window: Duration::from_millis(1),
                    workers: 2,
                    ..ServeCfg::default()
                });
                let t0 = Instant::now();
                let mut pending = Vec::with_capacity(n_requests);
                for i in 0..n_requests {
                    let (x, _) = ds.batch(1, i as u64, 1);
                    let sent = Instant::now();
                    let rx =
                        srv.submit(&format!("tenant-{}", i % tenants), x);
                    pending.push((sent, rx));
                }
                // latency is measured at consume time in submission
                // order; per-tenant FIFO + round-robin keep completion
                // close to that order, so the skew is small
                let mut lat: Vec<f64> = Vec::new();
                let (mut ok, mut errs) = (0usize, 0usize);
                for (sent, rx) in pending {
                    match rx.recv_timeout(Duration::from_secs(60)) {
                        Ok(Ok(_)) => {
                            ok += 1;
                            lat.push(sent.elapsed().as_secs_f64());
                        }
                        Ok(Err(_)) => errs += 1,
                        Err(e) => {
                            anyhow::bail!("serve bench reply lost: {e}")
                        }
                    }
                }
                let wall = t0.elapsed().as_secs_f64();
                srv.shutdown();
                fault::disarm();
                let s = srv.stats();
                lat.sort_by(f64::total_cmp);
                if lat.is_empty() {
                    lat.push(0.0); // keep the record well-formed
                }
                let pct = |q: f64| {
                    lat[((lat.len() - 1) as f64 * q).round() as usize]
                };
                let (p50, p99) = (pct(0.50), pct(0.99));
                let req_s = ok as f64 / wall.max(1e-9);
                let timing = stats::robust(&lat);
                let id = format!("serve/b{max_batch}/t{tenants}/{fname}");
                let mut params = BTreeMap::new();
                params.insert("preset".into(),
                              Json::Str(preset.to_string()));
                params.insert("max_batch".into(),
                              Json::Num(max_batch as f64));
                params.insert("tenants".into(), Json::Num(tenants as f64));
                params.insert("fault".into(),
                              Json::Str(fplan.unwrap_or("none").into()));
                params.insert("requests".into(),
                              Json::Num(n_requests as f64));
                params.insert("p50_ms".into(), Json::Num(p50 * 1e3));
                params.insert("p99_ms".into(), Json::Num(p99 * 1e3));
                params.insert("req_per_sec".into(), Json::Num(req_s));
                params.insert("ok".into(), Json::Num(ok as f64));
                params.insert("errors".into(), Json::Num(errs as f64));
                params.insert("shed".into(), Json::Num(s.shed as f64));
                params.insert("expired".into(),
                              Json::Num(s.expired as f64));
                params.insert("panics".into(), Json::Num(s.panics as f64));
                params.insert("degraded_batches".into(),
                              Json::Num(s.degraded_batches as f64));
                t.row(&[id.clone(),
                        format!("{:.2} ms", p50 * 1e3),
                        format!("{:.2} ms", p99 * 1e3),
                        format!("{req_s:.1}"),
                        format!("{ok}"),
                        format!("{}", s.shed),
                        format!("{}", s.expired),
                        format!("{}", s.panics)]);
                results.push(BenchRecord {
                    id,
                    params,
                    timing,
                    flops: 0,
                    bytes_moved: 0,
                    gflops: 0.0,
                    roofline: None,
                });
            }
        }
    }
    t.print("serving latency/throughput (multi-tenant, lm_tiny)");
    let mut extra = BTreeMap::new();
    extra.insert("backend".into(), Json::Str("native".into()));
    extra.insert("requests_per_cell".into(),
                 Json::Num(n_requests as f64));
    extra.insert("workers".into(), Json::Num(2.0));
    Ok(envelope(
        "serve", smoke,
        "in-process timed serving through rust/src/serve: each sample \
         is one request's submit-to-reply latency through the bounded \
         queue, deadline-aware batcher and worker pool; p50/p99 from \
         the raw sorted latencies, req/s = served requests over the \
         cell's wall clock; fault cells run with the named HOT_FAULT \
         plan armed",
        results, extra))
}
