//! Run configuration for the coordinator: loaded from JSON files or CLI
//! overrides, validated against the artifact manifest.

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct RunConfig {
    /// artifact directory (contains manifest.json)
    pub artifacts: String,
    /// model preset name (must exist in the manifest)
    pub preset: String,
    /// backward variant ("fp" | "hot" | "lbp" | ...)
    pub variant: String,
    pub steps: usize,
    pub batch: usize,
    pub lr: f64,
    /// cosine-annealing floor as a fraction of lr
    pub lr_min_frac: f64,
    pub warmup_steps: usize,
    pub seed: u64,
    /// LQS calibration batches before training (0 = per-tensor everywhere)
    pub calib_batches: usize,
    /// LQS decision threshold (paper: 0.5 == "50% error difference")
    pub lqs_threshold: f64,
    /// memory budget for the ABC buffer manager (bytes; 0 = unlimited)
    pub mem_budget: u64,
    /// microbatches per optimizer step (grad accumulation; 1 = fused path)
    pub accum: usize,
    pub eval_every: usize,
    pub checkpoint_dir: Option<String>,
    /// synthetic-vision noise level (task difficulty; default 0.5)
    pub data_noise: f64,
    /// periodic checkpoint cadence in steps (0 = final step only)
    pub checkpoint_every: usize,
    /// retention: checkpoints kept besides the best-eval one
    pub keep_last: usize,
    /// numeric sentinels (finite loss/state, clip-rate watchdog)
    pub sentinel: bool,
    /// rollback budget before a sentinel trip aborts the run
    pub max_rollbacks: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts: "artifacts".into(),
            preset: "small".into(),
            variant: "hot".into(),
            steps: 100,
            batch: 32,
            lr: 1e-3,
            lr_min_frac: 0.1,
            warmup_steps: 10,
            seed: 0,
            calib_batches: 2,
            lqs_threshold: 0.5,
            mem_budget: 0,
            accum: 1,
            eval_every: 25,
            checkpoint_dir: None,
            data_noise: 0.5,
            checkpoint_every: 0,
            keep_last: 3,
            sentinel: true,
            max_rollbacks: 3,
        }
    }
}

impl RunConfig {
    pub fn from_json(j: &Json) -> Result<RunConfig> {
        let mut c = RunConfig::default();
        let obj = j.as_obj().context("run config must be a JSON object")?;
        for (k, v) in obj {
            match k.as_str() {
                "artifacts" => c.artifacts = v.as_str().context("artifacts")?.into(),
                "preset" => c.preset = v.as_str().context("preset")?.into(),
                "variant" => c.variant = v.as_str().context("variant")?.into(),
                "steps" => c.steps = v.as_usize().context("steps")?,
                "batch" => c.batch = v.as_usize().context("batch")?,
                "lr" => c.lr = v.as_f64().context("lr")?,
                "lr_min_frac" => c.lr_min_frac = v.as_f64().context("lr_min_frac")?,
                "warmup_steps" => c.warmup_steps = v.as_usize().context("warmup_steps")?,
                "seed" => c.seed = v.as_i64().context("seed")? as u64,
                "calib_batches" => c.calib_batches = v.as_usize().context("calib_batches")?,
                "lqs_threshold" => c.lqs_threshold = v.as_f64().context("lqs_threshold")?,
                "mem_budget" => c.mem_budget = v.as_i64().context("mem_budget")? as u64,
                "accum" => c.accum = v.as_usize().context("accum")?,
                "eval_every" => c.eval_every = v.as_usize().context("eval_every")?,
                "checkpoint_dir" => {
                    c.checkpoint_dir = Some(v.as_str().context("checkpoint_dir")?.into())
                }
                "data_noise" => c.data_noise = v.as_f64().context("data_noise")?,
                "checkpoint_every" => {
                    c.checkpoint_every = v.as_usize().context("checkpoint_every")?
                }
                "keep_last" => c.keep_last = v.as_usize().context("keep_last")?,
                "sentinel" => c.sentinel = v.as_bool().context("sentinel")?,
                "max_rollbacks" => {
                    c.max_rollbacks = v.as_usize().context("max_rollbacks")?
                }
                other => bail!("unknown config key {other:?}"),
            }
        }
        c.validate()?;
        Ok(c)
    }

    pub fn load(path: &str) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_json(&j)
    }

    pub fn validate(&self) -> Result<()> {
        if self.steps == 0 {
            bail!("steps must be > 0");
        }
        if self.accum == 0 {
            bail!("accum must be >= 1");
        }
        if !(0.0..=1.0).contains(&self.lr_min_frac) {
            bail!("lr_min_frac must be in [0,1]");
        }
        if self.lr <= 0.0 {
            bail!("lr must be positive");
        }
        if self.keep_last == 0 {
            bail!("keep_last must be >= 1");
        }
        Ok(())
    }

    /// Cosine-annealed LR with linear warmup (the paper's fine-tuning
    /// schedule), computed rust-side and fed to the step artifact.
    pub fn lr_at(&self, step: usize) -> f32 {
        let base = self.lr as f32;
        if step < self.warmup_steps {
            return base * (step as f32 + 1.0) / self.warmup_steps as f32;
        }
        let t = (step - self.warmup_steps) as f32
            / (self.steps.saturating_sub(self.warmup_steps)).max(1) as f32;
        let floor = base * self.lr_min_frac as f32;
        floor + 0.5 * (base - floor) * (1.0 + (std::f32::consts::PI * t).cos())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_overrides() {
        let j = Json::parse(
            r#"{"preset":"tiny","variant":"lbp","steps":7,"lr":0.01}"#).unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.preset, "tiny");
        assert_eq!(c.variant, "lbp");
        assert_eq!(c.steps, 7);
        assert!((c.lr - 0.01).abs() < 1e-12);
        assert_eq!(c.batch, 32); // default kept
    }

    #[test]
    fn unknown_key_rejected() {
        let j = Json::parse(r#"{"stepz": 5}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn validation() {
        let mut c = RunConfig::default();
        c.steps = 0;
        assert!(c.validate().is_err());
        c = RunConfig::default();
        c.lr = -1.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn lr_schedule_shape() {
        let mut c = RunConfig::default();
        c.steps = 100;
        c.warmup_steps = 10;
        c.lr = 1.0;
        c.lr_min_frac = 0.1;
        assert!(c.lr_at(0) < c.lr_at(9));
        let peak = c.lr_at(10);
        assert!((peak - 1.0).abs() < 1e-3);
        assert!(c.lr_at(99) < 0.2);
        assert!(c.lr_at(99) >= 0.1 - 1e-3);
    }
}
