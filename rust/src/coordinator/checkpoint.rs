//! Checkpointing: params + optimizer state as raw-f32 blobs with a JSON
//! header (same byte format as aot.py's init blobs, so a checkpoint can
//! seed any tool in the repo).
//!
//! The parameter blob loads *directly* into `WeightStore` slabs
//! (`WeightStore::from_le_bytes`) — bytes decode once into the `Arc`
//! allocations, with no intermediate `Vec<Value>` layer. Optimizer
//! moments stay `Value`s: they are `TrainState` material, never shared.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::backend::WeightStore;
use crate::runtime::manifest::TensorSpec;
use crate::runtime::value::Value;
use crate::util::json::Json;

#[derive(Debug)]
pub struct Checkpoint {
    pub step: usize,
    pub preset: String,
    pub variant: String,
    pub weights: WeightStore,
    pub m: Vec<Value>,
    pub v: Vec<Value>,
}

fn write_f32_blob(values: &[Value], path: &Path) -> Result<()> {
    let mut bytes = Vec::new();
    for v in values {
        for x in v.as_f32()? {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
    }
    std::fs::write(path, bytes)?;
    Ok(())
}

fn write_store_blob(weights: &WeightStore, path: &Path) -> Result<()> {
    let mut bytes = Vec::with_capacity(weights.total_bytes());
    for (_, d) in weights.iter() {
        for x in d {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
    }
    std::fs::write(path, bytes)?;
    Ok(())
}

fn read_f32_blob(specs: &[TensorSpec], path: &Path) -> Result<Vec<Value>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    let want: usize = specs.iter().map(|s| s.numel() * 4).sum();
    if bytes.len() != want {
        bail!("{path:?}: {} bytes, specs want {want}", bytes.len());
    }
    let mut out = Vec::with_capacity(specs.len());
    let mut off = 0;
    for s in specs {
        let n = s.numel();
        let mut data = vec![0.0f32; n];
        for (i, x) in data.iter_mut().enumerate() {
            let b = &bytes[off + 4 * i..off + 4 * i + 4];
            *x = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        }
        off += 4 * n;
        out.push(Value::F32 { shape: s.shape.clone(), data });
    }
    Ok(out)
}

impl Checkpoint {
    /// Writes `dir/ckpt_<step>.json` + three blobs alongside. The
    /// param blob streams straight from the store's slabs.
    pub fn save(&self, dir: &str) -> Result<String> {
        std::fs::create_dir_all(dir)?;
        let base = format!("ckpt_{:06}", self.step);
        let dirp = Path::new(dir);
        write_store_blob(&self.weights,
                         &dirp.join(format!("{base}.params.bin")))?;
        write_f32_blob(&self.m, &dirp.join(format!("{base}.m.bin")))?;
        write_f32_blob(&self.v, &dirp.join(format!("{base}.v.bin")))?;
        let mut hdr = BTreeMap::new();
        hdr.insert("step".into(), Json::Num(self.step as f64));
        hdr.insert("preset".into(), Json::Str(self.preset.clone()));
        hdr.insert("variant".into(), Json::Str(self.variant.clone()));
        let hdr_path = dirp.join(format!("{base}.json"));
        std::fs::write(&hdr_path, Json::Obj(hdr).to_string())?;
        Ok(hdr_path.to_string_lossy().into_owned())
    }

    /// Load from a header path written by `save`. The parameter bytes
    /// decode once, directly into `WeightStore` slabs.
    pub fn load(header_path: &str, param_specs: &[TensorSpec]) -> Result<Checkpoint> {
        let text = std::fs::read_to_string(header_path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let step = j.get("step").and_then(Json::as_usize).context("step")?;
        let preset = j.get("preset").and_then(Json::as_str).context("preset")?;
        let variant = j.get("variant").and_then(Json::as_str).context("variant")?;
        let base = header_path.strip_suffix(".json").context("header name")?;
        let pbytes = std::fs::read(format!("{base}.params.bin"))
            .with_context(|| format!("reading {base}.params.bin"))?;
        Ok(Checkpoint {
            step,
            preset: preset.into(),
            variant: variant.into(),
            weights: WeightStore::from_le_bytes(param_specs.to_vec(),
                                                &pbytes)?,
            m: read_f32_blob(param_specs, Path::new(&format!("{base}.m.bin")))?,
            v: read_f32_blob(param_specs, Path::new(&format!("{base}.v.bin")))?,
        })
    }

    /// Latest checkpoint header in a directory, if any.
    pub fn latest(dir: &str) -> Option<String> {
        let mut headers: Vec<String> = std::fs::read_dir(dir)
            .ok()?
            .filter_map(|e| e.ok())
            .map(|e| e.path().to_string_lossy().into_owned())
            .filter(|p| p.ends_with(".json") && p.contains("ckpt_"))
            .collect();
        headers.sort();
        headers.pop()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::DType;

    fn specs() -> Vec<TensorSpec> {
        vec![
            TensorSpec { name: "a".into(), shape: vec![2, 2], dtype: DType::F32 },
            TensorSpec { name: "b".into(), shape: vec![3], dtype: DType::F32 },
        ]
    }

    fn values(offset: f32) -> Vec<Value> {
        vec![
            Value::F32 { shape: vec![2, 2], data: vec![offset, 1.0, 2.0, 3.0] },
            Value::F32 { shape: vec![3], data: vec![4.0, 5.0, offset] },
        ]
    }

    fn store(offset: f32) -> WeightStore {
        WeightStore::from_values(specs(), values(offset)).unwrap()
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("hot_ckpt_test");
        let _ = std::fs::remove_dir_all(&dir);
        let dirs = dir.to_str().unwrap();
        let ck = Checkpoint {
            step: 42,
            preset: "small".into(),
            variant: "hot".into(),
            weights: store(0.5),
            m: values(1.5),
            v: values(2.5),
        };
        let hdr = ck.save(dirs).unwrap();
        let back = Checkpoint::load(&hdr, &specs()).unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(back.preset, "small");
        for ((_, a), (_, b)) in ck.weights.iter().zip(back.weights.iter()) {
            assert_eq!(a, b);
        }
        assert_eq!(back.v[1].as_f32().unwrap(), ck.v[1].as_f32().unwrap());
    }

    #[test]
    fn latest_finds_newest() {
        let dir = std::env::temp_dir().join("hot_ckpt_latest");
        let _ = std::fs::remove_dir_all(&dir);
        let dirs = dir.to_str().unwrap();
        for step in [5, 20, 10] {
            Checkpoint {
                step,
                preset: "p".into(),
                variant: "hot".into(),
                weights: store(0.0),
                m: values(0.0),
                v: values(0.0),
            }
            .save(dirs)
            .unwrap();
        }
        let latest = Checkpoint::latest(dirs).unwrap();
        assert!(latest.contains("ckpt_000020"), "{latest}");
    }

    #[test]
    fn size_mismatch_rejected() {
        let dir = std::env::temp_dir().join("hot_ckpt_bad");
        let _ = std::fs::remove_dir_all(&dir);
        let ck = Checkpoint {
            step: 1,
            preset: "p".into(),
            variant: "hot".into(),
            weights: store(0.0),
            m: values(0.0),
            v: values(0.0),
        };
        let hdr = ck.save(dir.to_str().unwrap()).unwrap();
        let bad_specs = vec![TensorSpec { name: "a".into(), shape: vec![100],
                                          dtype: DType::F32 }];
        assert!(Checkpoint::load(&hdr, &bad_specs).is_err());
    }
}
