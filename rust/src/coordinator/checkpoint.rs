//! Checkpointing: params + optimizer state as raw-f32 blobs with a JSON
//! header (same byte format as aot.py's init blobs, so a checkpoint can
//! seed any tool in the repo).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::TensorSpec;
use crate::runtime::value::Value;
use crate::util::json::Json;

#[derive(Debug)]
pub struct Checkpoint {
    pub step: usize,
    pub preset: String,
    pub variant: String,
    pub params: Vec<Value>,
    pub m: Vec<Value>,
    pub v: Vec<Value>,
}

fn write_f32_blob(values: &[Value], path: &Path) -> Result<()> {
    let mut bytes = Vec::new();
    for v in values {
        for x in v.as_f32()? {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
    }
    std::fs::write(path, bytes)?;
    Ok(())
}

fn read_f32_blob(specs: &[TensorSpec], path: &Path) -> Result<Vec<Value>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    let want: usize = specs.iter().map(|s| s.numel() * 4).sum();
    if bytes.len() != want {
        bail!("{path:?}: {} bytes, specs want {want}", bytes.len());
    }
    let mut out = Vec::with_capacity(specs.len());
    let mut off = 0;
    for s in specs {
        let n = s.numel();
        let mut data = vec![0.0f32; n];
        for (i, x) in data.iter_mut().enumerate() {
            let b = &bytes[off + 4 * i..off + 4 * i + 4];
            *x = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        }
        off += 4 * n;
        out.push(Value::F32 { shape: s.shape.clone(), data });
    }
    Ok(out)
}

impl Checkpoint {
    /// Writes `dir/ckpt_<step>.json` + three blobs alongside.
    pub fn save(&self, dir: &str) -> Result<String> {
        std::fs::create_dir_all(dir)?;
        let base = format!("ckpt_{:06}", self.step);
        let dirp = Path::new(dir);
        write_f32_blob(&self.params, &dirp.join(format!("{base}.params.bin")))?;
        write_f32_blob(&self.m, &dirp.join(format!("{base}.m.bin")))?;
        write_f32_blob(&self.v, &dirp.join(format!("{base}.v.bin")))?;
        let mut hdr = BTreeMap::new();
        hdr.insert("step".into(), Json::Num(self.step as f64));
        hdr.insert("preset".into(), Json::Str(self.preset.clone()));
        hdr.insert("variant".into(), Json::Str(self.variant.clone()));
        let hdr_path = dirp.join(format!("{base}.json"));
        std::fs::write(&hdr_path, Json::Obj(hdr).to_string())?;
        Ok(hdr_path.to_string_lossy().into_owned())
    }

    /// Load from a header path written by `save`.
    pub fn load(header_path: &str, param_specs: &[TensorSpec]) -> Result<Checkpoint> {
        let text = std::fs::read_to_string(header_path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let step = j.get("step").and_then(Json::as_usize).context("step")?;
        let preset = j.get("preset").and_then(Json::as_str).context("preset")?;
        let variant = j.get("variant").and_then(Json::as_str).context("variant")?;
        let base = header_path.strip_suffix(".json").context("header name")?;
        Ok(Checkpoint {
            step,
            preset: preset.into(),
            variant: variant.into(),
            params: read_f32_blob(param_specs, Path::new(&format!("{base}.params.bin")))?,
            m: read_f32_blob(param_specs, Path::new(&format!("{base}.m.bin")))?,
            v: read_f32_blob(param_specs, Path::new(&format!("{base}.v.bin")))?,
        })
    }

    /// Latest checkpoint header in a directory, if any.
    pub fn latest(dir: &str) -> Option<String> {
        let mut headers: Vec<String> = std::fs::read_dir(dir)
            .ok()?
            .filter_map(|e| e.ok())
            .map(|e| e.path().to_string_lossy().into_owned())
            .filter(|p| p.ends_with(".json") && p.contains("ckpt_"))
            .collect();
        headers.sort();
        headers.pop()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::DType;

    fn specs() -> Vec<TensorSpec> {
        vec![
            TensorSpec { name: "a".into(), shape: vec![2, 2], dtype: DType::F32 },
            TensorSpec { name: "b".into(), shape: vec![3], dtype: DType::F32 },
        ]
    }

    fn values(offset: f32) -> Vec<Value> {
        vec![
            Value::F32 { shape: vec![2, 2], data: vec![offset, 1.0, 2.0, 3.0] },
            Value::F32 { shape: vec![3], data: vec![4.0, 5.0, offset] },
        ]
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("hot_ckpt_test");
        let _ = std::fs::remove_dir_all(&dir);
        let dirs = dir.to_str().unwrap();
        let ck = Checkpoint {
            step: 42,
            preset: "small".into(),
            variant: "hot".into(),
            params: values(0.5),
            m: values(1.5),
            v: values(2.5),
        };
        let hdr = ck.save(dirs).unwrap();
        let back = Checkpoint::load(&hdr, &specs()).unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(back.preset, "small");
        assert_eq!(back.params[0].as_f32().unwrap(),
                   ck.params[0].as_f32().unwrap());
        assert_eq!(back.v[1].as_f32().unwrap(), ck.v[1].as_f32().unwrap());
    }

    #[test]
    fn latest_finds_newest() {
        let dir = std::env::temp_dir().join("hot_ckpt_latest");
        let _ = std::fs::remove_dir_all(&dir);
        let dirs = dir.to_str().unwrap();
        for step in [5, 20, 10] {
            Checkpoint {
                step,
                preset: "p".into(),
                variant: "hot".into(),
                params: values(0.0),
                m: values(0.0),
                v: values(0.0),
            }
            .save(dirs)
            .unwrap();
        }
        let latest = Checkpoint::latest(dirs).unwrap();
        assert!(latest.contains("ckpt_000020"), "{latest}");
    }

    #[test]
    fn size_mismatch_rejected() {
        let dir = std::env::temp_dir().join("hot_ckpt_bad");
        let _ = std::fs::remove_dir_all(&dir);
        let ck = Checkpoint {
            step: 1,
            preset: "p".into(),
            variant: "hot".into(),
            params: values(0.0),
            m: values(0.0),
            v: values(0.0),
        };
        let hdr = ck.save(dir.to_str().unwrap()).unwrap();
        let bad_specs = vec![TensorSpec { name: "a".into(), shape: vec![100],
                                          dtype: DType::F32 }];
        assert!(Checkpoint::load(&hdr, &bad_specs).is_err());
    }
}
