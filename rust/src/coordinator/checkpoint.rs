//! Checkpointing: params + optimizer state as raw-f32 blobs with a
//! signed JSON manifest (same blob byte format as aot.py's init blobs,
//! so a checkpoint can seed any tool in the repo).
//!
//! The parameter blob loads *directly* into `WeightStore` slabs
//! (`WeightStore::from_le_bytes`) — bytes decode once into the `Arc`
//! allocations, with no intermediate `Vec<Value>` layer. Optimizer
//! moments stay `Value`s: they are `TrainState` material, never shared.
//!
//! Crash safety (DESIGN.md §Resilience): every file goes through the
//! atomic write protocol (tmp + fsync + rename), blobs land before the
//! manifest, and the manifest carries per-blob and per-tensor CRC-32s
//! plus a keyed signature — so a torn, truncated, bit-rotted, or
//! shuffled checkpoint is detected with a typed reason instead of
//! loading garbage into the weight slabs.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::backend::WeightStore;
use crate::resilience::fault;
use crate::resilience::manifest::{
    write_atomic, BlobSum, CkptManifest, RejectReason, Schedule, CKPT_FORMAT,
};
use crate::runtime::manifest::TensorSpec;
use crate::runtime::value::Value;

#[derive(Debug)]
pub struct Checkpoint {
    pub step: usize,
    pub preset: String,
    pub variant: String,
    pub weights: WeightStore,
    pub m: Vec<Value>,
    pub v: Vec<Value>,
}

/// Run context recorded in the manifest so `--resume` can replay the
/// exact trajectory: data-PRNG cursor = (seed, step, accum), the LR
/// schedule, the LQS selections, and the latest eval loss (retention's
/// best-eval input). `Default` is for context-free saves (tools/tests).
#[derive(Debug, Clone, Default)]
pub struct SaveCtx {
    pub seed: u64,
    pub accum: usize,
    pub schedule: Schedule,
    pub lqs_mask: Vec<f32>,
    pub eval_loss: Option<f64>,
}

fn values_bytes(values: &[Value]) -> Result<Vec<u8>> {
    let mut bytes = Vec::new();
    for v in values {
        for x in v.as_f32()? {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
    }
    Ok(bytes)
}

fn store_bytes(weights: &WeightStore) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(weights.total_bytes());
    for (_, d) in weights.iter() {
        for x in d {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
    }
    bytes
}

/// Decode a verified blob into `Value`s (sorted-spec order). Lengths
/// were already pinned by `BlobSum::verify`.
fn decode_values(specs: &[TensorSpec], bytes: &[u8]) -> Vec<Value> {
    let mut out = Vec::with_capacity(specs.len());
    let mut off = 0;
    for s in specs {
        let n = s.numel();
        let mut data = vec![0.0f32; n];
        for (i, x) in data.iter_mut().enumerate() {
            let b = &bytes[off + 4 * i..off + 4 * i + 4];
            *x = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        }
        off += 4 * n;
        out.push(Value::F32 { shape: s.shape.clone(), data });
    }
    out
}

impl Checkpoint {
    /// Context-free save (unit tests, tools): manifest carries zeros
    /// for the run context. Training saves go through [`save_with`].
    ///
    /// [`save_with`]: Checkpoint::save_with
    pub fn save(&self, dir: &str) -> Result<String> {
        self.save_with(dir, &SaveCtx::default())
    }

    /// Writes `dir/ckpt_<step>.json` + three blobs alongside, each via
    /// the atomic write protocol, blobs first and the signed manifest
    /// last — a crash at any point leaves either a complete checkpoint
    /// or an unloadable torn one. The param blob streams straight from
    /// the store's slabs.
    pub fn save_with(&self, dir: &str, ctx: &SaveCtx) -> Result<String> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint dir {dir}"))?;
        let base = format!("ckpt_{:06}", self.step);
        let dirp = Path::new(dir);
        let specs = self.weights.specs();

        let blobs: Vec<(&str, String, Vec<u8>)> = vec![
            ("params", format!("{base}.params.bin"),
             store_bytes(&self.weights)),
            ("m", format!("{base}.m.bin"), values_bytes(&self.m)?),
            ("v", format!("{base}.v.bin"), values_bytes(&self.v)?),
        ];
        let man = CkptManifest {
            format: CKPT_FORMAT,
            step: self.step,
            preset: self.preset.clone(),
            variant: self.variant.clone(),
            simd_tier: crate::kernels::active_tier().name().to_string(),
            threads: crate::kernels::num_threads(),
            seed: ctx.seed,
            accum: ctx.accum,
            schedule: ctx.schedule.clone(),
            lqs_mask: ctx.lqs_mask.clone(),
            eval_loss: ctx.eval_loss,
            blobs: blobs
                .iter()
                .map(|(_, file, bytes)| BlobSum::of(file, specs, bytes))
                .collect(),
        };

        // checksums above were taken from the true bytes; injected
        // corruption lands *after*, modeling on-disk rot that the
        // loader's CRC pass must catch
        for (i, (kind, file, bytes)) in blobs.into_iter().enumerate() {
            let mut bytes = bytes;
            if let Some(desc) = fault::mutate_blob(kind, &mut bytes) {
                crate::warn_!("{desc}");
            }
            write_atomic(&dirp.join(&file), &bytes, kind)?;
            if i == 0 && fault::crash_between_blobs() {
                bail!("simulated crash between blob writes (HOT_FAULT \
                       crash-between-blobs): {base} left torn");
            }
        }
        let mut text = man.to_signed_text().into_bytes();
        if let Some(desc) = fault::mutate_blob("manifest", &mut text) {
            crate::warn_!("{desc}");
        }
        let hdr_path = dirp.join(format!("{base}.json"));
        write_atomic(&hdr_path, &text, "manifest")?;
        Ok(hdr_path.to_string_lossy().into_owned())
    }

    /// Fully verified load: manifest signature, blob sizes, whole-blob
    /// CRCs, per-tensor extent CRCs against the live `specs` — any
    /// failure returns the typed [`RejectReason`] naming the offending
    /// file or tensor. Returns the manifest too, so resume can restore
    /// the data cursor / schedule / LQS selections it records.
    pub fn load_verified(header_path: &str, specs: &[TensorSpec])
                         -> Result<(Checkpoint, CkptManifest), RejectReason> {
        let man = CkptManifest::read(header_path)?;
        let dir = Path::new(header_path)
            .parent()
            .map(Path::to_path_buf)
            .unwrap_or_default();
        let blob = |suffix: &str| {
            man.blobs
                .iter()
                .find(|b| b.file.ends_with(suffix))
                .ok_or_else(|| RejectReason::MissingField {
                    path: header_path.to_string(),
                    field: format!("blobs[*{suffix}]"),
                })
        };
        let read = |sum: &BlobSum| -> Result<Vec<u8>, RejectReason> {
            let p = dir.join(&sum.file);
            let bytes =
                std::fs::read(&p).map_err(|e| RejectReason::BlobIo {
                    file: p.to_string_lossy().into_owned(),
                    err: e.to_string(),
                })?;
            sum.verify(specs, &bytes)?;
            Ok(bytes)
        };
        let pbytes = read(blob(".params.bin")?)?;
        let mbytes = read(blob(".m.bin")?)?;
        let vbytes = read(blob(".v.bin")?)?;
        let weights = WeightStore::from_le_bytes(specs.to_vec(), &pbytes)
            .map_err(|e| RejectReason::SpecMismatch {
                detail: e.to_string(),
            })?;
        Ok((
            Checkpoint {
                step: man.step,
                preset: man.preset.clone(),
                variant: man.variant.clone(),
                weights,
                m: decode_values(specs, &mbytes),
                v: decode_values(specs, &vbytes),
            },
            man,
        ))
    }

    /// Load from a header path written by `save`, with full
    /// verification; errors name the offending file/tensor. The
    /// parameter bytes decode once, directly into `WeightStore` slabs.
    pub fn load(header_path: &str, param_specs: &[TensorSpec])
                -> Result<Checkpoint> {
        let (ck, _) = Self::load_verified(header_path, param_specs)
            .map_err(anyhow::Error::new)
            .with_context(|| format!("loading checkpoint {header_path}"))?;
        Ok(ck)
    }

    /// Latest checkpoint header in a directory, if any. Purely
    /// name-based; use `resilience::resume_latest_valid` to also walk
    /// past corrupt or torn checkpoints.
    pub fn latest(dir: &str) -> Option<String> {
        let mut headers: Vec<String> = std::fs::read_dir(dir)
            .ok()?
            .filter_map(|e| e.ok())
            .map(|e| e.path().to_string_lossy().into_owned())
            .filter(|p| p.ends_with(".json") && p.contains("ckpt_"))
            .collect();
        headers.sort();
        headers.pop()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resilience::fault::{self, FaultPlan};
    use crate::resilience::store::resume_latest_valid;
    use crate::runtime::manifest::DType;

    fn specs() -> Vec<TensorSpec> {
        vec![
            TensorSpec { name: "a".into(), shape: vec![2, 2], dtype: DType::F32 },
            TensorSpec { name: "b".into(), shape: vec![3], dtype: DType::F32 },
        ]
    }

    fn values(offset: f32) -> Vec<Value> {
        vec![
            Value::F32 { shape: vec![2, 2], data: vec![offset, 1.0, 2.0, 3.0] },
            Value::F32 { shape: vec![3], data: vec![4.0, 5.0, offset] },
        ]
    }

    fn store(offset: f32) -> WeightStore {
        WeightStore::from_values(specs(), values(offset)).unwrap()
    }

    fn ckpt(step: usize, offset: f32) -> Checkpoint {
        Checkpoint {
            step,
            preset: "small".into(),
            variant: "hot".into(),
            weights: store(offset),
            m: values(offset + 1.0),
            v: values(offset + 2.0),
        }
    }

    #[test]
    fn roundtrip() {
        let _g = fault::test_lock();
        let dir = std::env::temp_dir().join("hot_ckpt_test");
        let _ = std::fs::remove_dir_all(&dir);
        let dirs = dir.to_str().unwrap();
        let ck = ckpt(42, 0.5);
        let hdr = ck.save(dirs).unwrap();
        let back = Checkpoint::load(&hdr, &specs()).unwrap();
        assert_eq!(back.step, 42);
        assert_eq!(back.preset, "small");
        for ((_, a), (_, b)) in ck.weights.iter().zip(back.weights.iter()) {
            assert_eq!(a, b);
        }
        assert_eq!(back.v[1].as_f32().unwrap(), ck.v[1].as_f32().unwrap());
    }

    #[test]
    fn latest_finds_newest() {
        let _g = fault::test_lock();
        let dir = std::env::temp_dir().join("hot_ckpt_latest");
        let _ = std::fs::remove_dir_all(&dir);
        let dirs = dir.to_str().unwrap();
        for step in [5, 20, 10] {
            ckpt(step, 0.0).save(dirs).unwrap();
        }
        let latest = Checkpoint::latest(dirs).unwrap();
        assert!(latest.contains("ckpt_000020"), "{latest}");
    }

    #[test]
    fn size_mismatch_rejected() {
        let _g = fault::test_lock();
        let dir = std::env::temp_dir().join("hot_ckpt_bad");
        let _ = std::fs::remove_dir_all(&dir);
        let hdr = ckpt(1, 0.0).save(dir.to_str().unwrap()).unwrap();
        let bad_specs = vec![TensorSpec { name: "a".into(), shape: vec![100],
                                          dtype: DType::F32 }];
        let err = Checkpoint::load(&hdr, &bad_specs);
        assert!(err.is_err());
        // the verified path reports the typed reason
        assert!(matches!(Checkpoint::load_verified(&hdr, &bad_specs),
                         Err(RejectReason::SpecMismatch { .. })));
    }

    #[test]
    fn corrupt_blob_rejected_with_crc_reason() {
        let _g = fault::test_lock();
        let dir = std::env::temp_dir().join("hot_ckpt_crc");
        let _ = std::fs::remove_dir_all(&dir);
        let hdr = ckpt(3, 0.0).save(dir.to_str().unwrap()).unwrap();
        let blob = hdr.replace(".json", ".m.bin");
        let mut bytes = std::fs::read(&blob).unwrap();
        bytes[5] ^= 0x01;
        std::fs::write(&blob, &bytes).unwrap();
        match Checkpoint::load_verified(&hdr, &specs()) {
            Err(RejectReason::BlobCrc { file, .. }) => {
                assert!(file.ends_with(".m.bin"), "{file}");
            }
            other => panic!("wanted BlobCrc, got {other:?}"),
        }
        // anyhow path names the file too
        let msg = format!("{:#}", Checkpoint::load(&hdr, &specs())
            .unwrap_err());
        assert!(msg.contains(".m.bin"), "{msg}");
    }

    #[test]
    fn crash_between_blobs_leaves_no_loadable_checkpoint() {
        let _g = fault::test_lock();
        let dir = std::env::temp_dir().join("hot_ckpt_crash");
        let _ = std::fs::remove_dir_all(&dir);
        let dirs = dir.to_str().unwrap();
        fault::arm(FaultPlan::CrashBetweenBlobs);
        let err = ckpt(7, 0.0).save(dirs);
        assert!(err.is_err(), "save must abort at the crash point");
        assert!(Checkpoint::latest(dirs).is_none(), "no manifest on disk");
        let scan = resume_latest_valid(dirs, &specs(), None);
        assert!(scan.loaded.is_none());
        assert!(matches!(scan.rejected[0].reason,
                         RejectReason::ManifestMissing { step: 7 }));
        // the fault fired once; the retry save is clean and loads
        let hdr = ckpt(7, 0.0).save(dirs).unwrap();
        assert!(Checkpoint::load(&hdr, &specs()).is_ok());
        fault::disarm();
    }

    #[test]
    fn tampered_manifest_rejected() {
        let _g = fault::test_lock();
        let dir = std::env::temp_dir().join("hot_ckpt_tamper");
        let _ = std::fs::remove_dir_all(&dir);
        let hdr = ckpt(2, 0.0).save(dir.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&hdr).unwrap();
        // forge the step field without re-signing
        let forged = text.replace("\"step\":2", "\"step\":9");
        assert_ne!(forged, text);
        std::fs::write(&hdr, forged).unwrap();
        assert!(matches!(Checkpoint::load_verified(&hdr, &specs()),
                         Err(RejectReason::BadSignature { .. })));
    }
}
