//! ABC context-buffer manager — the rust-owned "CTX" of the paper's
//! Fig 5.
//!
//! In split fwd/bwd mode the forward artifact emits every saved-for-
//! backward tensor (under HOT+ABC the qlinear entries arrive already
//! HLA+INT8 compressed); this store holds them between the two calls,
//! does byte-exact accounting (live bytes / peak / cumulative), enforces
//! an optional memory budget (reproducing Fig 1's OOM wall as a typed
//! error), and can repack INT4-range payloads two-nibbles-per-byte.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::runtime::manifest::{CtxSpec, DType};
use crate::runtime::value::Value;

#[derive(Debug, Default, Clone)]
pub struct CtxStats {
    pub live_bytes: u64,
    pub peak_bytes: u64,
    pub total_allocated: u64,
    pub allocs: u64,
    pub frees: u64,
    /// bytes the same tensors would occupy raw-FP32 (savings denominator)
    pub fp32_equiv_bytes: u64,
}

#[derive(Debug)]
pub struct BudgetExceeded {
    pub requested: u64,
    pub live: u64,
    pub budget: u64,
}

impl std::fmt::Display for BudgetExceeded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ctx budget exceeded: live {} + requested {} > budget {} \
                (the Fig-1 OOM wall)", self.live, self.requested, self.budget)
    }
}

impl std::error::Error for BudgetExceeded {}

/// One microbatch's saved context.
#[derive(Debug)]
struct Entry {
    values: Vec<Value>,
    bytes: u64,
}

#[derive(Debug)]
pub struct CtxStore {
    /// 0 = unlimited
    budget: u64,
    entries: BTreeMap<u64, Entry>,
    stats: CtxStats,
}

impl CtxStore {
    pub fn new(budget: u64) -> CtxStore {
        CtxStore { budget, entries: BTreeMap::new(), stats: CtxStats::default() }
    }

    /// Store the ctx tensors of microbatch `id`. `specs` (from the fwd
    /// artifact manifest) drive the FP32-equivalent accounting.
    pub fn put(&mut self, id: u64, values: Vec<Value>, specs: &[CtxSpec])
               -> Result<()> {
        if self.entries.contains_key(&id) {
            bail!("ctx for microbatch {id} already stored");
        }
        let bytes: u64 = values.iter().map(|v| v.bytes() as u64).sum();
        if self.budget > 0 && self.stats.live_bytes + bytes > self.budget {
            return Err(BudgetExceeded {
                requested: bytes,
                live: self.stats.live_bytes,
                budget: self.budget,
            }
            .into());
        }
        // fp32-equivalent: int8 ctx entries are HOT-compressed activations;
        // they stand in for an uncompressed (16/rank)x f32 buffer. We can't
        // recover rank from shape alone, so we charge the conservative
        // int8->f32 factor (4x) plus the HLA factor recorded by the spec
        // metadata when key == "xq" (rank-compressed along L).
        let mut fp32_equiv = 0u64;
        for (v, s) in values.iter().zip(specs) {
            let f = match (v.dtype(), s.key.as_str()) {
                (DType::I8, "xq") => 8, // int8 (4x) * HLA r=8/16 (2x)
                (DType::I8, _) => 4,
                _ => 1,
            };
            fp32_equiv += v.bytes() as u64 * f;
        }
        self.stats.live_bytes += bytes;
        self.stats.peak_bytes = self.stats.peak_bytes.max(self.stats.live_bytes);
        self.stats.total_allocated += bytes;
        self.stats.fp32_equiv_bytes += fp32_equiv;
        self.stats.allocs += 1;
        self.entries.insert(id, Entry { values, bytes });
        Ok(())
    }

    /// Take (and free) the ctx of microbatch `id` for its backward pass.
    pub fn take(&mut self, id: u64) -> Result<Vec<Value>> {
        match self.entries.remove(&id) {
            None => bail!("no ctx stored for microbatch {id}"),
            Some(e) => {
                self.stats.live_bytes -= e.bytes;
                self.stats.frees += 1;
                Ok(e.values)
            }
        }
    }

    pub fn live_microbatches(&self) -> usize {
        self.entries.len()
    }

    pub fn stats(&self) -> &CtxStats {
        &self.stats
    }

    /// Compression ratio achieved vs keeping FP32 activations (>= 1).
    pub fn compression_ratio(&self) -> f64 {
        if self.stats.total_allocated == 0 {
            return 1.0;
        }
        self.stats.fp32_equiv_bytes as f64 / self.stats.total_allocated as f64
    }

    /// Repack an int8 ctx tensor whose values fit INT4 into nibbles
    /// (storage-side only; unpacked before the bwd call). Returns packed
    /// bytes or None if any value is outside [-8, 7].
    pub fn pack_nibbles(v: &Value) -> Option<Vec<u8>> {
        let data = v.as_i8().ok()?;
        if data.len() % 2 != 0 || data.iter().any(|&q| !(-8..=7).contains(&q)) {
            return None;
        }
        Some(crate::quant::pack_int4(data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn val(n: usize, dt: DType) -> Value {
        match dt {
            DType::F32 => Value::F32 { shape: vec![n], data: vec![0.5; n] },
            DType::I8 => Value::I8 { shape: vec![n], data: vec![3; n] },
            DType::I32 => Value::I32 { shape: vec![n], data: vec![1; n] },
        }
    }

    fn spec(key: &str) -> CtxSpec {
        CtxSpec { module: "m".into(), kind: "ql".into(), key: key.into(),
                  shape: vec![], dtype: DType::I8, index: 0 }
    }

    #[test]
    fn accounting_alloc_free() {
        let mut s = CtxStore::new(0);
        s.put(0, vec![val(100, DType::F32)], &[spec("x")]).unwrap();
        assert_eq!(s.stats().live_bytes, 400);
        s.put(1, vec![val(50, DType::I8)], &[spec("xq")]).unwrap();
        assert_eq!(s.stats().live_bytes, 450);
        assert_eq!(s.stats().peak_bytes, 450);
        s.take(0).unwrap();
        assert_eq!(s.stats().live_bytes, 50);
        s.take(1).unwrap();
        assert_eq!(s.stats().live_bytes, 0);
        assert_eq!(s.stats().allocs, 2);
        assert_eq!(s.stats().frees, 2);
        assert_eq!(s.stats().peak_bytes, 450);
    }

    #[test]
    fn budget_wall() {
        let mut s = CtxStore::new(500);
        s.put(0, vec![val(100, DType::F32)], &[spec("x")]).unwrap();
        let err = s.put(1, vec![val(100, DType::F32)], &[spec("x")]);
        assert!(err.is_err());
        let msg = format!("{}", err.unwrap_err());
        assert!(msg.contains("budget exceeded"), "{msg}");
        // after freeing, it fits
        s.take(0).unwrap();
        s.put(1, vec![val(100, DType::F32)], &[spec("x")]).unwrap();
    }

    #[test]
    fn double_put_and_missing_take_rejected() {
        let mut s = CtxStore::new(0);
        s.put(3, vec![val(1, DType::F32)], &[spec("x")]).unwrap();
        assert!(s.put(3, vec![val(1, DType::F32)], &[spec("x")]).is_err());
        assert!(s.take(9).is_err());
    }

    #[test]
    fn compression_ratio_abc() {
        let mut s = CtxStore::new(0);
        // one compressed activation: 1000 int8 bytes standing for 8000 fp32
        s.put(0, vec![val(1000, DType::I8)], &[spec("xq")]).unwrap();
        assert!((s.compression_ratio() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn nibble_packing() {
        let v = val(10, DType::I8);
        let packed = CtxStore::pack_nibbles(&v).unwrap();
        assert_eq!(packed.len(), 5);
        let big = Value::I8 { shape: vec![2], data: vec![100, 0] };
        assert!(CtxStore::pack_nibbles(&big).is_none());
    }

    #[test]
    fn prop_conservation() {
        // alloc/free in arbitrary interleavings: live == sum of live
        // entries, peak >= live always, final live == 0
        crate::util::proptest::check("ctx conservation", 25, |case| {
            let mut s = CtxStore::new(0);
            let n_ops = case.usize_in(1, 20);
            let mut live: Vec<(u64, u64)> = vec![];
            let mut next_id = 0u64;
            for _ in 0..n_ops {
                if live.is_empty() || case.rng.uniform() < 0.6 {
                    let n = case.usize_in(1, 64);
                    s.put(next_id, vec![val(n, DType::F32)], &[spec("x")])
                        .map_err(|e| e.to_string())?;
                    live.push((next_id, 4 * n as u64));
                    next_id += 1;
                } else {
                    let k = case.usize_in(0, live.len() - 1);
                    let (id, _) = live.remove(k);
                    s.take(id).map_err(|e| e.to_string())?;
                }
                let want: u64 = live.iter().map(|(_, b)| b).sum();
                if s.stats().live_bytes != want {
                    return Err(format!("live {} != {}", s.stats().live_bytes, want));
                }
                if s.stats().peak_bytes < s.stats().live_bytes {
                    return Err("peak < live".into());
                }
            }
            for (id, _) in live {
                s.take(id).map_err(|e| e.to_string())?;
            }
            if s.stats().live_bytes != 0 {
                return Err("leak at end".into());
            }
            Ok(())
        });
    }
}
