//! ABC context-buffer manager — the rust-owned "CTX" of the paper's
//! Fig 5.
//!
//! In split fwd/bwd mode the forward emits every saved-for-backward
//! tensor (under HOT+ABC the entries arrive in the packed
//! `Value::QuantF32` storage format — HLA + per-row INT8/INT4 codes
//! with nibble packing); this store holds them between the two calls,
//! does byte-exact accounting of the true stored footprint (live /
//! peak / cumulative, plus the FP32-equivalent derived from `CtxSpec`
//! rank metadata), enforces an optional memory budget (reproducing
//! Fig 1's OOM wall as a typed error), and expands nibble payloads to
//! one-byte codes on `take`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::hadamard::BLOCK;
use crate::runtime::manifest::CtxSpec;
use crate::runtime::value::Value;

#[derive(Debug, Default, Clone)]
pub struct CtxStats {
    pub live_bytes: u64,
    pub peak_bytes: u64,
    pub total_allocated: u64,
    pub allocs: u64,
    pub frees: u64,
    /// bytes the same tensors would occupy raw-FP32 (savings denominator)
    pub fp32_equiv_bytes: u64,
}

#[derive(Debug)]
pub struct BudgetExceeded {
    pub requested: u64,
    pub live: u64,
    pub budget: u64,
}

impl std::fmt::Display for BudgetExceeded {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ctx budget exceeded: live {} + requested {} > budget {} \
                (the Fig-1 OOM wall)", self.live, self.requested, self.budget)
    }
}

impl std::error::Error for BudgetExceeded {}

/// One microbatch's saved context.
#[derive(Debug)]
struct Entry {
    values: Vec<Value>,
    bytes: u64,
}

#[derive(Debug)]
pub struct CtxStore {
    /// 0 = unlimited
    budget: u64,
    entries: BTreeMap<u64, Entry>,
    stats: CtxStats,
}

impl CtxStore {
    pub fn new(budget: u64) -> CtxStore {
        CtxStore { budget, entries: BTreeMap::new(), stats: CtxStats::default() }
    }

    /// Store the ctx tensors of microbatch `id`. `specs` (from the fwd
    /// artifact manifest) drive the FP32-equivalent accounting; a
    /// values/specs arity mismatch is a hard error — a silent `zip`
    /// truncation here would under-account live bytes forever.
    pub fn put(&mut self, id: u64, values: Vec<Value>, specs: &[CtxSpec])
               -> Result<()> {
        if self.entries.contains_key(&id) {
            bail!("ctx for microbatch {id} already stored");
        }
        if values.len() != specs.len() {
            bail!("ctx arity mismatch for microbatch {id}: {} values vs {} \
                   specs — accounting would silently drop the difference",
                  values.len(), specs.len());
        }
        let bytes: u64 = values.iter().map(|v| v.bytes() as u64).sum();
        if self.budget > 0 && self.stats.live_bytes + bytes > self.budget {
            return Err(BudgetExceeded {
                requested: bytes,
                live: self.stats.live_bytes,
                budget: self.budget,
            }
            .into());
        }
        let fp32_equiv = values
            .iter()
            .zip(specs)
            .map(|(v, s)| fp32_equiv_bytes(v, s))
            .sum::<u64>();
        self.stats.live_bytes += bytes;
        self.stats.peak_bytes = self.stats.peak_bytes.max(self.stats.live_bytes);
        self.stats.total_allocated += bytes;
        self.stats.fp32_equiv_bytes += fp32_equiv;
        self.stats.allocs += 1;
        self.entries.insert(id, Entry { values, bytes });
        Ok(())
    }

    /// Take (and free) the ctx of microbatch `id` for its backward pass.
    /// Nibble-packed INT4 payloads come back expanded to one-byte codes
    /// (identical quantized values — the packing is storage-side only),
    /// so consumers address codes directly.
    pub fn take(&mut self, id: u64) -> Result<Vec<Value>> {
        match self.entries.remove(&id) {
            None => bail!("no ctx stored for microbatch {id}"),
            Some(e) => {
                self.stats.live_bytes -= e.bytes;
                self.stats.frees += 1;
                Ok(e.values.into_iter().map(unpack_value).collect())
            }
        }
    }

    pub fn live_microbatches(&self) -> usize {
        self.entries.len()
    }

    pub fn stats(&self) -> &CtxStats {
        &self.stats
    }

    /// Compression ratio achieved vs keeping FP32 activations (>= 1).
    pub fn compression_ratio(&self) -> f64 {
        if self.stats.total_allocated == 0 {
            return 1.0;
        }
        self.stats.fp32_equiv_bytes as f64 / self.stats.total_allocated as f64
    }

    /// Repack an int8 ctx tensor whose values fit INT4 into nibbles
    /// (storage-side only; unpacked before the bwd call). Odd element
    /// counts pack too — the final high nibble pads with 0 and the
    /// tensor's shape keeps the logical length. Returns None only if a
    /// value is outside [-8, 7].
    pub fn pack_nibbles(v: &Value) -> Option<Vec<u8>> {
        let data = v.as_i8().ok()?;
        if data.iter().any(|&q| !(-8..=7).contains(&q)) {
            return None;
        }
        Some(crate::quant::pack_int4_padded(data))
    }
}

/// FP32-equivalent footprint of one ctx tensor, from its spec metadata:
/// every logical element stands for one f32 of eager-mode storage, and a
/// rank-compressed payload's leading dim additionally stands for
/// `shape[0] / rank * 16` raw rows. Scale scalars riding a compressed
/// payload (legacy key "sx") are pure storage overhead — equivalent 0.
fn fp32_equiv_bytes(v: &Value, s: &CtxSpec) -> u64 {
    if s.key == "sx" {
        return 0;
    }
    let numel = v.numel() as u64;
    let shape = v.shape();
    let raw_numel = match shape.first() {
        Some(&rows) if s.rank > 0 && rows > 0 && rows % s.rank == 0 => {
            numel / rows as u64 * (rows / s.rank * BLOCK) as u64
        }
        _ => numel,
    };
    raw_numel * 4
}

/// Expand a nibble-packed payload to one-byte codes (same values).
fn unpack_value(v: Value) -> Value {
    match v {
        Value::QuantF32 { shape, bits: 4, data, scales } => {
            let numel: usize = shape.iter().product();
            let codes = crate::quant::unpack_int4_n(&data, numel);
            Value::QuantF32 {
                shape,
                bits: 8,
                data: codes.into_iter().map(|q| q as u8).collect(),
                scales,
            }
        }
        v => v,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::DType;

    fn val(n: usize, dt: DType) -> Value {
        match dt {
            DType::F32 => Value::F32 { shape: vec![n], data: vec![0.5; n] },
            DType::I8 => Value::I8 { shape: vec![n], data: vec![3; n] },
            DType::I32 => Value::I32 { shape: vec![n], data: vec![1; n] },
            DType::I4 => unreachable!("tests build I4 via QuantF32"),
        }
    }

    fn spec(key: &str) -> CtxSpec {
        spec_r(key, 0)
    }

    fn spec_r(key: &str, rank: usize) -> CtxSpec {
        CtxSpec { module: "m".into(), kind: "ql".into(), key: key.into(),
                  shape: vec![], dtype: DType::I8, index: 0, rank }
    }

    #[test]
    fn accounting_alloc_free() {
        let mut s = CtxStore::new(0);
        s.put(0, vec![val(100, DType::F32)], &[spec("x")]).unwrap();
        assert_eq!(s.stats().live_bytes, 400);
        s.put(1, vec![val(50, DType::I8)], &[spec("xq")]).unwrap();
        assert_eq!(s.stats().live_bytes, 450);
        assert_eq!(s.stats().peak_bytes, 450);
        s.take(0).unwrap();
        assert_eq!(s.stats().live_bytes, 50);
        s.take(1).unwrap();
        assert_eq!(s.stats().live_bytes, 0);
        assert_eq!(s.stats().allocs, 2);
        assert_eq!(s.stats().frees, 2);
        assert_eq!(s.stats().peak_bytes, 450);
    }

    #[test]
    fn budget_wall() {
        let mut s = CtxStore::new(500);
        s.put(0, vec![val(100, DType::F32)], &[spec("x")]).unwrap();
        let err = s.put(1, vec![val(100, DType::F32)], &[spec("x")]);
        assert!(err.is_err());
        let msg = format!("{}", err.unwrap_err());
        assert!(msg.contains("budget exceeded"), "{msg}");
        // after freeing, it fits
        s.take(0).unwrap();
        s.put(1, vec![val(100, DType::F32)], &[spec("x")]).unwrap();
    }

    #[test]
    fn double_put_and_missing_take_rejected() {
        let mut s = CtxStore::new(0);
        s.put(3, vec![val(1, DType::F32)], &[spec("x")]).unwrap();
        assert!(s.put(3, vec![val(1, DType::F32)], &[spec("x")]).is_err());
        assert!(s.take(9).is_err());
    }

    #[test]
    fn compression_ratio_from_metadata() {
        // rank-8 compressed payload: 128 stored rows stand for
        // 128/8*16 = 256 raw rows of 10 f32 columns = 10240 raw bytes.
        let (rows, cols) = (128usize, 10usize);
        let v = Value::QuantF32 { shape: vec![rows, cols], bits: 8,
                                  data: vec![1; rows * cols],
                                  scales: vec![0.5; rows] };
        let stored = v.bytes() as u64; // 1280 codes + 512 scale bytes
        assert_eq!(stored, 1792);
        let mut s = CtxStore::new(0);
        s.put(0, vec![v], &[spec_r("xq", 8)]).unwrap();
        let want = 10240.0 / stored as f64;
        assert!((s.compression_ratio() - want).abs() < 1e-9,
                "{} vs {want}", s.compression_ratio());

        // same payload without rank metadata: each element stands for
        // one f32 — no hardcoded HLA factor sneaks back in
        let v = Value::I8 { shape: vec![1000], data: vec![3; 1000] };
        let mut s = CtxStore::new(0);
        s.put(0, vec![v], &[spec("xq")]).unwrap();
        assert!((s.compression_ratio() - 4.0).abs() < 1e-9);

        // INT4 nibble payload: twice the ratio of INT8 on the codes
        let q = Value::QuantF32 { shape: vec![rows, cols], bits: 4,
                                  data: vec![0x11; (rows * cols) / 2],
                                  scales: vec![0.5; rows] };
        let mut s = CtxStore::new(0);
        let stored4 = q.bytes() as u64; // 640 + 512
        s.put(0, vec![q], &[spec_r("xq", 8)]).unwrap();
        assert!((s.compression_ratio() - 10240.0 / stored4 as f64).abs()
                < 1e-9);
        // legacy per-tensor scale scalars are overhead, equivalent 0
        let mut s = CtxStore::new(0);
        s.put(0, vec![val(1, DType::F32)], &[spec("sx")]).unwrap();
        assert_eq!(s.stats().fp32_equiv_bytes, 0);
    }

    #[test]
    fn put_arity_mismatch_is_hard_error() {
        let mut s = CtxStore::new(0);
        let err = s.put(0, vec![val(4, DType::F32), val(4, DType::F32)],
                        &[spec("x")]);
        let msg = format!("{}", err.unwrap_err());
        assert!(msg.contains("arity mismatch"), "{msg}");
        // nothing was stored or accounted
        assert_eq!(s.stats().allocs, 0);
        assert_eq!(s.stats().live_bytes, 0);
        assert_eq!(s.live_microbatches(), 0);
    }

    #[test]
    fn nibble_packing() {
        let v = val(10, DType::I8);
        let packed = CtxStore::pack_nibbles(&v).unwrap();
        assert_eq!(packed.len(), 5);
        let big = Value::I8 { shape: vec![2], data: vec![100, 0] };
        assert!(CtxStore::pack_nibbles(&big).is_none());
        // odd element counts pack with a padding nibble, logical length
        // preserved by the shape
        let odd = Value::I8 { shape: vec![7], data: vec![-8, 7, 0, 3, -3, 1, 5] };
        let packed = CtxStore::pack_nibbles(&odd).unwrap();
        assert_eq!(packed.len(), 4);
        assert_eq!(crate::quant::unpack_int4_n(&packed, 7),
                   odd.as_i8().unwrap());
    }

    #[test]
    fn take_unpacks_nibble_payloads() {
        let codes: Vec<i8> = vec![-7, 3, 0, 5, -1, 2];
        let v = Value::QuantF32 { shape: vec![2, 3], bits: 4,
                                  data: crate::quant::pack_int4_padded(&codes),
                                  scales: vec![0.5, 0.25] };
        let packed_bytes = v.bytes() as u64;
        let deq = v.to_f32().unwrap();
        let mut s = CtxStore::new(0);
        s.put(0, vec![v], &[spec_r("xq", 8)]).unwrap();
        assert_eq!(s.stats().live_bytes, packed_bytes,
                   "accounting charges packed bytes");
        let out = s.take(0).unwrap();
        match &out[0] {
            Value::QuantF32 { bits: 8, data, .. } => {
                assert_eq!(data.len(), 6, "codes expanded to one byte each");
            }
            other => panic!("expected expanded QuantF32, got {other:?}"),
        }
        assert_eq!(out[0].to_f32().unwrap(), deq,
                   "unpack must not change the quantized values");
        assert_eq!(s.stats().live_bytes, 0);
    }

    #[test]
    fn prop_conservation() {
        // alloc/free in arbitrary interleavings: live == sum of live
        // entries, peak >= live always, final live == 0
        crate::util::proptest::check("ctx conservation", 25, |case| {
            let mut s = CtxStore::new(0);
            let n_ops = case.usize_in(1, 20);
            let mut live: Vec<(u64, u64)> = vec![];
            let mut next_id = 0u64;
            for _ in 0..n_ops {
                if live.is_empty() || case.rng.uniform() < 0.6 {
                    let n = case.usize_in(1, 64);
                    s.put(next_id, vec![val(n, DType::F32)], &[spec("x")])
                        .map_err(|e| e.to_string())?;
                    live.push((next_id, 4 * n as u64));
                    next_id += 1;
                } else {
                    let k = case.usize_in(0, live.len() - 1);
                    let (id, _) = live.remove(k);
                    s.take(id).map_err(|e| e.to_string())?;
                }
                let want: u64 = live.iter().map(|(_, b)| b).sum();
                if s.stats().live_bytes != want {
                    return Err(format!("live {} != {}", s.stats().live_bytes, want));
                }
                if s.stats().peak_bytes < s.stats().live_bytes {
                    return Err("peak < live".into());
                }
            }
            for (id, _) in live {
                s.take(id).map_err(|e| e.to_string())?;
            }
            if s.stats().live_bytes != 0 {
                return Err("leak at end".into());
            }
            Ok(())
        });
    }
}
