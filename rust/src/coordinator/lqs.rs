//! LQS calibration controller (paper §5.2.2).
//!
//! Runs the calib artifact over a few batches *before* training, averages
//! the per-layer MSE statistics, applies the paper's 50%-difference rule,
//! and hands the trainer its per-layer {0,1} mask. Also surfaces the
//! Fig-4 (path error) and Fig-6/9 (outlier) diagnostics.
//!
//! Calibration reads weights but never writes them: the backend's
//! `calib_step` takes the trainer's `WeightStore` by shared reference,
//! so calibrating cannot perturb a store that serving sessions share.

use anyhow::Result;

#[derive(Debug, Clone)]
pub struct LayerDiag {
    pub name: String,
    pub mse_tensor: f64,
    pub mse_token: f64,
    pub outlier_ratio: f64,
    pub gx_err_hq: f64,
    pub gx_err_hla: f64,
    pub gw_err_hq: f64,
    pub gw_err_hla: f64,
}

#[derive(Debug, Clone)]
pub struct CalibReport {
    pub layers: Vec<LayerDiag>,
    pub threshold: f64,
}

impl CalibReport {
    /// Average raw calib-artifact outputs (7 vectors per batch) into a
    /// report. `outputs_per_batch[b][k]` is the k-th output of batch b.
    pub fn from_batches(names: &[String],
                        outputs_per_batch: &[Vec<Vec<f32>>],
                        threshold: f64) -> Result<CalibReport> {
        let nq = names.len();
        let nb = outputs_per_batch.len().max(1);
        let mut acc = vec![[0.0f64; 7]; nq];
        for batch in outputs_per_batch {
            anyhow::ensure!(batch.len() == 7, "calib artifact must emit 7 vectors");
            for (k, vec_k) in batch.iter().enumerate() {
                anyhow::ensure!(vec_k.len() == nq, "calib vector length mismatch");
                for (q, v) in vec_k.iter().enumerate() {
                    acc[q][k] += *v as f64 / nb as f64;
                }
            }
        }
        let layers = names
            .iter()
            .enumerate()
            .map(|(q, n)| LayerDiag {
                name: n.clone(),
                mse_tensor: acc[q][0],
                mse_token: acc[q][1],
                outlier_ratio: acc[q][2],
                gx_err_hq: acc[q][3],
                gx_err_hla: acc[q][4],
                gw_err_hq: acc[q][5],
                gw_err_hla: acc[q][6],
            })
            .collect();
        Ok(CalibReport { layers, threshold })
    }

    /// The paper's rule: per-token iff (mse_tensor - mse_token) /
    /// mse_tensor >= threshold (default 0.5).
    pub fn lqs_mask(&self) -> Vec<f32> {
        self.layers
            .iter()
            .map(|l| {
                if l.mse_tensor <= 0.0 {
                    return 0.0;
                }
                let rel = (l.mse_tensor - l.mse_token) / l.mse_tensor;
                if rel >= self.threshold {
                    1.0
                } else {
                    0.0
                }
            })
            .collect()
    }

    pub fn n_per_token(&self) -> usize {
        self.lqs_mask().iter().filter(|&&m| m > 0.5).count()
    }

    /// Layers ranked by outlier ratio (Fig 6/9's "case (a)" candidates).
    pub fn outlier_ranking(&self) -> Vec<(String, f64)> {
        let mut v: Vec<(String, f64)> = self
            .layers
            .iter()
            .map(|l| (l.name.clone(), l.outlier_ratio))
            .collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v
    }
}

// ---------------------------------------------------------------------------
// Runtime quantizer telemetry -> LQS
// ---------------------------------------------------------------------------

/// Per-layer quantizer health observed *during* training (the obs
/// subsystem drains it every step from the quant epilogues), folded into
/// the LQS view. Calibration picks the initial per-token/per-tensor mask
/// before step 0; this is the runtime signal that would drive the same
/// decision mid-run — layers with high observed dequant error or clip
/// rate are exactly the "case (a)" outlier layers of Figs 6/9.
#[derive(Debug, Clone, Default)]
pub struct QuantTelemetry {
    pub layers: Vec<crate::obs::LayerQuant>,
}

impl QuantTelemetry {
    /// Snapshot the latest step's drained telemetry (already sorted by
    /// descending mean |dequant − f32| error).
    pub fn from_step(layers: &[crate::obs::LayerQuant]) -> QuantTelemetry {
        QuantTelemetry { layers: layers.to_vec() }
    }

    /// Layers ranked by observed mean dequant error, worst first.
    pub fn ranked(&self) -> Vec<(&str, f64)> {
        let mut v: Vec<(&str, f64)> = self
            .layers
            .iter()
            .map(|l| (l.name.as_str(), l.mean_abs_err))
            .collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v
    }

    /// Layers whose observed clip rate exceeds `thresh` — the runtime
    /// analogue of `CalibReport::outlier_ranking`: heavy clipping under
    /// per-tensor min-max scaling means outlier tokens are stretching
    /// the shared scale, the condition LQS flips to per-token for.
    pub fn clip_suspects(&self, thresh: f64) -> Vec<&str> {
        self.layers
            .iter()
            .filter(|l| l.clip_rate > thresh)
            .map(|l| l.name.as_str())
            .collect()
    }

    /// Refine an existing LQS mask with the runtime signal: any qlinear
    /// whose observed clip rate exceeds `thresh` is forced per-token.
    /// Telemetry names are module paths ("blk0.qkv"); qlinear names from
    /// the preset match by suffix/prefix containment.
    pub fn refine_mask(&self, names: &[String], mask: &[f32], thresh: f64)
                       -> Vec<f32> {
        let suspects = self.clip_suspects(thresh);
        names
            .iter()
            .zip(mask)
            .map(|(n, &m)| {
                let hit = suspects.iter()
                    .any(|s| n.contains(*s) || s.contains(n.as_str()));
                if hit { 1.0 } else { m }
            })
            .collect()
    }
}

/// The next-wider backward variant in the sentinel's quantizer
/// escalation ladder (INT4 -> INT8 -> FP): `_abc4` configs widen to
/// `_abc8`, any remaining quantized base falls back to full-precision
/// `"fp"`, and `fp` itself has nowhere left to go (`None`).
pub fn widen_variant(variant: &str) -> Option<String> {
    if variant.contains("_abc4") {
        return Some(variant.replace("_abc4", "_abc8"));
    }
    let base = variant.split('_').next().unwrap_or(variant);
    if base != "fp" {
        return Some("fp".to_string());
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn names(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("l{i}")).collect()
    }

    #[test]
    fn averaging_and_rule() {
        let names = names(3);
        // two batches; layer 0: token much better (pick per-token);
        // layer 1: small difference (per-tensor); layer 2: zero mse
        let b1 = vec![
            vec![1.0, 1.0, 0.0],       // mse_tensor
            vec![0.2, 0.9, 0.0],       // mse_token
            vec![5.0, 1.0, 1.0],       // outlier
            vec![0.0; 3], vec![0.0; 3], vec![0.0; 3], vec![0.0; 3],
        ];
        let b2 = b1.clone();
        let rep = CalibReport::from_batches(&names, &[b1, b2], 0.5).unwrap();
        assert_eq!(rep.lqs_mask(), vec![1.0, 0.0, 0.0]);
        assert_eq!(rep.n_per_token(), 1);
    }

    #[test]
    fn boundary_exactly_50pct() {
        let names = names(1);
        let b = vec![
            vec![1.0], vec![0.5], vec![1.0],
            vec![0.0], vec![0.0], vec![0.0], vec![0.0],
        ];
        let rep = CalibReport::from_batches(&names, &[b], 0.5).unwrap();
        // difference == 50% -> per-token ("if >= 50%, per-token is used")
        assert_eq!(rep.lqs_mask(), vec![1.0]);
    }

    #[test]
    fn outlier_ranking_sorted() {
        let names = names(3);
        let b = vec![
            vec![1.0; 3], vec![1.0; 3],
            vec![2.0, 9.0, 4.0],
            vec![0.0; 3], vec![0.0; 3], vec![0.0; 3], vec![0.0; 3],
        ];
        let rep = CalibReport::from_batches(&names, &[b], 0.5).unwrap();
        let rank = rep.outlier_ranking();
        assert_eq!(rank[0].0, "l1");
        assert!(rank[0].1 > rank[1].1 && rank[1].1 > rank[2].1);
    }

    fn lq(name: &str, clip: f64, err: f64) -> crate::obs::LayerQuant {
        crate::obs::LayerQuant { name: name.into(), amax: 1.0,
                                 clip_rate: clip, mean_abs_err: err,
                                 numel: 100 }
    }

    #[test]
    fn telemetry_ranks_by_error() {
        let t = QuantTelemetry::from_step(&[
            lq("l0", 0.0, 1e-3), lq("l1", 0.0, 5e-2), lq("l2", 0.0, 2e-3),
        ]);
        let r = t.ranked();
        assert_eq!(r[0].0, "l1");
        assert!(r[0].1 > r[1].1 && r[1].1 > r[2].1);
    }

    #[test]
    fn clip_suspects_feed_mask_refinement() {
        let t = QuantTelemetry::from_step(&[
            lq("l0", 0.2, 1e-3),  // heavy clipping -> per-token
            lq("l1", 0.0, 1e-3),
        ]);
        assert_eq!(t.clip_suspects(0.1), vec!["l0"]);
        let names = names(3);
        let refined = t.refine_mask(&names, &[0.0, 0.0, 1.0], 0.1);
        // l0 flipped per-token, l1 untouched, l2 keeps its calib choice
        assert_eq!(refined, vec![1.0, 0.0, 1.0]);
    }

    #[test]
    fn widen_ladder() {
        assert_eq!(widen_variant("hot_abc4").as_deref(), Some("hot_abc8"));
        assert_eq!(widen_variant("hot_abc8").as_deref(), Some("fp"));
        assert_eq!(widen_variant("hot").as_deref(), Some("fp"));
        assert_eq!(widen_variant("lbp").as_deref(), Some("fp"));
        assert_eq!(widen_variant("fp"), None);
        assert_eq!(widen_variant("fp_abc4").as_deref(), Some("fp_abc8"));
    }

    #[test]
    fn arity_validated() {
        let names = names(2);
        assert!(CalibReport::from_batches(&names, &[vec![vec![0.0; 2]; 6]], 0.5)
            .is_err());
        assert!(CalibReport::from_batches(&names, &[vec![vec![0.0; 3]; 7]], 0.5)
            .is_err());
    }
}
