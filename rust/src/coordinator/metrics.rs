//! Step-level training metrics: loss/acc/lr/step-time series, rolling
//! summaries, CSV export (benches and EXPERIMENTS.md read these).

use std::io::Write;

#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    pub acc: f32,
    pub lr: f32,
    pub step_time_s: f64,
    pub ctx_live_bytes: u64,
    /// high-water mark of the ctx store as of this step
    pub ctx_peak_bytes: u64,
    /// fp32-equivalent / stored bytes so far (1.0 when nothing stored)
    pub ctx_compression: f64,
    /// bytes of frozen base weights behind `Arc` slabs (WeightStore)
    pub weight_bytes_shared: u64,
    /// bytes of per-tenant trainable overlay (AdapterSet; 0 outside LoRA)
    pub adapter_bytes: u64,
    /// total nanoseconds attributed to spans this step (0 when obs off)
    pub prof_span_ns: u64,
    /// FLOPs executed this step, summed across kernel tiers (obs counters)
    pub prof_flops: u64,
    /// bytes produced by quantization epilogues this step (obs counters)
    pub prof_bytes_quant: u64,
    /// top-k layers by mean |dequant - f32| error, "name:err;..." (may be "")
    pub quant_top: String,
}

#[derive(Debug, Default)]
pub struct MetricsLog {
    pub records: Vec<StepRecord>,
    pub evals: Vec<(usize, f32, f32)>, // (step, loss, acc)
    /// Out-of-band run events (sentinel trips, rollbacks, quantizer
    /// widening) keyed by step. Kept off the CSV — its column set is a
    /// stable interface — and surfaced in logs and abort reports.
    pub notes: Vec<(usize, String)>,
}

impl MetricsLog {
    pub fn new() -> MetricsLog {
        MetricsLog::default()
    }

    pub fn push(&mut self, r: StepRecord) {
        self.records.push(r);
    }

    pub fn push_eval(&mut self, step: usize, loss: f32, acc: f32) {
        self.evals.push((step, loss, acc));
    }

    pub fn push_note(&mut self, step: usize, note: impl Into<String>) {
        self.notes.push((step, note.into()));
    }

    pub fn last_loss(&self) -> Option<f32> {
        self.records.last().map(|r| r.loss)
    }

    /// Mean loss over the trailing `n` steps (loss-curve smoothing).
    pub fn smoothed_loss(&self, n: usize) -> Option<f32> {
        if self.records.is_empty() {
            return None;
        }
        let take = n.min(self.records.len());
        let s: f32 = self.records[self.records.len() - take..]
            .iter()
            .map(|r| r.loss)
            .sum();
        Some(s / take as f32)
    }

    /// Mean step time excluding warmup. The skip is `max(1, 5%)` of the
    /// recorded steps — always at least the first step (compile/warmup),
    /// growing with run length so long runs also shed cache-cold steps —
    /// clamped so at least one record always survives.
    pub fn mean_step_time(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        let n = self.records.len();
        let skip = (n / 20).max(1).min(n - 1);
        let xs = &self.records[skip..];
        xs.iter().map(|r| r.step_time_s).sum::<f64>() / xs.len() as f64
    }

    pub fn throughput_steps_per_s(&self) -> f64 {
        let t = self.mean_step_time();
        if t > 0.0 {
            1.0 / t
        } else {
            0.0
        }
    }

    /// Best (max) eval accuracy seen so far. NaN accuracies (e.g. an eval
    /// on an empty split) are skipped rather than poisoning the fold:
    /// `f32::max` is NaN-propagating in the accumulator position, so an
    /// early NaN would otherwise stick for the rest of the run.
    pub fn best_eval_acc(&self) -> Option<f32> {
        self.evals
            .iter()
            .map(|e| e.2)
            .filter(|a| !a.is_nan())
            .fold(None, |m, a| Some(m.map_or(a, |mm: f32| mm.max(a))))
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "step,loss,acc,lr,step_time_s,ctx_live_bytes,ctx_peak_bytes,\
             ctx_compression,weight_bytes_shared,adapter_bytes,\
             prof_span_ns,prof_flops,prof_bytes_quant,quant_top\n");
        for r in &self.records {
            s.push_str(&format!("{},{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
                                r.step, r.loss, r.acc, r.lr, r.step_time_s,
                                r.ctx_live_bytes, r.ctx_peak_bytes,
                                r.ctx_compression, r.weight_bytes_shared,
                                r.adapter_bytes, r.prof_span_ns,
                                r.prof_flops, r.prof_bytes_quant,
                                r.quant_top));
        }
        s
    }

    pub fn save_csv(&self, path: &str) -> anyhow::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())?;
        Ok(())
    }

    /// Compact loss-curve string for logs: every `every`-th smoothed loss.
    pub fn curve_string(&self, every: usize) -> String {
        let mut parts = Vec::new();
        for (i, r) in self.records.iter().enumerate() {
            if i % every == 0 || i + 1 == self.records.len() {
                parts.push(format!("{}:{:.3}", r.step, r.loss));
            }
        }
        parts.join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: usize, loss: f32, t: f64) -> StepRecord {
        StepRecord { step, loss, acc: 0.5, lr: 1e-3, step_time_s: t,
                     ctx_live_bytes: 0, ctx_peak_bytes: 0,
                     ctx_compression: 1.0, weight_bytes_shared: 0,
                     adapter_bytes: 0, prof_span_ns: 0, prof_flops: 0,
                     prof_bytes_quant: 0, quant_top: String::new() }
    }

    #[test]
    fn smoothing() {
        let mut m = MetricsLog::new();
        for i in 0..10 {
            m.push(rec(i, i as f32, 0.01));
        }
        assert_eq!(m.last_loss(), Some(9.0));
        assert!((m.smoothed_loss(4).unwrap() - 7.5).abs() < 1e-6);
        assert!(m.smoothed_loss(100).is_some());
    }

    #[test]
    fn step_time_skips_warmup() {
        let mut m = MetricsLog::new();
        m.push(rec(0, 1.0, 10.0)); // compile step
        m.push(rec(1, 1.0, 0.1));
        m.push(rec(2, 1.0, 0.1));
        assert!((m.mean_step_time() - 0.1).abs() < 1e-9);
        assert!((m.throughput_steps_per_s() - 10.0).abs() < 1e-6);
    }

    #[test]
    fn eval_tracking() {
        let mut m = MetricsLog::new();
        m.push_eval(10, 1.0, 0.4);
        m.push_eval(20, 0.8, 0.7);
        m.push_eval(30, 0.9, 0.6);
        assert_eq!(m.best_eval_acc(), Some(0.7));
    }

    #[test]
    fn best_eval_acc_ignores_nan() {
        // f32::max propagates NaN from the accumulator position, so an
        // early NaN eval used to poison every later comparison.
        let mut m = MetricsLog::new();
        m.push_eval(10, 1.0, f32::NAN);
        m.push_eval(20, 0.8, 0.7);
        m.push_eval(30, 0.9, 0.6);
        assert_eq!(m.best_eval_acc(), Some(0.7));
        // all-NaN evals -> no usable accuracy at all
        let mut m2 = MetricsLog::new();
        m2.push_eval(10, 1.0, f32::NAN);
        assert_eq!(m2.best_eval_acc(), None);
    }

    #[test]
    fn warmup_skip_is_five_percent_min_one() {
        // 100 records: skip = max(1, 100/20) = 5. First five are slow;
        // the mean must reflect only the steady-state tail.
        let mut m = MetricsLog::new();
        for i in 0..100 {
            let t = if i < 5 { 10.0 } else { 0.1 };
            m.push(rec(i, 1.0, t));
        }
        assert!((m.mean_step_time() - 0.1).abs() < 1e-9);
        // 2 records: skip clamps to 1, never to all of them
        let mut m2 = MetricsLog::new();
        m2.push(rec(0, 1.0, 10.0));
        m2.push(rec(1, 1.0, 0.2));
        assert!((m2.mean_step_time() - 0.2).abs() < 1e-9);
        // 1 record: skip clamps so the single record survives
        let mut m1 = MetricsLog::new();
        m1.push(rec(0, 1.0, 0.3));
        assert!((m1.mean_step_time() - 0.3).abs() < 1e-9);
    }

    #[test]
    fn csv_format() {
        let mut m = MetricsLog::new();
        m.push(rec(0, 1.5, 0.01));
        let csv = m.to_csv();
        assert!(csv.starts_with("step,loss"));
        assert!(csv.contains("ctx_peak_bytes"));
        assert!(csv.contains("weight_bytes_shared")
                && csv.contains("adapter_bytes"));
        assert!(csv.contains("prof_flops") && csv.contains("quant_top"));
        assert!(csv.contains("0,1.5,0.5,0.001,0.01,0,0,1,0,0"));
    }

    #[test]
    fn csv_prof_columns_round_trip() {
        let mut m = MetricsLog::new();
        let mut r = rec(0, 1.5, 0.01);
        r.weight_bytes_shared = 4096;
        r.adapter_bytes = 128;
        r.prof_span_ns = 123;
        r.prof_flops = 456;
        r.prof_bytes_quant = 789;
        r.quant_top = "head:1.0e-2;embed:5.0e-3".into();
        m.push(r);
        let csv = m.to_csv();
        let row = csv.lines().nth(1).unwrap();
        assert!(row.ends_with(",4096,128,123,456,789,head:1.0e-2;embed:5.0e-3"),
                "{row}");
        // same number of cells in header and rows
        let ncols = csv.lines().next().unwrap().split(',').count();
        assert_eq!(row.split(',').count(), ncols);
    }

    #[test]
    fn notes_stay_off_the_csv() {
        let mut m = MetricsLog::new();
        m.push(rec(0, 1.5, 0.01));
        m.push_note(0, "rollback to step 0");
        assert_eq!(m.notes, vec![(0, "rollback to step 0".to_string())]);
        assert!(!m.to_csv().contains("rollback"));
    }

    #[test]
    fn curve_string_sparse() {
        let mut m = MetricsLog::new();
        for i in 0..7 {
            m.push(rec(i, 1.0, 0.01));
        }
        let c = m.curve_string(3);
        assert!(c.contains("0:") && c.contains("3:") && c.contains("6:"));
    }
}
