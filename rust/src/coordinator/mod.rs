//! L3 coordinator: training orchestration, ABC context buffers, LQS
//! calibration, metrics and checkpoints. See trainer.rs for the three
//! execution modes (fused / split / accum).

pub mod checkpoint;
pub mod ctx;
pub mod lqs;
pub mod metrics;
pub mod trainer;

pub use checkpoint::Checkpoint;
pub use ctx::{BudgetExceeded, CtxStats, CtxStore};
pub use lqs::{CalibReport, LayerDiag, QuantTelemetry};
pub use metrics::{MetricsLog, StepRecord};
pub use trainer::{DataSource, LoraTrainer, Mode, Trainer};
