//! Training orchestrator: owns model/optimizer state host-side, drives
//! an `Executor` backend (native CPU or PJRT artifacts), and implements
//! the three execution modes —
//!
//!   * fused      one backend call per step (fwd+bwd+AdamW)
//!   * split      fwd -> rust-held ABC ctx buffers -> bwd -> opt
//!                (the Fig-5 pipeline with the CTX owned by this process)
//!   * accum      gradient accumulation over microbatches (grad call per
//!                microbatch, host-side summation, one opt call)
//!
//! plus LQS calibration before training and LoRA fine-tuning state.
//!
//! State ownership (DESIGN.md §Model state ownership): a `Trainer` holds
//! exactly one `WeightStore` (the sole unshared handle, so in-place
//! AdamW works) plus one `TrainState` (moments + ctx). Checkpointing
//! `share()`s the store for the duration of the save — no slab clones
//! in steady state. A `LoraTrainer` holds an `AdapterSet` over a shared
//! frozen base instead.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::backend::{AdapterSet, Executor, TrainState, WeightStore};
use crate::config::RunConfig;
use crate::coordinator::checkpoint::{Checkpoint, SaveCtx};
use crate::coordinator::lqs::{widen_variant, CalibReport};
use crate::coordinator::metrics::{MetricsLog, StepRecord};
use crate::data::{LmDataset, VisionDataset};
use crate::resilience::fault;
use crate::resilience::manifest::Schedule;
use crate::resilience::store::{resume_latest_valid, sweep_tmp, CkptStore};
use crate::resilience::{Sentinel, SentinelCfg, Trip};
use crate::runtime::value::Value;
use crate::runtime::Preset;

pub enum DataSource {
    Vision(VisionDataset),
    Lm(LmDataset),
}

impl DataSource {
    pub fn batch(&self, split: u64, index: u64, batch: usize) -> (Value, Value) {
        match self {
            DataSource::Vision(d) => d.batch(split, index, batch),
            DataSource::Lm(d) => d.batch(split, index, batch),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Mode {
    Fused,
    Split,
    Accum,
}

pub struct Trainer {
    pub rt: Arc<dyn Executor>,
    pub cfg: RunConfig,
    pub preset: Preset,
    /// Base weights — the training loop's single, unshared store; AdamW
    /// mutates its slabs in place via the backend's `opt_step`.
    pub weights: WeightStore,
    /// Training-only state: AdamW moments + the ABC ctx store.
    pub state: TrainState,
    pub lqs_mask: Vec<f32>,
    pub metrics: MetricsLog,
    pub data: DataSource,
    pub step: usize,
    /// Execute a specific train-step key instead of the
    /// `train_{variant}_{preset}` default (rank-sweep benches etc.).
    pub key_override: Option<String>,
    /// Keep raw span events when tracing (for Chrome-trace export). Off by
    /// default so long traced runs only pay for aggregates.
    pub keep_trace: bool,
    /// Accumulated span events across all steps (when `keep_trace`).
    pub trace: Vec<crate::obs::TraceEvent>,
    /// Per-layer quantizer telemetry from the most recent step.
    pub last_quant: Vec<crate::obs::LayerQuant>,
    /// Numeric sentinel + rollback/escalation state (DESIGN.md
    /// §Resilience).
    pub sentinel: Sentinel,
    /// Retention manager for `cfg.checkpoint_dir`, when set.
    pub store: Option<CkptStore>,
    /// When true, `calibrate` keeps the current LQS mask instead of
    /// re-deriving it — set after a resume (the manifest's mask wins)
    /// and after a sentinel LQS fallback (recalibrating would clobber
    /// the runtime widening).
    pub mask_locked: bool,
}

/// Flatten an optional per-step profile into the StepRecord columns.
fn prof_fields(p: Option<&crate::obs::StepProfile>)
               -> (u64, u64, u64, String) {
    match p {
        Some(p) => {
            let bq = p.counters[crate::obs::Counter::BytesQuantized as usize];
            (p.step_coverage_ns(), p.flops(), bq, p.top_quant_csv(3))
        }
        None => (0, 0, 0, String::new()),
    }
}

impl Trainer {
    pub fn new(rt: Arc<dyn Executor>, cfg: RunConfig) -> Result<Trainer> {
        let preset = rt.preset(&cfg.preset)?;
        let weights = rt.init_store(&cfg.preset)?;
        let state = TrainState::new(&preset.params, cfg.mem_budget);
        let data = Self::make_data(&preset, &cfg);
        let nq = preset.qlinears.len();
        let sentinel = Sentinel::new(SentinelCfg {
            enabled: cfg.sentinel,
            max_rollbacks: cfg.max_rollbacks,
            ..SentinelCfg::default()
        });
        let store = cfg.checkpoint_dir.as_deref()
            .map(|d| CkptStore::new(d, cfg.keep_last));
        Ok(Trainer {
            rt,
            cfg,
            lqs_mask: vec![0.0; nq],
            weights,
            state,
            metrics: MetricsLog::new(),
            data,
            preset,
            step: 0,
            key_override: None,
            keep_trace: false,
            trace: Vec::new(),
            last_quant: Vec::new(),
            sentinel,
            store,
            mask_locked: false,
        })
    }

    /// Batches are pure functions of (seed, split, index), so rebuilding
    /// the source from the (possibly checkpoint-adopted) seed replays the
    /// exact sample order.
    fn make_data(preset: &Preset, cfg: &RunConfig) -> DataSource {
        match preset.model.arch.as_str() {
            "lm" => DataSource::Lm(LmDataset::new(preset.model.seq,
                                                  preset.model.in_dim, cfg.seed)),
            _ => DataSource::Vision(VisionDataset::new(
                preset.model.seq, preset.model.in_dim,
                preset.model.n_classes, cfg.seed)
                .with_noise(cfg.data_noise as f32)),
        }
    }

    // ------------------------------------------------------------------
    // step keys
    // ------------------------------------------------------------------

    pub fn train_key(&self) -> String {
        self.key_override.clone().unwrap_or_else(
            || format!("train_{}_{}", self.cfg.variant, self.cfg.preset))
    }

    // ------------------------------------------------------------------
    // LQS calibration (before training)
    // ------------------------------------------------------------------

    pub fn calibrate(&mut self) -> Result<Option<CalibReport>> {
        if self.mask_locked {
            crate::info!("LQS mask locked (resume / sentinel fallback) — \
                          skipping calibration");
            return Ok(None);
        }
        let key = format!("calib_{}", self.cfg.preset);
        if self.cfg.calib_batches == 0 || !self.rt.supports(&key) {
            return Ok(None);
        }
        let mut per_batch = Vec::new();
        for b in 0..self.cfg.calib_batches {
            let (x, y) = self.data.batch(2, b as u64, self.batch_size());
            per_batch.push(self.rt.calib_step(&key, &self.weights, &x, &y)?);
        }
        let report = CalibReport::from_batches(&self.preset.qlinears,
                                               &per_batch,
                                               self.cfg.lqs_threshold)?;
        self.lqs_mask = report.lqs_mask();
        crate::info!("LQS: {}/{} layers per-token", report.n_per_token(),
                     self.preset.qlinears.len());
        // calibration ran under the trace gate too — discard its spans
        // and counters so step 0's profile reflects step 0 only
        if crate::obs::enabled() {
            crate::obs::drain_step(false);
        }
        Ok(Some(report))
    }

    pub fn batch_size(&self) -> usize {
        // artifact-pinned batch wins (PJRT graphs are shape-static);
        // otherwise the run config decides (native backend)
        self.rt
            .key_batch(&self.train_key())
            .unwrap_or(self.cfg.batch)
            .max(1)
    }

    // ------------------------------------------------------------------
    // step modes
    // ------------------------------------------------------------------

    /// One fused train step; weights and moments update in place.
    pub fn fused_step(&mut self, x: Value, y: Value) -> Result<(f32, f32)> {
        self.rt.train_step(
            &self.train_key(), &mut self.weights, &mut self.state,
            self.step as f32 + 1.0, self.cfg.lr_at(self.step),
            &self.lqs_mask, &x, &y)
    }

    /// Split mode: fwd -> ctx store -> bwd -> opt. Exercises ABC across
    /// the backend boundary; the compressed buffers live in
    /// `self.state.ctx` between the calls.
    pub fn split_step(&mut self, x: Value, y: Value) -> Result<(f32, f32)> {
        let fwd_key = format!("fwd_{}_{}", self.cfg.variant, self.cfg.preset);
        let bwd_key = format!("bwd_{}_{}", self.cfg.variant, self.cfg.preset);
        let opt_key = format!("opt_{}", self.cfg.preset);

        let fwd = self.rt.forward_step(&fwd_key, &self.weights,
                                       &self.lqs_mask, &x, &y)?;
        let mb = self.step as u64;
        self.state.ctx.put(mb, fwd.ctx, &fwd.ctx_specs)?;

        // ... in a real pipeline other microbatches' forwards would run
        // here while ctx is held; take it back for the backward:
        let ctx_vals = self.state.ctx.take(mb)?;
        let grads = self.rt.backward_step(&bwd_key, &self.weights,
                                          &self.lqs_mask, &x, ctx_vals)?;

        self.apply_opt(&opt_key, grads)?;
        Ok((fwd.loss, fwd.acc))
    }

    /// Gradient accumulation: `cfg.accum` microbatches through the grad
    /// step, host-side averaging, one optimizer call.
    pub fn accum_step(&mut self, base_index: u64) -> Result<(f32, f32)> {
        let grad_key = format!("grad_{}_{}", self.cfg.variant, self.cfg.preset);
        let opt_key = format!("opt_{}", self.cfg.preset);
        let mut sum: Option<Vec<Value>> = None;
        let (mut loss_s, mut acc_s) = (0.0f32, 0.0f32);
        for k in 0..self.cfg.accum {
            let (x, y) = self.data.batch(
                0, base_index * self.cfg.accum as u64 + k as u64,
                self.batch_size());
            let out = self.rt.grad_step(&grad_key, &self.weights,
                                        &self.lqs_mask, &x, &y)?;
            loss_s += out.loss;
            acc_s += out.acc;
            if out.grads.len() != self.weights.len() {
                bail!("grad step arity {} != {}", out.grads.len(),
                      self.weights.len());
            }
            match &mut sum {
                None => sum = Some(out.grads),
                Some(acc) => {
                    for (a, g) in acc.iter_mut().zip(out.grads) {
                        if let (Value::F32 { data: ad, .. },
                                Value::F32 { data: gd, .. }) = (a, g)
                        {
                            for (x0, x1) in ad.iter_mut().zip(gd) {
                                *x0 += x1;
                            }
                        }
                    }
                }
            }
        }
        let mut grads = sum.context("accum >= 1 validated by RunConfig")?;
        let inv = 1.0 / self.cfg.accum as f32;
        for g in &mut grads {
            if let Value::F32 { data, .. } = g {
                for x in data.iter_mut() {
                    *x *= inv;
                }
            }
        }
        self.apply_opt(&opt_key, grads)?;
        Ok((loss_s * inv, acc_s * inv))
    }

    fn apply_opt(&mut self, opt_key: &str, grads: Vec<Value>) -> Result<()> {
        self.rt.opt_step(
            opt_key, &mut self.weights, &grads, &mut self.state,
            self.step as f32 + 1.0, self.cfg.lr_at(self.step))
    }

    // ------------------------------------------------------------------
    // loops
    // ------------------------------------------------------------------

    pub fn step_once(&mut self, mode: Mode) -> Result<(f32, f32)> {
        let t0 = Instant::now();
        // batch generation stays outside the train_step span — the span
        // times backend work; each guard drops at the end of its arm, so
        // every event is pushed before drain_step sweeps the rings below
        let (mut loss, acc) = match mode {
            Mode::Fused => {
                let (x, y) = self.data.batch(0, self.step as u64,
                                             self.batch_size());
                let _sp = crate::obs::span(crate::obs::Span::TrainStep);
                self.fused_step(x, y)?
            }
            Mode::Split => {
                let (x, y) = self.data.batch(0, self.step as u64,
                                             self.batch_size());
                let _sp = crate::obs::span(crate::obs::Span::TrainStep);
                self.split_step(x, y)?
            }
            Mode::Accum => {
                let _sp = crate::obs::span(crate::obs::Span::TrainStep);
                self.accum_step(self.step as u64)?
            }
        };
        if fault::nan_in_grad(self.step) {
            // what a NaN gradient leaves behind: the loss it came from
            // and a poisoned first AdamW moment after the optimizer step
            crate::warn_!("fault injection: NaN in gradient stream at \
                           step {}", self.step);
            loss = f32::NAN;
            if let Some(m0) = self.state.m.first_mut() {
                if let Ok(d) = m0.as_f32_mut() {
                    if let Some(x0) = d.first_mut() {
                        *x0 = f32::NAN;
                    }
                }
            }
        }
        let prof = crate::obs::enabled()
            .then(|| crate::obs::drain_step(self.keep_trace));
        let (prof_span_ns, prof_flops, prof_bytes_quant, quant_top) =
            prof_fields(prof.as_ref());
        if let Some(p) = prof {
            self.trace.extend(p.events);
            self.last_quant = p.quant;
        }
        self.metrics.push(StepRecord {
            step: self.step,
            loss,
            acc,
            lr: self.cfg.lr_at(self.step),
            step_time_s: t0.elapsed().as_secs_f64(),
            ctx_live_bytes: self.state.ctx.stats().live_bytes,
            ctx_peak_bytes: self.state.ctx.stats().peak_bytes,
            ctx_compression: self.state.ctx.compression_ratio(),
            weight_bytes_shared: self.weights.total_bytes() as u64,
            adapter_bytes: 0,
            prof_span_ns,
            prof_flops,
            prof_bytes_quant,
            quant_top,
        });
        self.step += 1;
        Ok((loss, acc))
    }

    /// Runtime quantizer telemetry from the most recent traced step,
    /// in the LQS-facing form (rank by error, clip-rate mask refinement).
    pub fn quant_telemetry(&self) -> crate::coordinator::lqs::QuantTelemetry {
        crate::coordinator::lqs::QuantTelemetry::from_step(&self.last_quant)
    }

    /// Mean (loss, acc) over `n` eval batches. Routes through the
    /// backend's ctx-free inference walk — nothing is saved or
    /// quantized for backward (pinned by the obs-counter test).
    pub fn eval(&self, n: usize) -> Result<(f32, f32)> {
        let key = format!("eval_{}", self.cfg.preset);
        let (mut ls, mut as_) = (0.0f32, 0.0f32);
        for b in 0..n {
            let (x, y) = self.data.batch(1, b as u64, self.batch_size());
            let (l, a) = self.rt.eval_step(&key, &self.weights, &x, &y)?;
            ls += l;
            as_ += a;
        }
        // like calibration: a mid-run eval's spans must not leak into
        // the next training step's profile
        if crate::obs::enabled() {
            crate::obs::drain_step(false);
        }
        Ok((ls / n as f32, as_ / n as f32))
    }

    /// Full training run per the RunConfig; returns final (eval loss, acc)
    /// if the backend can evaluate this preset.
    pub fn train(&mut self) -> Result<Option<(f32, f32)>> {
        let mode = if self.cfg.accum > 1 { Mode::Accum } else { Mode::Fused };
        self.train_mode(mode)
    }

    /// The training loop proper, in an explicit step mode: calibrate,
    /// anchor-checkpoint, then step until `cfg.steps` with the numeric
    /// sentinel checking every completed step. A sentinel trip hands the
    /// step to [`recover`] (rollback + escalation) and the loop re-runs
    /// from the restored step; evals and checkpoints only happen on
    /// steps the sentinel passed, so a poisoned state is never saved.
    ///
    /// [`recover`]: Trainer::recover
    pub fn train_mode(&mut self, mode: Mode) -> Result<Option<(f32, f32)>> {
        self.calibrate()?;
        let has_eval = self.rt.supports(&format!("eval_{}", self.cfg.preset));
        if self.cfg.sentinel && self.cfg.checkpoint_dir.is_some()
            && self.step < self.cfg.steps
        {
            // anchor: rollback always has a last-good target, even
            // before the first periodic checkpoint
            self.checkpoint_now()?;
        }
        while self.step < self.cfg.steps {
            let (loss, acc) = self.step_once(mode)?;
            if self.cfg.sentinel {
                if let Some(trip) = self.sentinel.check(
                    self.step - 1, loss, &self.weights, &self.state,
                    &self.last_quant)
                {
                    self.recover(trip)?;
                    continue;
                }
            }
            if self.step % 20 == 0 || self.step == 1 {
                crate::info!("step {:>5} loss {:.4} acc {:.3} lr {:.2e}",
                             self.step, loss, acc, self.cfg.lr_at(self.step - 1));
            }
            if has_eval && self.cfg.eval_every > 0
                && self.step % self.cfg.eval_every == 0
            {
                let (el, ea) = self.eval(4)?;
                self.metrics.push_eval(self.step, el, ea);
                if let Some(store) = &mut self.store {
                    store.note_eval(self.step, el as f64);
                }
                crate::info!("  eval @ {}: loss {:.4} acc {:.3}", self.step, el, ea);
            }
            let due = self.step == self.cfg.steps
                || (self.cfg.checkpoint_every > 0
                    && self.step % self.cfg.checkpoint_every == 0);
            if self.cfg.checkpoint_dir.is_some() && due {
                if let Some(p) = self.checkpoint_now()? {
                    crate::info!("checkpoint -> {p}");
                }
            }
        }
        if has_eval {
            let fin = self.eval(8)?;
            self.metrics.push_eval(self.step, fin.0, fin.1);
            Ok(Some(fin))
        } else {
            Ok(None)
        }
    }

    // ------------------------------------------------------------------
    // checkpoints + recovery
    // ------------------------------------------------------------------

    fn save_ctx(&self) -> SaveCtx {
        SaveCtx {
            seed: self.cfg.seed,
            accum: self.cfg.accum,
            schedule: self.schedule(),
            lqs_mask: self.lqs_mask.clone(),
            eval_loss: self.metrics.evals.last().map(|e| e.1 as f64),
        }
    }

    fn schedule(&self) -> Schedule {
        Schedule {
            steps: self.cfg.steps,
            warmup_steps: self.cfg.warmup_steps,
            lr: self.cfg.lr,
            lr_min_frac: self.cfg.lr_min_frac,
        }
    }

    /// Save a checkpoint of the current state into `cfg.checkpoint_dir`
    /// (no-op returning `None` when unset) and apply retention.
    /// `share()` freezes the slabs only for the lifetime of the save —
    /// the extra handle drops with the `Checkpoint`, and no weight
    /// bytes are cloned.
    pub fn checkpoint_now(&mut self) -> Result<Option<String>> {
        let Some(dir) = self.cfg.checkpoint_dir.clone() else {
            return Ok(None);
        };
        let ck = Checkpoint {
            step: self.step,
            preset: self.cfg.preset.clone(),
            variant: self.cfg.variant.clone(),
            weights: self.weights.share(),
            m: self.state.m.clone(),
            v: self.state.v.clone(),
        };
        let path = ck.save_with(&dir, &self.save_ctx())?;
        if let Some(store) = &self.store {
            let deleted = store.retain()?;
            if !deleted.is_empty() {
                crate::debug!("retention dropped checkpoint steps \
                               {deleted:?}");
            }
        }
        Ok(Some(path))
    }

    /// Bounded-retry recovery after a sentinel trip: roll back to the
    /// newest valid checkpoint, then escalate — first a per-layer LQS
    /// fallback (clip suspects forced per-token), then a wider
    /// quantizer variant (INT4 -> INT8 -> FP) — and abort with the
    /// sentinel's structured report once the rollback budget is spent
    /// or no valid checkpoint remains.
    fn recover(&mut self, trip: Trip) -> Result<()> {
        crate::obs::count(crate::obs::Counter::SentinelTrips, 1);
        crate::warn_!("sentinel trip: {trip}");
        let tripped_step = self.step.saturating_sub(1);
        self.metrics.push_note(tripped_step, format!("sentinel trip: {trip}"));
        // grab the telemetry of the *tripped* step before rollback; it
        // names the layer whose quantizer diverged
        let telemetry = self.quant_telemetry();
        self.sentinel.trips.push(trip);

        if self.sentinel.rollbacks >= self.sentinel.cfg.max_rollbacks {
            bail!("{}", self.sentinel.report());
        }
        let Some(dir) = self.cfg.checkpoint_dir.clone() else {
            bail!("sentinel tripped with no checkpoint_dir to roll back \
                   to\n{}", self.sentinel.report());
        };
        let scan = resume_latest_valid(&dir, &self.preset.params,
                                       Some(&self.cfg.preset));
        for r in &scan.rejected {
            crate::warn_!("rollback scan skipped {}: {}", r.label, r.reason);
        }
        let Some((ck, _man, header)) = scan.loaded else {
            bail!("sentinel tripped but no valid checkpoint in {dir}\n{}",
                  self.sentinel.report());
        };
        self.weights = ck.weights;
        self.state.m = ck.m;
        self.state.v = ck.v;
        self.step = ck.step;
        self.sentinel.rollbacks += 1;
        crate::obs::count(crate::obs::Counter::Rollbacks, 1);
        let act = format!("rollback {}/{} to step {} ({header})",
                          self.sentinel.rollbacks,
                          self.sentinel.cfg.max_rollbacks, self.step, );
        crate::warn_!("{act}");
        self.sentinel.actions.push(act.clone());
        self.metrics.push_note(tripped_step, act);

        // escalation 1: per-layer LQS fallback — clip suspects go
        // per-token, which widens each token's own scale
        if self.sentinel.rollbacks == 1 {
            let refined = telemetry.refine_mask(&self.preset.qlinears,
                                                &self.lqs_mask, 0.05);
            if refined != self.lqs_mask {
                self.lqs_mask = refined;
                self.mask_locked = true;
                let act = "LQS fallback: clip-suspect layers forced \
                           per-token".to_string();
                crate::warn_!("{act}");
                self.sentinel.actions.push(act.clone());
                self.metrics.push_note(tripped_step, act);
                return Ok(());
            }
        }
        // escalation 2: widen the quantizer (INT4 -> INT8 -> FP), when
        // the backend has the wider train key for this preset
        if let Some(wider) = widen_variant(&self.cfg.variant) {
            let key = format!("train_{wider}_{}", self.cfg.preset);
            if self.key_override.is_none() && self.rt.supports(&key) {
                let act = format!("quantizer widened: variant {} -> {wider}",
                                  self.cfg.variant);
                crate::warn_!("{act}");
                self.cfg.variant = wider;
                self.sentinel.actions.push(act.clone());
                self.metrics.push_note(tripped_step, act);
            }
        }
        // rollback alone is a valid retry too: write-site faults fire
        // once, and a transient NaN does not recur from a clean state
        Ok(())
    }

    // ------------------------------------------------------------------
    // resume
    // ------------------------------------------------------------------

    /// Resume from an explicit checkpoint header (fully verified).
    pub fn resume(&mut self, header: &str) -> Result<()> {
        let (ck, man) = Checkpoint::load_verified(header, &self.preset.params)
            .map_err(anyhow::Error::new)
            .with_context(|| format!("resuming from {header}"))?;
        self.apply_checkpoint(ck, man, header)
    }

    /// Resume from the newest *valid* checkpoint in
    /// `cfg.checkpoint_dir`, walking past corrupt or torn candidates
    /// with a logged reason each. Returns `false` (fresh run) when the
    /// directory holds nothing loadable.
    pub fn resume_auto(&mut self) -> Result<bool> {
        let Some(dir) = self.cfg.checkpoint_dir.clone() else {
            bail!("--resume needs --checkpoint-dir (nowhere to scan)");
        };
        let swept = sweep_tmp(&dir);
        if swept > 0 {
            crate::info!("swept {swept} stale .tmp file(s) from {dir}");
        }
        let scan = resume_latest_valid(&dir, &self.preset.params,
                                       Some(&self.cfg.preset));
        for r in &scan.rejected {
            crate::warn_!("resume scan skipped {}: {}", r.label, r.reason);
        }
        match scan.loaded {
            Some((ck, man, header)) => {
                self.apply_checkpoint(ck, man, &header)?;
                Ok(true)
            }
            None => {
                crate::info!("no valid checkpoint in {dir}; starting fresh");
                Ok(false)
            }
        }
    }

    /// Restore trainer state from a verified checkpoint + manifest,
    /// reconciling the manifest's run context against the live config:
    /// SIMD tier / thread mismatches degrade gracefully (warn +
    /// redispatch), the data-PRNG seed and variant are adopted from the
    /// manifest (they define the trajectory being resumed), and the LQS
    /// mask is restored verbatim and locked against recalibration.
    fn apply_checkpoint(&mut self, ck: Checkpoint,
                        man: crate::resilience::CkptManifest,
                        label: &str) -> Result<()> {
        if ck.preset != self.cfg.preset {
            bail!("checkpoint preset {} != configured {}", ck.preset,
                  self.cfg.preset);
        }
        let tier = crate::kernels::active_tier().name();
        if man.simd_tier != tier {
            crate::warn_!("resume {label}: checkpoint written under SIMD \
                           tier {:?}, host runs {tier:?} — kernels \
                           redispatch; results stay bit-identical",
                          man.simd_tier);
        }
        if man.threads != crate::kernels::num_threads() {
            crate::info!("resume {label}: thread count {} -> {}",
                         man.threads, crate::kernels::num_threads());
        }
        if man.seed != self.cfg.seed {
            crate::warn_!("resume {label}: adopting checkpoint data seed \
                           {} (config said {})", man.seed, self.cfg.seed);
            self.cfg.seed = man.seed;
            self.data = Self::make_data(&self.preset, &self.cfg);
        }
        if man.schedule != self.schedule() {
            crate::warn_!("resume {label}: LR schedule differs from the \
                           checkpointed run ({:?} vs {:?}); the resumed \
                           trajectory will diverge", man.schedule,
                          self.schedule());
        }
        if man.variant != self.cfg.variant {
            crate::warn_!("resume {label}: adopting checkpoint variant \
                           {:?} (config said {:?})", man.variant,
                          self.cfg.variant);
            self.cfg.variant = man.variant.clone();
        }
        if man.lqs_mask.len() == self.lqs_mask.len() {
            self.lqs_mask = man.lqs_mask.clone();
            self.mask_locked = true;
        } else {
            crate::warn_!("resume {label}: manifest LQS mask arity {} != \
                           {} qlinears; will recalibrate",
                          man.lqs_mask.len(), self.lqs_mask.len());
        }
        self.weights = ck.weights;
        self.state.m = ck.m;
        self.state.v = ck.v;
        self.step = ck.step;
        crate::info!("resumed {label} at step {}", self.step);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// LoRA fine-tuning driver (Table 9 / HOT+LoRA rows of Tables 3-4)
// ---------------------------------------------------------------------------

pub struct LoraTrainer {
    pub rt: Arc<dyn Executor>,
    pub cfg: RunConfig,
    pub key: String,
    /// This tenant's trainable overlay + a shared handle to the frozen
    /// base weights (`adapters.base()`).
    pub adapters: AdapterSet,
    /// AdamW moments for the trainable set (the ctx store is unused —
    /// LoRA steps are fused).
    pub state: TrainState,
    pub lqs_mask: Vec<f32>,
    pub metrics: MetricsLog,
    pub data: VisionDataset,
    pub step: usize,
    /// Keep raw span events when tracing (Chrome-trace export).
    pub keep_trace: bool,
    /// Accumulated span events across all steps (when `keep_trace`).
    pub trace: Vec<crate::obs::TraceEvent>,
    batch: usize,
}

impl LoraTrainer {
    pub fn new(rt: Arc<dyn Executor>, cfg: RunConfig, key: &str) -> Result<Self> {
        let meta = rt.lora_meta(key)?;
        let preset = rt.preset(&meta.preset)?;
        let base = rt.init_store(&meta.preset)?;
        // trainable init: lora_a ~ N(0, 1/r), lora_b = 0, embed/head copied
        let mut rng = crate::util::prng::Pcg32::seeded(cfg.seed ^ 0x10ae);
        let trainable: Vec<Value> = meta
            .trainable
            .iter()
            .map(|s| {
                if s.name.ends_with(".lora_a") {
                    let r = s.shape[0] as f32;
                    let mut data = vec![0.0f32; s.numel()];
                    rng.fill_normal(&mut data, 0.0, 1.0 / r);
                    Ok(Value::F32 { shape: s.shape.clone(), data })
                } else if s.name.ends_with(".lora_b") {
                    Ok(Value::zeros_like_spec(s))
                } else {
                    // full-rank trainable (embed/head): seeded from the
                    // frozen base by name
                    Ok(Value::F32 { shape: s.shape.clone(),
                                    data: base.f(&s.name)?.to_vec() })
                }
            })
            .collect::<Result<_>>()?;
        let adapters = AdapterSet::new(&base, meta.trainable.clone(),
                                       trainable)?;
        let state = TrainState::new(&meta.trainable, 0);
        let data = VisionDataset::new(preset.model.seq, preset.model.in_dim,
                                      preset.model.n_classes, cfg.seed);
        let batch = meta.batch.unwrap_or(cfg.batch).max(1);
        Ok(LoraTrainer {
            rt,
            key: key.to_string(),
            adapters,
            state,
            lqs_mask: vec![0.0; preset.qlinears.len()],
            metrics: MetricsLog::new(),
            data,
            cfg,
            step: 0,
            keep_trace: false,
            trace: Vec::new(),
            batch,
        })
    }

    pub fn step_once(&mut self) -> Result<(f32, f32)> {
        let t0 = Instant::now();
        let (loss, acc) = {
            let _sp = crate::obs::span(crate::obs::Span::TrainStep);
            let (x, y) = self.data.batch(0, self.step as u64, self.batch);
            self.rt.lora_step(
                &self.key, &mut self.adapters, &mut self.state,
                self.step as f32 + 1.0, self.cfg.lr_at(self.step),
                &self.lqs_mask, &x, &y)?
        };
        let prof = crate::obs::enabled()
            .then(|| crate::obs::drain_step(self.keep_trace));
        let (prof_span_ns, prof_flops, prof_bytes_quant, quant_top) =
            prof_fields(prof.as_ref());
        if let Some(p) = prof {
            self.trace.extend(p.events);
        }
        self.metrics.push(StepRecord {
            step: self.step,
            loss,
            acc,
            lr: self.cfg.lr_at(self.step),
            step_time_s: t0.elapsed().as_secs_f64(),
            ctx_live_bytes: 0,
            ctx_peak_bytes: 0,
            ctx_compression: 1.0,
            weight_bytes_shared: self.adapters.base().total_bytes() as u64,
            adapter_bytes: self.adapters.adapter_bytes() as u64,
            prof_span_ns,
            prof_flops,
            prof_bytes_quant,
            quant_top,
        });
        self.step += 1;
        Ok((loss, acc))
    }
}
