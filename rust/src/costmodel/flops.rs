//! FLOP / bit-operation (bops) accounting — Table 11 and Fig 7 (right).
//!
//! Table 11 (per layer, n = Hadamard block = 16, r = HLA rank):
//!   vanilla BP        4·L·I·O                       (two GEMMs)
//!   HOT g_x overhead  2·L·O·log n + 2·I·O·log n + 2·L·O + 2·I·O
//!   HOT g_w overhead  2·L·I·log n + 2·L·O·log n + 2·I·(L·r/n) + 2·O·(L·r/n)
//!   dequant           2·I·O + 2·L·I
//!
//! Bops follow UNIQ/NIPQ accounting: a MAC at (b1, b2) bits costs b1·b2
//! bit-ops; FP32 is charged as 32x32. Elementwise transform/quant ops are
//! charged at 32-bit adds (HT is add/sub only).

use super::zoo::Layer;

pub const BLOCK: usize = 16;
pub const LOG_N: usize = 4; // log2(16)

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Method {
    Fp32,
    Hot { rank: usize },
    LbpWht { rank: usize },
    Luq,
    Int4,
}

impl Method {
    pub fn label(&self) -> String {
        match self {
            Method::Fp32 => "FP".into(),
            Method::Hot { rank } => format!("HOT(r={rank})"),
            Method::LbpWht { rank } => format!("LBP-WHT(r={rank})"),
            Method::Luq => "LUQ".into(),
            Method::Int4 => "INT4".into(),
        }
    }
}

/// FLOPs of the two backward GEMMs under `method` (the low-precision GEMM
/// ops counted as FLOPs — see `bops` for precision-weighted cost).
pub fn bwd_gemm_flops(l: &Layer, method: Method) -> u64 {
    let (ll, o, i) = (l.l as u64, l.o as u64, l.i as u64);
    match method {
        Method::Fp32 | Method::Luq | Method::Int4 => 4 * ll * i * o,
        // HOT: g_x full dims; g_w over compressed L
        Method::Hot { rank } => {
            2 * ll * i * o + 2 * (ll * rank as u64 / BLOCK as u64) * i * o
        }
        // LBP-WHT: both paths over compressed L
        Method::LbpWht { rank } => {
            4 * (ll * rank as u64 / BLOCK as u64) * i * o
        }
    }
}

/// Transform/quant/dequant overhead FLOPs (Table 11).
pub fn overhead_flops(l: &Layer, method: Method) -> u64 {
    let (ll, o, i) = (l.l as u64, l.o as u64, l.i as u64);
    let logn = LOG_N as u64;
    match method {
        Method::Fp32 => 0,
        Method::Int4 | Method::Luq => {
            // quantize both operands of both GEMMs + dequant outputs
            2 * (ll * o + i * o) + 2 * (ll * o + ll * i) + 2 * (i * o + ll * i)
        }
        Method::Hot { rank } => {
            let r = rank as u64;
            let gx = 2 * ll * o * logn + 2 * i * o * logn + 2 * ll * o + 2 * i * o;
            let gw = 2 * ll * i * logn + 2 * ll * o * logn
                + 2 * i * (ll * r / BLOCK as u64)
                + 2 * o * (ll * r / BLOCK as u64);
            let dequant = 2 * i * o + 2 * ll * i;
            gx + gw + dequant
        }
        Method::LbpWht { rank } => {
            let r = rank as u64;
            // project g_y & x & the g_x expansion (all HT-based)
            2 * ll * o * logn + 2 * ll * i * logn + 2 * (ll * r / BLOCK as u64) * i * logn
        }
    }
}

pub fn total_flops(l: &Layer, method: Method) -> u64 {
    bwd_gemm_flops(l, method) + overhead_flops(l, method)
}

/// Bit-operations for the backward pass of one layer.
pub fn bops(l: &Layer, method: Method) -> u64 {
    let (ll, o, i) = (l.l as u64, l.o as u64, l.i as u64);
    let fp = 32 * 32;
    match method {
        Method::Fp32 => 2 * ll * i * o * fp * 2 / 2, // both GEMMs at 32x32
        Method::Hot { rank } => {
            let gx = 2 * ll * i * o * (4 * 4);
            let gw = 2 * (ll * rank as u64 / BLOCK as u64) * i * o * (8 * 8);
            gx + gw + overhead_flops(l, method) * 32
        }
        Method::LbpWht { rank } => {
            // FP16 GEMMs over compressed dims
            let g = 4 * (ll * rank as u64 / BLOCK as u64) * i * o * (16 * 16);
            g + overhead_flops(l, method) * 32
        }
        Method::Luq => {
            // FP4-ish gradient x INT4 operand
            4 * ll * i * o * (4 * 4) + overhead_flops(l, method) * 32
        }
        Method::Int4 => 4 * ll * i * o * (4 * 4) + overhead_flops(l, method) * 32,
    }
}

/// Whole-model backward bops (per sample).
pub fn model_bops(layers: &[Layer], method: Method) -> u64 {
    layers.iter().map(|l| bops(l, method)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer() -> Layer {
        Layer::new("test", 197, 768, 3072)
    }

    #[test]
    fn vanilla_matches_table11() {
        let l = layer();
        assert_eq!(bwd_gemm_flops(&l, Method::Fp32),
                   4 * 197 * 768 * 3072);
    }

    #[test]
    fn appendix_d_example() {
        // 'stages.3.fc2' (49, 448, 1792): vanilla 137.3 MFlops less the
        // low-precision GEMMs leaves ~11.5 MFlops of HOT overhead.
        let l = Layer::new("stages.3.fc2", 49, 448, 1792);
        let vanilla = bwd_gemm_flops(&l, Method::Fp32) as f64 / 1e6;
        assert!((vanilla - 157.4).abs() < 25.0, "{vanilla}");
        let ovh = overhead_flops(&l, Method::Hot { rank: 8 }) as f64 / 1e6;
        assert!(ovh > 5.0 && ovh < 20.0, "{ovh}");
    }

    #[test]
    fn hot_overhead_small_relative() {
        // paper: overhead negligible when log n << dims (~7% predicted)
        let l = layer();
        let ovh = overhead_flops(&l, Method::Hot { rank: 8 }) as f64;
        let van = bwd_gemm_flops(&l, Method::Fp32) as f64;
        assert!(ovh / van < 0.15, "{}", ovh / van);
    }

    #[test]
    fn hot_bops_beat_fp_by_large_factor() {
        let l = layer();
        let r = bops(&l, Method::Hot { rank: 8 }) as f64
            / bops(&l, Method::Fp32) as f64;
        // paper Fig 7: ~65% reduction in total compute; per-layer GEMM
        // bops drop much harder (4x4 vs 32x32)
        assert!(r < 0.5, "{r}");
    }

    #[test]
    fn gemm_flops_ordering() {
        let l = layer();
        let fp = bwd_gemm_flops(&l, Method::Fp32);
        let hot = bwd_gemm_flops(&l, Method::Hot { rank: 8 });
        let lbp = bwd_gemm_flops(&l, Method::LbpWht { rank: 8 });
        assert!(lbp < hot && hot < fp);
    }

    #[test]
    fn rank_scales_gw_cost() {
        let l = layer();
        let h1 = total_flops(&l, Method::Hot { rank: 1 });
        let h8 = total_flops(&l, Method::Hot { rank: 8 });
        let h16 = total_flops(&l, Method::Hot { rank: 16 });
        assert!(h1 < h8 && h8 < h16);
    }
}
