//! Training-memory model — Figures 1, 2, 7 (left) and Table 7's memory
//! column.
//!
//! Components (bytes), per the paper's Fig 2 breakdown:
//!   * weights           4·P                (FP32 master copy)
//!   * gradients         4·P                (one full grad buffer)
//!   * optimizer state   8·P                (AdamW m+v)
//!   * activations       method-dependent; per qlinear the saved-for-bwd
//!     input x is the dominant term: batch·L·I·4 for FP-keeping methods,
//!     batch·(L·r/16)·I·1 (+4) under HOT's ABC. Attention internals
//!     (softmax probs, q/k/v) and norm stats are FP for every method.
//!
//! LoRA halves differently: base weights have no grads/optimizer state;
//! adapters add 2·r_lora·(I+O) params per adapted layer.

use super::zoo::{Layer, ModelSpec};

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MemMethod {
    Fp32,
    /// LBP-WHT & LUQ store FP activations too (paper: "consume the same
    /// memory as FP32").
    FpActivations,
    Hot { rank: usize, abc: bool },
    Lora { r_lora: usize },
    HotLora { rank: usize, r_lora: usize },
}

#[derive(Debug, Clone, Default)]
pub struct MemBreakdown {
    pub weights: u64,
    pub gradients: u64,
    pub optimizer: u64,
    pub activations: u64,
    pub attention: u64,
}

impl MemBreakdown {
    pub fn total(&self) -> u64 {
        self.weights + self.gradients + self.optimizer + self.activations
            + self.attention
    }

    pub fn gb(&self) -> f64 {
        self.total() as f64 / (1u64 << 30) as f64
    }
}

fn act_bytes_layer(l: &Layer, batch: usize, m: MemMethod) -> u64 {
    let raw = (batch * l.l * l.i * 4) as u64;
    let compressed =
        |rank: usize| (batch * (l.l * rank / 16).max(1) * l.i) as u64 + 4;
    match m {
        MemMethod::Fp32 | MemMethod::FpActivations | MemMethod::Lora { .. } => raw,
        MemMethod::Hot { abc: false, .. } => raw,
        MemMethod::Hot { rank, abc: true } => compressed(rank),
        MemMethod::HotLora { rank, .. } => compressed(rank),
    }
}

/// Eager-framework extras: tensors a stock PyTorch backward materializes
/// beyond the linear-layer inputs — attention q/k/v, softmax probs, GELU
/// pre-activations. The paper's FP/LUQ/LBP baselines run in eager mode
/// and pay these (this is what drives Fig 1's OOM at batch 256), while
/// HOT's custom backward kernels recompute them from the (already saved,
/// compressed) layer inputs — the paper's memory estimates count only the
/// compressed buffers for HOT.
fn eager_extra_bytes(spec: &ModelSpec, batch: usize) -> u64 {
    if spec.heads == 0 {
        return 0;
    }
    let per_block = 3 * spec.seq * spec.d_model * 4        // q, k, v
        + spec.heads * spec.seq * spec.seq * 4             // probs
        + 4 * spec.seq * spec.d_model * 4;                 // gelu input
    (batch * spec.depth * per_block) as u64
}

pub fn breakdown(spec: &ModelSpec, batch: usize, m: MemMethod) -> MemBreakdown {
    let p = spec.params();
    let (w, g, o) = match m {
        MemMethod::Lora { r_lora } | MemMethod::HotLora { r_lora, .. } => {
            let adapter: u64 = spec
                .layers
                .iter()
                .filter(|l| l.l > 1) // head/fc excluded from adapters
                .map(|l| (r_lora * (l.i + l.o)) as u64)
                .sum();
            (4 * p + 4 * adapter, 4 * adapter, 8 * adapter)
        }
        _ => (4 * p, 4 * p, 8 * p),
    };
    // LoRA frozen layers skip g_w but adapter grads still need the same x,
    // so LoRA activations stay FP — matching the paper's Table 1.
    let act: u64 = spec.layers.iter().map(|l| act_bytes_layer(l, batch, m)).sum();
    let extras = match m {
        MemMethod::Hot { abc: true, .. } | MemMethod::HotLora { .. } => 0,
        _ => eager_extra_bytes(spec, batch),
    };
    MemBreakdown {
        weights: w,
        gradients: g,
        optimizer: o,
        activations: act,
        attention: extras,
    }
}

/// Fig 1: total training memory vs batch size, with a device budget.
pub fn batch_sweep(spec: &ModelSpec, batches: &[usize], m: MemMethod)
                   -> Vec<(usize, f64)> {
    batches.iter().map(|&b| (b, breakdown(spec, b, m).gb())).collect()
}

/// Largest batch (from `batches`) that fits under `budget_gb`, or None.
pub fn max_feasible_batch(spec: &ModelSpec, batches: &[usize], m: MemMethod,
                          budget_gb: f64) -> Option<usize> {
    batches
        .iter()
        .copied()
        .filter(|&b| breakdown(spec, b, m).gb() <= budget_gb)
        .max()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::zoo;

    #[test]
    fn hot_cuts_activations_8x() {
        let spec = zoo::vit_b();
        let fp = breakdown(&spec, 256, MemMethod::Fp32);
        let hot = breakdown(&spec, 256, MemMethod::Hot { rank: 8, abc: true });
        let ratio = hot.activations as f64 / fp.activations as f64;
        assert!((ratio - 0.125).abs() < 0.01, "{ratio}");
    }

    #[test]
    fn fig1_fp_oom_hot_fits() {
        // paper Fig 1: on 24 GB, FP fails at batch 256; HOT trains at 1024
        let spec = zoo::vit_b();
        let batches = [64, 128, 256, 512, 1024];
        let fp = max_feasible_batch(&spec, &batches, MemMethod::Fp32, 24.0);
        let hot = max_feasible_batch(&spec, &batches,
                                     MemMethod::Hot { rank: 8, abc: true }, 24.0);
        assert!(fp.unwrap_or(0) < 256, "{fp:?}");
        assert_eq!(hot, Some(1024));
    }

    #[test]
    fn lbp_equals_fp_memory() {
        let spec = zoo::vit_b();
        let fp = breakdown(&spec, 256, MemMethod::Fp32);
        let lbp = breakdown(&spec, 256, MemMethod::FpActivations);
        assert_eq!(fp.total(), lbp.total());
    }

    #[test]
    fn lora_cuts_optimizer_not_activations() {
        let spec = zoo::vit_b();
        let fp = breakdown(&spec, 256, MemMethod::Fp32);
        let lora = breakdown(&spec, 256, MemMethod::Lora { r_lora: 8 });
        assert!(lora.optimizer < fp.optimizer / 50);
        assert_eq!(lora.activations, fp.activations);
    }

    #[test]
    fn hot_lora_cuts_both() {
        let spec = zoo::vit_b();
        let fp = breakdown(&spec, 256, MemMethod::Fp32);
        let hl = breakdown(&spec, 256,
                           MemMethod::HotLora { rank: 8, r_lora: 8 });
        assert!(hl.optimizer < fp.optimizer / 50);
        assert!(hl.activations < fp.activations / 7);
    }

    #[test]
    fn paper_fig7_memory_reduction_band() {
        // paper: up to 75% total reduction on ViT; 86% on ResNet-50
        for (spec, lo) in [(zoo::vit_b(), 0.50), (zoo::resnet50(), 0.60)] {
            let fp = breakdown(&spec, 256, MemMethod::Fp32).total() as f64;
            let hot = breakdown(&spec, 256,
                                MemMethod::Hot { rank: 8, abc: true })
                .total() as f64;
            let reduction = 1.0 - hot / fp;
            assert!(reduction > lo, "{}: {}", spec.name, reduction);
        }
    }

    #[test]
    fn abc_off_equals_fp_activations() {
        let spec = zoo::vit_b();
        let noabc = breakdown(&spec, 64, MemMethod::Hot { rank: 8, abc: false });
        let fp = breakdown(&spec, 64, MemMethod::Fp32);
        assert_eq!(noabc.activations, fp.activations);
    }
}
