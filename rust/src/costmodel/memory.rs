//! Training-memory model — Figures 1, 2, 7 (left) and Table 7's memory
//! column.
//!
//! Components (bytes), per the paper's Fig 2 breakdown:
//!   * weights           4·P                (FP32 master copy)
//!   * gradients         4·P                (one full grad buffer)
//!   * optimizer state   8·P                (AdamW m+v)
//!   * activations       method-dependent; per qlinear the saved-for-bwd
//!     input x is the dominant term: batch·L·I·4 for FP-keeping methods,
//!     ceil(batch·L·r/16)·(I + 4) under HOT's ABC (INT8 payload + one
//!     f32 scale per compressed row). Attention internals (softmax
//!     probs, q/k/v) and norm stats are FP for the eager baselines;
//!     HOT's custom backward stores them packed (`native_ctx_bytes`).
//!
//! LoRA halves differently: base weights have no grads/optimizer state;
//! adapters add 2·r_lora·(I+O) params per adapted layer.

use super::zoo::{Layer, ModelSpec};
use crate::backend::native::layers::BackwardCfg;
use crate::backend::native::presets::ModelShape;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MemMethod {
    Fp32,
    /// LBP-WHT & LUQ store FP activations too (paper: "consume the same
    /// memory as FP32").
    FpActivations,
    Hot { rank: usize, abc: bool },
    Lora { r_lora: usize },
    HotLora { rank: usize, r_lora: usize },
}

#[derive(Debug, Clone, Default)]
pub struct MemBreakdown {
    pub weights: u64,
    pub gradients: u64,
    pub optimizer: u64,
    pub activations: u64,
    pub attention: u64,
}

impl MemBreakdown {
    pub fn total(&self) -> u64 {
        self.weights + self.gradients + self.optimizer + self.activations
            + self.attention
    }

    pub fn gb(&self) -> f64 {
        self.total() as f64 / (1u64 << 30) as f64
    }
}

fn act_bytes_layer(l: &Layer, batch: usize, m: MemMethod) -> u64 {
    let raw = (batch * l.l * l.i * 4) as u64;
    // INT8 payload (one byte per element of the rank-compressed buffer)
    // plus one 4-byte f32 scale PER COMPRESSED ROW — the quantizer is
    // per-row (`minmax_scale_rows`), not per-tensor. div_ceil keeps
    // tiny l·rank products from truncating the whole buffer to zero.
    let compressed = |rank: usize| {
        let rows = ((batch * l.l * rank) as u64).div_ceil(16).max(1);
        rows * l.i as u64 + 4 * rows
    };
    match m {
        MemMethod::Fp32 | MemMethod::FpActivations | MemMethod::Lora { .. } => raw,
        MemMethod::Hot { abc: false, .. } => raw,
        MemMethod::Hot { rank, abc: true } => compressed(rank),
        MemMethod::HotLora { rank, .. } => compressed(rank),
    }
}

/// Eager-framework extras: tensors a stock PyTorch backward materializes
/// beyond the linear-layer inputs — attention q/k/v, softmax probs, GELU
/// pre-activations. The paper's FP/LUQ/LBP baselines run in eager mode
/// and pay these (this is what drives Fig 1's OOM at batch 256), while
/// HOT's custom backward kernels recompute them from the (already saved,
/// compressed) layer inputs — the paper's memory estimates count only the
/// compressed buffers for HOT.
fn eager_extra_bytes(spec: &ModelSpec, batch: usize) -> u64 {
    if spec.heads == 0 {
        return 0;
    }
    let per_block = 3 * spec.seq * spec.d_model * 4        // q, k, v
        + spec.heads * spec.seq * spec.seq * 4             // probs
        + 4 * spec.seq * spec.d_model * 4;                 // gelu input
    (batch * spec.depth * per_block) as u64
}

pub fn breakdown(spec: &ModelSpec, batch: usize, m: MemMethod) -> MemBreakdown {
    let p = spec.params();
    let (w, g, o) = match m {
        MemMethod::Lora { r_lora } | MemMethod::HotLora { r_lora, .. } => {
            let adapter: u64 = spec
                .layers
                .iter()
                .filter(|l| l.l > 1) // head/fc excluded from adapters
                .map(|l| (r_lora * (l.i + l.o)) as u64)
                .sum();
            (4 * p + 4 * adapter, 4 * adapter, 8 * adapter)
        }
        _ => (4 * p, 4 * p, 8 * p),
    };
    // LoRA frozen layers skip g_w but adapter grads still need the same x,
    // so LoRA activations stay FP — matching the paper's Table 1.
    let act: u64 = spec.layers.iter().map(|l| act_bytes_layer(l, batch, m)).sum();
    let extras = match m {
        MemMethod::Hot { abc: true, .. } | MemMethod::HotLora { .. } => 0,
        _ => eager_extra_bytes(spec, batch),
    };
    MemBreakdown {
        weights: w,
        gradients: g,
        optimizer: o,
        activations: act,
        attention: extras,
    }
}

/// Predicted saved-for-backward ctx bytes of ONE microbatch on the
/// native backend — what the `CtxStore` will measure for a split-mode
/// step. Mirrors `backend::native::model::ctx_layout` entry by entry
/// (a unit test pins the two equal, so they cannot drift):
///
///   * qlinear x: raw `rows·cols·4` for eager variants; under ABC the
///     HLA rank-compressed payload `(rows/16·rank)·cols` codes at
///     `abc_bits` (nibble-packed at 4) + one f32 scale per row;
///   * LN x-hat, attention q/k/v heads + probs, GELU input, CE probs:
///     raw f32 for eager variants; per-row INT8 codes + row scales
///     under the packed schema (`BackwardCfg::packs_ctx`), with GELU's
///     tanh and the CE one-hot recomputed instead of stored (the
///     one-hot shrinks to one i32 label per row);
///   * LN rstd stays f32 everywhere.
pub fn native_ctx_bytes(shape: &ModelShape, cfg: &BackwardCfg, batch: usize)
                        -> u64 {
    let (d, l, m, c) = (shape.d_model, shape.seq, shape.d_mlp(),
                        shape.n_classes);
    let n = batch * l;
    let packed = cfg.packs_ctx();
    // per-row quantized f32 tensor: codes + f32 scale per row
    let qrows = |rows: usize, cols: usize| -> u64 {
        (rows * cols) as u64 + 4 * rows as u64
    };
    let fp = |rows: usize, cols: usize| (rows * cols * 4) as u64;
    let buf = |rows: usize, cols: usize| -> u64 {
        if packed { qrows(rows, cols) } else { fp(rows, cols) }
    };
    let ql = |rows: usize, cols: usize| -> u64 {
        if cfg.compresses(rows) {
            let nc = rows / 16 * cfg.rank;
            ((nc * cols * cfg.abc_bits as usize) as u64).div_ceil(8)
                + 4 * nc as u64
        } else {
            fp(rows, cols)
        }
    };
    let ln = |rows: usize| 4 * rows as u64 + buf(rows, d);
    let mut total = ql(n, shape.in_dim); // embed
    for _ in 0..shape.depth {
        if shape.has_attention() {
            let heads = batch * shape.heads * l;
            total += ln(n)                      // ln1
                + ql(n, d)                      // qkv
                + 3 * buf(heads, d / shape.heads) // qh kh vh
                + buf(heads, l)                 // probs
                + ql(n, d);                     // proj
        }
        total += ln(n)                          // ln2
            + ql(n, d)                          // fc1
            + if packed { qrows(n, m) } else { 2 * fp(n, m) } // gelu x (+t)
            + ql(n, m);                         // fc2
    }
    total += ln(n); // lnf
    let head_rows = if shape.arch == "lm" { n } else { batch };
    total += ql(head_rows, d);
    total += if packed {
        4 * head_rows as u64 + qrows(head_rows, c) // labels + probs
    } else {
        2 * fp(head_rows, c) // onehot + probs
    };
    total
}

/// Fig 1: total training memory vs batch size, with a device budget.
pub fn batch_sweep(spec: &ModelSpec, batches: &[usize], m: MemMethod)
                   -> Vec<(usize, f64)> {
    batches.iter().map(|&b| (b, breakdown(spec, b, m).gb())).collect()
}

/// Largest batch (from `batches`) that fits under `budget_gb`, or None.
pub fn max_feasible_batch(spec: &ModelSpec, batches: &[usize], m: MemMethod,
                          budget_gb: f64) -> Option<usize> {
    batches
        .iter()
        .copied()
        .filter(|&b| breakdown(spec, b, m).gb() <= budget_gb)
        .max()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::zoo;

    #[test]
    fn hot_cuts_activations_8x() {
        let spec = zoo::vit_b();
        let fp = breakdown(&spec, 256, MemMethod::Fp32);
        let hot = breakdown(&spec, 256, MemMethod::Hot { rank: 8, abc: true });
        let ratio = hot.activations as f64 / fp.activations as f64;
        assert!((ratio - 0.125).abs() < 0.01, "{ratio}");
    }

    #[test]
    fn fig1_fp_oom_hot_fits() {
        // paper Fig 1: on 24 GB, FP fails at batch 256; HOT trains at 1024
        let spec = zoo::vit_b();
        let batches = [64, 128, 256, 512, 1024];
        let fp = max_feasible_batch(&spec, &batches, MemMethod::Fp32, 24.0);
        let hot = max_feasible_batch(&spec, &batches,
                                     MemMethod::Hot { rank: 8, abc: true }, 24.0);
        assert!(fp.unwrap_or(0) < 256, "{fp:?}");
        assert_eq!(hot, Some(1024));
    }

    #[test]
    fn lbp_equals_fp_memory() {
        let spec = zoo::vit_b();
        let fp = breakdown(&spec, 256, MemMethod::Fp32);
        let lbp = breakdown(&spec, 256, MemMethod::FpActivations);
        assert_eq!(fp.total(), lbp.total());
    }

    #[test]
    fn lora_cuts_optimizer_not_activations() {
        let spec = zoo::vit_b();
        let fp = breakdown(&spec, 256, MemMethod::Fp32);
        let lora = breakdown(&spec, 256, MemMethod::Lora { r_lora: 8 });
        assert!(lora.optimizer < fp.optimizer / 50);
        assert_eq!(lora.activations, fp.activations);
    }

    #[test]
    fn hot_lora_cuts_both() {
        let spec = zoo::vit_b();
        let fp = breakdown(&spec, 256, MemMethod::Fp32);
        let hl = breakdown(&spec, 256,
                           MemMethod::HotLora { rank: 8, r_lora: 8 });
        assert!(hl.optimizer < fp.optimizer / 50);
        assert!(hl.activations < fp.activations / 7);
    }

    #[test]
    fn paper_fig7_memory_reduction_band() {
        // paper: up to 75% total reduction on ViT; 86% on ResNet-50
        for (spec, lo) in [(zoo::vit_b(), 0.50), (zoo::resnet50(), 0.60)] {
            let fp = breakdown(&spec, 256, MemMethod::Fp32).total() as f64;
            let hot = breakdown(&spec, 256,
                                MemMethod::Hot { rank: 8, abc: true })
                .total() as f64;
            let reduction = 1.0 - hot / fp;
            assert!(reduction > lo, "{}: {}", spec.name, reduction);
        }
    }

    #[test]
    fn abc_off_equals_fp_activations() {
        let spec = zoo::vit_b();
        let noabc = breakdown(&spec, 64, MemMethod::Hot { rank: 8, abc: false });
        let fp = breakdown(&spec, 64, MemMethod::Fp32);
        assert_eq!(noabc.activations, fp.activations);
    }

    #[test]
    fn per_row_scales_and_tiny_layers_are_charged() {
        // tiny l·rank used to truncate to 0 compressed rows; and the
        // scale overhead must be one f32 PER ROW, not per layer
        let l = Layer::new("t", 1, 64, 64);
        let hot = MemMethod::Hot { rank: 8, abc: true };
        let got = act_bytes_layer(&l, 1, hot);
        // 1·1·8 / 16 rows rounds up to 1 row: 64 payload + 4 scale bytes
        assert_eq!(got, 68);
        // 256 tokens at rank 8 -> 128 rows: payload 128·64, scales 128·4
        let l2 = Layer::new("t2", 256, 64, 64);
        assert_eq!(act_bytes_layer(&l2, 1, hot), 128 * 64 + 128 * 4);
    }

    #[test]
    fn native_ctx_bytes_matches_measured_ctx_exactly() {
        // the predictor must agree byte-for-byte with what the native
        // forward actually emits (and the CtxStore therefore accounts)
        use crate::backend::native::model::{self, Params};
        use crate::backend::native::presets;
        use crate::runtime::value::Value;
        use crate::util::prng::Pcg32;
        for (preset, batch, tags) in [
            ("tiny", 4usize, &["fp", "hot", "hot_noabc", "hot_abc4"][..]),
            ("lm_tiny", 2, &["fp", "hot", "hot_abc4"][..]),
            ("mlp_small", 2, &["fp", "hot"][..]),
        ] {
            let shape = presets::shape_of(preset).unwrap();
            let specs = presets::param_specs(&shape);
            let values = presets::init_values(&shape, 1);
            let p = Params::new(&specs, &values).unwrap();
            let mask = vec![0.0f32; shape.n_qlinears()];
            let mut rng = Pcg32::seeded(7);
            let (x, y) = if shape.arch == "lm" {
                let n = batch * shape.seq;
                (Value::I32 {
                    shape: vec![batch, shape.seq],
                    data: (0..n).map(|_| rng.below(shape.in_dim as u32) as i32)
                        .collect(),
                 },
                 Value::I32 {
                    shape: vec![batch, shape.seq],
                    data: (0..n)
                        .map(|_| rng.below(shape.n_classes as u32) as i32)
                        .collect(),
                 })
            } else {
                let n = batch * shape.seq * shape.in_dim;
                (Value::F32 { shape: vec![batch, shape.seq, shape.in_dim],
                              data: (0..n).map(|_| rng.normal()).collect() },
                 Value::I32 {
                    shape: vec![batch],
                    data: (0..batch)
                        .map(|_| rng.below(shape.n_classes as u32) as i32)
                        .collect(),
                 })
            };
            for tag in tags {
                let cfg = crate::backend::native::layers::BackwardCfg::parse(
                    tag).unwrap();
                let fwd = model::forward(&shape, &cfg, &p, &mask, &x, &y)
                    .unwrap();
                let (vals, _) = model::flatten_ctx(fwd.ctxs);
                let measured: u64 = vals.iter().map(|v| v.bytes() as u64)
                    .sum();
                let predicted = native_ctx_bytes(&shape, &cfg, batch);
                assert_eq!(predicted, measured, "{preset}/{tag}");
            }
        }
    }
}
