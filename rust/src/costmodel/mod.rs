//! Analytic cost models: FLOPs/bops (Table 11, Fig 7-right) and training
//! memory (Fig 1, Fig 2, Fig 7-left, Table 7). The model zoo carries the
//! paper's exact evaluation-layer dimensions.

pub mod flops;
pub mod memory;
pub mod zoo;

pub use flops::{bops, model_bops, overhead_flops, total_flops, Method};
pub use memory::{breakdown, max_feasible_batch, native_ctx_bytes,
                 MemBreakdown, MemMethod};
pub use zoo::{Layer, ModelSpec};
