//! Model zoo: the paper's evaluation architectures as (L, O, I) matmul
//! layer lists — the exact notation of HOT §4.1 / Appendix D, where conv
//! layers are im2col'd (L = W*H spatial positions, I = C_in*k*k).
//!
//! Dims follow the standard 224x224 ImageNet configurations; Table 6's
//! profiled layers appear verbatim (they are spot-checked in tests).

#[derive(Debug, Clone)]
pub struct Layer {
    pub name: String,
    pub l: usize,
    pub o: usize,
    pub i: usize,
}

impl Layer {
    pub fn new(name: &str, l: usize, o: usize, i: usize) -> Layer {
        Layer { name: name.to_string(), l, o, i }
    }

    /// Forward MACs (= g_x MACs = g_w MACs).
    pub fn macs(&self) -> u64 {
        (self.l * self.o * self.i) as u64
    }
}

#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub layers: Vec<Layer>,
    /// attention heads per block (0 for CNNs/MLPs): drives the FP
    /// attention-internals activation term (softmax probs).
    pub heads: usize,
    pub seq: usize,
    pub d_model: usize,
    pub depth: usize,
}

impl ModelSpec {
    pub fn params(&self) -> u64 {
        self.layers.iter().map(|l| (l.o * l.i) as u64 + l.o as u64).sum()
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }
}

/// ViT encoder: per block [qkv (L,3D,D), proj (L,D,D), fc1 (L,4D,D),
/// fc2 (L,D,4D)] + patch embed + head.
pub fn vit(name: &str, depth: usize, d: usize, l: usize, heads: usize,
           patch_in: usize, classes: usize) -> ModelSpec {
    let mut layers = vec![Layer::new("patch_embed", l, d, patch_in)];
    for b in 0..depth {
        layers.push(Layer::new(&format!("blk{b}.qkv"), l, 3 * d, d));
        layers.push(Layer::new(&format!("blk{b}.proj"), l, d, d));
        layers.push(Layer::new(&format!("blk{b}.fc1"), l, 4 * d, d));
        layers.push(Layer::new(&format!("blk{b}.fc2"), l, d, 4 * d));
    }
    layers.push(Layer::new("head", 1, classes, d));
    ModelSpec { name: name.into(), layers, heads, seq: l, d_model: d, depth }
}

pub fn vit_b() -> ModelSpec {
    vit("ViT-B", 12, 768, 197, 12, 768, 1000)
}

pub fn vit_s() -> ModelSpec {
    vit("ViT-S", 12, 384, 197, 6, 768, 1000)
}

/// ResNet im2col layers at 224x224 (bottleneck blocks [3,4,6,3] for -50).
/// Only conv layers carry HOT; L halves (spatially /4) per stage.
pub fn resnet50() -> ModelSpec {
    let mut layers = vec![Layer::new("conv1", 12544, 64, 147)]; // 7x7x3
    let stages: [(usize, usize, usize, usize); 4] = [
        // (spatial L, width, blocks, in_ch of stage)
        (3136, 64, 3, 64),
        (784, 128, 4, 256),
        (196, 256, 6, 512),
        (49, 512, 3, 1024),
    ];
    for (si, (l, w, blocks, in_ch)) in stages.iter().enumerate() {
        for b in 0..*blocks {
            let cin = if b == 0 { *in_ch } else { w * 4 };
            layers.push(Layer::new(&format!("layer{}.{}.conv1", si + 1, b),
                                   *l, *w, cin));
            layers.push(Layer::new(&format!("layer{}.{}.conv2", si + 1, b),
                                   *l, *w, w * 9));
            layers.push(Layer::new(&format!("layer{}.{}.conv3", si + 1, b),
                                   *l, w * 4, *w));
            if b == 0 {
                layers.push(Layer::new(&format!("layer{}.{}.down", si + 1, b),
                                       *l, w * 4, cin));
            }
        }
    }
    layers.push(Layer::new("fc", 1, 1000, 2048));
    ModelSpec { name: "ResNet-50".into(), layers, heads: 0, seq: 3136,
                d_model: 512, depth: 16 }
}

pub fn resnet18() -> ModelSpec {
    let mut layers = vec![Layer::new("conv1", 12544, 64, 147)];
    let stages: [(usize, usize, usize); 4] =
        [(3136, 64, 2), (784, 128, 2), (196, 256, 2), (49, 512, 2)];
    let mut cin = 64;
    for (si, (l, w, blocks)) in stages.iter().enumerate() {
        for b in 0..*blocks {
            let c0 = if b == 0 { cin } else { *w };
            layers.push(Layer::new(&format!("layer{}.{}.conv1", si + 1, b),
                                   *l, *w, c0 * 9));
            layers.push(Layer::new(&format!("layer{}.{}.conv2", si + 1, b),
                                   *l, *w, w * 9));
        }
        cin = *w;
    }
    layers.push(Layer::new("fc", 1, 1000, 512));
    ModelSpec { name: "ResNet-18".into(), layers, heads: 0, seq: 3136,
                d_model: 512, depth: 8 }
}

/// EfficientFormer-L7-ish: 4 stages of (meta)blocks with fc1/fc2 (+qkv/proj
/// in the last stage), dims from Table 6's profiled rows.
pub fn efficientformer_l7() -> ModelSpec {
    let mut layers = vec![Layer::new("stem", 3136, 96, 48)];
    let stages: [(usize, usize, usize, bool); 4] = [
        (3136, 96, 6, false),
        (784, 192, 6, false),
        (196, 384, 8, false),
        (49, 768, 8, true),
    ];
    for (si, (l, d, blocks, attn)) in stages.iter().enumerate() {
        for b in 0..*blocks {
            if *attn {
                layers.push(Layer::new(&format!("stages.{si}.{b}.qkv"),
                                       *l, 1536, 768));
                layers.push(Layer::new(&format!("stages.{si}.{b}.proj"),
                                       *l, 768, 1024));
            }
            layers.push(Layer::new(&format!("stages.{si}.{b}.fc1"),
                                   *l, d * 4, *d));
            layers.push(Layer::new(&format!("stages.{si}.{b}.fc2"),
                                   *l, *d, d * 4));
        }
    }
    layers.push(Layer::new("head", 1, 1000, 768));
    ModelSpec { name: "EfficientFormer-L7".into(), layers, heads: 8,
                seq: 49, d_model: 768, depth: 28 }
}

pub fn efficientformer_l1() -> ModelSpec {
    let mut layers = vec![Layer::new("stem", 3136, 48, 48)];
    let stages: [(usize, usize, usize, bool); 4] = [
        (3136, 48, 3, false),
        (784, 96, 2, false),
        (196, 224, 6, false),
        (49, 448, 4, true),
    ];
    for (si, (l, d, blocks, attn)) in stages.iter().enumerate() {
        for b in 0..*blocks {
            if *attn {
                layers.push(Layer::new(&format!("stages.{si}.{b}.qkv"),
                                       *l, 896, 448));
                layers.push(Layer::new(&format!("stages.{si}.{b}.proj"),
                                       *l, 448, 448));
            }
            layers.push(Layer::new(&format!("stages.{si}.{b}.fc1"),
                                   *l, d * 4, *d));
            layers.push(Layer::new(&format!("stages.{si}.{b}.fc2"),
                                   *l, *d, d * 4));
        }
    }
    layers.push(Layer::new("head", 1, 1000, 448));
    ModelSpec { name: "EfficientFormer-L1".into(), layers, heads: 8,
                seq: 49, d_model: 448, depth: 15 }
}

/// The exact per-layer dims of the paper's Table 6 latency profile.
pub fn table6_layers() -> Vec<(String, Layer)> {
    let rows: Vec<(&str, &str, usize, usize, usize)> = vec![
        ("ResNet-50", "layer1.conv1", 3136, 64, 256),
        ("ResNet-50", "layer1.conv2", 3136, 64, 576),
        ("ResNet-50", "layer2.conv1", 784, 128, 512),
        ("ResNet-50", "layer2.conv2", 784, 128, 1152),
        ("ResNet-50", "layer3.conv2", 196, 256, 2304),
        ("ResNet-50", "layer4.conv2", 49, 512, 4608),
        ("ViT-B", "qkv", 197, 2304, 768),
        ("ViT-B", "proj", 197, 768, 768),
        ("ViT-B", "fc1", 197, 3072, 768),
        ("ViT-B", "fc2", 197, 768, 3072),
        ("EfficientFormer-L7", "stages.0.fc1", 3136, 384, 96),
        ("EfficientFormer-L7", "stages.1.fc1", 784, 768, 192),
        ("EfficientFormer-L7", "stages.2.fc1", 196, 1536, 384),
        ("EfficientFormer-L7", "stages.3.qkv", 49, 1536, 768),
        ("EfficientFormer-L7", "stages.3.proj", 49, 768, 1024),
        ("EfficientFormer-L7", "stages.3.fc1", 49, 3072, 768),
    ];
    rows.into_iter()
        .map(|(m, n, l, o, i)| (m.to_string(), Layer::new(n, l, o, i)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vit_b_param_count_plausible() {
        let m = vit_b();
        let p = m.params();
        // ViT-B is ~86M; matmul-only accounting should land in 80..92M
        assert!(p > 80_000_000 && p < 95_000_000, "{}", p);
    }

    #[test]
    fn resnet50_macs_plausible() {
        let m = resnet50();
        // ~4.1 GMACs at 224x224
        let g = m.total_macs() as f64 / 1e9;
        assert!(g > 3.0 && g < 5.5, "{}", g);
    }

    #[test]
    fn resnet18_params_plausible() {
        let p = resnet18().params() as f64 / 1e6;
        assert!(p > 9.0 && p < 13.0, "{}", p);
    }

    #[test]
    fn table6_vit_rows_match_model() {
        let m = vit_b();
        let qkv = m.layers.iter().find(|l| l.name == "blk0.qkv").unwrap();
        assert_eq!((qkv.l, qkv.o, qkv.i), (197, 2304, 768));
        let fc2 = m.layers.iter().find(|l| l.name == "blk0.fc2").unwrap();
        assert_eq!((fc2.l, fc2.o, fc2.i), (197, 768, 3072));
    }

    #[test]
    fn table6_has_16_rows() {
        assert_eq!(table6_layers().len(), 16);
    }

    #[test]
    fn efficientformer_l1_table_d_row() {
        // Appendix D cites stages.3.fc2-like dims (49, 448, 1792)
        let m = efficientformer_l1();
        let fc2 = m.layers.iter().find(|l| l.name == "stages.3.0.fc2").unwrap();
        assert_eq!((fc2.l, fc2.o, fc2.i), (49, 448, 1792));
    }
}
