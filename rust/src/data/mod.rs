//! Synthetic datasets — the substitution for CIFAR/ImageNet/VOC/MRPC/
//! Alpaca (DESIGN.md §Substitutions). Two families:
//!
//!   * vision: per-class gaussian "prototype patch grids" + noise; the
//!     class structure lives in low-frequency content (like natural
//!     images), which is what makes HLA-vs-quantization sensitivity
//!     behave the way the paper reports.
//!   * lm: class-conditioned markov chains over a small vocab (a causal
//!     model can reduce perplexity by learning transition structure).
//!
//! Deterministic per (seed, split): train/eval never overlap.

use crate::runtime::value::Value;
use crate::util::prng::Pcg32;

#[derive(Debug, Clone)]
pub struct VisionDataset {
    pub seq: usize,
    pub in_dim: usize,
    pub n_classes: usize,
    /// per-class prototype, (seq * in_dim)
    prototypes: Vec<Vec<f32>>,
    pub noise: f32,
    seed: u64,
}

impl VisionDataset {
    pub fn new(seq: usize, in_dim: usize, n_classes: usize, seed: u64) -> Self {
        let mut rng = Pcg32::new(seed, 0x11);
        let mut prototypes = Vec::with_capacity(n_classes);
        for _ in 0..n_classes {
            // low-frequency prototypes: random coarse pattern, smoothed
            // along the sequence axis so class evidence is low-pass
            let mut proto = vec![0.0f32; seq * in_dim];
            let coarse: Vec<f32> = (0..(seq / 4 + 1) * in_dim)
                .map(|_| rng.normal() * 1.5)
                .collect();
            for t in 0..seq {
                for d in 0..in_dim {
                    let c0 = coarse[(t / 4) * in_dim + d];
                    let c1 = coarse[(t / 4 + 1).min(seq / 4) * in_dim + d];
                    let frac = (t % 4) as f32 / 4.0;
                    proto[t * in_dim + d] = c0 * (1.0 - frac) + c1 * frac;
                }
            }
            prototypes.push(proto);
        }
        VisionDataset { seq, in_dim, n_classes, prototypes, noise: 0.5, seed }
    }

    /// Same dataset with a different noise level (task difficulty knob:
    /// benches use harder settings so method quality separates).
    pub fn with_noise(mut self, noise: f32) -> Self {
        self.noise = noise;
        self
    }

    /// Batch `index` of `split` (0 = train, 1 = eval): (x, y) Values with
    /// shapes (b, seq, in_dim) f32 and (b,) i32.
    pub fn batch(&self, split: u64, index: u64, batch: usize) -> (Value, Value) {
        let mut rng = Pcg32::new(
            self.seed ^ (split.wrapping_mul(0x9e37_79b9_7f4a_7c15)),
            0x100 + index,
        );
        let mut x = vec![0.0f32; batch * self.seq * self.in_dim];
        let mut y = vec![0i32; batch];
        let n = self.seq * self.in_dim;
        for b in 0..batch {
            let cls = rng.below(self.n_classes as u32) as usize;
            y[b] = cls as i32;
            let proto = &self.prototypes[cls];
            for j in 0..n {
                x[b * n + j] = proto[j] + self.noise * rng.normal();
            }
        }
        (
            Value::F32 { shape: vec![batch, self.seq, self.in_dim], data: x },
            Value::I32 { shape: vec![batch], data: y },
        )
    }

    /// Variant with an injected token-level outlier (drives the Fig-6/9
    /// outlier experiments): token `tok` scaled by `gain` on every sample.
    pub fn batch_with_outlier(&self, split: u64, index: u64, batch: usize,
                              tok: usize, gain: f32) -> (Value, Value) {
        let (mut x, y) = self.batch(split, index, batch);
        if let Value::F32 { ref mut data, .. } = x {
            let n = self.seq * self.in_dim;
            for b in 0..batch {
                for d in 0..self.in_dim {
                    data[b * n + tok * self.in_dim + d] *= gain;
                }
            }
        }
        (x, y)
    }
}

#[derive(Debug, Clone)]
pub struct LmDataset {
    pub seq: usize,
    pub vocab: usize,
    /// row-stochastic transition matrix (vocab x vocab), shared; the
    /// learnable signal.
    trans_cdf: Vec<f32>,
    seed: u64,
}

impl LmDataset {
    pub fn new(seq: usize, vocab: usize, seed: u64) -> Self {
        let mut rng = Pcg32::new(seed, 0x22);
        // sparse-ish peaked transitions: each token strongly prefers a
        // few successors (gives a causal LM something to learn)
        let mut cdf = vec![0.0f32; vocab * vocab];
        for t in 0..vocab {
            let mut probs = vec![0.0f32; vocab];
            for p in probs.iter_mut() {
                *p = 0.05 + rng.uniform();
            }
            // boost 3 preferred successors
            for _ in 0..3 {
                probs[rng.below(vocab as u32) as usize] += 5.0 * rng.uniform();
            }
            let total: f32 = probs.iter().sum();
            let mut acc = 0.0;
            for v in 0..vocab {
                acc += probs[v] / total;
                cdf[t * vocab + v] = acc;
            }
        }
        LmDataset { seq, vocab, trans_cdf: cdf, seed }
    }

    /// (x, y): x (b, seq) i32 tokens, y (b, seq) i32 next-token labels.
    pub fn batch(&self, split: u64, index: u64, batch: usize) -> (Value, Value) {
        let mut rng = Pcg32::new(
            self.seed ^ (split.wrapping_mul(0xbf58_476d_1ce4_e5b9)),
            0x200 + index,
        );
        let mut x = vec![0i32; batch * self.seq];
        let mut y = vec![0i32; batch * self.seq];
        for b in 0..batch {
            let mut tok = rng.below(self.vocab as u32) as usize;
            for t in 0..self.seq {
                x[b * self.seq + t] = tok as i32;
                let u = rng.uniform();
                let row = &self.trans_cdf[tok * self.vocab..(tok + 1) * self.vocab];
                let next = row.iter().position(|&c| u <= c).unwrap_or(self.vocab - 1);
                y[b * self.seq + t] = next as i32;
                tok = next;
            }
        }
        (
            Value::I32 { shape: vec![batch, self.seq], data: x },
            Value::I32 { shape: vec![batch, self.seq], data: y },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vision_shapes_and_labels() {
        let ds = VisionDataset::new(32, 48, 16, 0);
        let (x, y) = ds.batch(0, 0, 8);
        assert_eq!(x.shape(), &[8, 32, 48]);
        assert_eq!(y.shape(), &[8]);
        if let Value::I32 { data, .. } = y {
            assert!(data.iter().all(|&c| (0..16).contains(&c)));
        } else {
            panic!("labels must be i32");
        }
    }

    #[test]
    fn vision_deterministic_and_split_disjoint() {
        let ds = VisionDataset::new(16, 16, 4, 7);
        let (a1, _) = ds.batch(0, 3, 4);
        let (a2, _) = ds.batch(0, 3, 4);
        assert_eq!(a1.as_f32().unwrap(), a2.as_f32().unwrap());
        let (b, _) = ds.batch(1, 3, 4);
        assert_ne!(a1.as_f32().unwrap(), b.as_f32().unwrap());
    }

    #[test]
    fn vision_classes_separable() {
        // prototype distance >> noise: nearest-prototype classification
        // on clean prototypes should be perfect
        let ds = VisionDataset::new(16, 16, 4, 1);
        let (x, y) = ds.batch(0, 0, 32);
        let xd = x.as_f32().unwrap();
        let n = 16 * 16;
        if let Value::I32 { data: yd, .. } = y {
            let mut correct = 0;
            for b in 0..32 {
                let sample = &xd[b * n..(b + 1) * n];
                let best = (0..4)
                    .min_by(|&a, &c| {
                        let da: f32 = ds.prototypes[a].iter().zip(sample)
                            .map(|(p, s)| (p - s) * (p - s)).sum();
                        let dc: f32 = ds.prototypes[c].iter().zip(sample)
                            .map(|(p, s)| (p - s) * (p - s)).sum();
                        da.partial_cmp(&dc).unwrap()
                    })
                    .unwrap();
                if best as i32 == yd[b] {
                    correct += 1;
                }
            }
            assert!(correct >= 30, "{correct}/32");
        }
    }

    #[test]
    fn outlier_injection() {
        let ds = VisionDataset::new(16, 8, 4, 2);
        let (x0, _) = ds.batch(0, 0, 2);
        let (x1, _) = ds.batch_with_outlier(0, 0, 2, 5, 30.0);
        let a = x0.as_f32().unwrap();
        let b = x1.as_f32().unwrap();
        let _n = 16 * 8;
        // token 5 amplified, others identical
        assert_eq!(a[0], b[0]);
        let off = 5 * 8;
        assert!((b[off] - 30.0 * a[off]).abs() < 1e-4);
    }

    #[test]
    fn lm_tokens_in_vocab() {
        let ds = LmDataset::new(32, 128, 3);
        let (x, y) = ds.batch(0, 0, 4);
        for v in [&x, &y] {
            if let Value::I32 { data, .. } = v {
                assert!(data.iter().all(|&t| (0..128).contains(&t)));
            }
        }
    }

    #[test]
    fn lm_labels_are_next_tokens() {
        let ds = LmDataset::new(16, 32, 4);
        let (x, y) = ds.batch(0, 0, 2);
        if let (Value::I32 { data: xd, .. }, Value::I32 { data: yd, .. }) = (x, y) {
            // y[t] == x[t+1] within each sequence
            for b in 0..2 {
                for t in 0..15 {
                    assert_eq!(yd[b * 16 + t], xd[b * 16 + t + 1]);
                }
            }
        }
    }

    #[test]
    fn lm_transitions_learnable() {
        // empirical transition entropy must be far below uniform
        let ds = LmDataset::new(64, 16, 5);
        let (x, _) = ds.batch(0, 0, 64);
        if let Value::I32 { data, .. } = x {
            let mut counts = vec![0u32; 16 * 16];
            for b in 0..64 {
                for t in 0..63 {
                    let a = data[b * 64 + t] as usize;
                    let c = data[b * 64 + t + 1] as usize;
                    counts[a * 16 + c] += 1;
                }
            }
            let mut h = 0.0f64;
            let total: u32 = counts.iter().sum();
            for &c in &counts {
                if c > 0 {
                    let p = c as f64 / total as f64;
                    h -= p * p.log2();
                }
            }
            // uniform over 256 pairs would be 8 bits
            assert!(h < 7.5, "joint entropy {h}");
        }
    }
}
