//! Fast Walsh-Hadamard transform, order-16 block-diagonal (natural order,
//! normalized by 1/4 so the transform is orthonormal and involutive).
//!
//! `fwht_inplace` is the scalar reference semantics; the block
//! transforms below route through `kernels::`, which runs the same
//! butterfly network on the active SIMD tier (`kernels::dispatch`).
//! Every tier executes the identical add/sub/mul sequence per element,
//! so the transform is bit-identical no matter which tier ran it — a
//! hard requirement, because the pseudo-stochastic quantizer keys off
//! the transformed values' mantissa bits.

pub const BLOCK: usize = 16;
pub const NORM: f32 = 0.25; // 1/sqrt(16)

/// In-place FWHT of one 16-element tile (butterflies, natural order).
#[inline]
pub fn fwht_inplace(v: &mut [f32; BLOCK]) {
    let mut size = 1;
    while size < BLOCK {
        let stride = size * 2;
        let mut base = 0;
        while base < BLOCK {
            for i in base..base + size {
                let a = v[i];
                let b = v[i + size];
                v[i] = a + b;
                v[i + size] = a - b;
            }
            base += stride;
        }
        size = stride;
    }
    for x in v.iter_mut() {
        *x *= NORM;
    }
}

/// The normalized 16x16 Sylvester Walsh matrix (row-major).
pub fn hadamard_matrix() -> [[f32; BLOCK]; BLOCK] {
    let mut h = [[0.0f32; BLOCK]; BLOCK];
    for (i, row) in h.iter_mut().enumerate() {
        for (j, v) in row.iter_mut().enumerate() {
            // H[i][j] = (-1)^{popcount(i & j)} / 4
            *v = if (i & j).count_ones() % 2 == 0 { NORM } else { -NORM };
        }
    }
    h
}

/// Block-FWHT along the *last* axis of a row-major (rows, cols) matrix,
/// cols % 16 == 0. Matches `hadamard.block_ht(x, axis=1)` /
/// `kernels.fwht.block_fwht`. Routed through the blocked/threaded/SIMD
/// kernel subsystem (bit-identical to tile-by-tile `fwht_inplace` at
/// every tier).
pub fn block_fwht_rows(x: &mut [f32], rows: usize, cols: usize) {
    crate::kernels::fwht_rows(x, rows, cols);
}

/// Block-FWHT along axis 0 (column direction) of a (rows, cols) matrix.
/// Routed through `kernels::fwht_cols` (strip-mined gather instead of
/// a full-matrix stride per column).
pub fn block_fwht_cols(x: &mut [f32], rows: usize, cols: usize) {
    crate::kernels::fwht_cols(x, rows, cols);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    #[test]
    fn involution() {
        let mut r = Pcg32::seeded(1);
        let mut v = [0.0f32; BLOCK];
        for x in v.iter_mut() {
            *x = r.normal();
        }
        let orig = v;
        fwht_inplace(&mut v);
        fwht_inplace(&mut v);
        for (a, b) in v.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn matches_matrix_form() {
        let mut r = Pcg32::seeded(2);
        let mut v = [0.0f32; BLOCK];
        for x in v.iter_mut() {
            *x = r.normal();
        }
        let h = hadamard_matrix();
        let want: Vec<f32> = (0..BLOCK)
            .map(|i| (0..BLOCK).map(|j| h[i][j] * v[j]).sum())
            .collect();
        fwht_inplace(&mut v);
        for (a, b) in v.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn matrix_orthonormal() {
        let h = hadamard_matrix();
        for i in 0..BLOCK {
            for j in 0..BLOCK {
                let dot: f32 = (0..BLOCK).map(|k| h[i][k] * h[j][k]).sum();
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn block_rows_energy() {
        let mut r = Pcg32::seeded(3);
        let (rows, cols) = (4, 48);
        let mut x: Vec<f32> = (0..rows * cols).map(|_| r.normal()).collect();
        let e0: f32 = x.iter().map(|v| v * v).sum();
        block_fwht_rows(&mut x, rows, cols);
        let e1: f32 = x.iter().map(|v| v * v).sum();
        assert!((e0 - e1).abs() / e0 < 1e-5);
    }

    #[test]
    fn rows_cols_consistent() {
        // transform along axis0 == transpose . axis1 . transpose
        let mut r = Pcg32::seeded(4);
        let (rows, cols) = (32, 3);
        let x: Vec<f32> = (0..rows * cols).map(|_| r.normal()).collect();
        let mut a = x.clone();
        block_fwht_cols(&mut a, rows, cols);
        // manual transpose path
        let mut xt = vec![0.0f32; rows * cols];
        for i in 0..rows {
            for j in 0..cols {
                xt[j * rows + i] = x[i * cols + j];
            }
        }
        block_fwht_rows(&mut xt, cols, rows);
        for i in 0..rows {
            for j in 0..cols {
                assert!((a[i * cols + j] - xt[j * rows + i]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn prop_involution_any_shape() {
        crate::util::proptest::check("fwht involution", 25, |case| {
            let rows = case.usize_in(1, 6);
            let tiles = case.usize_in(1, 4);
            let cols = tiles * BLOCK;
            let orig = case.f32_vec(rows * cols, 2.0);
            let mut x = orig.clone();
            block_fwht_rows(&mut x, rows, cols);
            block_fwht_rows(&mut x, rows, cols);
            for (a, b) in x.iter().zip(&orig) {
                if (a - b).abs() > 1e-4 {
                    return Err(format!("{a} != {b}"));
                }
            }
            Ok(())
        });
    }
}
