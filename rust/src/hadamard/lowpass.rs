//! Low-pass basis selection: 1-D sequency order and LBP-WHT's LP_L1
//! criterion for 2-D (4x4) image tiles. Mirrors python hadamard.py.

use super::fwht::BLOCK;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Criterion {
    /// 1-D sequency (sign-change count) order — transformer L dims.
    Sequency,
    /// LBP-WHT LP_L1 over a 4x4 spatial tile — image-patch L dims.
    LpL1,
}

impl Criterion {
    pub fn parse(s: &str) -> Option<Criterion> {
        match s {
            "sequency" => Some(Criterion::Sequency),
            "lp_l1" => Some(Criterion::LpL1),
            _ => None,
        }
    }
}

/// Sign-change count of natural-order Walsh row `i` (order n=16):
/// row entries are (-1)^{popcount(i & j)} over j.
fn sequency_of(i: usize, n: usize) -> usize {
    let mut changes = 0;
    let mut prev = (i & 0).count_ones() % 2;
    for j in 1..n {
        let cur = (i & j).count_ones() % 2;
        if cur != prev {
            changes += 1;
        }
        prev = cur;
    }
    changes
}

/// Permutation mapping sequency rank -> natural row index (n must be a
/// power of two; we only ever use 4 and 16).
pub fn sequency_order(n: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by_key(|&i| sequency_of(i, n));
    idx
}

/// LP_L1 ordering for a (bh x bw) 2-D basis: natural flat indices sorted
/// by (row-sequency + col-sequency, row-seq, col-seq).
pub fn lp_l1_order_2d(bh: usize, bw: usize) -> Vec<usize> {
    let sv: Vec<usize> = {
        let ord = sequency_order(bh);
        let mut inv = vec![0; bh];
        for (rank, &nat) in ord.iter().enumerate() {
            inv[nat] = rank;
        }
        inv
    };
    let sh: Vec<usize> = {
        let ord = sequency_order(bw);
        let mut inv = vec![0; bw];
        for (rank, &nat) in ord.iter().enumerate() {
            inv[nat] = rank;
        }
        inv
    };
    let mut keys: Vec<(usize, usize, usize, usize)> = Vec::new();
    for r in 0..bh {
        for c in 0..bw {
            keys.push((sv[r] + sh[c], sv[r], sh[c], r * bw + c));
        }
    }
    keys.sort();
    keys.into_iter().map(|k| k.3).collect()
}

/// Natural-order indices of the `rank` lowest-frequency components of an
/// order-16 tile under the given criterion.
pub fn lowpass_indices(rank: usize, criterion: Criterion) -> Vec<usize> {
    assert!(rank >= 1 && rank <= BLOCK);
    match criterion {
        Criterion::Sequency => sequency_order(BLOCK)[..rank].to_vec(),
        Criterion::LpL1 => lp_l1_order_2d(4, 4)[..rank].to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hadamard::fwht::hadamard_matrix;

    #[test]
    fn sequency_is_permutation() {
        for n in [4, 16] {
            let mut o = sequency_order(n);
            o.sort_unstable();
            assert_eq!(o, (0..n).collect::<Vec<_>>());
        }
    }

    #[test]
    fn sequency_monotone() {
        let ord = sequency_order(16);
        let seqs: Vec<usize> = ord.iter().map(|&i| sequency_of(i, 16)).collect();
        for w in seqs.windows(2) {
            assert!(w[0] <= w[1]);
        }
        assert_eq!(seqs[0], 0); // DC row first
        assert_eq!(ord[0], 0);
    }

    #[test]
    fn sequency_matches_matrix_sign_changes() {
        let h = hadamard_matrix();
        for i in 0..16 {
            let mut changes = 0;
            for j in 1..16 {
                if (h[i][j] > 0.0) != (h[i][j - 1] > 0.0) {
                    changes += 1;
                }
            }
            assert_eq!(changes, sequency_of(i, 16), "row {}", i);
        }
    }

    #[test]
    fn lp_l1_permutation_and_dc() {
        let mut o = lp_l1_order_2d(4, 4);
        assert_eq!(o[0], 0);
        o.sort_unstable();
        assert_eq!(o, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn lowpass_prefix_property() {
        let full = lowpass_indices(16, Criterion::Sequency);
        for r in [1, 2, 4, 8] {
            assert_eq!(lowpass_indices(r, Criterion::Sequency), full[..r]);
        }
    }

    #[test]
    fn criterion_parse() {
        assert_eq!(Criterion::parse("sequency"), Some(Criterion::Sequency));
        assert_eq!(Criterion::parse("lp_l1"), Some(Criterion::LpL1));
        assert_eq!(Criterion::parse("nope"), None);
    }
}
