//! Rust-native Walsh-Hadamard machinery: FWHT, sequency / LP_L1 orders,
//! block HLA projection — the host-side mirror of python/compile/hadamard.py.
//!
//! Used by the coordinator to verify/repack ABC buffers, by integration
//! tests to cross-check artifact outputs, and by the latency simulator's
//! op model. Semantics match the L1 kernels bit-for-bit where exactness
//! is possible (FWHT is adds/subs only — exact in f32 for our ranges).

pub mod fwht;
pub mod lowpass;

pub use fwht::{block_fwht_rows, fwht_inplace, BLOCK};
pub use lowpass::{lowpass_indices, lp_l1_order_2d, sequency_order};

/// Block-HLA projection along axis 0 of a row-major (rows, cols) matrix:
/// (rows, cols) -> (rows/BLOCK*rank, cols). Mirrors
/// `hadamard.block_hla(x, rank, axis=0)`.
pub fn block_hla_axis0(x: &[f32], rows: usize, cols: usize, rank: usize,
                       criterion: lowpass::Criterion) -> Vec<f32> {
    assert_eq!(rows % BLOCK, 0, "rows must tile into {}", BLOCK);
    assert!(rank >= 1 && rank <= BLOCK);
    let sel = lowpass_indices(rank, criterion);
    let h = fwht::hadamard_matrix();
    let tiles = rows / BLOCK;
    let mut out = vec![0.0f32; tiles * rank * cols];
    for t in 0..tiles {
        for (ri, &nat) in sel.iter().enumerate() {
            let hrow = &h[nat];
            let dst_row = t * rank + ri;
            for c in 0..cols {
                let mut acc = 0.0f32;
                for b in 0..BLOCK {
                    acc += hrow[b] * x[(t * BLOCK + b) * cols + c];
                }
                out[dst_row * cols + c] = acc;
            }
        }
    }
    out
}

/// Adjoint of `block_hla_axis0` (external-HLA expansion).
pub fn block_hla_expand_axis0(x: &[f32], rows_c: usize, cols: usize,
                              rank: usize, criterion: lowpass::Criterion)
                              -> Vec<f32> {
    assert_eq!(rows_c % rank, 0);
    let sel = lowpass_indices(rank, criterion);
    let h = fwht::hadamard_matrix();
    let tiles = rows_c / rank;
    let mut out = vec![0.0f32; tiles * BLOCK * cols];
    for t in 0..tiles {
        for (ri, &nat) in sel.iter().enumerate() {
            let hrow = &h[nat];
            for b in 0..BLOCK {
                let w = hrow[b];
                let dst = (t * BLOCK + b) * cols;
                let src = (t * rank + ri) * cols;
                for c in 0..cols {
                    out[dst + c] += w * x[src + c];
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;
    use lowpass::Criterion;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Pcg32::seeded(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    #[test]
    fn hla_full_rank_preserves_energy() {
        let x = randv(32 * 4, 1);
        let c = block_hla_axis0(&x, 32, 4, 16, Criterion::Sequency);
        let e0: f32 = x.iter().map(|v| v * v).sum();
        let e1: f32 = c.iter().map(|v| v * v).sum();
        assert!((e0 - e1).abs() / e0 < 1e-5);
    }

    #[test]
    fn hla_shapes() {
        let x = randv(64 * 3, 2);
        for r in [1, 2, 4, 8] {
            let c = block_hla_axis0(&x, 64, 3, r, Criterion::Sequency);
            assert_eq!(c.len(), 64 / 16 * r * 3);
        }
    }

    #[test]
    fn expand_compress_projection() {
        // compress(expand(c)) == c (rows of H-hat are orthonormal)
        let x = randv(32 * 2, 3);
        let c = block_hla_axis0(&x, 32, 2, 8, Criterion::Sequency);
        let e = block_hla_expand_axis0(&c, 16, 2, 8, Criterion::Sequency);
        let c2 = block_hla_axis0(&e, 32, 2, 8, Criterion::Sequency);
        for (a, b) in c.iter().zip(&c2) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn dc_signal_exact_at_rank_1() {
        let x = vec![3.0f32; 32 * 2];
        let c = block_hla_axis0(&x, 32, 2, 1, Criterion::Sequency);
        let e = block_hla_expand_axis0(&c, 2, 2, 1, Criterion::Sequency);
        for (a, b) in e.iter().zip(&x) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn prop_expand_is_exact_adjoint_of_compress() {
        // ⟨P x, y⟩ == ⟨x, Pᵀ y⟩ for P = block_hla_axis0 and
        // Pᵀ = block_hla_expand_axis0, over random shapes/ranks/criteria
        crate::util::proptest::check("hla adjoint", 30, |case| {
            let tiles = case.usize_in(1, 3);
            let cols = case.usize_in(1, 6);
            let rank = case.usize_in(1, BLOCK);
            let rows = tiles * BLOCK;
            let crit = *case.choice(&[Criterion::Sequency, Criterion::LpL1]);
            let x = case.f32_vec(rows * cols, 1.0);
            let y = case.f32_vec(tiles * rank * cols, 1.0);
            let px = block_hla_axis0(&x, rows, cols, rank, crit);
            let pty = block_hla_expand_axis0(&y, tiles * rank, cols, rank,
                                             crit);
            let lhs: f32 = px.iter().zip(&y).map(|(a, b)| a * b).sum();
            let rhs: f32 = x.iter().zip(&pty).map(|(a, b)| a * b).sum();
            let scale = lhs.abs().max(rhs.abs()).max(1.0);
            if (lhs - rhs).abs() / scale < 1e-4 {
                Ok(())
            } else {
                Err(format!("⟨Px,y⟩={lhs} != ⟨x,Pᵀy⟩={rhs} \
                             (tiles={tiles} cols={cols} rank={rank})"))
            }
        });
    }

    #[test]
    fn prop_hla_error_monotone_in_rank() {
        crate::util::proptest::check("hla error monotone", 20, |case| {
            let tiles = case.usize_in(1, 3);
            let cols = case.usize_in(1, 5);
            let rows = tiles * BLOCK;
            let x = case.f32_vec(rows * cols, 1.0);
            let err = |r: usize| {
                let c = block_hla_axis0(&x, rows, cols, r, Criterion::Sequency);
                let e = block_hla_expand_axis0(&c, tiles * r, cols, r,
                                               Criterion::Sequency);
                x.iter().zip(&e).map(|(a, b)| (a - b) * (a - b)).sum::<f32>()
            };
            let (e4, e16) = (err(4), err(16));
            if e16 <= e4 + 1e-4 {
                Ok(())
            } else {
                Err(format!("rank-16 err {} > rank-4 err {}", e16, e4))
            }
        });
    }
}
