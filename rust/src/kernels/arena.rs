//! Thread-local, grow-only packing/scratch arenas.
//!
//! The GEMM packers used to allocate their panel buffers on every call
//! (`vec![0; strips * k * NR]` per GEMM, one lhs panel per row-task) and
//! the fused FWHT epilogues cloned their input into a fresh transform
//! buffer. In the training loop those are the same handful of shapes
//! thousands of times over — pure allocator churn on the hottest paths.
//!
//! An arena here is one `Vec` per (thread, slot): callers borrow it for
//! the duration of one kernel call via `with_f32`/`with_i8`, resize it
//! to the shape they need (capacity only ever grows) and hand it back.
//! Pool workers are long-lived threads, so after the first step at a
//! given shape every steady-state kernel call packs into memory that is
//! already there.
//!
//! Lifetime rules (also in DESIGN.md §Kernels):
//!
//!   * a slot is borrowed for at most one kernel *call* — it must never
//!     be held across a call into another kernel entry point that could
//!     reuse the same slot (the slots below are disjoint per use site:
//!     rhs-pack, lhs-pack, fused transform, quant row);
//!   * the take-and-put-back protocol makes accidental re-entry safe
//!     rather than unsound: the inner borrower just sees an empty vec
//!     and allocates (the grow counter makes such a bug visible);
//!   * arenas die with their thread; pool workers live for the process,
//!     so their arenas are bounded by the largest shape each worker
//!     ever packed.
//!
//! `grow_count()` counts capacity growth on the *current thread* — the
//! no-alloc-after-warmup contract is asserted by a serial test that
//! pins the thread budget to 1 so all packing happens on one thread.

use std::cell::Cell;

/// Right-hand-side pack buffer (one per GEMM call, caller thread).
pub(crate) const RHS: usize = 0;
/// Left-hand-side panel buffer (one per row-task, worker threads too).
pub(crate) const LHS: usize = 1;
/// Fused-epilogue transform scratch (`fwht_quant_*`).
pub(crate) const FUSED: usize = 2;
const F32_SLOTS: usize = 3;

/// Integer rhs pack buffer.
pub(crate) const I_RHS: usize = 0;
/// Integer lhs panel buffer.
pub(crate) const I_LHS: usize = 1;
/// Per-row quantize scratch (`quant_pack_rows`).
pub(crate) const QROW: usize = 2;
const I8_SLOTS: usize = 3;

thread_local! {
    static F32_ARENA: [Cell<Vec<f32>>; F32_SLOTS] =
        [Cell::new(Vec::new()), Cell::new(Vec::new()), Cell::new(Vec::new())];
    static I8_ARENA: [Cell<Vec<i8>>; I8_SLOTS] =
        [Cell::new(Vec::new()), Cell::new(Vec::new()), Cell::new(Vec::new())];
    static GROWS: Cell<usize> = Cell::new(0);
}

/// Capacity-growth events observed on this thread (monotonic). Stable
/// across repeated kernel calls at already-seen shapes — the
/// no-allocation-after-warmup contract.
pub fn grow_count() -> usize {
    GROWS.with(|g| g.get())
}

fn track<T, R>(cell: &Cell<Vec<T>>, f: impl FnOnce(&mut Vec<T>) -> R) -> R {
    let mut v = cell.take();
    let cap0 = v.capacity();
    let r = f(&mut v);
    if v.capacity() > cap0 {
        GROWS.with(|g| g.set(g.get() + 1));
        crate::obs::count(crate::obs::Counter::ArenaGrows, 1);
    }
    cell.set(v);
    r
}

/// Borrow this thread's f32 arena `slot` for the duration of `f`.
pub(crate) fn with_f32<R>(slot: usize, f: impl FnOnce(&mut Vec<f32>) -> R)
                          -> R {
    F32_ARENA.with(|a| track(&a[slot], f))
}

/// Borrow this thread's i8 arena `slot` for the duration of `f`.
pub(crate) fn with_i8<R>(slot: usize, f: impl FnOnce(&mut Vec<i8>) -> R)
                         -> R {
    I8_ARENA.with(|a| track(&a[slot], f))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuse_does_not_grow() {
        // warm a private slot shape, then re-borrowing at the same (or
        // a smaller) size must not move the counter
        with_f32(FUSED, |v| {
            v.clear();
            v.resize(1024, 0.0);
        });
        let g0 = grow_count();
        for round in 0..5 {
            with_f32(FUSED, |v| {
                v.clear();
                v.resize(1024 - round, 0.0);
                v[0] = round as f32;
            });
        }
        assert_eq!(grow_count(), g0, "steady-state reuse must not grow");
        with_f32(FUSED, |v| {
            v.clear();
            v.resize(4096, 0.0);
        });
        assert!(grow_count() > g0, "a larger shape must register a grow");
    }

    #[test]
    fn reentry_is_safe_and_isolated() {
        // the take-and-put-back protocol: an (illegal but possible)
        // nested borrow of the same slot sees an empty vec, not an
        // aliased one
        with_i8(QROW, |outer| {
            outer.clear();
            outer.resize(8, 3);
            with_i8(QROW, |inner| {
                assert!(inner.is_empty(), "nested borrow must not alias");
                inner.push(1);
            });
            assert_eq!(outer.len(), 8);
            assert!(outer.iter().all(|&v| v == 3));
        });
    }
}
