//! Per-shape dispatch cache: how many parallel tasks a GEMM of a given
//! shape should fan out to.
//!
//! The decision is cheap but not free (a few branches plus a
//! `num_threads` load), and the training loop replays the same handful
//! of shapes thousands of times, so plans are memoized by
//! `(n, k, m, element, thread budget)`. Including the budget in the key
//! means `set_num_threads` never needs to invalidate anything — a new
//! budget simply populates new entries.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

use crate::kernels::pool;

/// Element family of the kernel being planned (f32 and i8 have
/// different arithmetic density, so they get separate entries).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Elem {
    F32,
    I8,
}

/// A resolved execution plan for one GEMM shape.
#[derive(Debug, Clone, Copy)]
pub struct Plan {
    /// Row-chunk tasks to fan out to (1 = stay on the calling thread).
    pub tasks: usize,
}

/// Below this many multiply-accumulates a fork costs more than it buys.
const PAR_MAC_FLOOR: usize = 1 << 18;

/// Target rows per parallel task (a multiple of the microkernel MR).
const TASK_ROWS: usize = 48;

type Key = (usize, usize, usize, Elem, usize);

fn cache() -> &'static Mutex<HashMap<Key, Plan>> {
    static CACHE: OnceLock<Mutex<HashMap<Key, Plan>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Plan a (n, k) x (k, m) GEMM under the current thread budget.
pub fn plan(n: usize, k: usize, m: usize, elem: Elem) -> Plan {
    let width = pool::num_threads();
    let key = (n, k, m, elem, width);
    if let Some(p) = cache().lock().unwrap().get(&key) {
        return *p;
    }
    let macs = n.saturating_mul(k).saturating_mul(m);
    let tasks = if width <= 1 || macs < PAR_MAC_FLOOR || n < 2 {
        1
    } else {
        // more tasks than threads so the stealing cursor can balance
        // uneven chunks, but no thinner than TASK_ROWS rows each
        n.div_ceil(TASK_ROWS).min(width * 4)
    }
    .max(1);
    let p = Plan { tasks };
    cache().lock().unwrap().insert(key, p);
    p
}

/// Number of memoized plans (diagnostics / tests).
pub fn cached_plans() -> usize {
    cache().lock().unwrap().len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_shapes_stay_serial() {
        assert_eq!(plan(4, 4, 4, Elem::F32).tasks, 1);
        assert_eq!(plan(1, 512, 512, Elem::I8).tasks, 1);
    }

    #[test]
    fn large_shapes_fan_out_under_a_budget() {
        let _gate = pool::test_serial();
        pool::set_num_threads(4);
        let p = plan(1024, 256, 256, Elem::F32);
        assert!(p.tasks > 1, "expected a parallel plan, got {}", p.tasks);
        assert!(p.tasks <= 16);
        pool::set_num_threads(1);
        assert_eq!(plan(1024, 256, 256, Elem::F32).tasks, 1);
        pool::set_num_threads(0);
    }

    #[test]
    fn plans_are_memoized() {
        // other tests insert plans concurrently, so only per-key
        // stability is assertable here
        let p1 = plan(77, 33, 11, Elem::F32);
        let p2 = plan(77, 33, 11, Elem::F32);
        assert_eq!(p1.tasks, p2.tasks);
        assert!(cached_plans() >= 1);
    }
}
