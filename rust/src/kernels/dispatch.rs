//! Kernel dispatch: which ISA tier and how many parallel tasks a kernel
//! of a given shape should run with.
//!
//! Two decisions live here:
//!
//!   * **ISA tier** — a process-global `CpuCaps` probe (run once) detects
//!     AVX2+FMA on x86_64 / NEON on aarch64, and `active_tier()` maps
//!     that to the best available microkernel family in `kernels::simd`.
//!     The probe honors two overrides: the `HOT_SIMD=0` environment
//!     variable (read once, hard-disables SIMD for the process — the CI
//!     scalar-fallback leg) and the runtime `set_simd_enabled` knob
//!     (`NativeBackend::with_simd`). The scalar kernels are always the
//!     fallback, and `kernels::reference` stays the correctness oracle
//!     for every tier.
//!   * **fan-out** — how many row-chunk tasks a GEMM forks into, as
//!     before.
//!
//! Both are cheap but not free, and the training loop replays the same
//! handful of shapes thousands of times, so resolved plans are memoized
//! by `(n, k, m, element, thread budget, active tier)`. Including the
//! budget and tier in the key means neither `set_num_threads` nor
//! `set_simd_enabled` ever needs to invalidate anything — a new setting
//! simply populates new entries.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::kernels::pool;
use crate::obs;

/// Element family of the kernel being planned (f32 and i8 have
/// different arithmetic density, so they get separate entries).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Elem {
    F32,
    I8,
}

/// Instruction-set tier a kernel executes at. `Scalar` is the portable
/// fallback and always available; the SIMD tiers are selected only when
/// the one-time `CpuCaps` probe proved the ISA present, so every unsafe
/// intrinsic block in `kernels::simd` runs behind this safe gate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Tier {
    Scalar,
    /// x86_64 with AVX2 and FMA (both probed — FMA-less AVX2 parts
    /// exist and would fault on the f32 microkernel).
    Avx2,
    /// aarch64; NEON is architecturally mandatory there.
    Neon,
}

impl Tier {
    /// Display name (bench JSON, diagnostics).
    pub fn name(self) -> &'static str {
        match self {
            Tier::Scalar => "scalar",
            Tier::Avx2 => "avx2",
            Tier::Neon => "neon",
        }
    }
}

/// One-time CPU capability probe.
#[derive(Debug, Clone, Copy)]
pub struct CpuCaps {
    pub avx2: bool,
    pub neon: bool,
    /// `HOT_SIMD=0` (or `off` / `scalar`) was set when the process
    /// first touched the kernels: SIMD is hard-disabled.
    pub env_off: bool,
}

/// The process-global capability probe (memoized on first use).
pub fn caps() -> CpuCaps {
    static CAPS: OnceLock<CpuCaps> = OnceLock::new();
    *CAPS.get_or_init(|| {
        let env_off = matches!(std::env::var("HOT_SIMD").as_deref(),
                               Ok("0") | Ok("off") | Ok("scalar"));
        #[cfg(target_arch = "x86_64")]
        let avx2 = std::is_x86_feature_detected!("avx2")
            && std::is_x86_feature_detected!("fma");
        #[cfg(not(target_arch = "x86_64"))]
        let avx2 = false;
        CpuCaps { avx2, neon: cfg!(target_arch = "aarch64"), env_off }
    })
}

impl CpuCaps {
    /// Stable machine identity for bench baselines: arch, best probed
    /// tier, nominal frequency, and core count — e.g.
    /// `x86_64/avx2+fma/1c@2.10GHz`. Two BenchReports are only
    /// regression-comparable when their fingerprints match (the bench
    /// `compare` degrades to a schema check otherwise).
    pub fn fingerprint(&self) -> String {
        let isa = if self.env_off {
            "scalar(env)"
        } else if self.avx2 {
            "avx2+fma"
        } else if self.neon {
            "neon"
        } else {
            "scalar"
        };
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        match cpu_freq_ghz() {
            Some(f) => format!("{}/{}/{}c@{:.2}GHz",
                               std::env::consts::ARCH, isa, cores, f),
            None => format!("{}/{}/{}c", std::env::consts::ARCH, isa,
                            cores),
        }
    }
}

/// Nominal CPU frequency in GHz for the peak-FLOP/s roofline estimate.
/// `HOT_FREQ_GHZ` overrides; otherwise the linux `/proc/cpuinfo` model
/// string ("... @ 2.10GHz") or, failing that, the live `cpu MHz` field.
/// `None` when nothing is known (non-linux without the env override) —
/// the roofline block then reports no peak rather than inventing one.
pub fn cpu_freq_ghz() -> Option<f64> {
    static FREQ: OnceLock<Option<f64>> = OnceLock::new();
    *FREQ.get_or_init(|| {
        if let Some(f) = std::env::var("HOT_FREQ_GHZ")
            .ok()
            .and_then(|v| v.parse::<f64>().ok())
            .filter(|f| *f > 0.0)
        {
            return Some(f);
        }
        let info = std::fs::read_to_string("/proc/cpuinfo").ok()?;
        // "model name : Intel(R) Xeon(R) Processor @ 2.10GHz"
        for line in info.lines() {
            if let Some(rest) = line.strip_prefix("model name") {
                if let Some(ghz) = rest
                    .rsplit_once('@')
                    .and_then(|(_, s)| s.trim().strip_suffix("GHz"))
                    .and_then(|s| s.trim().parse::<f64>().ok())
                    .filter(|f| *f > 0.0)
                {
                    return Some(ghz);
                }
            }
        }
        for line in info.lines() {
            if let Some(rest) = line.strip_prefix("cpu MHz") {
                if let Some(mhz) = rest
                    .split(':')
                    .nth(1)
                    .and_then(|s| s.trim().parse::<f64>().ok())
                    .filter(|f| *f > 0.0)
                {
                    return Some(mhz / 1e3);
                }
            }
        }
        None
    })
}

/// Peak useful operations per cycle per core for one kernel family at
/// one tier — the FLOP/s numerator of the roofline estimate
/// (frequency × SIMD width × FMA ports, the classic peak model).
///
/// f32: AVX2+FMA sustains two 8-lane FMAs per cycle = 32 FLOP/cycle;
/// NEON two 4-lane FMAs = 16; the scalar tier is modeled at one
/// mul + one add per cycle. i8 ops are counted like the FLOP counters
/// count them (2 ops per MAC): `vpmaddwd`-class widening MACs move
/// 2× the f32 lane count through the same two ports.
pub fn peak_ops_per_cycle(tier: Tier, elem: Elem) -> f64 {
    let f32_ops = match tier {
        Tier::Scalar => 2.0,
        Tier::Avx2 => 32.0,
        Tier::Neon => 16.0,
    };
    match elem {
        Elem::F32 => f32_ops,
        Elem::I8 => match tier {
            Tier::Scalar => f32_ops,
            _ => 2.0 * f32_ops,
        },
    }
}

/// Estimated peak GFLOP/s (or int GOP/s) for `threads` cores at `tier`
/// — `None` when the CPU frequency is unknown. The bench harness'
/// roofline block reports achieved/peak against this.
pub fn peak_gflops(tier: Tier, elem: Elem, threads: usize) -> Option<f64> {
    let f = cpu_freq_ghz()?;
    Some(f * peak_ops_per_cycle(tier, elem) * threads.max(1) as f64)
}

/// Runtime SIMD knob (`NativeBackend::with_simd`); defaults to on.
/// `HOT_SIMD=0` in the environment wins over this.
static SIMD_ON: AtomicBool = AtomicBool::new(true);

/// Enable/disable the SIMD tiers at runtime. Takes effect on the next
/// kernel call (plans are keyed by the effective tier, so no
/// invalidation is needed). The scalar fallback is always kept correct
/// by the same property tests, so flipping this mid-run only changes
/// speed — and, for f32, least-significant-bit rounding (FMA).
pub fn set_simd_enabled(on: bool) {
    SIMD_ON.store(on, Ordering::Relaxed);
}

/// Whether SIMD tiers may be selected right now.
pub fn simd_enabled() -> bool {
    !caps().env_off && SIMD_ON.load(Ordering::Relaxed)
}

/// Best tier the hardware probe allows, ignoring the runtime knob (the
/// `HOT_SIMD` env override still wins). The single caps-to-tier
/// mapping — `active_tier` and the tier parity tests both use it, so
/// adding a tier cannot desynchronize them.
pub(crate) fn probed_tier() -> Tier {
    let c = caps();
    if c.env_off {
        Tier::Scalar
    } else if c.avx2 {
        Tier::Avx2
    } else if c.neon {
        Tier::Neon
    } else {
        Tier::Scalar
    }
}

/// The best tier the current process may use.
pub fn active_tier() -> Tier {
    if !SIMD_ON.load(Ordering::Relaxed) {
        return Tier::Scalar;
    }
    probed_tier()
}

/// A resolved execution plan for one GEMM shape.
#[derive(Debug, Clone, Copy)]
pub struct Plan {
    /// Row-chunk tasks to fan out to (1 = stay on the calling thread).
    pub tasks: usize,
    /// Microkernel tier for this shape (may be `Scalar` below the
    /// `SIMD_MAC_FLOOR` even when a SIMD tier is active).
    pub tier: Tier,
}

/// Below this many multiply-accumulates a fork costs more than it buys.
const PAR_MAC_FLOOR: usize = 1 << 18;

/// Below this many multiply-accumulates the wider SIMD register tile
/// pads more than it computes; tiny shapes stay on the scalar kernels.
const SIMD_MAC_FLOOR: usize = 1 << 9;

/// Target rows per parallel task (a multiple of every tier's MR).
const TASK_ROWS: usize = 48;

type Key = (usize, usize, usize, Elem, usize, Tier);

fn cache() -> &'static Mutex<HashMap<Key, Plan>> {
    static CACHE: OnceLock<Mutex<HashMap<Key, Plan>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Plan a (n, k) x (k, m) GEMM under the current thread budget and
/// SIMD setting.
pub fn plan(n: usize, k: usize, m: usize, elem: Elem) -> Plan {
    let width = pool::num_threads();
    let active = active_tier();
    let key = (n, k, m, elem, width, active);
    if let Some(p) = cache().lock().unwrap().get(&key) {
        obs::count(obs::Counter::PlanHits, 1);
        return *p;
    }
    obs::count(obs::Counter::PlanMisses, 1);
    let macs = n.saturating_mul(k).saturating_mul(m);
    let tasks = if width <= 1 || macs < PAR_MAC_FLOOR || n < 2 {
        1
    } else {
        // more tasks than threads so the stealing cursor can balance
        // uneven chunks, but no thinner than TASK_ROWS rows each
        n.div_ceil(TASK_ROWS).min(width * 4)
    }
    .max(1);
    let tier = if macs < SIMD_MAC_FLOOR { Tier::Scalar } else { active };
    let p = Plan { tasks, tier };
    cache().lock().unwrap().insert(key, p);
    p
}

/// Number of memoized plans (diagnostics / tests).
pub fn cached_plans() -> usize {
    cache().lock().unwrap().len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_shapes_stay_serial() {
        assert_eq!(plan(4, 4, 4, Elem::F32).tasks, 1);
        assert_eq!(plan(1, 512, 512, Elem::I8).tasks, 1);
    }

    #[test]
    fn large_shapes_fan_out_under_a_budget() {
        let _gate = pool::test_serial();
        pool::set_num_threads(4);
        let p = plan(1024, 256, 256, Elem::F32);
        assert!(p.tasks > 1, "expected a parallel plan, got {}", p.tasks);
        assert!(p.tasks <= 16);
        pool::set_num_threads(1);
        assert_eq!(plan(1024, 256, 256, Elem::F32).tasks, 1);
        pool::set_num_threads(0);
    }

    #[test]
    fn plans_are_memoized() {
        // other tests insert plans concurrently, so only per-key
        // stability is assertable here; the gate keeps concurrent
        // set_simd_enabled togglers from flipping the tier between
        // the two lookups
        let _gate = pool::test_serial();
        let p1 = plan(77, 33, 11, Elem::F32);
        let p2 = plan(77, 33, 11, Elem::F32);
        assert_eq!(p1.tasks, p2.tasks);
        assert_eq!(p1.tier, p2.tier);
        assert!(cached_plans() >= 1);
    }

    #[test]
    fn tiny_shapes_stay_scalar_even_with_simd_active() {
        // (4, 4, 4) = 64 macs < SIMD_MAC_FLOOR
        assert_eq!(plan(4, 4, 4, Elem::F32).tier, Tier::Scalar);
    }

    #[test]
    fn plan_tier_follows_the_active_tier() {
        let _gate = pool::test_serial();
        set_simd_enabled(false);
        assert_eq!(active_tier(), Tier::Scalar);
        assert_eq!(plan(128, 128, 128, Elem::F32).tier, Tier::Scalar);
        set_simd_enabled(true);
        // with the knob back on the plan mirrors whatever the probe
        // found (scalar on hardware without AVX2/NEON)
        assert_eq!(plan(128, 128, 128, Elem::F32).tier, active_tier());
    }

    #[test]
    fn fingerprint_and_peaks_are_consistent() {
        let fp = caps().fingerprint();
        assert!(fp.starts_with(std::env::consts::ARCH), "{fp}");
        assert!(fp.contains("c"), "core count missing: {fp}");
        // wider tiers can never lower the modeled peak
        assert!(peak_ops_per_cycle(Tier::Avx2, Elem::F32)
                    > peak_ops_per_cycle(Tier::Scalar, Elem::F32));
        assert!(peak_ops_per_cycle(Tier::Neon, Elem::I8)
                    >= peak_ops_per_cycle(Tier::Neon, Elem::F32));
        // peak scales linearly with threads whenever frequency is known
        if let Some(p1) = peak_gflops(Tier::Scalar, Elem::F32, 1) {
            let p4 = peak_gflops(Tier::Scalar, Elem::F32, 4).unwrap();
            assert!((p4 - 4.0 * p1).abs() < 1e-9);
            assert!(p1 > 0.0);
        }
        // freq probe is memoized: two calls agree
        assert_eq!(cpu_freq_ghz(), cpu_freq_ghz());
    }

    #[test]
    fn env_override_forces_scalar_when_set() {
        // the env var is read once at probe time, so this asserts only
        // when the whole process runs under HOT_SIMD=0 (the CI scalar
        // leg); otherwise it checks the probe is consistent
        if matches!(std::env::var("HOT_SIMD").as_deref(),
                    Ok("0") | Ok("off") | Ok("scalar")) {
            assert!(caps().env_off);
            assert_eq!(active_tier(), Tier::Scalar);
        } else {
            assert!(!caps().env_off);
        }
    }
}
