//! Threaded block-FWHT transforms and the fused FWHT+quantize epilogue.
//!
//! HLQ's observation (and HOT's speedup recipe) is that the Hadamard
//! transform and the quantizer should ride the same memory pass instead
//! of running as separate kernels. Host-side that means:
//!
//!   * `fwht_rows` / `fwht_cols` — the order-16 block transforms,
//!     strip-mined for cache locality (the column variant gathers
//!     16-row tiles instead of striding the full matrix per column) and
//!     forked across the kernel pool for large tensors;
//!   * `fwht_quant_rows` / `fwht_quant_cols` — transform + min-max
//!     amax folded into one pass, then pseudo-stochastic quantize:
//!     one full traversal fewer than transform → scan → quantize, and
//!     bit-exact against the separate passes (same tile butterflies,
//!     same scale formula, same quantizer on the same f32 bits).
//!
//! Everything here is bit-identical to `hadamard::fwht::fwht_inplace`
//! applied tile by tile — the butterflies run in the same order, so
//! the pseudo-stochastic quantizer (which keys off result mantissas)
//! sees identical inputs no matter which path produced them.

use std::sync::Mutex;

use crate::hadamard::fwht::{BLOCK, NORM};
use crate::kernels::arena;
use crate::kernels::dispatch::{self, Tier};
use crate::kernels::pool;
use crate::kernels::simd;
use crate::obs;
use crate::quant;

/// Minimum elements before a transform forks across the pool.
const MIN_PAR: usize = 1 << 15;

/// Block-FWHT along the last axis of a row-major (rows, cols) matrix,
/// cols % 16 == 0. Threaded over row chunks for large tensors. The tile
/// butterflies run on the active SIMD tier — bit-identical to the
/// scalar tier by construction (same add/sub/mul sequence).
pub fn fwht_rows(x: &mut [f32], rows: usize, cols: usize) {
    assert_eq!(x.len(), rows * cols);
    assert_eq!(cols % BLOCK, 0, "cols must tile into {BLOCK}");
    let tier = dispatch::active_tier();
    par_rows(x, rows, cols, 1, &|chunk: &mut [f32]| {
        simd::fwht_tiles(tier, chunk, false)
    });
}

/// `fwht_rows` that also returns max|x| of the transformed tensor,
/// folded into the transform pass.
pub fn fwht_rows_amax(x: &mut [f32], rows: usize, cols: usize) -> f32 {
    assert_eq!(x.len(), rows * cols);
    assert_eq!(cols % BLOCK, 0, "cols must tile into {BLOCK}");
    let tier = dispatch::active_tier();
    par_rows(x, rows, cols, 1, &|chunk: &mut [f32]| {
        simd::fwht_tiles(tier, chunk, true)
    })
}

/// Block-FWHT along axis 0 of a row-major (rows, cols) matrix,
/// rows % 16 == 0. Strip-mined: gathers 16xW tiles so the butterflies
/// stream instead of striding the full matrix per column.
pub fn fwht_cols(x: &mut [f32], rows: usize, cols: usize) {
    assert_eq!(x.len(), rows * cols);
    assert_eq!(rows % BLOCK, 0, "rows must tile into {BLOCK}");
    if x.is_empty() {
        return;
    }
    let tier = dispatch::active_tier();
    par_rows(x, rows, cols, BLOCK, &|chunk: &mut [f32]| {
        cols_worker::<false>(tier, chunk, cols)
    });
}

/// `fwht_cols` that also returns max|x| of the transformed tensor.
pub fn fwht_cols_amax(x: &mut [f32], rows: usize, cols: usize) -> f32 {
    assert_eq!(x.len(), rows * cols);
    assert_eq!(rows % BLOCK, 0, "rows must tile into {BLOCK}");
    if x.is_empty() {
        return 0.0;
    }
    let tier = dispatch::active_tier();
    par_rows(x, rows, cols, BLOCK, &|chunk: &mut [f32]| {
        cols_worker::<true>(tier, chunk, cols)
    })
}

/// Shared body of the fused FWHT+quant epilogues: copy into the
/// thread-local transform scratch, run the amax-folding transform,
/// derive the min-max scale, quantize on the active tier. Steady state
/// allocates only the returned code buffer.
fn fwht_quant(x: &[f32], rows: usize, cols: usize, bits: u8,
              transform_amax: fn(&mut [f32], usize, usize) -> f32)
              -> (Vec<i8>, f32) {
    let _sp = obs::span(obs::Span::FwhtQuant);
    arena::with_f32(arena::FUSED, |t| {
        t.clear();
        t.extend_from_slice(x);
        let amax = transform_amax(t, rows, cols);
        let scale = amax.max(1e-8) / quant::qmax(bits) as f32;
        let mut q = vec![0i8; x.len()];
        simd::quantize_ps_into(dispatch::active_tier(), t, scale, bits,
                               &mut q);
        obs::count(obs::Counter::BytesQuantized, q.len() as u64);
        (q, scale)
    })
}

/// Fused epilogue: block-FWHT along rows, then pseudo-stochastic
/// min-max quantize at `bits`, the scale's amax scan folded into the
/// transform. Returns (q, scale); bit-exact vs separate
/// FWHT-then-quant passes at every tier.
pub fn fwht_quant_rows(x: &[f32], rows: usize, cols: usize, bits: u8)
                       -> (Vec<i8>, f32) {
    fwht_quant(x, rows, cols, bits, fwht_rows_amax)
}

/// Fused epilogue along axis 0: block-FWHT down columns + quantize.
pub fn fwht_quant_cols(x: &[f32], rows: usize, cols: usize, bits: u8)
                       -> (Vec<i8>, f32) {
    fwht_quant(x, rows, cols, bits, fwht_cols_amax)
}

/// Per-row quantize → pack epilogue: the ABC storage-side compressor.
/// For each row of the (rows, cols) matrix, the min-max scale scan,
/// the pseudo-stochastic quantizer and the byte/nibble packer run while
/// the row is cache-hot — the whole-tensor scan → quantize → pack
/// pipeline this replaces streamed the tensor three times. Returns
/// (packed codes, per-row scales): one byte per code at 8 bits, two
/// nibbles per byte at 4 bits (contiguous over the tensor; an odd
/// element count pads the final high nibble, logical length is the
/// caller's shape). Bit-exact vs `minmax_scale_rows` + `quantize_ps` +
/// `pack_int4_padded` run as separate passes.
pub fn quant_pack_rows(x: &[f32], rows: usize, cols: usize, bits: u8)
                       -> (Vec<u8>, Vec<f32>) {
    assert_eq!(x.len(), rows * cols);
    let _sp = obs::span(obs::Span::QuantPackRows);
    let tier = dispatch::active_tier();
    let qmax = quant::qmax(bits) as f32;
    let mut scales = Vec::with_capacity(rows);
    let mut data = Vec::with_capacity((rows * cols * bits as usize).div_ceil(8));
    arena::with_i8(arena::QROW, |qrow| {
        qrow.clear();
        qrow.resize(cols, 0);
        // carry nibble for 4-bit packing across odd-cols row boundaries
        let mut carry: Option<u8> = None;
        for r in 0..rows {
            let row = &x[r * cols..(r + 1) * cols];
            let scale = simd::amax(tier, row).max(1e-8) / qmax;
            scales.push(scale);
            // quantize the cache-hot row on the SIMD tier (bit-exact vs
            // the scalar quantizer), then pack straight out of scratch
            simd::quantize_ps_into(tier, row, scale, bits, qrow);
            match bits {
                8 => data.extend(qrow.iter().map(|&q| q as u8)),
                _ => {
                    for &q in qrow.iter() {
                        match carry.take() {
                            None => carry = Some((q as u8) & 0xF),
                            Some(lo) => {
                                data.push((((q as u8) & 0xF) << 4) | lo)
                            }
                        }
                    }
                }
            }
        }
        if let Some(lo) = carry {
            data.push(lo); // pad the final high nibble with 0
        }
    });
    obs::count(obs::Counter::BytesPacked, data.len() as u64);
    (data, scales)
}

// ---------------------------------------------------------------------------
// Workers
// ---------------------------------------------------------------------------

/// Run `worker` over row chunks (each a multiple of `granule` rows),
/// forking across the pool when the tensor is large enough. Returns the
/// max of the workers' returns (the folded amax).
fn par_rows(x: &mut [f32], rows: usize, cols: usize, granule: usize,
            worker: &(dyn Fn(&mut [f32]) -> f32 + Sync)) -> f32 {
    let width = pool::num_threads();
    if width <= 1 || x.len() < MIN_PAR || rows <= granule {
        return worker(x);
    }
    let chunk_rows =
        rows.div_ceil(width * 2).max(1).next_multiple_of(granule);
    let parts: Vec<Mutex<(&mut [f32], f32)>> = x
        .chunks_mut(chunk_rows * cols)
        .map(|c| Mutex::new((c, 0.0f32)))
        .collect();
    pool::parallel_for(parts.len(), &|i| {
        let mut guard = parts[i].lock().unwrap();
        let (chunk, amax) = &mut *guard;
        *amax = worker(chunk);
    });
    parts
        .into_iter()
        .map(|p| p.into_inner().unwrap().1)
        .fold(0.0f32, f32::max)
}

/// Column transform over a chunk whose row count is a multiple of 16:
/// gather a 16xW tile, butterfly along the 16 axis (identical add/sub
/// order to `fwht_inplace`; the per-stage row pairs run on the SIMD
/// tier's vector butterfly), scale by NORM, scatter back. `AMAX`
/// selects at compile time whether the post-transform max|x| is folded
/// in — plain transforms skip the per-element abs/compare entirely.
fn cols_worker<const AMAX: bool>(tier: Tier, x: &mut [f32], cols: usize)
                                 -> f32 {
    const W: usize = 64;
    let rows = x.len() / cols;
    let mut buf = [0.0f32; BLOCK * W];
    let mut amax = 0.0f32;
    for tr in 0..rows / BLOCK {
        let base = tr * BLOCK;
        let mut c0 = 0usize;
        while c0 < cols {
            let w = W.min(cols - c0);
            for b in 0..BLOCK {
                let at = (base + b) * cols + c0;
                buf[b * w..(b + 1) * w].copy_from_slice(&x[at..at + w]);
            }
            let mut size = 1usize;
            while size < BLOCK {
                let stride = size * 2;
                let mut lo = 0usize;
                while lo < BLOCK {
                    for i in lo..lo + size {
                        let (top, bot) = buf.split_at_mut((i + size) * w);
                        simd::butterfly_rows(tier,
                                             &mut top[i * w..(i + 1) * w],
                                             &mut bot[..w]);
                    }
                    lo += stride;
                }
                size = stride;
            }
            let tile_amax =
                simd::scale_amax(tier, &mut buf[..BLOCK * w], NORM, AMAX);
            if AMAX {
                amax = amax.max(tile_amax);
            }
            for b in 0..BLOCK {
                let at = (base + b) * cols + c0;
                x[at..at + w].copy_from_slice(&buf[b * w..(b + 1) * w]);
            }
            c0 += w;
        }
    }
    amax
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hadamard::fwht::fwht_inplace;
    use crate::util::prng::Pcg32;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Pcg32::seeded(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    /// The obvious tile-by-tile reference for the row transform.
    fn naive_rows(x: &mut [f32]) {
        let mut tile = [0.0f32; BLOCK];
        for t in x.chunks_exact_mut(BLOCK) {
            tile.copy_from_slice(t);
            fwht_inplace(&mut tile);
            t.copy_from_slice(&tile);
        }
    }

    #[test]
    fn rows_bit_identical_to_tilewise_reference() {
        for (rows, cols) in [(1, 16), (5, 48), (33, 32)] {
            let orig = randv(rows * cols, 1 + rows as u64);
            let mut a = orig.clone();
            fwht_rows(&mut a, rows, cols);
            let mut b = orig.clone();
            naive_rows(&mut b);
            assert_eq!(a, b, "{rows}x{cols}");
        }
    }

    #[test]
    fn cols_bit_identical_to_transpose_path() {
        for (rows, cols) in [(16, 1), (32, 7), (48, 130)] {
            let orig = randv(rows * cols, 7 + cols as u64);
            let mut a = orig.clone();
            fwht_cols(&mut a, rows, cols);
            // transpose -> row transform -> transpose runs the same
            // butterflies per column in the same order
            let mut xt = vec![0.0f32; rows * cols];
            for r in 0..rows {
                for c in 0..cols {
                    xt[c * rows + r] = orig[r * cols + c];
                }
            }
            naive_rows(&mut xt);
            for r in 0..rows {
                for c in 0..cols {
                    assert_eq!(a[r * cols + c], xt[c * rows + r],
                               "({r},{c}) of {rows}x{cols}");
                }
            }
        }
    }

    #[test]
    fn fused_quant_equals_separate_passes() {
        for bits in [4u8, 8] {
            let (rows, cols) = (24, 48);
            let x = randv(rows * cols, 11);
            let (q, s) = fwht_quant_rows(&x, rows, cols, bits);
            let mut t = x.clone();
            naive_rows(&mut t);
            let s_want = quant::minmax_scale(&t, bits);
            let q_want = quant::quantize_ps(&t, s_want, bits);
            assert_eq!(s.to_bits(), s_want.to_bits(), "bits={bits}");
            assert_eq!(q, q_want, "bits={bits}");

            let (rows, cols) = (48, 24);
            let x = randv(rows * cols, 12);
            let (q, s) = fwht_quant_cols(&x, rows, cols, bits);
            let mut t = x.clone();
            crate::hadamard::fwht::block_fwht_cols(&mut t, rows, cols);
            let s_want = quant::minmax_scale(&t, bits);
            let q_want = quant::quantize_ps(&t, s_want, bits);
            assert_eq!(s.to_bits(), s_want.to_bits(), "cols bits={bits}");
            assert_eq!(q, q_want, "cols bits={bits}");
        }
    }

    #[test]
    fn quant_pack_rows_equals_separate_passes() {
        for (rows, cols) in [(4usize, 8usize), (3, 5), (7, 1), (1, 9)] {
            let x = randv(rows * cols, 100 + (rows * cols) as u64);
            for bits in [4u8, 8] {
                let (data, scales) = quant_pack_rows(&x, rows, cols, bits);
                let s_want = quant::minmax_scale_rows(&x, rows, cols, bits);
                assert_eq!(scales, s_want, "{rows}x{cols}@{bits}");
                let mut q_want = Vec::new();
                for r in 0..rows {
                    q_want.extend(quant::quantize_ps(
                        &x[r * cols..(r + 1) * cols], s_want[r], bits));
                }
                let d_want = match bits {
                    8 => q_want.iter().map(|&q| q as u8).collect::<Vec<u8>>(),
                    _ => quant::pack_int4_padded(&q_want),
                };
                assert_eq!(data, d_want, "{rows}x{cols}@{bits}");
                if bits == 4 {
                    assert_eq!(quant::unpack_int4_n(&data, rows * cols),
                               q_want, "{rows}x{cols} unpack");
                }
            }
        }
    }

    #[test]
    fn threaded_transform_is_bit_deterministic() {
        let _gate = pool::test_serial();
        let (rows, cols) = (512, 128); // 64k elements: above the fork floor
        let orig = randv(rows * cols, 13);
        pool::set_num_threads(1);
        let mut serial = orig.clone();
        let amax_s = fwht_rows_amax(&mut serial, rows, cols);
        pool::set_num_threads(4);
        let mut par = orig.clone();
        let amax_p = fwht_rows_amax(&mut par, rows, cols);
        let mut par_c = orig.clone();
        fwht_cols(&mut par_c, rows, cols);
        pool::set_num_threads(0);
        assert_eq!(serial, par);
        assert_eq!(amax_s.to_bits(), amax_p.to_bits());
        let mut serial_c = orig.clone();
        pool::set_num_threads(1);
        fwht_cols(&mut serial_c, rows, cols);
        pool::set_num_threads(0);
        assert_eq!(serial_c, par_c);
    }

    #[test]
    fn fused_scratch_reuses_after_warmup() {
        // transform/quant scratch comes from the thread-local arena;
        // steady state must allocate only the returned buffers
        let _gate = pool::test_serial();
        pool::set_num_threads(1);
        let (rows, cols) = (24, 48);
        let x = randv(rows * cols, 900);
        for _ in 0..2 {
            std::hint::black_box(fwht_quant_rows(&x, rows, cols, 4));
            std::hint::black_box(quant_pack_rows(&x, rows, cols, 8));
        }
        let g0 = crate::kernels::arena::grow_count();
        for _ in 0..4 {
            std::hint::black_box(fwht_quant_rows(&x, rows, cols, 4));
            std::hint::black_box(quant_pack_rows(&x, rows, cols, 8));
        }
        assert_eq!(crate::kernels::arena::grow_count(), g0,
                   "steady-state fused epilogues must not grow the arena");
        pool::set_num_threads(0);
    }

    #[test]
    fn involution_still_holds() {
        let (rows, cols) = (3, 64);
        let orig = randv(rows * cols, 14);
        let mut x = orig.clone();
        fwht_rows(&mut x, rows, cols);
        fwht_rows(&mut x, rows, cols);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }
}
