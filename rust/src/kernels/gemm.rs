//! Cache-blocked, register-tiled, multi-threaded GEMM kernels.
//!
//! Layout story (BLIS-lite): the rhs is packed once into NR-column
//! strips (k-major within a strip, zero-padded lanes past the column
//! edge), the lhs is packed per row-task into MR-row strips per KC
//! depth block, and an MRxNR microkernel with a fully unrolled register
//! tile does all the arithmetic. Output rows are partitioned into tasks
//! and stolen off the shared cursor in `kernels::pool`, so results are
//! bit-deterministic for a given shape regardless of thread count
//! (tasks own disjoint row ranges; the summation order inside a row
//! never depends on scheduling).
//!
//! Three element families:
//!   * f32 (NN / NT / TN) — forward qlinears and the FP gradient paths;
//!   * i8 -> i32 (NN / TN) — the HQ/HLA quantized backward GEMMs, with
//!     an optional fused dequant-scale epilogue on the output write;
//!   * INT4-nibble (NN) — for lhs operands that already live packed
//!     two-values-per-byte (the `quant::pack_int4` ABC wire format):
//!     they stay packed in memory and expand only into the L1-resident
//!     panel. Freshly quantized tensors should use the i8 kernels —
//!     packing just to unpack costs an extra pass.
//!
//! The naive loop nests these kernels replaced live on as oracles in
//! `kernels::reference`.
//!
//! Tiering: `dispatch::plan` hands every call a `Tier`; the packers
//! produce whatever (MR, NR) panel geometry that tier's microkernel
//! wants (`simd::f32_tile`), and the register tile itself is either the
//! scalar loops below or an ISA microkernel from `kernels::simd`. The
//! int tiles share one packed layout across tiers and are bit-exact;
//! the f32 SIMD tile uses FMA and differs from scalar only in last-bit
//! rounding. Packing buffers come from the thread-local grow-only
//! arenas in `kernels::arena` — after warmup no GEMM call allocates a
//! panel.

use std::sync::Mutex;

use crate::kernels::arena;
use crate::kernels::dispatch::{self, Elem, Tier};
use crate::kernels::pool;
use crate::kernels::simd;
use crate::obs;

/// Record the nominal 2·n·k·m FLOPs of one GEMM against the counter of
/// the tier that actually executed it (no-op while tracing is off).
fn count_flops(tier: Tier, n: usize, k: usize, m: usize) {
    if !obs::enabled() {
        return;
    }
    let fl = 2 * n as u64 * k as u64 * m as u64;
    obs::count(match tier {
                   Tier::Scalar => obs::Counter::FlopsScalar,
                   Tier::Avx2 => obs::Counter::FlopsAvx2,
                   Tier::Neon => obs::Counter::FlopsNeon,
               },
               fl);
}

/// Bill packed-panel traffic — `read` source bytes in, `written` panel
/// bytes out — against the bytes-moved counter the bench harness'
/// roofline divides by cell time. Microkernel re-reads of the (cache-
/// resident) panels are deliberately not billed; this counter is the
/// compulsory DRAM-side traffic of the packing scheme.
#[inline]
fn count_panel_bytes(read: usize, written: usize) {
    obs::count(obs::Counter::BytesPanels, (read + written) as u64);
}

/// Scalar-tier microkernel rows (register-tile height). SIMD tiers may
/// use wider tiles — see `simd::f32_tile`.
pub const MR: usize = 4;
/// Scalar-tier microkernel columns (register-tile width).
pub const NR: usize = 8;
/// Depth-block for f32 (keeps an MR panel + NR strip slice in L1).
const KC_F32: usize = 256;
/// Depth-block for i8 (denser panels, larger block).
const KC_I8: usize = 1024;

/// Largest contraction depth an i8 GEMM may accumulate in i32: every
/// product is bounded by 127^2, so k·127² must stay below `i32::MAX`.
/// Assumes operands live in the symmetric quantized range [-127, 127]
/// (every repo quantizer clamps there); -128 would void the bound and
/// is rejected in debug builds.
pub const MAX_K_I8: usize = (i32::MAX / (127 * 127)) as usize;
/// The (much looser) bound for the INT4-nibble lhs family: nibbles
/// sign-extend to [-8, 7], so every product is bounded by 8·127 (the
/// i8 rhs under the same symmetric-range contract).
pub const MAX_K_I4: usize = (i32::MAX / (8 * 127)) as usize;

#[derive(Debug, Clone, Copy)]
enum Lhs {
    /// lhs is (n, k) row-major.
    N,
    /// lhs is (k, n) row-major; the product contracts its rows.
    T,
}

#[derive(Debug, Clone, Copy)]
enum Rhs {
    /// rhs is (k, m) row-major.
    N,
    /// rhs is (m, k) row-major; the product contracts its columns.
    T,
}

/// Integer lhs operand: plain i8 in either layout, or an INT4
/// nibble-packed (n, k/2) byte matrix (low nibble = even k index,
/// matching `quant::pack_int4`).
#[derive(Clone, Copy)]
enum IntLhs<'a> {
    I8(&'a [i8], Lhs),
    I4(&'a [u8]),
}

impl IntLhs<'_> {
    /// Per-family depth bound keeping every i32 accumulator exact.
    fn max_k(&self) -> usize {
        match self {
            IntLhs::I8(..) => MAX_K_I8,
            IntLhs::I4(_) => MAX_K_I4,
        }
    }
}

// ---------------------------------------------------------------------------
// Public entry points (argument orders mirror the old naive loops)
// ---------------------------------------------------------------------------

/// a @ b: a (n, k), b (k, m) -> (n, m).
pub fn gemm_f32_nn(a: &[f32], b: &[f32], n: usize, k: usize, m: usize)
                   -> Vec<f32> {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), k * m);
    gemm_f32(Lhs::N, a, Rhs::N, b, n, k, m)
}

/// x @ w.T: x (n, k), w (m, k) -> (n, m).
pub fn gemm_f32_nt(x: &[f32], w: &[f32], n: usize, k: usize, m: usize)
                   -> Vec<f32> {
    debug_assert_eq!(x.len(), n * k);
    debug_assert_eq!(w.len(), m * k);
    gemm_f32(Lhs::N, x, Rhs::T, w, n, k, m)
}

/// a.T @ b: a (k, n), b (k, m) -> (n, m).
pub fn gemm_f32_tn(a: &[f32], b: &[f32], k: usize, n: usize, m: usize)
                   -> Vec<f32> {
    debug_assert_eq!(a.len(), k * n);
    debug_assert_eq!(b.len(), k * m);
    gemm_f32(Lhs::T, a, Rhs::N, b, n, k, m)
}

/// Integer GEMM a @ b with i32 accumulation: a (n, k), b (k, m) i8.
/// All i8 entry points expect operands in the symmetric quantized
/// range [-127, 127] — see `MAX_K_I8`.
pub fn gemm_i8_nn(a: &[i8], b: &[i8], n: usize, k: usize, m: usize)
                  -> Vec<i32> {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), k * m);
    gemm_int_i32(IntLhs::I8(a, Lhs::N), b, n, k, m)
}

/// Integer GEMM a.T @ b with i32 accumulation: a (k, n), b (k, m) i8.
pub fn gemm_i8_tn(a: &[i8], b: &[i8], k: usize, n: usize, m: usize)
                  -> Vec<i32> {
    debug_assert_eq!(a.len(), k * n);
    debug_assert_eq!(b.len(), k * m);
    gemm_int_i32(IntLhs::I8(a, Lhs::T), b, n, k, m)
}

/// `gemm_i8_nn` with the dequant epilogue fused into the output write:
/// each i32 tile lands in the f32 output pre-scaled, so no second pass
/// over (n, m) happens. Always equal to `i32 GEMM then scale` — depths
/// beyond one KC block fall back to the exact i32 accumulator so the
/// bit-mirror contract with `ref.py` holds at every k.
pub fn gemm_i8_nn_deq(a: &[i8], b: &[i8], n: usize, k: usize, m: usize,
                      scale: f32) -> Vec<f32> {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), k * m);
    gemm_int_deq(IntLhs::I8(a, Lhs::N), b, n, k, m, scale)
}

/// `gemm_i8_tn` with the fused dequant-scale epilogue.
pub fn gemm_i8_tn_deq(a: &[i8], b: &[i8], k: usize, n: usize, m: usize,
                      scale: f32) -> Vec<f32> {
    debug_assert_eq!(a.len(), k * n);
    debug_assert_eq!(b.len(), k * m);
    gemm_int_deq(IntLhs::I8(a, Lhs::T), b, n, k, m, scale)
}

/// INT4-nibble GEMM: a stays packed (n, k/2) bytes (k even, low nibble
/// = even k index — the `quant::pack_int4` wire format), b is i8
/// (k, m). i32 accumulation, fused dequant-scale output. Bit-exact
/// against unpack-then-`gemm_i8_nn`.
pub fn gemm_i4_nn_deq(a_packed: &[u8], b: &[i8], n: usize, k: usize,
                      m: usize, scale: f32) -> Vec<f32> {
    assert_eq!(k % 2, 0, "INT4 GEMM needs an even contraction depth");
    debug_assert_eq!(a_packed.len(), n * k / 2);
    debug_assert_eq!(b.len(), k * m);
    gemm_int_deq(IntLhs::I4(a_packed), b, n, k, m, scale)
}

/// Row-major transpose: (rows, cols) -> (cols, rows).
pub fn transpose(a: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), rows * cols);
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = a[r * cols + c];
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Row-task fan-out (shared by every element family)
// ---------------------------------------------------------------------------

/// Split the (n, m) output into row chunks and run `f(r0, r1, chunk)`
/// on each — serially for 1 task, else stolen off the pool. Chunks are
/// disjoint `&mut` row ranges, so tasks never alias.
fn run_rows<T: Send>(n: usize, m: usize, tasks: usize, out: &mut [T],
                     f: &(dyn Fn(usize, usize, &mut [T]) + Sync)) {
    if tasks <= 1 {
        f(0, n, out);
        return;
    }
    let rows_per = n.div_ceil(tasks).max(1);
    let mut parts: Vec<Mutex<(usize, &mut [T])>> = Vec::new();
    let mut r0 = 0usize;
    for chunk in out.chunks_mut(rows_per * m) {
        let rows = chunk.len() / m;
        parts.push(Mutex::new((r0, chunk)));
        r0 += rows;
    }
    pool::parallel_for(parts.len(), &|i| {
        let mut guard = parts[i].lock().unwrap();
        let (r0, chunk) = &mut *guard;
        let rows = chunk.len() / m;
        f(*r0, *r0 + rows, chunk);
    });
}

// ---------------------------------------------------------------------------
// f32 path
// ---------------------------------------------------------------------------

fn gemm_f32(lhs: Lhs, a: &[f32], rhs: Rhs, b: &[f32], n: usize, k: usize,
            m: usize) -> Vec<f32> {
    let _sp = obs::span(obs::Span::GemmF32);
    let mut out = vec![0.0f32; n * m];
    if n == 0 || m == 0 || k == 0 {
        return out;
    }
    let onehot = match lhs {
        Lhs::N => onehot_rows(a, k),
        Lhs::T => None,
    };
    if let Some(rows) = onehot {
        gather_rows(&rows, rhs, b, k, m, &mut out);
        // the gather does n·m multiplies, not a dense contraction —
        // and moves one rhs row in + one output row out per lhs row
        count_flops(Tier::Scalar, n, 1, m);
        count_panel_bytes(n * m * 4, n * m * 4);
        return out;
    }
    let plan = dispatch::plan(n, k, m, Elem::F32);
    count_flops(plan.tier, n, k, m);
    let (_, nr) = simd::f32_tile(plan.tier);
    arena::with_f32(arena::RHS, |pb| {
        {
            let _sp = obs::span(obs::Span::PackRhs);
            pack_rhs_f32(rhs, b, k, m, nr, pb);
            count_panel_bytes(k * m * 4, pb.len() * 4);
        }
        let pb: &[f32] = pb;
        run_rows(n, m, plan.tasks, &mut out, &|r0, r1, c| {
            task_f32(plan.tier, lhs, a, pb, n, k, m, r0, r1, c);
        });
    });
    out
}

/// If every lhs row has at most one nonzero (the LM one-hot embedding
/// feeding the first qlinear), return the per-row (col, val) pairs so
/// the GEMM can run as an O(n·m) gather instead of dense O(n·k·m) —
/// the sparsity win the old naive loop got from skipping zero entries.
/// The scan exits at the first row with a second nonzero, so a typical
/// dense lhs bails inside row 0; the worst case (a long prefix of
/// ≤1-nonzero rows before a dense one) adds one extra read pass over
/// the lhs, ≤ 1/m of the dense GEMM's own work.
fn onehot_rows(a: &[f32], k: usize) -> Option<Vec<(usize, f32)>> {
    let mut chunks = a.chunks_exact(k);
    // probe the first row before allocating anything: a typical dense
    // lhs (every GEMM outside the embedding) bails here for free
    let first = onehot_row(chunks.next()?)?;
    let mut rows = Vec::with_capacity(a.len() / k);
    rows.push(first);
    for row in chunks {
        rows.push(onehot_row(row)?);
    }
    Some(rows)
}

/// `None` if the row has two or more nonzeros; `Some((0, 0.0))` for an
/// all-zero row.
fn onehot_row(row: &[f32]) -> Option<(usize, f32)> {
    let mut hit: Option<(usize, f32)> = None;
    for (j, &v) in row.iter().enumerate() {
        if v != 0.0 {
            if hit.is_some() {
                return None;
            }
            hit = Some((j, v));
        }
    }
    Some(hit.unwrap_or((0, 0.0)))
}

/// out[r, :] = val_r * b[col_r, :] (Rhs::N) or val_r * b[:, col_r]
/// read across (m, k) rows (Rhs::T). Memory-bound; runs serial.
fn gather_rows(rows: &[(usize, f32)], rhs: Rhs, b: &[f32], k: usize,
               m: usize, out: &mut [f32]) {
    for (&(j, v), dst) in rows.iter().zip(out.chunks_exact_mut(m)) {
        if v == 0.0 {
            continue; // all-zero lhs row: output row stays zero
        }
        match rhs {
            Rhs::N => {
                for (d, &bv) in dst.iter_mut().zip(&b[j * m..(j + 1) * m]) {
                    *d = v * bv;
                }
            }
            Rhs::T => {
                let col = b.iter().skip(j).step_by(k);
                for (d, &bv) in dst.iter_mut().zip(col) {
                    *d = v * bv;
                }
            }
        }
    }
}

/// Pack the rhs into `nr`-column strips, k-major within each strip:
/// value (kk, j) of strip s lives at `pb[(s * k + kk) * nr + j]`.
/// Lanes past the column edge are zero, so the microkernel never
/// branches on m. `nr` is the planned tier's register-tile width.
fn pack_rhs_f32(rhs: Rhs, b: &[f32], k: usize, m: usize, nr: usize,
                pb: &mut Vec<f32>) {
    let strips = m.div_ceil(nr);
    pb.clear();
    pb.resize(strips * k * nr, 0.0);
    match rhs {
        Rhs::N => {
            for kk in 0..k {
                let row = &b[kk * m..(kk + 1) * m];
                for s in 0..strips {
                    let c0 = s * nr;
                    let w = nr.min(m - c0);
                    let base = (s * k + kk) * nr;
                    pb[base..base + w].copy_from_slice(&row[c0..c0 + w]);
                }
            }
        }
        Rhs::T => {
            for j in 0..m {
                let (s, lane) = (j / nr, j % nr);
                let row = &b[j * k..(j + 1) * k];
                for (kk, &v) in row.iter().enumerate() {
                    pb[(s * k + kk) * nr + lane] = v;
                }
            }
        }
    }
}

/// Pack lhs rows r0..r1 at depths kbeg..kend into `mr`-row strips,
/// k-major: value (row r, depth kk) of strip t lives at
/// `ap[(t * kc + kk) * mr + (r % mr)]`. Rows past r1 are zero.
#[allow(clippy::too_many_arguments)]
fn pack_lhs_f32(lhs: Lhs, a: &[f32], n: usize, k: usize, r0: usize,
                r1: usize, kbeg: usize, kend: usize, mr: usize,
                ap: &mut Vec<f32>) {
    let rows = r1 - r0;
    let kc = kend - kbeg;
    ap.clear();
    ap.resize(rows.div_ceil(mr) * kc * mr, 0.0);
    match lhs {
        Lhs::N => {
            for r in 0..rows {
                let (t, lane) = (r / mr, r % mr);
                let src = &a[(r0 + r) * k + kbeg..(r0 + r) * k + kend];
                for (kk, &v) in src.iter().enumerate() {
                    ap[(t * kc + kk) * mr + lane] = v;
                }
            }
        }
        Lhs::T => {
            for kk in 0..kc {
                let src = &a[(kbeg + kk) * n + r0..(kbeg + kk) * n + r1];
                for (r, &v) in src.iter().enumerate() {
                    let (t, lane) = (r / mr, r % mr);
                    ap[(t * kc + kk) * mr + lane] = v;
                }
            }
        }
    }
}

/// Scalar MRxNR register tile over one packed panel pair. The flat
/// `acc` is row-major MR rows of NR lanes (the first 32 entries of the
/// shared accumulator buffer).
#[inline]
fn tile_f32_scalar(asl: &[f32], bs: &[f32], acc: &mut [f32]) {
    for (af, bf) in asl.chunks_exact(MR).zip(bs.chunks_exact(NR)) {
        for (&av, arow) in af.iter().zip(acc.chunks_exact_mut(NR)) {
            for (a, &bv) in arow.iter_mut().zip(bf) {
                *a += av * bv;
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn task_f32(tier: Tier, lhs: Lhs, a: &[f32], pb: &[f32], n: usize, k: usize,
            m: usize, r0: usize, r1: usize, c: &mut [f32]) {
    let (mr, nr) = simd::f32_tile(tier);
    let rows = r1 - r0;
    let strips_m = m.div_ceil(nr);
    arena::with_f32(arena::LHS, |ap| {
        let mut kbeg = 0usize;
        while kbeg < k {
            let kend = k.min(kbeg + KC_F32);
            let kc = kend - kbeg;
            {
                let _sp = obs::span(obs::Span::PackLhs);
                pack_lhs_f32(lhs, a, n, k, r0, r1, kbeg, kend, mr, ap);
                // lhs slab in + panel out, plus the += writeback pass
                // over this task's (rows, m) output tile for the block
                count_panel_bytes(rows * kc * 4 + rows * m * 4,
                                  ap.len() * 4 + rows * m * 4);
            }
            for s in 0..strips_m {
                let bs = &pb[(s * k + kbeg) * nr..(s * k + kend) * nr];
                let cmax = nr.min(m - s * nr);
                for t in 0..rows.div_ceil(mr) {
                    let asl = &ap[t * kc * mr..(t + 1) * kc * mr];
                    let mut acc = [0.0f32; simd::F32_ACC];
                    match tier {
                        Tier::Scalar => tile_f32_scalar(asl, bs, &mut acc),
                        _ => simd::tile_f32_wide(tier, asl, bs, kc, &mut acc),
                    }
                    let rmax = mr.min(rows - t * mr);
                    for (i, arow) in
                        acc.chunks_exact(nr).enumerate().take(rmax)
                    {
                        let row = t * mr + i;
                        let base = row * m + s * nr;
                        for (d, &v) in
                            c[base..base + cmax].iter_mut().zip(&arow[..cmax])
                        {
                            *d += v;
                        }
                    }
                }
            }
            kbeg = kend;
        }
    });
}

// ---------------------------------------------------------------------------
// i8 / INT4 path
// ---------------------------------------------------------------------------

fn gemm_int_i32(src: IntLhs, b: &[i8], n: usize, k: usize, m: usize)
                -> Vec<i32> {
    // release-mode assert: beyond the bound the accumulator silently
    // wraps and produces garbage gradients, and the check is one
    // comparison per GEMM call
    let max_k = src.max_k();
    assert!(k <= max_k,
            "int GEMM depth {k} can overflow i32 (max {max_k})");
    debug_check_symmetric(src, b);
    let _sp = obs::span(obs::Span::GemmI8);
    let mut out = vec![0i32; n * m];
    if n == 0 || m == 0 || k == 0 {
        return out;
    }
    let plan = dispatch::plan(n, k, m, Elem::I8);
    count_flops(plan.tier, n, k, m);
    arena::with_i8(arena::I_RHS, |pb| {
        {
            let _sp = obs::span(obs::Span::PackRhs);
            pack_rhs_i8(b, k, m, pb);
            count_panel_bytes(k * m, pb.len());
        }
        let pb: &[i8] = pb;
        run_rows(n, m, plan.tasks, &mut out, &|r0, r1, c| {
            task_int(plan.tier, src, pb, n, k, m, r0, r1,
                     &mut |row_base, tile_c, vals: &[i32]| {
                for (d, &v) in c
                    [row_base + tile_c..row_base + tile_c + vals.len()]
                    .iter_mut()
                    .zip(vals)
                {
                    *d += v;
                }
            });
        });
    });
    out
}

fn gemm_int_deq(src: IntLhs, b: &[i8], n: usize, k: usize, m: usize,
                scale: f32) -> Vec<f32> {
    let max_k = src.max_k();
    assert!(k <= max_k,
            "int GEMM depth {k} can overflow i32 (max {max_k})");
    debug_check_symmetric(src, b);
    if k > KC_I8 {
        // multi-block depths would accumulate f32-converted partials
        // per KC block; keep the exact i32 total and scale once so the
        // result is identical to the naive dequant at every depth
        return gemm_int_i32(src, b, n, k, m)
            .iter()
            .map(|&v| v as f32 * scale)
            .collect();
    }
    // span sits below the multi-block fallback: that path delegates to
    // `gemm_int_i32`, whose own span/FLOP record covers it (a second
    // record here would double-book the nested GemmI8 time)
    let _sp = obs::span(obs::Span::GemmI8);
    let mut out = vec![0.0f32; n * m];
    if n == 0 || m == 0 || k == 0 {
        return out;
    }
    let plan = dispatch::plan(n, k, m, Elem::I8);
    count_flops(plan.tier, n, k, m);
    arena::with_i8(arena::I_RHS, |pb| {
        {
            let _sp = obs::span(obs::Span::PackRhs);
            pack_rhs_i8(b, k, m, pb);
            count_panel_bytes(k * m, pb.len());
        }
        let pb: &[i8] = pb;
        run_rows(n, m, plan.tasks, &mut out, &|r0, r1, c| {
            task_int(plan.tier, src, pb, n, k, m, r0, r1,
                     &mut |row_base, tile_c, vals: &[i32]| {
                for (d, &v) in c
                    [row_base + tile_c..row_base + tile_c + vals.len()]
                    .iter_mut()
                    .zip(vals)
                {
                    *d += v as f32 * scale;
                }
            });
        });
    });
    out
}

/// Debug-only: the 127-based `MAX_K_*` bounds assume the symmetric
/// quantized range, so an i8 operand of -128 voids the no-overflow
/// guarantee. Every repo quantizer clamps to ±127 — this guards
/// direct pub-API callers. (The I4 lhs extreme of -8 is already
/// accounted for in `MAX_K_I4`, so only i8 slices are scanned.)
fn debug_check_symmetric(src: IntLhs, b: &[i8]) {
    if !cfg!(debug_assertions) {
        return;
    }
    if let IntLhs::I8(a, _) = src {
        assert!(a.iter().all(|&v| v != i8::MIN),
                "i8 GEMM lhs must lie in [-127, 127]");
    }
    assert!(b.iter().all(|&v| v != i8::MIN),
            "i8 GEMM rhs must lie in [-127, 127]");
}

/// Int rhs pack: NR-column strips, k-major — one layout for every tier
/// (the SIMD int tile interleaves depth pairs at load time, so it reads
/// the scalar layout as-is).
fn pack_rhs_i8(b: &[i8], k: usize, m: usize, pb: &mut Vec<i8>) {
    let strips = m.div_ceil(NR);
    pb.clear();
    pb.resize(strips * k * NR, 0);
    for kk in 0..k {
        let row = &b[kk * m..(kk + 1) * m];
        for s in 0..strips {
            let c0 = s * NR;
            let w = NR.min(m - c0);
            let base = (s * k + kk) * NR;
            pb[base..base + w].copy_from_slice(&row[c0..c0 + w]);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn pack_lhs_int(src: IntLhs, n: usize, k: usize, r0: usize, r1: usize,
                kbeg: usize, kend: usize, ap: &mut Vec<i8>) {
    let rows = r1 - r0;
    let kc = kend - kbeg;
    ap.clear();
    ap.resize(rows.div_ceil(MR) * kc * MR, 0);
    match src {
        IntLhs::I8(a, Lhs::N) => {
            for r in 0..rows {
                let (t, lane) = (r / MR, r % MR);
                let line = &a[(r0 + r) * k + kbeg..(r0 + r) * k + kend];
                for (kk, &v) in line.iter().enumerate() {
                    ap[(t * kc + kk) * MR + lane] = v;
                }
            }
        }
        IntLhs::I8(a, Lhs::T) => {
            for kk in 0..kc {
                let line = &a[(kbeg + kk) * n + r0..(kbeg + kk) * n + r1];
                for (r, &v) in line.iter().enumerate() {
                    let (t, lane) = (r / MR, r % MR);
                    ap[(t * kc + kk) * MR + lane] = v;
                }
            }
        }
        IntLhs::I4(a) => {
            // KC_I8 is even, so kbeg always starts on a whole byte
            let kb = k / 2;
            for r in 0..rows {
                let (t, lane) = (r / MR, r % MR);
                let line = &a[(r0 + r) * kb..(r0 + r + 1) * kb];
                for kk in 0..kc {
                    let kabs = kbeg + kk;
                    let byte = line[kabs / 2];
                    let nib =
                        (if kabs % 2 == 0 { byte & 0xF } else { byte >> 4 })
                            as i8;
                    let v = if nib >= 8 { nib - 16 } else { nib };
                    ap[(t * kc + kk) * MR + lane] = v;
                }
            }
        }
    }
}

/// Scalar int register tile (flat row-major MRxNR accumulator).
#[inline]
fn tile_i8_scalar(asl: &[i8], bs: &[i8], acc: &mut [i32]) {
    for (af, bf) in asl.chunks_exact(MR).zip(bs.chunks_exact(NR)) {
        for (&av, arow) in af.iter().zip(acc.chunks_exact_mut(NR)) {
            let av = av as i32;
            for (a, &bv) in arow.iter_mut().zip(bf) {
                *a += av * bv as i32;
            }
        }
    }
}

/// Shared int task: packs lhs panels, runs the tile loop, and hands
/// each finished (row_base, col, values) tile to `store` — the i32 and
/// fused-dequant epilogues differ only there. The SIMD int tiles are
/// exact i32 arithmetic over the same packed layout, so the result is
/// bit-identical at every tier.
#[allow(clippy::too_many_arguments)]
fn task_int(tier: Tier, src: IntLhs, pb: &[i8], n: usize, k: usize, m: usize,
            r0: usize, r1: usize, store: &mut dyn FnMut(usize, usize, &[i32])) {
    let rows = r1 - r0;
    let strips_m = m.div_ceil(NR);
    arena::with_i8(arena::I_LHS, |ap| {
        let mut kbeg = 0usize;
        while kbeg < k {
            let kend = k.min(kbeg + KC_I8);
            let kc = kend - kbeg;
            {
                let _sp = obs::span(obs::Span::PackLhs);
                pack_lhs_int(src, n, k, r0, r1, kbeg, kend, ap);
                let src_bytes = match src {
                    IntLhs::I4(_) => rows * kc / 2, // two codes per byte
                    IntLhs::I8(..) => rows * kc,
                };
                count_panel_bytes(src_bytes + rows * m * 4,
                                  ap.len() + rows * m * 4);
            }
            for s in 0..strips_m {
                let bs = &pb[(s * k + kbeg) * NR..(s * k + kend) * NR];
                let cmax = NR.min(m - s * NR);
                for t in 0..rows.div_ceil(MR) {
                    let asl = &ap[t * kc * MR..(t + 1) * kc * MR];
                    let mut acc = [0i32; simd::INT_ACC];
                    match tier {
                        Tier::Scalar => tile_i8_scalar(asl, bs, &mut acc),
                        _ => simd::tile_i8_wide(tier, asl, bs, kc, &mut acc),
                    }
                    let rmax = MR.min(rows - t * MR);
                    for (i, arow) in
                        acc.chunks_exact(NR).enumerate().take(rmax)
                    {
                        let row = t * MR + i;
                        store(row * m, s * NR, &arow[..cmax]);
                    }
                }
            }
            kbeg = kend;
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::reference;
    use crate::util::prng::Pcg32;
    use crate::util::proptest::rel_err;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Pcg32::seeded(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    fn randq(n: usize, seed: u64, lim: u32) -> Vec<i8> {
        let mut r = Pcg32::seeded(seed);
        (0..n)
            .map(|_| (r.below(2 * lim + 1) as i32 - lim as i32) as i8)
            .collect()
    }

    const SHAPES: [(usize, usize, usize); 6] = [
        (1, 1, 1),
        (3, 17, 5),
        (64, 64, 64),
        (127, 33, 65),
        (16, 257, 7),
        (40, 19, 128),
    ];

    #[test]
    fn f32_matches_naive_oracle_all_layouts() {
        for (idx, &(n, k, m)) in SHAPES.iter().enumerate() {
            let seed = 100 + idx as u64;
            let a = randv(n * k, seed);
            let b = randv(k * m, seed + 50);
            let w = randv(m * k, seed + 60); // (m, k) for NT
            let at = transpose(&a, n, k); // (k, n) for TN
            let e = rel_err(&gemm_f32_nn(&a, &b, n, k, m),
                            &reference::matmul(&a, &b, n, k, m));
            assert!(e < 1e-4, "nn {n}x{k}x{m}: {e}");
            let e = rel_err(&gemm_f32_nt(&a, &w, n, k, m),
                            &reference::matmul_nt(&a, &w, n, k, m));
            assert!(e < 1e-4, "nt {n}x{k}x{m}: {e}");
            let e = rel_err(&gemm_f32_tn(&at, &b, k, n, m),
                            &reference::matmul_tn(&at, &b, k, n, m));
            assert!(e < 1e-4, "tn {n}x{k}x{m}: {e}");
        }
    }

    #[test]
    fn onehot_lhs_gather_matches_naive_oracle() {
        // one-hot lhs rows (the LM embedding) take the gather fast
        // path; it must agree with the dense oracle for NN and NT,
        // including scaled hits and all-zero rows
        let (n, k, m) = (9, 13, 7);
        let mut r = Pcg32::seeded(31);
        let mut a = vec![0.0f32; n * k];
        for row in 0..n {
            if row == 4 {
                continue; // leave one row all-zero
            }
            a[row * k + r.below(k as u32) as usize] =
                if row % 2 == 0 { 1.0 } else { -0.5 };
        }
        let b = randv(k * m, 32);
        let w = randv(m * k, 33);
        assert!(rel_err(&gemm_f32_nn(&a, &b, n, k, m),
                        &reference::matmul(&a, &b, n, k, m)) < 1e-6);
        assert!(rel_err(&gemm_f32_nt(&a, &w, n, k, m),
                        &reference::matmul_nt(&a, &w, n, k, m)) < 1e-6);
    }

    #[test]
    fn f32_threaded_matches_and_is_deterministic() {
        let _gate = pool::test_serial();
        let (n, k, m) = (127, 65, 33);
        let a = randv(n * k, 7);
        let b = randv(k * m, 8);
        pool::set_num_threads(1);
        let serial = gemm_f32_nn(&a, &b, n, k, m);
        pool::set_num_threads(4);
        let par = gemm_f32_nn(&a, &b, n, k, m);
        pool::set_num_threads(0);
        // identical row partitioning -> bit-identical output
        assert_eq!(serial, par);
        assert!(rel_err(&par, &reference::matmul(&a, &b, n, k, m)) < 1e-4);
    }

    #[test]
    fn i8_bit_exact_vs_reference() {
        for (idx, &(n, k, m)) in SHAPES.iter().enumerate() {
            let seed = 300 + idx as u64;
            let a = randq(n * k, seed, 127);
            let b = randq(k * m, seed + 50, 127);
            assert_eq!(gemm_i8_nn(&a, &b, n, k, m),
                       reference::matmul_i8_nn(&a, &b, n, k, m),
                       "nn {n}x{k}x{m}");
            let at = randq(k * n, seed + 70, 127);
            assert_eq!(gemm_i8_tn(&at, &b, k, n, m),
                       reference::matmul_i8_tn(&at, &b, k, n, m),
                       "tn {n}x{k}x{m}");
        }
    }

    #[test]
    fn i8_deq_equals_i32_then_scale() {
        // k = 2048 crosses the KC_I8 = 1024 block boundary, pinning the
        // exact-i32-total contract on multi-block depths too (the
        // gw_hq4 path contracts over batch*seq, which exceeds 1024)
        for (n, k, m) in [(24, 32, 17), (2, 2048, 3)] {
            let a = randq(n * k, 1, 127);
            let b = randq(k * m, 2, 127);
            let s = 0.0371f32;
            let want: Vec<f32> = reference::matmul_i8_nn(&a, &b, n, k, m)
                .iter()
                .map(|&v| v as f32 * s)
                .collect();
            assert_eq!(gemm_i8_nn_deq(&a, &b, n, k, m, s), want,
                       "nn {n}x{k}x{m}");
            let at = transpose_i8(&a, n, k);
            assert_eq!(gemm_i8_tn_deq(&at, &b, k, n, m, s), want,
                       "tn {n}x{k}x{m}");
        }
    }

    fn transpose_i8(a: &[i8], rows: usize, cols: usize) -> Vec<i8> {
        let mut out = vec![0i8; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                out[c * rows + r] = a[r * cols + c];
            }
        }
        out
    }

    #[test]
    fn int4_nibble_gemm_bit_exact_vs_unpacked() {
        for &(n, k, m) in &[(1usize, 2usize, 1usize), (3, 16, 5), (9, 48, 11),
                            (32, 32, 32)] {
            let q = randq(n * k, 42 + n as u64, 7); // INT4 range
            let packed = crate::quant::pack_int4(&q);
            let b = randq(k * m, 43 + m as u64, 7);
            let s = 0.125f32;
            let want: Vec<f32> = reference::matmul_i8_nn(&q, &b, n, k, m)
                .iter()
                .map(|&v| v as f32 * s)
                .collect();
            assert_eq!(gemm_i4_nn_deq(&packed, &b, n, k, m, s), want,
                       "{n}x{k}x{m}");
        }
    }

    #[test]
    fn int4_rejects_odd_depth() {
        let r = std::panic::catch_unwind(|| {
            gemm_i4_nn_deq(&[0u8; 2], &[0i8; 3], 1, 3, 1, 1.0)
        });
        assert!(r.is_err(), "odd k must be rejected");
    }

    #[test]
    fn max_k_contract_is_pinned() {
        // every i8 product is bounded by 127², every i4·i8 product by
        // 8·127 (nibbles sign-extend to [-8, 7]); k·bound must fit i32
        assert_eq!(MAX_K_I8, 133_144);
        assert_eq!(MAX_K_I4, 2_113_665);
        for (max_k, prod) in [(MAX_K_I8, 127i64 * 127), (MAX_K_I4, 8 * 127)] {
            assert!(max_k as i64 * prod <= i32::MAX as i64);
            assert!((max_k as i64 + 1) * prod > i32::MAX as i64);
        }
    }

    #[cfg(debug_assertions)]
    #[test]
    fn asymmetric_i8_rejected_in_debug() {
        // -128 operands would void the 127-based overflow bounds
        let r = std::panic::catch_unwind(|| {
            gemm_i8_nn(&[-128], &[1], 1, 1, 1)
        });
        assert!(r.is_err(), "-128 lhs must be debug-rejected");
        let r = std::panic::catch_unwind(|| {
            gemm_i8_nn(&[1], &[-128], 1, 1, 1)
        });
        assert!(r.is_err(), "-128 rhs must be debug-rejected");
    }

    #[test]
    fn int4_accepts_depth_beyond_i8_bound() {
        // the INT4 family's looser bound must not inherit the i8 limit
        let k = MAX_K_I8 + 2; // even
        let a = vec![0u8; k / 2];
        let b = vec![0i8; k];
        assert_eq!(gemm_i4_nn_deq(&a, &b, 1, k, 1, 1.0), vec![0.0]);
    }

    #[test]
    fn over_max_k_panics() {
        let k = MAX_K_I8 + 2;
        let a = vec![0i8; k];
        let b = vec![0i8; k];
        let r = std::panic::catch_unwind(|| gemm_i8_nn(&a, &b, 1, k, 1));
        assert!(r.is_err(), "k beyond the i32 bound must panic");
    }

    #[test]
    fn no_panel_allocation_after_warmup() {
        // the arena contract from the SIMD/arena PR: once a shape has
        // been seen, repeating it must not allocate any packing buffer.
        // Thread budget pinned to 1 so every pack happens on this
        // thread (grow_count is thread-local).
        let _gate = pool::test_serial();
        pool::set_num_threads(1);
        let (n, k, m) = (48, 300, 33); // crosses one KC_F32 boundary
        let a = randv(n * k, 500);
        let b = randv(k * m, 501);
        let qa = randq(n * k, 502, 127);
        let qb = randq(k * m, 503, 127);
        for _ in 0..2 {
            std::hint::black_box(gemm_f32_nn(&a, &b, n, k, m));
            std::hint::black_box(gemm_i8_nn(&qa, &qb, n, k, m));
        }
        let g0 = crate::kernels::arena::grow_count();
        for _ in 0..4 {
            std::hint::black_box(gemm_f32_nn(&a, &b, n, k, m));
            std::hint::black_box(gemm_i8_nn(&qa, &qb, n, k, m));
        }
        assert_eq!(crate::kernels::arena::grow_count(), g0,
                   "steady-state GEMMs must not grow the packing arenas");
        pool::set_num_threads(0);
    }

    #[test]
    fn empty_dims_yield_zeros() {
        let b = randv(3 * 4, 77);
        assert!(gemm_f32_nn(&[], &b, 0, 3, 4).is_empty());
        assert_eq!(gemm_f32_nn(&[], &[], 2, 0, 3), vec![0.0; 6]);
        assert!(gemm_i8_nn(&[], &[0i8], 0, 1, 1).is_empty());
    }

    #[test]
    fn transpose_roundtrip() {
        let a = randv(7 * 5, 9);
        assert_eq!(transpose(&transpose(&a, 7, 5), 5, 7), a);
    }
}
