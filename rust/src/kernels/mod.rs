//! The native compute kernel subsystem — blocked, multi-threaded GEMM
//! and fused Hadamard/quantize epilogues powering `NativeBackend`.
//!
//! HOT's 2.6x training speedup comes from running the Hadamard-
//! quantized backward GEMMs on real low-precision kernels with the
//! transform/quantize fused into the GEMM pipeline (HLQ, Kim & Park
//! 2024). This module is that compute story for the CPU backend:
//!
//!   * `gemm` — cache-blocked f32 microkernels (NN/NT/TN, packed
//!     panels, register tiles), packed INT8->i32 and INT4-nibble
//!     GEMMs for the HQ/HLA backward paths, fused dequant-scale output;
//!   * `simd` — runtime-dispatched AVX2+FMA / NEON microkernels (wide
//!     f32 register tiles, widening int inner products, vector FWHT
//!     butterflies, vector amax/quantize), selected per shape by
//!     `dispatch` with the scalar kernels as the portable fallback;
//!   * `fused` — threaded block-FWHT-16 plus the fused FWHT+quantize
//!     epilogue (amax folded into the transform pass);
//!   * `arena` — thread-local grow-only packing/scratch buffers: no
//!     GEMM panel or fused-epilogue scratch allocation after warmup;
//!   * `pool` — std-only fork-join pool with a work-stealing task
//!     cursor (`--threads N` / `set_num_threads`);
//!   * `dispatch` — `CpuCaps` probe + per-shape plan memoization (ISA
//!     tier and fan-out; `HOT_SIMD=0` / `set_simd_enabled(false)`
//!     force the scalar tier);
//!   * `reference` — the original naive loop nests, kept solely as
//!     property-test oracles.
//!
//! Everything is deterministic: for a given shape and tier the result
//! is bit-identical at any thread count, because tasks own disjoint
//! output rows and in-row summation order never depends on scheduling.
//! The int GEMMs and every FWHT/quant epilogue are additionally
//! bit-identical *across* tiers; the f32 GEMM differs in last-bit
//! rounding only (FMA).

pub mod arena;
pub mod dispatch;
pub mod fused;
pub mod gemm;
pub mod pool;
pub mod reference;
// crate-only: the tier wrappers rely on callers upholding the packed
// layout contracts and on `Tier` values coming from the CpuCaps probe;
// exposing them outside the crate would let safe code reach the
// intrinsics with an unprobed tier or short panels
pub(crate) mod simd;

pub use dispatch::{active_tier, caps, cpu_freq_ghz, peak_gflops,
                   peak_ops_per_cycle, set_simd_enabled, simd_enabled,
                   CpuCaps, Elem, Tier};
pub use fused::{fwht_cols, fwht_cols_amax, fwht_quant_cols,
                fwht_quant_rows, fwht_rows, fwht_rows_amax,
                quant_pack_rows};
pub use gemm::{gemm_f32_nn, gemm_f32_nt, gemm_f32_tn, gemm_i4_nn_deq,
               gemm_i8_nn, gemm_i8_nn_deq, gemm_i8_tn, gemm_i8_tn_deq,
               transpose, MAX_K_I4, MAX_K_I8, MR, NR};
pub use pool::{num_threads, parallel_for, set_num_threads};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::proptest::rel_err;

    #[test]
    fn prop_blocked_f32_matches_oracle_any_shape() {
        proptest::check("blocked f32 gemm vs naive", 25, |case| {
            let n = case.usize_in(1, 70);
            let k = case.usize_in(1, 70);
            let m = case.usize_in(1, 70);
            let a = case.f32_vec(n * k, 1.0);
            let b = case.f32_vec(k * m, 1.0);
            let got = gemm_f32_nn(&a, &b, n, k, m);
            let want = reference::matmul(&a, &b, n, k, m);
            let e = rel_err(&got, &want);
            if e < 1e-4 {
                Ok(())
            } else {
                Err(format!("{n}x{k}x{m}: rel err {e}"))
            }
        });
    }

    #[test]
    fn prop_blocked_i8_bit_exact_any_shape() {
        proptest::check("blocked i8 gemm vs naive", 25, |case| {
            let n = case.usize_in(1, 50);
            let k = case.usize_in(1, 50);
            let m = case.usize_in(1, 50);
            let a: Vec<i8> = (0..n * k)
                .map(|_| (case.rng.below(255) as i32 - 127) as i8)
                .collect();
            let b: Vec<i8> = (0..k * m)
                .map(|_| (case.rng.below(255) as i32 - 127) as i8)
                .collect();
            if gemm_i8_nn(&a, &b, n, k, m)
                == reference::matmul_i8_nn(&a, &b, n, k, m)
            {
                Ok(())
            } else {
                Err(format!("{n}x{k}x{m}: i8 mismatch"))
            }
        });
    }

    #[test]
    fn prop_int4_matches_int8_any_even_depth() {
        proptest::check("int4 nibble gemm vs i8", 20, |case| {
            let n = case.usize_in(1, 24);
            let k = 2 * case.usize_in(1, 24);
            let m = case.usize_in(1, 24);
            let q: Vec<i8> = (0..n * k)
                .map(|_| (case.rng.below(15) as i32 - 7) as i8)
                .collect();
            let b: Vec<i8> = (0..k * m)
                .map(|_| (case.rng.below(15) as i32 - 7) as i8)
                .collect();
            let packed = crate::quant::pack_int4(&q);
            let got = gemm_i4_nn_deq(&packed, &b, n, k, m, 1.0);
            let want: Vec<f32> = reference::matmul_i8_nn(&q, &b, n, k, m)
                .iter()
                .map(|&v| v as f32)
                .collect();
            if got == want {
                Ok(())
            } else {
                Err(format!("{n}x{k}x{m}: int4 mismatch"))
            }
        });
    }
}
