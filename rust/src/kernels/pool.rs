//! Std-only fork-join thread pool with work-stealing task scheduling.
//!
//! One process-global pool, spawned lazily on first use. Workers park on
//! a condvar; `parallel_for` publishes a *region* — a borrowed closure
//! plus an atomic task cursor — and every participating thread (the
//! caller included) repeatedly steals the next unclaimed task index
//! until the region is drained. Idle threads therefore self-balance
//! against slow tasks instead of being handed a static partition.
//!
//! The closure is borrowed, not `'static`: `parallel_for` erases the
//! lifetime, and soundness comes from the retire protocol — the caller
//! clears the region and blocks until every joined worker has retired
//! (`live == 0`) before its stack frame returns, so no worker can touch
//! the closure after it dies. Late-waking workers observe `region ==
//! None` and go back to sleep without joining.
//!
//! `--threads N` maps to [`set_num_threads`]; 0 means one thread per
//! available core. The cap may exceed the core count (useful for
//! oversubscription experiments in `benches/kernel_gemm.rs`) — the pool
//! grows on demand. Nested or concurrent `parallel_for` calls fall back
//! to inline execution (the submit lock is `try_lock`ed), which keeps
//! the pool deadlock-free by construction.
//!
//! Workers are long-lived, which is what makes the thread-local packing
//! arenas in `kernels::arena` effective: each worker's panel buffers
//! warm up once per shape and are reused for the life of the process
//! (they are never handed across threads — a task packs into its own
//! thread's arena only).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;

/// Requested thread budget; 0 = one per available core.
static THREAD_CAP: AtomicUsize = AtomicUsize::new(0);

/// Set the process-wide kernel thread budget (0 restores the per-core
/// default). Takes effect on the next `parallel_for`.
pub fn set_num_threads(n: usize) {
    THREAD_CAP.store(n, Ordering::Relaxed);
}

/// Serializes tests that mutate the process-global thread budget (the
/// harness runs tests concurrently; cap-dependent assertions must not
/// interleave). Poison is ignored so one failing test doesn't cascade.
#[cfg(test)]
pub(crate) fn test_serial() -> std::sync::MutexGuard<'static, ()> {
    static GATE: Mutex<()> = Mutex::new(());
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// The thread budget `parallel_for` will use right now.
pub fn num_threads() -> usize {
    let cap = THREAD_CAP.load(Ordering::Relaxed);
    if cap != 0 {
        return cap;
    }
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// One published parallel region: a lifetime-erased closure and the
/// shared cursor tasks are stolen from.
struct Region {
    /// SAFETY: points at the caller's borrowed closure; only valid until
    /// the caller retires the region (see `parallel_for`).
    func: &'static (dyn Fn(usize) + Sync),
    tasks: usize,
    next: AtomicUsize,
    /// First panic payload caught on a worker; re-raised on the caller
    /// after the region retires so task panics are never swallowed.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

impl Region {
    /// Steal-and-run until the cursor passes `tasks`. `stolen` marks a
    /// worker (non-submitter) draining — observability only; the
    /// scheduling itself is identical either way, which is what keeps a
    /// traced run bit-identical to an untraced one.
    fn drain(&self, stolen: bool) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.tasks {
                break;
            }
            let _sp = crate::obs::span(crate::obs::Span::PoolTask);
            if stolen {
                crate::obs::count(crate::obs::Counter::PoolSteals, 1);
            }
            (self.func)(i);
        }
    }
}

struct State {
    region: Option<Arc<Region>>,
    /// Bumped on each publish so parked workers can tell a fresh region
    /// from one they already joined.
    seq: u64,
    /// Workers still allowed to join the current region.
    slots: usize,
    /// Workers currently inside `Region::drain`.
    live: usize,
}

struct Pool {
    state: Mutex<State>,
    work_cv: Condvar,
    done_cv: Condvar,
    /// Serializes regions; `try_lock` failure = nested/concurrent call,
    /// which runs inline instead of queueing (no deadlock possible).
    submit: Mutex<()>,
    spawned: Mutex<usize>,
}

fn global() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool {
        state: Mutex::new(State { region: None, seq: 0, slots: 0, live: 0 }),
        work_cv: Condvar::new(),
        done_cv: Condvar::new(),
        submit: Mutex::new(()),
        spawned: Mutex::new(0),
    })
}

fn ensure_workers(pool: &'static Pool, want: usize) {
    let mut spawned = pool.spawned.lock().unwrap();
    while *spawned < want {
        thread::Builder::new()
            .name(format!("hot-kernel-{}", *spawned))
            .spawn(move || worker_loop(pool))
            .expect("spawn kernel pool worker");
        *spawned += 1;
    }
}

fn worker_loop(pool: &'static Pool) {
    let mut seen = 0u64;
    let mut st = pool.state.lock().unwrap();
    loop {
        if st.seq != seen && st.slots > 0 {
            if let Some(region) = st.region.clone() {
                seen = st.seq;
                st.slots -= 1;
                st.live += 1;
                drop(st);
                // a panicking task must not leak `live` (the caller
                // would wait forever); park the payload on the region
                // and the caller re-raises it after the retire barrier
                let result = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| region.drain(true)));
                if let Err(payload) = result {
                    let mut slot = region.panic.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(payload);
                    }
                }
                st = pool.state.lock().unwrap();
                st.live -= 1;
                if st.live == 0 {
                    pool.done_cv.notify_all();
                }
                continue;
            }
            // region already retired: remember the seq so we don't spin
            seen = st.seq;
        }
        crate::obs::count(crate::obs::Counter::PoolParks, 1);
        st = pool.work_cv.wait(st).unwrap();
    }
}

/// Drop guard that retires the published region: clears it (so late
/// wakers can't join) and blocks until every joined worker has left
/// `Region::drain`. Running in `Drop` keeps the lifetime erasure in
/// `parallel_for` sound even when a task panics on the caller thread.
struct Retire {
    pool: &'static Pool,
}

impl Drop for Retire {
    fn drop(&mut self) {
        let mut st = self.pool.state.lock().unwrap();
        st.region = None;
        st.slots = 0;
        while st.live > 0 {
            st = self.pool.done_cv.wait(st).unwrap();
        }
    }
}

/// Run `f(0..tasks)` across the pool. Each index runs exactly once;
/// scheduling is dynamic (work-stealing cursor), completion is a
/// barrier: every call has returned when this returns. Falls back to
/// inline serial execution when the budget is 1, the pool is busy, or
/// the call is nested inside another `parallel_for`.
pub fn parallel_for(tasks: usize, f: &(dyn Fn(usize) + Sync)) {
    if tasks == 0 {
        return;
    }
    let width = num_threads().min(tasks);
    if width <= 1 {
        for i in 0..tasks {
            f(i);
        }
        return;
    }
    let pool = global();
    let guard = match pool.submit.try_lock() {
        Ok(g) => g,
        // a task panic on a previous caller poisons `submit` as its
        // guard unwinds; the pool state itself is consistent (Retire
        // ran), so recover instead of degrading to inline forever
        Err(std::sync::TryLockError::Poisoned(p)) => p.into_inner(),
        Err(std::sync::TryLockError::WouldBlock) => {
            // nested or concurrent region: run inline
            for i in 0..tasks {
                f(i);
            }
            return;
        }
    };
    ensure_workers(pool, width - 1);
    // SAFETY: `_retire` clears the region and drains `live` before this
    // frame returns — normally or by unwind — so the erased borrow
    // never outlives `f`.
    let func: &'static (dyn Fn(usize) + Sync) =
        unsafe { &*(f as *const (dyn Fn(usize) + Sync)) };
    let region = Arc::new(Region {
        func,
        tasks,
        next: AtomicUsize::new(0),
        panic: Mutex::new(None),
    });
    {
        let mut st = pool.state.lock().unwrap();
        st.region = Some(region.clone());
        st.seq = st.seq.wrapping_add(1);
        st.slots = width - 1;
        pool.work_cv.notify_all();
    }
    let _retire = Retire { pool };
    region.drain(false);
    drop(_retire);
    drop(guard);
    if let Some(payload) = region.panic.lock().unwrap().take() {
        std::panic::resume_unwind(payload);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_every_index_exactly_once() {
        let _gate = test_serial();
        set_num_threads(4);
        let hits: Vec<AtomicUsize> =
            (0..257).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(hits.len(), &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        set_num_threads(0);
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn nested_calls_run_inline_without_deadlock() {
        let _gate = test_serial();
        set_num_threads(4);
        let total = AtomicU64::new(0);
        parallel_for(8, &|i| {
            parallel_for(8, &|j| {
                total.fetch_add((i * 8 + j) as u64, Ordering::Relaxed);
            });
        });
        set_num_threads(0);
        assert_eq!(total.load(Ordering::Relaxed), (0..64).sum::<u64>());
    }

    #[test]
    fn zero_tasks_and_single_thread_paths() {
        let _gate = test_serial();
        parallel_for(0, &|_| panic!("no tasks to run"));
        set_num_threads(1);
        let sum = AtomicUsize::new(0);
        parallel_for(5, &|i| {
            sum.fetch_add(i + 1, Ordering::Relaxed);
        });
        set_num_threads(0);
        assert_eq!(sum.load(Ordering::Relaxed), 15);
    }

    #[test]
    fn budget_resolves() {
        let _gate = test_serial();
        set_num_threads(3);
        assert_eq!(num_threads(), 3);
        set_num_threads(0);
        assert!(num_threads() >= 1);
    }

    #[test]
    fn worker_task_panics_propagate_to_caller() {
        let _gate = test_serial();
        set_num_threads(4);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            parallel_for(64, &|i| {
                if i == 13 {
                    panic!("boom at {i}");
                }
            });
        }));
        assert!(r.is_err(), "task panic must reach the caller");
        // the pool stays usable after a panicked region
        set_num_threads(2);
        let sum = AtomicUsize::new(0);
        parallel_for(8, &|i| {
            sum.fetch_add(i, Ordering::Relaxed);
        });
        set_num_threads(0);
        assert_eq!(sum.load(Ordering::Relaxed), 28);
    }

    #[test]
    fn regions_reusable_back_to_back() {
        let _gate = test_serial();
        set_num_threads(2);
        for round in 0..32 {
            let sum = AtomicUsize::new(0);
            parallel_for(16, &|i| {
                sum.fetch_add(i, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), 120, "round {round}");
        }
        set_num_threads(0);
    }
}
