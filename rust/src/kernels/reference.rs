//! Naive loop-nest GEMM oracles.
//!
//! These are the seed tree's original debug-friendly triple loops,
//! collected in ONE place. They are the correctness contract the
//! blocked/threaded kernels in `kernels::gemm` are property-tested
//! against — nothing outside this module and the kernel tests should
//! call them on a hot path.
//!
//! Each oracle still books its nominal 2·n·k·m FLOPs against the
//! scalar-tier counter so the bench binaries can derive GFLOP/s from
//! the same telemetry for naive and blocked rows alike (the
//! zero-skipping shortcuts don't change the nominal count).

use crate::obs;

fn count_flops(n: usize, k: usize, m: usize) {
    obs::count(obs::Counter::FlopsScalar, 2 * n as u64 * k as u64 * m as u64);
}

/// y = x @ w.T: x (n, k), w (m, k) -> (n, m).
pub fn matmul_nt(x: &[f32], w: &[f32], n: usize, k: usize, m: usize)
                 -> Vec<f32> {
    debug_assert_eq!(x.len(), n * k);
    debug_assert_eq!(w.len(), m * k);
    count_flops(n, k, m);
    let mut out = vec![0.0f32; n * m];
    for r in 0..n {
        let xr = &x[r * k..(r + 1) * k];
        let dst = &mut out[r * m..(r + 1) * m];
        for (c, d) in dst.iter_mut().enumerate() {
            let wr = &w[c * k..(c + 1) * k];
            let mut acc = 0.0f32;
            for (a, b) in xr.iter().zip(wr) {
                acc += a * b;
            }
            *d = acc;
        }
    }
    out
}

/// a @ b: a (n, k), b (k, m) -> (n, m). Skips zero lhs entries.
pub fn matmul(a: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), k * m);
    count_flops(n, k, m);
    let mut out = vec![0.0f32; n * m];
    for r in 0..n {
        for p in 0..k {
            let av = a[r * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * m..(p + 1) * m];
            let dst = &mut out[r * m..(r + 1) * m];
            for (d, bv) in dst.iter_mut().zip(brow) {
                *d += av * bv;
            }
        }
    }
    out
}

/// a.T @ b: a (k, n), b (k, m) -> (n, m).
pub fn matmul_tn(a: &[f32], b: &[f32], k: usize, n: usize, m: usize)
                 -> Vec<f32> {
    debug_assert_eq!(a.len(), k * n);
    debug_assert_eq!(b.len(), k * m);
    count_flops(n, k, m);
    let mut out = vec![0.0f32; n * m];
    for p in 0..k {
        let arow = &a[p * n..(p + 1) * n];
        let brow = &b[p * m..(p + 1) * m];
        for (r, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let dst = &mut out[r * m..(r + 1) * m];
            for (d, bv) in dst.iter_mut().zip(brow) {
                *d += av * bv;
            }
        }
    }
    out
}

/// Integer GEMM a @ b with i32 accumulation: a (n, k), b (k, m) i8.
pub fn matmul_i8_nn(a: &[i8], b: &[i8], n: usize, k: usize, m: usize)
                    -> Vec<i32> {
    count_flops(n, k, m);
    let mut out = vec![0i32; n * m];
    for r in 0..n {
        for p in 0..k {
            let av = a[r * k + p] as i32;
            if av == 0 {
                continue;
            }
            let brow = &b[p * m..(p + 1) * m];
            let dst = &mut out[r * m..(r + 1) * m];
            for (d, &bv) in dst.iter_mut().zip(brow) {
                *d += av * bv as i32;
            }
        }
    }
    out
}

/// Integer GEMM a.T @ b with i32 accumulation: a (k, n), b (k, m) i8.
pub fn matmul_i8_tn(a: &[i8], b: &[i8], k: usize, n: usize, m: usize)
                    -> Vec<i32> {
    count_flops(n, k, m);
    let mut out = vec![0i32; n * m];
    for p in 0..k {
        let arow = &a[p * n..(p + 1) * n];
        let brow = &b[p * m..(p + 1) * m];
        for (r, &av) in arow.iter().enumerate() {
            if av == 0 {
                continue;
            }
            let dst = &mut out[r * m..(r + 1) * m];
            for (d, &bv) in dst.iter_mut().zip(brow) {
                *d += av as i32 * bv as i32;
            }
        }
    }
    out
}
