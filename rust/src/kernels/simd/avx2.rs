//! AVX2+FMA microkernels (x86_64).
//!
//! Every function here is `unsafe` with `#[target_feature]` and is only
//! ever reached through the safe dispatch wrappers in `kernels::simd`,
//! which in turn only select `Tier::Avx2` after the one-time `CpuCaps`
//! probe proved both AVX2 and FMA present. Slices are indexed with
//! `get_unchecked` only where the caller-checked layout contracts
//! (documented per function) guarantee bounds.
//!
//! Numerics contracts, pinned by the property tests in `kernels::simd`:
//!
//!   * f32 GEMM: FMA fuses the multiply-add rounding, so results differ
//!     from the scalar tier in the last bits (within the 1e-4 oracle
//!     tolerance); per-element accumulation order is unchanged (k-major,
//!     one accumulator per output lane), so results stay bit-identical
//!     across thread counts at this tier.
//!   * i8/i4 GEMM: `pmaddwd` on sign-extended i16 operands is exact
//!     integer arithmetic; bit-identical to the scalar tier (the
//!     `MAX_K_*` accumulator contracts in `kernels::gemm` keep every
//!     i32 partial sum in range).
//!   * FWHT / amax / quantize: identical add/sub/mul/max/compare
//!     operations on the same values in an order that IEEE-754 makes
//!     associativity-free, so bit-identical to the scalar tier — the
//!     pseudo-stochastic quantizer keys off result mantissas and must
//!     see the same bits no matter which tier produced them.

#![allow(clippy::missing_safety_doc)] // safety contracts live on the module

use core::arch::x86_64::*;

use crate::quant;

/// f32 microkernel rows at this tier.
pub const MR_F32: usize = 6;
/// f32 microkernel columns (two 8-lane vectors).
pub const NR_F32: usize = 16;

/// 6x16 f32 register tile: `acc[i*16 + j] = sum_k asl[k*6+i] * bs[k*16+j]`.
///
/// Layout contract: `asl.len() == kc * 6`, `bs.len() == kc * 16`,
/// `acc.len() >= 96`. 12 accumulator registers + 2 rhs lanes + 1
/// broadcast stay inside the 16 ymm registers.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn tile_f32_6x16(asl: &[f32], bs: &[f32], kc: usize,
                            acc: &mut [f32]) {
    debug_assert_eq!(asl.len(), kc * MR_F32);
    debug_assert_eq!(bs.len(), kc * NR_F32);
    debug_assert!(acc.len() >= MR_F32 * NR_F32);
    let mut c = [_mm256_setzero_ps(); 12];
    let ap = asl.as_ptr();
    let bp = bs.as_ptr();
    for kk in 0..kc {
        let b0 = _mm256_loadu_ps(bp.add(kk * 16));
        let b1 = _mm256_loadu_ps(bp.add(kk * 16 + 8));
        // unrolled over the 6 rows; LLVM keeps all 12 accumulators live
        let mut i = 0;
        while i < 6 {
            let a = _mm256_broadcast_ss(&*ap.add(kk * 6 + i));
            c[2 * i] = _mm256_fmadd_ps(a, b0, c[2 * i]);
            c[2 * i + 1] = _mm256_fmadd_ps(a, b1, c[2 * i + 1]);
            i += 1;
        }
    }
    let out = acc.as_mut_ptr();
    for (i, v) in c.iter().enumerate() {
        _mm256_storeu_ps(out.add(i * 8), *v);
    }
}

/// 4x8 i8 -> i32 register tile over the scalar tier's packed layout:
/// `acc[i*8 + j] += sum_k asl[k*4+i] * bs[k*8+j]`, exact i32.
///
/// Depth pairs (k, k+1) are interleaved with `unpacklo` and contracted
/// with `pmaddwd` on sign-extended i16 lanes — products are bounded by
/// 127^2 (or 8*127 for expanded INT4 panels), so the pairwise i16
/// multiply never saturates and the i32 adds never wrap under the
/// `MAX_K_*` contracts.
///
/// Layout contract: `asl.len() == kc * 4`, `bs.len() == kc * 8`,
/// `acc.len() >= 32`.
#[target_feature(enable = "avx2")]
pub unsafe fn tile_i8_4x8(asl: &[i8], bs: &[i8], kc: usize,
                          acc: &mut [i32]) {
    debug_assert_eq!(asl.len(), kc * 4);
    debug_assert_eq!(bs.len(), kc * 8);
    debug_assert!(acc.len() >= 32);
    let mut c = [_mm256_setzero_si256(); 4];
    let ap = asl.as_ptr();
    let bp = bs.as_ptr();
    let mut kk = 0;
    while kk < kc {
        let pair = kk + 1 < kc;
        let b0 = _mm_loadl_epi64(bp.add(kk * 8) as *const __m128i);
        let b1 = if pair {
            _mm_loadl_epi64(bp.add((kk + 1) * 8) as *const __m128i)
        } else {
            _mm_setzero_si128()
        };
        // [b(k,0), b(k+1,0), ..., b(k,7), b(k+1,7)] sign-extended to i16
        let bw = _mm256_cvtepi8_epi16(_mm_unpacklo_epi8(b0, b1));
        let mut i = 0;
        while i < 4 {
            let a0 = *ap.add(kk * 4 + i) as i16 as u16 as u32;
            let a1 = if pair {
                *ap.add((kk + 1) * 4 + i) as i16 as u16 as u32
            } else {
                0
            };
            let av = _mm256_set1_epi32(((a1 << 16) | a0) as i32);
            c[i] = _mm256_add_epi32(c[i], _mm256_madd_epi16(bw, av));
            i += 1;
        }
        kk += 2;
    }
    let out = acc.as_mut_ptr();
    for (i, v) in c.iter().enumerate() {
        _mm256_storeu_si256(out.add(i * 8) as *mut __m256i, *v);
    }
}

/// One FWHT-16 butterfly network over both halves of a tile, stages
/// 1/2/4 inside each 8-lane vector. Sign masks flip the subtrahend
/// lane, and IEEE addition of a negated operand is bit-identical to
/// subtraction, so the result matches `fwht_inplace` exactly.
#[target_feature(enable = "avx2")]
unsafe fn fwht8_inner(v: __m256, s1: __m256, s2: __m256, s4: __m256)
                      -> __m256 {
    // stage 1: lanes [1,0,3,2,...]
    let sw = _mm256_permute_ps::<0b10_11_00_01>(v);
    let v = _mm256_add_ps(sw, _mm256_xor_ps(v, s1));
    // stage 2: lanes [2,3,0,1,...]
    let sw = _mm256_permute_ps::<0b01_00_11_10>(v);
    let v = _mm256_add_ps(sw, _mm256_xor_ps(v, s2));
    // stage 4: swap 128-bit halves
    let sw = _mm256_permute2f128_ps::<0x01>(v, v);
    _mm256_add_ps(sw, _mm256_xor_ps(v, s4))
}

/// Block-FWHT every 16-tile of `x` in place (`x.len() % 16 == 0`),
/// optionally folding in max|x| of the transformed values. Bit-exact
/// vs tile-by-tile `fwht_inplace` + a scalar amax fold.
#[target_feature(enable = "avx2")]
pub unsafe fn fwht_tiles(x: &mut [f32], want_amax: bool) -> f32 {
    debug_assert_eq!(x.len() % 16, 0);
    let s1 = _mm256_setr_ps(0.0, -0.0, 0.0, -0.0, 0.0, -0.0, 0.0, -0.0);
    let s2 = _mm256_setr_ps(0.0, 0.0, -0.0, -0.0, 0.0, 0.0, -0.0, -0.0);
    let s4 = _mm256_setr_ps(0.0, 0.0, 0.0, 0.0, -0.0, -0.0, -0.0, -0.0);
    let norm = _mm256_set1_ps(crate::hadamard::fwht::NORM);
    let absm = _mm256_set1_ps(-0.0);
    let mut am = _mm256_setzero_ps();
    let p = x.as_mut_ptr();
    let mut at = 0;
    while at < x.len() {
        let v0 = fwht8_inner(_mm256_loadu_ps(p.add(at)), s1, s2, s4);
        let v1 = fwht8_inner(_mm256_loadu_ps(p.add(at + 8)), s1, s2, s4);
        // stage 8 across the two halves, then the 1/sqrt(16) norm
        let t0 = _mm256_mul_ps(_mm256_add_ps(v0, v1), norm);
        let t1 = _mm256_mul_ps(_mm256_sub_ps(v0, v1), norm);
        if want_amax {
            // operand order matters: maxps returns the SECOND operand
            // on a NaN compare, so keeping `am` second ignores NaN
            // values exactly like the scalar `f32::max` fold
            am = _mm256_max_ps(_mm256_andnot_ps(absm, t0), am);
            am = _mm256_max_ps(_mm256_andnot_ps(absm, t1), am);
        }
        _mm256_storeu_ps(p.add(at), t0);
        _mm256_storeu_ps(p.add(at + 8), t1);
        at += 16;
    }
    if want_amax { hmax(am) } else { 0.0 }
}

/// In-place paired butterfly over two equal-length rows:
/// `(a, b) <- (a + b, a - b)` elementwise. Bit-exact vs the scalar loop.
#[target_feature(enable = "avx2")]
pub unsafe fn butterfly_rows(a: &mut [f32], b: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let pa = a.as_mut_ptr();
    let pb = b.as_mut_ptr();
    let mut i = 0;
    while i + 8 <= n {
        let va = _mm256_loadu_ps(pa.add(i));
        let vb = _mm256_loadu_ps(pb.add(i));
        _mm256_storeu_ps(pa.add(i), _mm256_add_ps(va, vb));
        _mm256_storeu_ps(pb.add(i), _mm256_sub_ps(va, vb));
        i += 8;
    }
    while i < n {
        let (va, vb) = (*pa.add(i), *pb.add(i));
        *pa.add(i) = va + vb;
        *pb.add(i) = va - vb;
        i += 1;
    }
}

/// `x *= s` elementwise, optionally returning max|x| of the scaled
/// values. Bit-exact vs the scalar loop (mul and max are exact ops).
#[target_feature(enable = "avx2")]
pub unsafe fn scale_amax(x: &mut [f32], s: f32, want_amax: bool) -> f32 {
    let vs = _mm256_set1_ps(s);
    let absm = _mm256_set1_ps(-0.0);
    let mut am = _mm256_setzero_ps();
    let n = x.len();
    let p = x.as_mut_ptr();
    let mut i = 0;
    while i + 8 <= n {
        let v = _mm256_mul_ps(_mm256_loadu_ps(p.add(i)), vs);
        if want_amax {
            // `am` second: NaN lanes fall back to the accumulator,
            // mirroring the NaN-ignoring scalar `f32::max` fold
            am = _mm256_max_ps(_mm256_andnot_ps(absm, v), am);
        }
        _mm256_storeu_ps(p.add(i), v);
        i += 8;
    }
    let mut tail = 0.0f32;
    while i < n {
        let v = *p.add(i) * s;
        *p.add(i) = v;
        if want_amax {
            tail = tail.max(v.abs());
        }
        i += 1;
    }
    if want_amax { hmax(am).max(tail) } else { 0.0 }
}

/// max|x| over a slice (0.0 for empty). Bit-exact vs the scalar fold.
#[target_feature(enable = "avx2")]
pub unsafe fn amax(x: &[f32]) -> f32 {
    let absm = _mm256_set1_ps(-0.0);
    let mut am = _mm256_setzero_ps();
    let n = x.len();
    let p = x.as_ptr();
    let mut i = 0;
    while i + 8 <= n {
        let v = _mm256_loadu_ps(p.add(i));
        // `am` second: NaN lanes fall back to the accumulator,
        // mirroring the NaN-ignoring scalar `f32::max` fold
        am = _mm256_max_ps(_mm256_andnot_ps(absm, v), am);
        i += 8;
    }
    let mut m = hmax(am);
    while i < n {
        m = m.max((*p.add(i)).abs());
        i += 1;
    }
    m
}

/// Pseudo-stochastic quantize a slice at one scale — bit-exact mirror
/// of `quant::quantize_ps_one` per element (same div/floor/compare on
/// the same bits; the pseudo-random source is the input's low mantissa
/// bits, which the integer lane ops read exactly like the scalar code).
#[target_feature(enable = "avx2")]
pub unsafe fn quantize_ps(xs: &[f32], scale: f32, bits: u8,
                          out: &mut [i8]) {
    debug_assert_eq!(xs.len(), out.len());
    let qmax = quant::qmax(bits) as f32;
    let vs = _mm256_set1_ps(scale);
    let vmax = _mm256_set1_ps(qmax);
    let vmin = _mm256_set1_ps(-qmax);
    let m11 = _mm256_set1_epi32(0x7FF);
    let v2048 = _mm256_set1_ps(2048.0);
    let one = _mm256_set1_ps(1.0);
    let lane_fix = _mm256_setr_epi32(0, 4, 1, 5, 2, 6, 3, 7);
    let n = xs.len();
    let src = xs.as_ptr();
    let dst = out.as_mut_ptr();
    let mut i = 0;
    while i + 32 <= n {
        let mut q = [_mm256_setzero_si256(); 4];
        for (j, qv) in q.iter_mut().enumerate() {
            let x = _mm256_loadu_ps(src.add(i + 8 * j));
            let v = _mm256_div_ps(x, vs);
            let f = _mm256_floor_ps(v);
            let u = _mm256_div_ps(
                _mm256_cvtepi32_ps(_mm256_and_si256(_mm256_castps_si256(x),
                                                    m11)),
                v2048);
            let gt = _mm256_cmp_ps::<_CMP_GT_OQ>(_mm256_sub_ps(v, f), u);
            let r = _mm256_add_ps(f, _mm256_and_ps(gt, one));
            let r = _mm256_min_ps(_mm256_max_ps(r, vmin), vmax);
            // scalar parity on NaN quotients: Rust's clamp keeps NaN
            // and `NaN as i8` saturates to 0, while min/max here would
            // collapse NaN to -qmax — zero those lanes explicitly
            let ordered = _mm256_cmp_ps::<_CMP_ORD_Q>(v, v);
            *qv = _mm256_cvttps_epi32(_mm256_and_ps(r, ordered));
        }
        // i32x8 x4 -> i8x32; packs never saturates (|q| <= 127), and
        // the permute undoes the 128-bit lane interleave
        let p01 = _mm256_packs_epi32(q[0], q[1]);
        let p23 = _mm256_packs_epi32(q[2], q[3]);
        let pb = _mm256_packs_epi16(p01, p23);
        let pb = _mm256_permutevar8x32_epi32(pb, lane_fix);
        _mm256_storeu_si256(dst.add(i) as *mut __m256i, pb);
        i += 32;
    }
    while i < n {
        *dst.add(i) = quant::quantize_ps_one(*src.add(i), scale, bits);
        i += 1;
    }
}

/// Horizontal max of 8 lanes.
#[target_feature(enable = "avx2")]
unsafe fn hmax(v: __m256) -> f32 {
    let lo = _mm256_castps256_ps128(v);
    let hi = _mm256_extractf128_ps::<1>(v);
    let m = _mm_max_ps(lo, hi);
    let m = _mm_max_ps(m, _mm_movehl_ps(m, m));
    let m = _mm_max_ss(m, _mm_shuffle_ps::<0b01>(m, m));
    _mm_cvtss_f32(m)
}
