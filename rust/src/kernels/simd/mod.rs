//! Runtime-dispatched SIMD execution tier.
//!
//! `kernels::dispatch` probes the CPU once (`CpuCaps`) and hands every
//! kernel a `Tier`; this module turns that tier into concrete
//! microkernels. The unsafe intrinsic code lives in the per-ISA
//! submodules (`avx2` on x86_64, `neon` on aarch64) and is reachable
//! *only* through the dispatch wrappers here. The whole module is
//! `pub(crate)`: soundness rests on (a) SIMD `Tier` values flowing
//! from the successful `CpuCaps` probe (so the ISA is present) and
//! (b) the in-crate callers upholding the packed-layout length
//! contracts documented per wrapper — neither of which an external
//! caller could be trusted with.
//!
//! Per-tier register tiles:
//!
//! | kernel    | scalar | AVX2+FMA            | NEON                 |
//! |-----------|--------|---------------------|----------------------|
//! | f32 GEMM  | 4x8    | 6x16 (FMA)          | 6x16 (`vfmaq`)       |
//! | int GEMM  | 4x8    | 4x8 (`pmaddwd`)     | 4x8 (`vmlal_s16`)    |
//! | FWHT-16   | loops  | 2x8-lane butterfly  | 4x4-lane butterfly   |
//! | quant/amax| loops  | 8/32-lane           | 4/8-lane             |
//!
//! The INT4-nibble GEMM shares the int microkernel: its packed operand
//! expands into the same i8 panel layout, so the widening inner product
//! serves both families. Everything except the f32 GEMM (whose FMA
//! changes last-bit rounding) is bit-exact across tiers; the fused
//! FWHT+quant epilogues in particular MUST be — the pseudo-stochastic
//! quantizer keys off result mantissas, and `hadamard::fwht` promises
//! one transform semantics regardless of tier.

#[cfg(target_arch = "x86_64")]
pub mod avx2;
#[cfg(target_arch = "aarch64")]
pub mod neon;

use crate::kernels::dispatch::Tier;
use crate::kernels::gemm::{MR, NR};
use crate::quant;

/// Accumulator capacity covering every tier's f32 tile (6x16).
pub const F32_ACC: usize = 96;
/// Accumulator capacity covering every tier's int tile (4x8).
pub const INT_ACC: usize = 32;

/// (MR, NR) of the f32 microkernel at `tier`.
pub fn f32_tile(tier: Tier) -> (usize, usize) {
    match tier {
        Tier::Scalar => (MR, NR),
        Tier::Avx2 | Tier::Neon => (6, 16),
    }
}

/// Run the wide f32 register tile for a SIMD `tier`.
/// Layout contract: `asl` is a kc-deep MR-major panel, `bs` a kc-deep
/// NR-major strip for `f32_tile(tier)`, `acc` holds at least MRxNR.
#[cfg_attr(not(any(target_arch = "x86_64", target_arch = "aarch64")),
           allow(unused_variables))]
pub fn tile_f32_wide(tier: Tier, asl: &[f32], bs: &[f32], kc: usize,
                     acc: &mut [f32]) {
    match tier {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Tier::Avx2 exists only after the CpuCaps probe
        // detected avx2+fma on this machine
        Tier::Avx2 => unsafe { avx2::tile_f32_6x16(asl, bs, kc, acc) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally mandatory on aarch64
        Tier::Neon => unsafe { neon::tile_f32_6x16(asl, bs, kc, acc) },
        _ => unreachable!("scalar tier has no wide f32 microkernel"),
    }
}

/// Run the int register tile for a SIMD `tier` (exact i32; bit-equal to
/// the scalar tile). Same layout contract as the scalar 4x8 tile.
#[cfg_attr(not(any(target_arch = "x86_64", target_arch = "aarch64")),
           allow(unused_variables))]
pub fn tile_i8_wide(tier: Tier, asl: &[i8], bs: &[i8], kc: usize,
                    acc: &mut [i32]) {
    match tier {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: gated on the CpuCaps avx2 probe (see tile_f32_wide)
        Tier::Avx2 => unsafe { avx2::tile_i8_4x8(asl, bs, kc, acc) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally mandatory on aarch64
        Tier::Neon => unsafe { neon::tile_i8_4x8(asl, bs, kc, acc) },
        _ => unreachable!("scalar tier has its own int tile"),
    }
}

/// Block-FWHT every 16-tile of `x` in place (`x.len() % 16 == 0`),
/// optionally folding in max|x| of the transformed tensor. Bit-exact
/// across tiers.
pub fn fwht_tiles(tier: Tier, x: &mut [f32], want_amax: bool) -> f32 {
    match tier {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: gated on the CpuCaps avx2 probe
        Tier::Avx2 => unsafe { avx2::fwht_tiles(x, want_amax) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally mandatory on aarch64
        Tier::Neon => unsafe { neon::fwht_tiles(x, want_amax) },
        _ => fwht_tiles_scalar(x, want_amax),
    }
}

/// `(a, b) <- (a + b, a - b)` elementwise (the column-FWHT butterfly
/// over two gathered rows). Bit-exact across tiers.
pub fn butterfly_rows(tier: Tier, a: &mut [f32], b: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    match tier {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: gated on the CpuCaps avx2 probe
        Tier::Avx2 => unsafe { avx2::butterfly_rows(a, b) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally mandatory on aarch64
        Tier::Neon => unsafe { neon::butterfly_rows(a, b) },
        _ => {
            for (av, bv) in a.iter_mut().zip(b.iter_mut()) {
                let (x, y) = (*av, *bv);
                *av = x + y;
                *bv = x - y;
            }
        }
    }
}

/// `x *= s` elementwise, optionally returning max|x| of the scaled
/// values. Bit-exact across tiers.
pub fn scale_amax(tier: Tier, x: &mut [f32], s: f32, want_amax: bool)
                  -> f32 {
    match tier {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: gated on the CpuCaps avx2 probe
        Tier::Avx2 => unsafe { avx2::scale_amax(x, s, want_amax) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally mandatory on aarch64
        Tier::Neon => unsafe { neon::scale_amax(x, s, want_amax) },
        _ => {
            let mut am = 0.0f32;
            for v in x.iter_mut() {
                *v *= s;
                if want_amax {
                    am = am.max(v.abs());
                }
            }
            am
        }
    }
}

/// max|x| over a slice (0.0 for empty). Bit-exact across tiers,
/// including NaN inputs: every tier's fold ignores NaN exactly like
/// the scalar `f32::max` (AVX2 keeps the accumulator as the maxps
/// fallback operand; NEON uses `vmaxnmq`).
pub fn amax(tier: Tier, x: &[f32]) -> f32 {
    match tier {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: gated on the CpuCaps avx2 probe
        Tier::Avx2 => unsafe { avx2::amax(x) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally mandatory on aarch64
        Tier::Neon => unsafe { neon::amax(x) },
        _ => x.iter().fold(0.0f32, |m, v| m.max(v.abs())),
    }
}

/// Pseudo-stochastic quantize `xs` at one `scale` into `out`
/// (`out.len() == xs.len()`). Bit-exact mirror of
/// `quant::quantize_ps_one` per element at every tier.
pub fn quantize_ps_into(tier: Tier, xs: &[f32], scale: f32, bits: u8,
                        out: &mut [i8]) {
    debug_assert_eq!(xs.len(), out.len());
    match tier {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: gated on the CpuCaps avx2 probe
        Tier::Avx2 => unsafe { avx2::quantize_ps(xs, scale, bits, out) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is architecturally mandatory on aarch64
        Tier::Neon => unsafe { neon::quantize_ps(xs, scale, bits, out) },
        _ => {
            for (o, &x) in out.iter_mut().zip(xs) {
                *o = quant::quantize_ps_one(x, scale, bits);
            }
        }
    }
}

/// Portable tile transform (the pre-SIMD `rows_worker` body).
fn fwht_tiles_scalar(x: &mut [f32], want_amax: bool) -> f32 {
    use crate::hadamard::fwht::{fwht_inplace, BLOCK};
    let mut tile = [0.0f32; BLOCK];
    let mut am = 0.0f32;
    for t in x.chunks_exact_mut(BLOCK) {
        tile.copy_from_slice(t);
        fwht_inplace(&mut tile);
        if want_amax {
            for &v in &tile {
                am = am.max(v.abs());
            }
        }
        t.copy_from_slice(&tile);
    }
    am
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dispatch::{self, active_tier, set_simd_enabled};
    use crate::kernels::{gemm_f32_nn, gemm_f32_nt, gemm_f32_tn,
                         gemm_i4_nn_deq, gemm_i8_nn, gemm_i8_tn, pool,
                         reference};
    use crate::util::prng::Pcg32;
    use crate::util::proptest::rel_err;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Pcg32::seeded(seed);
        (0..n).map(|_| r.normal()).collect()
    }

    fn randq(n: usize, seed: u64, lim: u32) -> Vec<i8> {
        let mut r = Pcg32::seeded(seed);
        (0..n)
            .map(|_| (r.below(2 * lim + 1) as i32 - lim as i32) as i8)
            .collect()
    }

    // knob-ignoring tier for the direct-parity tests: deterministic
    // SIMD coverage even while a concurrent test has the knob off
    use crate::kernels::dispatch::probed_tier;

    /// Odd/prime-heavy shapes exercising every tile-edge case of the
    /// wide microkernels (partial MR, partial NR, tiny k, deep k).
    const SHAPES: [(usize, usize, usize); 8] = [
        (1, 1, 1),
        (5, 3, 17),
        (6, 16, 16),
        (7, 19, 23),
        (13, 257, 31),
        (61, 67, 71),
        (97, 16, 101),
        (128, 128, 128),
    ];

    #[test]
    fn simd_and_scalar_f32_gemm_both_match_oracle() {
        let _gate = pool::test_serial();
        for (idx, &(n, k, m)) in SHAPES.iter().enumerate() {
            let seed = 9000 + idx as u64;
            let a = randv(n * k, seed);
            let b = randv(k * m, seed + 1);
            let w = randv(m * k, seed + 2);
            let at = crate::kernels::transpose(&a, n, k);
            let want_nn = reference::matmul(&a, &b, n, k, m);
            let want_nt = reference::matmul_nt(&a, &w, n, k, m);
            let want_tn = reference::matmul_tn(&at, &b, k, n, m);
            for simd in [true, false] {
                set_simd_enabled(simd);
                let tag = if simd { "simd" } else { "scalar" };
                let e = rel_err(&gemm_f32_nn(&a, &b, n, k, m), &want_nn);
                assert!(e < 1e-4, "{tag} nn {n}x{k}x{m}: {e}");
                let e = rel_err(&gemm_f32_nt(&a, &w, n, k, m), &want_nt);
                assert!(e < 1e-4, "{tag} nt {n}x{k}x{m}: {e}");
                let e = rel_err(&gemm_f32_tn(&at, &b, k, n, m), &want_tn);
                assert!(e < 1e-4, "{tag} tn {n}x{k}x{m}: {e}");
            }
            set_simd_enabled(true);
        }
    }

    #[test]
    fn simd_int_gemms_bit_exact_vs_scalar_and_oracle() {
        let _gate = pool::test_serial();
        for (idx, &(n, k, m)) in SHAPES.iter().enumerate() {
            let seed = 9500 + idx as u64;
            let a = randq(n * k, seed, 127);
            let b = randq(k * m, seed + 1, 127);
            let at = randq(k * n, seed + 2, 127);
            let want_nn = reference::matmul_i8_nn(&a, &b, n, k, m);
            let want_tn = reference::matmul_i8_tn(&at, &b, k, n, m);
            set_simd_enabled(true);
            let simd_nn = gemm_i8_nn(&a, &b, n, k, m);
            let simd_tn = gemm_i8_tn(&at, &b, k, n, m);
            set_simd_enabled(false);
            assert_eq!(simd_nn, gemm_i8_nn(&a, &b, n, k, m),
                       "nn tiers disagree {n}x{k}x{m}");
            assert_eq!(simd_tn, gemm_i8_tn(&at, &b, k, n, m),
                       "tn tiers disagree {n}x{k}x{m}");
            set_simd_enabled(true);
            assert_eq!(simd_nn, want_nn, "nn {n}x{k}x{m}");
            assert_eq!(simd_tn, want_tn, "tn {n}x{k}x{m}");
        }
    }

    #[test]
    fn simd_int4_nibble_gemm_bit_exact_across_tiers() {
        let _gate = pool::test_serial();
        for &(n, k, m) in &[(3usize, 16usize, 5usize), (9, 46, 11),
                            (33, 128, 37)] {
            let q = randq(n * k, 77 + n as u64, 7);
            let b = randq(k * m, 78 + m as u64, 7);
            let packed = crate::quant::pack_int4(&q);
            let want: Vec<f32> = reference::matmul_i8_nn(&q, &b, n, k, m)
                .iter()
                .map(|&v| v as f32 * 0.25)
                .collect();
            for simd in [true, false] {
                set_simd_enabled(simd);
                assert_eq!(gemm_i4_nn_deq(&packed, &b, n, k, m, 0.25), want,
                           "simd={simd} {n}x{k}x{m}");
            }
            set_simd_enabled(true);
        }
    }

    #[test]
    fn quantizer_bit_exact_vs_scalar_reference() {
        // cover: negatives, zeros, grid points, clamp range, NaN/inf
        // degenerates (diverged-training inputs), odd tails
        let mut xs = randv(1037, 321);
        xs.extend_from_slice(&[0.0, -0.0, 1.0, -1.0, 1e6, -1e6, 0.5f32,
                               127.0 * 0.037, -127.0 * 0.037,
                               f32::NAN, f32::INFINITY, f32::NEG_INFINITY]);
        for bits in [4u8, 8] {
            let scale = 0.037f32;
            let want = crate::quant::quantize_ps(&xs, scale, bits);
            let mut got = vec![0i8; xs.len()];
            quantize_ps_into(probed_tier(), &xs, scale, bits, &mut got);
            assert_eq!(got, want, "bits={bits} tier={:?}", probed_tier());
            // tiny scale drives huge quotients through the clamp
            let mut got = vec![0i8; xs.len()];
            quantize_ps_into(probed_tier(), &xs, 1e-6, bits, &mut got);
            assert_eq!(got, crate::quant::quantize_ps(&xs, 1e-6, bits),
                       "clamped bits={bits}");
        }
    }

    #[test]
    fn fwht_tiles_bit_exact_across_tiers() {
        for tiles in [1usize, 3, 7, 32] {
            let orig = randv(tiles * 16, 55 + tiles as u64);
            let mut scalar = orig.clone();
            let am_s = fwht_tiles(Tier::Scalar, &mut scalar, true);
            let mut active = orig.clone();
            let am_a = fwht_tiles(probed_tier(), &mut active, true);
            assert_eq!(scalar, active, "{tiles} tiles");
            assert_eq!(am_s.to_bits(), am_a.to_bits(), "{tiles} tiles amax");
        }
    }

    #[test]
    fn helper_ops_bit_exact_across_tiers() {
        let tier = probed_tier();
        let a0 = randv(37, 81);
        let b0 = randv(37, 82);
        let (mut a1, mut b1) = (a0.clone(), b0.clone());
        butterfly_rows(Tier::Scalar, &mut a1, &mut b1);
        let (mut a2, mut b2) = (a0, b0);
        butterfly_rows(tier, &mut a2, &mut b2);
        assert_eq!(a1, a2);
        assert_eq!(b1, b2);

        let mut x1 = randv(43, 83);
        let mut x2 = x1.clone();
        let m1 = scale_amax(Tier::Scalar, &mut x1, 0.25, true);
        let m2 = scale_amax(tier, &mut x2, 0.25, true);
        assert_eq!(x1, x2);
        assert_eq!(m1.to_bits(), m2.to_bits());

        let xs = randv(51, 84);
        assert_eq!(amax(Tier::Scalar, &xs).to_bits(),
                   amax(tier, &xs).to_bits());
        assert_eq!(amax(tier, &[]), 0.0);

        // NaN parity: the fold must ignore NaN like scalar f32::max —
        // in particular a NaN must not wipe out an earlier lane max
        let mut ys = randv(40, 85);
        ys[3] = 100.0;
        ys[11] = f32::NAN;
        ys[12] = f32::NAN;
        assert_eq!(amax(Tier::Scalar, &ys).to_bits(),
                   amax(tier, &ys).to_bits());
        assert_eq!(amax(tier, &ys), 100.0);
        let mut y1 = ys.clone();
        let mut y2 = ys.clone();
        let m1 = scale_amax(Tier::Scalar, &mut y1, 0.5, true);
        let m2 = scale_amax(tier, &mut y2, 0.5, true);
        assert_eq!(m1.to_bits(), m2.to_bits());
    }

    #[test]
    fn dispatch_knob_and_env_force_the_scalar_fallback() {
        let _gate = pool::test_serial();
        // the runtime knob always forces scalar plans...
        set_simd_enabled(false);
        assert_eq!(active_tier(), Tier::Scalar);
        let (n, k, m) = (37, 41, 43);
        let a = randv(n * k, 91);
        let b = randv(k * m, 92);
        let got = gemm_f32_nn(&a, &b, n, k, m);
        let e = rel_err(&got, &reference::matmul(&a, &b, n, k, m));
        assert!(e < 1e-4, "scalar fallback disagrees with oracle: {e}");
        set_simd_enabled(true);
        // ...and under HOT_SIMD=0 (the CI scalar leg) the env probe
        // pins the whole process to scalar regardless of the knob
        if dispatch::caps().env_off {
            assert_eq!(active_tier(), Tier::Scalar);
        }
    }
}
