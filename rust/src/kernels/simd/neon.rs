//! NEON microkernels (aarch64). Structural mirror of `simd::avx2` —
//! same tile shapes, same layout contracts, same numerics contracts
//! (f32 GEMM within the oracle tolerance via `vfmaq`, everything else
//! bit-exact vs the scalar tier). NEON is architecturally mandatory on
//! aarch64, so the only gate these kernels need is the tier selection
//! in `kernels::dispatch`.

#![allow(clippy::missing_safety_doc)] // safety contracts live on the module

use core::arch::aarch64::*;

use crate::quant;

/// f32 microkernel rows at this tier.
pub const MR_F32: usize = 6;
/// f32 microkernel columns (four 4-lane vectors).
pub const NR_F32: usize = 16;

/// 6x16 f32 register tile: `acc[i*16 + j] = sum_k asl[k*6+i] * bs[k*16+j]`.
/// Layout contract: `asl.len() == kc * 6`, `bs.len() == kc * 16`,
/// `acc.len() >= 96`. 24 accumulators + 4 rhs lanes + 1 broadcast fit
/// the 32 NEON registers.
#[target_feature(enable = "neon")]
pub unsafe fn tile_f32_6x16(asl: &[f32], bs: &[f32], kc: usize,
                            acc: &mut [f32]) {
    debug_assert_eq!(asl.len(), kc * MR_F32);
    debug_assert_eq!(bs.len(), kc * NR_F32);
    debug_assert!(acc.len() >= MR_F32 * NR_F32);
    let mut c = [vdupq_n_f32(0.0); 24];
    let ap = asl.as_ptr();
    let bp = bs.as_ptr();
    for kk in 0..kc {
        let b0 = vld1q_f32(bp.add(kk * 16));
        let b1 = vld1q_f32(bp.add(kk * 16 + 4));
        let b2 = vld1q_f32(bp.add(kk * 16 + 8));
        let b3 = vld1q_f32(bp.add(kk * 16 + 12));
        let mut i = 0;
        while i < 6 {
            let a = vdupq_n_f32(*ap.add(kk * 6 + i));
            c[4 * i] = vfmaq_f32(c[4 * i], a, b0);
            c[4 * i + 1] = vfmaq_f32(c[4 * i + 1], a, b1);
            c[4 * i + 2] = vfmaq_f32(c[4 * i + 2], a, b2);
            c[4 * i + 3] = vfmaq_f32(c[4 * i + 3], a, b3);
            i += 1;
        }
    }
    let out = acc.as_mut_ptr();
    for (i, v) in c.iter().enumerate() {
        vst1q_f32(out.add(i * 4), *v);
    }
}

/// 4x8 i8 -> i32 register tile over the scalar tier's packed layout:
/// `acc[i*8 + j] += sum_k asl[k*4+i] * bs[k*8+j]`, exact i32 via
/// `smull`-family widening MACs (`vmlal_s16`).
/// Layout contract: `asl.len() == kc * 4`, `bs.len() == kc * 8`,
/// `acc.len() >= 32`.
#[target_feature(enable = "neon")]
pub unsafe fn tile_i8_4x8(asl: &[i8], bs: &[i8], kc: usize,
                          acc: &mut [i32]) {
    debug_assert_eq!(asl.len(), kc * 4);
    debug_assert_eq!(bs.len(), kc * 8);
    debug_assert!(acc.len() >= 32);
    let mut c = [vdupq_n_s32(0); 8];
    let ap = asl.as_ptr();
    let bp = bs.as_ptr();
    for kk in 0..kc {
        let b16 = vmovl_s8(vld1_s8(bp.add(kk * 8)));
        let blo = vget_low_s16(b16);
        let bhi = vget_high_s16(b16);
        let mut i = 0;
        while i < 4 {
            let a = vdup_n_s16(*ap.add(kk * 4 + i) as i16);
            c[2 * i] = vmlal_s16(c[2 * i], blo, a);
            c[2 * i + 1] = vmlal_s16(c[2 * i + 1], bhi, a);
            i += 1;
        }
    }
    let out = acc.as_mut_ptr();
    for (i, v) in c.iter().enumerate() {
        vst1q_s32(out.add(i * 4), *v);
    }
}

/// Flip the sign of the lanes selected by `mask` (-0.0 bit pattern).
#[target_feature(enable = "neon")]
unsafe fn sign_flip(v: float32x4_t, mask: uint32x4_t) -> float32x4_t {
    vreinterpretq_f32_u32(veorq_u32(vreinterpretq_u32_f32(v), mask))
}

/// Butterfly stages 1 and 2 of the FWHT-16 inside one 4-lane vector.
#[target_feature(enable = "neon")]
unsafe fn fwht4_inner(v: float32x4_t, s1: uint32x4_t, s2: uint32x4_t)
                      -> float32x4_t {
    // stage 1: adjacent swap [x1, x0, x3, x2]
    let sw = vrev64q_f32(v);
    let v = vaddq_f32(sw, sign_flip(v, s1));
    // stage 2: pair swap [x2, x3, x0, x1]
    let sw = vextq_f32::<2>(v, v);
    vaddq_f32(sw, sign_flip(v, s2))
}

/// Block-FWHT every 16-tile of `x` in place (`x.len() % 16 == 0`),
/// optionally folding in max|x|. Bit-exact vs tile-by-tile
/// `fwht_inplace`.
#[target_feature(enable = "neon")]
pub unsafe fn fwht_tiles(x: &mut [f32], want_amax: bool) -> f32 {
    debug_assert_eq!(x.len() % 16, 0);
    let s1 = vld1q_u32([0u32, 0x8000_0000, 0, 0x8000_0000].as_ptr());
    let s2 = vld1q_u32([0u32, 0, 0x8000_0000, 0x8000_0000].as_ptr());
    let norm = vdupq_n_f32(crate::hadamard::fwht::NORM);
    let mut am = vdupq_n_f32(0.0);
    let p = x.as_mut_ptr();
    let mut at = 0;
    while at < x.len() {
        let v0 = fwht4_inner(vld1q_f32(p.add(at)), s1, s2);
        let v1 = fwht4_inner(vld1q_f32(p.add(at + 4)), s1, s2);
        let v2 = fwht4_inner(vld1q_f32(p.add(at + 8)), s1, s2);
        let v3 = fwht4_inner(vld1q_f32(p.add(at + 12)), s1, s2);
        // stage 4: (i, i+4) pairs across vector boundaries
        let (u0, u1) = (vaddq_f32(v0, v1), vsubq_f32(v0, v1));
        let (u2, u3) = (vaddq_f32(v2, v3), vsubq_f32(v2, v3));
        // stage 8: (i, i+8), then the 1/sqrt(16) norm
        let t0 = vmulq_f32(vaddq_f32(u0, u2), norm);
        let t1 = vmulq_f32(vaddq_f32(u1, u3), norm);
        let t2 = vmulq_f32(vsubq_f32(u0, u2), norm);
        let t3 = vmulq_f32(vsubq_f32(u1, u3), norm);
        if want_amax {
            // vmaxnmq (FMAXNM) ignores NaN operands, mirroring the
            // NaN-ignoring scalar `f32::max` fold
            am = vmaxnmq_f32(am, vabsq_f32(t0));
            am = vmaxnmq_f32(am, vabsq_f32(t1));
            am = vmaxnmq_f32(am, vabsq_f32(t2));
            am = vmaxnmq_f32(am, vabsq_f32(t3));
        }
        vst1q_f32(p.add(at), t0);
        vst1q_f32(p.add(at + 4), t1);
        vst1q_f32(p.add(at + 8), t2);
        vst1q_f32(p.add(at + 12), t3);
        at += 16;
    }
    if want_amax { vmaxvq_f32(am) } else { 0.0 }
}

/// In-place paired butterfly over two equal-length rows:
/// `(a, b) <- (a + b, a - b)` elementwise.
#[target_feature(enable = "neon")]
pub unsafe fn butterfly_rows(a: &mut [f32], b: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let pa = a.as_mut_ptr();
    let pb = b.as_mut_ptr();
    let mut i = 0;
    while i + 4 <= n {
        let va = vld1q_f32(pa.add(i));
        let vb = vld1q_f32(pb.add(i));
        vst1q_f32(pa.add(i), vaddq_f32(va, vb));
        vst1q_f32(pb.add(i), vsubq_f32(va, vb));
        i += 4;
    }
    while i < n {
        let (va, vb) = (*pa.add(i), *pb.add(i));
        *pa.add(i) = va + vb;
        *pb.add(i) = va - vb;
        i += 1;
    }
}

/// `x *= s` elementwise, optionally returning max|x| of the scaled
/// values.
#[target_feature(enable = "neon")]
pub unsafe fn scale_amax(x: &mut [f32], s: f32, want_amax: bool) -> f32 {
    let vs = vdupq_n_f32(s);
    let mut am = vdupq_n_f32(0.0);
    let n = x.len();
    let p = x.as_mut_ptr();
    let mut i = 0;
    while i + 4 <= n {
        let v = vmulq_f32(vld1q_f32(p.add(i)), vs);
        if want_amax {
            // NaN-ignoring fold (see fwht_tiles)
            am = vmaxnmq_f32(am, vabsq_f32(v));
        }
        vst1q_f32(p.add(i), v);
        i += 4;
    }
    let mut tail = 0.0f32;
    while i < n {
        let v = *p.add(i) * s;
        *p.add(i) = v;
        if want_amax {
            tail = tail.max(v.abs());
        }
        i += 1;
    }
    if want_amax { vmaxvq_f32(am).max(tail) } else { 0.0 }
}

/// max|x| over a slice (0.0 for empty).
#[target_feature(enable = "neon")]
pub unsafe fn amax(x: &[f32]) -> f32 {
    let mut am = vdupq_n_f32(0.0);
    let n = x.len();
    let p = x.as_ptr();
    let mut i = 0;
    while i + 4 <= n {
        // NaN-ignoring fold (see fwht_tiles)
        am = vmaxnmq_f32(am, vabsq_f32(vld1q_f32(p.add(i))));
        i += 4;
    }
    let mut m = vmaxvq_f32(am);
    while i < n {
        m = m.max((*p.add(i)).abs());
        i += 1;
    }
    m
}

/// Pseudo-stochastic quantize a slice at one scale — bit-exact mirror
/// of `quant::quantize_ps_one` per element.
#[target_feature(enable = "neon")]
pub unsafe fn quantize_ps(xs: &[f32], scale: f32, bits: u8,
                          out: &mut [i8]) {
    debug_assert_eq!(xs.len(), out.len());
    let qmax = quant::qmax(bits) as f32;
    let vs = vdupq_n_f32(scale);
    let vmax = vdupq_n_f32(qmax);
    let vmin = vdupq_n_f32(-qmax);
    let m11 = vdupq_n_u32(0x7FF);
    let v2048 = vdupq_n_f32(2048.0);
    let one = vreinterpretq_u32_f32(vdupq_n_f32(1.0));
    let n = xs.len();
    let src = xs.as_ptr();
    let dst = out.as_mut_ptr();
    let mut i = 0;
    while i + 8 <= n {
        let mut half = [vdupq_n_s32(0); 2];
        let mut j = 0;
        while j < 2 {
            let x = vld1q_f32(src.add(i + 4 * j));
            let v = vdivq_f32(x, vs);
            let f = vrndmq_f32(v); // floor
            let u = vdivq_f32(
                vcvtq_f32_u32(vandq_u32(vreinterpretq_u32_f32(x), m11)),
                v2048);
            let gt = vcgtq_f32(vsubq_f32(v, f), u);
            let bump = vreinterpretq_f32_u32(vandq_u32(gt, one));
            let r = vaddq_f32(f, bump);
            let r = vminq_f32(vmaxq_f32(r, vmin), vmax);
            // scalar parity on NaN quotients (see the AVX2 mirror):
            // zero NaN lanes so they quantize to 0 like `NaN as i8`
            let ordered = vceqq_f32(v, v);
            let r = vreinterpretq_f32_u32(
                vandq_u32(vreinterpretq_u32_f32(r), ordered));
            half[j] = vcvtq_s32_f32(r); // truncate toward zero
            j += 1;
        }
        // i32x4 x2 -> i16x8 -> i8x8; never saturates (|q| <= 127)
        let w = vcombine_s16(vqmovn_s32(half[0]), vqmovn_s32(half[1]));
        vst1_s8(dst.add(i), vqmovn_s16(w));
        i += 8;
    }
    while i < n {
        *dst.add(i) = quant::quantize_ps_one(*src.add(i), scale, bits);
        i += 1;
    }
}
