//! GPU-kernel latency simulator — regenerates Table 6 and Fig 8.
//!
//! The paper profiles its CUDA kernels on an RTX 3090. We have no GPU, so
//! (per DESIGN.md §Substitutions) we model each of the five pipeline
//! modules — HT, HLA, quantize, integer GEMM, dequantize — with a
//! roofline + fixed-launch-cost model calibrated to the 3090's published
//! characteristics:
//!
//!   FP32 GEMM       35.6 TFLOP/s  (CUDA cores)
//!   FP16 TC GEMM    71   TFLOP/s
//!   INT8 TC GEMM   284   TOP/s
//!   INT4 TC GEMM   568   TOP/s
//!   HBM bandwidth  936   GB/s
//!   kernel launch  ~5 us (pipeline fixed cost per kernel)
//!
//! Efficiency factors account for the small-GEMM regime of Table 6 (the
//! paper's layers run 50-250 us; tensor-core utilization at those sizes
//! is far below peak). Constants were fit once against the paper's FP
//! column and then *frozen*: the claim we reproduce is the per-method
//! speedup shape, not absolute microseconds.

use crate::costmodel::zoo::Layer;

#[derive(Debug, Clone, Copy)]
pub struct Gpu {
    pub fp32_tflops: f64,
    pub fp16_tflops: f64,
    pub int8_tops: f64,
    pub int4_tops: f64,
    pub hbm_gbs: f64,
    pub launch_us: f64,
    /// achievable fraction of peak for the paper's (small) GEMM sizes
    pub gemm_eff: f64,
    /// elementwise/transform kernels are bandwidth-bound; achievable BW frac
    pub ew_eff: f64,
}

pub const RTX_3090: Gpu = Gpu {
    fp32_tflops: 35.6,
    fp16_tflops: 71.0,
    int8_tops: 284.0,
    int4_tops: 568.0,
    hbm_gbs: 936.0,
    launch_us: 2.0,
    gemm_eff: 0.20,
    ew_eff: 0.85,
};

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Precision {
    Fp32,
    Fp16,
    Int8,
    Int4,
}

impl Precision {
    fn tput(self, g: &Gpu) -> f64 {
        match self {
            Precision::Fp32 => g.fp32_tflops * 1e12,
            Precision::Fp16 => g.fp16_tflops * 1e12,
            Precision::Int8 => g.int8_tops * 1e12,
            Precision::Int4 => g.int4_tops * 1e12,
        }
    }

    fn bytes(self) -> f64 {
        match self {
            Precision::Fp32 => 4.0,
            Precision::Fp16 => 2.0,
            Precision::Int8 => 1.0,
            Precision::Int4 => 0.5,
        }
    }
}

/// One simulated kernel dispatch.
#[derive(Debug, Clone)]
pub struct KernelCost {
    pub name: String,
    pub us: f64,
}

fn gemm_us(g: &Gpu, m: usize, n: usize, k: usize, p: Precision) -> f64 {
    let flops = 2.0 * m as f64 * n as f64 * k as f64;
    let bytes = (m as f64 * k as f64 + k as f64 * n as f64) * p.bytes()
        + m as f64 * n as f64 * 4.0; // accum/output in 32-bit
    // SM-utilization penalty for skinny GEMMs: tiles along the smallest
    // output dim can't fill the device (the paper's conv-tail layers with
    // L = 49 run far below peak; this is what makes their FP column cost
    // 110-140 us even at modest FLOP counts).
    let shape_eff = (m.min(n) as f64 / 128.0).clamp(0.35, 1.0);
    let compute = flops / (p.tput(g) * g.gemm_eff * shape_eff);
    let memory = bytes / (g.hbm_gbs * 1e9 * g.ew_eff);
    compute.max(memory) * 1e6 + g.launch_us
}

/// Elementwise / transform pass over `n` elements reading `rb` and
/// writing `wb` bytes per element (+log-n add work for FWHT folded into
/// bandwidth: FWHT is memory-bound at order 16).
fn ew_us(g: &Gpu, n: usize, rb: f64, wb: f64) -> f64 {
    let bytes = n as f64 * (rb + wb);
    bytes / (g.hbm_gbs * 1e9 * g.ew_eff) * 1e6 + g.launch_us
}

/// Full backward pipeline for one layer under a method. Returns the
/// per-module breakdown (Fig 8's five bars).
pub fn pipeline(g: &Gpu, l: &Layer, method: crate::costmodel::Method)
                -> Vec<KernelCost> {
    use crate::costmodel::Method as M;
    let (ll, o, i) = (l.l, l.o, l.i);
    let mut ks = Vec::new();
    match method {
        M::Fp32 => {
            ks.push(KernelCost { name: "gemm_gx(fp32)".into(),
                                 us: gemm_us(g, ll, i, o, Precision::Fp32) });
            ks.push(KernelCost { name: "gemm_gw(fp32)".into(),
                                 us: gemm_us(g, o, i, ll, Precision::Fp32) });
        }
        M::Hot { rank } => {
            // The paper's kernels fuse the quantizer into the transform
            // epilogues ("operator fusion for HT and quantization"), so
            // the pipeline is 5 dispatches; the pseudo-stochastic
            // quantizer's own cost is the int8 write traffic (no extra
            // read pass, no RNG).
            let lc = (ll * rank / 16).max(1);
            // HT on g_y (O dim) + w (O dim): read fp32, write int8 (fused)
            ks.push(KernelCost { name: "ht".into(),
                                 us: ew_us(g, ll * o + o * i, 4.0, 0.0) });
            // HLA projection on g_y + x along L: read fp32, write int8
            // at rank/16 of the rows (fused quant epilogue)
            ks.push(KernelCost { name: "hla".into(),
                                 us: ew_us(g, ll * o + ll * i, 4.0, 0.0) });
            // quant epilogues: the int8 stores of all four operands
            ks.push(KernelCost { name: "quant".into(),
                                 us: (ll * o + o * i + lc * (o + i)) as f64
                                     / (g.hbm_gbs * 1e9 * g.ew_eff) * 1e6 });
            ks.push(KernelCost { name: "gemm_gx(int4)".into(),
                                 us: gemm_us(g, ll, i, o, Precision::Int4) });
            ks.push(KernelCost { name: "gemm_gw(int8)".into(),
                                 us: gemm_us(g, o, i, lc, Precision::Int8) });
            ks.push(KernelCost { name: "dequant".into(),
                                 us: ew_us(g, ll * i + o * i, 4.0, 4.0) });
        }
        M::LbpWht { rank } => {
            let lc = (ll * rank / 16).max(1);
            ks.push(KernelCost { name: "hla".into(),
                                 us: ew_us(g, ll * o + ll * i, 4.0,
                                           4.0 * rank as f64 / 16.0) });
            ks.push(KernelCost { name: "gemm_gx(fp16)".into(),
                                 us: gemm_us(g, lc, i, o, Precision::Fp16) });
            ks.push(KernelCost { name: "expand".into(),
                                 us: ew_us(g, ll * i, 4.0, 4.0) });
            ks.push(KernelCost { name: "gemm_gw(fp16)".into(),
                                 us: gemm_us(g, o, i, lc, Precision::Fp16) });
        }
        M::Luq | M::Int4 => {
            ks.push(KernelCost { name: "quant".into(),
                                 us: ew_us(g, ll * o + o * i + ll * i, 4.0, 1.0) });
            ks.push(KernelCost { name: "gemm_gx(int4)".into(),
                                 us: gemm_us(g, ll, i, o, Precision::Int4) });
            ks.push(KernelCost { name: "gemm_gw(int4)".into(),
                                 us: gemm_us(g, o, i, ll, Precision::Int4) });
            ks.push(KernelCost { name: "dequant".into(),
                                 us: ew_us(g, ll * i + o * i, 4.0, 4.0) });
        }
    }
    ks
}

pub fn total_us(g: &Gpu, l: &Layer, method: crate::costmodel::Method) -> f64 {
    pipeline(g, l, method).iter().map(|k| k.us).sum()
}

/// Average speedup of `method` vs FP32 across a layer list (Table 7's
/// "Acceleration" column).
pub fn avg_speedup(g: &Gpu, layers: &[Layer],
                   method: crate::costmodel::Method) -> f64 {
    let mut acc = 0.0;
    for l in layers {
        acc += total_us(g, l, crate::costmodel::Method::Fp32)
            / total_us(g, l, method);
    }
    acc / layers.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::zoo::{table6_layers, vit_b, Layer};
    use crate::costmodel::Method;

    #[test]
    fn fp_latency_in_paper_band() {
        // paper Table 6 FP column: 111-233 us across all 16 layers
        let g = RTX_3090;
        for (_, l) in table6_layers() {
            let us = total_us(&g, &l, Method::Fp32);
            assert!(us > 20.0 && us < 500.0, "{}: {us}", l.name);
        }
    }

    #[test]
    fn hot_speedup_shape() {
        // paper: 1.6-3.3x per layer, ~2.6x avg on ViT-B
        let g = RTX_3090;
        for (_, l) in table6_layers() {
            let s = total_us(&g, &l, Method::Fp32)
                / total_us(&g, &l, Method::Hot { rank: 8 });
            assert!(s > 1.0, "{}: {s}", l.name);
            assert!(s < 6.0, "{}: {s}", l.name);
        }
    }

    #[test]
    fn hot_beats_lbp_on_vit() {
        // Table 6: HOT outperforms LBP-WHT by a large margin on ViT-B
        let g = RTX_3090;
        let qkv = Layer::new("qkv", 197, 2304, 768);
        let hot = total_us(&g, &qkv, Method::Hot { rank: 8 });
        let lbp = total_us(&g, &qkv, Method::LbpWht { rank: 8 });
        let fp = total_us(&g, &qkv, Method::Fp32);
        assert!(hot < lbp, "hot {hot} lbp {lbp}");
        assert!(lbp < fp, "lbp {lbp} fp {fp}");
    }

    #[test]
    fn fc2_biggest_vit_speedup() {
        // paper: fc2 (197,768,3072) shows the top ViT speedup (3.3x)
        let g = RTX_3090;
        let layers = [
            Layer::new("qkv", 197, 2304, 768),
            Layer::new("proj", 197, 768, 768),
            Layer::new("fc1", 197, 3072, 768),
            Layer::new("fc2", 197, 768, 3072),
        ];
        let speedup = |l: &Layer| {
            total_us(&g, l, Method::Fp32) / total_us(&g, l, Method::Hot { rank: 8 })
        };
        let s_proj = speedup(&layers[1]);
        let s_fc2 = speedup(&layers[3]);
        assert!(s_fc2 > s_proj, "fc2 {s_fc2} proj {s_proj}");
    }

    #[test]
    fn avg_vit_speedup_band() {
        // paper: 2.6x average over ViT-B layers; accept the 1.8-3.5 band
        let g = RTX_3090;
        let layers: Vec<Layer> = vit_b()
            .layers
            .into_iter()
            .filter(|l| l.l > 1)
            .collect();
        let s = avg_speedup(&g, &layers, Method::Hot { rank: 8 });
        assert!(s > 1.8 && s < 3.5, "{s}");
    }

    #[test]
    fn breakdown_has_five_hot_modules() {
        let g = RTX_3090;
        let l = Layer::new("qkv", 197, 2304, 768);
        let ks = pipeline(&g, &l, Method::Hot { rank: 8 });
        assert_eq!(ks.len(), 6); // ht, hla, quant, 2 gemms, dequant
        let gemm: f64 = ks.iter().filter(|k| k.name.contains("gemm"))
            .map(|k| k.us).sum();
        let overhead: f64 = ks.iter().filter(|k| !k.name.contains("gemm"))
            .map(|k| k.us).sum();
        // integer GEMMs must dominate savings; overhead present but modest
        assert!(overhead < gemm * 2.5, "ovh {overhead} gemm {gemm}");
    }
}
