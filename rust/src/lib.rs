//! HOT: Hadamard-based Optimized Training — rust coordinator (L3).
//!
//! Reproduction of Kim et al., "HOT: Hadamard-based Optimized Training"
//! (2025). Architecture (see DESIGN.md):
//!
//!   * python/jax/Pallas author the training graphs at build time and AOT
//!     them to HLO-text artifacts (`make artifacts`);
//!   * this crate loads the artifacts through PJRT (`runtime`), owns the
//!     training loop, ABC context buffers, LQS calibration, data,
//!     metrics and checkpoints (`coordinator`);
//!   * `costmodel` / `latsim` regenerate the paper's analytic
//!     tables/figures; `hadamard` / `quant` mirror kernel semantics
//!     host-side; `util` holds the offline-built substrates.

pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod hadamard;
pub mod latsim;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod util;
