//! HOT: Hadamard-based Optimized Training — rust coordinator (L3).
//!
//! Reproduction of Kim et al., "HOT: Hadamard-based Optimized Training"
//! (2025). Architecture (see DESIGN.md):
//!
//!   * `backend` defines the `Executor` trait ("run a train/fwd/bwd/opt
//!     step") with two implementations: the pure-rust `NativeBackend`
//!     (default — self-contained, no artifacts) and, behind the `pjrt`
//!     feature, the AOT-artifact `runtime::Runtime` authored by
//!     python/jax/Pallas (`make artifacts`);
//!   * `coordinator` owns the training loop, ABC context buffers, LQS
//!     calibration, data, metrics and checkpoints — backend-agnostic;
//!   * `kernels` is the native compute layer: blocked multi-threaded
//!     GEMM (f32 / INT8 / INT4-nibble), fused FWHT+quant epilogues and
//!     the `--threads` work-stealing pool every hot path routes through;
//!   * `costmodel` / `latsim` regenerate the paper's analytic
//!     tables/figures; `hadamard` / `quant` mirror kernel semantics
//!     host-side (both backends share them); `util` holds the
//!     offline-built substrates.

pub mod backend;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod data;
pub mod hadamard;
pub mod kernels;
pub mod latsim;
pub mod obs;
pub mod quant;
pub mod resilience;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;
