//! `hot` — leader binary for the HOT reproduction.
//!
//! Subcommands:
//!   train       run a training job (fused / split / accum modes)
//!   calibrate   run LQS calibration only and print the report
//!   eval        evaluate a checkpoint (or the init params)
//!   infer       inference-only: load a checkpoint into a frozen
//!               WeightStore and run batched logits (no TrainState,
//!               no ctx writes, no quantization)
//!   bench       run the statistical bench suites (kernels / e2e),
//!               write schema-v2 BENCH_*.json, optionally --check
//!               against committed baselines (nonzero exit on
//!               regression)
//!   memory      print the analytic memory model for a zoo architecture
//!   latency     print the Table-6 latency simulation
//!   info        list presets / step keys of the selected backend
//!   runhlo      (pjrt builds) run an arbitrary HLO text file
//!
//! `--backend native|pjrt|auto` selects the execution backend (default
//! auto: PJRT when compiled in and artifacts exist, else native).

use std::sync::Arc;

use anyhow::{bail, Result};

use hot::backend::Executor;
use hot::config::RunConfig;
use hot::coordinator::{Mode, Trainer};
use hot::util::args::Args;
use hot::util::timer::Table;

fn main() -> Result<()> {
    hot::util::log::init_from_env();
    hot::obs::init_from_env();
    hot::resilience::fault::init_from_env()?;
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("eval") => cmd_eval(&args),
        Some("infer") => cmd_infer(&args),
        Some("serve") => cmd_serve(&args),
        Some("ckpt") => cmd_ckpt(&args),
        Some("bench") => cmd_bench(&args),
        Some("memory") => cmd_memory(&args),
        Some("latency") => cmd_latency(&args),
        Some("info") => cmd_info(&args),
        Some("runhlo") => cmd_runhlo(&args),
        _ => {
            eprintln!(
                "usage: hot <train|calibrate|eval|infer|serve|ckpt|bench|memory|latency|info> [--opts]\n\
                 common: --backend native|pjrt|auto --artifacts DIR\n\
                         --preset NAME --variant V --steps N --batch N\n\
                         --lr F --mode fused|split|accum --accum N\n\
                         --threads N --seed N --config run.json\n\
                         --trace-out trace.json (Chrome-trace; HOT_TRACE=1\n\
                         enables counters without the event dump)\n\
                 train:  --checkpoint-dir DIR --checkpoint-every N\n\
                         --keep-last K --max-rollbacks N --no-sentinel\n\
                         --resume [CKPT.json] (bare --resume: newest valid\n\
                         checkpoint in --checkpoint-dir)\n\
                 infer:  hot infer CKPT.json | --resume CKPT.json |\n\
                         --checkpoint-dir DIR (newest); --batches N\n\
                 serve:  --checkpoint-dir DIR (newest; else init weights)\n\
                         --tenants N --requests N --max-queue N\n\
                         --deadline-ms N --max-batch N --window-ms N\n\
                         --workers N (multi-tenant serving smoke: drives\n\
                         synthetic traffic, prints p50/p99 + req/s, exits\n\
                         nonzero on any non-finite logit)\n\
                 ckpt:   hot ckpt verify|list --checkpoint-dir DIR\n\
                 bench:  --suite kernels|e2e|serve|all --smoke --out DIR\n\
                         --check BASELINE_DIR --report report.md"
            );
            Ok(())
        }
    }
}

fn run_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.get("config")? {
        Some(path) => RunConfig::load(path)?,
        None => RunConfig::default(),
    };
    if let Some(v) = args.get("artifacts")? {
        cfg.artifacts = v.into();
    }
    if let Some(v) = args.get("preset")? {
        cfg.preset = v.into();
    }
    if let Some(v) = args.get("variant")? {
        cfg.variant = v.into();
    }
    cfg.steps = args.usize_or("steps", cfg.steps)?;
    cfg.batch = args.usize_or("batch", cfg.batch)?;
    cfg.lr = args.f64_or("lr", cfg.lr)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.accum = args.usize_or("accum", cfg.accum)?;
    cfg.calib_batches = args.usize_or("calib-batches", cfg.calib_batches)?;
    cfg.mem_budget = args.u64_or("mem-budget", cfg.mem_budget)?;
    cfg.eval_every = args.usize_or("eval-every", cfg.eval_every)?;
    cfg.data_noise = args.f64_or("data-noise", cfg.data_noise)?;
    if let Some(d) = args.get("checkpoint-dir")? {
        cfg.checkpoint_dir = Some(d.into());
    }
    cfg.checkpoint_every = args.usize_or("checkpoint-every",
                                         cfg.checkpoint_every)?;
    cfg.keep_last = args.usize_or("keep-last", cfg.keep_last)?;
    cfg.max_rollbacks = args.usize_or("max-rollbacks", cfg.max_rollbacks)?;
    if args.flag("no-sentinel") {
        cfg.sentinel = false;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn executor(args: &Args, cfg: &RunConfig) -> Result<Arc<dyn Executor>> {
    let backend = args.str_or("backend", "auto")?;
    let rt =
        hot::backend::by_name_threaded(&backend, &cfg.artifacts,
                                       args.threads()?)?;
    hot::info!("backend: {} ({} kernel threads)", rt.name(),
               hot::kernels::num_threads());
    Ok(rt)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = run_config(args)?;
    let mode = match args.str_or("mode", "fused")?.as_str() {
        "fused" => Mode::Fused,
        "split" => Mode::Split,
        "accum" => Mode::Accum,
        m => bail!("unknown mode {m:?}"),
    };
    let rt = executor(args, &cfg)?;
    let mut tr = Trainer::new(rt, cfg)?;
    let trace_out = args.get("trace-out")?.map(String::from);
    if trace_out.is_some() {
        // --trace-out implies tracing and keeps the raw span events
        hot::obs::set_trace_enabled(true);
        tr.keep_trace = true;
    }
    if let Some(ck) = args.get_optional("resume") {
        tr.resume(ck)?;
    } else if args.flag("resume") {
        // bare --resume: newest valid checkpoint in --checkpoint-dir,
        // walking past corrupt/torn candidates; fresh run if none
        tr.resume_auto()?;
    }
    let fin = tr.train_mode(mode)?;
    if let Some((l, a)) = fin {
        println!("final eval: loss {l:.4} acc {a:.4}");
    }
    println!("mean step time: {:.4}s ({:.2} steps/s)",
             tr.metrics.mean_step_time(), tr.metrics.throughput_steps_per_s());
    println!("ctx: peak {} B ({} B fp32-equivalent), compression {:.2}x",
             tr.state.ctx.stats().peak_bytes,
             tr.state.ctx.stats().fp32_equiv_bytes,
             tr.state.ctx.compression_ratio());
    if let Some(csv) = args.get("csv")? {
        tr.metrics.save_csv(csv)?;
        println!("metrics -> {csv}");
    }
    if let Some(path) = trace_out {
        hot::obs::chrome::write_trace(&path, &tr.trace)?;
        println!("trace -> {path} ({} events)", tr.trace.len());
        let telem = tr.quant_telemetry();
        for (name, err) in telem.ranked().into_iter().take(5) {
            println!("quant err {name}: {err:.3e}");
        }
    }
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let cfg = run_config(args)?;
    let rt = executor(args, &cfg)?;
    let mut tr = Trainer::new(rt, cfg)?;
    match tr.calibrate()? {
        None => println!("backend cannot calibrate this preset"),
        Some(rep) => {
            let mut t = Table::new(&["layer", "mse_tensor", "mse_token",
                                     "outlier", "LQS"]);
            for (l, m) in rep.layers.iter().zip(rep.lqs_mask()) {
                t.row(&[
                    l.name.clone(),
                    format!("{:.3e}", l.mse_tensor),
                    format!("{:.3e}", l.mse_token),
                    format!("{:.2}", l.outlier_ratio),
                    if m > 0.5 { "per-token".into() } else { "per-tensor".into() },
                ]);
            }
            t.print("LQS calibration (paper §5.2.2)");
        }
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let cfg = run_config(args)?;
    let rt = executor(args, &cfg)?;
    let mut tr = Trainer::new(rt, cfg)?;
    if let Some(ck) = args.get("resume")? {
        tr.resume(ck)?;
    }
    let (l, a) = tr.eval(args.usize_or("batches", 8)?)?;
    println!("eval: loss {l:.4} acc {a:.4}");
    Ok(())
}

/// `hot infer`: the inference-only path. Loads a checkpoint straight
/// into a frozen `WeightStore` (no optimizer moments, no ctx store) and
/// runs batched logits through `Executor::infer` — the ctx-free forward
/// walk. Checkpoint resolution: positional header path, `--resume`, or
/// the newest header under `--checkpoint-dir`; with none of those it
/// serves the backend's init weights.
fn cmd_infer(args: &Args) -> Result<()> {
    use hot::coordinator::{Checkpoint, DataSource};
    use hot::data::{LmDataset, VisionDataset};
    let cfg = run_config(args)?;
    let rt = executor(args, &cfg)?;
    let preset = rt.preset(&cfg.preset)?;
    let key = format!("infer_{}", cfg.preset);
    if !rt.supports(&key) {
        bail!("backend {} has no inference path for preset {}",
              rt.name(), cfg.preset);
    }

    let header = args
        .positional
        .first()
        .cloned()
        .or_else(|| args.get_optional("resume").map(String::from))
        .or_else(|| cfg.checkpoint_dir.as_deref().and_then(Checkpoint::latest));
    let weights = match header {
        Some(h) => {
            let ck = Checkpoint::load(&h, &preset.params)?;
            if ck.preset != cfg.preset {
                bail!("checkpoint preset {} != configured {}", ck.preset,
                      cfg.preset);
            }
            hot::info!("weights <- {h} (step {})", ck.step);
            ck.weights
        }
        None => {
            hot::info!("no checkpoint given; serving init weights");
            rt.init_store(&cfg.preset)?
        }
    };

    let data = match preset.model.arch.as_str() {
        "lm" => DataSource::Lm(LmDataset::new(preset.model.seq,
                                              preset.model.in_dim, cfg.seed)),
        _ => DataSource::Vision(VisionDataset::new(
            preset.model.seq, preset.model.in_dim, preset.model.n_classes,
            cfg.seed)),
    };
    let batches = args.usize_or("batches", 4)?;
    let batch = rt.key_batch(&key).unwrap_or(cfg.batch).max(1);
    let mut rows = 0usize;
    for b in 0..batches {
        let (x, _) = data.batch(1, b as u64, batch);
        let logits = rt.infer(&key, &weights, &x)?;
        let d = logits.as_f32()?;
        if let Some(bad) = d.iter().find(|v| !v.is_finite()) {
            bail!("non-finite logit {bad} in batch {b}");
        }
        rows += d.len() / logits.shape().last().copied().unwrap_or(1).max(1);
    }
    println!("infer: {batches} batches x {batch} ok \
              ({rows} logit rows, all finite, {} weight bytes shared)",
             weights.total_bytes());
    Ok(())
}

/// `hot serve`: stand up the fail-safe multi-tenant server over the
/// native backend and drive synthetic per-tenant traffic through it —
/// the in-process serving smoke CI runs. Weights come from the newest
/// checkpoint under `--checkpoint-dir` (manifest/CRC-verified) or the
/// backend's init weights. Prints p50/p99 latency, req/s and the
/// shed/expired/panic tallies; exits nonzero if any served logit is
/// non-finite or every request failed. `HOT_FAULT` serve plans
/// (slow-request/panic-in-batch/corrupt-adapter) apply — the chaos CI
/// leg runs the fault matrix through exactly this entry point.
fn cmd_serve(args: &Args) -> Result<()> {
    use std::time::{Duration, Instant};

    use hot::coordinator::{Checkpoint, DataSource};
    use hot::data::{LmDataset, VisionDataset};
    use hot::serve::{Registry, ServeCfg, ServeError, Server};

    let cfg = run_config(args)?;
    let rt = executor(args, &cfg)?;
    let preset = rt.preset(&cfg.preset)?;
    let key = format!("infer_{}", cfg.preset);
    if !rt.supports(&key) {
        bail!("backend {} has no inference path for preset {}", rt.name(),
              cfg.preset);
    }
    let tenants = args.usize_or("tenants", 2)?.max(1);
    let requests = args.usize_or("requests", 64)?.max(1);
    let serve_cfg = ServeCfg {
        preset: cfg.preset.clone(),
        max_queue: args.usize_or("max-queue", 256)?,
        deadline: Duration::from_millis(args.u64_or("deadline-ms", 2000)?),
        max_batch: args.usize_or("max-batch", 8)?,
        window: Duration::from_millis(args.u64_or("window-ms", 2)?),
        workers: args.usize_or("workers", 2)?,
        ..ServeCfg::default()
    };

    let weights = match cfg.checkpoint_dir.as_deref()
        .and_then(Checkpoint::latest)
    {
        Some(h) => {
            let ck = Checkpoint::load(&h, &preset.params)?;
            if ck.preset != cfg.preset {
                bail!("checkpoint preset {} != configured {}", ck.preset,
                      cfg.preset);
            }
            hot::info!("serving weights <- {h} (step {})", ck.step);
            ck.weights
        }
        None => {
            hot::info!("no checkpoint; serving init weights");
            rt.init_store(&cfg.preset)?
        }
    };

    let reg = Registry::new(weights, &cfg.preset);
    for t in 0..tenants {
        reg.register(&format!("tenant-{t}"))?;
    }
    let srv = Server::start(reg, serve_cfg);
    let data = match preset.model.arch.as_str() {
        "lm" => DataSource::Lm(LmDataset::new(preset.model.seq,
                                              preset.model.in_dim,
                                              cfg.seed)),
        _ => DataSource::Vision(VisionDataset::new(
            preset.model.seq, preset.model.in_dim, preset.model.n_classes,
            cfg.seed)),
    };

    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(requests);
    for i in 0..requests {
        let (x, _) = data.batch(1, i as u64, 1);
        let sent = Instant::now();
        let rx = srv.submit(&format!("tenant-{}", i % tenants), x);
        pending.push((sent, rx));
    }
    let mut lat: Vec<f64> = Vec::new();
    let (mut served, mut shed, mut expired, mut panicked, mut other) =
        (0usize, 0usize, 0usize, 0usize, 0usize);
    for (sent, rx) in pending {
        match rx.recv_timeout(Duration::from_secs(120)) {
            Ok(Ok(logits)) => {
                if let Some(bad) =
                    logits.as_f32()?.iter().find(|v| !v.is_finite())
                {
                    srv.shutdown();
                    bail!("non-finite served logit {bad}");
                }
                served += 1;
                lat.push(sent.elapsed().as_secs_f64());
            }
            Ok(Err(ServeError::Overloaded { .. }))
            | Ok(Err(ServeError::ShuttingDown)) => shed += 1,
            Ok(Err(ServeError::DeadlineExceeded { .. })) => expired += 1,
            Ok(Err(ServeError::PanicInForward)) => panicked += 1,
            Ok(Err(e)) => {
                hot::warn_!("request refused: {e}");
                other += 1;
            }
            Err(e) => {
                srv.shutdown();
                bail!("reply channel lost (worker died unreplaced?): {e}");
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    srv.shutdown();
    let stats = srv.stats();
    if served == 0 {
        bail!("no request was served: {shed} shed, {expired} expired, \
               {panicked} panicked, {other} refused");
    }
    lat.sort_by(f64::total_cmp);
    let pct =
        |q: f64| lat[((lat.len() - 1) as f64 * q).round() as usize] * 1e3;
    println!("serve: {served}/{requests} ok across {tenants} tenants \
              (p50 {:.2} ms, p99 {:.2} ms, {:.1} req/s; shed {shed}, \
              expired {expired}, panicked {panicked}, refused {other}; \
              max queue depth {}, {} batches, {} degraded, {} workers \
              replaced); clean shutdown",
             pct(0.50), pct(0.99), served as f64 / wall.max(1e-9),
             stats.queue_max_depth, stats.batches, stats.degraded_batches,
             stats.workers_replaced);
    Ok(())
}

/// `hot ckpt verify|list`: inspect a checkpoint directory. `list`
/// prints each candidate's manifest status; `verify` additionally
/// checks every blob (sizes, whole-blob CRCs, per-tensor extent CRCs
/// against the preset's live specs) and prints a machine-readable
/// `latest_valid_step=N` line — CI's kill/resume smoke parses it.
/// Exits nonzero when `verify` finds no valid checkpoint at all.
fn cmd_ckpt(args: &Args) -> Result<()> {
    use hot::coordinator::Checkpoint;
    use hot::resilience::manifest::CkptManifest;
    use hot::resilience::store::candidates;
    let action = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "verify".to_string());
    if !matches!(action.as_str(), "verify" | "list") {
        bail!("hot ckpt wants verify|list, got {action:?}");
    }
    let cfg = run_config(args)?;
    let Some(dir) = cfg.checkpoint_dir.clone() else {
        bail!("hot ckpt needs --checkpoint-dir DIR");
    };
    let rt = executor(args, &cfg)?;
    let cands = candidates(&dir);
    if cands.is_empty() {
        bail!("no checkpoint candidates in {dir}");
    }
    let mut t = Table::new(&["step", "preset", "status"]);
    let mut latest_valid: Option<usize> = None;
    for c in &cands {
        let step = format!("{}", c.step);
        let Some(header) = &c.header else {
            t.row(&[step, "-".into(),
                    "TORN: blobs without a manifest (crash during \
                     save)".into()]);
            continue;
        };
        let man = match CkptManifest::read(header) {
            Ok(m) => m,
            Err(r) => {
                t.row(&[step, "-".into(), format!("REJECT: {r}")]);
                continue;
            }
        };
        if action == "list" {
            t.row(&[step, man.preset.clone(),
                    format!("manifest ok: variant {} tier {} seed {} \
                             eval {}", man.variant, man.simd_tier, man.seed,
                            man.eval_loss.map_or("-".into(),
                                                 |l| format!("{l:.4}")))]);
            continue;
        }
        let preset = match rt.preset(&man.preset) {
            Ok(p) => p,
            Err(e) => {
                t.row(&[step, man.preset.clone(),
                        format!("REJECT: unknown preset ({e})")]);
                continue;
            }
        };
        match Checkpoint::load_verified(header, &preset.params) {
            Ok((_, m)) => {
                latest_valid = Some(c.step);
                t.row(&[step, m.preset.clone(),
                        format!("ok: {} blobs verified, variant {} tier {}",
                                m.blobs.len(), m.variant, m.simd_tier)]);
            }
            Err(r) => t.row(&[step, man.preset.clone(),
                              format!("REJECT: {r}")]),
        }
    }
    t.print(&format!("checkpoints in {dir}"));
    if action == "verify" {
        match latest_valid {
            Some(s) => println!("latest_valid_step={s}"),
            None => bail!("no valid checkpoint in {dir}"),
        }
    }
    Ok(())
}

/// `hot bench`: run the statistical bench suites through the shared
/// harness (`hot::bench`), write schema-v2 `BENCH_*.json`, and — with
/// `--check DIR` — diff against committed baselines with noise-aware
/// per-cell tolerances, exiting nonzero on regression or schema drift.
/// `--smoke` (or the `HOT_BENCH_STEPS` env convention) selects the CI
/// sizing: small shapes, fixed iteration counts, same schema.
fn cmd_bench(args: &Args) -> Result<()> {
    let smoke =
        args.flag("smoke") || std::env::var("HOT_BENCH_STEPS").is_ok();
    let suite = args.str_or("suite", "all")?;
    let out_dir = args.str_or("out", ".")?;
    let check = args.get("check")?.map(String::from);
    let report_path = args.get("report")?.map(String::from);
    if !matches!(suite.as_str(), "kernels" | "e2e" | "serve" | "all") {
        bail!("--suite wants kernels|e2e|serve|all, got {suite:?}");
    }
    hot::kernels::set_num_threads(args.threads()?);
    let mut reports = Vec::new();
    if suite == "kernels" || suite == "all" {
        reports.push(hot::bench::suites::run_kernels(smoke));
    }
    if suite == "e2e" || suite == "all" {
        let cfg = run_config(args)?;
        let rt = executor(args, &cfg)?;
        let steps = args.usize_or("steps", if smoke { 6 } else { 12 })?;
        reports.push(hot::bench::suites::run_e2e(rt, smoke, steps)?);
    }
    if suite == "serve" || suite == "all" {
        reports.push(hot::bench::suites::run_serve(smoke)?);
    }
    let mut failed = false;
    let mut md = String::new();
    for rep in &reports {
        let fname = format!("BENCH_{}.json", rep.bench);
        let out_path = if out_dir == "." {
            fname.clone()
        } else {
            std::fs::create_dir_all(&out_dir)?;
            format!("{out_dir}/{fname}")
        };
        rep.save(&out_path)?;
        println!("wrote {out_path}");
        let Some(base_dir) = &check else { continue };
        // --check PATH: a directory of baselines, or a single file
        let base_path = if std::path::Path::new(base_dir).is_dir() {
            format!("{base_dir}/{fname}")
        } else {
            base_dir.clone()
        };
        let base = match hot::bench::BenchReport::load(&base_path) {
            Ok(b) => b,
            Err(e) => {
                hot::warn_!("no comparable baseline at {base_path}: {e}");
                continue;
            }
        };
        let outcome = hot::bench::compare(&base, rep);
        print!("{}", outcome.render_terminal());
        md.push_str(&outcome.render_markdown());
        md.push('\n');
        failed |= outcome.failed();
    }
    if let Some(p) = &report_path {
        std::fs::write(p, &md)?;
        println!("report -> {p}");
    }
    if failed {
        bail!("bench check FAILED: regression or schema mismatch \
               against the baseline (see report above)");
    }
    Ok(())
}

fn cmd_memory(args: &Args) -> Result<()> {
    use hot::costmodel::{breakdown, zoo, MemMethod};
    let model = args.str_or("model", "vit_b")?;
    let batch = args.usize_or("batch", 256)?;
    let spec = match model.as_str() {
        "vit_b" => zoo::vit_b(),
        "vit_s" => zoo::vit_s(),
        "resnet50" => zoo::resnet50(),
        "resnet18" => zoo::resnet18(),
        "efficientformer_l7" => zoo::efficientformer_l7(),
        "efficientformer_l1" => zoo::efficientformer_l1(),
        m => bail!("unknown zoo model {m:?}"),
    };
    let mut t = Table::new(&["method", "weights", "grads", "optimizer",
                             "activations", "attn", "total GB"]);
    for (name, m) in [
        ("FP", MemMethod::Fp32),
        ("LBP-WHT/LUQ", MemMethod::FpActivations),
        ("LoRA", MemMethod::Lora { r_lora: 8 }),
        ("HOT", MemMethod::Hot { rank: 8, abc: true }),
        ("HOT+LoRA", MemMethod::HotLora { rank: 8, r_lora: 8 }),
    ] {
        let b = breakdown(&spec, batch, m);
        let gb = |x: u64| format!("{:.2}", x as f64 / (1u64 << 30) as f64);
        t.row(&[name.into(), gb(b.weights), gb(b.gradients), gb(b.optimizer),
                gb(b.activations), gb(b.attention), format!("{:.2}", b.gb())]);
    }
    t.print(&format!("{} @ batch {batch} (GB)", spec.name));
    Ok(())
}

fn cmd_latency(args: &Args) -> Result<()> {
    use hot::costmodel::zoo::table6_layers;
    use hot::costmodel::Method;
    use hot::latsim::{total_us, RTX_3090};
    let _ = args;
    let mut t = Table::new(&["model", "(L,O,I)", "layer", "FP us",
                             "LBP us", "HOT us", "speedup"]);
    for (model, l) in table6_layers() {
        let fp = total_us(&RTX_3090, &l, Method::Fp32);
        let lbp = total_us(&RTX_3090, &l, Method::LbpWht { rank: 8 });
        let hotl = total_us(&RTX_3090, &l, Method::Hot { rank: 8 });
        t.row(&[model, format!("({},{},{})", l.l, l.o, l.i), l.name.clone(),
                format!("{fp:.0}"), format!("{lbp:.0}"), format!("{hotl:.0}"),
                format!("{:.1}x", fp / hotl)]);
    }
    t.print("Table 6 — simulated RTX-3090 backward latency");
    Ok(())
}

/// Debug tool: run an arbitrary HLO text file with seeded-random inputs.
/// `hot runhlo file.hlo.txt f32:64x64 f32:64x48`
#[cfg(feature = "pjrt")]
fn cmd_runhlo(args: &Args) -> Result<()> {
    use hot::util::prng::Pcg32;
    let file = args.positional.first().expect("hlo file");
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e}"))?;
    let proto = xla::HloModuleProto::from_text_file(file)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let exe = client
        .compile(&xla::XlaComputation::from_proto(&proto))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let mut rng = Pcg32::seeded(args.u64_or("seed", 0)?);
    let mut lits = Vec::new();
    for spec in &args.positional[1..] {
        let (ty, dims) = spec.split_once(':').expect("ty:dims");
        let dims: Vec<usize> = dims.split('x').map(|d| d.parse().unwrap()).collect();
        let n: usize = dims.iter().product();
        let v = match ty {
            "f32" => hot::runtime::Value::F32 {
                shape: dims,
                data: (0..n).map(|_| rng.normal()).collect(),
            },
            "i32" => hot::runtime::Value::I32 { shape: dims, data: vec![1; n] },
            t => bail!("bad ty {t}"),
        };
        lits.push(v.to_literal()?);
    }
    let out = exe
        .execute::<xla::Literal>(&lits)
        .map_err(|e| anyhow::anyhow!("{e}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let parts = out.to_tuple().map_err(|e| anyhow::anyhow!("{e}"))?;
    for (i, p) in parts.iter().enumerate() {
        let v = hot::runtime::Value::from_literal(p)?;
        match v {
            hot::runtime::Value::F32 { ref data, ref shape } => {
                let head: Vec<f32> = data.iter().take(4).copied().collect();
                let sum: f64 = data.iter().map(|x| x.abs() as f64).sum();
                println!("out{i}: f32 {shape:?} head={head:?} sum|x|={sum:.3}");
            }
            other => println!("out{i}: {:?} {:?}", other.dtype(), other.shape()),
        }
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_runhlo(_args: &Args) -> Result<()> {
    bail!("runhlo needs the `pjrt` feature — rebuild with --features pjrt")
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg = run_config(args)?;
    let rt = executor(args, &cfg)?;
    println!("{}", rt.describe());
    for name in rt.preset_names() {
        let p = rt.preset(&name)?;
        println!("preset {name}: arch={} d={} depth={} seq={} params={}",
                 p.model.arch, p.model.d_model, p.model.depth, p.model.seq,
                 p.n_params());
    }
    println!("default batch: {}", rt.default_batch());
    Ok(())
}
