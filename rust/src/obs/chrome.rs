//! Chrome-trace (Perfetto-compatible) export of drained span events
//! through `util::json` — load the file at `chrome://tracing` or
//! https://ui.perfetto.dev.
//!
//! Field mapping (the Trace Event Format's "complete" events):
//!   name = span registry name, cat = "hot", ph = "X", pid = 1,
//!   tid = obs thread index, ts/dur = microseconds (f64, from ns).

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::obs::TraceEvent;
use crate::util::json::Json;

const PID: f64 = 1.0;

fn complete_event(ev: &TraceEvent) -> Json {
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(ev.name().to_string()));
    m.insert("cat".to_string(), Json::Str("hot".to_string()));
    m.insert("ph".to_string(), Json::Str("X".to_string()));
    m.insert("pid".to_string(), Json::Num(PID));
    m.insert("tid".to_string(), Json::Num(ev.tid as f64));
    m.insert("ts".to_string(), Json::Num(ev.start_ns as f64 / 1e3));
    m.insert("dur".to_string(), Json::Num(ev.dur_ns() as f64 / 1e3));
    Json::Obj(m)
}

fn metadata_event(name: &str, tid: f64, arg: &str) -> Json {
    let mut args = BTreeMap::new();
    args.insert("name".to_string(), Json::Str(arg.to_string()));
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(name.to_string()));
    m.insert("ph".to_string(), Json::Str("M".to_string()));
    m.insert("pid".to_string(), Json::Num(PID));
    m.insert("tid".to_string(), Json::Num(tid));
    m.insert("args".to_string(), Json::Obj(args));
    Json::Obj(m)
}

/// Build the trace document: one metadata block (process/thread names)
/// followed by every span event, preserving drain order (per-thread
/// end-time order).
pub fn trace_json(events: &[TraceEvent]) -> Json {
    let mut arr = vec![metadata_event("process_name", 0.0, "hot")];
    let mut tids: Vec<u32> = events.iter().map(|e| e.tid).collect();
    tids.sort_unstable();
    tids.dedup();
    for tid in tids {
        let label =
            if tid == 0 { "main".to_string() } else { format!("pool-{tid}") };
        arr.push(metadata_event("thread_name", tid as f64, &label));
    }
    arr.extend(events.iter().map(complete_event));
    let mut root = BTreeMap::new();
    root.insert("traceEvents".to_string(), Json::Arr(arr));
    root.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    Json::Obj(root)
}

/// Write `events` to `path` as Chrome-trace JSON.
pub fn write_trace(path: &str, events: &[TraceEvent]) -> Result<()> {
    std::fs::write(path, trace_json(events).to_string())
        .with_context(|| format!("writing trace to {path}"))
}

/// A lenient parse result: the events this tooling understood, plus a
/// count of the ones it did not.
#[derive(Debug, Clone, PartialEq)]
pub struct ParsedTrace {
    pub events: Vec<TraceEvent>,
    /// Events skipped because their phase, span name, or fields were
    /// not understood. Traces from newer writers (extra event types,
    /// spans this build does not know) stay loadable — a nonzero count
    /// tells the caller the view is partial rather than failing it.
    pub skipped: usize,
}

/// Parse a trace document back into the events this build understands.
///
/// A structurally malformed document (no `traceEvents` array) is an
/// error; an individually unknown event — a foreign `ph`, a span name
/// outside this build's registry, missing or negative `ts`/`dur`/`tid`
/// — is skipped and counted, so traces written by newer code remain
/// loadable by older tooling. `ph == "M"` metadata is expected and not
/// counted as skipped.
pub fn parse_trace(j: &Json) -> Result<ParsedTrace> {
    let arr = j
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .context("trace: missing traceEvents array")?;
    let mut out = ParsedTrace { events: Vec::new(), skipped: 0 };
    for ev in arr {
        let ph = ev.get("ph").and_then(|v| v.as_str()).unwrap_or("");
        if ph == "M" {
            continue; // expected metadata (process/thread names)
        }
        if ph != "X" {
            out.skipped += 1; // foreign event type from a newer writer
            continue;
        }
        let known = (|| {
            let name = ev.get("name")?.as_str()?;
            let span =
                crate::obs::SPAN_NAMES.iter().position(|&n| n == name)?;
            let tid = ev.get("tid")?.as_i64()?;
            let ts = ev.get("ts")?.as_f64()?;
            let dur = ev.get("dur")?.as_f64()?;
            if tid < 0 || ts < 0.0 || dur < 0.0 || ts.is_nan() || dur.is_nan()
            {
                return None;
            }
            Some(TraceEvent {
                span: span as u8,
                tid: tid as u32,
                start_ns: (ts * 1e3).round() as u64,
                end_ns: ((ts + dur) * 1e3).round() as u64,
            })
        })();
        match known {
            Some(e) => out.events.push(e),
            None => out.skipped += 1,
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Span;

    fn ev(span: Span, tid: u32, start: u64, end: u64) -> TraceEvent {
        TraceEvent { span: span as u8, tid, start_ns: start, end_ns: end }
    }

    #[test]
    fn export_roundtrips_through_the_parser() {
        let events = vec![
            ev(Span::TrainStep, 0, 1_000, 9_000),
            ev(Span::GemmF32, 0, 2_000, 4_000),
            ev(Span::PoolTask, 1, 2_500, 3_500),
            ev(Span::OptStep, 0, 8_000, 9_000),
        ];
        let doc = trace_json(&events);
        // serialize -> reparse -> re-extract: everything survives
        let text = doc.to_string();
        let back = Json::parse(&text).unwrap();
        let got = parse_trace(&back).unwrap();
        assert_eq!(got.events, events);
        assert_eq!(got.skipped, 0, "own exports must parse losslessly");
        // schema essentials are present
        let arr = back.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(arr.len() > events.len(), "metadata + span events");
        let first_x =
            arr.iter().find(|e| e.get("ph").unwrap().as_str() == Some("X"))
                .unwrap();
        assert_eq!(first_x.get("cat").unwrap().as_str(), Some("hot"));
        assert_eq!(first_x.get("name").unwrap().as_str(),
                   Some("train_step"));
        assert_eq!(first_x.get("ts").unwrap().as_f64(), Some(1.0));
        assert_eq!(first_x.get("dur").unwrap().as_f64(), Some(8.0));
    }

    #[test]
    fn parser_skips_unknown_events_with_count() {
        // unknown span name, foreign phase, and missing fields are each
        // skipped and counted — never an error (forward compatibility
        // with newer writers); a malformed document still errors
        let j = Json::parse(
            r#"{"traceEvents":[
                 {"name":"bogus","ph":"X","pid":1,"tid":0,"ts":0,"dur":1},
                 {"name":"flow","ph":"s","pid":1,"tid":0,"ts":0,"id":7},
                 {"name":"gemm_f32","ph":"X","pid":1,"tid":0,"ts":0},
                 {"name":"gemm_f32","ph":"X","pid":1,"tid":0,"ts":-4,
                  "dur":1},
                 {"name":"gemm_f32","ph":"X","pid":1,"tid":0,"ts":2,
                  "dur":3}]}"#,
        )
        .unwrap();
        let got = parse_trace(&j).unwrap();
        assert_eq!(got.skipped, 4,
                   "unknown span + foreign ph + missing dur + negative ts");
        assert_eq!(got.events.len(), 1);
        assert_eq!(got.events[0].name(), "gemm_f32");
        let j = Json::parse(r#"{"notTraceEvents":[]}"#).unwrap();
        assert!(parse_trace(&j).is_err(), "malformed document must error");
    }

    #[test]
    fn roundtrip_survives_injected_foreign_event() {
        // a trace written by a hypothetical newer writer: our events
        // plus an event type (ph "C" counter sample) and a span name
        // this build has never heard of
        let events = vec![ev(Span::TrainStep, 0, 1_000, 9_000),
                          ev(Span::GemmI8, 1, 2_000, 2_500)];
        let doc = trace_json(&events);
        let mut arr = doc.get("traceEvents").unwrap().as_arr().unwrap()
            .to_vec();
        let foreign = Json::parse(
            r#"{"name":"gpu_mem","ph":"C","pid":1,"tid":0,"ts":5,
                "args":{"bytes":123}}"#,
        )
        .unwrap();
        let newer_span = Json::parse(
            r#"{"name":"span_from_the_future","ph":"X","pid":1,"tid":0,
                "ts":1,"dur":2}"#,
        )
        .unwrap();
        arr.insert(1, foreign);
        arr.push(newer_span);
        let mut root = std::collections::BTreeMap::new();
        root.insert("traceEvents".to_string(), Json::Arr(arr));
        let back =
            Json::parse(&Json::Obj(root).to_string()).unwrap();
        let got = parse_trace(&back).unwrap();
        assert_eq!(got.events, events,
                   "known events survive around foreign ones");
        assert_eq!(got.skipped, 2, "both foreign events counted");
    }

    #[test]
    fn thread_names_cover_every_tid() {
        let events =
            vec![ev(Span::PoolTask, 0, 0, 1), ev(Span::PoolTask, 3, 0, 1)];
        let doc = trace_json(&events);
        let arr = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let meta_tids: Vec<i64> = arr
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("M")
                    && e.get("name").unwrap().as_str()
                        == Some("thread_name"))
            .map(|e| e.get("tid").unwrap().as_i64().unwrap())
            .collect();
        assert_eq!(meta_tids, vec![0, 3]);
    }
}
