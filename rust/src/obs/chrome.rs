//! Chrome-trace (Perfetto-compatible) export of drained span events
//! through `util::json` — load the file at `chrome://tracing` or
//! https://ui.perfetto.dev.
//!
//! Field mapping (the Trace Event Format's "complete" events):
//!   name = span registry name, cat = "hot", ph = "X", pid = 1,
//!   tid = obs thread index, ts/dur = microseconds (f64, from ns).

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::obs::TraceEvent;
use crate::util::json::Json;

const PID: f64 = 1.0;

fn complete_event(ev: &TraceEvent) -> Json {
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(ev.name().to_string()));
    m.insert("cat".to_string(), Json::Str("hot".to_string()));
    m.insert("ph".to_string(), Json::Str("X".to_string()));
    m.insert("pid".to_string(), Json::Num(PID));
    m.insert("tid".to_string(), Json::Num(ev.tid as f64));
    m.insert("ts".to_string(), Json::Num(ev.start_ns as f64 / 1e3));
    m.insert("dur".to_string(), Json::Num(ev.dur_ns() as f64 / 1e3));
    Json::Obj(m)
}

fn metadata_event(name: &str, tid: f64, arg: &str) -> Json {
    let mut args = BTreeMap::new();
    args.insert("name".to_string(), Json::Str(arg.to_string()));
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(name.to_string()));
    m.insert("ph".to_string(), Json::Str("M".to_string()));
    m.insert("pid".to_string(), Json::Num(PID));
    m.insert("tid".to_string(), Json::Num(tid));
    m.insert("args".to_string(), Json::Obj(args));
    Json::Obj(m)
}

/// Build the trace document: one metadata block (process/thread names)
/// followed by every span event, preserving drain order (per-thread
/// end-time order).
pub fn trace_json(events: &[TraceEvent]) -> Json {
    let mut arr = vec![metadata_event("process_name", 0.0, "hot")];
    let mut tids: Vec<u32> = events.iter().map(|e| e.tid).collect();
    tids.sort_unstable();
    tids.dedup();
    for tid in tids {
        let label =
            if tid == 0 { "main".to_string() } else { format!("pool-{tid}") };
        arr.push(metadata_event("thread_name", tid as f64, &label));
    }
    arr.extend(events.iter().map(complete_event));
    let mut root = BTreeMap::new();
    root.insert("traceEvents".to_string(), Json::Arr(arr));
    root.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    Json::Obj(root)
}

/// Write `events` to `path` as Chrome-trace JSON.
pub fn write_trace(path: &str, events: &[TraceEvent]) -> Result<()> {
    std::fs::write(path, trace_json(events).to_string())
        .with_context(|| format!("writing trace to {path}"))
}

/// Parse a trace document back into events, validating the schema —
/// the self-validation half of the export round-trip (also exercised by
/// the CI smoke step on a real training run).
pub fn parse_trace(j: &Json) -> Result<Vec<TraceEvent>> {
    let arr = j
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .context("trace: missing traceEvents array")?;
    let mut out = Vec::new();
    for ev in arr {
        let ph = ev.get("ph").and_then(|v| v.as_str()).unwrap_or("");
        if ph != "X" {
            continue; // metadata et al.
        }
        let name =
            ev.get("name").and_then(|v| v.as_str()).context("event name")?;
        let span = crate::obs::SPAN_NAMES
            .iter()
            .position(|&n| n == name)
            .with_context(|| format!("unknown span name {name:?}"))? as u8;
        let tid = ev
            .get("tid")
            .and_then(|v| v.as_i64())
            .context("event tid")? as u32;
        let ts = ev.get("ts").and_then(|v| v.as_f64()).context("event ts")?;
        let dur =
            ev.get("dur").and_then(|v| v.as_f64()).context("event dur")?;
        anyhow::ensure!(ts >= 0.0 && dur >= 0.0,
                        "negative ts/dur on {name}: {ts} {dur}");
        out.push(TraceEvent {
            span,
            tid,
            start_ns: (ts * 1e3).round() as u64,
            end_ns: ((ts + dur) * 1e3).round() as u64,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Span;

    fn ev(span: Span, tid: u32, start: u64, end: u64) -> TraceEvent {
        TraceEvent { span: span as u8, tid, start_ns: start, end_ns: end }
    }

    #[test]
    fn export_roundtrips_through_the_parser() {
        let events = vec![
            ev(Span::TrainStep, 0, 1_000, 9_000),
            ev(Span::GemmF32, 0, 2_000, 4_000),
            ev(Span::PoolTask, 1, 2_500, 3_500),
            ev(Span::OptStep, 0, 8_000, 9_000),
        ];
        let doc = trace_json(&events);
        // serialize -> reparse -> re-extract: everything survives
        let text = doc.to_string();
        let back = Json::parse(&text).unwrap();
        let got = parse_trace(&back).unwrap();
        assert_eq!(got, events);
        // schema essentials are present
        let arr = back.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(arr.len() > events.len(), "metadata + span events");
        let first_x =
            arr.iter().find(|e| e.get("ph").unwrap().as_str() == Some("X"))
                .unwrap();
        assert_eq!(first_x.get("cat").unwrap().as_str(), Some("hot"));
        assert_eq!(first_x.get("name").unwrap().as_str(),
                   Some("train_step"));
        assert_eq!(first_x.get("ts").unwrap().as_f64(), Some(1.0));
        assert_eq!(first_x.get("dur").unwrap().as_f64(), Some(8.0));
    }

    #[test]
    fn parser_rejects_unknown_spans_and_missing_fields() {
        let j = Json::parse(
            r#"{"traceEvents":[{"name":"bogus","ph":"X","pid":1,"tid":0,
                 "ts":0,"dur":1}]}"#,
        )
        .unwrap();
        assert!(parse_trace(&j).is_err());
        let j = Json::parse(r#"{"notTraceEvents":[]}"#).unwrap();
        assert!(parse_trace(&j).is_err());
    }

    #[test]
    fn thread_names_cover_every_tid() {
        let events =
            vec![ev(Span::PoolTask, 0, 0, 1), ev(Span::PoolTask, 3, 0, 1)];
        let doc = trace_json(&events);
        let arr = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let meta_tids: Vec<i64> = arr
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("M")
                    && e.get("name").unwrap().as_str()
                        == Some("thread_name"))
            .map(|e| e.get("tid").unwrap().as_i64().unwrap())
            .collect();
        assert_eq!(meta_tids, vec![0, 3]);
    }
}
