//! Low-overhead hot-path observability: span tracing, kernel/quantizer
//! telemetry, and per-step profiles (ISSUE 6; the in-process
//! counterpart of the paper's cost accounting).
//!
//! Design contract:
//!   * ONE global gate. Every recording entry point (`span`, `count`,
//!     `set_layer`, `record_quant`) starts with a single relaxed atomic
//!     load of `TRACE_ON` and returns immediately when tracing is off —
//!     no allocation, no time query, no thread-local touch. The
//!     disabled-mode overhead test in `coordinator::trainer` pins this.
//!   * Per-thread SPSC ring buffers. Each thread lazily registers one
//!     `ThreadSink` (ring of `(span, t_start, t_end)` events + a block
//!     of monotonic counters) in a global sink list. The owning thread
//!     is the only writer; `drain_step` (called from the coordinator at
//!     step boundaries, when no parallel region is live) is the only
//!     reader. A full ring drops the event and bumps `EventsDropped`
//!     instead of blocking — tracing must never perturb scheduling.
//!   * Recording is read-only on the data path. Spans and counters
//!     never touch tensor data, so a traced run is bit-identical to an
//!     untraced one (pinned by a 2-thread determinism test).
//!
//! Timestamps are nanoseconds since the first observation in the
//! process (a `OnceLock<Instant>` epoch), so they are comparable across
//! threads and map directly onto Chrome-trace microseconds.

pub mod chrome;

use std::cell::{OnceCell, RefCell};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize,
                        Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

// ---------------------------------------------------------------------------
// Static registries: spans and counters
// ---------------------------------------------------------------------------

/// Static span registry. Adding a span = one enum variant + one name.
#[repr(u8)]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Span {
    GemmF32 = 0,
    GemmI8,
    FwhtQuant,
    QuantPackRows,
    PackLhs,
    PackRhs,
    PoolTask,
    OptStep,
    Forward,
    Backward,
    TrainStep,
}

pub const N_SPANS: usize = 11;
pub const SPAN_NAMES: [&str; N_SPANS] = [
    "gemm_f32", "gemm_i8", "fwht_quant", "quant_pack_rows", "pack_lhs",
    "pack_rhs", "pool_task", "opt_step", "fwd", "bwd", "train_step",
];

/// Monotonic per-thread counters, aggregated (as deltas) at step
/// boundaries by `drain_step` and (as totals) by the benches.
#[repr(u8)]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Counter {
    /// useful GEMM work (2·n·k·m f32 FLOPs / i8 MACs·2) by kernel tier
    FlopsScalar = 0,
    FlopsAvx2,
    FlopsNeon,
    /// output bytes of the fused FWHT→quant epilogues
    BytesQuantized,
    /// packed payload bytes produced by `quant_pack_rows`
    BytesPacked,
    /// GEMM packed-panel traffic: source bytes read + panel bytes
    /// written by the lhs/rhs packers, plus the output-tile writeback —
    /// the bandwidth numerator the bench harness' roofline block
    /// divides by cell time (panel re-reads inside the microkernel are
    /// cache-resident and deliberately not billed; see DESIGN.md
    /// §Benchmark methodology)
    BytesPanels,
    PlanHits,
    PlanMisses,
    ArenaGrows,
    /// pool tasks executed by a worker thread (not the submitter)
    PoolSteals,
    /// worker condvar parks
    PoolParks,
    /// frozen base-weight bytes held in `Arc`-shared `WeightStore` slabs
    /// (charged once per store construction, not per step)
    WeightBytesShared,
    /// per-tenant trainable bytes held by `AdapterSet`s (LoRA A/B pairs
    /// plus full-rank embed/head overrides)
    AdapterBytes,
    /// numeric sentinel trips (non-finite loss/state, clip runaway)
    SentinelTrips,
    /// rollbacks to a last-good checkpoint after a sentinel trip
    Rollbacks,
    /// serve: requests accepted into the bounded queue
    ServeRequests,
    /// serve: requests rejected at admission (queue above watermark)
    ServeShed,
    /// serve: requests dropped before the GEMM — deadline already past
    ServeExpired,
    /// serve: coalesced batches executed by the workers
    ServeBatches,
    /// serve: forward-walk panics caught by the request isolation wall
    ServePanics,
    /// serve: poisoned workers torn down and replaced after a panic
    ServeWorkerReplaced,
    /// serve: batches executed on a degraded (INT8) weight tier
    ServeDegraded,
    /// events lost to a full ring (never blocks the hot path)
    EventsDropped,
}

pub const N_COUNTERS: usize = 23;
pub const COUNTER_NAMES: [&str; N_COUNTERS] = [
    "flops_scalar", "flops_avx2", "flops_neon", "bytes_quantized",
    "bytes_packed", "bytes_panels", "plan_hits", "plan_misses",
    "arena_grows", "pool_steals", "pool_parks", "weight_bytes_shared",
    "adapter_bytes", "sentinel_trips", "rollbacks", "serve_requests",
    "serve_shed", "serve_expired", "serve_batches", "serve_panics",
    "serve_worker_replaced", "serve_degraded", "events_dropped",
];

// ---------------------------------------------------------------------------
// The gate
// ---------------------------------------------------------------------------

static TRACE_ON: AtomicBool = AtomicBool::new(false);

/// THE gate. Exactly one relaxed atomic load — every recording entry
/// point bails through this before doing any other work.
#[inline(always)]
pub fn enabled() -> bool {
    TRACE_ON.load(Ordering::Relaxed)
}

pub fn set_trace_enabled(on: bool) {
    TRACE_ON.store(on, Ordering::Relaxed);
}

/// Apply the `HOT_TRACE` env knob (1|on|true enables). Called from the
/// binaries' entry points and `NativeBackend` construction — NOT from
/// `enabled()`, which must stay a single atomic load. The env is read
/// once; later explicit `set_trace_enabled` calls still win.
pub fn init_from_env() {
    static ONCE: OnceLock<bool> = OnceLock::new();
    let on = *ONCE.get_or_init(|| {
        matches!(std::env::var("HOT_TRACE").as_deref(),
                 Ok("1") | Ok("on") | Ok("true"))
    });
    if on {
        set_trace_enabled(true);
    }
}

// ---------------------------------------------------------------------------
// Timebase
// ---------------------------------------------------------------------------

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

#[inline]
fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

// ---------------------------------------------------------------------------
// Per-thread sink: SPSC event ring + counter block
// ---------------------------------------------------------------------------

/// Ring capacity in events (power of two; ~96 KiB per thread). Sized so
/// one step of the large presets fits between step-boundary drains;
/// overflow drops (counted), never blocks.
const RING_CAP: usize = 4096;
const WORDS_PER_EVENT: usize = 3; // span, t_start, t_end

/// A drained span event. `tid` is obs' own dense thread index (0 = the
/// first observed thread), stable for the process lifetime.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    pub span: u8,
    pub tid: u32,
    pub start_ns: u64,
    pub end_ns: u64,
}

impl TraceEvent {
    pub fn name(&self) -> &'static str {
        SPAN_NAMES.get(self.span as usize).copied().unwrap_or("?")
    }

    pub fn dur_ns(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns)
    }
}

struct ThreadSink {
    tid: u32,
    /// events ever pushed; owner-written (Release), drainer-read
    head: AtomicUsize,
    /// events ever drained; drainer-written (Release), owner-read
    tail: AtomicUsize,
    ring: Box<[AtomicU64]>,
    counters: [AtomicU64; N_COUNTERS],
}

impl ThreadSink {
    fn new(tid: u32) -> ThreadSink {
        ThreadSink {
            tid,
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            ring: (0..RING_CAP * WORDS_PER_EVENT)
                .map(|_| AtomicU64::new(0))
                .collect::<Vec<_>>()
                .into_boxed_slice(),
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Owner-thread push. Full ring: drop + count, never block.
    fn push(&self, span: Span, start: u64, end: u64) {
        let h = self.head.load(Ordering::Relaxed);
        let t = self.tail.load(Ordering::Acquire);
        if h - t >= RING_CAP {
            self.counters[Counter::EventsDropped as usize]
                .fetch_add(1, Ordering::Relaxed);
            return;
        }
        let base = (h % RING_CAP) * WORDS_PER_EVENT;
        self.ring[base].store(span as u64, Ordering::Relaxed);
        self.ring[base + 1].store(start, Ordering::Relaxed);
        self.ring[base + 2].store(end, Ordering::Relaxed);
        self.head.store(h + 1, Ordering::Release);
    }

    /// Drainer-side read of everything published so far.
    fn drain_into(&self, out: &mut Vec<TraceEvent>) {
        let h = self.head.load(Ordering::Acquire);
        let mut t = self.tail.load(Ordering::Relaxed);
        while t < h {
            let base = (t % RING_CAP) * WORDS_PER_EVENT;
            out.push(TraceEvent {
                span: self.ring[base].load(Ordering::Relaxed) as u8,
                tid: self.tid,
                start_ns: self.ring[base + 1].load(Ordering::Relaxed),
                end_ns: self.ring[base + 2].load(Ordering::Relaxed),
            });
            t += 1;
        }
        self.tail.store(t, Ordering::Release);
    }
}

fn sinks() -> &'static Mutex<Vec<Arc<ThreadSink>>> {
    static SINKS: OnceLock<Mutex<Vec<Arc<ThreadSink>>>> = OnceLock::new();
    SINKS.get_or_init(|| Mutex::new(Vec::new()))
}

static NEXT_TID: AtomicU32 = AtomicU32::new(0);

thread_local! {
    static SINK: OnceCell<Arc<ThreadSink>> = const { OnceCell::new() };
}

/// Current thread's sink, lazily created + registered on first record
/// (one allocation per thread for the process lifetime — the arena
/// warmup pattern).
fn with_sink<R>(f: impl FnOnce(&ThreadSink) -> R) -> R {
    SINK.with(|cell| {
        let sink = cell.get_or_init(|| {
            let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            let sink = Arc::new(ThreadSink::new(tid));
            sinks().lock().unwrap().push(sink.clone());
            sink
        });
        f(sink)
    })
}

// ---------------------------------------------------------------------------
// Recording API
// ---------------------------------------------------------------------------

/// RAII span. Disarmed (and cost-free beyond one atomic load) when
/// tracing is off; otherwise records `(span, t_start, t_end)` into the
/// owning thread's ring on drop.
pub struct SpanGuard {
    span: Span,
    start: u64,
    armed: bool,
}

#[inline(always)]
pub fn span(s: Span) -> SpanGuard {
    if !enabled() {
        return SpanGuard { span: s, start: 0, armed: false };
    }
    SpanGuard { span: s, start: now_ns(), armed: true }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.armed {
            let end = now_ns();
            with_sink(|sink| sink.push(self.span, self.start, end));
        }
    }
}

/// Bump a per-thread counter. One relaxed load when tracing is off.
#[inline(always)]
pub fn count(c: Counter, v: u64) {
    if !enabled() {
        return;
    }
    with_sink(|sink| {
        sink.counters[c as usize].fetch_add(v, Ordering::Relaxed);
    });
}

/// Current thread's counter value (test/bench helper immune to
/// concurrent activity on other threads).
pub fn thread_counter(c: Counter) -> u64 {
    with_sink(|sink| sink.counters[c as usize].load(Ordering::Relaxed))
}

/// Process-wide counter total (monotonic; sums every thread's block).
pub fn counter_total(c: Counter) -> u64 {
    sinks()
        .lock()
        .unwrap()
        .iter()
        .map(|s| s.counters[c as usize].load(Ordering::Relaxed))
        .sum()
}

/// Total useful GEMM work across tiers — what the benches read instead
/// of hand-computed `2·n³` formulas.
pub fn flops_total() -> u64 {
    counter_total(Counter::FlopsScalar)
        + counter_total(Counter::FlopsAvx2)
        + counter_total(Counter::FlopsNeon)
}

/// Counter deltas since the previous drain (either flavor — this and
/// `drain_step` share one baseline, so interleaving them never double-
/// counts). The bench harness calls this at cell boundaries: once to
/// flush whatever warmup or a previous cell charged, and again to
/// assert the meter reads zero before the instrumented run starts —
/// the "drained-to-zero at cell start" contract pinned in
/// `rust/tests/obs_trace.rs`. Events and quant telemetry accumulated
/// since the last drain are discarded alongside.
pub fn drain_counters() -> [u64; N_COUNTERS] {
    drain_step(false).counters
}

// ---------------------------------------------------------------------------
// Per-layer quantizer telemetry
// ---------------------------------------------------------------------------

#[derive(Debug, Default, Clone, Copy)]
struct QuantAccum {
    amax: f32,
    clipped: u64,
    numel: u64,
    abs_err_sum: f64,
}

/// One layer's quantizer health over a drain window.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerQuant {
    pub name: String,
    /// max |x| seen entering the quantizer
    pub amax: f32,
    /// fraction of values past the representable range (clamped)
    pub clip_rate: f64,
    /// mean |dequant(x) − x| over quantized values
    pub mean_abs_err: f64,
    pub numel: u64,
}

thread_local! {
    static LAYER: RefCell<String> = const { RefCell::new(String::new()) };
}

fn quant_map() -> &'static Mutex<BTreeMap<String, QuantAccum>> {
    static MAP: OnceLock<Mutex<BTreeMap<String, QuantAccum>>> =
        OnceLock::new();
    MAP.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Label subsequent `record_quant` calls on this thread with the layer
/// they belong to (the model walk sets this per qlinear).
#[inline]
pub fn set_layer(name: &str) {
    if !enabled() {
        return;
    }
    LAYER.with(|l| {
        let mut l = l.borrow_mut();
        l.clear();
        l.push_str(name);
    });
}

/// Record one quant epilogue's health stats under the current layer
/// label. Called a handful of times per step, so a mutex-guarded map is
/// fine here (the event ring stays lock-free).
pub fn record_quant(amax: f32, clipped: u64, abs_err_sum: f64, numel: u64) {
    if !enabled() {
        return;
    }
    let name = LAYER.with(|l| {
        let l = l.borrow();
        if l.is_empty() { "(unattributed)".to_string() } else { l.clone() }
    });
    let mut map = quant_map().lock().unwrap();
    let e = map.entry(name).or_default();
    e.amax = e.amax.max(amax);
    e.clipped += clipped;
    e.numel += numel;
    e.abs_err_sum += abs_err_sum;
}

// ---------------------------------------------------------------------------
// Step-boundary aggregation
// ---------------------------------------------------------------------------

/// Everything observed since the previous drain: time by span, counter
/// deltas, per-layer quantizer health (sorted worst-error first), and —
/// when `keep_events` — the raw events for Chrome-trace export.
#[derive(Debug, Clone, Default)]
pub struct StepProfile {
    pub span_ns: [u64; N_SPANS],
    pub span_count: [u64; N_SPANS],
    pub counters: [u64; N_COUNTERS],
    pub quant: Vec<LayerQuant>,
    pub events: Vec<TraceEvent>,
}

impl StepProfile {
    pub fn flops(&self) -> u64 {
        self.counters[Counter::FlopsScalar as usize]
            + self.counters[Counter::FlopsAvx2 as usize]
            + self.counters[Counter::FlopsNeon as usize]
    }

    /// Time inside the top-level phase spans (fwd + bwd + opt) — the
    /// step-coverage number the acceptance gate compares to measured
    /// step time.
    pub fn step_coverage_ns(&self) -> u64 {
        self.span_ns[Span::Forward as usize]
            + self.span_ns[Span::Backward as usize]
            + self.span_ns[Span::OptStep as usize]
    }

    /// Top-k layers by mean quant error as a CSV-safe cell
    /// (`name:err` joined with `;` — no commas).
    pub fn top_quant_csv(&self, k: usize) -> String {
        self.quant
            .iter()
            .take(k)
            .map(|q| format!("{}:{:.3e}", q.name, q.mean_abs_err))
            .collect::<Vec<_>>()
            .join(";")
    }
}

fn prev_totals() -> &'static Mutex<[u64; N_COUNTERS]> {
    static PREV: OnceLock<Mutex<[u64; N_COUNTERS]>> = OnceLock::new();
    PREV.get_or_init(|| Mutex::new([0; N_COUNTERS]))
}

/// Drain every thread's ring and the quant map into one `StepProfile`.
/// Counters report the delta since the previous drain (the per-thread
/// blocks themselves stay monotonic). Call from the coordinator at step
/// boundaries — no parallel region is live there, so every in-flight
/// event has been published.
pub fn drain_step(keep_events: bool) -> StepProfile {
    // taking the prev-totals lock first serializes concurrent drains
    let mut prev = prev_totals().lock().unwrap();
    let mut prof = StepProfile::default();
    let mut totals = [0u64; N_COUNTERS];
    {
        let sinks = sinks().lock().unwrap();
        for sink in sinks.iter() {
            sink.drain_into(&mut prof.events);
            for (i, c) in sink.counters.iter().enumerate() {
                totals[i] += c.load(Ordering::Relaxed);
            }
        }
    }
    for i in 0..N_COUNTERS {
        prof.counters[i] = totals[i].saturating_sub(prev[i]);
    }
    *prev = totals;
    for ev in &prof.events {
        if let Some(s) = prof.span_ns.get_mut(ev.span as usize) {
            *s += ev.dur_ns();
            prof.span_count[ev.span as usize] += 1;
        }
    }
    let mut map = quant_map().lock().unwrap();
    for (name, a) in std::mem::take(&mut *map) {
        prof.quant.push(LayerQuant {
            name,
            amax: a.amax,
            clip_rate: if a.numel > 0 {
                a.clipped as f64 / a.numel as f64
            } else {
                0.0
            },
            mean_abs_err: if a.numel > 0 {
                a.abs_err_sum / a.numel as f64
            } else {
                0.0
            },
            numel: a.numel,
        });
    }
    drop(map);
    prof.quant.sort_by(|a, b| {
        b.mean_abs_err
            .partial_cmp(&a.mean_abs_err)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    if !keep_events {
        prof.events.clear();
    }
    prof
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registries_are_consistent() {
        assert_eq!(SPAN_NAMES.len(), N_SPANS);
        assert_eq!(COUNTER_NAMES.len(), N_COUNTERS);
        assert_eq!(Span::TrainStep as usize, N_SPANS - 1);
        assert_eq!(Counter::EventsDropped as usize, N_COUNTERS - 1);
    }

    #[test]
    fn sink_ring_roundtrips_and_drops_on_full() {
        let s = ThreadSink::new(7);
        s.push(Span::GemmF32, 10, 20);
        s.push(Span::OptStep, 30, 45);
        let mut out = Vec::new();
        s.drain_into(&mut out);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0],
                   TraceEvent { span: Span::GemmF32 as u8, tid: 7,
                                start_ns: 10, end_ns: 20 });
        assert_eq!(out[1].name(), "opt_step");
        assert_eq!(out[1].dur_ns(), 15);
        // drained ring accepts a full new window
        for i in 0..RING_CAP {
            s.push(Span::PoolTask, i as u64, i as u64 + 1);
        }
        // ... and drops (counted) past capacity instead of blocking
        s.push(Span::PoolTask, 0, 1);
        s.push(Span::PoolTask, 0, 1);
        assert_eq!(s.counters[Counter::EventsDropped as usize]
                       .load(Ordering::Relaxed),
                   2);
        out.clear();
        s.drain_into(&mut out);
        assert_eq!(out.len(), RING_CAP);
        // wrap-around: the ring is reusable after a drain
        s.push(Span::GemmI8, 5, 9);
        out.clear();
        s.drain_into(&mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].span, Span::GemmI8 as u8);
    }

    #[test]
    fn disabled_guards_are_inert() {
        // whatever other tests do, a disarmed guard records nothing
        let g = SpanGuard { span: Span::GemmF32, start: 0, armed: false };
        drop(g); // must not touch the sink
        // enabled()/set round-trip
        let was = enabled();
        set_trace_enabled(was); // no-op store
        assert_eq!(enabled(), was);
    }

    #[test]
    fn step_profile_helpers() {
        let mut p = StepProfile::default();
        p.counters[Counter::FlopsScalar as usize] = 5;
        p.counters[Counter::FlopsAvx2 as usize] = 7;
        assert_eq!(p.flops(), 12);
        p.span_ns[Span::Forward as usize] = 100;
        p.span_ns[Span::Backward as usize] = 200;
        p.span_ns[Span::OptStep as usize] = 50;
        p.span_ns[Span::GemmF32 as usize] = 999; // nested; not coverage
        assert_eq!(p.step_coverage_ns(), 350);
        p.quant = vec![
            LayerQuant { name: "blk0.fc1".into(), amax: 1.0,
                         clip_rate: 0.0, mean_abs_err: 0.25, numel: 4 },
            LayerQuant { name: "embed".into(), amax: 2.0, clip_rate: 0.1,
                         mean_abs_err: 0.125, numel: 8 },
        ];
        let cell = p.top_quant_csv(2);
        assert_eq!(cell, "blk0.fc1:2.500e-1;embed:1.250e-1");
        assert!(!cell.contains(','), "CSV cell must stay comma-free");
        assert_eq!(p.top_quant_csv(1), "blk0.fc1:2.500e-1");
    }
}
