//! Host-side quantizer semantics — bit-mirror of python kernels
//! (pseudo-stochastic min-max quant, INT4 nibble packing, LUQ baseline).
//!
//! Why rust needs this at all: the ABC context-buffer manager repacks
//! INT4 payloads two-nibbles-per-byte for storage accounting, the
//! checkpointing layer round-trips compressed buffers, and integration
//! tests cross-verify artifact outputs without going back through python.

pub const QMAX_I4: i32 = 7;
pub const QMAX_I8: i32 = 127;

pub fn qmax(bits: u8) -> i32 {
    match bits {
        4 => QMAX_I4,
        8 => QMAX_I8,
        b => panic!("unsupported bit width {b}"),
    }
}

/// The paper's pseudo-random source: lower 11 bits of the FP32 input,
/// scaled to [0, 1). Bit-identical to kernels/ref.py::pseudo_random_unit.
#[inline]
pub fn pseudo_random_unit(x: f32) -> f32 {
    (x.to_bits() & 0x7FF) as f32 / 2048.0
}

/// Stochastic rounding: round up iff frac(v) > u.
#[inline]
pub fn ps_round(v: f32, u: f32) -> f32 {
    let f = v.floor();
    if v - f > u {
        f + 1.0
    } else {
        f
    }
}

/// Min-max symmetric scale over a slice.
pub fn minmax_scale(xs: &[f32], bits: u8) -> f32 {
    let amax = xs.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    amax.max(1e-8) / qmax(bits) as f32
}

/// Pseudo-stochastic quantize one value.
#[inline]
pub fn quantize_ps_one(x: f32, scale: f32, bits: u8) -> i8 {
    let q = ps_round(x / scale, pseudo_random_unit(x));
    q.clamp(-(qmax(bits) as f32), qmax(bits) as f32) as i8
}

pub fn quantize_ps(xs: &[f32], scale: f32, bits: u8) -> Vec<i8> {
    xs.iter().map(|&x| quantize_ps_one(x, scale, bits)).collect()
}

pub fn dequantize(qs: &[i8], scale: f32) -> Vec<f32> {
    qs.iter().map(|&q| q as f32 * scale).collect()
}

/// Per-token (row-wise) scales over a row-major (rows, cols) matrix.
pub fn minmax_scale_rows(xs: &[f32], rows: usize, cols: usize, bits: u8)
                         -> Vec<f32> {
    (0..rows)
        .map(|r| minmax_scale(&xs[r * cols..(r + 1) * cols], bits))
        .collect()
}

// ---------------------------------------------------------------------------
// INT4 nibble packing (two values per byte; low nibble = even index)
// ---------------------------------------------------------------------------

pub fn pack_int4(qs: &[i8]) -> Vec<u8> {
    assert_eq!(qs.len() % 2, 0, "need an even count to pack nibbles");
    qs.chunks_exact(2)
        .map(|p| {
            let lo = (p[0] as u8) & 0xF;
            let hi = (p[1] as u8) & 0xF;
            (hi << 4) | lo
        })
        .collect()
}

/// `pack_int4` for any element count: an odd tail pads the final high
/// nibble with 0. The logical length is the caller's to keep (the ctx
/// wire format records it as the tensor shape); `unpack_int4_n`
/// truncates back to it.
pub fn pack_int4_padded(qs: &[i8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(qs.len().div_ceil(2));
    for p in qs.chunks(2) {
        let lo = (p[0] as u8) & 0xF;
        let hi = if p.len() == 2 { (p[1] as u8) & 0xF } else { 0 };
        out.push((hi << 4) | lo);
    }
    out
}

pub fn unpack_int4(packed: &[u8]) -> Vec<i8> {
    let mut out = Vec::with_capacity(packed.len() * 2);
    for &b in packed {
        let lo = (b & 0xF) as i8;
        let hi = ((b >> 4) & 0xF) as i8;
        out.push(if lo >= 8 { lo - 16 } else { lo });
        out.push(if hi >= 8 { hi - 16 } else { hi });
    }
    out
}

/// Unpack to exactly `n` values, dropping the padding nibble a
/// `pack_int4_padded` of an odd-length input appended.
pub fn unpack_int4_n(packed: &[u8], n: usize) -> Vec<i8> {
    assert_eq!(packed.len(), n.div_ceil(2), "packed length vs logical n");
    let mut out = unpack_int4(packed);
    out.truncate(n);
    out
}

/// One-pass decode + per-row dequantize of a packed payload (borrowed;
/// no intermediate code buffer). `scales` is one f32 per row, or len 1
/// for a per-tensor broadcast. THE single definition of the packed
/// format's dequant semantics — `AbcAct::dequantize` and
/// `Value::to_f32` both route here.
pub fn dequant_rows(data: &[u8], scales: &[f32], rows: usize, cols: usize,
                    bits: u8) -> Vec<f32> {
    let n = rows * cols;
    let scale =
        |r: usize| if scales.len() == 1 { scales[0] } else { scales[r] };
    let mut out = Vec::with_capacity(n);
    match bits {
        4 => {
            assert_eq!(data.len(), n.div_ceil(2), "packed length vs logical n");
            for &b in data {
                let lo = (b & 0xF) as i8;
                let lo = if lo >= 8 { lo - 16 } else { lo };
                out.push(lo as f32 * scale(out.len() / cols));
                if out.len() < n {
                    let hi = ((b >> 4) & 0xF) as i8;
                    let hi = if hi >= 8 { hi - 16 } else { hi };
                    out.push(hi as f32 * scale(out.len() / cols));
                }
            }
        }
        8 => {
            assert_eq!(data.len(), n, "payload length vs logical n");
            for (idx, &b) in data.iter().enumerate() {
                out.push((b as i8) as f32 * scale(idx / cols));
            }
        }
        b => panic!("unsupported packed bit width {b}"),
    }
    out
}

// ---------------------------------------------------------------------------
// Packed activation payload — the ABC ctx storage format
// ---------------------------------------------------------------------------

/// A per-row min-max quantized 2-D activation in storage form: INT`bits`
/// codes packed two-nibbles-per-byte at 4 bits (raw one-byte codes at
/// 8), one f32 scale per row, logical (rows, cols) kept so odd shapes
/// survive the padding nibble. This is both the in-memory ctx format of
/// the native backend and (inside `Value::QuantF32`) the split-mode
/// wire format the `CtxStore` accounts byte-for-byte.
#[derive(Debug, Clone)]
pub struct AbcAct {
    pub rows: usize,
    pub cols: usize,
    pub bits: u8,
    /// packed codes: `(rows*cols*bits).div_ceil(8)` bytes
    pub data: Vec<u8>,
    /// per-row scales (len `rows`); len 1 = per-tensor broadcast
    pub scales: Vec<f32>,
}

impl AbcAct {
    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }

    /// Stored payload size: packed codes + scale table.
    pub fn payload_bytes(&self) -> usize {
        self.data.len() + 4 * self.scales.len()
    }

    pub fn scale(&self, row: usize) -> f32 {
        if self.scales.len() == 1 {
            self.scales[0]
        } else {
            self.scales[row]
        }
    }

    /// Expand the packed codes back to one-byte values (bit-exact).
    pub fn unpack(&self) -> Vec<i8> {
        match self.bits {
            4 => unpack_int4_n(&self.data, self.numel()),
            8 => self.data.iter().map(|&b| b as i8).collect(),
            b => panic!("unsupported packed bit width {b}"),
        }
    }

    /// Expand straight to UNSCALED f32 codes in one pass, one
    /// allocation — the g_w GEMM folds the row scales into its other
    /// operand, so this is what the hot backward path consumes.
    pub fn unpack_f32(&self) -> Vec<f32> {
        let n = self.numel();
        let mut out = Vec::with_capacity(n);
        match self.bits {
            4 => {
                for &b in &self.data {
                    let lo = (b & 0xF) as i8;
                    out.push((if lo >= 8 { lo - 16 } else { lo }) as f32);
                    if out.len() < n {
                        let hi = ((b >> 4) & 0xF) as i8;
                        out.push((if hi >= 8 { hi - 16 } else { hi }) as f32);
                    }
                }
            }
            8 => out.extend(self.data.iter().map(|&b| (b as i8) as f32)),
            b => panic!("unsupported packed bit width {b}"),
        }
        debug_assert_eq!(out.len(), n);
        out
    }

    /// Dequantize to f32 (row scale applied per row).
    pub fn dequantize(&self) -> Vec<f32> {
        dequant_rows(&self.data, &self.scales, self.rows, self.cols,
                     self.bits)
    }
}

// ---------------------------------------------------------------------------
// LUQ baseline (logarithmic stochastic quantization, Chmiel et al.)
// ---------------------------------------------------------------------------

/// Fake-quant LUQ at `bits`: snap to signed powers of two below max|x|,
/// stochastic in the log domain, stochastic underflow pruning. Mirrors
/// kernels/ref.py::quantize_luq (same pseudo-random source).
pub fn quantize_luq(xs: &[f32], bits: u8) -> Vec<f32> {
    let levels = (1i32 << (bits - 1)) - 1;
    let amax = xs.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-20);
    let e_hi = amax.log2().floor();
    let e_lo = e_hi - (levels - 1) as f32;
    xs.iter()
        .map(|&x| {
            if x == 0.0 {
                return 0.0;
            }
            let mag = x.abs();
            let sgn = x.signum();
            let u = pseudo_random_unit(x);
            if mag < e_lo.exp2() {
                // stochastic underflow: keep w.p. mag/2^e_lo
                return if u < mag / e_lo.exp2() { sgn * e_lo.exp2() } else { 0.0 };
            }
            let e = mag.log2().clamp(e_lo, e_hi);
            let ef = e.floor();
            let pl = ef.exp2();
            let ph = (ef + 1.0).exp2().min(e_hi.exp2());
            let p_up = if ph > pl { (mag - pl) / (ph - pl) } else { 0.0 };
            sgn * if u < p_up { ph } else { pl }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    fn randv(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut r = Pcg32::seeded(seed);
        (0..n).map(|_| r.normal() * scale).collect()
    }

    #[test]
    fn range_respected() {
        for bits in [4u8, 8] {
            let xs = randv(512, 1, 100.0);
            let s = minmax_scale(&xs, bits);
            let q = quantize_ps(&xs, s, bits);
            for v in q {
                assert!((v as i32).abs() <= qmax(bits));
            }
        }
    }

    #[test]
    fn roundtrip_error_bounded() {
        let xs = randv(512, 2, 3.0);
        for bits in [4u8, 8] {
            let s = minmax_scale(&xs, bits);
            let q = quantize_ps(&xs, s, bits);
            let d = dequantize(&q, s);
            for (a, b) in xs.iter().zip(&d) {
                assert!((a - b).abs() <= s * 1.0001, "{a} vs {b} (s={s})");
            }
        }
    }

    #[test]
    fn grid_points_fixed() {
        // values already on the grid never move
        let s = 0.5f32;
        for k in -7..=7 {
            let x = k as f32 * s;
            assert_eq!(quantize_ps_one(x, s, 4) as i32, k);
        }
    }

    #[test]
    fn nearly_unbiased() {
        let xs = randv(200_000, 3, 2.0);
        let s = minmax_scale(&xs, 4);
        let q = quantize_ps(&xs, s, 4);
        let d = dequantize(&q, s);
        let err: f64 = xs.iter().zip(&d).map(|(a, b)| (b - a) as f64).sum();
        let mean_err = err / xs.len() as f64;
        assert!(mean_err.abs() < 0.02 * s as f64, "bias {}", mean_err);
    }

    #[test]
    fn pack_roundtrip() {
        let mut r = Pcg32::seeded(4);
        let qs: Vec<i8> = (0..256).map(|_| (r.below(16) as i8) - 8).collect();
        assert_eq!(unpack_int4(&pack_int4(&qs)), qs);
    }

    #[test]
    fn prop_pack_roundtrip() {
        crate::util::proptest::check("int4 pack roundtrip", 30, |case| {
            let n = 2 * case.usize_in(1, 64);
            let qs: Vec<i8> = (0..n).map(|_| (case.rng.below(16) as i8) - 8).collect();
            if unpack_int4(&pack_int4(&qs)) == qs {
                Ok(())
            } else {
                Err("roundtrip mismatch".into())
            }
        });
    }

    #[test]
    fn pack_roundtrip_exhaustive_all_nibble_pairs() {
        // every INT4 value pair (lo, hi) in [-8, 7]^2 — all 256 bytes —
        // must survive pack -> unpack bit-exactly
        for lo in -8i8..=7 {
            for hi in -8i8..=7 {
                let qs = vec![lo, hi];
                let packed = pack_int4(&qs);
                assert_eq!(packed.len(), 1);
                assert_eq!(unpack_int4(&packed), qs, "({lo}, {hi})");
            }
        }
    }

    #[test]
    fn pack_halves_bytes() {
        let qs = vec![1i8; 128];
        assert_eq!(pack_int4(&qs).len(), 64);
    }

    #[test]
    fn padded_pack_roundtrips_odd_lengths() {
        for n in [1usize, 2, 3, 7, 13, 64, 65] {
            let qs: Vec<i8> = (0..n).map(|i| ((i % 16) as i8) - 8).collect();
            let packed = pack_int4_padded(&qs);
            assert_eq!(packed.len(), n.div_ceil(2), "n={n}");
            assert_eq!(unpack_int4_n(&packed, n), qs, "n={n}");
        }
        // even lengths match the strict packer bit-for-bit
        let qs: Vec<i8> = (0..32).map(|i| ((i % 16) as i8) - 8).collect();
        assert_eq!(pack_int4_padded(&qs), pack_int4(&qs));
    }

    #[test]
    fn abc_act_roundtrip_and_accounting() {
        // odd cols at 4 bits: padding nibble + logical length preserved
        let (rows, cols) = (3usize, 5usize);
        let q: Vec<i8> = (0..rows * cols).map(|i| ((i % 15) as i8) - 7)
            .collect();
        let scales = vec![0.5f32, 2.0, 1.0];
        let a = AbcAct { rows, cols, bits: 4,
                         data: pack_int4_padded(&q), scales: scales.clone() };
        assert_eq!(a.data.len(), (rows * cols).div_ceil(2));
        assert_eq!(a.payload_bytes(), a.data.len() + 12);
        assert_eq!(a.unpack(), q);
        let want_f: Vec<f32> = q.iter().map(|&v| v as f32).collect();
        assert_eq!(a.unpack_f32(), want_f, "odd-numel nibble expand");
        let d = a.dequantize();
        for r in 0..rows {
            for c in 0..cols {
                assert_eq!(d[r * cols + c], q[r * cols + c] as f32 * scales[r]);
            }
        }
        // 8-bit payload: one byte per code, same roundtrip contract
        let a8 = AbcAct { rows, cols, bits: 8,
                          data: q.iter().map(|&v| v as u8).collect(),
                          scales: vec![1.0] };
        assert_eq!(a8.unpack(), q);
        assert_eq!(a8.unpack_f32(),
                   q.iter().map(|&v| v as f32).collect::<Vec<f32>>());
        assert_eq!(a8.scale(2), 1.0, "len-1 scales broadcast");
        assert_eq!(a8.dequantize(),
                   q.iter().map(|&v| v as f32).collect::<Vec<f32>>(),
                   "broadcast dequant");
    }

    #[test]
    fn luq_powers_of_two() {
        let xs = randv(256, 5, 3.0);
        let y = quantize_luq(&xs, 4);
        for v in y {
            if v != 0.0 {
                let e = v.abs().log2();
                assert!((e - e.round()).abs() < 1e-5, "{v}");
            }
        }
    }

    #[test]
    fn luq_sign_preserved() {
        let xs = randv(256, 6, 3.0);
        let y = quantize_luq(&xs, 4);
        for (a, b) in xs.iter().zip(&y) {
            if *b != 0.0 {
                assert_eq!(a.signum(), b.signum());
            }
        }
    }

    #[test]
    fn per_row_scales() {
        let xs = vec![
            1.0, -2.0, 3.0, -4.0, // row 0: amax 4
            10.0, 20.0, -30.0, 5.0, // row 1: amax 30
        ];
        let s = minmax_scale_rows(&xs, 2, 4, 8);
        assert!((s[0] - 4.0 / 127.0).abs() < 1e-6);
        assert!((s[1] - 30.0 / 127.0).abs() < 1e-6);
    }
}
