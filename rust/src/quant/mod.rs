//! Host-side quantizer semantics — bit-mirror of python kernels
//! (pseudo-stochastic min-max quant, INT4 nibble packing, LUQ baseline).
//!
//! Why rust needs this at all: the ABC context-buffer manager repacks
//! INT4 payloads two-nibbles-per-byte for storage accounting, the
//! checkpointing layer round-trips compressed buffers, and integration
//! tests cross-verify artifact outputs without going back through python.

pub const QMAX_I4: i32 = 7;
pub const QMAX_I8: i32 = 127;

pub fn qmax(bits: u8) -> i32 {
    match bits {
        4 => QMAX_I4,
        8 => QMAX_I8,
        b => panic!("unsupported bit width {b}"),
    }
}

/// The paper's pseudo-random source: lower 11 bits of the FP32 input,
/// scaled to [0, 1). Bit-identical to kernels/ref.py::pseudo_random_unit.
#[inline]
pub fn pseudo_random_unit(x: f32) -> f32 {
    (x.to_bits() & 0x7FF) as f32 / 2048.0
}

/// Stochastic rounding: round up iff frac(v) > u.
#[inline]
pub fn ps_round(v: f32, u: f32) -> f32 {
    let f = v.floor();
    if v - f > u {
        f + 1.0
    } else {
        f
    }
}

/// Min-max symmetric scale over a slice.
pub fn minmax_scale(xs: &[f32], bits: u8) -> f32 {
    let amax = xs.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    amax.max(1e-8) / qmax(bits) as f32
}

/// Pseudo-stochastic quantize one value.
#[inline]
pub fn quantize_ps_one(x: f32, scale: f32, bits: u8) -> i8 {
    let q = ps_round(x / scale, pseudo_random_unit(x));
    q.clamp(-(qmax(bits) as f32), qmax(bits) as f32) as i8
}

pub fn quantize_ps(xs: &[f32], scale: f32, bits: u8) -> Vec<i8> {
    xs.iter().map(|&x| quantize_ps_one(x, scale, bits)).collect()
}

pub fn dequantize(qs: &[i8], scale: f32) -> Vec<f32> {
    qs.iter().map(|&q| q as f32 * scale).collect()
}

/// Per-token (row-wise) scales over a row-major (rows, cols) matrix.
pub fn minmax_scale_rows(xs: &[f32], rows: usize, cols: usize, bits: u8)
                         -> Vec<f32> {
    (0..rows)
        .map(|r| minmax_scale(&xs[r * cols..(r + 1) * cols], bits))
        .collect()
}

// ---------------------------------------------------------------------------
// INT4 nibble packing (two values per byte; low nibble = even index)
// ---------------------------------------------------------------------------

pub fn pack_int4(qs: &[i8]) -> Vec<u8> {
    assert_eq!(qs.len() % 2, 0, "need an even count to pack nibbles");
    qs.chunks_exact(2)
        .map(|p| {
            let lo = (p[0] as u8) & 0xF;
            let hi = (p[1] as u8) & 0xF;
            (hi << 4) | lo
        })
        .collect()
}

pub fn unpack_int4(packed: &[u8]) -> Vec<i8> {
    let mut out = Vec::with_capacity(packed.len() * 2);
    for &b in packed {
        let lo = (b & 0xF) as i8;
        let hi = ((b >> 4) & 0xF) as i8;
        out.push(if lo >= 8 { lo - 16 } else { lo });
        out.push(if hi >= 8 { hi - 16 } else { hi });
    }
    out
}

// ---------------------------------------------------------------------------
// LUQ baseline (logarithmic stochastic quantization, Chmiel et al.)
// ---------------------------------------------------------------------------

/// Fake-quant LUQ at `bits`: snap to signed powers of two below max|x|,
/// stochastic in the log domain, stochastic underflow pruning. Mirrors
/// kernels/ref.py::quantize_luq (same pseudo-random source).
pub fn quantize_luq(xs: &[f32], bits: u8) -> Vec<f32> {
    let levels = (1i32 << (bits - 1)) - 1;
    let amax = xs.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-20);
    let e_hi = amax.log2().floor();
    let e_lo = e_hi - (levels - 1) as f32;
    xs.iter()
        .map(|&x| {
            if x == 0.0 {
                return 0.0;
            }
            let mag = x.abs();
            let sgn = x.signum();
            let u = pseudo_random_unit(x);
            if mag < e_lo.exp2() {
                // stochastic underflow: keep w.p. mag/2^e_lo
                return if u < mag / e_lo.exp2() { sgn * e_lo.exp2() } else { 0.0 };
            }
            let e = mag.log2().clamp(e_lo, e_hi);
            let ef = e.floor();
            let pl = ef.exp2();
            let ph = (ef + 1.0).exp2().min(e_hi.exp2());
            let p_up = if ph > pl { (mag - pl) / (ph - pl) } else { 0.0 };
            sgn * if u < p_up { ph } else { pl }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    fn randv(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut r = Pcg32::seeded(seed);
        (0..n).map(|_| r.normal() * scale).collect()
    }

    #[test]
    fn range_respected() {
        for bits in [4u8, 8] {
            let xs = randv(512, 1, 100.0);
            let s = minmax_scale(&xs, bits);
            let q = quantize_ps(&xs, s, bits);
            for v in q {
                assert!((v as i32).abs() <= qmax(bits));
            }
        }
    }

    #[test]
    fn roundtrip_error_bounded() {
        let xs = randv(512, 2, 3.0);
        for bits in [4u8, 8] {
            let s = minmax_scale(&xs, bits);
            let q = quantize_ps(&xs, s, bits);
            let d = dequantize(&q, s);
            for (a, b) in xs.iter().zip(&d) {
                assert!((a - b).abs() <= s * 1.0001, "{a} vs {b} (s={s})");
            }
        }
    }

    #[test]
    fn grid_points_fixed() {
        // values already on the grid never move
        let s = 0.5f32;
        for k in -7..=7 {
            let x = k as f32 * s;
            assert_eq!(quantize_ps_one(x, s, 4) as i32, k);
        }
    }

    #[test]
    fn nearly_unbiased() {
        let xs = randv(200_000, 3, 2.0);
        let s = minmax_scale(&xs, 4);
        let q = quantize_ps(&xs, s, 4);
        let d = dequantize(&q, s);
        let err: f64 = xs.iter().zip(&d).map(|(a, b)| (b - a) as f64).sum();
        let mean_err = err / xs.len() as f64;
        assert!(mean_err.abs() < 0.02 * s as f64, "bias {}", mean_err);
    }

    #[test]
    fn pack_roundtrip() {
        let mut r = Pcg32::seeded(4);
        let qs: Vec<i8> = (0..256).map(|_| (r.below(16) as i8) - 8).collect();
        assert_eq!(unpack_int4(&pack_int4(&qs)), qs);
    }

    #[test]
    fn prop_pack_roundtrip() {
        crate::util::proptest::check("int4 pack roundtrip", 30, |case| {
            let n = 2 * case.usize_in(1, 64);
            let qs: Vec<i8> = (0..n).map(|_| (case.rng.below(16) as i8) - 8).collect();
            if unpack_int4(&pack_int4(&qs)) == qs {
                Ok(())
            } else {
                Err("roundtrip mismatch".into())
            }
        });
    }

    #[test]
    fn pack_roundtrip_exhaustive_all_nibble_pairs() {
        // every INT4 value pair (lo, hi) in [-8, 7]^2 — all 256 bytes —
        // must survive pack -> unpack bit-exactly
        for lo in -8i8..=7 {
            for hi in -8i8..=7 {
                let qs = vec![lo, hi];
                let packed = pack_int4(&qs);
                assert_eq!(packed.len(), 1);
                assert_eq!(unpack_int4(&packed), qs, "({lo}, {hi})");
            }
        }
    }

    #[test]
    fn pack_halves_bytes() {
        let qs = vec![1i8; 128];
        assert_eq!(pack_int4(&qs).len(), 64);
    }

    #[test]
    fn luq_powers_of_two() {
        let xs = randv(256, 5, 3.0);
        let y = quantize_luq(&xs, 4);
        for v in y {
            if v != 0.0 {
                let e = v.abs().log2();
                assert!((e - e.round()).abs() < 1e-5, "{v}");
            }
        }
    }

    #[test]
    fn luq_sign_preserved() {
        let xs = randv(256, 6, 3.0);
        let y = quantize_luq(&xs, 4);
        for (a, b) in xs.iter().zip(&y) {
            if *b != 0.0 {
                assert_eq!(a.signum(), b.signum());
            }
        }
    }

    #[test]
    fn per_row_scales() {
        let xs = vec![
            1.0, -2.0, 3.0, -4.0, // row 0: amax 4
            10.0, 20.0, -30.0, 5.0, // row 1: amax 30
        ];
        let s = minmax_scale_rows(&xs, 2, 4, 8);
        assert!((s[0] - 4.0 / 127.0).abs() < 1e-6);
        assert!((s[1] - 30.0 / 127.0).abs() < 1e-6);
    }
}
