//! Checksums for the checkpoint wire format.
//!
//! * [`crc32`] — the IEEE-802.3 reflected CRC-32 (polynomial
//!   0xEDB88320), table-driven. Guards every checkpoint blob and every
//!   per-tensor extent inside it: a single flipped byte anywhere in a
//!   blob is guaranteed to change the CRC, which is exactly the
//!   property the byte-flip rejection tests pin.
//! * [`sign`] / [`verify`] — a keyed FNV-1a-64 over the manifest's
//!   canonical JSON text. This is a *tamper-evidence* seal (a torn or
//!   hand-edited manifest cannot slip through as valid), not a
//!   cryptographic MAC: the key is fixed and public. DESIGN.md
//!   §Resilience spells out the threat model.

/// Byte-indexed CRC-32 table for the reflected IEEE polynomial, built
/// at compile time.
const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = build_table();

/// CRC-32 (IEEE, reflected) of `bytes`. Matches zlib's `crc32`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
/// Fixed signing key, mixed in ahead of the text. Versioned so a future
/// manifest revision can rotate it and old signatures stop validating.
const SIGN_KEY: &[u8] = b"hot-ckpt-manifest-v2";

/// Keyed FNV-1a-64 over `text`, rendered as 16 lowercase hex chars.
pub fn sign(text: &str) -> String {
    let mut h = FNV_OFFSET;
    for &b in SIGN_KEY.iter().chain(text.as_bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    format!("{h:016x}")
}

/// Constant-shape check of a stored signature against `text`.
pub fn verify(text: &str, sig: &str) -> bool {
    sign(text) == sig
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // zlib.crc32 reference values
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        let ramp: Vec<u8> = (0u8..32).collect();
        assert_eq!(crc32(&ramp), 0x9126_7E8A);
    }

    #[test]
    fn crc32_detects_every_single_byte_flip() {
        let base: Vec<u8> = (0u8..=255).cycle().take(1024).collect();
        let c0 = crc32(&base);
        let mut buf = base.clone();
        for off in [0usize, 1, 511, 512, 1023] {
            for bit in 0..8u8 {
                buf[off] ^= 1 << bit;
                assert_ne!(crc32(&buf), c0, "flip at {off} bit {bit}");
                buf[off] ^= 1 << bit;
            }
        }
        assert_eq!(crc32(&buf), c0);
    }

    #[test]
    fn sign_is_stable_and_sensitive() {
        let s = sign("{\"step\":3}");
        assert_eq!(s.len(), 16);
        assert_eq!(s, sign("{\"step\":3}"));
        assert_ne!(s, sign("{\"step\":4}"));
        assert!(verify("{\"step\":3}", &s));
        assert!(!verify("{\"step\":3} ", &s));
    }
}
