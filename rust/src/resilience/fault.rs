//! Deterministic fault-injection harness (`HOT_FAULT=`).
//!
//! A fault *plan* names exactly one failure and where it strikes; the
//! checkpoint writer and the trainer consult the hooks below at the
//! natural fault points. Every plan is deterministic — no randomness,
//! no timing dependence — so an integration test can arm a plan, run
//! training, and assert the exact recovery trajectory.
//!
//! Grammar (one plan per run):
//!
//! ```text
//! HOT_FAULT=corrupt-byte:<blob>:<offset>   flip one byte of the next
//!                                          written <blob> after its
//!                                          checksums were taken
//! HOT_FAULT=truncate-blob:<blob>[:keep]    write only the first <keep>
//!                                          bytes (default: half)
//! HOT_FAULT=crash-between-blobs            abort the save after the
//!                                          first blob, before the
//!                                          manifest exists
//! HOT_FAULT=nan-in-grad-at-step:<S>        poison the gradient stream
//!                                          at training step S
//! HOT_FAULT=io-error:<n>                   fail the next n blob writes
//!                                          (exercises bounded retry)
//! HOT_FAULT=slow-request:<ms>              serve: stall one batch by
//!                                          <ms> milliseconds (drives
//!                                          deadline expiry + shedding)
//! HOT_FAULT=panic-in-batch:<n>             serve: panic inside the
//!                                          n-th executed batch
//!                                          (exercises catch_unwind +
//!                                          worker replacement)
//! HOT_FAULT=corrupt-adapter:<tenant>       serve: flip a byte in that
//!                                          tenant's adapter blob at
//!                                          load time (CRC rejection +
//!                                          tenant quarantine)
//! ```
//!
//! `<blob>` is one of `params`, `m`, `v`, `manifest`. Write-site plans
//! fire once and disarm, so the *recovery* write after a rollback or a
//! re-run is clean — which is what lets the fault matrix assert
//! "train → fault → auto-resume converges".

use std::sync::Mutex;

use anyhow::{bail, Result};

/// One deterministic failure, parsed from the `HOT_FAULT` grammar.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FaultPlan {
    /// XOR 0x01 into byte `offset % len` of blob `blob` at write time,
    /// after its manifest checksums were computed (on-disk rot).
    CorruptByte { blob: String, offset: usize },
    /// Write only the first `keep` bytes of blob `blob` (`None` =
    /// half the blob) — a torn write the size check must catch.
    TruncateBlob { blob: String, keep: Option<usize> },
    /// Abort the save after the first blob and before the manifest —
    /// the classic kill -9 window; no loadable checkpoint may remain.
    CrashBetweenBlobs,
    /// Poison loss + first AdamW moment at training step `step`
    /// (what a NaN gradient leaves behind after the optimizer step).
    NanInGradAtStep { step: usize },
    /// Fail the next `failures` blob writes with a simulated I/O error.
    IoError { failures: usize },
    /// Serve: stall one batch execution by `ms` milliseconds — long
    /// enough to expire deadlines behind it and back the queue up.
    SlowRequest { ms: u64 },
    /// Serve: panic inside the `n`-th executed batch (1-based) — the
    /// worker's `catch_unwind` wall must absorb it.
    PanicInBatch { n: usize },
    /// Serve: flip a byte in `tenant`'s adapter params at load time so
    /// the manifest/CRC path rejects it and the tenant is quarantined.
    CorruptAdapter { tenant: String },
}

struct Armed {
    plan: FaultPlan,
    /// `IoError` counts down; every other plan fires once.
    remaining: usize,
}

fn slot() -> &'static Mutex<Option<Armed>> {
    static SLOT: Mutex<Option<Armed>> = Mutex::new(None);
    &SLOT
}

fn blob_kind(s: &str) -> Result<String> {
    match s {
        "params" | "m" | "v" | "manifest" => Ok(s.to_string()),
        other => bail!("HOT_FAULT: unknown blob {other:?} \
                        (want params|m|v|manifest)"),
    }
}

/// Parse one plan from the `HOT_FAULT` grammar.
pub fn parse(plan: &str) -> Result<FaultPlan> {
    let parts: Vec<&str> = plan.split(':').collect();
    match parts.as_slice() {
        ["corrupt-byte", blob, off] => Ok(FaultPlan::CorruptByte {
            blob: blob_kind(blob)?,
            offset: off.parse().map_err(|_| {
                anyhow::anyhow!("HOT_FAULT: bad offset {off:?}")
            })?,
        }),
        ["truncate-blob", blob] => Ok(FaultPlan::TruncateBlob {
            blob: blob_kind(blob)?,
            keep: None,
        }),
        ["truncate-blob", blob, keep] => Ok(FaultPlan::TruncateBlob {
            blob: blob_kind(blob)?,
            keep: Some(keep.parse().map_err(|_| {
                anyhow::anyhow!("HOT_FAULT: bad keep {keep:?}")
            })?),
        }),
        ["crash-between-blobs"] => Ok(FaultPlan::CrashBetweenBlobs),
        ["nan-in-grad-at-step", s] | ["nan-in-grad-at-step-S", s] => {
            Ok(FaultPlan::NanInGradAtStep {
                step: s.parse().map_err(|_| {
                    anyhow::anyhow!("HOT_FAULT: bad step {s:?}")
                })?,
            })
        }
        ["io-error", n] | ["io-error-with-retry", n] => {
            Ok(FaultPlan::IoError {
                failures: n.parse().map_err(|_| {
                    anyhow::anyhow!("HOT_FAULT: bad count {n:?}")
                })?,
            })
        }
        ["slow-request", ms] => Ok(FaultPlan::SlowRequest {
            ms: ms.parse().map_err(|_| {
                anyhow::anyhow!("HOT_FAULT: bad millis {ms:?}")
            })?,
        }),
        ["panic-in-batch", n] => Ok(FaultPlan::PanicInBatch {
            n: n.parse().map_err(|_| {
                anyhow::anyhow!("HOT_FAULT: bad batch index {n:?}")
            })?,
        }),
        ["corrupt-adapter", tenant] => Ok(FaultPlan::CorruptAdapter {
            tenant: tenant.to_string(),
        }),
        _ => bail!("HOT_FAULT: unknown plan {plan:?}"),
    }
}

/// Arm `plan` (replacing any armed plan).
pub fn arm(plan: FaultPlan) {
    let remaining = match &plan {
        FaultPlan::IoError { failures } => *failures,
        // counts executed batches down to the one that panics
        FaultPlan::PanicInBatch { n } => *n,
        _ => 1,
    };
    *slot().lock().unwrap() = Some(Armed { plan, remaining });
}

/// Disarm whatever is armed.
pub fn disarm() {
    *slot().lock().unwrap() = None;
}

/// Arm from the `HOT_FAULT` env var, erroring loudly on a bad plan
/// string (a silently ignored fault plan would fake test coverage).
pub fn init_from_env() -> Result<()> {
    if let Ok(s) = std::env::var("HOT_FAULT") {
        if !s.is_empty() {
            let plan = parse(&s)?;
            crate::warn_!("fault injection armed: {plan:?}");
            arm(plan);
        }
    }
    Ok(())
}

/// The armed plan, if any (diagnostics).
pub fn armed() -> Option<FaultPlan> {
    slot().lock().unwrap().as_ref().map(|a| a.plan.clone())
}

/// Serializes unit tests that arm plans or drive write paths that
/// consult the hooks — the slot is process-global and the cargo test
/// harness is multi-threaded.
#[cfg(test)]
pub fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------------------
// hooks — called from the fault points
// ---------------------------------------------------------------------------

/// Checkpoint writer hook: mutate `bytes` of blob `kind` in place if a
/// corruption plan targets it. Returns a description when it fired.
pub fn mutate_blob(kind: &str, bytes: &mut Vec<u8>) -> Option<String> {
    let mut g = slot().lock().unwrap();
    let armed = g.as_ref()?;
    let desc = match &armed.plan {
        FaultPlan::CorruptByte { blob, offset } if blob == kind => {
            if bytes.is_empty() {
                return None;
            }
            let off = offset % bytes.len();
            bytes[off] ^= 0x01;
            format!("corrupt-byte fired: {kind} byte {off}")
        }
        FaultPlan::TruncateBlob { blob, keep } if blob == kind => {
            let keep = keep.unwrap_or(bytes.len() / 2).min(bytes.len());
            bytes.truncate(keep);
            format!("truncate-blob fired: {kind} kept {keep} bytes")
        }
        _ => return None,
    };
    *g = None; // fired once
    Some(desc)
}

/// Checkpoint writer hook between blob writes: `true` exactly once if
/// `crash-between-blobs` is armed — the caller must abandon the save.
pub fn crash_between_blobs() -> bool {
    let mut g = slot().lock().unwrap();
    if matches!(g.as_ref().map(|a| &a.plan),
                Some(FaultPlan::CrashBetweenBlobs)) {
        *g = None;
        return true;
    }
    false
}

/// Blob-write hook: simulated I/O failure while the armed `io-error`
/// budget lasts. Returns the error description to surface.
pub fn io_error(label: &str) -> Option<String> {
    let mut g = slot().lock().unwrap();
    let armed = g.as_mut()?;
    if !matches!(armed.plan, FaultPlan::IoError { .. }) {
        return None;
    }
    if armed.remaining == 0 {
        *g = None;
        return None;
    }
    armed.remaining -= 1;
    let left = armed.remaining;
    if left == 0 {
        *g = None;
    }
    Some(format!("injected io error writing {label} ({left} more)"))
}

/// Trainer hook: `true` exactly once when the armed plan poisons the
/// gradient stream at `step`.
pub fn nan_in_grad(step: usize) -> bool {
    let mut g = slot().lock().unwrap();
    if matches!(g.as_ref().map(|a| &a.plan),
                Some(FaultPlan::NanInGradAtStep { step: s }) if *s == step) {
        *g = None;
        return true;
    }
    false
}

/// Serve worker hook: the stall in milliseconds, exactly once, when
/// `slow-request` is armed. The caller sleeps; this only reports.
pub fn slow_request() -> Option<u64> {
    let mut g = slot().lock().unwrap();
    if let Some(FaultPlan::SlowRequest { ms }) = g.as_ref().map(|a| &a.plan) {
        let ms = *ms;
        *g = None;
        return Some(ms);
    }
    None
}

/// Serve worker hook, called once per executed batch: `true` exactly
/// once, on the n-th call since arming — the caller must panic there
/// (inside its `catch_unwind` wall).
pub fn panic_in_batch() -> bool {
    let mut g = slot().lock().unwrap();
    let Some(armed) = g.as_mut() else { return false };
    if !matches!(armed.plan, FaultPlan::PanicInBatch { .. }) {
        return false;
    }
    armed.remaining = armed.remaining.saturating_sub(1);
    if armed.remaining == 0 {
        *g = None;
        return true;
    }
    false
}

/// Adapter-load hook: `true` exactly once when `corrupt-adapter` is
/// armed for `tenant` — the caller flips a byte in the adapter params
/// *before* CRC validation, so the manifest path rejects the load.
pub fn corrupt_adapter(tenant: &str) -> bool {
    let mut g = slot().lock().unwrap();
    if matches!(g.as_ref().map(|a| &a.plan),
                Some(FaultPlan::CorruptAdapter { tenant: t }) if t == tenant) {
        *g = None;
        return true;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grammar_parses() {
        assert_eq!(parse("corrupt-byte:params:64").unwrap(),
                   FaultPlan::CorruptByte { blob: "params".into(),
                                            offset: 64 });
        assert_eq!(parse("truncate-blob:m").unwrap(),
                   FaultPlan::TruncateBlob { blob: "m".into(), keep: None });
        assert_eq!(parse("truncate-blob:v:17").unwrap(),
                   FaultPlan::TruncateBlob { blob: "v".into(),
                                             keep: Some(17) });
        assert_eq!(parse("crash-between-blobs").unwrap(),
                   FaultPlan::CrashBetweenBlobs);
        assert_eq!(parse("nan-in-grad-at-step:3").unwrap(),
                   FaultPlan::NanInGradAtStep { step: 3 });
        assert_eq!(parse("io-error:2").unwrap(),
                   FaultPlan::IoError { failures: 2 });
        assert_eq!(parse("io-error-with-retry:2").unwrap(),
                   FaultPlan::IoError { failures: 2 });
        assert_eq!(parse("slow-request:250").unwrap(),
                   FaultPlan::SlowRequest { ms: 250 });
        assert_eq!(parse("panic-in-batch:2").unwrap(),
                   FaultPlan::PanicInBatch { n: 2 });
        assert_eq!(parse("corrupt-adapter:tenant-7").unwrap(),
                   FaultPlan::CorruptAdapter { tenant: "tenant-7".into() });
        assert!(parse("corrupt-byte:weights:1").is_err());
        assert!(parse("slow-request:fast").is_err());
        assert!(parse("panic-in-batch:maybe").is_err());
        assert!(parse("meteor-strike").is_err());
    }

    // Hook semantics share the process-global slot, so they run as one
    // sequential test under the slot's test lock.
    #[test]
    fn hooks_fire_once_and_disarm() {
        let _g = test_lock();
        disarm();

        arm(FaultPlan::CorruptByte { blob: "params".into(), offset: 1000 });
        let mut b = vec![0u8; 8];
        assert!(mutate_blob("m", &mut b).is_none(), "wrong blob untouched");
        assert!(mutate_blob("params", &mut b).is_some());
        assert_eq!(b[1000 % 8], 0x01, "offset wraps modulo len");
        assert!(mutate_blob("params", &mut b).is_none(), "fired once");

        arm(FaultPlan::TruncateBlob { blob: "v".into(), keep: None });
        let mut b = vec![7u8; 10];
        assert!(mutate_blob("v", &mut b).is_some());
        assert_eq!(b.len(), 5, "default keep = half");

        arm(FaultPlan::CrashBetweenBlobs);
        assert!(crash_between_blobs());
        assert!(!crash_between_blobs(), "fired once");

        arm(FaultPlan::IoError { failures: 2 });
        assert!(io_error("x").is_some());
        assert!(io_error("x").is_some());
        assert!(io_error("x").is_none(), "budget exhausted -> disarmed");

        arm(FaultPlan::NanInGradAtStep { step: 3 });
        assert!(!nan_in_grad(2));
        assert!(nan_in_grad(3));
        assert!(!nan_in_grad(3), "fired once");

        arm(FaultPlan::SlowRequest { ms: 40 });
        assert_eq!(slow_request(), Some(40));
        assert_eq!(slow_request(), None, "fired once");

        arm(FaultPlan::PanicInBatch { n: 2 });
        assert!(!panic_in_batch(), "batch 1 clean");
        assert!(panic_in_batch(), "batch 2 panics");
        assert!(!panic_in_batch(), "fired once");

        arm(FaultPlan::CorruptAdapter { tenant: "t1".into() });
        assert!(!corrupt_adapter("t0"), "wrong tenant untouched");
        assert!(corrupt_adapter("t1"));
        assert!(!corrupt_adapter("t1"), "fired once");

        disarm();
    }
}
